//! Versioned resubmit: the mutable-dataset write path.
//!
//! The paper's library submits each dataset exactly once (§V), but its
//! target applications checkpoint *evolving* state every iteration —
//! k-means centroids, PageRank rank vectors, RAxML model state. This
//! module turns the write-once store into a versioned-mutable one:
//!
//! - **Delta detection.** A resubmit re-replicates only changed blocks.
//!   The caller either supplies a dirty [`RangeSet`] outright
//!   ([`ResubmitMode::Dirty`] — O(dirty) work, no hashing) or asks the
//!   store to diff the new shards against the per-block checksums latched
//!   at the previous submit ([`ResubmitMode::DeltaByChecksum`] — one
//!   checksum per block, no byte compares against remote copies).
//!
//! - **Double-buffered replication.** New-version replica slices land in
//!   a *staging* store (`Dataset::staging`) while the committed stores
//!   keep serving loads — the GASPI async one-sided checkpointing shape
//!   (arXiv:1505.04628): the copy overlaps the application's next compute
//!   step ([`Overlap::Compute`]), and only the *exposed* remainder
//!   `max(0, t_repl − t_compute)` costs wall-clock.
//!
//! - **Epoch-tagged atomic commit.** A version counter sits beside the
//!   communicator epoch. Failures or reconfigurations observed at any
//!   [`ResubmitStep`] boundary abort the resubmit by dropping the staging
//!   wholesale ([`Error::ResubmitAborted`]): loads keep serving the
//!   previous committed version byte-exactly, never a torn mix. Only the
//!   commit step — a local buffer swap, atomic in the simulator — moves
//!   the version forward.
//!
//! A shape-changing variant ([`Dataset::resubmit_reshaped`]) publishes a
//! version with a different block count: it stages a complete fresh §IV-A
//! layout (over `min(p, n')` of the current ranks) and swaps it in at
//! commit, resetting the scrub cursor to the new, possibly smaller slot
//! space.

use crate::error::{Error, Result};
use crate::restore::block::{BlockRange, RangeSet};
use crate::restore::distribution::{Distribution, PermutedPiece};
use crate::restore::registry::{Dataset, StagedLayout, Staging};
use crate::restore::store::{checksum_of, HolderIndex, PeStore, SliceBuf};
use crate::simnet::cluster::Cluster;
use crate::simnet::network::{Accumulator, PhaseCost};

/// Which blocks of the new version differ from the committed one.
#[derive(Debug, Clone, Copy)]
pub enum ResubmitMode<'a> {
    /// Re-replicate every block (a full checkpoint).
    Full,
    /// The caller knows exactly which *original* block IDs changed (e.g.
    /// the iteration's write set); only those are re-replicated, with no
    /// hashing — O(dirty) work regardless of the dataset size.
    Dirty(&'a RangeSet),
    /// Diff the new shards against the per-block checksums latched at the
    /// previous commit; blocks whose checksum is unchanged are skipped.
    /// Execution mode only (cost-model datasets carry no sums).
    DeltaByChecksum,
}

/// How the replication phase is charged against the simulated clock.
#[derive(Debug, Clone, Copy)]
pub enum Overlap {
    /// Synchronous checkpoint: the full replication cost advances the
    /// clock before resubmit returns.
    Blocking,
    /// GASPI-style overlap: the application's next compute step takes the
    /// given seconds and runs concurrently with replication, so only the
    /// *exposed* remainder `max(0, t_repl − t_compute)` advances the
    /// clock. The caller charges its compute step itself (e.g. via
    /// `Cluster::tick_compute`), exactly as it would without
    /// checkpointing.
    Compute(f64),
}

/// Boundaries of the resubmit state machine at which a fault-injection
/// callback runs (mirroring `ReshapeStep`/`RecoveryStep` from the
/// recovery machinery). After every pre-commit boundary the resubmit
/// revalidates the epoch and every participant; a violation aborts to the
/// previous committed version ([`Error::ResubmitAborted`]). A kill at
/// [`ResubmitStep::Committed`] is an ordinary post-commit failure — the
/// new version is already live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResubmitStep {
    /// Inputs validated; nothing staged yet.
    Validated,
    /// The new version's replica slices sit in the staging store; loads
    /// still serve the committed version.
    Staged,
    /// Replication cost charged (blocking or overlap-exposed).
    Charged,
    /// Version counter bumped; the new version is the committed one.
    Committed,
}

impl ResubmitStep {
    /// Every boundary, in order — what an exhaustive kill-at-every-step
    /// test iterates.
    pub const ALL: [ResubmitStep; 4] = [
        ResubmitStep::Validated,
        ResubmitStep::Staged,
        ResubmitStep::Charged,
        ResubmitStep::Committed,
    ];
}

/// What a committed resubmit did and cost.
#[derive(Debug, Clone)]
pub struct ResubmitReport {
    /// The version this resubmit committed (previous committed + 1).
    pub version: u64,
    /// Original-ID blocks re-replicated (the dirty set's cardinality; the
    /// full block count for `Full`/reshaped resubmits).
    pub dirty_blocks: u64,
    /// Total replicated payload: Σ over dirty pieces of `len · b` bytes
    /// per holder copy.
    pub replicated_bytes: u64,
    /// Full replication cost (serialization copy + sparse all-to-all),
    /// independent of how much of it the overlap hid.
    pub cost: PhaseCost,
    /// Wall-clock the clock actually advanced for replication:
    /// `cost.sim_time_s` when [`Overlap::Blocking`], the exposed
    /// remainder under [`Overlap::Compute`].
    pub exposed_s: f64,
}

/// Scratch for the per-source message coalescing of the staging walk:
/// dense per-destination byte/fragment tallies plus the touched list, so
/// one (src, dst) pair costs exactly one message no matter how many dirty
/// pieces it carries — the same coalescing submit applies.
struct Coalesce {
    dst_bytes: Vec<u64>,
    dst_pieces: Vec<u64>,
    touched: Vec<u32>,
}

impl Coalesce {
    fn new(machine_world: usize) -> Self {
        Coalesce {
            dst_bytes: vec![0; machine_world],
            dst_pieces: vec![0; machine_world],
            touched: Vec::new(),
        }
    }

    fn add(&mut self, dst: usize, bytes: u64) {
        if self.dst_bytes[dst] == 0 {
            self.touched.push(dst as u32);
        }
        self.dst_bytes[dst] += bytes;
        self.dst_pieces[dst] += 1;
    }

    /// Emit one coalesced message per touched destination of source
    /// `src`, then clear — submit's granularity: `msg` even when
    /// `dst == src` (the accumulator models that as a local copy),
    /// fragments on both endpoints, the source's only once.
    fn flush(&mut self, src: usize, acc: &mut Accumulator) {
        for &d in &self.touched {
            let d = d as usize;
            acc.msg(src, d, self.dst_bytes[d]);
            acc.frag(src, self.dst_pieces[d]);
            if d != src {
                acc.frag(d, self.dst_pieces[d]);
            }
            self.dst_bytes[d] = 0;
            self.dst_pieces[d] = 0;
        }
        self.touched.clear();
    }
}

/// Where a resubmit's new bytes come from. The write paths differ only in
/// how a dirty piece's payload is addressed: per-rank shards index within
/// the owner's slice, a flat image indexes by original block id, and the
/// cost-model variant materializes nothing.
#[derive(Clone, Copy)]
enum NewBytes<'a> {
    /// `shards[j]` is distribution rank `j`'s serialized shard
    /// (`slice_len(j) · block_size` bytes) — the decomposed form apps
    /// that keep per-rank state use ([`Dataset::resubmit`]).
    PerRank(&'a [Vec<u8>]),
    /// One flat buffer of the whole dataset in original block order
    /// (`n_blocks · block_size` bytes) — the form a KV image or a
    /// reshaped checkpoint naturally holds ([`Dataset::resubmit_flat`]).
    Flat(&'a [u8]),
    /// Cost-model: schedules and costs only ([`Dataset::resubmit_virtual`]).
    Virtual,
}

impl NewBytes<'_> {
    fn is_real(&self) -> bool {
        !matches!(self, NewBytes::Virtual)
    }
}

impl Dataset {
    /// Publish a new version of this dataset's data (same block count and
    /// layout): re-replicate the blocks `mode` marks dirty into a staging
    /// store, charge the copy per `overlap`, and commit atomically.
    /// `shards[j]` is distribution rank `j`'s serialized shard
    /// (`slice_len(j) · block_size` bytes — the same partition `shard_of`
    /// describes, also after a rebalance). Execution mode only; the
    /// cost-model twin is [`Dataset::resubmit_virtual`].
    pub fn resubmit(
        &mut self,
        cluster: &mut Cluster,
        shards: &[Vec<u8>],
        mode: ResubmitMode<'_>,
        overlap: Overlap,
    ) -> Result<ResubmitReport> {
        self.resubmit_with_faults(cluster, shards, mode, overlap, &mut |_, _| {})
    }

    /// [`Dataset::resubmit`] with a fault-injection callback fired at
    /// every [`ResubmitStep`] boundary (the torn-resubmit test surface).
    pub fn resubmit_with_faults(
        &mut self,
        cluster: &mut Cluster,
        shards: &[Vec<u8>],
        mode: ResubmitMode<'_>,
        overlap: Overlap,
        inject: &mut dyn FnMut(ResubmitStep, &mut Cluster),
    ) -> Result<ResubmitReport> {
        self.resubmit_inner(cluster, NewBytes::PerRank(shards), mode, overlap, inject)
    }

    /// [`Dataset::resubmit`] taking the new content as ONE flat buffer in
    /// original block order (`n_blocks · block_size` bytes) instead of
    /// per-rank shards — the natural form for callers that keep a single
    /// authoritative image (the KV write path, [`crate::restore::kv`]).
    /// Identical semantics, staging, costs, and abort behavior.
    pub fn resubmit_flat(
        &mut self,
        cluster: &mut Cluster,
        flat: &[u8],
        mode: ResubmitMode<'_>,
        overlap: Overlap,
    ) -> Result<ResubmitReport> {
        self.resubmit_flat_with_faults(cluster, flat, mode, overlap, &mut |_, _| {})
    }

    /// [`Dataset::resubmit_flat`] with the boundary fault callback.
    pub fn resubmit_flat_with_faults(
        &mut self,
        cluster: &mut Cluster,
        flat: &[u8],
        mode: ResubmitMode<'_>,
        overlap: Overlap,
        inject: &mut dyn FnMut(ResubmitStep, &mut Cluster),
    ) -> Result<ResubmitReport> {
        self.resubmit_inner(cluster, NewBytes::Flat(flat), mode, overlap, inject)
    }

    /// Cost-model resubmit: schedules and costs are identical to the
    /// execution-mode [`Dataset::resubmit`] of the same dirty set, but no
    /// bytes are materialized. Cost-model datasets carry no checksums, so
    /// the dirty set is always explicit.
    pub fn resubmit_virtual(
        &mut self,
        cluster: &mut Cluster,
        dirty: &RangeSet,
        overlap: Overlap,
    ) -> Result<ResubmitReport> {
        let mode = ResubmitMode::Dirty(dirty);
        self.resubmit_inner(cluster, NewBytes::Virtual, mode, overlap, &mut |_, _| {})
    }

    fn resubmit_inner(
        &mut self,
        cluster: &mut Cluster,
        bytes: NewBytes<'_>,
        mode: ResubmitMode<'_>,
        overlap: Overlap,
        inject: &mut dyn FnMut(ResubmitStep, &mut Cluster),
    ) -> Result<ResubmitReport> {
        self.ensure_submitted()?;
        self.ensure_current_epoch(cluster)?;
        if bytes.is_real() != self.execution {
            return Err(Error::Config(if self.execution {
                "resubmit_virtual on an execution-mode dataset: use resubmit (real shards)".into()
            } else {
                "resubmit with real shards on a cost-model dataset: use resubmit_virtual".into()
            }));
        }
        if let Overlap::Compute(t) = overlap {
            if !t.is_finite() || t < 0.0 {
                return Err(Error::Config(format!("resubmit overlap compute time {t} invalid")));
            }
        }
        let bs = self.cfg.block_size as u64;
        match bytes {
            NewBytes::PerRank(shards) => {
                if shards.len() != self.dist.world() {
                    return Err(Error::Config(format!(
                        "resubmit: got {} shards for distribution world {}",
                        shards.len(),
                        self.dist.world()
                    )));
                }
                for (j, s) in shards.iter().enumerate() {
                    let want = (self.dist.slice_len(j) * bs) as usize;
                    if s.len() != want {
                        return Err(Error::Config(format!(
                            "resubmit: rank {j} shard has {} bytes, expected {want}",
                            s.len()
                        )));
                    }
                }
            }
            NewBytes::Flat(flat) => {
                let want = (self.dist.n_blocks() * bs) as usize;
                if flat.len() != want {
                    return Err(Error::Config(format!(
                        "resubmit_flat: image has {} bytes, expected {want} \
                         (n_blocks · block_size)",
                        flat.len()
                    )));
                }
            }
            NewBytes::Virtual => {}
        }
        self.check_resubmit_participants(cluster)?;

        inject(ResubmitStep::Validated, cluster);
        if !self.resubmit_still_valid(cluster) {
            return Err(self.abort_resubmit());
        }

        // Resolve the dirty set (original block IDs).
        let n = self.dist.n_blocks();
        let owned: RangeSet;
        let dirty: &RangeSet = match mode {
            ResubmitMode::Full => {
                owned = RangeSet::new(vec![BlockRange::new(0, n)]);
                &owned
            }
            ResubmitMode::Dirty(set) => {
                if set.ranges().last().is_some_and(|r| r.end > n) {
                    return Err(Error::Config(format!(
                        "resubmit: dirty set extends past the dataset's {n} blocks"
                    )));
                }
                set
            }
            ResubmitMode::DeltaByChecksum => {
                owned = match bytes {
                    NewBytes::PerRank(shards) => self.delta_by_checksum(shards),
                    NewBytes::Flat(flat) => self.delta_by_checksum_flat(flat),
                    NewBytes::Virtual => {
                        return Err(Error::Config(
                            "checksum-delta resubmit needs real shards; cost-model datasets \
                             pass an explicit dirty set"
                                .into(),
                        ));
                    }
                };
                &owned
            }
        };

        // Stage: build the new version's replica slices next to (never
        // inside) the committed stores, and accumulate the sparse
        // all-to-all cost of shipping them — one coalesced message per
        // (source, holder) pair, exactly submit's granularity.
        let dist = self.dist.clone();
        let machine = self.stores.len();
        let mut staged: Vec<PeStore> =
            (0..machine).map(|_| PeStore::new(self.cfg.block_size)).collect();
        let mut acc = Accumulator::new(cluster.network(), cluster.topology());
        let mut co = Coalesce::new(machine);
        let mut pieces: Vec<PermutedPiece> = Vec::new();
        let mut replicated = 0u64;
        let mut max_src_bytes = 0u64;
        let mut cur_src_bytes = 0u64;
        let mut cur_src: Option<usize> = None;
        for range in dirty.ranges() {
            let mut cur = range.start;
            while cur < range.end {
                // Owner of original block `cur`: shard partition boundaries
                // coincide with slice boundaries in original ID space.
                let j = dist.slice_of(cur);
                let stop = range.end.min(dist.slice_end(j));
                let src = self.pe_map[j] as usize;
                if cur_src != Some(src) {
                    if let Some(s) = cur_src {
                        co.flush(s, &mut acc);
                        max_src_bytes = max_src_bytes.max(cur_src_bytes);
                        cur_src_bytes = 0;
                    }
                    cur_src = Some(src);
                }
                cur_src_bytes += (stop - cur) * bs;
                pieces.clear();
                dist.permuted_pieces(BlockRange::new(cur, stop), &mut pieces);
                for pc in &pieces {
                    let slot = dist.slice_of(pc.perm_start);
                    let holders = self.holder_index.holders_of(slot);
                    if holders.is_empty() {
                        // Every copy of this slot is lost/quarantined; a new
                        // version cannot be placed until repair re-creates
                        // holders. Nothing staged has committed — clean abort.
                        return Err(Error::IrrecoverableDataLoss {
                            dataset: self.id,
                            start: pc.perm_start,
                            end: pc.perm_start + pc.len,
                        });
                    }
                    let piece_bytes = pc.len * bs;
                    let prange = BlockRange::new(pc.perm_start, pc.perm_start + pc.len);
                    for &h in holders {
                        let d = h as usize;
                        co.add(d, piece_bytes);
                        replicated += piece_bytes;
                        let buf = match bytes {
                            NewBytes::PerRank(shards) => {
                                let off =
                                    ((pc.orig_start - dist.slice_start(j)) * bs) as usize;
                                SliceBuf::Real(
                                    shards[j][off..off + piece_bytes as usize].to_vec(),
                                )
                            }
                            NewBytes::Flat(flat) => {
                                let off = (pc.orig_start * bs) as usize;
                                SliceBuf::Real(
                                    flat[off..off + piece_bytes as usize].to_vec(),
                                )
                            }
                            NewBytes::Virtual => SliceBuf::Virtual(piece_bytes),
                        };
                        staged[d].insert(prange, buf);
                    }
                }
                cur = stop;
            }
        }
        if let Some(s) = cur_src {
            co.flush(s, &mut acc);
            max_src_bytes = max_src_bytes.max(cur_src_bytes);
        }
        let dirty_blocks = dirty.total_blocks();
        self.staging = Some(Staging {
            stores: staged,
            version: self.version + 1,
            dirty_blocks,
            replicated_bytes: replicated,
            new_layout: None,
        });

        inject(ResubmitStep::Staged, cluster);
        if !self.resubmit_still_valid(cluster) {
            return Err(self.abort_resubmit());
        }

        // Charge: local serialization of each source's dirty bytes (the
        // §IV-C doubled-memory copy, bottlenecked by the largest source)
        // then the replication all-to-all, overlapped per `overlap`.
        let ser_cost = PhaseCost::local_copy(cluster.network(), max_src_bytes);
        let cost = ser_cost.then(acc.finish());
        let exposed_s = match overlap {
            Overlap::Blocking => {
                cluster.advance(&cost);
                cost.sim_time_s
            }
            Overlap::Compute(t) => {
                let exposed = (cost.sim_time_s - t).max(0.0);
                cluster.tick_compute(exposed);
                exposed
            }
        };

        inject(ResubmitStep::Charged, cluster);
        if !self.resubmit_still_valid(cluster) {
            return Err(self.abort_resubmit());
        }

        // Commit: drain the staged slices into the committed stores — a
        // local swap, atomic in the simulator. `write_from` re-latches the
        // per-block checksums, so scrub/load verification tracks the new
        // version with no cursor disturbance (the slot space is unchanged).
        let staging = self.staging.take().expect("staged above");
        for (pe, st) in staging.stores.iter().enumerate() {
            for sl in st.slices() {
                if let SliceBuf::Real(bytes) = &sl.buf {
                    self.stores[pe].write_from(sl.range.start, bytes);
                }
            }
        }
        self.version = staging.version;

        inject(ResubmitStep::Committed, cluster);

        Ok(ResubmitReport {
            version: self.version,
            dirty_blocks,
            replicated_bytes: replicated,
            cost,
            exposed_s,
        })
    }

    /// Publish a new version with a *different block count* (always a full
    /// checkpoint): stages a complete fresh §IV-A layout over
    /// `min(p, n')` of the dataset's current ranks and swaps it in at
    /// commit, resetting the scrub cursor to the new slot space.
    /// `global` is the new serialized content (`n' · block_size` bytes).
    pub fn resubmit_reshaped(
        &mut self,
        cluster: &mut Cluster,
        global: &[u8],
        overlap: Overlap,
    ) -> Result<ResubmitReport> {
        self.resubmit_reshaped_with_faults(cluster, global, overlap, &mut |_, _| {})
    }

    /// [`Dataset::resubmit_reshaped`] with the boundary fault callback.
    pub fn resubmit_reshaped_with_faults(
        &mut self,
        cluster: &mut Cluster,
        global: &[u8],
        overlap: Overlap,
        inject: &mut dyn FnMut(ResubmitStep, &mut Cluster),
    ) -> Result<ResubmitReport> {
        self.ensure_submitted()?;
        self.ensure_current_epoch(cluster)?;
        if !self.execution {
            return Err(Error::Config(
                "resubmit_reshaped needs real bytes (execution mode)".into(),
            ));
        }
        if let Overlap::Compute(t) = overlap {
            if !t.is_finite() || t < 0.0 {
                return Err(Error::Config(format!("resubmit overlap compute time {t} invalid")));
            }
        }
        let bs = self.cfg.block_size as u64;
        if global.is_empty() || global.len() as u64 % bs != 0 {
            return Err(Error::Config(format!(
                "resubmit_reshaped: {} bytes is not a positive multiple of block size {bs}",
                global.len()
            )));
        }
        let n_new = global.len() as u64 / bs;
        let r = self.dist.replicas();
        let world_new = (self.dist.world() as u64).min(n_new) as usize;
        if world_new < r {
            return Err(Error::Config(format!(
                "resubmit_reshaped: {n_new} blocks cannot carry r = {r} replicas over \
                 {world_new} ranks"
            )));
        }
        let s_pr = self.cfg.perm_range_blocks.map(|s| s as u64);
        let dist_new = Distribution::new_balanced(
            world_new,
            n_new,
            r,
            s_pr,
            self.cfg.seed,
            self.cfg.placement_offset,
        )?;
        let pe_map_new: Vec<u32> = self.pe_map[..world_new].to_vec();
        for &pe in &pe_map_new {
            if !cluster.is_alive(pe as usize) {
                return Err(Error::DeadPe(pe as usize));
            }
        }

        inject(ResubmitStep::Validated, cluster);
        if !(self.epoch == cluster.epoch()
            && pe_map_new.iter().all(|&pe| cluster.is_alive(pe as usize)))
        {
            return Err(self.abort_resubmit());
        }

        // Stage the complete new layout: every rank's r slices, built by
        // un-permuting the global buffer, plus a fresh holder index.
        let machine = self.stores.len();
        let mut staged: Vec<PeStore> =
            (0..machine).map(|_| PeStore::new(self.cfg.block_size)).collect();
        let mut hi_new = HolderIndex::new(world_new);
        let mut replicated = 0u64;
        for j in 0..world_new {
            for k in 0..r {
                let range = dist_new.stored_slice(j, k);
                let slot = dist_new.slice_of(range.start);
                let pe = pe_map_new[j] as usize;
                let mut buf = vec![0u8; (range.len() * bs) as usize];
                for (i, y) in (range.start..range.end).enumerate() {
                    let x = dist_new.unpermute_block(y) as usize;
                    buf[i * bs as usize..(i + 1) * bs as usize]
                        .copy_from_slice(&global[x * bs as usize..(x + 1) * bs as usize]);
                }
                staged[pe].insert(range, SliceBuf::Real(buf));
                hi_new.insert(slot, pe);
                replicated += range.len() * bs;
            }
        }
        // Cost: each new owner scatters its new shard to the r holders of
        // every piece, coalesced per (source, destination) like submit.
        let mut acc = Accumulator::new(cluster.network(), cluster.topology());
        let mut co = Coalesce::new(machine);
        let mut pieces: Vec<PermutedPiece> = Vec::new();
        let mut max_src_bytes = 0u64;
        for j in 0..world_new {
            let src = pe_map_new[j] as usize;
            max_src_bytes = max_src_bytes.max(dist_new.slice_len(j) * bs);
            pieces.clear();
            dist_new.permuted_pieces(dist_new.shard_of(j), &mut pieces);
            for pc in &pieces {
                for k in 0..r {
                    let dst = pe_map_new[dist_new.holder(pc.perm_start, k)] as usize;
                    co.add(dst, pc.len * bs);
                }
            }
            co.flush(src, &mut acc);
        }
        self.staging = Some(Staging {
            stores: staged,
            version: self.version + 1,
            dirty_blocks: n_new,
            replicated_bytes: replicated,
            new_layout: Some(StagedLayout {
                dist: dist_new,
                pe_map: pe_map_new.clone(),
                holder_index: hi_new,
            }),
        });

        inject(ResubmitStep::Staged, cluster);
        if !(self.epoch == cluster.epoch()
            && pe_map_new.iter().all(|&pe| cluster.is_alive(pe as usize)))
        {
            return Err(self.abort_resubmit());
        }

        let ser_cost = PhaseCost::local_copy(cluster.network(), max_src_bytes);
        let cost = ser_cost.then(acc.finish());
        let exposed_s = match overlap {
            Overlap::Blocking => {
                cluster.advance(&cost);
                cost.sim_time_s
            }
            Overlap::Compute(t) => {
                let exposed = (cost.sim_time_s - t).max(0.0);
                cluster.tick_compute(exposed);
                exposed
            }
        };

        inject(ResubmitStep::Charged, cluster);
        if !(self.epoch == cluster.epoch()
            && pe_map_new.iter().all(|&pe| cluster.is_alive(pe as usize)))
        {
            return Err(self.abort_resubmit());
        }

        // Commit: the staged stores ARE the new version's stores — swap the
        // whole layout in atomically and restart the scrub walk in the new
        // (possibly smaller) slot space.
        let staging = self.staging.take().expect("staged above");
        let layout = staging.new_layout.expect("reshaped staging carries a layout");
        let version = staging.version;
        self.install_layout(
            cluster,
            layout.dist,
            layout.pe_map,
            staging.stores,
            layout.holder_index,
        );
        self.scrub_slot = 0;
        self.version = version;

        inject(ResubmitStep::Committed, cluster);

        Ok(ResubmitReport {
            version: self.version,
            dirty_blocks: n_new,
            replicated_bytes: replicated,
            cost,
            exposed_s,
        })
    }

    /// Diff new shards against the committed per-block checksums: a block
    /// is dirty when no surviving holder's latched sum matches the new
    /// content's checksum.
    fn delta_by_checksum(&self, shards: &[Vec<u8>]) -> RangeSet {
        let bs = self.cfg.block_size as u64;
        let mut runs: Vec<BlockRange> = Vec::new();
        for j in 0..self.dist.world() {
            let shard = self.dist.shard_of(j);
            for x in shard.start..shard.end {
                let off = ((x - shard.start) * bs) as usize;
                let blk = &shards[j][off..off + bs as usize];
                let y = self.dist.permute_block(x);
                let slot = self.dist.slice_of(y);
                let committed = self
                    .holder_index
                    .holders_of(slot)
                    .iter()
                    .find_map(|&h| self.stores[h as usize].block_sum(y));
                if committed != Some(checksum_of(y, blk)) {
                    match runs.last_mut() {
                        Some(last) if last.end == x => last.end = x + 1,
                        _ => runs.push(BlockRange::new(x, x + 1)),
                    }
                }
            }
        }
        RangeSet::new(runs)
    }

    /// [`Dataset::delta_by_checksum`] over a flat image in original block
    /// order — the [`Dataset::resubmit_flat`] form of the same diff.
    fn delta_by_checksum_flat(&self, flat: &[u8]) -> RangeSet {
        let bs = self.cfg.block_size as u64;
        let mut runs: Vec<BlockRange> = Vec::new();
        for x in 0..self.dist.n_blocks() {
            let off = (x * bs) as usize;
            let blk = &flat[off..off + bs as usize];
            let y = self.dist.permute_block(x);
            let slot = self.dist.slice_of(y);
            let committed = self
                .holder_index
                .holders_of(slot)
                .iter()
                .find_map(|&h| self.stores[h as usize].block_sum(y));
            if committed != Some(checksum_of(y, blk)) {
                match runs.last_mut() {
                    Some(last) if last.end == x => last.end = x + 1,
                    _ => runs.push(BlockRange::new(x, x + 1)),
                }
            }
        }
        RangeSet::new(runs)
    }

    /// Are all resubmit participants alive — every source rank
    /// (`pe_map`) and every current holder of every slot? `DeadPe`
    /// otherwise.
    fn check_resubmit_participants(&self, cluster: &Cluster) -> Result<()> {
        for &pe in &self.pe_map {
            if !cluster.is_alive(pe as usize) {
                return Err(Error::DeadPe(pe as usize));
            }
        }
        for slot in 0..self.dist.world() {
            for &h in self.holder_index.holders_of(slot) {
                if !cluster.is_alive(h as usize) {
                    return Err(Error::DeadPe(h as usize));
                }
            }
        }
        Ok(())
    }

    /// Mid-flight revalidation at every boundary: same epoch, every
    /// participant still alive.
    fn resubmit_still_valid(&self, cluster: &Cluster) -> bool {
        self.epoch == cluster.epoch() && self.check_resubmit_participants(cluster).is_ok()
    }

    /// Drop any staging and produce the abort error: the previous
    /// committed version stays live, byte-exactly.
    fn abort_resubmit(&mut self) -> Error {
        self.staging = None;
        Error::ResubmitAborted { dataset: self.id, version: self.version }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RestoreConfig;
    use crate::restore::ReStore;

    fn cfg(p: usize, bpp: usize, r: usize, s_pr: Option<usize>) -> RestoreConfig {
        RestoreConfig::builder(p, 8, bpp).replicas(r).perm_range_blocks(s_pr).build().unwrap()
    }

    fn make_shards(world: usize, bytes: usize) -> Vec<Vec<u8>> {
        (0..world).map(|pe| (0..bytes).map(|i| (pe * 31 + i) as u8).collect()).collect()
    }

    /// Read every original block back from its first holder.
    fn global_bytes(rs: &ReStore) -> Vec<u8> {
        let dist = rs.distribution();
        let mut out = Vec::new();
        for x in 0..dist.n_blocks() {
            let y = dist.permute_block(x);
            let slot = dist.slice_of(y);
            let h = rs.holder_index().holders_of(slot)[0] as usize;
            out.extend_from_slice(rs.stores()[h].read(y, 1).unwrap());
        }
        out
    }

    #[test]
    fn full_resubmit_replaces_every_copy_and_bumps_version() {
        let cfg = cfg(8, 64, 4, Some(16));
        let mut cluster = Cluster::new_execution(8, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards = make_shards(8, 64 * 8);
        rs.submit(&mut cluster, &shards).unwrap();
        assert_eq!(rs.dataset(crate::restore::DatasetId::FIRST).unwrap().version(), 1);

        let new: Vec<Vec<u8>> =
            shards.iter().map(|s| s.iter().map(|b| b.wrapping_add(7)).collect()).collect();
        let ds = rs.dataset_mut(crate::restore::DatasetId::FIRST).unwrap();
        let rep = ds
            .resubmit(&mut cluster, &new, ResubmitMode::Full, Overlap::Blocking)
            .unwrap();
        assert_eq!(rep.version, 2);
        assert_eq!(rep.dirty_blocks, 8 * 64);
        // every copy of every block serves the new bytes and verifies clean
        let dist = rs.distribution().clone();
        for x in 0..dist.n_blocks() {
            let y = dist.permute_block(x);
            let pe = (x / 64) as usize;
            let off = ((x % 64) * 8) as usize;
            for k in 0..4 {
                let holder = dist.holder(y, k);
                assert_eq!(rs.stores()[holder].read(y, 1).unwrap(), &new[pe][off..off + 8]);
                assert_eq!(rs.stores()[holder].verify(y, 1), None);
            }
        }
    }

    #[test]
    fn dirty_resubmit_touches_only_dirty_blocks() {
        let cfg = cfg(8, 64, 2, Some(16));
        let mut cluster = Cluster::new_execution(8, 2);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards = make_shards(8, 64 * 8);
        rs.submit(&mut cluster, &shards).unwrap();

        // rewrite blocks [10, 20) (all inside PE 0's shard of 64 blocks)
        let mut new = shards.clone();
        for x in 10..20usize {
            for b in &mut new[0][x * 8..(x + 1) * 8] {
                *b ^= 0xFF;
            }
        }
        let dirty = RangeSet::new(vec![BlockRange::new(10, 20)]);
        let ds = rs.dataset_mut(crate::restore::DatasetId::FIRST).unwrap();
        let rep = ds
            .resubmit(&mut cluster, &new, ResubmitMode::Dirty(&dirty), Overlap::Blocking)
            .unwrap();
        assert_eq!(rep.dirty_blocks, 10);
        assert_eq!(rep.replicated_bytes, 10 * 8 * 2); // r = 2 copies
        // whole dataset now equals the new content (clean blocks kept)
        let flat: Vec<u8> = new.concat();
        assert_eq!(global_bytes(&rs), flat);
    }

    #[test]
    fn checksum_delta_matches_explicit_dirty_cost_exactly() {
        let cfg = cfg(8, 64, 4, Some(16));
        let dirty = RangeSet::new(vec![BlockRange::new(3, 9), BlockRange::new(100, 130)]);
        let shards = make_shards(8, 64 * 8);
        let mut new = shards.clone();
        for r in dirty.ranges() {
            for x in r.start..r.end {
                let pe = (x / 64) as usize;
                let off = ((x % 64) * 8) as usize;
                for b in &mut new[pe][off..off + 8] {
                    *b = b.wrapping_mul(3).wrapping_add(1);
                }
            }
        }

        let run = |mode: ResubmitMode<'_>| {
            let mut cluster = Cluster::new_execution(8, 4);
            let mut rs = ReStore::new(cfg.clone(), &cluster).unwrap();
            rs.submit(&mut cluster, &shards).unwrap();
            let ds = rs.dataset_mut(crate::restore::DatasetId::FIRST).unwrap();
            let rep = ds.resubmit(&mut cluster, &new, mode, Overlap::Blocking).unwrap();
            (rep, cluster.now(), global_bytes(&rs))
        };
        let (d_rep, d_now, d_bytes) = run(ResubmitMode::DeltaByChecksum);
        let (e_rep, e_now, e_bytes) = run(ResubmitMode::Dirty(&dirty));
        let (f_rep, _, f_bytes) = run(ResubmitMode::Full);

        // message/byte parity: the checksum diff re-replicates exactly the
        // explicitly-declared dirty blocks, nothing more
        assert_eq!(d_rep.dirty_blocks, dirty.total_blocks());
        assert_eq!(d_rep.cost, e_rep.cost);
        assert_eq!(d_rep.replicated_bytes, e_rep.replicated_bytes);
        assert_eq!(d_now, e_now);
        // and strictly less than a full resubmit of the same content
        assert!(d_rep.replicated_bytes < f_rep.replicated_bytes);
        assert!(d_rep.cost.total_bytes < f_rep.cost.total_bytes);
        assert!(d_rep.cost.total_msgs <= f_rep.cost.total_msgs);
        // all three commit identical bytes
        assert_eq!(d_bytes, e_bytes);
        assert_eq!(d_bytes, f_bytes);
    }

    #[test]
    fn flat_resubmit_matches_per_rank_exactly() {
        let cfg = cfg(8, 64, 4, Some(16));
        let dirty = RangeSet::new(vec![BlockRange::new(3, 9), BlockRange::new(100, 130)]);
        let shards = make_shards(8, 64 * 8);
        let mut new = shards.clone();
        for r in dirty.ranges() {
            for x in r.start..r.end {
                let pe = (x / 64) as usize;
                let off = ((x % 64) * 8) as usize;
                for b in &mut new[pe][off..off + 8] {
                    *b = b.wrapping_mul(5).wrapping_add(3);
                }
            }
        }
        let flat: Vec<u8> = new.concat();

        let run = |use_flat: bool, mode: ResubmitMode<'_>| {
            let mut cluster = Cluster::new_execution(8, 4);
            let mut rs = ReStore::new(cfg.clone(), &cluster).unwrap();
            rs.submit(&mut cluster, &shards).unwrap();
            let ds = rs.dataset_mut(crate::restore::DatasetId::FIRST).unwrap();
            let rep = if use_flat {
                ds.resubmit_flat(&mut cluster, &flat, mode, Overlap::Blocking).unwrap()
            } else {
                ds.resubmit(&mut cluster, &new, mode, Overlap::Blocking).unwrap()
            };
            (rep, cluster.now(), global_bytes(&rs))
        };
        // the flat entry point is the SAME write, addressed differently:
        // identical dirty sets, costs, clock, and committed bytes — for
        // both the explicit-dirty and the checksum-delta modes
        for mode in [ResubmitMode::Dirty(&dirty), ResubmitMode::DeltaByChecksum] {
            let (f_rep, f_now, f_bytes) = run(true, mode);
            let (p_rep, p_now, p_bytes) = run(false, mode);
            assert_eq!(f_rep.dirty_blocks, dirty.total_blocks());
            assert_eq!(f_rep.dirty_blocks, p_rep.dirty_blocks);
            assert_eq!(f_rep.replicated_bytes, p_rep.replicated_bytes);
            assert_eq!(f_rep.cost, p_rep.cost);
            assert_eq!(f_now, p_now);
            assert_eq!(f_bytes, p_bytes);
            assert_eq!(f_bytes, flat);
        }

        // length validation: a short image is rejected before any staging
        let mut cluster = Cluster::new_execution(8, 4);
        let mut rs = ReStore::new(cfg.clone(), &cluster).unwrap();
        rs.submit(&mut cluster, &shards).unwrap();
        let ds = rs.dataset_mut(crate::restore::DatasetId::FIRST).unwrap();
        let short = &flat[..flat.len() - 8];
        let r = ds.resubmit_flat(&mut cluster, short, ResubmitMode::Full, Overlap::Blocking);
        assert!(r.is_err());
    }

    #[test]
    fn virtual_resubmit_costs_match_real() {
        let cfg = cfg(8, 64, 4, Some(16));
        let dirty = RangeSet::new(vec![BlockRange::new(0, 16), BlockRange::new(200, 260)]);

        let mut c1 = Cluster::new_execution(8, 4);
        let mut rs1 = ReStore::new(cfg.clone(), &c1).unwrap();
        let shards = make_shards(8, 64 * 8);
        rs1.submit(&mut c1, &shards).unwrap();
        let mut new = shards.clone();
        new[0][0] ^= 1;
        let real = rs1
            .dataset_mut(crate::restore::DatasetId::FIRST)
            .unwrap()
            .resubmit(&mut c1, &new, ResubmitMode::Dirty(&dirty), Overlap::Blocking)
            .unwrap();

        let mut c2 = Cluster::new_execution(8, 4);
        let mut rs2 = ReStore::new(cfg, &c2).unwrap();
        rs2.submit_virtual(&mut c2).unwrap();
        let virt = rs2
            .dataset_mut(crate::restore::DatasetId::FIRST)
            .unwrap()
            .resubmit_virtual(&mut c2, &dirty, Overlap::Blocking)
            .unwrap();
        assert_eq!(real.cost, virt.cost);
        assert_eq!(real.replicated_bytes, virt.replicated_bytes);
        assert_eq!(c1.now(), c2.now());
    }

    #[test]
    fn overlap_hides_replication_up_to_the_compute_time() {
        let cfg = cfg(8, 64, 2, None);
        let dirty = RangeSet::new(vec![BlockRange::new(0, 512)]);

        let elapsed = |overlap: Overlap| {
            let mut cluster = Cluster::new_execution(8, 2);
            let mut rs = ReStore::new(cfg.clone(), &cluster).unwrap();
            rs.submit_virtual(&mut cluster).unwrap();
            let before = cluster.now();
            let rep = rs
                .dataset_mut(crate::restore::DatasetId::FIRST)
                .unwrap()
                .resubmit_virtual(&mut cluster, &dirty, overlap)
                .unwrap();
            (cluster.now() - before, rep)
        };
        let (blocking_dt, blocking) = elapsed(Overlap::Blocking);
        assert!(blocking_dt > 0.0);
        assert!((blocking.exposed_s - blocking.cost.sim_time_s).abs() < 1e-12);

        // compute longer than the copy: fully hidden, zero exposed time
        let (hidden_dt, hidden) = elapsed(Overlap::Compute(blocking.cost.sim_time_s * 2.0));
        assert_eq!(hidden.exposed_s, 0.0);
        assert_eq!(hidden_dt, 0.0);
        // compute covering half: only the remainder is exposed
        let half = blocking.cost.sim_time_s / 2.0;
        let (half_dt, half_rep) = elapsed(Overlap::Compute(half));
        assert!((half_rep.exposed_s - (blocking.cost.sim_time_s - half)).abs() < 1e-12);
        assert!((half_dt - half_rep.exposed_s).abs() < 1e-12);
        // the modeled full cost is identical regardless of overlap
        assert_eq!(blocking.cost, hidden.cost);
        assert_eq!(blocking.cost, half_rep.cost);
    }

    #[test]
    fn kill_at_each_boundary_aborts_to_committed_version() {
        for step in [ResubmitStep::Validated, ResubmitStep::Staged, ResubmitStep::Charged] {
            let cfg = cfg(8, 32, 2, Some(16));
            let mut cluster = Cluster::new_execution(8, 2);
            let mut rs = ReStore::new(cfg, &cluster).unwrap();
            let shards = make_shards(8, 32 * 8);
            rs.submit(&mut cluster, &shards).unwrap();
            let committed = global_bytes(&rs);

            let new: Vec<Vec<u8>> =
                shards.iter().map(|s| s.iter().map(|b| !b).collect()).collect();
            let ds = rs.dataset_mut(crate::restore::DatasetId::FIRST).unwrap();
            let err = ds
                .resubmit_with_faults(
                    &mut cluster,
                    &new,
                    ResubmitMode::Full,
                    Overlap::Blocking,
                    &mut |s, c| {
                        if s == step {
                            c.kill(&[3]);
                        }
                    },
                )
                .unwrap_err();
            assert!(
                matches!(err, Error::ResubmitAborted { version: 1, .. }),
                "step {step:?}: {err}"
            );
            let ds = rs.dataset(crate::restore::DatasetId::FIRST).unwrap();
            assert_eq!(ds.version(), 1, "step {step:?}");
            assert!(!ds.replication_in_flight(), "step {step:?}: staging dropped");
            // surviving holders still serve the old version byte-exactly
            let dist = rs.distribution().clone();
            for x in 0..dist.n_blocks() {
                let y = dist.permute_block(x);
                for k in 0..2 {
                    let h = dist.holder(y, k);
                    if cluster.is_alive(h) {
                        assert_eq!(
                            rs.stores()[h].read(y, 1).unwrap(),
                            &committed[(x * 8) as usize..(x * 8 + 8) as usize],
                            "step {step:?}: block {x} copy {k}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn kill_at_committed_keeps_the_new_version() {
        let cfg = cfg(8, 32, 2, None);
        let mut cluster = Cluster::new_execution(8, 2);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards = make_shards(8, 32 * 8);
        rs.submit(&mut cluster, &shards).unwrap();
        let new: Vec<Vec<u8>> = shards.iter().map(|s| s.iter().map(|b| !b).collect()).collect();
        let rep = rs
            .dataset_mut(crate::restore::DatasetId::FIRST)
            .unwrap()
            .resubmit_with_faults(
                &mut cluster,
                &new,
                ResubmitMode::Full,
                Overlap::Blocking,
                &mut |s, c| {
                    if s == ResubmitStep::Committed {
                        c.kill(&[5]);
                    }
                },
            )
            .unwrap();
        assert_eq!(rep.version, 2);
        assert_eq!(rs.dataset(crate::restore::DatasetId::FIRST).unwrap().version(), 2);
    }

    #[test]
    fn resubmit_guards_mode_epoch_and_shapes() {
        let cfg = cfg(4, 32, 2, None);
        let mut cluster = Cluster::new_execution(4, 2);
        let mut rs = ReStore::new(cfg.clone(), &cluster).unwrap();
        let shards = make_shards(4, 32 * 8);
        let dirty = RangeSet::new(vec![BlockRange::new(0, 4)]);

        // before submit
        let ds = rs.dataset_mut(crate::restore::DatasetId::FIRST).unwrap();
        assert!(matches!(
            ds.resubmit(&mut cluster, &shards, ResubmitMode::Full, Overlap::Blocking),
            Err(Error::NotSubmitted)
        ));
        rs.submit(&mut cluster, &shards).unwrap();
        let ds = rs.dataset_mut(crate::restore::DatasetId::FIRST).unwrap();
        // wrong shard count / wrong shard size
        assert!(ds
            .resubmit(&mut cluster, &shards[..3], ResubmitMode::Full, Overlap::Blocking)
            .is_err());
        let bad = vec![vec![0u8; 8]; 4];
        assert!(ds.resubmit(&mut cluster, &bad, ResubmitMode::Full, Overlap::Blocking).is_err());
        // dirty set out of bounds
        let oob = RangeSet::new(vec![BlockRange::new(0, 4 * 32 + 1)]);
        assert!(ds
            .resubmit(&mut cluster, &shards, ResubmitMode::Dirty(&oob), Overlap::Blocking)
            .is_err());
        // execution dataset refuses the cost-model entry point and vice versa
        assert!(ds.resubmit_virtual(&mut cluster, &dirty, Overlap::Blocking).is_err());
        let mut c2 = Cluster::new_execution(4, 2);
        let mut rv = ReStore::new(cfg, &c2).unwrap();
        rv.submit_virtual(&mut c2).unwrap();
        let dv = rv.dataset_mut(crate::restore::DatasetId::FIRST).unwrap();
        assert!(dv
            .resubmit(&mut c2, &shards, ResubmitMode::Full, Overlap::Blocking)
            .is_err());
        assert!(dv
            .resubmit_inner(
                &mut c2,
                None,
                ResubmitMode::DeltaByChecksum,
                Overlap::Blocking,
                &mut |_, _| {},
            )
            .is_err());
        // negative overlap
        assert!(dv.resubmit_virtual(&mut c2, &dirty, Overlap::Compute(-1.0)).is_err());
        // dead source rank
        cluster.kill(&[2]);
        let ds = rs.dataset_mut(crate::restore::DatasetId::FIRST).unwrap();
        assert!(matches!(
            ds.resubmit(&mut cluster, &shards, ResubmitMode::Full, Overlap::Blocking),
            Err(Error::DeadPe(2))
        ));
    }

    #[test]
    fn empty_dirty_set_commits_a_free_version() {
        let cfg = cfg(4, 32, 2, None);
        let mut cluster = Cluster::new_execution(4, 2);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards = make_shards(4, 32 * 8);
        rs.submit(&mut cluster, &shards).unwrap();
        let before = cluster.now();
        // identical content under checksum-delta: nothing to replicate
        let rep = rs
            .dataset_mut(crate::restore::DatasetId::FIRST)
            .unwrap()
            .resubmit(&mut cluster, &shards, ResubmitMode::DeltaByChecksum, Overlap::Blocking)
            .unwrap();
        assert_eq!(rep.dirty_blocks, 0);
        assert_eq!(rep.replicated_bytes, 0);
        assert_eq!(rep.cost.total_msgs, 0);
        assert_eq!(cluster.now(), before);
        assert_eq!(rep.version, 2);
    }

    #[test]
    fn reshaped_resubmit_changes_block_count_and_resets_scrub_cursor() {
        let cfg = cfg(8, 32, 2, None);
        let mut cluster = Cluster::new_execution(8, 2);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        rs.submit(&mut cluster, &make_shards(8, 32 * 8)).unwrap();

        // shrink to 4 blocks total (< world): layout re-forms over 4 ranks
        let global: Vec<u8> = (0..4 * 8).map(|i| i as u8).collect();
        let ds = rs.dataset_mut(crate::restore::DatasetId::FIRST).unwrap();
        let rep = ds.resubmit_reshaped(&mut cluster, &global, Overlap::Blocking).unwrap();
        assert_eq!(rep.version, 2);
        assert_eq!(rep.dirty_blocks, 4);
        let ds = rs.dataset(crate::restore::DatasetId::FIRST).unwrap();
        assert_eq!(ds.distribution().n_blocks(), 4);
        assert_eq!(ds.distribution().world(), 4);
        assert_eq!(global_bytes(&rs), global);
        // every copy verifies clean under the fresh layout
        let dist = rs.distribution().clone();
        for pe in 0..4 {
            for s in rs.stores()[pe].slices() {
                assert_eq!(rs.stores()[pe].verify(s.range.start, s.range.len()), None);
            }
        }
        crate::restore::store::assert_memory_invariant(rs.stores(), &dist);

        // grow back up: 64 blocks over the full 8 ranks again
        let big: Vec<u8> = (0..64 * 8).map(|i| (i * 7) as u8).collect();
        let rep = rs
            .dataset_mut(crate::restore::DatasetId::FIRST)
            .unwrap()
            .resubmit_reshaped(&mut cluster, &big, Overlap::Blocking)
            .unwrap();
        assert_eq!(rep.version, 3);
        assert_eq!(rs.distribution().n_blocks(), 64);
        assert_eq!(rs.distribution().world(), 8);
        assert_eq!(global_bytes(&rs), big);
    }

    #[test]
    fn reshaped_kill_at_boundaries_aborts_whole_layout() {
        for step in [ResubmitStep::Validated, ResubmitStep::Staged, ResubmitStep::Charged] {
            let cfg = cfg(8, 32, 2, None);
            let mut cluster = Cluster::new_execution(8, 2);
            let mut rs = ReStore::new(cfg, &cluster).unwrap();
            let shards = make_shards(8, 32 * 8);
            rs.submit(&mut cluster, &shards).unwrap();
            let committed = global_bytes(&rs);

            let global: Vec<u8> = (0..16 * 8).map(|i| i as u8).collect();
            let err = rs
                .dataset_mut(crate::restore::DatasetId::FIRST)
                .unwrap()
                .resubmit_reshaped_with_faults(
                    &mut cluster,
                    &global,
                    Overlap::Blocking,
                    &mut |s, c| {
                        if s == step {
                            c.kill(&[1]);
                        }
                    },
                )
                .unwrap_err();
            assert!(matches!(err, Error::ResubmitAborted { version: 1, .. }), "step {step:?}");
            let ds = rs.dataset(crate::restore::DatasetId::FIRST).unwrap();
            assert_eq!(ds.version(), 1);
            assert_eq!(ds.distribution().n_blocks(), 8 * 32, "old geometry kept");
            // surviving copies still carry the committed version
            let dist = rs.distribution().clone();
            for x in 0..dist.n_blocks() {
                let y = dist.permute_block(x);
                for k in 0..2 {
                    let h = dist.holder(y, k);
                    if cluster.is_alive(h) {
                        assert_eq!(
                            rs.stores()[h].read(y, 1).unwrap(),
                            &committed[(x * 8) as usize..(x * 8 + 8) as usize]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn resubmit_after_rebalance_uses_the_reshaped_shards() {
        // shrink 8 → 6 via the recovery handshake, then resubmit in the
        // new geometry: shards follow the post-rebalance slice partition.
        let cfg = cfg(8, 32, 2, Some(16));
        let mut cluster = Cluster::new_execution(8, 2);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards = make_shards(8, 32 * 8);
        rs.submit(&mut cluster, &shards).unwrap();
        cluster.kill(&[2, 5]);
        let (map, _cost) = crate::simnet::ulfm::shrink(&mut cluster);
        rs.rebalance(&mut cluster, &map).unwrap();

        let dist = rs.distribution().clone();
        assert_eq!(dist.world(), 6);
        let flat: Vec<u8> = (0..dist.n_blocks() * 8).map(|i| (i * 13) as u8).collect();
        let new_shards: Vec<Vec<u8>> = (0..6)
            .map(|j| {
                let sh = dist.shard_of(j);
                flat[(sh.start * 8) as usize..(sh.end * 8) as usize].to_vec()
            })
            .collect();
        let rep = rs
            .dataset_mut(crate::restore::DatasetId::FIRST)
            .unwrap()
            .resubmit(&mut cluster, &new_shards, ResubmitMode::Full, Overlap::Blocking)
            .unwrap();
        assert_eq!(rep.version, 2);
        assert_eq!(global_bytes(&rs), flat);
    }
}
