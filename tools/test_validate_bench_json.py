#!/usr/bin/env python3
"""Regression tests for tools/validate_bench_json.py.

Run by CI's bench-json smoke job (and by hand):

    python3 tools/test_validate_bench_json.py

Covers the schema checks and, specifically, the `zero-ok` name tag: a
counter metric whose healthy value is exactly zero (e.g. the kv bench's
`kv stale-serves-count zero-ok p=1536` tripwire) must pass validation at
0.0 — while untagged zeros, negatives, and non-finite values still fail.
"""

import json
import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from validate_bench_json import validate_file  # noqa: E402


def write_artifact(tmpdir: str, entries) -> str:
    path = os.path.join(tmpdir, "BENCH_test.json")
    with open(path, "w", encoding="utf-8") as fh:
        for e in entries:
            fh.write(e if isinstance(e, str) else json.dumps(e))
            fh.write("\n")
    return path


class ValidateBenchJson(unittest.TestCase):
    def check(self, entries) -> list:
        with tempfile.TemporaryDirectory() as tmpdir:
            return validate_file(write_artifact(tmpdir, entries))

    def test_well_formed_artifact_passes(self):
        self.assertEqual(
            self.check([{"name": "load p=1536 wall", "ns_per_iter": 123.4}]), []
        )

    def test_missing_file_and_empty_artifact_fail(self):
        self.assertTrue(validate_file("/nonexistent/BENCH_x.json"))
        self.assertTrue(self.check([]))

    def test_schema_violations_fail(self):
        self.assertTrue(self.check(["not json"]))
        self.assertTrue(self.check([{"name": "x"}]))  # missing ns_per_iter
        self.assertTrue(self.check([{"name": "x", "ns_per_iter": 1, "extra": 2}]))
        self.assertTrue(self.check([{"name": "", "ns_per_iter": 1}]))
        self.assertTrue(self.check([{"name": "x", "ns_per_iter": "fast"}]))
        self.assertTrue(self.check([{"name": "x", "ns_per_iter": float("nan")}]))

    def test_untagged_zero_fails(self):
        problems = self.check([{"name": "kv stale-serves-count p=1536", "ns_per_iter": 0.0}])
        self.assertEqual(len(problems), 1)
        self.assertIn("zero-ok", problems[0])

    def test_zero_ok_tag_allows_exactly_zero(self):
        self.assertEqual(
            self.check(
                [{"name": "kv stale-serves-count zero-ok p=1536", "ns_per_iter": 0.0}]
            ),
            [],
        )

    def test_zero_ok_tag_still_rejects_negative_and_non_finite(self):
        self.assertTrue(
            self.check([{"name": "x zero-ok", "ns_per_iter": -1.0}])
        )
        self.assertTrue(
            self.check(['{"name": "x zero-ok", "ns_per_iter": Infinity}'])
        )

    def test_zero_ok_tag_on_positive_value_still_passes(self):
        self.assertEqual(
            self.check([{"name": "x zero-ok", "ns_per_iter": 7.0}]), []
        )


if __name__ == "__main__":
    unittest.main(verbosity=2)
