"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth the Pallas kernels (kmeans.py, phylo.py) are
validated against in python/tests/. They are also what `model.py` would use
if the Pallas path were disabled — importable with no Pallas dependency.
"""

import jax.numpy as jnp


def kmeans_assign_ref(points, centers):
    """Assignment step of Lloyd's algorithm.

    Args:
      points:  (N, D) float array, this PE's local points.
      centers: (K, D) float array, current cluster centers.

    Returns:
      sums:    (K, D) sum of points assigned to each center.
      counts:  (K,)   number of points assigned to each center.
      inertia: ()     sum of squared distances to the assigned center.
    """
    # ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2
    x2 = jnp.sum(points * points, axis=1, keepdims=True)  # (N, 1)
    c2 = jnp.sum(centers * centers, axis=1)[None, :]  # (1, K)
    d2 = x2 - 2.0 * points @ centers.T + c2  # (N, K)
    assign = jnp.argmin(d2, axis=1)  # (N,)
    onehot = (assign[:, None] == jnp.arange(centers.shape[0])[None, :]).astype(
        points.dtype
    )  # (N, K)
    sums = onehot.T @ points  # (K, D)
    counts = jnp.sum(onehot, axis=0)  # (K,)
    inertia = jnp.sum(jnp.min(d2, axis=1))
    return sums, counts, inertia


def kmeans_update_ref(sums, counts, old_centers):
    """Center update from globally-reduced partial sums.

    Centers with an empty cluster keep their previous position.
    """
    safe = jnp.maximum(counts, 1.0)[:, None]
    new = sums / safe
    return jnp.where(counts[:, None] > 0.0, new, old_centers)


def phylo_clv_ref(clv_l, clv_r, p_l, p_r):
    """Felsenstein pruning step for one inner node of a phylogenetic tree.

    clv[s, i] = (sum_j P_l[i, j] clv_l[s, j]) * (sum_j P_r[i, j] clv_r[s, j])

    Args:
      clv_l, clv_r: (S, A) conditional likelihood vectors of the children.
      p_l, p_r:     (A, A) transition probability matrices of the child edges.

    Returns:
      clv: (S, A) conditional likelihood vectors of the parent.
    """
    return (clv_l @ p_l.T) * (clv_r @ p_r.T)


def phylo_loglik_ref(clv_l, clv_r, p_l, p_r, freqs, weights):
    """Per-partition log-likelihood at the (virtual) root.

    Returns:
      clv:    (S, A) root CLVs (so the caller can continue pruning upwards).
      loglik: ()     sum_s weights[s] * log(sum_i freqs[i] clv[s, i]).
    """
    clv = phylo_clv_ref(clv_l, clv_r, p_l, p_r)
    site_lik = clv @ freqs  # (S,)
    # clamp to avoid -inf on underflow; RAxML-NG uses per-site scaling, the
    # proxy kernel clamps instead (documented substitution, DESIGN.md §5)
    site_lik = jnp.maximum(site_lik, jnp.finfo(site_lik.dtype).tiny)
    return clv, jnp.sum(weights * jnp.log(site_lik))
