//! An MTBF failure storm with *silent corruption* — bit flips against the
//! cluster clock — weathered by all three recovery policies.
//!
//! Every wave of the storm delivers two kinds of damage:
//!
//! * **kills** — Poisson PE failures, exactly as `examples/failure_storm.rs`;
//! * **corruption strikes** — `CorruptionModel` bit flips sampled at a
//!   per-byte rate over the bytes actually resident in the window, with
//!   node-correlated bursts (a flaky DIMM corrupts neighbours too).
//!
//! After each wave the example runs a **full scrub** over both registered
//! datasets: every resident copy is checksum-verified, corrupt copies are
//! quarantined out of the holder index and re-replicated from a surviving
//! copy via the §IV-E repair machinery. Only then does the recovery policy
//! run (rebalance ingest re-verifies checksums, so the scrub must win the
//! race), and finally EVERY block of BOTH datasets is reloaded and compared
//! byte-for-byte against the originally submitted shards — the golden
//! oracle: no corrupt byte is ever served, no repair is ever inexact.
//!
//! One wave additionally injects a **mid-recovery kill** between
//! `plan_reshape` and the epoch-bump install (`recover_with_faults`); the
//! policy detects the stale attempt via epoch validation and retries
//! against the new survivor set within `MAX_RECOVERY_ATTEMPTS`.
//!
//! Run with: `cargo run --release --example scrub_storm`

use restore::config::RestoreConfig;
use restore::metrics::fmt_time;
use restore::restore::block::{BlockRange, RangeSet};
use restore::restore::idl;
use restore::restore::policy::{
    RecoveryAction, RecoveryPolicy, RecoveryStep, Shrink, ShrinkThenRegrow, Substitute,
};
use restore::restore::{DatasetId, LoadRequest, ReStore};
use restore::simnet::cluster::Cluster;
use restore::simnet::failure::{CorruptionModel, MtbfStorm};
use restore::simnet::network::PhaseCost;

const P: usize = 64;
const PPN: usize = 8;
const SPARES: usize = 16;
const R: usize = 4;
const BPP: u64 = 64;
const BS: usize = 8;
/// Second dataset: model state with its own replication level/block size.
const R2: usize = 2;
const BPP2: u64 = 16;
const BS2: usize = 16;
/// Per-PE mean time between failures — one strike every ~50 simulated
/// seconds at 64 alive PEs.
const PE_MTBF_S: f64 = 3200.0;
/// Per-byte bit-flip rate. Both datasets keep ~160 KiB resident, so a
/// ~50 s window sees a handful of strikes — enough that every wave's scrub
/// has real work, far too few to ever corrupt all r copies of one block.
const BYTE_FLIP_RATE: f64 = 5.0e-7;
const WAVES: usize = 6;
/// The wave that additionally kills a PE *mid-recovery* (at the
/// `RecoveryStep::Reshaped` boundary) to exercise the retry path.
const TORN_WAVE: usize = 4;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut policies: Vec<Box<dyn RecoveryPolicy>> = vec![
        Box::new(Shrink),
        Box::new(Substitute),
        Box::new(ShrinkThenRegrow { target_world: P }),
    ];
    for policy in policies.iter_mut() {
        run_storm(policy.as_mut())?;
    }
    println!("\nall policies weathered the corrupting storm; every reload was byte-exact");
    Ok(())
}

fn run_storm(policy: &mut dyn RecoveryPolicy) -> Result<(), Box<dyn std::error::Error>> {
    println!(
        "\n=== policy `{}`: {WAVES}-wave MTBF+corruption storm over p = {P} (+{SPARES} spares) ===",
        policy.name()
    );
    let cfg = RestoreConfig::builder(P, BS, BPP as usize).replicas(R).build()?;
    let model_cfg = RestoreConfig::builder(P, BS2, BPP2 as usize).replicas(R2).build()?;
    let mut cluster = Cluster::with_spares(P, PPN, SPARES);
    let mut store = ReStore::new(cfg, &cluster)?;
    let model = store.create_dataset(model_cfg, &cluster)?;
    let shards: Vec<Vec<u8>> = (0..P)
        .map(|pe| (0..BPP as usize * BS).map(|i| (pe * 41 + i * 3) as u8).collect())
        .collect();
    let model_shards: Vec<Vec<u8>> = (0..P)
        .map(|pe| (0..BPP2 as usize * BS2).map(|i| (pe * 13 + i * 7) as u8).collect())
        .collect();
    store.submit(&mut cluster, &shards)?;
    store.dataset_mut(model)?.submit(&mut cluster, &model_shards)?;

    // Same seeds for every policy: all three face the *identical* storm
    // (the corruption model carries its own RNG, so arming it does not
    // perturb the kill sequence either).
    let mut storm = MtbfStorm::new(PE_MTBF_S, 0.0, 0xA11CE)
        .with_corruption(CorruptionModel::new(BYTE_FLIP_RATE, 0.25, 2, 0x5C2B));
    let (mut scrubbed, mut repaired, mut irrecoverable) = (0u64, 0usize, 0usize);
    let mut strikes_total = 0usize;
    for wave in 1..=WAVES {
        let resident = resident_bytes(&cluster, &store);
        let ev = storm
            .next_event_in(&cluster, &resident)
            .expect("enough survivors to continue");
        // run the application until the strike lands
        let gap = PhaseCost { sim_time_s: ev.at_s - cluster.now(), ..Default::default() };
        cluster.advance(&gap);
        // silent corruption accumulated over the window lands first ...
        strikes_total += ev.corruption.len();
        for strike in &ev.corruption {
            apply_strike(&mut store, model, strike.pe, strike.byte, strike.bit);
        }
        // ... then the fail-stop kill
        cluster.kill(&ev.kills);

        // Full scrub BEFORE recovery: rebalance ingest re-verifies
        // checksums, so corrupt copies must be quarantined and repaired
        // from a surviving replica first.
        for id in [DatasetId::FIRST, model] {
            let rep = store.dataset_mut(id)?.scrub(&mut cluster, u64::MAX)?;
            assert!(rep.wrapped, "u64::MAX budget covers the full cursor circle");
            scrubbed += rep.scanned_blocks;
            repaired += rep.repaired;
            irrecoverable += rep.irrecoverable;
        }

        let out = if wave == TORN_WAVE {
            // Mid-recovery kill: one extra PE dies between plan_reshape and
            // the epoch-bump install. The atomic install leaves the old
            // layout byte-intact; the policy sees the stale epoch and
            // retries against the new survivor set.
            let mut fired = false;
            let out = policy.recover_with_faults(&mut cluster, &mut store, &mut |step, cl| {
                if step == RecoveryStep::Reshaped && !fired {
                    fired = true;
                    let victim = *cl.survivors().last().expect("survivors remain");
                    cl.kill(&[victim]);
                }
            })?;
            println!(
                "wave {wave}: mid-recovery kill at `Reshaped` -> retried, degraded={}",
                out.degraded
            );
            out
        } else {
            policy.recover(&mut cluster, &mut store)?
        };
        let action = match out.action {
            RecoveryAction::Shrunk { new_world } => format!("shrunk to {new_world}"),
            RecoveryAction::Substituted { replaced } => {
                format!("substituted {replaced} spare(s), world kept at {}", out.map.new_world())
            }
            RecoveryAction::Regrown { shrunk_to, regrown_to } => {
                format!("shrunk to {shrunk_to}, regrown to {regrown_to}")
            }
        };
        println!(
            "wave {wave} at {}: {} flip(s), killed {:?} -> {action}{} ({}, {} spares left)",
            fmt_time(ev.at_s),
            ev.corruption.len(),
            ev.kills,
            if out.degraded { " [degraded]" } else { "" },
            fmt_time(out.recovery_time_s),
            cluster.n_spares(),
        );

        // Golden oracle: EVERY block of BOTH datasets reloads with exactly
        // the bytes submitted before any failure or corruption.
        verify_full_reload(&mut cluster, &mut store, DatasetId::FIRST, &shards, BPP, BS)?;
        verify_full_reload(&mut cluster, &mut store, model, &model_shards, BPP2, BS2)?;
    }

    let p_final = store.distribution().world() as u64;
    println!(
        "storm over: world {P} -> {p_final}, {} corruption strikes, {} spares left",
        strikes_total,
        cluster.n_spares(),
    );
    // The CI-grepped integrity markers: everything the scrubber saw, fixed,
    // and (never, at this rate and r) lost.
    println!(
        "integrity: scrubbed={scrubbed} repaired={repaired} irrecoverable={irrecoverable}"
    );
    assert!(repaired > 0, "a {WAVES}-wave storm at this flip rate repairs something");
    assert_eq!(irrecoverable, 0, "r = {R} survives independent bit flips");
    println!(
        "P(IDL | 8 more failures, corruption-free) at the final world: {:.2e}",
        idl::p_idl_approx(p_final, R as u64, 8)
    );
    Ok(())
}

/// Total resident payload bytes per cluster rank, summed over all datasets
/// — the exposure surface `CorruptionModel::sample_window` weights strikes
/// by.
fn resident_bytes(cluster: &Cluster, store: &ReStore) -> Vec<u64> {
    (0..cluster.world())
        .map(|pe| {
            store
                .datasets()
                .iter()
                .map(|ds| ds.stores().get(pe).map_or(0, |s| s.real_bytes()))
                .sum()
        })
        .collect()
}

/// Route one strike to the dataset owning that byte of `pe`'s concatenated
/// resident payload (dataset 0's bytes first, then the model's).
fn apply_strike(store: &mut ReStore, model: DatasetId, pe: usize, byte: u64, bit: u8) {
    let ds0_bytes = store
        .dataset(DatasetId::FIRST)
        .map(|ds| ds.stores().get(pe).map_or(0, |s| s.real_bytes()))
        .unwrap_or(0);
    if byte < ds0_bytes {
        store.dataset_mut(DatasetId::FIRST).unwrap().corrupt_bit(pe, byte, bit);
    } else {
        store.dataset_mut(model).unwrap().corrupt_bit(pe, byte - ds0_bytes, bit);
    }
}

/// Reload every block of `id` to one survivor and compare byte-for-byte
/// with the originally submitted shards.
fn verify_full_reload(
    cluster: &mut Cluster,
    store: &mut ReStore,
    id: DatasetId,
    shards: &[Vec<u8>],
    bpp: u64,
    bs: usize,
) -> Result<(), Box<dyn std::error::Error>> {
    let pe = cluster.survivors()[0];
    let n = shards.len() as u64 * bpp;
    let reqs = vec![LoadRequest { pe, ranges: RangeSet::new(vec![BlockRange::new(0, n)]) }];
    let out = store.dataset_mut(id)?.load(cluster, &reqs)?;
    let bytes = out.shards[0].bytes.as_ref().expect("execution mode");
    let mut off = 0usize;
    for x in 0..n {
        let src = &shards[(x / bpp) as usize];
        let boff = ((x % bpp) as usize) * bs;
        assert_eq!(
            &bytes[off..off + bs],
            &src[boff..boff + bs],
            "dataset {id:?}: block {x} corrupted"
        );
        off += bs;
    }
    Ok(())
}
