"""Build-time compile path: L2 jax models + L1 Pallas kernels + AOT export.

Never imported at runtime — the Rust coordinator only consumes the HLO text
artifacts that `python -m compile.aot` writes to ../artifacts/.
"""
