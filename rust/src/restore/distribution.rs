//! Replica placement: the paper's data distribution (§IV-A, §IV-B),
//! generalized to **balanced unequal slices**.
//!
//! Copy `k` of the block with ID `x` lives on PE
//!
//! ```text
//! L(x, k) = slice_of(π(x)) + k·⌊p/r⌋   (mod p)
//! ```
//!
//! where `π` permutes *permutation ranges* of `s_pr` consecutive blocks
//! (identity when permutation is disabled) and `slice_of` maps a permuted
//! block ID to its *slice*. The permuted ID space `[0, n)` is divided into
//! `p` contiguous slices — one per PE — in the **balanced unequal**
//! partition: the first `n mod p` slices hold `⌈n/p⌉` blocks, the rest
//! `⌊n/p⌋`. Slice boundaries have the closed form
//!
//! ```text
//! slice_start(i) = i·⌊n/p⌋ + min(i, n mod p)
//! ```
//!
//! and the inverse `slice_of(y)` is one division plus one branch (big
//! slices are a contiguous prefix). When `p | n` every slice has
//! `n/p` blocks and `slice_of(y) = ⌊y·p/n⌋ = ⌊y / (n/p)⌋` — the paper's
//! original equal-slice geometry, which `Distribution::new` (submit time)
//! always produces. The unequal case is what makes §IV-B *shrinking
//! recovery* work for **arbitrary** survivor counts: `reshaped(p')` only
//! requires `r ≤ p' ≤ n`, so a 16 → 13 kill wave rebalances instead of
//! lingering in the dead-rank layout.
//!
//! With `r ∤ p` the copy stride `⌊p/r⌋` still yields `r` pairwise distinct
//! holders (`k·⌊p/r⌋ < p` for `k < r`), but the §IV-D *groups* (`{ i ≡ g
//! (mod p/r) }` storing identical data) are exact only when `r | p`;
//! group-based IDL formulas are an approximation otherwise.
//!
//! A piece of a request can now be misaligned against both the unit and
//! the slice lattice, so [`Distribution::permuted_pieces`] splits at
//! permutation-unit edges first and then at slice edges
//! ([`Distribution::split_at_slices`]) — each final piece has a single
//! well-defined holder set.
//!
//! ## The placement index (perf)
//!
//! `π` is a 4-round Feistel cipher with cycle walking — ~16 hash rounds per
//! unit mapping, paid by *every* `permute_block` call. Submit touches every
//! unit once, but the load path re-maps the requested units on **every**
//! recovery, so the cipher cost recurs per failure. When the unit domain is
//! small enough ([`UNIT_INDEX_MAX_UNITS`]) the constructor precomputes the
//! whole unit→slot table once — one `Vec<u32>` shared (via `Arc`) by
//! submit, load, and repair — turning the per-unit mapping into one L1/L2
//! array read. [`Distribution::reshaped`] shares the table (and the
//! cipher) with the old layout by `Arc`: a rebalance re-derives nothing.
//!
//! Trade-off: 4 bytes per permutation unit of *global* memory. At the
//! paper's defaults (256 KiB ranges, 16 MiB/PE ⇒ 64 units/PE) that is
//! 256 B/PE — 6 MiB for the full p = 24 576 system, negligible next to the
//! 64 MiB/PE of replica payload. At pathological unit counts (tiny ranges ×
//! huge worlds) the table is skipped and the cipher is evaluated on demand,
//! so memory stays bounded; the inverse direction (`unpermute_block`, only
//! used on cold error paths) always uses the cipher.

use std::sync::Arc;

use crate::config::RestoreConfig;
use crate::error::{Error, Result};
use crate::restore::block::BlockRange;
use crate::restore::permutation::{Feistel, Identity, RangePermutation};

/// A contiguous piece of a request after mapping to the permuted ID space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PermutedPiece {
    /// Start in permuted block ID space.
    pub perm_start: u64,
    /// Corresponding start in original block ID space.
    pub orig_start: u64,
    /// Piece length in blocks. Never crosses a permutation-range boundary
    /// or (after [`Distribution::split_at_slices`]) a slice boundary.
    pub len: u64,
}

/// Largest unit domain for which the precomputed unit→slot placement index
/// is built (4 bytes per unit ⇒ ≤ 64 MiB of index). See the module docs
/// for the memory-vs-Feistel-throughput trade-off.
pub const UNIT_INDEX_MAX_UNITS: u64 = 1 << 24;

/// The placement function shared by submit, load, and repair.
#[derive(Clone)]
pub struct Distribution {
    p: usize,
    r: usize,
    offset: usize,
    /// The raw configured placement offset (before the `mod p` reduction),
    /// kept so [`Distribution::reshaped`] can re-reduce it at the new world
    /// size exactly as a fresh construction would.
    offset_cfg: usize,
    /// Total number of blocks `n` (invariant across reshapes).
    n: u64,
    /// `⌊n/p⌋` — the small-slice length.
    q: u64,
    /// `n mod p` — the number of big (`⌈n/p⌉`-block) slices, which form a
    /// contiguous prefix of the slice space. 0 ⇔ the equal-slice layout.
    rem: u64,
    /// Permutation unit in blocks. With permutation disabled this tracks
    /// the slice size when `p | n` (one unit per slice) and degenerates to
    /// the whole ID space (`s_pr = n`, a single unit) otherwise — the
    /// identity map is unaffected either way.
    s_pr: u64,
    /// True when the configuration disabled permutation ranges (the unit
    /// permutation is the identity).
    identity: bool,
    perm: Arc<dyn RangePermutation>,
    /// Precomputed `unit → permuted slot` table (forward direction of
    /// `perm`), built once at construction when the domain is small enough.
    /// `None` ⇒ evaluate the cipher on demand.
    unit_index: Option<Arc<Vec<u32>>>,
}

impl Distribution {
    /// The submit-time layout of a validated config — always equal slices
    /// (`n = p · blocks_per_pe`), so this is just
    /// [`Distribution::new_balanced`] at the config's world: the config
    /// guarantees
    /// `r ≤ p ≤ n`, `p | n`, and (with permutation on) `s_pr | n`, making
    /// the balanced constructor infallible here. One constructor body
    /// keeps `new`, `new_balanced`, and `reshaped` permanently in sync —
    /// the golden "reshaped ≡ fresh balanced construction" invariant
    /// depends on it.
    pub fn new(cfg: &RestoreConfig) -> Self {
        Distribution::new_balanced(
            cfg.world,
            cfg.n_blocks(),
            cfg.replicas,
            cfg.perm_range_blocks.map(|s| s as u64),
            cfg.seed,
            cfg.placement_offset,
        )
        .expect("RestoreConfig::validate guarantees a feasible balanced layout")
    }

    /// A from-scratch balanced (possibly unequal-slice) layout: `world` PEs
    /// carrying `n_blocks` blocks with `replicas` copies each — the golden
    /// reference every [`Distribution::reshaped`] must equal. Unlike
    /// [`Distribution::new`] this does not require `world | n_blocks`; the
    /// slice partition is the balanced ⌊n/p⌋/⌈n/p⌉ split. Requires
    /// `replicas ≤ world ≤ n_blocks` and, with permutation ranges on,
    /// `perm_range_blocks | n_blocks` (the shared permuted unit lattice).
    pub fn new_balanced(
        world: usize,
        n_blocks: u64,
        replicas: usize,
        perm_range_blocks: Option<u64>,
        seed: u64,
        placement_offset: usize,
    ) -> Result<Self> {
        if world == 0 || replicas == 0 || replicas > world || (world as u64) > n_blocks {
            return Err(Error::Config(format!(
                "balanced layout needs 1 <= r={replicas} <= p={world} <= n={n_blocks}"
            )));
        }
        let (s_pr, perm): (u64, Arc<dyn RangePermutation>) = match perm_range_blocks {
            Some(s) => {
                if s == 0 || n_blocks % s != 0 {
                    return Err(Error::Config(format!(
                        "perm range of {s} blocks must divide n = {n_blocks} blocks"
                    )));
                }
                (s, Arc::new(Feistel::new(n_blocks / s, seed)))
            }
            None if n_blocks % world as u64 == 0 => {
                // equal slices: one identity unit per slice, exactly as
                // `Distribution::new` lays it out
                (n_blocks / world as u64, Arc::new(Identity { domain: world as u64 }))
            }
            None => {
                // unequal slices: the identity map needs no unit lattice;
                // collapse to a single whole-space unit
                (n_blocks, Arc::new(Identity { domain: 1 }))
            }
        };
        let unit_index = (perm_range_blocks.is_some()
            && perm.domain() <= UNIT_INDEX_MAX_UNITS)
            .then(|| {
                Arc::new((0..perm.domain()).map(|u| perm.apply(u) as u32).collect::<Vec<u32>>())
            });
        Ok(Distribution {
            p: world,
            r: replicas,
            offset: placement_offset % world,
            offset_cfg: placement_offset,
            n: n_blocks,
            q: n_blocks / world as u64,
            rem: n_blocks % world as u64,
            s_pr,
            identity: perm_range_blocks.is_none(),
            perm,
            unit_index,
        })
    }

    /// Can this layout be rewritten for a post-shrink world of `new_world`
    /// PEs holding the same `n` blocks? With balanced unequal slices the
    /// only requirements are `r ≤ new_world` (the `r` copies must land on
    /// distinct PEs) and `new_world ≤ n` (no empty slices): every real kill
    /// wave that leaves at least `r` survivors admits the layout. Unit
    /// misalignment is handled by splitting request pieces at both unit
    /// *and* slice edges, so no divisibility constraint remains.
    pub fn reshape_feasible(&self, new_world: usize) -> bool {
        new_world >= self.r && new_world as u64 <= self.n
    }

    /// The same data, re-laid-out over `new_world` PEs with balanced
    /// ⌊n/p'⌋/⌈n/p'⌉ slices — the core of the shrinking-recovery rebalance
    /// (§IV-B): the permuted block ID space (permutation, seed, unit size,
    /// and therefore the precomputed unit→slot placement index) is
    /// **shared by `Arc`** with the old layout; only the slice partition,
    /// the copy stride `⌊p'/r⌋`, and the offset reduction change.
    /// Identical to [`Distribution::new_balanced`] at `new_world`
    /// (golden-tested), without re-deriving Feistel keys or
    /// re-materializing the index.
    ///
    /// With permutation disabled the identity map carries over; the unit
    /// bookkeeping is re-derived exactly as `new_balanced` would (one unit
    /// per slice when `p' | n`, a single whole-space unit otherwise).
    pub fn reshaped(&self, new_world: usize) -> Result<Distribution> {
        if !self.reshape_feasible(new_world) {
            return Err(Error::Config(format!(
                "cannot reshape layout to world {new_world}: need r = {} <= {new_world} <= n = {}",
                self.r, self.n
            )));
        }
        let (s_pr, perm, unit_index): (u64, Arc<dyn RangePermutation>, _) = if self.identity {
            if self.n % new_world as u64 == 0 {
                (self.n / new_world as u64, Arc::new(Identity { domain: new_world as u64 }), None)
            } else {
                (self.n, Arc::new(Identity { domain: 1 }), None)
            }
        } else {
            (self.s_pr, Arc::clone(&self.perm), self.unit_index.clone())
        };
        Ok(Distribution {
            p: new_world,
            r: self.r,
            offset: self.offset_cfg % new_world,
            offset_cfg: self.offset_cfg,
            n: self.n,
            q: self.n / new_world as u64,
            rem: self.n % new_world as u64,
            s_pr,
            identity: self.identity,
            perm,
            unit_index,
        })
    }

    pub fn world(&self) -> usize {
        self.p
    }

    pub fn replicas(&self) -> usize {
        self.r
    }

    /// Permutation-unit size in blocks.
    pub fn perm_range_blocks(&self) -> u64 {
        self.s_pr
    }

    pub fn n_blocks(&self) -> u64 {
        self.n
    }

    /// Are all slices the same length (`p | n`)?
    pub fn equal_slices(&self) -> bool {
        self.rem == 0
    }

    /// Length of the longest slice, `⌈n/p⌉` — what a pre-sized per-slice
    /// buffer must accommodate.
    pub fn max_slice_blocks(&self) -> u64 {
        self.q + (self.rem > 0) as u64
    }

    /// Start of slice `i` in permuted block IDs (valid for `i ≤ p`; at
    /// `i = p` this is `n`): `i·⌊n/p⌋ + min(i, n mod p)` — the closed-form
    /// prefix sum of the balanced slice lengths.
    #[inline]
    pub fn slice_start(&self, i: usize) -> u64 {
        debug_assert!(i <= self.p);
        let i = i as u64;
        i * self.q + i.min(self.rem)
    }

    /// End of slice `i` (== `slice_start(i + 1)`).
    #[inline]
    pub fn slice_end(&self, i: usize) -> u64 {
        self.slice_start(i + 1)
    }

    /// Length of slice `i`: `⌈n/p⌉` for the first `n mod p` slices,
    /// `⌊n/p⌋` for the rest.
    #[inline]
    pub fn slice_len(&self, i: usize) -> u64 {
        debug_assert!(i < self.p);
        self.q + ((i as u64) < self.rem) as u64
    }

    /// The permuted interval `[slice_start(i), slice_end(i))` of slice `i`.
    pub fn slice_range(&self, i: usize) -> BlockRange {
        BlockRange::new(self.slice_start(i), self.slice_end(i))
    }

    /// Slice containing permuted block `y` — the closed-form inverse of
    /// [`Distribution::slice_start`]: one division plus one branch (the
    /// big slices form a contiguous prefix of length `rem·(q+1)`).
    #[inline]
    pub fn slice_of(&self, y: u64) -> usize {
        debug_assert!(y < self.n);
        let big_end = self.rem * (self.q + 1);
        if y < big_end {
            (y / (self.q + 1)) as usize
        } else {
            (self.rem + (y - big_end) / self.q) as usize
        }
    }

    /// Group offset `⌊p/r⌋` between successive copies (§IV-A; exact
    /// `p/r` when `r | p`).
    pub fn copy_stride(&self) -> usize {
        self.p / self.r
    }

    /// The configured constant placement offset (see `RestoreConfig`).
    pub fn placement_offset(&self) -> usize {
        self.offset
    }

    /// §IV-D group of a PE: all PEs with equal `pe mod ⌊p/r⌋` store the
    /// same slices **when `r | p`**; with a non-dividing `r` the stride
    /// wraps unevenly and this is only the first-copy neighborhood.
    pub fn group_of(&self, pe: usize) -> usize {
        pe % self.copy_stride()
    }

    /// Is the precomputed unit→slot placement index active?
    pub fn has_unit_index(&self) -> bool {
        self.unit_index.is_some()
    }

    /// Permuted slot of permutation unit `unit` — one array read when the
    /// placement index is built, a Feistel evaluation otherwise.
    #[inline]
    pub fn unit_slot(&self, unit: u64) -> u64 {
        match &self.unit_index {
            Some(ix) => ix[unit as usize] as u64,
            None => self.perm.apply(unit),
        }
    }

    /// Permuted position of original block `x`.
    #[inline]
    pub fn permute_block(&self, x: u64) -> u64 {
        let unit = x / self.s_pr;
        let off = x % self.s_pr;
        self.unit_slot(unit) * self.s_pr + off
    }

    /// Original position of permuted block `y`.
    pub fn unpermute_block(&self, y: u64) -> u64 {
        let unit = y / self.s_pr;
        let off = y % self.s_pr;
        self.perm.invert(unit) * self.s_pr + off
    }

    /// PE owning the *primary* (k = 0) copy of permuted block `y`.
    pub fn primary_of_permuted(&self, y: u64) -> usize {
        debug_assert!(y < self.n);
        self.slice_of(y)
    }

    /// PE holding copy `k` of permuted block `y`: `L` of the paper
    /// (plus the configurable constant placement offset). The `r` holders
    /// are pairwise distinct for any `r ≤ p`: `k·⌊p/r⌋ < p` for `k < r`.
    pub fn holder(&self, y: u64, k: usize) -> usize {
        debug_assert!(k < self.r);
        (self.primary_of_permuted(y) + k * self.copy_stride() + self.offset) % self.p
    }

    /// All `r` holders of permuted block `y`.
    pub fn holders(&self, y: u64) -> Vec<usize> {
        (0..self.r).map(|k| self.holder(y, k)).collect()
    }

    /// The permuted slice `[start, end)` stored by `pe` as copy `k`.
    pub fn stored_slice(&self, pe: usize, k: usize) -> BlockRange {
        debug_assert!(pe < self.p && k < self.r);
        let primary =
            (pe + 2 * self.p - (k * self.copy_stride() + self.offset) % self.p) % self.p;
        self.slice_range(primary)
    }

    /// Original block range submitted by `pe` (the application's shard) —
    /// the same balanced partition as the permuted slices, in original IDs.
    pub fn shard_of(&self, pe: usize) -> BlockRange {
        BlockRange::new(self.slice_start(pe), self.slice_end(pe))
    }

    /// Decompose an *original* block range into permuted pieces, each fully
    /// inside one permutation unit AND one permuted slice (so each piece
    /// has a single well-defined holder set).
    pub fn permuted_pieces(&self, range: BlockRange, out: &mut Vec<PermutedPiece>) {
        for unit_piece in range.chunks(self.s_pr) {
            let perm_start = self.permute_block(unit_piece.start);
            // A piece inside one permutation unit maps contiguously; it can
            // still straddle one or more slice boundaries (units and slices
            // are independent lattices once slices are unequal) — split at
            // every slice edge it crosses.
            let piece = PermutedPiece {
                perm_start,
                orig_start: unit_piece.start,
                len: unit_piece.len(),
            };
            self.split_at_slices(piece, out);
        }
    }

    fn split_at_slices(&self, piece: PermutedPiece, out: &mut Vec<PermutedPiece>) {
        let mut start = piece.perm_start;
        let mut orig = piece.orig_start;
        let end = piece.perm_start + piece.len;
        while start < end {
            let slice_end = self.slice_end(self.slice_of(start));
            let stop = slice_end.min(end);
            out.push(PermutedPiece { perm_start: start, orig_start: orig, len: stop - start });
            orig += stop - start;
            start = stop;
        }
    }
}

impl std::fmt::Debug for Distribution {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Distribution")
            .field("p", &self.p)
            .field("r", &self.r)
            .field("n", &self.n)
            .field("q", &self.q)
            .field("rem", &self.rem)
            .field("s_pr", &self.s_pr)
            .field("unit_index", &self.unit_index.as_ref().map(|ix| ix.len()))
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RestoreConfig;

    fn dist(p: usize, bpp: usize, r: usize, s_pr: Option<usize>) -> Distribution {
        let cfg = RestoreConfig::builder(p, 64, bpp)
            .replicas(r)
            .perm_range_blocks(s_pr)
            .build()
            .unwrap();
        Distribution::new(&cfg)
    }

    #[test]
    fn paper_figure1_layout() {
        // Fig 1: p=4, n=16, r=2, no permutation. Copy 1 of block x on PE
        // ⌊x/4⌋, copy 2 on PE ⌊x/4⌋+2 mod 4.
        let d = dist(4, 4, 2, None);
        for x in 0..16u64 {
            assert_eq!(d.permute_block(x), x); // identity
            assert_eq!(d.holder(x, 0), (x / 4) as usize);
            assert_eq!(d.holder(x, 1), ((x / 4 + 2) % 4) as usize);
        }
        // PE 0 stores its own slice (copy 1) and PE 2's slice (copy 2).
        assert_eq!(d.stored_slice(0, 0), BlockRange::new(0, 4));
        assert_eq!(d.stored_slice(0, 1), BlockRange::new(8, 12));
        assert_eq!(d.stored_slice(2, 1), BlockRange::new(0, 4));
    }

    #[test]
    fn holders_are_distinct_and_stride_separated() {
        let d = dist(16, 64, 4, Some(8));
        for y in (0..d.n_blocks()).step_by(37) {
            let hs = d.holders(y);
            let set: std::collections::HashSet<_> = hs.iter().collect();
            assert_eq!(set.len(), 4);
            for w in hs.windows(2) {
                assert_eq!((w[1] + 16 - w[0]) % 16, 4); // stride p/r = 4
            }
        }
    }

    #[test]
    fn permute_roundtrip() {
        let d = dist(8, 64, 2, Some(8));
        for x in 0..d.n_blocks() {
            assert_eq!(d.unpermute_block(d.permute_block(x)), x);
        }
    }

    #[test]
    fn permutation_preserves_offsets_within_unit() {
        let d = dist(8, 64, 2, Some(8));
        for x in (0..d.n_blocks()).step_by(8) {
            let base = d.permute_block(x);
            for off in 1..8 {
                assert_eq!(d.permute_block(x + off), base + off);
            }
        }
    }

    #[test]
    fn stored_slice_inverts_holder() {
        let d = dist(12, 48, 3, Some(4));
        for pe in 0..12 {
            for k in 0..3 {
                let slice = d.stored_slice(pe, k);
                // every permuted block in that slice has pe as its k-holder
                for y in slice.start..slice.end {
                    assert_eq!(d.holder(y, k), pe);
                }
            }
        }
    }

    #[test]
    fn balanced_slice_geometry_closed_forms() {
        // n = 100 over p = 7: rem = 2 big slices of 15, then 5 of 14.
        let d = Distribution::new_balanced(7, 100, 3, None, 1, 0).unwrap();
        assert!(!d.equal_slices());
        assert_eq!(d.max_slice_blocks(), 15);
        let lens: Vec<u64> = (0..7).map(|i| d.slice_len(i)).collect();
        assert_eq!(lens, vec![15, 15, 14, 14, 14, 14, 14]);
        assert_eq!(lens.iter().sum::<u64>(), 100);
        // slice_start is the prefix sum of the lengths; slice_of inverts it
        let mut start = 0u64;
        for i in 0..7usize {
            assert_eq!(d.slice_start(i), start);
            assert_eq!(d.slice_end(i), start + lens[i]);
            assert_eq!(d.slice_range(i).len(), lens[i]);
            start += lens[i];
        }
        assert_eq!(d.slice_start(7), 100);
        for y in 0..100u64 {
            let i = d.slice_of(y);
            assert!(d.slice_start(i) <= y && y < d.slice_end(i), "y={y} slice {i}");
        }
        // shard partition mirrors the slice partition in original IDs
        assert_eq!(d.shard_of(0), BlockRange::new(0, 15));
        assert_eq!(d.shard_of(2), BlockRange::new(30, 44));
    }

    #[test]
    fn balanced_holders_distinct_for_non_dividing_r() {
        // r = 4 over p = 13: stride ⌊13/4⌋ = 3, holders {s, s+3, s+6, s+9}.
        let d = Distribution::new_balanced(13, 16 * 64, 4, Some(16), 0xD157, 0).unwrap();
        assert_eq!(d.copy_stride(), 3);
        for y in (0..d.n_blocks()).step_by(17) {
            let hs = d.holders(y);
            let set: std::collections::HashSet<_> = hs.iter().collect();
            assert_eq!(set.len(), 4, "y={y}: holders {hs:?} not distinct");
            for (k, &h) in hs.iter().enumerate() {
                assert_eq!(h, (d.slice_of(y) + 3 * k) % 13);
                // stored_slice stays the inverse view
                assert!(d.stored_slice(h, k).contains(y));
            }
        }
    }

    #[test]
    fn pieces_cover_request_and_respect_boundaries() {
        let d = dist(8, 64, 2, Some(8));
        let req = BlockRange::new(5, 200);
        let mut pieces = Vec::new();
        d.permuted_pieces(req, &mut pieces);
        // total length preserved
        assert_eq!(pieces.iter().map(|p| p.len).sum::<u64>(), req.len());
        let mut orig = req.start;
        for p in &pieces {
            assert_eq!(p.orig_start, orig, "pieces in request order");
            orig += p.len;
            // no piece crosses a slice boundary
            let first_slice = p.perm_start / 64;
            let last_slice = (p.perm_start + p.len - 1) / 64;
            assert_eq!(first_slice, last_slice);
            // mapping is consistent with permute_block
            assert_eq!(d.permute_block(p.orig_start), p.perm_start);
        }
    }

    #[test]
    fn pieces_split_at_unit_and_unequal_slice_edges() {
        // n = 1024 blocks over p' = 13 with 16-block units: slice
        // boundaries are NOT unit-aligned, so pieces must split at both
        // lattices and still cover the request exactly.
        let d = Distribution::new_balanced(13, 1024, 4, Some(16), 0xD157, 0).unwrap();
        let req = BlockRange::new(3, 997);
        let mut pieces = Vec::new();
        d.permuted_pieces(req, &mut pieces);
        assert_eq!(pieces.iter().map(|p| p.len).sum::<u64>(), req.len());
        let mut orig = req.start;
        for p in &pieces {
            assert_eq!(p.orig_start, orig, "pieces in request order");
            orig += p.len;
            // single slice per piece
            assert_eq!(
                d.slice_of(p.perm_start),
                d.slice_of(p.perm_start + p.len - 1),
                "piece {p:?} crosses a slice edge"
            );
            // single unit per piece
            assert_eq!(p.perm_start / 16, (p.perm_start + p.len - 1) / 16);
            assert_eq!(d.permute_block(p.orig_start), p.perm_start);
        }
    }

    #[test]
    fn groups_store_identical_data() {
        let d = dist(8, 16, 2, Some(4));
        // group stride p/r = 4: PEs 1 and 5 are in the same group
        let slices =
            |pe: usize| -> Vec<BlockRange> { (0..2).map(|k| d.stored_slice(pe, k)).collect() };
        let a = slices(1);
        let b = slices(5);
        let sa: std::collections::HashSet<_> = a.into_iter().collect();
        let sb: std::collections::HashSet<_> = b.into_iter().collect();
        assert_eq!(sa, sb);
        assert_eq!(d.group_of(1), d.group_of(5));
        assert_ne!(d.group_of(1), d.group_of(2));
    }

    #[test]
    fn unit_index_matches_cipher() {
        // The precomputed table must agree with the Feistel cipher exactly
        // (one entry per unit, forward direction).
        let cfg = RestoreConfig::builder(8, 64, 64)
            .replicas(2)
            .perm_range_blocks(Some(8))
            .build()
            .unwrap();
        let d = Distribution::new(&cfg);
        assert!(d.has_unit_index());
        let f = Feistel::new(cfg.n_blocks() / 8, cfg.seed);
        for u in 0..(cfg.n_blocks() / 8) {
            assert_eq!(d.unit_slot(u), f.apply(u), "unit {u}");
        }
    }

    #[test]
    fn identity_distribution_skips_unit_index() {
        let d = dist(4, 16, 2, None);
        assert!(!d.has_unit_index());
        assert_eq!(d.permute_block(17), 17);
    }

    #[test]
    fn reshaped_matches_fresh_balanced_construction() {
        // The rebalance layout must be indistinguishable from building a
        // new balanced Distribution at the shrunken world from scratch —
        // same permuted space, same holders, same slices — for dividing
        // AND non-dividing survivor counts.
        for (s_pr, new_p) in [
            (Some(16u64), 8usize),
            (Some(16), 4),
            (Some(16), 13),
            (Some(16), 7),
            (Some(16), 5),
            (None, 8),
            (None, 4),
            (None, 13),
            (None, 6),
        ] {
            let cfg = RestoreConfig::builder(16, 8, 64)
                .replicas(4)
                .perm_range_blocks(s_pr.map(|s| s as usize))
                .seed(0xD157)
                .build()
                .unwrap();
            let old = Distribution::new(&cfg);
            let got = old.reshaped(new_p).unwrap();
            let want =
                Distribution::new_balanced(new_p, cfg.n_blocks(), 4, s_pr, 0xD157, 0).unwrap();
            assert_eq!(got.world(), want.world());
            assert_eq!(got.perm_range_blocks(), want.perm_range_blocks(), "s_pr {s_pr:?} p' {new_p}");
            assert_eq!(got.n_blocks(), old.n_blocks());
            assert_eq!(got.copy_stride(), want.copy_stride());
            for y in 0..got.n_blocks() {
                assert_eq!(got.permute_block(y), want.permute_block(y), "s_pr {s_pr:?} y {y}");
                assert_eq!(got.unpermute_block(y), want.unpermute_block(y));
                assert_eq!(got.slice_of(y), want.slice_of(y), "s_pr {s_pr:?} y {y}");
                for k in 0..4 {
                    assert_eq!(got.holder(y, k), want.holder(y, k), "s_pr {s_pr:?} y {y} k {k}");
                }
            }
            for pe in 0..new_p {
                assert_eq!(got.slice_len(pe), want.slice_len(pe));
                assert_eq!(got.shard_of(pe), want.shard_of(pe));
                for k in 0..4 {
                    assert_eq!(got.stored_slice(pe, k), want.stored_slice(pe, k));
                }
            }
        }
    }

    #[test]
    fn reshape_feasibility_rules() {
        // p=16, bpp=64, s_pr=16: n = 1024 blocks. Balanced unequal slices
        // admit EVERY world with r <= p' <= n.
        let d = dist(16, 64, 4, Some(16));
        assert!(d.reshape_feasible(16));
        assert!(d.reshape_feasible(13), "non-dividing p' must now be feasible");
        assert!(d.reshape_feasible(12));
        assert!(d.reshape_feasible(8));
        assert!(d.reshape_feasible(5));
        assert!(d.reshape_feasible(4), "p' = r is the floor");
        assert!(!d.reshape_feasible(3), "r = 4 needs at least 4 distinct holders");
        assert!(!d.reshape_feasible(0));
        assert!(d.reshaped(3).is_err());
        // identity layouts follow the same rule
        let id = dist(16, 64, 2, None);
        assert!(id.reshape_feasible(10), "n % p' != 0 is no longer a constraint");
        assert!(id.reshape_feasible(2));
        assert!(!id.reshape_feasible(1), "r = 2 must fit in the new world");
    }

    #[test]
    fn reshaped_chains_through_non_dividing_worlds() {
        // 16 -> 13 -> 7: each step must equal the fresh balanced layout.
        let cfg = RestoreConfig::builder(16, 8, 64)
            .replicas(4)
            .perm_range_blocks(Some(16))
            .seed(0xC4A1)
            .build()
            .unwrap();
        let d16 = Distribution::new(&cfg);
        let d13 = d16.reshaped(13).unwrap();
        let d7 = d13.reshaped(7).unwrap();
        let want7 = Distribution::new_balanced(7, cfg.n_blocks(), 4, Some(16), 0xC4A1, 0).unwrap();
        for y in (0..d7.n_blocks()).step_by(11) {
            assert_eq!(d7.slice_of(y), want7.slice_of(y));
            for k in 0..4 {
                assert_eq!(d7.holder(y, k), want7.holder(y, k), "y {y} k {k}");
            }
        }
        assert_eq!(d7.max_slice_blocks(), want7.max_slice_blocks());
    }

    #[test]
    fn reshaped_preserves_offset_semantics() {
        let cfg = RestoreConfig::builder(8, 8, 64)
            .replicas(2)
            .placement_offset(5)
            .build()
            .unwrap();
        let old = Distribution::new(&cfg);
        let got = old.reshaped(4).unwrap();
        let fresh = RestoreConfig::builder(4, 8, 128)
            .replicas(2)
            .placement_offset(5)
            .build()
            .unwrap();
        let want = Distribution::new(&fresh);
        assert_eq!(got.placement_offset(), want.placement_offset());
        for y in (0..512).step_by(13) {
            assert_eq!(got.holder(y, 1), want.holder(y, 1));
        }
        // ...and at a non-dividing world against the balanced reference
        let got5 = old.reshaped(5).unwrap();
        let want5 = Distribution::new_balanced(5, 512, 2, None, cfg.seed, 5).unwrap();
        assert_eq!(got5.placement_offset(), want5.placement_offset());
        for y in (0..512).step_by(7) {
            assert_eq!(got5.holder(y, 1), want5.holder(y, 1));
        }
    }

    #[test]
    fn no_permutation_keeps_shard_contiguous() {
        let d = dist(4, 16, 2, None);
        let mut pieces = Vec::new();
        d.permuted_pieces(BlockRange::new(16, 32), &mut pieces);
        assert_eq!(pieces.len(), 1);
        assert_eq!(pieces[0].perm_start, 16);
    }
}
