//! The ReStore library core (§IV + §V of the paper).
//!
//! * [`block`] — block IDs, ranges, range sets.
//! * [`distribution`] — the placement function `L(x,k)` with permutation
//!   ranges and the precomputed unit→slot placement index shared by
//!   submit, load, and repair.
//! * [`permutation`] — Feistel range permutation (and identity).
//! * [`store`] — per-PE in-memory replica storage.
//! * [`submit`] — the one-time checkpoint creation path.
//! * [`load`] — the recovery path (request resolution + sparse all-to-all),
//!   plus the request-pattern helpers for the paper's three benchmark
//!   operations (*load 1 %*, *load all*, scattered/single-target recovery).
//! * [`idl`] — §IV-D irrecoverable-data-loss probabilities (exact
//!   inclusion–exclusion, the small-f approximation, and the Monte-Carlo
//!   failure simulator behind Fig 3).
//! * [`rebalance`] — §IV-B shrinking recovery: rewrite the layout over the
//!   `p'` survivors after `ulfm::shrink` with a minimal migration schedule,
//!   under a bumped communicator epoch.
//! * [`repair`] — §IV-E replica re-creation after failures (Appendix
//!   Distributions A and B).
//! * [`serialize`] — typed helpers to move `f32`/`u64` app data in and out
//!   of block payloads.

pub mod block;
pub mod distribution;
pub mod hashing;
pub mod idl;
pub mod load;
pub mod permutation;
pub mod rebalance;
pub mod repair;
pub mod serialize;
pub mod store;
pub mod submit;

use crate::config::RestoreConfig;
use crate::error::{Error, Result};
use crate::simnet::cluster::Cluster;
use crate::simnet::network::PhaseCost;

use block::RangeSet;
use distribution::Distribution;
use store::{HolderIndex, PeStore};

/// A per-PE load request: the *original* block ID ranges this PE wants.
/// (The paper's preferred API mode: "providing exactly those ID ranges each
/// individual PE needs on exactly that PE", §V.)
#[derive(Debug, Clone)]
pub struct LoadRequest {
    pub pe: usize,
    pub ranges: RangeSet,
}

/// Data loaded for one requesting PE, in request order.
#[derive(Debug, Clone)]
pub struct LoadedShard {
    pub pe: usize,
    /// `Some(bytes)` in execution mode, `None` in cost-model mode.
    pub bytes: Option<Vec<u8>>,
}

/// Result of a [`ReStore::load`].
#[derive(Debug, Clone)]
pub struct LoadOutput {
    pub shards: Vec<LoadedShard>,
    /// Cost of the request sparse all-to-all (phase 1).
    pub request_cost: PhaseCost,
    /// Cost of the data sparse all-to-all (phase 2).
    pub data_cost: PhaseCost,
    /// Total (= request + data).
    pub cost: PhaseCost,
}

/// Result of a [`ReStore::submit`].
#[derive(Debug, Clone)]
pub struct SubmitReport {
    pub cost: PhaseCost,
}

/// The replicated in-memory storage over a (simulated) cluster.
///
/// One `ReStore` instance owns the stores of *all* PEs — the simulator's
/// global view of what, in the paper's C++ library, is one instance per MPI
/// rank. All placement, routing and scheduling decisions are computed
/// per-PE exactly as each rank would compute them locally.
pub struct ReStore {
    cfg: RestoreConfig,
    dist: Distribution,
    stores: Vec<PeStore>,
    submitted: bool,
    /// Reverse holder index (permuted slot → storing PEs, in *cluster*
    /// ranks), maintained incrementally by submit, §IV-E repair, and the
    /// §IV-B rebalance; consulted by repair/rebalance planning and the load
    /// path's post-repair fallback instead of an O(p) store sweep.
    holder_index: HolderIndex,
    /// Distribution rank → cluster rank. The identity until the first
    /// [`ReStore::rebalance`]; afterwards the shrink's dense re-ranking
    /// (`RankMap::new_to_old`), so the `Distribution` computes the §IV-A
    /// layout in the compact post-shrink world while stores, requests, and
    /// the network keep addressing original cluster ranks.
    pe_map: Vec<u32>,
    /// Communicator epoch this layout was computed at. `submit`/`load`/
    /// `repair` refuse to run when `ulfm::shrink` has bumped the cluster
    /// epoch past it — the caller must `rebalance` (or
    /// `acknowledge_shrink`) first.
    epoch: u64,
    /// Reusable buffers for the load pipeline — grown on first use, then
    /// reused so steady-state `load()` calls allocate nothing per piece.
    scratch: load::LoadScratch,
}

impl ReStore {
    /// Create an instance sized for `cluster`'s world.
    pub fn new(cfg: RestoreConfig, cluster: &Cluster) -> Result<Self> {
        cfg.validate()?;
        if cfg.world != cluster.world() {
            return Err(Error::Config(format!(
                "config world {} != cluster world {}",
                cfg.world,
                cluster.world()
            )));
        }
        let dist = Distribution::new(&cfg);
        let stores = (0..cfg.world).map(|_| PeStore::new(cfg.block_size)).collect();
        let holder_index = HolderIndex::new(cluster.world());
        Ok(ReStore {
            cfg,
            dist,
            stores,
            submitted: false,
            holder_index,
            pe_map: (0..cfg.world as u32).collect(),
            epoch: cluster.epoch(),
            scratch: load::LoadScratch::default(),
        })
    }

    pub fn config(&self) -> &RestoreConfig {
        &self.cfg
    }

    pub fn distribution(&self) -> &Distribution {
        &self.dist
    }

    pub fn stores(&self) -> &[PeStore] {
        &self.stores
    }

    pub fn is_submitted(&self) -> bool {
        self.submitted
    }

    /// The reverse holder index (permuted slot → storing PEs).
    pub fn holder_index(&self) -> &HolderIndex {
        &self.holder_index
    }

    /// Communicator epoch the current layout addresses.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Cluster rank of distribution rank `dist_rank` (identity until the
    /// first rebalance).
    #[inline]
    pub fn cluster_rank(&self, dist_rank: usize) -> usize {
        self.pe_map[dist_rank] as usize
    }

    /// Does the current survivor count admit the balanced §IV-A layout
    /// (⌊n/p'⌋/⌈n/p'⌉ slices — see [`Distribution::reshape_feasible`])?
    /// With balanced unequal slices this holds for **every** `p' ≥ r`, so
    /// after any real kill wave the answer is almost always yes. A pure
    /// feasibility predicate: [`ReStore::rebalance`] additionally requires
    /// the epoch handshake (a `ulfm::shrink` not yet adopted) and a
    /// current [`RankMap`](crate::simnet::ulfm::RankMap) —
    /// [`ReStore::rebalance_or_acknowledge`] packages the whole policy.
    /// Only when fewer than `r` PEs survive must applications stay in the
    /// dead world via [`ReStore::acknowledge_shrink`] + §IV-E repair.
    pub fn can_rebalance(&self, cluster: &Cluster) -> bool {
        self.submitted && self.dist.reshape_feasible(cluster.n_alive())
    }

    /// Adopt a shrunk communicator **without** rewriting the layout: the
    /// distribution keeps addressing the original world (load falls back to
    /// routing around dead ranks, repair re-replicates in place), but every
    /// dead PE's replica memory is reclaimed and the store's epoch catches
    /// up to the cluster's so submit/load/repair run again. This folds the
    /// former standalone `drop_pe` reclaim — reclaiming must go through
    /// here (not the raw stores) to keep the reverse holder index
    /// consistent. Safe to call when no shrink happened (pure reclaim) and
    /// idempotent.
    pub fn acknowledge_shrink(&mut self, cluster: &Cluster) -> Result<()> {
        if cluster.world() != self.stores.len() {
            return Err(Error::Config(format!(
                "acknowledge_shrink: cluster world {} != store world {}",
                cluster.world(),
                self.stores.len()
            )));
        }
        for pe in 0..self.stores.len() {
            if !cluster.is_alive(pe) && !self.stores[pe].slices().is_empty() {
                self.stores[pe].clear();
                self.holder_index.drop_pe(pe);
            }
        }
        self.epoch = cluster.epoch();
        Ok(())
    }

    /// The full §IV-B shrink handshake for applications: rewrite the layout
    /// over the survivors when the shrunken world admits the balanced
    /// §IV-A distribution (any `p' ≥ r` — almost always, see
    /// [`ReStore::can_rebalance`]), otherwise stay in the dead world
    /// (reclaiming dead stores) — either way the store ends at the
    /// cluster's epoch. Returns the rebalance report when one ran.
    ///
    /// The `map` is validated against the cluster's *current* survivor set
    /// **before** any policy branch: a stale `RankMap` from an earlier
    /// shrink would otherwise silently steer the policy (acknowledging a
    /// rebalanceable world, or rebalancing against the wrong survivors) —
    /// surfaced as [`Error::StaleRankMap`] with the store untouched.
    ///
    /// If the rebalance itself discovers an interval with no surviving
    /// holder (`Error::IrrecoverableDataLoss`), the policy degrades to
    /// acknowledging instead of failing: data that is still held stays
    /// loadable in the dead world, and only a *targeted* load of the lost
    /// ranges reports the loss — applications whose live state covers the
    /// lost blocks keep running, exactly as before the rebalance existed.
    pub fn rebalance_or_acknowledge(
        &mut self,
        cluster: &mut Cluster,
        map: &crate::simnet::ulfm::RankMap,
    ) -> Result<Option<rebalance::RebalanceReport>> {
        map.validate_against(cluster)?;
        // A shrink that removed no ranks leaves the layout already correct:
        // adopting the epoch (acknowledge) is the O(1) action, not a
        // keep-everything rebalance that re-materializes the whole store.
        if self.submitted
            && cluster.epoch() > self.epoch
            && map.new_world() < self.dist.world()
            && self.dist.reshape_feasible(map.new_world())
        {
            match self.rebalance(cluster, map) {
                Ok(report) => return Ok(Some(report)),
                // Some interval has no surviving holder: the full-layout
                // rewrite is impossible, but data that IS still held stays
                // loadable in the dead world — degrade to acknowledge (the
                // failed rebalance left the old layout fully intact) and
                // let targeted loads surface real losses to the caller, as
                // the pre-rebalance code paths always did.
                Err(Error::IrrecoverableDataLoss { .. }) => {}
                Err(e) => return Err(e),
            }
        }
        self.acknowledge_shrink(cluster)?;
        Ok(None)
    }

    pub(crate) fn stores_mut(&mut self) -> &mut Vec<PeStore> {
        &mut self.stores
    }

    pub(crate) fn holder_index_mut(&mut self) -> &mut HolderIndex {
        &mut self.holder_index
    }

    /// Swap in a rebalanced layout (called by `rebalance` after the
    /// migration executed): new distribution, rank translation, stores, and
    /// holder index become current atomically, under the cluster's epoch.
    pub(crate) fn install_layout(
        &mut self,
        cluster: &Cluster,
        dist: Distribution,
        pe_map: Vec<u32>,
        stores: Vec<PeStore>,
        holder_index: HolderIndex,
    ) {
        debug_assert_eq!(pe_map.len(), dist.world());
        debug_assert_eq!(stores.len(), self.cfg.world);
        self.dist = dist;
        self.pe_map = pe_map;
        self.stores = stores;
        self.holder_index = holder_index;
        self.epoch = cluster.epoch();
    }

    pub(crate) fn mark_submitted(&mut self) -> Result<()> {
        if self.submitted {
            return Err(Error::AlreadySubmitted);
        }
        self.submitted = true;
        Ok(())
    }

    pub(crate) fn ensure_submitted(&self) -> Result<()> {
        if !self.submitted {
            return Err(Error::NotSubmitted);
        }
        Ok(())
    }

    /// The shrink-handshake guard on every routing operation: fail with
    /// [`Error::StaleEpoch`] when `ulfm::shrink` has produced a newer
    /// communicator than the one this layout was computed for.
    pub(crate) fn ensure_current_epoch(&self, cluster: &Cluster) -> Result<()> {
        if self.epoch != cluster.epoch() {
            return Err(Error::StaleEpoch {
                store_epoch: self.epoch,
                cluster_epoch: cluster.epoch(),
            });
        }
        Ok(())
    }

    /// Is any store holding real bytes (execution mode) rather than
    /// virtual lengths (cost-model mode)?
    pub(crate) fn is_execution_mode(&self) -> bool {
        self.stores.iter().any(|st| {
            st.slices()
                .first()
                .is_some_and(|s| matches!(s.buf, store::SliceBuf::Real(_)))
        })
    }
}
