#!/usr/bin/env python3
"""Render a Fig-4-style weak-scaling table from BENCH_*.json files.

The load-path bench binaries tag every entry name with its PE count
(`... p=1536`, `... p=24576`). This script groups the `{name,
ns_per_iter}` JSON lines by operation, pivots the PE counts into columns,
and reports the wall-clock resolve+route overhead per operation together
with the scale factor between the smallest and largest measured p — the
companion number to the paper's Fig 4 (simulated recovery time vs. the
simulator's own routing overhead at p = 24576):

    python3 tools/weak_scaling_figure.py BENCH_load_scale.json \
        BENCH_fused_load.json

CI runs this after the bench smoke steps and ships the rendered table as
WEAK_SCALING.md inside the bench-json artifact. Raw-metric entries (e.g.
`... msgs-saved-pct ...`) are listed in a separate section, as the value
their name declares rather than nanoseconds.
"""

import argparse
import json
import re
import sys

P_RE = re.compile(r"^(?P<op>.+?)\s+p=(?P<p>\d+)$")


def fmt_ns(value: float) -> str:
    if value >= 1e9:
        return f"{value / 1e9:.2f} s"
    if value >= 1e6:
        return f"{value / 1e6:.2f} ms"
    if value >= 1e3:
        return f"{value / 1e3:.2f} µs"
    return f"{value:.0f} ns"


def load(paths):
    rows = []
    for path in paths:
        try:
            with open(path, encoding="utf-8") as fh:
                for line in fh:
                    line = line.strip()
                    if not line:
                        continue
                    obj = json.loads(line)
                    rows.append((obj["name"], float(obj["ns_per_iter"])))
        except FileNotFoundError:
            print(f"warning: {path} not found (skipped)", file=sys.stderr)
    return rows


def render(rows) -> str:
    # split raw-metric entries (unit declared in the name) from timings
    timings, metrics = {}, []
    for name, value in rows:
        m = P_RE.match(name)
        if not m:
            metrics.append((name, value))
            continue
        op, p = m.group("op"), int(m.group("p"))
        if "msgs-saved-pct" in op or "sim-ns" in op or "bytes" in op:
            metrics.append((name, value))
            continue
        timings.setdefault(op, {})[p] = value

    ps = sorted({p for per_op in timings.values() for p in per_op})
    out = ["# Weak scaling — resolve+route wall overhead per operation", ""]
    header = "| operation | " + " | ".join(f"p = {p}" for p in ps) + " | scale |"
    sep = "|---" * (len(ps) + 2) + "|"
    out += [header, sep]
    for op in sorted(timings):
        per_op = timings[op]
        cells = [fmt_ns(per_op[p]) if p in per_op else "—" for p in ps]
        measured = [p for p in ps if p in per_op]
        if len(measured) >= 2 and per_op[measured[0]] > 0:
            lo, hi = measured[0], measured[-1]
            scale = f"{per_op[hi] / per_op[lo]:.1f}x over {hi // lo}x PEs"
        else:
            scale = "—"
        out.append(f"| `{op}` | " + " | ".join(cells) + f" | {scale} |")
    if metrics:
        out += ["", "## Raw metrics (unit declared by the entry name)", ""]
        out += ["| metric | value |", "|---|---|"]
        for name, value in metrics:
            if "msgs-saved-pct" in name:
                # from_value scales by 1e-9 on write and 1e9 on read: the
                # ns_per_iter field carries the percentage verbatim
                out.append(f"| `{name}` | {value:.1f} % |")
            else:
                out.append(f"| `{name}` | {value:.1f} |")
    return "\n".join(out)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("json_files", nargs="+")
    args = ap.parse_args()
    print(render(load(args.json_files)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
