//! Integration: the multi-dataset store registry and its fused
//! cross-dataset phases (§V "one ReStore object per datatype").
//!
//! The golden contracts this suite pins:
//!
//! * **facade** — the single-dataset `ReStore` API is a thin delegating
//!   facade over dataset 0 (the rest of the repo's test suite running
//!   unchanged is the byte-level half of this pin; here we check the
//!   handle and the facade agree).
//! * **fused load** — `load_many` over k datasets returns shards
//!   byte-identical to k sequential `Dataset::load`s, with identical
//!   request/data byte totals and strictly fewer total messages whenever
//!   two datasets share a (requester, server) pair.
//! * **fused shrink** — a chained 16 → 13 → 7 shrink rebalances every
//!   feasible dataset under ONE epoch bump per wave, and each rebalanced
//!   store is byte-identical to a fresh balanced construction
//!   (`Distribution::new_balanced` layout oracle) at the survivor count.
//! * **per-dataset degradation** — an IDL-hit dataset degrades to
//!   acknowledge while the others rebalance, and IDL errors carry the
//!   dataset id.

use restore::config::{RestoreConfig, ServerSelection};
use restore::error::Error;
use restore::restore::block::{BlockRange, RangeSet};
use restore::restore::store::SliceBuf;
use restore::restore::{Dataset, DatasetId, LoadRequest, ReStore};
use restore::simnet::cluster::Cluster;
use restore::simnet::network::PhaseCost;
use restore::simnet::ulfm;

fn make_shards(world: usize, bytes: usize, salt: usize) -> Vec<Vec<u8>> {
    (0..world)
        .map(|pe| (0..bytes).map(|i| (pe * 31 + i * 7 + salt) as u8).collect())
        .collect()
}

/// Two-dataset registry: dataset 0 is bulk data (r = 4, 8 B blocks,
/// optionally permuted), dataset 1 is small state (r = 2, 16 B blocks,
/// contiguous). Returns the cluster, the store, and both original shard
/// sets.
fn build_two(
    p: usize,
    s_pr: Option<usize>,
    policy: ServerSelection,
) -> (Cluster, ReStore, DatasetId, Vec<Vec<u8>>, Vec<Vec<u8>>) {
    let cfg0 = RestoreConfig::builder(p, 8, 64)
        .replicas(4)
        .perm_range_blocks(s_pr)
        .server_selection(policy)
        .build()
        .unwrap();
    let cfg1 = RestoreConfig::builder(p, 16, 32)
        .replicas(2)
        .server_selection(policy)
        .build()
        .unwrap();
    let mut cluster = Cluster::new_execution(p, 4);
    let mut store = ReStore::new(cfg0, &cluster).unwrap();
    let ds1 = store.create_dataset(cfg1, &cluster).unwrap();
    let shards0 = make_shards(p, 64 * 8, 0);
    let shards1 = make_shards(p, 32 * 16, 5);
    store.submit(&mut cluster, &shards0).unwrap();
    store.dataset_mut(ds1).unwrap().submit(&mut cluster, &shards1).unwrap();
    (cluster, store, ds1, shards0, shards1)
}

/// Scatter the `failed` PEs' shards (of a `bpp`-blocks-per-PE dataset)
/// evenly over the survivors.
fn scatter_for(bpp: u64, cluster: &Cluster, failed: &[usize]) -> Vec<LoadRequest> {
    let survivors = cluster.survivors();
    let ns = survivors.len() as u64;
    let mut per_pe: Vec<Vec<BlockRange>> = vec![Vec::new(); survivors.len()];
    for &dead in failed {
        let start = dead as u64 * bpp;
        for (j, ranges) in per_pe.iter_mut().enumerate() {
            let s = start + (j as u64 * bpp) / ns;
            let e = start + ((j as u64 + 1) * bpp) / ns;
            if s < e {
                ranges.push(BlockRange::new(s, e));
            }
        }
    }
    survivors
        .iter()
        .zip(per_pe)
        .filter(|(_, r)| !r.is_empty())
        .map(|(&pe, r)| LoadRequest { pe, ranges: RangeSet::new(r) })
        .collect()
}

#[test]
fn facade_is_dataset_zero() {
    let (cluster, store, ds1, _, _) = build_two(8, Some(16), ServerSelection::Random);
    let d0 = store.dataset(DatasetId::FIRST).unwrap();
    assert_eq!(d0.id(), DatasetId::FIRST);
    assert_eq!(store.epoch(), d0.epoch());
    assert_eq!(store.config().block_size, d0.config().block_size);
    assert_eq!(store.distribution().world(), d0.distribution().world());
    assert_eq!(store.stores().len(), d0.stores().len());
    assert_eq!(store.is_submitted(), d0.is_submitted());
    assert_eq!(store.can_rebalance(&cluster), d0.can_rebalance(&cluster));
    // the two datasets carry genuinely independent configs
    let d1 = store.dataset(ds1).unwrap();
    assert_eq!(d1.config().replicas, 2);
    assert_eq!(d1.config().block_size, 16);
    assert_eq!(d0.config().replicas, 4);
    assert_eq!(store.n_datasets(), 2);
    // unknown ids are rejected, with the registry size in the error
    match store.dataset(DatasetId(7)) {
        Err(Error::UnknownDataset { dataset: 7, datasets: 2 }) => {}
        other => panic!("expected UnknownDataset, got {:?}", other.map(|_| ())),
    }
}

/// Golden (b): fused vs sequential — byte-identical shards, identical
/// byte totals, strictly fewer messages on a crafted guaranteed-shared
/// pair (Primary policy, identity layouts: both datasets serve PE 2's
/// shard from PE 2 to requester 0).
#[test]
fn load_many_merges_shared_pairs_exactly() {
    let (mut cluster, mut store, ds1, shards0, shards1) =
        build_two(8, None, ServerSelection::Primary);
    let reqs0 = vec![LoadRequest {
        pe: 0,
        ranges: RangeSet::new(vec![BlockRange::new(2 * 64, 3 * 64)]),
    }];
    let reqs1 = vec![LoadRequest {
        pe: 0,
        ranges: RangeSet::new(vec![BlockRange::new(2 * 32, 3 * 32)]),
    }];

    // sequential reference: two full two-phase rounds
    let out0 = store.load(&mut cluster, &reqs0).unwrap();
    let out1 = store.dataset_mut(ds1).unwrap().load(&mut cluster, &reqs1).unwrap();
    assert_eq!(out0.request_cost.total_msgs + out1.request_cost.total_msgs, 2);
    assert_eq!(out0.data_cost.total_msgs + out1.data_cost.total_msgs, 2);

    // fused: ONE request message and ONE data message for the shared
    // (0, 2) pair, same bytes
    let parts = [(DatasetId::FIRST, reqs0), (ds1, reqs1)];
    let fused = store.load_many(&mut cluster, &parts).unwrap();
    assert_eq!(fused.request_cost.total_msgs, 1, "shared pair must merge");
    assert_eq!(fused.data_cost.total_msgs, 1, "shared pair must merge");
    assert_eq!(
        fused.request_cost.total_bytes,
        out0.request_cost.total_bytes + out1.request_cost.total_bytes
    );
    assert_eq!(
        fused.data_cost.total_bytes,
        out0.data_cost.total_bytes + out1.data_cost.total_bytes
    );
    // shards byte-identical to the sequential loads...
    assert_eq!(fused.parts[0].shards[0].bytes, out0.shards[0].bytes);
    assert_eq!(fused.parts[1].shards[0].bytes, out1.shards[0].bytes);
    // ...and to the original data
    assert_eq!(fused.parts[0].shards[0].bytes.as_deref().unwrap(), &shards0[2][..]);
    assert_eq!(fused.parts[1].shards[0].bytes.as_deref().unwrap(), &shards1[2][..]);
}

/// Golden (b) at scale: a scattered two-dataset recovery after a failure —
/// fused shards byte-identical to sequential, byte totals identical,
/// message totals never higher. In the identity layout (`s_pr = None`)
/// the kill wave leaves PE 11 as the ONLY alive holder of both datasets'
/// slot-3 data, so every policy routes every requester's slot-3 pieces of
/// both datasets to 11 — the (requester, 11) pairs are provably shared
/// and the fused message count must be strictly lower.
#[test]
fn load_many_matches_sequential_scatter_recovery() {
    for policy in
        [ServerSelection::Random, ServerSelection::LeastLoaded, ServerSelection::Primary]
    {
        for s_pr in [Some(16), None] {
            let tag = format!("{policy:?}/{s_pr:?}");
            let (mut cluster, mut store, ds1, _, _) = build_two(16, s_pr, policy);
            // Kill dataset 0's slot-3 holder group minus PE 11 ({3, 7, 15}
            // of the stride-4 group {3, 7, 11, 15}). Dataset 1 (stride 8
            // pairs) loses one holder of {3, 11} and both of {7, 15} — so
            // its requests cover only dead PE 3's shard (slot 3, sole
            // alive holder 11), while dataset 0 scatters all three dead
            // shards.
            cluster.kill(&[3, 7, 15]);
            let parts = [
                (DatasetId::FIRST, scatter_for(64, &cluster, &[3, 7, 15])),
                (ds1, scatter_for(32, &cluster, &[3])),
            ];

            let mut seq_req = PhaseCost::default();
            let mut seq_data = PhaseCost::default();
            let mut seq_shards: Vec<Vec<Option<Vec<u8>>>> = Vec::new();
            for (id, reqs) in &parts {
                let out = store.dataset_mut(*id).unwrap().load(&mut cluster, reqs).unwrap();
                seq_req = seq_req.then(out.request_cost);
                seq_data = seq_data.then(out.data_cost);
                seq_shards.push(out.shards.into_iter().map(|s| s.bytes).collect());
            }

            let fused = store.load_many(&mut cluster, &parts).unwrap();
            for (d, part) in fused.parts.iter().enumerate() {
                for (i, shard) in part.shards.iter().enumerate() {
                    assert_eq!(shard.bytes, seq_shards[d][i], "{tag}: dataset {d} shard {i}");
                }
            }
            assert_eq!(fused.request_cost.total_bytes, seq_req.total_bytes, "{tag}");
            assert_eq!(fused.data_cost.total_bytes, seq_data.total_bytes, "{tag}");
            assert!(
                fused.request_cost.total_msgs <= seq_req.total_msgs,
                "{tag}: fusing can never add messages"
            );
            assert!(fused.data_cost.total_msgs <= seq_data.total_msgs, "{tag}");
            if s_pr.is_none() {
                // identity layout: the shared (requester, 11) pairs are
                // guaranteed — strictly fewer messages, same bytes.
                assert!(
                    fused.request_cost.total_msgs < seq_req.total_msgs,
                    "{tag}: shared slot-3 pairs must merge ({} !< {})",
                    fused.request_cost.total_msgs,
                    seq_req.total_msgs
                );
                assert!(fused.data_cost.total_msgs < seq_data.total_msgs, "{tag}");
            }
        }
    }
}

/// Pooled-arena fused load: `load_many_pooled` plans and charges exactly
/// like `load_many` (identical phase costs) but assembles every dataset's
/// shards into ONE output arena — each shard a span of it, byte-identical
/// to the per-shard `Vec` the unpooled path allocates. Cost-model datasets
/// contribute no bytes: their spans are `None`, and they pool fine next to
/// execution-mode datasets in the same call.
#[test]
fn load_many_pooled_matches_unpooled_span_for_span() {
    let (mut cluster, mut store, ds1, _, _) = build_two(16, None, ServerSelection::Primary);
    cluster.kill(&[3, 7, 15]);
    let parts = [
        (DatasetId::FIRST, scatter_for(64, &cluster, &[3, 7, 15])),
        (ds1, scatter_for(32, &cluster, &[3])),
    ];

    let fused = store.load_many(&mut cluster, &parts).unwrap();
    let pooled = store.load_many_pooled(&mut cluster, &parts).unwrap();

    assert_eq!(pooled.request_cost, fused.request_cost, "same plan, same request phase");
    assert_eq!(pooled.data_cost, fused.data_cost, "same plan, same data phase");
    assert_eq!(pooled.cost, fused.cost);

    // span-for-span byte parity with the unpooled per-shard Vecs, and the
    // arena is exactly the concatenation of the spans in emission order
    let mut expected_total = 0usize;
    assert_eq!(pooled.parts.len(), fused.parts.len());
    for (d, (fpart, ppart)) in fused.parts.iter().zip(&pooled.parts).enumerate() {
        assert_eq!(ppart.dataset, fpart.dataset);
        assert_eq!(ppart.shards.len(), fpart.shards.len());
        for (i, (fs, ps)) in fpart.shards.iter().zip(&ppart.shards).enumerate() {
            assert_eq!(ps.pe, fs.pe, "dataset {d} shard {i}");
            assert_eq!(
                pooled.shard_bytes(d, i),
                fs.bytes.as_deref(),
                "dataset {d} shard {i} bytes"
            );
            expected_total += fs.bytes.as_ref().map_or(0, |b| b.len());
        }
    }
    assert_eq!(pooled.arena.len(), expected_total, "one arena, no slack");

    // a cost-model dataset pooled next to an execution one: virtual shards
    // have no spans, real shards keep theirs
    let mut cluster2 = Cluster::new_execution(8, 4);
    let cfg_r = RestoreConfig::builder(8, 8, 64).replicas(2).build().unwrap();
    let cfg_v = RestoreConfig::builder(8, 8, 64).replicas(2).build().unwrap();
    let mut store2 = ReStore::new(cfg_r, &cluster2).unwrap();
    let dsv = store2.create_dataset(cfg_v, &cluster2).unwrap();
    store2.submit(&mut cluster2, &make_shards(8, 64 * 8, 3)).unwrap();
    store2.dataset_mut(dsv).unwrap().submit_virtual(&mut cluster2).unwrap();
    cluster2.kill(&[2]);
    let mixed = [
        (DatasetId::FIRST, scatter_for(64, &cluster2, &[2])),
        (dsv, scatter_for(64, &cluster2, &[2])),
    ];
    let out = store2.load_many_pooled(&mut cluster2, &mixed).unwrap();
    assert!(out.parts[0].shards.iter().all(|s| s.span.is_some()));
    assert!(out.parts[1].shards.iter().all(|s| s.span.is_none()));
    assert!(out.cost.total_bytes > 0, "virtual loads still charge the cost model");
}

#[test]
fn load_many_rejects_duplicates_unknown_ids_and_out_of_space_requests() {
    let (mut cluster, mut store, ds1, _, _) = build_two(8, Some(16), ServerSelection::Random);
    let req = |pe: usize, s: u64, e: u64| {
        vec![LoadRequest { pe, ranges: RangeSet::new(vec![BlockRange::new(s, e)]) }]
    };
    // duplicate dataset entries
    let dup = [(ds1, req(0, 0, 8)), (ds1, req(1, 8, 16))];
    assert!(matches!(store.load_many(&mut cluster, &dup), Err(Error::Config(_))));
    // unknown id
    let unk = [(DatasetId(9), req(0, 0, 8))];
    assert!(matches!(
        store.load_many(&mut cluster, &unk),
        Err(Error::UnknownDataset { dataset: 9, .. })
    ));
    // out-of-space request (ds1 has 8 * 32 = 256 blocks)
    let oob = [(ds1, req(0, 250, 300))];
    assert!(matches!(store.load_many(&mut cluster, &oob), Err(Error::Config(_))));
    // ...and a valid call still works afterwards (scratches were reattached)
    let ok = [(DatasetId::FIRST, req(1, 0, 16)), (ds1, req(1, 0, 8))];
    let out = store.load_many(&mut cluster, &ok).unwrap();
    assert_eq!(out.parts.len(), 2);
}

/// IDL errors carry the dataset id: killing both r = 2 holders of dataset
/// 1's slot 0 (PEs 0 and 8) loses only dataset 1's blocks — dataset 0
/// still has 2 of 4 holders alive.
#[test]
fn idl_is_tagged_with_the_lossy_dataset() {
    let (mut cluster, mut store, ds1, _, _) = build_two(16, None, ServerSelection::Random);
    cluster.kill(&[0, 8]);
    let parts = [
        (DatasetId::FIRST, scatter_for(64, &cluster, &[0])),
        (ds1, scatter_for(32, &cluster, &[0])),
    ];
    match store.load_many(&mut cluster, &parts) {
        Err(Error::IrrecoverableDataLoss { dataset, .. }) => assert_eq!(dataset, ds1),
        other => panic!("expected dataset-tagged IDL, got {:?}", other.map(|_| ())),
    }
    // dataset 0 alone still loads the lost shard fine
    let out = store.load(&mut cluster, &scatter_for(64, &cluster, &[0])).unwrap();
    assert!(out.cost.total_bytes > 0);
}

/// Per-dataset degradation in the fused handshake: after killing a whole
/// r = 2 group of dataset 1, the shrink rebalances dataset 0 (feasible)
/// and acknowledges dataset 1 (IDL) — both under the cluster's epoch.
#[test]
fn fused_handshake_degrades_only_the_lossy_dataset() {
    let (mut cluster, mut store, ds1, _, _) = build_two(16, None, ServerSelection::Random);
    cluster.kill(&[0, 8]);
    let (_failed, map, _) = ulfm::recover(&mut cluster);
    let outcomes = store.rebalance_or_acknowledge_all(&mut cluster, &map).unwrap();
    let rep0 = outcomes[0].as_ref().expect("dataset 0 must rebalance");
    assert_eq!(rep0.new_world, 14);
    assert!(outcomes[1].is_none(), "dataset 1 must degrade to acknowledge");
    assert_eq!(store.epoch(), cluster.epoch());
    assert_eq!(store.dataset(ds1).unwrap().epoch(), cluster.epoch());
    // dataset 1 keeps the dead-world layout; its dead stores are reclaimed
    assert_eq!(store.dataset(ds1).unwrap().distribution().world(), 16);
    assert!(store.dataset(ds1).unwrap().stores()[0].slices().is_empty());
    // a targeted load of the lost slot reports the tagged loss
    let lost = vec![LoadRequest {
        pe: 1,
        ranges: RangeSet::new(vec![BlockRange::new(0, 32)]),
    }];
    match store.dataset_mut(ds1).unwrap().load(&mut cluster, &lost) {
        Err(Error::IrrecoverableDataLoss { dataset, .. }) => assert_eq!(dataset, ds1),
        other => panic!("expected tagged IDL, got {:?}", other.map(|_| ())),
    }
}

/// The fresh-layout store oracle: the permuted bytes each (new rank, copy)
/// slice of `ds` must hold, derived block by block from the original
/// global data — `Distribution::new_balanced` semantics without touching
/// the migration machinery.
fn assert_matches_fresh_layout(
    ds: &Dataset,
    new_to_old: &[usize],
    shards: &[Vec<u8>],
    tag: &str,
) {
    let dist = ds.distribution();
    let bs = ds.config().block_size;
    let global: Vec<u8> = shards.iter().flatten().copied().collect();
    for (j, &pe) in new_to_old.iter().enumerate() {
        let mut want: Vec<(BlockRange, Vec<u8>)> = (0..dist.replicas())
            .map(|k| {
                let range = dist.stored_slice(j, k);
                let mut buf = Vec::with_capacity(range.len() as usize * bs);
                for y in range.start..range.end {
                    let x = dist.unpermute_block(y) as usize;
                    buf.extend_from_slice(&global[x * bs..(x + 1) * bs]);
                }
                (range, buf)
            })
            .collect();
        want.sort_by_key(|(r, _)| r.start);
        let got = ds.stores()[pe].slices();
        assert_eq!(got.len(), want.len(), "{tag}: new rank {j} slice count");
        for (g, (wrange, wbytes)) in got.iter().zip(&want) {
            assert_eq!(g.range, *wrange, "{tag}: new rank {j}");
            let SliceBuf::Real(gb) = &g.buf else {
                panic!("{tag}: execution mode must store real bytes");
            };
            assert_eq!(gb, wbytes, "{tag}: new rank {j} slice {wrange:?}");
        }
    }
}

/// Golden (c): the chained 16 → 13 → 7 shrink rebalances BOTH datasets
/// under exactly one epoch bump per wave, each landing byte-identical to
/// a fresh balanced construction at the survivor count, and both
/// datasets' original data stays loadable bit-exactly at p'' = 7.
#[test]
fn chained_shrink_rebalances_all_datasets_under_one_epoch() {
    let (mut cluster, mut store, ds1, shards0, shards1) =
        build_two(16, Some(16), ServerSelection::Random);

    // --- wave 1: 16 -> 13 -------------------------------------------------
    cluster.kill(&[0, 1, 2]);
    let epoch_before = cluster.epoch();
    let (_failed, map, _) = ulfm::recover(&mut cluster);
    assert_eq!(cluster.epoch(), epoch_before + 1, "one shrink = one epoch bump");
    let outcomes = store.rebalance_or_acknowledge_all(&mut cluster, &map).unwrap();
    for (i, o) in outcomes.iter().enumerate() {
        assert_eq!(o.as_ref().expect("both rebalance").new_world, 13, "dataset {i}");
    }
    assert_eq!(store.epoch(), cluster.epoch());
    assert_eq!(store.dataset(ds1).unwrap().epoch(), cluster.epoch());
    let new_to_old: Vec<usize> = map.new_to_old.clone();
    assert_matches_fresh_layout(
        store.dataset(DatasetId::FIRST).unwrap(),
        &new_to_old,
        &shards0,
        "wave1/ds0",
    );
    assert_matches_fresh_layout(store.dataset(ds1).unwrap(), &new_to_old, &shards1, "wave1/ds1");

    // --- wave 2: 13 -> 7 (kill new ranks 0..5) -----------------------------
    // safe: ds0 holders sit at stride 3 (s+6, s+9 survive), ds1 at stride
    // 6 (s or s+6 survives) — no slot loses every holder.
    let kills: Vec<usize> = new_to_old[..6].to_vec();
    cluster.kill(&kills);
    let epoch_before = cluster.epoch();
    let (_failed, map2, _) = ulfm::recover(&mut cluster);
    assert_eq!(cluster.epoch(), epoch_before + 1);
    let outcomes = store.rebalance_or_acknowledge_all(&mut cluster, &map2).unwrap();
    for (i, o) in outcomes.iter().enumerate() {
        assert_eq!(o.as_ref().expect("both rebalance").new_world, 7, "dataset {i}");
    }
    assert_eq!(store.epoch(), cluster.epoch());
    assert_eq!(store.dataset(ds1).unwrap().epoch(), cluster.epoch());
    assert_matches_fresh_layout(
        store.dataset(DatasetId::FIRST).unwrap(),
        &map2.new_to_old,
        &shards0,
        "wave2/ds0",
    );
    assert_matches_fresh_layout(
        store.dataset(ds1).unwrap(),
        &map2.new_to_old,
        &shards1,
        "wave2/ds1",
    );

    // --- every original byte of both datasets still loads, fused ----------
    let dead_all: Vec<usize> = (0..16).filter(|pe| !cluster.is_alive(*pe)).collect();
    let parts = [
        (DatasetId::FIRST, scatter_for(64, &cluster, &dead_all)),
        (ds1, scatter_for(32, &cluster, &dead_all)),
    ];
    let out = store.load_many(&mut cluster, &parts).unwrap();
    for (d, (shards, bpp, bs)) in
        [(&shards0, 64u64, 8usize), (&shards1, 32, 16)].into_iter().enumerate()
    {
        for (req, shard) in parts[d].1.iter().zip(&out.parts[d].shards) {
            let bytes = shard.bytes.as_ref().expect("execution mode");
            let mut off = 0usize;
            for range in req.ranges.ranges() {
                for x in range.start..range.end {
                    let pe = (x / bpp) as usize;
                    let boff = (x % bpp) as usize * bs;
                    assert_eq!(
                        &bytes[off..off + bs],
                        &shards[pe][boff..boff + bs],
                        "dataset {d} block {x}"
                    );
                    off += bs;
                }
            }
        }
    }
}
