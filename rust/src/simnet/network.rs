//! α-β(-NIC) network cost model.
//!
//! The paper analyses its algorithms in terms of the **bottleneck number of
//! messages** and the **bottleneck communication volume** (§II). This module
//! turns the *exact* message schedule of a communication phase into those
//! two bottleneck quantities plus a simulated elapsed time:
//!
//! ```text
//! t = α · max_PE(sent + received msgs)                    (latency term)
//!   + max_node(bytes)/node_bw · (1 + γ·ln(1 + msgs/PE))   (shared NIC term)
//!   + max_PE(sent + received bytes) / pe_mem_bw           (copy term)
//! ```
//!
//! The `γ` factor models NIC/MPI fragmentation congestion: a node moving
//! its bytes as many small interleaved messages achieves lower effective
//! bandwidth than one moving few large streams (packet interleaving,
//! matching, rendezvous). This is what makes the paper's *dense* patterns
//! (submit/load-all with permutations, Fig 4b) slower despite equal volume.
//!
//! A global *bisection* bound additionally caps phases that move large
//! total volume (SuperMUC-NG's island fat-tree is 1:4 pruned): the NIC
//! term is lower-bounded by `total_bytes / (node_bw·nodes/oversub)`.
//!
//! The NIC term models 48 PEs sharing one 100 Gbit/s OmniPath port
//! (§VI-A + §VI-D.2: "all 48 processes on a single node have to share the
//! same interconnect"); calibration against the paper's reported §VI-D.2
//! numbers is recorded in EXPERIMENTS.md.

use crate::config::NetworkConfig;
use crate::simnet::topology::Topology;

/// Cost of one communication phase (and, additively, of a whole operation).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseCost {
    /// Simulated elapsed time in seconds.
    pub sim_time_s: f64,
    /// Bottleneck number of messages (max over PEs of sent+received).
    pub bottleneck_msgs: u64,
    /// Bottleneck communication volume (max over PEs of sent+received bytes).
    pub bottleneck_bytes: u64,
    /// Total bytes moved across the network in this phase.
    pub total_bytes: u64,
    /// Total number of point-to-point messages.
    pub total_msgs: u64,
}

impl PhaseCost {
    /// Sequential composition: phases run one after the other.
    pub fn then(self, next: PhaseCost) -> PhaseCost {
        PhaseCost {
            sim_time_s: self.sim_time_s + next.sim_time_s,
            bottleneck_msgs: self.bottleneck_msgs + next.bottleneck_msgs,
            bottleneck_bytes: self.bottleneck_bytes + next.bottleneck_bytes,
            total_bytes: self.total_bytes + next.total_bytes,
            total_msgs: self.total_msgs + next.total_msgs,
        }
    }

    /// A pure-latency phase of `msgs` sequential message rounds (barriers,
    /// agreement protocols).
    pub fn latency(net: &NetworkConfig, msgs: u64) -> PhaseCost {
        PhaseCost {
            sim_time_s: net.alpha_s * msgs as f64,
            bottleneck_msgs: msgs,
            ..Default::default()
        }
    }

    /// A pure local-copy phase (serialization into send buffers etc.).
    pub fn local_copy(net: &NetworkConfig, bytes: u64) -> PhaseCost {
        PhaseCost {
            sim_time_s: bytes as f64 / net.pe_mem_bw_bytes_per_s,
            ..Default::default()
        }
    }
}

/// Per-PE accumulator for one phase's message schedule.
///
/// Callers register every point-to-point message with [`Accumulator::msg`];
/// [`Accumulator::finish`] produces the [`PhaseCost`]. Self-messages (a PE
/// "sending" to itself, e.g. a replica that stays local) cost only memory
/// bandwidth, no NIC or latency — matching the paper's experiments which
/// explicitly exclude same-node copies by construction.
///
/// ## Sparse (epoch-stamped) counters
///
/// The per-PE and per-node counter tables are *epoch-stamped*: an entry is
/// live only while its stamp matches the accumulator's current phase epoch,
/// and the endpoints a phase actually charges are recorded in touched
/// lists. Clearing for the next phase is therefore O(1) (bump the epoch,
/// truncate the touched lists) and [`Accumulator::compute`] walks only the
/// touched entries — a steady-state load at p = 2^20 pays for the handful
/// of PEs it routed through, not for five length-p zeroing sweeps per
/// phase. Untouched entries read as zero, so every bottleneck max and the
/// NIC loop are exactly the dense sums/maxes (golden- and property-tested
/// against a dense reference).
#[derive(Debug)]
pub struct Accumulator {
    net: NetworkConfig,
    topo: Topology,
    /// Current phase stamp: an entry of the stamped tables below is live
    /// iff its stamp equals this. Bumped by `reset`/`finish_reset`, which
    /// is what makes clearing O(1). u64: never wraps in any realistic run,
    /// so a stale stamp can never alias a live one.
    epoch: u64,
    pe_stamp: Vec<u64>,
    pe_msgs: Vec<u32>,
    pe_frags: Vec<u64>,
    pe_bytes: Vec<u64>,
    /// PEs charged this phase (indices into the `pe_*` tables).
    touched_pes: Vec<u32>,
    node_stamp: Vec<u64>,
    node_bytes: Vec<u64>,
    node_msgs: Vec<u64>,
    /// Nodes charged this phase (indices into the `node_*` tables).
    touched_nodes: Vec<u32>,
    local_bytes: u64,
    total_bytes: u64,
    total_msgs: u64,
    last_touched_pes: usize,
    last_touched_nodes: usize,
}

impl Default for Accumulator {
    /// An empty 1-PE accumulator — a placeholder shell for pooled reuse;
    /// call [`Accumulator::reset`] against the real cluster before use.
    fn default() -> Self {
        Accumulator::new(&NetworkConfig::default(), &Topology::new(1, 1))
    }
}

impl Accumulator {
    pub fn new(net: &NetworkConfig, topo: &Topology) -> Self {
        Accumulator {
            net: net.clone(),
            topo: topo.clone(),
            epoch: 1,
            pe_stamp: vec![0; topo.pes()],
            pe_msgs: vec![0; topo.pes()],
            pe_frags: vec![0; topo.pes()],
            pe_bytes: vec![0; topo.pes()],
            touched_pes: Vec::new(),
            node_stamp: vec![0; topo.nodes()],
            node_bytes: vec![0; topo.nodes()],
            node_msgs: vec![0; topo.nodes()],
            touched_nodes: Vec::new(),
            local_bytes: 0,
            total_bytes: 0,
            total_msgs: 0,
            last_touched_pes: 0,
            last_touched_nodes: 0,
        }
    }

    /// Re-arm a pooled accumulator for a new phase: adopt `net`/`topo` and
    /// invalidate every counter by bumping the phase stamp — O(1), no
    /// zeroing sweep. The tables only ever grow (to the largest world
    /// seen), so after a warm-up phase this performs no heap allocation
    /// (the last O(p) allocation of every `ReStore::load` call, pooled in
    /// its `LoadScratch`). A *shrinking* topology change (a §IV-B
    /// rebalance to p') is handled by the same stamp bump: entries charged
    /// against the old, larger node/PE count go stale instead of lingering
    /// in the table — the next phase can never be billed against the old
    /// world's capacity (regression-tested below).
    pub fn reset(&mut self, net: &NetworkConfig, topo: &Topology) {
        self.net = net.clone();
        self.topo = topo.clone();
        let pes = self.topo.pes();
        if self.pe_stamp.len() < pes {
            self.pe_stamp.resize(pes, 0);
            self.pe_msgs.resize(pes, 0);
            self.pe_frags.resize(pes, 0);
            self.pe_bytes.resize(pes, 0);
        }
        let nodes = self.topo.nodes();
        if self.node_stamp.len() < nodes {
            self.node_stamp.resize(nodes, 0);
            self.node_bytes.resize(nodes, 0);
            self.node_msgs.resize(nodes, 0);
        }
        self.begin_phase();
    }

    /// Start the next phase: one stamp bump invalidates every table entry
    /// (grown entries carry stamp 0 and the epoch starts at 1, so they are
    /// stale too); the touched lists truncate in place.
    fn begin_phase(&mut self) {
        self.epoch += 1;
        self.touched_pes.clear();
        self.touched_nodes.clear();
        self.local_bytes = 0;
        self.total_bytes = 0;
        self.total_msgs = 0;
    }

    /// Capacity of the per-PE counter vectors (steady-state reuse tests).
    pub fn pe_capacity(&self) -> usize {
        self.pe_msgs.capacity()
    }

    /// Touched-entry counts `(PEs, nodes)` of the most recently *finished*
    /// pooled phase ([`Accumulator::finish_reset`]) — the scale-
    /// independence contract surfaced to the alloc-count harness and the
    /// million-rank bench: for a fixed request shape these must not grow
    /// with the world size.
    pub fn last_touched(&self) -> (usize, usize) {
        (self.last_touched_pes, self.last_touched_nodes)
    }

    #[inline]
    fn touch_pe(&mut self, pe: usize) {
        if self.pe_stamp[pe] != self.epoch {
            self.pe_stamp[pe] = self.epoch;
            self.pe_msgs[pe] = 0;
            self.pe_frags[pe] = 0;
            self.pe_bytes[pe] = 0;
            self.touched_pes.push(pe as u32);
        }
    }

    #[inline]
    fn touch_node(&mut self, node: usize) {
        if self.node_stamp[node] != self.epoch {
            self.node_stamp[node] = self.epoch;
            self.node_bytes[node] = 0;
            self.node_msgs[node] = 0;
            self.touched_nodes.push(node as u32);
        }
    }

    /// Register one message of `bytes` from `src` to `dst`.
    pub fn msg(&mut self, src: usize, dst: usize, bytes: u64) {
        if src == dst {
            self.local_bytes = self.local_bytes.max(bytes);
            return;
        }
        self.touch_pe(src);
        self.touch_pe(dst);
        self.pe_msgs[src] += 1;
        self.pe_msgs[dst] += 1;
        self.pe_bytes[src] += bytes;
        self.pe_bytes[dst] += bytes;
        let (ns, nd) = (self.topo.node_of(src), self.topo.node_of(dst));
        self.touch_node(ns);
        self.node_bytes[ns] += bytes;
        self.node_msgs[ns] += 1;
        if nd != ns {
            self.touch_node(nd);
            self.node_bytes[nd] += bytes;
            self.node_msgs[nd] += 1;
        }
        self.total_bytes += bytes;
        self.total_msgs += 1;
    }

    /// Charge `count` non-contiguous fragments handled by `pe` this phase
    /// (packing on the sender, unpacking on the receiver).
    pub fn frag(&mut self, pe: usize, count: u64) {
        self.touch_pe(pe);
        self.pe_frags[pe] += count;
    }

    pub fn finish(self) -> PhaseCost {
        self.compute()
    }

    /// Compute the phase cost and clear in place — O(touched): record the
    /// touched-entry counts, bump the stamp, truncate the touched lists.
    /// The accumulator is ready for the next [`Accumulator::reset`]-free
    /// phase at the same world size.
    pub fn finish_reset(&mut self) -> PhaseCost {
        let cost = self.compute();
        self.last_touched_pes = self.touched_pes.len();
        self.last_touched_nodes = self.touched_nodes.len();
        self.begin_phase();
        cost
    }

    fn compute(&self) -> PhaseCost {
        // Bottleneck maxes over the touched entries only: every untouched
        // entry is (logically) zero, so the maxes equal the dense sweep's.
        let mut bmsgs = 0u64;
        let mut bfrags = 0u64;
        let mut bbytes = 0u64;
        for &pe in &self.touched_pes {
            let pe = pe as usize;
            bmsgs = bmsgs.max(self.pe_msgs[pe] as u64);
            bfrags = bfrags.max(self.pe_frags[pe]);
            bbytes = bbytes.max(self.pe_bytes[pe]);
        }
        // the binding node: the one with the largest *degraded* byte time;
        // track the worst per-node degradation factor as well (the pruned
        // global links suffer the same message interleaving, so it also
        // scales the bisection bound below). Untouched nodes contribute a
        // zero byte time and never update degrade_max (b == 0), so walking
        // only the touched nodes is exact.
        let mut nic_time = 0.0f64;
        let mut degrade_max = 1.0f64;
        for &node in &self.touched_nodes {
            let node = node as usize;
            let (b, m) = (self.node_bytes[node], self.node_msgs[node]);
            let per_pe = m as f64 / self.net.pes_per_node as f64;
            let degrade = 1.0 + self.net.frag_gamma * (1.0 + per_pe).ln();
            nic_time = nic_time.max(b as f64 / self.net.node_bw_bytes_per_s * degrade);
            if b > 0 {
                degrade_max = degrade_max.max(degrade);
            }
        }
        // pruned-fat-tree bisection bound on global traffic
        let nodes = self.topo.nodes();
        let bisect_time = if self.net.bisection_oversubscription > 0.0 && nodes > 1 {
            // small systems are non-blocking: bisection never drops below
            // a single node's bandwidth
            let bw = self.net.node_bw_bytes_per_s
                * (nodes as f64 / self.net.bisection_oversubscription).max(1.0);
            self.total_bytes as f64 / bw * degrade_max
        } else {
            0.0
        };
        let t = self.net.alpha_s * bmsgs as f64
            + self.net.fragment_cost_s * bfrags as f64
            + nic_time.max(bisect_time)
            + (bbytes + self.local_bytes) as f64 / self.net.pe_mem_bw_bytes_per_s;
        PhaseCost {
            sim_time_s: t,
            bottleneck_msgs: bmsgs,
            bottleneck_bytes: bbytes,
            total_bytes: self.total_bytes,
            total_msgs: self.total_msgs,
        }
    }
}

/// Cost of a binomial-tree allreduce of `bytes` payload over `p` live PEs
/// spread over the topology (used by the apps' per-iteration reductions).
pub fn allreduce_cost(net: &NetworkConfig, p: usize, bytes: u64) -> PhaseCost {
    if p <= 1 {
        return PhaseCost::default();
    }
    let rounds = (p as f64).log2().ceil() as u64;
    // reduce + broadcast: 2 rounds of log p messages of `bytes` each.
    PhaseCost {
        sim_time_s: 2.0
            * rounds as f64
            * (net.alpha_s + bytes as f64 / net.node_bw_bytes_per_s),
        bottleneck_msgs: 2 * rounds,
        bottleneck_bytes: 2 * rounds * bytes,
        total_bytes: 2 * (p as u64 - 1) * bytes,
        total_msgs: 2 * (p as u64 - 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(p: usize) -> (NetworkConfig, Topology) {
        (NetworkConfig::default(), Topology::new(p, 48))
    }

    #[test]
    fn empty_phase_is_free() {
        let (net, topo) = setup(96);
        let acc = Accumulator::new(&net, &topo);
        assert_eq!(acc.finish(), PhaseCost::default());
    }

    #[test]
    fn single_message_cost() {
        let (net, topo) = setup(96);
        let mut acc = Accumulator::new(&net, &topo);
        acc.msg(0, 50, 1_000_000); // cross-node
        let c = acc.finish();
        assert_eq!(c.bottleneck_msgs, 1);
        assert_eq!(c.bottleneck_bytes, 1_000_000);
        assert_eq!(c.total_msgs, 1);
        // alpha + nic (with single-message degradation) + memcpy
        let degrade = 1.0 + 0.12 * (1.0f64 + 1.0 / 48.0).ln();
        let expect = 2e-6 + 1e6 / 12.5e9 * degrade + 1e6 / 8e9;
        assert!((c.sim_time_s - expect).abs() < 1e-12);
    }

    #[test]
    fn self_message_is_memcpy_only() {
        let (net, topo) = setup(96);
        let mut acc = Accumulator::new(&net, &topo);
        acc.msg(3, 3, 8_000_000);
        let c = acc.finish();
        assert_eq!(c.bottleneck_msgs, 0);
        assert_eq!(c.total_bytes, 0);
        assert!((c.sim_time_s - 1e-3).abs() < 1e-9); // 8 MB / 8 GB/s
    }

    #[test]
    fn nic_sharing_dominates_fanin() {
        // 48 PEs of node 0 each receive 1 MB from distinct remote PEs: the
        // shared NIC serializes ~48 MB even though each PE gets only 1 MB.
        let (net, topo) = setup(96);
        let mut acc = Accumulator::new(&net, &topo);
        for i in 0..48 {
            acc.msg(48 + i, i, 1_000_000);
        }
        let c = acc.finish();
        assert_eq!(c.bottleneck_msgs, 1);
        assert_eq!(c.bottleneck_bytes, 1_000_000);
        assert!(c.sim_time_s > 48e6 / 12.5e9 * 0.99);
    }

    #[test]
    fn many_small_messages_pay_latency() {
        // The Fig-4a left edge: tiny permutation ranges explode the message
        // count and latency dominates.
        let (net, topo) = setup(4800);
        let mut acc = Accumulator::new(&net, &topo);
        for dst in 1..4097 {
            acc.msg(0, dst, 64);
        }
        let c = acc.finish();
        assert_eq!(c.bottleneck_msgs, 4096);
        assert!(c.sim_time_s > 4096.0 * 2e-6 * 0.99);
    }

    #[test]
    fn pooled_reset_matches_fresh_accumulator() {
        let (net, topo) = setup(96);
        let mut pooled = Accumulator::default();
        for round in 0..3 {
            pooled.reset(&net, &topo);
            let mut fresh = Accumulator::new(&net, &topo);
            for (s, d, b) in [(0usize, 50usize, 1_000_000u64), (3, 3, 512), (7, 60, 64)] {
                pooled.msg(s, d, b + round);
                fresh.msg(s, d, b + round);
            }
            pooled.frag(50, 2);
            fresh.frag(50, 2);
            assert_eq!(pooled.finish_reset(), fresh.finish(), "round {round}");
        }
    }

    #[test]
    fn finish_reset_leaves_a_clean_slate() {
        let (net, topo) = setup(96);
        let mut acc = Accumulator::new(&net, &topo);
        acc.msg(0, 50, 4096);
        acc.frag(0, 3);
        let _ = acc.finish_reset();
        let cap = acc.pe_capacity();
        // without an intervening reset the next phase starts from zero
        assert_eq!(acc.finish_reset(), PhaseCost::default());
        assert_eq!(acc.pe_capacity(), cap, "capacity must be retained");
    }

    /// Satellite regression: after a topology *shrink* (a §IV-B rebalance
    /// to p'), entries charged against the old, larger PE/node count must
    /// go stale — a pooled accumulator re-armed at the smaller world has
    /// to cost phases exactly like a fresh accumulator built at p', with
    /// no leakage from the pre-shrink phase (whose node 1 no longer
    /// exists) and no loss of vector capacity.
    #[test]
    fn reset_to_smaller_topology_drops_stale_entries() {
        let (net, big) = setup(96); // 2 nodes
        let small = Topology::new(48, 48); // 1 node after the "rebalance"
        let mut pooled = Accumulator::new(&net, &big);
        // a heavy pre-shrink phase touching both nodes and high ranks
        for dst in 48..96 {
            pooled.msg(0, dst, 1_000_000);
        }
        pooled.frag(95, 7);
        let _ = pooled.finish_reset();
        let cap = pooled.pe_capacity();

        for round in 0..2 {
            pooled.reset(&net, &small);
            let mut fresh = Accumulator::new(&net, &small);
            for (s, d, b) in [(0usize, 17usize, 4096u64), (3, 3, 512), (40, 2, 64)] {
                pooled.msg(s, d, b + round);
                fresh.msg(s, d, b + round);
            }
            pooled.frag(17, 2);
            fresh.frag(17, 2);
            assert_eq!(pooled.finish_reset(), fresh.finish(), "round {round}");
            assert_eq!(pooled.last_touched(), (4, 1), "round {round}");
        }
        assert_eq!(pooled.pe_capacity(), cap, "shrink must keep capacity");

        // ...and growing back re-admits the high ranks with clean counters
        pooled.reset(&net, &big);
        let mut fresh = Accumulator::new(&net, &big);
        pooled.msg(0, 95, 1234);
        fresh.msg(0, 95, 1234);
        assert_eq!(pooled.finish_reset(), fresh.finish());
    }

    /// The sparse accumulator must be charge-identical to the dense seed
    /// reference over random phase sequences with pooled reuse between
    /// (the in-file companion of the full property test in
    /// `rust/tests/prop_invariants.rs`).
    #[test]
    fn sparse_accumulator_matches_dense_reference_over_random_phases() {
        use crate::util::rng::Rng;
        let mut rng = Rng::seed_from_u64(0x5BA25E);
        let net = NetworkConfig::default();
        let mut pooled = Accumulator::default();
        for phase in 0..200 {
            let p = 1 + rng.gen_index(200);
            let ppn = 1 + rng.gen_index(48);
            let topo = Topology::new(p, ppn);
            pooled.reset(&net, &topo);
            let mut fresh = Accumulator::new(&net, &topo);
            for _ in 0..rng.gen_index(32) {
                let (s, d) = (rng.gen_index(p), rng.gen_index(p));
                let b = rng.gen_u64_below(1 << 20);
                pooled.msg(s, d, b);
                fresh.msg(s, d, b);
                if rng.gen_bool(0.3) {
                    let (pe, n) = (rng.gen_index(p), 1 + rng.gen_u64_below(8));
                    pooled.frag(pe, n);
                    fresh.frag(pe, n);
                }
            }
            assert_eq!(pooled.finish_reset(), fresh.finish(), "phase {phase} (p={p})");
        }
    }

    #[test]
    fn then_adds() {
        let a = PhaseCost {
            sim_time_s: 1.0,
            bottleneck_msgs: 2,
            bottleneck_bytes: 10,
            total_bytes: 20,
            total_msgs: 4,
        };
        let b = a.then(a);
        assert_eq!(b.sim_time_s, 2.0);
        assert_eq!(b.bottleneck_msgs, 4);
        assert_eq!(b.total_bytes, 40);
    }

    #[test]
    fn allreduce_scales_logarithmically() {
        let net = NetworkConfig::default();
        let c1 = allreduce_cost(&net, 48, 1024);
        let c2 = allreduce_cost(&net, 24576, 1024);
        assert!(c2.sim_time_s > c1.sim_time_s);
        assert!(c2.sim_time_s < c1.sim_time_s * 4.0); // log, not linear
        assert_eq!(allreduce_cost(&net, 1, 1024), PhaseCost::default());
    }
}
