//! Fig 4b — weak scaling of the three benchmark operations (§VI-B2).
//!
//! 16 MiB per PE; p = 48 … 24576; operations *submit*, *load 1 % data*,
//! *load all data*, each with and without ID randomization (256 KiB
//! permutation ranges). All data crosses the network (load-all rotates by
//! one shard so no PE loads its own data).
//!
//! Paper shape: permutations speed up load-1% and slow down submit and
//! load-all, increasingly so at high PE counts.

use restore::config::RestoreConfig;
use restore::metrics::{fmt_time, Stats, Table};
use restore::restore::load::{load_all_requests, load_percent_requests};
use restore::restore::ReStore;
use restore::simnet::cluster::Cluster;
use restore::util::bench::sim_samples;

const BYTES_PER_PE: usize = 16 * 1024 * 1024;
const BLOCK: usize = 64;
const PERM_RANGE: usize = 256 * 1024;

fn main() {
    let pes = [48usize, 192, 768, 3072, 12288, 24576];
    let reps = 5;

    for &op in &["submit", "load 1% data", "load all data"] {
        println!("=== Fig 4b: {op}, 16 MiB per PE (weak scaling) ===\n");
        let mut table =
            Table::new(vec!["p", "no permutation", "with permutation", "perm/no-perm"]);
        for &p in &pes {
            let plain = run_op(op, p, None, reps);
            let perm = run_op(op, p, Some(PERM_RANGE), reps);
            table.row(vec![
                p.to_string(),
                fmt_time(plain.mean),
                fmt_time(perm.mean),
                format!("{:.2}x", perm.mean / plain.mean),
            ]);
        }
        println!("{}", table.render());
    }

    // Expected qualitative anchors from the paper:
    let l1_plain = run_op("load 1% data", 24576, None, reps);
    let l1_perm = run_op("load 1% data", 24576, Some(PERM_RANGE), reps);
    let la_plain = run_op("load all data", 24576, None, reps);
    let la_perm = run_op("load all data", 24576, Some(PERM_RANGE), reps);
    println!(
        "anchors at p=24576: permutation speeds up load-1% ({} -> {}) {}",
        fmt_time(l1_plain.mean),
        fmt_time(l1_perm.mean),
        ok(l1_perm.mean < l1_plain.mean)
    );
    println!(
        "                    permutation slows down load-all ({} -> {}) {}",
        fmt_time(la_plain.mean),
        fmt_time(la_perm.mean),
        ok(la_perm.mean >= la_plain.mean)
    );
}

fn ok(b: bool) -> &'static str {
    if b {
        "[OK]"
    } else {
        "[MISMATCH]"
    }
}

fn run_op(op: &str, p: usize, perm: Option<usize>, reps: usize) -> Stats {
    sim_samples(reps, |rep| {
        let cfg = RestoreConfig::builder(p, BLOCK, BYTES_PER_PE / BLOCK)
            .replicas(4)
            .perm_range_bytes(perm)
            .seed(0xF16_4B + rep)
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p, 48.min(p));
        let mut store = ReStore::new(cfg, &cluster).unwrap();
        let sub = store.submit_virtual(&mut cluster).unwrap();
        match op {
            "submit" => sub.cost.sim_time_s,
            "load 1% data" => {
                let reqs =
                    load_percent_requests(&store, &cluster, 1.0, (rep as usize * 13) % p);
                let t = cluster.now();
                store.load(&mut cluster, &reqs).unwrap();
                cluster.now() - t
            }
            _ => {
                let reqs = load_all_requests(&store, &cluster);
                let t = cluster.now();
                store.load(&mut cluster, &reqs).unwrap();
                cluster.now() - t
            }
        }
    })
}
