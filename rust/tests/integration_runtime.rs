//! Integration: PJRT runtime executes the AOT artifacts with correct
//! numerics (Rust-side oracles recompute the kernels' results).
//!
//! Requires the `pjrt` feature and `make artifacts` to have run; tests
//! locate the artifact directory relative to the workspace root and skip
//! themselves (with a note on stderr) when the artifacts are absent.

#![cfg(feature = "pjrt")]

use restore::runtime::Engine;
use restore::util::rng::Rng;

/// The engine, or `None` (skip) when `make artifacts` has not run.
fn engine() -> Option<Engine> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping PJRT test: {dir}/manifest.json missing (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(dir).expect("artifacts present but engine failed to load"))
}

/// Rust oracle for the k-means assignment step.
fn kmeans_oracle(points: &[f32], centers: &[f32], d: usize, k: usize) -> (Vec<f32>, Vec<f32>, f32) {
    let n = points.len() / d;
    let mut sums = vec![0f32; k * d];
    let mut counts = vec![0f32; k];
    let mut inertia = 0f32;
    for i in 0..n {
        let x = &points[i * d..(i + 1) * d];
        let (mut best_c, mut best_d2) = (0usize, f32::INFINITY);
        for c in 0..k {
            let ctr = &centers[c * d..(c + 1) * d];
            let d2: f32 = x.iter().zip(ctr).map(|(a, b)| (a - b) * (a - b)).sum();
            if d2 < best_d2 {
                best_c = c;
                best_d2 = d2;
            }
        }
        for (s, v) in sums[best_c * d..(best_c + 1) * d].iter_mut().zip(x) {
            *s += v;
        }
        counts[best_c] += 1.0;
        inertia += best_d2;
    }
    (sums, counts, inertia)
}

fn random_f32s(rng: &mut Rng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gen_range_f32(lo, hi)).collect()
}

#[test]
fn kmeans_tiny_artifact_matches_rust_oracle() {
    let Some(mut engine) = engine() else { return };
    let mut rng = Rng::seed_from_u64(7);
    let points = random_f32s(&mut rng, 256 * 8, -4.0, 4.0);
    let centers = random_f32s(&mut rng, 4 * 8, -4.0, 4.0);
    let out = engine.execute_f32("kmeans_step_tiny", &[&points, &centers]).unwrap();
    let (sums, counts, inertia) = kmeans_oracle(&points, &centers, 8, 4);
    assert_eq!(out[1], counts, "counts must match exactly");
    for (a, b) in out[0].iter().zip(&sums) {
        assert!((a - b).abs() < 1e-3, "sums {a} vs {b}");
    }
    assert!((out[2][0] - inertia).abs() / inertia.max(1.0) < 1e-4);
}

#[test]
fn kmeans_update_artifact_keeps_empty_clusters() {
    let Some(mut engine) = engine() else { return };
    let sums = vec![0f32; 4 * 8];
    let mut counts = vec![0f32; 4];
    counts[1] = 2.0;
    let old: Vec<f32> = (0..32).map(|i| i as f32).collect();
    let out = engine.execute_f32("kmeans_update_tiny", &[&sums, &counts, &old]).unwrap();
    let new = &out[0];
    // cluster 1 has count 2, sums 0 -> moves to origin; others keep old
    for d in 0..8 {
        assert_eq!(new[8 + d], 0.0);
        assert_eq!(new[d], old[d]);
        assert_eq!(new[16 + d], old[16 + d]);
    }
}

#[test]
fn phylo_small_artifact_matches_rust_oracle() {
    let Some(mut engine) = engine() else { return };
    let mut rng = Rng::seed_from_u64(9);
    let s = 1024;
    let clv_l = random_f32s(&mut rng, s * 4, 0.05, 1.0);
    let clv_r = random_f32s(&mut rng, s * 4, 0.05, 1.0);
    let p_l = restore::apps::raxml::transition_matrix(17);
    let p_r = restore::apps::raxml::transition_matrix(23);
    let freqs = vec![0.25f32; 4];
    let weights = vec![1.0f32; s];
    let out = engine
        .execute_f32("phylo_step_small", &[&clv_l, &clv_r, &p_l, &p_r, &freqs, &weights])
        .unwrap();

    // oracle
    let mut ll = 0f64;
    for site in 0..s {
        let mut clv = [0f32; 4];
        for i in 0..4 {
            let mut left = 0f32;
            let mut right = 0f32;
            for j in 0..4 {
                left += p_l[i * 4 + j] * clv_l[site * 4 + j];
                right += p_r[i * 4 + j] * clv_r[site * 4 + j];
            }
            clv[i] = left * right;
            assert!(
                (out[0][site * 4 + i] - clv[i]).abs() < 1e-5,
                "clv mismatch at site {site}"
            );
        }
        let site_lik: f32 = clv.iter().map(|v| v * 0.25).sum();
        ll += (site_lik.max(f32::MIN_POSITIVE)).ln() as f64;
    }
    assert!((out[1][0] as f64 - ll).abs() < 0.05 * ll.abs().max(1.0), "{} vs {ll}", out[1][0]);
}

#[test]
fn manifest_lists_all_paper_variants() {
    let Some(engine) = engine() else { return };
    for name in [
        "kmeans_step",
        "kmeans_step_small",
        "kmeans_step_tiny",
        "kmeans_update",
        "kmeans_update_tiny",
        "phylo_step",
        "phylo_step_small",
    ] {
        let entry = engine.entry(name).unwrap();
        assert!(!entry.args.is_empty());
        assert!(!entry.results.is_empty());
    }
    // the paper-scale shapes
    let km = engine.entry("kmeans_step").unwrap();
    assert_eq!(km.args[0].shape, vec![65536, 32]);
    assert_eq!(km.args[1].shape, vec![20, 32]);
}

#[test]
fn shape_mismatch_is_rejected_before_xla() {
    let Some(mut engine) = engine() else { return };
    let bad = vec![0f32; 3];
    let err = engine.execute_f32("kmeans_step_tiny", &[&bad, &bad]).unwrap_err();
    assert!(format!("{err}").contains("expected"));
}

#[test]
fn zero_weights_make_phylo_loglik_zero() {
    // the padding trick the raxml proxy relies on
    let Some(mut engine) = engine() else { return };
    let s = 1024;
    let clv = vec![0.5f32; s * 4];
    let p = restore::apps::raxml::transition_matrix(3);
    let freqs = vec![0.25f32; 4];
    let weights = vec![0f32; s];
    let out = engine.execute_f32("phylo_step_small", &[&clv, &clv, &p, &p, &freqs, &weights]).unwrap();
    assert_eq!(out[1][0], 0.0);
}
