# Developer entry points. `make artifacts` is the only Python invocation in
# the whole system: it AOT-lowers the L2 JAX/Pallas models to HLO text under
# artifacts/ (+ manifest.json) for the Rust PJRT runtime — see
# rust/src/runtime/mod.rs. The PJRT-gated tests and bench sections skip
# themselves until it has run.

PYTHON ?= python3

.PHONY: artifacts test bench-json bench-json-short perf-table weak-scaling clean-artifacts

artifacts:
	cd python && $(PYTHON) -m compile.aot --outdir ../artifacts
	@test -s artifacts/manifest.json && echo "artifacts/manifest.json OK"

test:
	cargo build --release && cargo test -q

# The CI bench smoke set: emits BENCH_hotpath.json / BENCH_load_scale.json /
# BENCH_rebalance.json / BENCH_fused_load.json / BENCH_policies.json /
# BENCH_scrub.json / BENCH_million.json / BENCH_checkpoint.json /
# BENCH_kv.json ({name, ns_per_iter} JSON lines).
bench-json:
	cargo bench --bench hotpath
	cargo bench --bench load_scale
	cargo bench --bench rebalance
	cargo bench --bench fused_load
	cargo bench --bench policies
	cargo bench --bench scrub
	cargo bench --bench million
	cargo bench --bench checkpoint
	cargo bench --bench kv

# Short mode: every bench binary runs end to end (so every BENCH_*.json
# artifact exists) but skips the p = 24576 configurations and cuts
# repetition counts — seconds instead of minutes. CI validates the
# resulting artifacts line-by-line against the {name, ns_per_iter} schema
# with tools/validate_bench_json.py so tools/perf_table.py always gets
# parseable input.
bench-json-short:
	BENCH_SHORT=1 $(MAKE) bench-json
	$(PYTHON) tools/validate_bench_json.py BENCH_hotpath.json \
		BENCH_load_scale.json BENCH_rebalance.json BENCH_fused_load.json \
		BENCH_policies.json BENCH_scrub.json BENCH_million.json \
		BENCH_checkpoint.json BENCH_kv.json

# Render the EXPERIMENTS.md §Perf measured table from BENCH_*.json files
# (downloaded from CI's bench-json artifact, or produced by `make
# bench-json` locally).
perf-table:
	$(PYTHON) tools/perf_table.py BENCH_hotpath.json BENCH_load_scale.json \
		BENCH_rebalance.json BENCH_fused_load.json
	$(PYTHON) tools/perf_table.py --marker policy-table BENCH_policies.json
	$(PYTHON) tools/perf_table.py --marker integrity-table BENCH_scrub.json
	$(PYTHON) tools/perf_table.py --marker scale-table BENCH_million.json
	$(PYTHON) tools/perf_table.py --marker checkpoint-table BENCH_checkpoint.json
	$(PYTHON) tools/perf_table.py --marker kv-table BENCH_kv.json

# Render the Fig-4-style weak-scaling table (ROADMAP item) from the
# load-path and fused-load artifacts.
weak-scaling:
	$(PYTHON) tools/weak_scaling_figure.py BENCH_load_scale.json BENCH_fused_load.json

clean-artifacts:
	rm -rf artifacts
