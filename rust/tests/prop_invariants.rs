//! Property-based tests over randomized configurations (in-tree generator;
//! the environment has no proptest — see Cargo.toml note). Each property
//! runs against many random (p, r, blocks, s_pr, failures) tuples and
//! shrinks nothing but prints the failing seed, which reproduces exactly.

use restore::config::{RestoreConfig, ServerSelection};
use restore::restore::block::{BlockRange, RangeSet};
use restore::restore::distribution::Distribution;
use restore::restore::load::{load_all_requests, scatter_requests};
use restore::restore::permutation::{Feistel, RangePermutation};
use restore::restore::repair::RepairScheme;
use restore::restore::store::{assert_memory_invariant, HolderIndex};
use restore::restore::{LoadRequest, ReStore};
use restore::simnet::cluster::Cluster;
use restore::util::rng::Rng;

/// Random valid config: p in [2, 32], r | p, block size in {4..64},
/// perm ranges on/off.
fn random_config(rng: &mut Rng) -> RestoreConfig {
    loop {
        let p = 2 + rng.gen_index(31);
        let divisors: Vec<usize> = (1..=p).filter(|r| p % r == 0 && *r <= 8).collect();
        let r = divisors[rng.gen_index(divisors.len())];
        let bs = [4usize, 8, 16, 64][rng.gen_index(4)];
        let bpp_choices = [16usize, 32, 64, 96, 256];
        let bpp = bpp_choices[rng.gen_index(bpp_choices.len())];
        let s_pr = if rng.gen_bool(0.5) {
            let divs: Vec<usize> = (1..=bpp).filter(|s| bpp % s == 0).collect();
            Some(divs[rng.gen_index(divs.len())])
        } else {
            None
        };
        let sel = [ServerSelection::Random, ServerSelection::LeastLoaded, ServerSelection::Primary]
            [rng.gen_index(3)];
        if let Ok(cfg) = RestoreConfig::builder(p, bs, bpp)
            .replicas(r)
            .perm_range_blocks(s_pr)
            .seed(rng.next_u64())
            .server_selection(sel)
            .build()
        {
            return cfg;
        }
    }
}

fn shards_for(cfg: &RestoreConfig, rng: &mut Rng) -> Vec<Vec<u8>> {
    (0..cfg.world)
        .map(|_| {
            (0..cfg.blocks_per_pe * cfg.block_size).map(|_| rng.next_u64() as u8).collect()
        })
        .collect()
}

fn expected_bytes(shards: &[Vec<u8>], ranges: &RangeSet, cfg: &RestoreConfig) -> Vec<u8> {
    let bpp = cfg.blocks_per_pe as u64;
    let bs = cfg.block_size;
    let mut out = Vec::new();
    for r in ranges.ranges() {
        for x in r.start..r.end {
            let pe = (x / bpp) as usize;
            let off = ((x % bpp) as usize) * bs;
            out.extend_from_slice(&shards[pe][off..off + bs]);
        }
    }
    out
}

#[test]
fn prop_submit_satisfies_memory_invariant() {
    let mut rng = Rng::seed_from_u64(0xA11CE);
    for trial in 0..40 {
        let cfg = random_config(&mut rng);
        let mut cluster = Cluster::new_execution(cfg.world, 4);
        let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
        store.submit_virtual(&mut cluster).unwrap_or_else(|e| panic!("trial {trial}: {e}"));
        let dist = Distribution::new(&cfg);
        assert_memory_invariant(store.stores(), &dist);
    }
}

#[test]
fn prop_arbitrary_requests_roundtrip_bitexact_under_failures() {
    let mut rng = Rng::seed_from_u64(0xB0B);
    for trial in 0..25 {
        let cfg = random_config(&mut rng);
        let mut cluster = Cluster::new_execution(cfg.world, 4);
        let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
        let shards = shards_for(&cfg, &mut rng);
        store.submit(&mut cluster, &shards).unwrap();

        // kill up to r-1 PEs of each group — never an IDL
        let stride = cfg.world / cfg.replicas;
        let mut dead = Vec::new();
        for g in 0..stride {
            let kills = rng.gen_index(cfg.replicas); // 0..r-1
            for k in 0..kills {
                dead.push(g + k * stride);
            }
        }
        let dead: Vec<usize> =
            dead.into_iter().take(cluster.n_alive().saturating_sub(1)).collect();
        cluster.kill(&dead);

        // random requests from random alive PEs
        let survivors = cluster.survivors();
        let n = cfg.n_blocks();
        let n_reqs = 1 + rng.gen_index(4);
        let mut reqs: Vec<LoadRequest> = Vec::new();
        for _ in 0..n_reqs {
            let pe = survivors[rng.gen_index(survivors.len())];
            let n_ranges = 1 + rng.gen_index(3);
            let mut ranges: Vec<BlockRange> = Vec::new();
            for _ in 0..n_ranges {
                let a = rng.gen_u64_below(n);
                let len = 1 + rng.gen_u64_below((n - a).min(cfg.blocks_per_pe as u64 * 2));
                ranges.push(BlockRange::new(a, a + len));
            }
            reqs.push(LoadRequest { pe, ranges: RangeSet::new(ranges) });
        }

        let out = store
            .load(&mut cluster, &reqs)
            .unwrap_or_else(|e| panic!("trial {trial} (p={}, r={}): {e}", cfg.world, cfg.replicas));
        for (req, shard) in reqs.iter().zip(&out.shards) {
            assert_eq!(
                shard.bytes.as_deref().unwrap(),
                expected_bytes(&shards, &req.ranges, &cfg),
                "trial {trial}: wrong bytes for PE {}",
                req.pe
            );
        }
    }
}

#[test]
fn prop_scatter_recovery_covers_lost_shards_exactly() {
    let mut rng = Rng::seed_from_u64(0xC0C0A);
    for trial in 0..25 {
        let cfg = random_config(&mut rng);
        if cfg.replicas < 2 {
            continue;
        }
        let mut cluster = Cluster::new_execution(cfg.world, 4);
        let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
        store.submit_virtual(&mut cluster).unwrap();

        // kill a random set of < r PEs from distinct groups
        let stride = cfg.world / cfg.replicas;
        let mut dead: Vec<usize> = Vec::new();
        for g in 0..stride {
            if rng.gen_bool(0.3) {
                dead.push(g + rng.gen_index(cfg.replicas) * stride);
            }
        }
        dead.dedup();
        let dead: Vec<usize> =
            dead.into_iter().take(cluster.n_alive().saturating_sub(1)).collect();
        if dead.is_empty() {
            continue;
        }
        cluster.kill(&dead);

        let reqs = scatter_requests(&store, &cluster, &dead);
        let requested: u64 = reqs.iter().map(|r| r.ranges.total_blocks()).sum();
        assert_eq!(
            requested,
            dead.len() as u64 * cfg.blocks_per_pe as u64,
            "trial {trial}: scatter must request exactly the lost blocks"
        );
        // requests must be disjoint and land only on survivors
        let mut all: Vec<BlockRange> = Vec::new();
        for r in &reqs {
            assert!(cluster.is_alive(r.pe));
            all.extend(r.ranges.ranges().iter().copied());
        }
        let merged = RangeSet::new(all.clone());
        assert_eq!(merged.total_blocks(), requested, "trial {trial}: overlapping requests");
        store.load(&mut cluster, &reqs).unwrap();
    }
}

#[test]
fn prop_load_all_partitions_whole_id_space() {
    let mut rng = Rng::seed_from_u64(0xDEAD);
    for _trial in 0..30 {
        let cfg = random_config(&mut rng);
        let mut cluster = Cluster::new_execution(cfg.world, 4);
        let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
        store.submit_virtual(&mut cluster).unwrap();
        let reqs = load_all_requests(&store, &cluster);
        let all: Vec<BlockRange> =
            reqs.iter().flat_map(|r| r.ranges.ranges().iter().copied()).collect();
        let merged = RangeSet::new(all);
        assert_eq!(merged.total_blocks(), cfg.n_blocks());
        assert_eq!(merged.ranges().len(), 1, "must be a seamless partition");
        store.load(&mut cluster, &reqs).unwrap();
    }
}

#[test]
fn prop_holder_index_matches_store_scan_under_kill_repair_storms() {
    // After ANY sequence of kills, repairs, and dead-store reclaims, the
    // incrementally maintained reverse holder index must exactly equal a
    // from-scratch scan of every PE store — and a repeated repair after
    // the same failures must move nothing (idempotence).
    let mut rng = Rng::seed_from_u64(0x1DE7);
    for trial in 0..20 {
        let cfg = random_config(&mut rng);
        let mut cluster = Cluster::new_execution(cfg.world, 4);
        let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
        store.submit_virtual(&mut cluster).unwrap();
        let check = |store: &ReStore, when: &str| {
            let rebuilt =
                HolderIndex::rebuild(store.stores(), store.distribution().blocks_per_pe());
            assert_eq!(
                *store.holder_index(),
                rebuilt,
                "trial {trial} (p={}, r={}): index drifted {when}",
                cfg.world,
                cfg.replicas
            );
        };
        check(&store, "after submit");

        let scheme = if rng.gen_bool(0.5) {
            RepairScheme::DoubleHashing
        } else {
            RepairScheme::FeistelWalk
        };
        for wave in 0..3 {
            if cluster.n_alive() <= 1 {
                break;
            }
            // kill a random non-empty subset of survivors (leave one alive)
            let survivors = cluster.survivors();
            let kills = 1 + rng.gen_index((survivors.len() - 1).max(1));
            let dead: Vec<usize> = (0..kills)
                .map(|_| survivors[rng.gen_index(survivors.len())])
                .collect();
            let dead: Vec<usize> =
                dead.into_iter().take(cluster.n_alive().saturating_sub(1)).collect();
            cluster.kill(&dead);

            // occasionally reclaim a dead PE's store before repairing
            if rng.gen_bool(0.3) {
                if let Some(&pe) = cluster.failed().first() {
                    store.drop_pe(&cluster, pe).unwrap();
                    check(&store, &format!("after drop_pe({pe}) in wave {wave}"));
                }
            }

            let first = store.repair_replicas(&mut cluster, scheme).unwrap();
            check(&store, &format!("after repair wave {wave}"));
            let second = store.repair_replicas(&mut cluster, scheme).unwrap();
            assert_eq!(
                second.transfers, 0,
                "trial {trial} wave {wave}: second repair moved {} units (first moved {})",
                second.transfers, first.transfers
            );
            check(&store, &format!("after idempotent re-repair wave {wave}"));
        }
    }
}

#[test]
fn prop_drop_pe_rejects_alive_pes_and_out_of_range() {
    let cfg = RestoreConfig::builder(4, 8, 16).replicas(2).build().unwrap();
    let mut cluster = Cluster::new_execution(4, 2);
    let mut store = ReStore::new(cfg, &cluster).unwrap();
    store.submit_virtual(&mut cluster).unwrap();
    assert!(store.drop_pe(&cluster, 1).is_err(), "alive PE must be rejected");
    assert!(store.drop_pe(&cluster, 9).is_err(), "out-of-range PE must be rejected");
    cluster.kill(&[1]);
    store.drop_pe(&cluster, 1).unwrap();
    assert_eq!(store.stores()[1].slices().len(), 0);
    assert_eq!(
        *store.holder_index(),
        HolderIndex::rebuild(store.stores(), store.distribution().blocks_per_pe())
    );
}

#[test]
fn prop_feistel_bijection_random_domains() {
    let mut rng = Rng::seed_from_u64(0xFE15);
    for _ in 0..50 {
        let domain = 1 + rng.gen_u64_below(1 << 14);
        let f = Feistel::new(domain, rng.next_u64());
        // spot-check bijection by sampling (full check for small domains)
        if domain <= 512 {
            let mut seen = vec![false; domain as usize];
            for i in 0..domain {
                let y = f.apply(i);
                assert!(y < domain && !seen[y as usize]);
                seen[y as usize] = true;
            }
        } else {
            for _ in 0..200 {
                let i = rng.gen_u64_below(domain);
                let y = f.apply(i);
                assert!(y < domain);
                assert_eq!(f.invert(y), i);
            }
        }
    }
}

#[test]
fn prop_distribution_holder_consistency() {
    // stored_slice and holder must be inverse views of each other for
    // random configs.
    let mut rng = Rng::seed_from_u64(0x90D);
    for _ in 0..40 {
        let cfg = random_config(&mut rng);
        let dist = Distribution::new(&cfg);
        for _ in 0..50 {
            let y = rng.gen_u64_below(dist.n_blocks());
            for k in 0..dist.replicas() {
                let pe = dist.holder(y, k);
                assert!(dist.stored_slice(pe, k).contains(y));
            }
        }
    }
}

#[test]
fn prop_idl_simulation_never_below_r() {
    let mut rng = Rng::seed_from_u64(0x1D1);
    for _ in 0..30 {
        let r = 1 + rng.gen_u64_below(4);
        let groups = 1 + rng.gen_u64_below(64);
        let p = r * groups;
        let f = restore::restore::idl::simulate_failures_until_idl(p, r, &mut rng);
        assert!(f >= r, "IDL after {f} failures with r={r}");
        assert!(f <= p);
    }
}
