//! Micro-bench harness — in-tree replacement for `criterion`, used by the
//! `benches/` binaries (`harness = false`).
//!
//! Measures wall-clock over warmup + timed iterations, reports
//! mean/median/p10/p90 like the paper's plots (§VI-A: 10 repetitions,
//! mean with 10th/90th percentile error bars).

use std::time::Instant;

use crate::metrics::{fmt_time, Stats};

/// One timed measurement series.
pub struct BenchResult {
    pub name: String,
    pub stats: Stats,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<48} mean {:>12}  p10 {:>12}  p90 {:>12}  (n={})",
            self.name,
            fmt_time(self.stats.mean),
            fmt_time(self.stats.p10),
            fmt_time(self.stats.p90),
            self.stats.n
        )
    }
}

/// Time `f` for `reps` repetitions after `warmup` unmeasured calls.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    BenchResult { name: name.to_string(), stats: Stats::from(&samples) }
}

/// Collect repeated *simulated-time* samples (for cost-model benches the
/// measurement is the simulated clock, not wall time).
pub fn sim_samples<F: FnMut(u64) -> f64>(reps: usize, mut f: F) -> Stats {
    let samples: Vec<f64> = (0..reps.max(1) as u64).map(&mut f).collect();
    Stats::from(&samples)
}

/// Prevent the optimizer from discarding a value (std::hint wrapper).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0u64;
        let r = bench("noop", 2, 5, || {
            count += 1;
            black_box(count);
        });
        assert_eq!(count, 7); // 2 warmup + 5 timed
        assert_eq!(r.stats.n, 5);
        assert!(r.stats.mean >= 0.0);
        assert!(r.line().contains("noop"));
    }

    #[test]
    fn sim_samples_passes_rep_index() {
        let s = sim_samples(4, |rep| rep as f64);
        assert_eq!(s.n, 4);
        assert_eq!(s.mean, 1.5);
    }
}
