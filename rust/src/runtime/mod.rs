//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` (the only Python invocation in the whole system) lowers
//! the L2 models to **HLO text** in `artifacts/` plus a `manifest.json`
//! describing every variant's shapes. This module loads that manifest,
//! compiles each artifact on the PJRT CPU client on first use, and executes
//! it with `f32` tensors from the Rust hot path — Python never runs here.
//!
//! HLO *text* (not serialized protos) is the interchange format because
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see DESIGN.md and python/compile/aot.py).
//!
//! The `xla` crate (and its xla_extension C library) is only linked when
//! the `pjrt` cargo feature is enabled. Without it, manifest loading and
//! the [`Engine`] API surface still compile — every operation returns a
//! descriptive [`Error::Artifact`] — so the apps, benches, and examples
//! build and degrade gracefully on machines without the C library.

use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::time::Instant;

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Tensor spec from the manifest.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
    pub name: Option<String>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub args: Vec<TensorSpec>,
    pub results: Vec<TensorSpec>,
    pub sha256: Option<String>,
}

fn parse_tensor_spec(v: &Json) -> Result<TensorSpec> {
    let bad = || Error::Artifact("malformed tensor spec in manifest".into());
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(bad)?
        .iter()
        .map(|s| s.as_usize().ok_or_else(bad))
        .collect::<Result<Vec<usize>>>()?;
    Ok(TensorSpec {
        shape,
        dtype: v.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string(),
        name: v.get("name").and_then(Json::as_str).map(str::to_string),
    })
}

fn parse_manifest(text: &str) -> Result<HashMap<String, ArtifactEntry>> {
    let root = Json::parse(text)?;
    let obj = root
        .as_obj()
        .ok_or_else(|| Error::Artifact("manifest root must be an object".into()))?;
    let mut out = HashMap::new();
    for (name, v) in obj {
        let bad = |w: &str| Error::Artifact(format!("manifest entry '{name}': missing {w}"));
        let file =
            v.get("file").and_then(Json::as_str).ok_or_else(|| bad("file"))?.to_string();
        let args = v
            .get("args")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("args"))?
            .iter()
            .map(parse_tensor_spec)
            .collect::<Result<Vec<_>>>()?;
        let results = v
            .get("results")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("results"))?
            .iter()
            .map(parse_tensor_spec)
            .collect::<Result<Vec<_>>>()?;
        let sha256 = v.get("sha256").and_then(Json::as_str).map(str::to_string);
        out.insert(name.clone(), ArtifactEntry { file, args, results, sha256 });
    }
    Ok(out)
}

/// Parse `dir/manifest.json` into artifact entries — pure JSON work, no
/// PJRT involved, so it is available in every build configuration.
pub fn load_manifest(dir: impl AsRef<Path>) -> Result<HashMap<String, ArtifactEntry>> {
    let mpath = dir.as_ref().join("manifest.json");
    let text = std::fs::read_to_string(&mpath).map_err(|e| {
        Error::Artifact(format!(
            "cannot read {} — run `make artifacts` first ({e})",
            mpath.display()
        ))
    })?;
    parse_manifest(&text)
}

/// The PJRT execution engine: one compiled executable per model variant.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: HashMap<String, ArtifactEntry>,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative wall-clock seconds spent inside PJRT `execute` calls.
    pub exec_seconds: f64,
    /// Number of `execute` calls.
    pub exec_calls: u64,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Load the artifact manifest from `dir` (e.g. `artifacts/`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = load_manifest(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            dir,
            manifest,
            compiled: HashMap::new(),
            exec_seconds: 0.0,
            exec_calls: 0,
        })
    }

    /// Default artifact directory: `$RESTORE_ARTIFACTS` or `artifacts/`.
    pub fn load_default() -> Result<Engine> {
        let dir =
            std::env::var("RESTORE_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Engine::load(dir)
    }

    pub fn manifest(&self) -> &HashMap<String, ArtifactEntry> {
        &self.manifest
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.manifest
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact variant '{name}'")))
    }

    /// Compile `name` if not yet compiled (idempotent).
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let entry = self.entry(name)?.clone();
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute variant `name` with `f32` inputs; returns the flattened
    /// `f32` outputs in manifest order.
    ///
    /// Inputs are validated against the manifest's shapes — a mismatch is
    /// an immediate error rather than an XLA crash.
    pub fn execute_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.ensure_compiled(name)?;
        let entry = self.entry(name)?.clone();
        if inputs.len() != entry.args.len() {
            return Err(Error::Artifact(format!(
                "{name}: got {} inputs, expected {}",
                inputs.len(),
                entry.args.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, spec)) in inputs.iter().zip(&entry.args).enumerate() {
            if data.len() != spec.elements() {
                return Err(Error::Artifact(format!(
                    "{name}: input {i} has {} elems, expected {} (shape {:?})",
                    data.len(),
                    spec.elements(),
                    spec.shape
                )));
            }
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            literals.push(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &spec.shape,
                bytes,
            )?);
        }
        let exe = self.compiled.get(name).expect("ensured above");
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&literals)?;
        let root = result[0][0].to_literal_sync()?;
        self.exec_seconds += t0.elapsed().as_secs_f64();
        self.exec_calls += 1;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = root.to_tuple()?;
        if parts.len() != entry.results.len() {
            return Err(Error::Artifact(format!(
                "{name}: got {} results, expected {}",
                parts.len(),
                entry.results.len()
            )));
        }
        parts.into_iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }
}

/// API-compatible stand-in compiled without the `pjrt` feature: no value
/// of it can ever be constructed (`load*` always returns
/// [`Error::Artifact`] naming the missing feature), so callers (apps,
/// benches, `restore smoke`) compile unchanged and degrade with a clear
/// message instead of failing to link against a C library the machine
/// lacks. Manifest *parsing* stays available through the free
/// [`load_manifest`] in every build.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    /// Cumulative wall-clock seconds spent inside PJRT `execute` calls.
    pub exec_seconds: f64,
    /// Number of `execute` calls.
    pub exec_calls: u64,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    fn unavailable<T>() -> Result<T> {
        Err(Error::Artifact(
            "PJRT runtime unavailable: this binary was built without the `pjrt` cargo \
             feature — rebuild with `--features pjrt` (needs an extracted xla_extension, \
             see Cargo.toml and .github/workflows/ci.yml)"
                .into(),
        ))
    }

    /// Always fails: the PJRT client is not compiled in.
    pub fn load(_dir: impl AsRef<Path>) -> Result<Engine> {
        Self::unavailable()
    }

    /// Always fails: the PJRT client is not compiled in.
    pub fn load_default() -> Result<Engine> {
        Self::unavailable()
    }

    pub fn entry(&self, _name: &str) -> Result<&ArtifactEntry> {
        Self::unavailable()
    }

    pub fn ensure_compiled(&mut self, _name: &str) -> Result<()> {
        Self::unavailable()
    }

    pub fn execute_f32(&mut self, _name: &str, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        Self::unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn missing_manifest_is_a_helpful_error() {
        let msg = match Engine::load("/nonexistent-dir") {
            Err(e) => format!("{e}"),
            Ok(_) => panic!("expected error"),
        };
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn missing_manifest_dir_is_a_helpful_error() {
        let msg = match load_manifest("/nonexistent-dir") {
            Err(e) => format!("{e}"),
            Ok(_) => panic!("expected error"),
        };
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_names_the_missing_feature() {
        let msg = match Engine::load_default() {
            Err(e) => format!("{e}"),
            Ok(_) => panic!("expected error"),
        };
        assert!(msg.contains("pjrt"), "{msg}");
    }

    #[test]
    fn manifest_parses_shapes() {
        let text = r#"{
            "kmeans_step_tiny": {
                "file": "kmeans_step_tiny.hlo.txt",
                "args": [{"shape": [256, 8], "dtype": "float32"}],
                "results": [{"shape": [4, 8], "dtype": "float32", "name": "centers"}]
            }
        }"#;
        let m = parse_manifest(text).unwrap();
        let e = &m["kmeans_step_tiny"];
        assert_eq!(e.file, "kmeans_step_tiny.hlo.txt");
        assert_eq!(e.args[0].elements(), 2048);
        assert_eq!(e.results[0].name.as_deref(), Some("centers"));
    }

    // Execution tests against real artifacts live in rust/tests/
    // integration_runtime.rs (they need `make artifacts` to have run,
    // and the `pjrt` feature).
}
