//! §IV-B shrinking-rebalance benchmark (EXPERIMENTS.md §Perf).
//!
//! Cost-model runs of `ReStore::rebalance` at the hotpath baseline scale
//! (p = 1536) and the paper's largest configuration (p = 24576). The
//! balanced unequal-slice layout admits **every** survivor count `p' ≥ r`,
//! so next to the classic dividing fractions (1/3 and 1/2) each scale also
//! runs a *non-dividing* `p'` — the kill waves real clusters produce,
//! which the former equal-slice geometry had to refuse. Kill patterns are
//! consecutive rank prefixes taking at most 2 members of every §IV-D
//! group, so no wave is an IDL.
//!
//! With `BENCH_SHORT=1` only the p = 1536 configurations run (the CI
//! schema smoke — see `make bench-json-short`).
//!
//! Emits three JSON entries per configuration to `BENCH_rebalance.json`
//! (the `{name, ns_per_iter}` artifact schema; the name states the unit):
//!
//! * `rebalance wall ... ` — wall-clock nanoseconds of the planner +
//!   executor (cost-model: schedule-only, no byte movement);
//! * `rebalance sim-ns ...` — simulated time charged to the cluster clock;
//! * `rebalance migrated-bytes ...` — bytes the minimal migration moved.

use std::time::Instant;

use restore::config::RestoreConfig;
use restore::restore::ReStore;
use restore::simnet::cluster::Cluster;
use restore::simnet::ulfm;
use restore::util::bench::{short_mode, write_json_artifact, BenchResult};

fn rebalance_at(p: usize, p_new: usize, results: &mut Vec<BenchResult>) {
    let cfg = RestoreConfig::paper_default(p).unwrap();
    let mut cluster = Cluster::new_execution(p, 48);
    let mut store = ReStore::new(cfg, &cluster).unwrap();
    store.submit_virtual(&mut cluster).unwrap();

    // kill ranks 0..(p - p'): with p - p' <= p/2 and group stride p/4,
    // every §IV-D group loses at most 2 of its 4 members — never an IDL
    let kills: Vec<usize> = (0..p - p_new).collect();
    cluster.kill(&kills);
    let (_failed, map, _cost) = ulfm::recover(&mut cluster);
    assert!(store.can_rebalance(&cluster), "p'={p_new} must admit the layout");

    let sim0 = cluster.now();
    let wall0 = Instant::now();
    let report = store.rebalance(&mut cluster, &map).unwrap();
    let wall = wall0.elapsed().as_secs_f64();
    let sim = cluster.now() - sim0;
    let frac = (p - p_new) as f64 / p as f64;
    let dividing = if store.distribution().equal_slices() { "equal" } else { "unequal" };

    let tag = format!("p={p} p'={p_new} f={:.2} {dividing}", frac);
    println!(
        "rebalance {tag}: {} transfers, {:.2} GiB migrated, sim {:.1} ms, wall {:.1} ms",
        report.transfers,
        report.migrated_bytes as f64 / (1u64 << 30) as f64,
        sim * 1e3,
        wall * 1e3,
    );
    results.push(BenchResult::from_value(&format!("rebalance wall {tag}"), wall * 1e9));
    results.push(BenchResult::from_value(&format!("rebalance sim-ns {tag}"), sim * 1e9));
    results.push(BenchResult::from_value(
        &format!("rebalance migrated-bytes {tag}"),
        report.migrated_bytes as f64,
    ));
}

fn main() {
    println!("=== shrinking-rebalance benchmarks (cost-model) ===\n");
    let mut results: Vec<BenchResult> = Vec::new();
    // p = 2^a·3 worlds. Per scale: one NON-dividing p' (balanced unequal
    // slices — the generalized layout's new coverage) plus the two classic
    // dividing fractions 1/3 and 1/2.
    let configs: &[(usize, [usize; 3])] =
        &[(1536usize, [1531usize, 1024, 768]), (24576, [23003, 16384, 12288])];
    let configs = if short_mode() { &configs[..1] } else { configs };
    for &(p, targets) in configs {
        for p_new in targets {
            rebalance_at(p, p_new, &mut results);
        }
    }
    write_json_artifact("BENCH_rebalance.json", &results).expect("write BENCH_rebalance.json");
    println!("\nwrote BENCH_rebalance.json ({} entries)", results.len());
}
