//! Fused vs. sequential multi-dataset recovery (EXPERIMENTS.md §Perf,
//! §V walkthrough).
//!
//! A recovery that needs k datasets (kmeans points + centroids, PageRank
//! edges + ranks, RAxML sites + model state) pays one full two-phase
//! sparse-all-to-all round per dataset when driven sequentially;
//! `ReStore::load_many` merges the per-dataset message plans into ONE
//! request all-to-all and ONE data all-to-all. This bench measures both
//! drivings of the same 3-dataset scattered recovery (one failed 48-PE
//! node) in cost-model mode at p = 1536 and p = 24576, and reports the
//! message savings — bytes are identical by construction (asserted), the
//! fused round sends one message per (requester, server) pair across all
//! datasets.

use restore::config::RestoreConfig;
use restore::restore::block::{BlockRange, RangeSet};
use restore::restore::{DatasetId, LoadRequest, ReStore};
use restore::simnet::cluster::Cluster;
use restore::simnet::network::PhaseCost;
use restore::util::bench::{bench, black_box, short_mode, write_json_artifact, BenchResult};

/// Scatter the `failed` PEs' submit-time shards (of a dataset with
/// `bpp` blocks per PE) evenly over the survivors — the per-dataset
/// generalization of `restore::load::scatter_requests`.
fn scatter_for(bpp: u64, cluster: &Cluster, failed: &[usize]) -> Vec<LoadRequest> {
    let survivors = cluster.survivors();
    let ns = survivors.len() as u64;
    let mut per_pe: Vec<Vec<BlockRange>> = vec![Vec::new(); survivors.len()];
    for &dead in failed {
        let start = dead as u64 * bpp;
        for (j, ranges) in per_pe.iter_mut().enumerate() {
            let s = start + (j as u64 * bpp) / ns;
            let e = start + ((j as u64 + 1) * bpp) / ns;
            if s < e {
                ranges.push(BlockRange::new(s, e));
            }
        }
    }
    survivors
        .iter()
        .zip(per_pe)
        .filter(|(_, r)| !r.is_empty())
        .map(|(&pe, r)| LoadRequest { pe, ranges: RangeSet::new(r) })
        .collect()
}

fn run_scale(p: usize, reps: usize, results: &mut Vec<BenchResult>) {
    println!("--- p = {p} (cost-model, 3 datasets) ---");
    // Three §V datasets with distinct r/b: bulk data (paper default),
    // a medium metadata set, and a small state set.
    let bulk = RestoreConfig::paper_default(p).unwrap();
    let meta = RestoreConfig::builder(p, 32, 4096)
        .replicas(2)
        .perm_range_blocks(Some(128))
        .build()
        .unwrap();
    let state = RestoreConfig::builder(p, 32, 256).replicas(2).build().unwrap();
    let bpps = [bulk.blocks_per_pe as u64, meta.blocks_per_pe as u64, state.blocks_per_pe as u64];

    let mut cluster = Cluster::new_execution(p, 48);
    let mut store = ReStore::new(bulk, &cluster).unwrap();
    let ds_meta = store.create_dataset(meta, &cluster).unwrap();
    let ds_state = store.create_dataset(state, &cluster).unwrap();
    store.submit_virtual(&mut cluster).unwrap();
    store.dataset_mut(ds_meta).unwrap().submit_virtual(&mut cluster).unwrap();
    store.dataset_mut(ds_state).unwrap().submit_virtual(&mut cluster).unwrap();
    let ids = [DatasetId::FIRST, ds_meta, ds_state];

    // one full node fails; the survivors scatter-load all three datasets
    let failed: Vec<usize> = (0..48).collect();
    cluster.kill(&failed);
    let parts: Vec<(DatasetId, Vec<LoadRequest>)> = ids
        .iter()
        .zip(bpps)
        .map(|(&id, bpp)| (id, scatter_for(bpp, &cluster, &failed)))
        .collect();

    // cost parity + savings (once, outside the timed loops)
    let fused = store.load_many(&mut cluster, &parts).unwrap();
    let mut seq = PhaseCost::default();
    for (id, reqs) in &parts {
        let out = store.dataset_mut(*id).unwrap().load(&mut cluster, reqs).unwrap();
        seq = seq.then(out.cost);
    }
    assert_eq!(fused.cost.total_bytes, seq.total_bytes, "fused changes no payload bytes");
    assert!(fused.cost.total_msgs < seq.total_msgs, "shared pairs must merge");
    println!(
        "    messages: sequential {} -> fused {} ({:.1} % saved), bytes identical",
        seq.total_msgs,
        fused.cost.total_msgs,
        100.0 * (seq.total_msgs - fused.cost.total_msgs) as f64 / seq.total_msgs as f64,
    );
    results.push(BenchResult::from_value(
        &format!("fused-load msgs-saved-pct 3ds p={p}"),
        100.0 * (seq.total_msgs - fused.cost.total_msgs) as f64 / seq.total_msgs as f64,
    ));

    let r = bench(&format!("fused-load resolve+route 3ds p={p}"), 1, reps, || {
        black_box(store.load_many(&mut cluster, &parts).unwrap());
    });
    println!("{}", r.line());
    results.push(r);

    let r = bench(&format!("sequential-load resolve+route 3ds p={p}"), 1, reps, || {
        for (id, reqs) in &parts {
            black_box(store.dataset_mut(*id).unwrap().load(&mut cluster, reqs).unwrap());
        }
    });
    println!("{}", r.line());
    results.push(r);
}

fn main() {
    println!("=== fused multi-dataset load benchmarks ===\n");
    let mut results: Vec<BenchResult> = Vec::new();
    if short_mode() {
        run_scale(1536, 2, &mut results);
    } else {
        run_scale(1536, 10, &mut results);
        run_scale(24576, 3, &mut results);
    }
    write_json_artifact("BENCH_fused_load.json", &results).expect("write BENCH_fused_load.json");
    println!("\nwrote BENCH_fused_load.json ({} entries)", results.len());
}
