//! Fault-tolerant PageRank — the third application class the paper names
//! as a ReStore use case (§IV-C: "RAxML-NG, k-means, and page-rank").
//!
//! Vertex-partitioned power iteration over a synthetic scale-free-ish
//! digraph. Each PE owns a contiguous vertex interval and the out-edge
//! lists of those vertices — exactly the kind of static input data ReStore
//! targets: submitted once, reloaded in scattered fashion by the survivors
//! after every failure. Pure Rust compute (a sparse mat-vec is a poor fit
//! for a fixed-shape AOT kernel; DESIGN.md §3/S19), same recovery skeleton
//! as the other apps.

use crate::apps::{checkpoint_state, secondary_replicas, Ownership};
use crate::config::RestoreConfig;
use crate::error::Result;
use crate::restore::block::{BlockRange, RangeSet};
use crate::restore::load::scatter_requests_for_ranges;
use crate::restore::serialize::{blocks_to_u64s, u64s_to_blocks};
use crate::restore::{DatasetId, LoadRequest, ReStore};
use crate::simnet::cluster::Cluster;
use crate::simnet::failure::ExpDecaySchedule;
use crate::simnet::ulfm;
use crate::util::rng::Rng;

/// PageRank run parameters.
#[derive(Debug, Clone)]
pub struct PagerankParams {
    /// Vertices per PE; each vertex gets exactly `edges_per_vertex` out-edges
    /// (fixed out-degree keeps the block layout dense and self-describing).
    pub vertices_per_pe: usize,
    pub edges_per_vertex: usize,
    pub iterations: usize,
    pub damping: f64,
    pub failure_fraction: f64,
    pub seed: u64,
}

impl Default for PagerankParams {
    fn default() -> Self {
        PagerankParams {
            vertices_per_pe: 1024,
            edges_per_vertex: 8,
            iterations: 30,
            damping: 0.85,
            failure_fraction: 0.0,
            seed: 1,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct PagerankReport {
    pub iterations_run: usize,
    pub failures: usize,
    pub sim_total_s: f64,
    pub sim_restore_s: f64,
    pub sim_mpi_recovery_s: f64,
    /// L1 delta of the final iteration (convergence indicator).
    pub final_delta: f64,
    pub ranks: Vec<f64>,
}

/// Generate PE `pe`'s edge list: `vertices_per_pe * edges_per_vertex`
/// destination vertex ids (u64), deterministic in (seed, pe). Preferential
/// wiring toward low vertex ids gives a skewed degree distribution.
pub fn generate_edges(seed: u64, pe: usize, params: &PagerankParams, total_vertices: u64) -> Vec<u64> {
    let mut rng = Rng::seed_from_u64(seed ^ (pe as u64).wrapping_mul(0xED6E));
    let n = params.vertices_per_pe * params.edges_per_vertex;
    (0..n)
        .map(|_| {
            // square a uniform to bias toward low ids (hub structure)
            let u: f64 = rng.gen_f64();
            ((u * u * total_vertices as f64) as u64).min(total_vertices - 1)
        })
        .collect()
}

/// The §V per-datatype config for the initial-rank-vector dataset: 32 B
/// blocks (4 vertices' f64 ranks each), a lower replication level than the
/// edge dataset, no permutation.
pub fn rank_restore_cfg(p: usize, params: &PagerankParams) -> Result<RestoreConfig> {
    let bs = 32usize;
    let blocks = (params.vertices_per_pe * 8).div_ceil(bs);
    RestoreConfig::builder(p, bs, blocks)
        .replicas(secondary_replicas(p))
        .seed(0x9A6E)
        .build()
}

/// Run fault-tolerant PageRank in execution mode.
pub fn run(
    cluster: &mut Cluster,
    restore_cfg: &RestoreConfig,
    params: &PagerankParams,
) -> Result<PagerankReport> {
    let p = cluster.world();
    let epv = params.edges_per_vertex;
    let total_vertices = (p * params.vertices_per_pe) as u64;
    let bs = restore_cfg.block_size;
    let mut report = PagerankReport::default();
    let mut rng = Rng::seed_from_u64(params.seed ^ 0x9A6E);
    let schedule = ExpDecaySchedule::new(
        params.failure_fraction.clamp(0.0, 0.999).max(1e-12),
        params.iterations,
    );

    // --- input + submit ----------------------------------------------------
    // block layout: one vertex's out-edges = epv u64s; blocks hold an
    // integral number of vertices (block_size must be a multiple of 8*epv).
    let edges: Vec<Vec<u64>> =
        (0..p).map(|pe| generate_edges(params.seed, pe, params, total_vertices)).collect();
    let shards: Vec<Vec<u8>> = edges.iter().map(|e| u64s_to_blocks(e, bs)).collect();
    let mut store = ReStore::new(restore_cfg.clone(), cluster)?;
    let edges_ds = DatasetId::FIRST;
    let t0 = cluster.now();
    let submit = store.submit(cluster, &shards)?;
    report.sim_restore_s += submit.cost.sim_time_s;
    drop(shards);

    // Second dataset (§V: one ReStore object per datatype): the rank
    // vector (f64 bit patterns), checkpointed with its own r/b — a
    // restarted survivor re-fetches a dead PE's rank shard bit-exactly
    // after every failure (verified below). 32 B blocks hold 4 vertices'
    // ranks; the edge dataset keeps its larger blocks and r = 4. The ranks
    // evolve, so each iteration resubmits them as a new version;
    // `committed_ranks` mirrors the latest committed serialization of the
    // whole block space (PE d's region = PE d's rank shard).
    let rank_cfg = rank_restore_cfg(p, params)?;
    let rank_bs = rank_cfg.block_size;
    let rank_bpp = rank_cfg.blocks_per_pe as u64;
    let rank0 = (1.0f64 / total_vertices as f64).to_bits();
    let rank_shard =
        u64s_to_blocks(&vec![rank0; params.vertices_per_pe], rank_bs);
    let shard_bytes = rank_shard.len();
    let rank_ds = store.create_dataset(rank_cfg, cluster)?;
    let rank_shards: Vec<Vec<u8>> = vec![rank_shard.clone(); p];
    let submit_r = store.dataset_mut(rank_ds)?.submit(cluster, &rank_shards)?;
    report.sim_restore_s += submit_r.cost.sim_time_s;
    drop(rank_shards);
    let mut committed_ranks: Vec<u8> = rank_shard.repeat(p);
    drop(rank_shard);

    // ownership in blocks; vertices_per_block for edge<->vertex mapping
    let vertices_per_block = bs / (8 * epv);
    assert!(vertices_per_block > 0, "block must hold >= 1 vertex's edges");
    let mut ownership = Ownership::identity(p, restore_cfg.blocks_per_pe as u64);
    // per-PE: (first_vertex_of_range, edge list) pairs gained over time
    let mut extra: Vec<Vec<(u64, Vec<u64>)>> = vec![Vec::new(); p];

    let mut ranks = vec![1.0 / total_vertices as f64; total_vertices as usize];

    for iter in 0..params.iterations {
        // ---- compute: each survivor scatters rank mass over its edges ----
        let mut contribs = vec![0f64; total_vertices as usize];
        for pe in cluster.survivors() {
            let mut scatter = |first_vertex: u64, list: &[u64]| {
                for (i, chunk) in list.chunks(epv).enumerate() {
                    let v = first_vertex + i as u64;
                    let share = ranks[v as usize] / epv as f64;
                    for &dst in chunk {
                        contribs[dst as usize] += share;
                    }
                }
            };
            scatter(pe as u64 * params.vertices_per_pe as u64, &edges[pe]);
            for (fv, list) in &extra[pe] {
                scatter(*fv, list);
            }
        }
        // flops-ish estimate for the compute tick: edges / rate
        cluster.tick_compute(total_vertices as f64 * epv as f64 / 2e9);
        // allreduce of the dense rank vector
        cluster.allreduce_cost_only(total_vertices * 8);

        let base = (1.0 - params.damping) / total_vertices as f64;
        let mut delta = 0.0;
        for (v, c) in contribs.iter().enumerate() {
            let new = base + params.damping * c;
            delta += (new - ranks[v]).abs();
            ranks[v] = new;
        }
        report.final_delta = delta;

        // ---- per-iteration rank-vector checkpoint --------------------------
        // Resubmit the updated ranks as a new version, overlapped against
        // this iteration's (already charged) scatter compute; serialized
        // per original PE so each region matches the original per-shard
        // padding. Power iteration touches every rank, so the checksum
        // delta degenerates to a full resubmit — the mode stays uniform
        // across the apps and pays only one hashing pass for it.
        let ck_t0 = cluster.now();
        let mut global = Vec::with_capacity(p * shard_bytes);
        for pe in 0..p {
            let bits: Vec<u64> = ranks
                [pe * params.vertices_per_pe..(pe + 1) * params.vertices_per_pe]
                .iter()
                .map(|r| r.to_bits())
                .collect();
            global.extend_from_slice(&u64s_to_blocks(&bits, rank_bs));
        }
        let compute_overlap = total_vertices as f64 * epv as f64 / 2e9;
        if checkpoint_state(store.dataset_mut(rank_ds)?, cluster, &global, compute_overlap)?
            .is_some()
        {
            committed_ranks = global;
        }
        report.sim_restore_s += cluster.now() - ck_t0;

        // ---- failures ------------------------------------------------------
        let dead: Vec<usize> = if params.failure_fraction > 0.0 {
            schedule
                .sample(&mut rng, &cluster.survivors())
                .into_iter()
                .take(cluster.n_alive().saturating_sub(1))
                .collect()
        } else {
            Vec::new()
        };
        if !dead.is_empty() {
            report.failures += dead.len();
            cluster.kill(&dead);
            let t_mpi = cluster.now();
            let (_failed, map, _cost) = ulfm::recover(cluster);
            report.sim_mpi_recovery_s += cluster.now() - t_mpi;

            // §IV-B: rebalance the replica layouts of BOTH datasets over
            // the survivors in one fused handshake when the shrunken world
            // admits them; acknowledge per dataset otherwise.
            let t_rs = cluster.now();
            store.rebalance_or_acknowledge(cluster, &map)?;
            let survivors = cluster.survivors();
            let gained = ownership.rebalance(&dead, &survivors, 1);
            let requests: Vec<LoadRequest> = scatter_requests_for_ranges(&gained);
            // fused recovery round: the survivors' edge loads and the
            // initial-rank re-fetch share one request and one data
            // all-to-all across the two datasets
            let rank_reqs = vec![LoadRequest {
                pe: survivors[0],
                ranges: RangeSet::new(
                    dead.iter()
                        .map(|&d| {
                            BlockRange::new(d as u64 * rank_bpp, (d as u64 + 1) * rank_bpp)
                        })
                        .collect(),
                ),
            }];
            let parts = [(edges_ds, requests), (rank_ds, rank_reqs)];
            let edge_shards_out = match store.load_many(cluster, &parts) {
                Ok(fused) => {
                    // the recovered rank shards must be bit-exact copies of
                    // the latest *committed* checkpoint version (load
                    // output is in normalized ascending block order)
                    let got = fused.parts[1].shards[0].bytes.as_ref().expect("execution mode");
                    let mut dead_sorted = dead.clone();
                    dead_sorted.sort_unstable();
                    for (chunk, &d) in got.chunks(shard_bytes).zip(&dead_sorted) {
                        assert_eq!(
                            chunk,
                            &committed_ranks[d * shard_bytes..(d + 1) * shard_bytes],
                            "recovered rank shard of PE {d} diverged"
                        );
                    }
                    fused.parts.into_iter().next().unwrap().shards
                }
                // The low-replication rank dataset (r = 2) can lose whole
                // slots under heavy waves; the rank vector is live in app
                // memory, so degrade to an edges-only load — exactly what
                // the app did before the second dataset.
                Err(crate::error::Error::IrrecoverableDataLoss { dataset, .. })
                    if dataset == rank_ds =>
                {
                    store.load(cluster, &parts[0].1)?.shards
                }
                Err(e) => return Err(e),
            };
            let requests = &parts[0].1;
            for (req, shard) in requests.iter().zip(&edge_shards_out) {
                let bytes = shard.bytes.as_ref().expect("execution mode");
                let mut off = 0usize;
                for r in req.ranges.ranges() {
                    let n_vertices = r.len() as usize * vertices_per_block;
                    let n_u64 = n_vertices * epv;
                    let list = blocks_to_u64s(&bytes[off..], n_u64);
                    off += r.len() as usize * bs;
                    let first_vertex = r.start * vertices_per_block as u64;
                    extra[req.pe].push((first_vertex, list));
                }
            }
            report.sim_restore_s += cluster.now() - t_rs;
        }
        report.iterations_run = iter + 1;
    }

    report.sim_total_s = cluster.now() - t0;
    report.ranks = ranks;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(p: usize, params: &PagerankParams) -> RestoreConfig {
        let bs = 64;
        let blocks = params.vertices_per_pe * params.edges_per_vertex * 8 / bs;
        RestoreConfig::builder(p, bs, blocks).replicas(4.min(p)).build().unwrap()
    }

    #[test]
    fn ranks_sum_to_one_without_failures() {
        let params = PagerankParams { vertices_per_pe: 128, iterations: 20, ..Default::default() };
        let mut cluster = Cluster::new_execution(4, 2);
        let rep = run(&mut cluster, &cfg(4, &params), &params).unwrap();
        let sum: f64 = rep.ranks.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "rank mass {sum}");
        assert_eq!(rep.failures, 0);
        assert!(rep.final_delta < 1e-3, "not converging: {}", rep.final_delta);
    }

    #[test]
    fn failure_recovery_preserves_rank_mass_and_results() {
        let params = PagerankParams {
            vertices_per_pe: 128,
            iterations: 25,
            failure_fraction: 0.3,
            seed: 5,
            ..Default::default()
        };
        let mut c1 = Cluster::new_execution(8, 4);
        let rep = run(&mut c1, &cfg(8, &params), &params).unwrap();
        assert!(rep.failures > 0, "schedule should kill someone at 30%");
        let sum: f64 = rep.ranks.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);

        // identical maths with vs without failures: the edge data reloaded
        // from ReStore is bit-identical, so ranks must match exactly.
        let no_fail = PagerankParams { failure_fraction: 0.0, ..params.clone() };
        let mut c2 = Cluster::new_execution(8, 4);
        let rep2 = run(&mut c2, &cfg(8, &no_fail), &no_fail).unwrap();
        for (a, b) in rep.ranks.iter().zip(&rep2.ranks) {
            assert!((a - b).abs() < 1e-12, "{a} != {b}");
        }
        // ...and the failure run took longer (recovery costs time)
        assert!(rep.sim_total_s > rep2.sim_total_s);
    }

    #[test]
    fn hubs_attract_rank() {
        let params = PagerankParams { vertices_per_pe: 256, iterations: 30, ..Default::default() };
        let mut cluster = Cluster::new_execution(2, 2);
        let rep = run(&mut cluster, &cfg(2, &params), &params).unwrap();
        // low ids are preferentially wired: vertex 0 should outrank the median
        let mut sorted = rep.ranks.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        assert!(rep.ranks[0] > median * 2.0);
    }
}
