//! Fig 6 — data loading after a fault in FT-RAxML-NG (§VI-C).
//!
//! (a) per-dataset comparison: ReStore submit / ReStore load vs reloading
//!     the RBA file from the PFS (uncached / cached).
//! (b) scaling on the 19.1 GiB synthetic dataset.
//!
//! FT-RAxML-NG redistributes its input among all survivors, so permutation
//! ranges are off (§VI-C). Paper anchors: both submitting and loading beat
//! the RBA/PFS path, often by more than an order of magnitude; on the
//! synthetic dataset at low PE counts submit is slower than a file reload
//! (which the paper dismisses as irrelevant — real runs need more nodes).

use restore::apps::raxml::{measure_recovery, PhyloDataset};
use restore::config::PfsConfig;
use restore::metrics::{fmt_time, Table};

fn main() {
    let pfs = PfsConfig::default();

    println!("=== Fig 6a: recovery performance per dataset (1 % of PEs failed) ===\n");
    let mut table = Table::new(vec![
        "dataset",
        "PEs",
        "MiB/PE",
        "ReStore submit",
        "ReStore load",
        "PFS uncached",
        "PFS cached",
        "uncached/load",
    ]);
    for ds in PhyloDataset::paper_datasets() {
        let kills = (ds.pes / 100).max(1);
        let t = measure_recovery(ds.pes, 48, ds.bytes_per_pe, kills, &pfs, 7).unwrap();
        table.row(vec![
            ds.name.clone(),
            ds.pes.to_string(),
            format!("{:.1}", ds.bytes_per_pe as f64 / (1 << 20) as f64),
            fmt_time(t.restore_submit_s),
            fmt_time(t.restore_load_s),
            fmt_time(t.pfs_uncached_s),
            fmt_time(t.pfs_cached_s),
            format!("{:.0}x", t.pfs_uncached_s / t.restore_load_s),
        ]);
    }
    println!("{}", table.render());

    println!("=== Fig 6b: scaling on the 19.1 GiB synthetic dataset ===\n");
    let total = (19.1 * (1u64 << 30) as f64) as u64;
    let mut table = Table::new(vec![
        "PEs",
        "MiB/PE",
        "ReStore submit",
        "ReStore load",
        "PFS uncached",
        "PFS cached",
        "uncached/load",
    ]);
    let mut first_speedup = 0.0;
    let mut last_speedup = 0.0;
    for &p in &[192usize, 768, 1536, 3072, 6144] {
        let per_pe = total / p as u64;
        let kills = (p / 100).max(1);
        let t = measure_recovery(p, 48, per_pe, kills, &pfs, 11).unwrap();
        let speedup = t.pfs_uncached_s / t.restore_load_s;
        if p == 192 {
            first_speedup = speedup;
        }
        last_speedup = speedup;
        table.row(vec![
            p.to_string(),
            format!("{:.1}", per_pe as f64 / (1 << 20) as f64),
            fmt_time(t.restore_submit_s),
            fmt_time(t.restore_load_s),
            fmt_time(t.pfs_uncached_s),
            fmt_time(t.pfs_cached_s),
            format!("{speedup:.0}x"),
        ]);
    }
    println!("{}", table.render());
    // The paper itself concedes the low-PE regime of the synthetic dataset
    // is unfavourable (real inferences on it never run that small): the
    // anchor is ">= an order of magnitude" from mid-scale upward.
    println!(
        "paper anchor: ReStore load beats the PFS reload (>=10x from mid-scale up; \
         low-PE synthetic regime excluded by the paper) -> measured {first_speedup:.0}x..{last_speedup:.0}x {}",
        if first_speedup > 2.0 && last_speedup > 10.0 { "[OK]" } else { "[MISMATCH]" }
    );
}
