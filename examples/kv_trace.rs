//! A Zipf KV serving trace over two datasets — batched + cached vs a
//! sequential uncached oracle, with a failure landing mid-trace.
//!
//! Two identical execution-mode stores serve the SAME trace:
//!
//! * the **main** store serves reads through a `KvStore` with per-PE
//!   caches, 32 gets fused per `KvBatch` (one request + one data sparse
//!   all-to-all for all misses across both datasets);
//! * the **oracle** twin serves every get individually with caching
//!   disabled — a fresh load from the holders each time.
//!
//! Every single value is compared byte-for-byte between the two, through
//! write rounds (`put_many` riding the dirty-resubmit path on both) and
//! a 2-PE kill mid-trace (ULFM recovery + shrink rebalance on both). At
//! the end the main store's caches are audited against its replicas —
//! zero mismatches, zero stale serves — and the fused trace must have
//! sent strictly fewer messages than the oracle's sequential serving.
//!
//! Run with: `cargo run --release --example kv_trace`

use restore::config::RestoreConfig;
use restore::restore::{DatasetId, KvBatch, KvStore, Overlap, ReStore, Zipf};
use restore::simnet::cluster::Cluster;
use restore::simnet::ulfm;
use restore::util::rng::Rng;

const P: usize = 16;
const BS: usize = 32;
const BPP: usize = 64;
const R: usize = 4;
const N_KEYS: u64 = (P * BPP) as u64;
const BATCH: usize = 32;
const BATCHES: usize = 24;
const FRONTENDS: usize = 4;
const CACHE_SLOTS: usize = 256;
const WRITE_EVERY: usize = 4;
const WRITES_PER_ROUND: usize = 8;
const THETA: f64 = 0.9;

fn image(salt: u8) -> Vec<u8> {
    (0..N_KEYS as usize * BS).map(|i| (i as u8).wrapping_mul(13).wrapping_add(salt)).collect()
}

fn shards_of(store: &ReStore, flat: &[u8]) -> Vec<Vec<u8>> {
    let dist = store.distribution();
    (0..dist.world())
        .map(|j| {
            let r = dist.shard_of(j);
            flat[r.start as usize * BS..r.end as usize * BS].to_vec()
        })
        .collect()
}

/// One serving stack: cluster + store with two submitted datasets + kv
/// front-end registered over both.
fn stack(cache_slots: usize) -> (Cluster, ReStore, KvStore, Vec<DatasetId>) {
    let cfg = RestoreConfig::builder(P, BS, BPP).replicas(R).build().unwrap();
    let mut cluster = Cluster::new_execution(P, 4);
    let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
    store.submit(&mut cluster, &shards_of(&store, &image(1))).unwrap();
    let id2 = store.create_dataset(cfg, &cluster).unwrap();
    let shards2 = shards_of(&store, &image(2));
    store.dataset_mut(id2).unwrap().submit(&mut cluster, &shards2).unwrap();
    let ids = vec![DatasetId::FIRST, id2];
    let mut kv = KvStore::new();
    for (i, &id) in ids.iter().enumerate() {
        kv.register_with_image(&store, id, cache_slots, image(1 + i as u8)).unwrap();
    }
    (cluster, store, kv, ids)
}

fn main() {
    let (mut cluster, mut store, mut kv, ids) = stack(CACHE_SLOTS);
    let (mut o_cluster, mut o_store, mut o_kv, o_ids) = stack(0);
    assert_eq!(ids, o_ids);
    println!(
        "serving {} keys x {BS} B over {} datasets on p={P} (r={R}), \
         batch={BATCH}, {FRONTENDS} frontends",
        N_KEYS,
        ids.len()
    );

    let zipf = Zipf::new(N_KEYS as usize, THETA);
    let mut rng = Rng::seed_from_u64(0xE7);
    let mut fused_msgs = 0u64;
    let mut seq_msgs = 0u64;
    let mut write_round = 0usize;

    for b in 0..BATCHES {
        // -- the failure lands exactly mid-trace, on BOTH stacks --
        if b == BATCHES / 2 {
            println!("\n*** PEs 14 and 15 die mid-trace ***");
            for (cl, st) in [(&mut cluster, &mut store), (&mut o_cluster, &mut o_store)] {
                cl.kill(&[14, 15]);
                let (_failed, map, _cost) = ulfm::recover(cl);
                st.rebalance_or_acknowledge_all(cl, &map).unwrap();
            }
            // the epoch bump strands every cached entry — audited, not swept
            for &id in &ids {
                let audit = kv.validate_cache(&store, id).unwrap();
                assert_eq!(audit.live_entries, 0, "no cache entry may survive the epoch bump");
            }
        }

        let frontends: Vec<usize> =
            cluster.alive_ranks().iter().take(FRONTENDS).map(|&r| r as usize).collect();
        let mut batch = KvBatch::new();
        let mut trace: Vec<(DatasetId, usize, u64)> = Vec::with_capacity(BATCH);
        for i in 0..BATCH {
            let pe = frontends[rng.gen_index(frontends.len())];
            let id = ids[i % ids.len()];
            let key = zipf.sample(&mut rng);
            batch.get(id, pe, key);
            trace.push((id, pe, key));
        }

        // fused + cached on the main stack ...
        let out = kv.execute(&mut store, &mut cluster, &batch).unwrap();
        fused_msgs += out.cost.total_msgs;
        // ... vs one fresh uncached load per get on the oracle twin
        for (i, &(id, pe, key)) in trace.iter().enumerate() {
            let oracle = o_kv.get(&mut o_store, &mut o_cluster, id, pe, key).unwrap();
            seq_msgs += oracle.cost.total_msgs;
            assert_eq!(
                out.value(i).unwrap(),
                oracle.bytes.unwrap().as_slice(),
                "batch {b} get {i}: cached batched value diverged from the fresh-load oracle"
            );
        }

        // -- write rounds ride the dirty-resubmit path on BOTH stacks --
        if (b + 1) % WRITE_EVERY == 0 {
            write_round += 1;
            let id = ids[write_round % ids.len()];
            let keys: Vec<u64> =
                (0..WRITES_PER_ROUND).map(|_| zipf.sample(&mut rng)).collect();
            let values: Vec<Vec<u8>> = keys
                .iter()
                .map(|&k| {
                    (0..BS).map(|j| (k as u8).wrapping_add(j as u8) ^ write_round as u8).collect()
                })
                .collect();
            let writes: Vec<(u64, &[u8])> =
                keys.iter().zip(&values).map(|(&k, v)| (k, v.as_slice())).collect();
            kv.put_many(&mut store, &mut cluster, id, &writes, Overlap::Blocking).unwrap();
            o_kv.put_many(&mut o_store, &mut o_cluster, id, &writes, Overlap::Blocking).unwrap();
        }
    }

    // -- scans map a key range onto one RangeSet load; same oracle check --
    let pe = cluster.alive_ranks()[0] as usize;
    let scan = kv.scan(&mut store, &mut cluster, ids[0], pe, 100, 164).unwrap();
    let o_scan = o_kv.scan(&mut o_store, &mut o_cluster, ids[0], pe, 100, 164).unwrap();
    assert_eq!(scan.bytes.unwrap(), o_scan.bytes.unwrap());

    // -- final audit: every live cache entry matches a live replica --
    let mut hits = 0u64;
    let mut gets = 0u64;
    let mut stale = 0u64;
    for &id in &ids {
        let audit = kv.validate_cache(&store, id).unwrap();
        assert_eq!(audit.mismatched_entries, 0, "cache coherent with the replicas");
        let s = kv.stats(id).unwrap();
        hits += s.hits;
        gets += s.hits + s.misses;
        stale += s.stale_serves;
    }
    assert!(
        fused_msgs < seq_msgs,
        "fused batches must send strictly fewer messages ({fused_msgs} vs {seq_msgs})"
    );
    assert_eq!(stale, 0);

    println!(
        "\n{} gets in {BATCHES} batches, {} write rounds, 1 scan; all values \
         byte-identical to the fresh-load oracle",
        gets, write_round
    );
    println!(
        "kv_trace: hit-rate={:.3} msg-savings={:.3} stale-serves={stale}",
        hits as f64 / gets as f64,
        1.0 - fused_msgs as f64 / seq_msgs as f64,
    );
    println!("kv_trace: OK");
}
