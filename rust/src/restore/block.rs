//! Block identifiers and ranges.
//!
//! ReStore addresses user data as `n` fixed-size serialized *blocks* with
//! dense IDs `0..n` (§IV-A). The API works on half-open ID ranges — the
//! paper's load interface takes "a list of ranges of block identifiers"
//! (§V) — so ranges, not single blocks, are the unit everything below
//! operates on. This is also what lets the implementation scale: schedules
//! are O(ranges), never O(blocks).

/// A half-open range of block IDs `[start, end)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockRange {
    pub start: u64,
    pub end: u64,
}

impl BlockRange {
    pub fn new(start: u64, end: u64) -> Self {
        assert!(start <= end, "inverted range [{start}, {end})");
        BlockRange { start, end }
    }

    pub fn len(&self) -> u64 {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    pub fn contains(&self, id: u64) -> bool {
        self.start <= id && id < self.end
    }

    pub fn intersect(&self, other: &BlockRange) -> Option<BlockRange> {
        let s = self.start.max(other.start);
        let e = self.end.min(other.end);
        (s < e).then(|| BlockRange::new(s, e))
    }

    /// Split into subranges aligned to multiples of `chunk` (the
    /// permutation-range decomposition of §IV-B).
    pub fn chunks(&self, chunk: u64) -> impl Iterator<Item = BlockRange> + '_ {
        assert!(chunk > 0);
        let mut cur = self.start;
        let end = self.end;
        std::iter::from_fn(move || {
            if cur >= end {
                return None;
            }
            let next = ((cur / chunk) + 1) * chunk;
            let stop = next.min(end);
            let out = BlockRange::new(cur, stop);
            cur = stop;
            Some(out)
        })
    }
}

/// A normalized set of block ranges: sorted, non-overlapping, non-adjacent.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RangeSet {
    ranges: Vec<BlockRange>,
}

impl RangeSet {
    pub fn new(mut ranges: Vec<BlockRange>) -> Self {
        ranges.retain(|r| !r.is_empty());
        ranges.sort();
        let mut out: Vec<BlockRange> = Vec::with_capacity(ranges.len());
        for r in ranges {
            match out.last_mut() {
                Some(last) if r.start <= last.end => last.end = last.end.max(r.end),
                _ => out.push(r),
            }
        }
        RangeSet { ranges: out }
    }

    pub fn ranges(&self) -> &[BlockRange] {
        &self.ranges
    }

    pub fn total_blocks(&self) -> u64 {
        self.ranges.iter().map(BlockRange::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_basics() {
        let r = BlockRange::new(10, 20);
        assert_eq!(r.len(), 10);
        assert!(r.contains(10) && r.contains(19) && !r.contains(20));
        assert!(!r.is_empty());
        assert!(BlockRange::new(5, 5).is_empty());
    }

    #[test]
    #[should_panic(expected = "inverted")]
    fn inverted_range_panics() {
        BlockRange::new(5, 4);
    }

    #[test]
    fn intersect() {
        let a = BlockRange::new(0, 10);
        assert_eq!(a.intersect(&BlockRange::new(5, 15)), Some(BlockRange::new(5, 10)));
        assert_eq!(a.intersect(&BlockRange::new(10, 15)), None);
        assert_eq!(a.intersect(&BlockRange::new(2, 3)), Some(BlockRange::new(2, 3)));
    }

    #[test]
    fn chunks_align_to_boundaries() {
        let r = BlockRange::new(5, 23);
        let cs: Vec<_> = r.chunks(8).collect();
        assert_eq!(
            cs,
            vec![
                BlockRange::new(5, 8),
                BlockRange::new(8, 16),
                BlockRange::new(16, 23)
            ]
        );
        assert_eq!(cs.iter().map(BlockRange::len).sum::<u64>(), r.len());
    }

    #[test]
    fn chunks_exact_fit() {
        let r = BlockRange::new(16, 32);
        let cs: Vec<_> = r.chunks(8).collect();
        assert_eq!(cs, vec![BlockRange::new(16, 24), BlockRange::new(24, 32)]);
    }

    #[test]
    fn rangeset_normalizes() {
        let s = RangeSet::new(vec![
            BlockRange::new(10, 20),
            BlockRange::new(0, 5),
            BlockRange::new(15, 25),
            BlockRange::new(5, 5),
        ]);
        assert_eq!(s.ranges(), &[BlockRange::new(0, 5), BlockRange::new(10, 25)]);
        assert_eq!(s.total_blocks(), 20);
    }

    #[test]
    fn rangeset_merges_adjacent() {
        let s = RangeSet::new(vec![BlockRange::new(0, 5), BlockRange::new(5, 10)]);
        assert_eq!(s.ranges(), &[BlockRange::new(0, 10)]);
    }
}
