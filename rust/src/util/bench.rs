//! Micro-bench harness — in-tree replacement for `criterion`, used by the
//! `benches/` binaries (`harness = false`).
//!
//! Measures wall-clock over warmup + timed iterations, reports
//! mean/median/p10/p90 like the paper's plots (§VI-A: 10 repetitions,
//! mean with 10th/90th percentile error bars). Results can additionally be
//! emitted as machine-readable `{name, ns_per_iter}` JSON lines
//! ([`write_json_artifact`]) — CI uploads these as `BENCH_*.json` so the
//! perf trajectory is tracked across PRs.
//!
//! Also hosts [`CountingAlloc`], the allocation-count harness behind the
//! zero-per-unit-allocation assertions (`rust/tests/alloc_counts.rs`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::metrics::{fmt_time, Stats};

/// Global allocation counter incremented by [`CountingAlloc`].
static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

/// A [`System`]-backed global allocator that counts every allocation
/// (`alloc` and `realloc`; frees are not counted). Register it in a
/// dedicated test binary:
///
/// ```ignore
/// #[global_allocator]
/// static A: restore::util::bench::CountingAlloc = restore::util::bench::CountingAlloc;
/// ```
///
/// then bracket the code under test with [`alloc_count`] reads. Used to
/// assert that hot paths (execution-mode submit, repair planning) perform
/// no per-unit heap allocation.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Total allocations counted so far (0 unless [`CountingAlloc`] is the
/// registered global allocator). Take a before/after difference around the
/// code under test.
pub fn alloc_count() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

/// Is the `BENCH_SHORT` environment variable set (to anything but `0`)?
/// The bench binaries use this to skip their largest configurations and
/// cut repetition counts — CI's `make bench-json-short` schema smoke runs
/// every bench end to end (so each `BENCH_*.json` artifact exists and
/// parses) in seconds instead of minutes; the full-scale runs follow in
/// dedicated steps.
pub fn short_mode() -> bool {
    std::env::var_os("BENCH_SHORT").is_some_and(|v| !v.is_empty() && v != "0")
}

/// One timed measurement series.
pub struct BenchResult {
    pub name: String,
    pub stats: Stats,
}

impl BenchResult {
    /// A raw-metric result: `json_line` will report exactly `value` in the
    /// `ns_per_iter` field, which for these entries is a generic metric
    /// carrier (simulated nanoseconds, migrated bytes, ...) — the entry
    /// name states the unit. Keeps every `BENCH_*.json` artifact on the
    /// one-object-per-line `{name, ns_per_iter}` schema CI already parses.
    pub fn from_value(name: &str, value: f64) -> BenchResult {
        BenchResult { name: name.to_string(), stats: Stats::from(&[value * 1e-9]) }
    }

    pub fn line(&self) -> String {
        format!(
            "{:<48} mean {:>12}  p10 {:>12}  p90 {:>12}  (n={})",
            self.name,
            fmt_time(self.stats.mean),
            fmt_time(self.stats.p10),
            fmt_time(self.stats.p90),
            self.stats.n
        )
    }

    /// One machine-readable JSON object: `{"name": ..., "ns_per_iter": ...}`.
    /// The value is emitted with `{:?}` (shortest round-tripping repr) —
    /// fixed-point `{:.1}` used to truncate sub-0.05 ns metrics (IDL
    /// probabilities, fractions ride in this field) to a flat `0.0`, which
    /// `tools/validate_bench_json.py` now rejects as a broken measurement.
    pub fn json_line(&self) -> String {
        format!(
            "{{\"name\": \"{}\", \"ns_per_iter\": {:?}}}",
            self.name.replace('\\', "\\\\").replace('"', "\\\""),
            self.stats.mean * 1e9
        )
    }
}

/// Write `results` as one JSON object per line to `path` (the CI perf
/// artifact format — `BENCH_hotpath.json`, `BENCH_load_scale.json`).
pub fn write_json_artifact(path: &str, results: &[BenchResult]) -> std::io::Result<()> {
    let mut out = String::new();
    for r in results {
        out.push_str(&r.json_line());
        out.push('\n');
    }
    std::fs::write(path, out)
}

/// Time `f` for `reps` repetitions after `warmup` unmeasured calls.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    BenchResult { name: name.to_string(), stats: Stats::from(&samples) }
}

/// Collect repeated *simulated-time* samples (for cost-model benches the
/// measurement is the simulated clock, not wall time).
pub fn sim_samples<F: FnMut(u64) -> f64>(reps: usize, mut f: F) -> Stats {
    let samples: Vec<f64> = (0..reps.max(1) as u64).map(&mut f).collect();
    Stats::from(&samples)
}

/// Prevent the optimizer from discarding a value (std::hint wrapper).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0u64;
        let r = bench("noop", 2, 5, || {
            count += 1;
            black_box(count);
        });
        assert_eq!(count, 7); // 2 warmup + 5 timed
        assert_eq!(r.stats.n, 5);
        assert!(r.stats.mean >= 0.0);
        assert!(r.line().contains("noop"));
    }

    #[test]
    fn sim_samples_passes_rep_index() {
        let s = sim_samples(4, |rep| rep as f64);
        assert_eq!(s.n, 4);
        assert_eq!(s.mean, 1.5);
    }

    #[test]
    fn json_line_is_machine_readable() {
        let r = BenchResult {
            name: "load-1% resolve+route p=1536".into(),
            stats: Stats::from(&[1e-6, 3e-6]),
        };
        assert_eq!(
            r.json_line(),
            "{\"name\": \"load-1% resolve+route p=1536\", \"ns_per_iter\": 2000.0}"
        );
        // quotes in names stay valid JSON
        let q = BenchResult { name: "a\"b".into(), stats: Stats::from(&[1e-9]) };
        assert!(q.json_line().contains("a\\\"b"));
    }

    #[test]
    fn json_line_keeps_tiny_values_nonzero() {
        // Raw metrics far below 1 ns (IDL probabilities and alive fractions
        // ride the ns_per_iter field) must not collapse to "0.0" — the
        // validator rejects non-positive values as broken measurements.
        let r = BenchResult::from_value("idl-prob tiny", 1.0e-12);
        let line = r.json_line();
        assert!(!line.contains(": 0.0}"), "{line}");
        let v: f64 = line
            .rsplit(": ")
            .next()
            .unwrap()
            .trim_end_matches('}')
            .parse()
            .unwrap();
        assert!(v > 0.0 && v < 1.0e-9, "{line}");
    }

    #[test]
    fn alloc_count_is_monotonic() {
        // CountingAlloc is not registered in unit tests; the counter just
        // reads 0-or-more and never decreases.
        let a = alloc_count();
        let _v: Vec<u8> = Vec::with_capacity(128);
        assert!(alloc_count() >= a);
    }
}
