//! Typed serialization helpers: app data ⇄ block payloads.
//!
//! The paper's API has applications write "their serialized data blocks to
//! a memory location supplied by the library" (§V). These helpers cover the
//! formats our applications use: dense `f32` matrices (k-means points,
//! MSA/CLV columns) and `u64` edge lists (PageRank).

/// Serialize a flat `f32` slice into a whole number of `block_size`-byte
/// blocks, zero-padding the tail block.
pub fn f32s_to_blocks(data: &[f32], block_size: usize) -> Vec<u8> {
    assert!(block_size > 0 && block_size % 4 == 0);
    let bytes = data.len() * 4;
    let padded = bytes.div_ceil(block_size) * block_size;
    let mut out = Vec::with_capacity(padded);
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out.resize(padded, 0);
    out
}

/// Deserialize `count` `f32` values from block bytes.
pub fn blocks_to_f32s(bytes: &[u8], count: usize) -> Vec<f32> {
    assert!(bytes.len() >= count * 4);
    bytes[..count * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Serialize a `u64` slice into blocks (PageRank edge lists).
pub fn u64s_to_blocks(data: &[u64], block_size: usize) -> Vec<u8> {
    assert!(block_size > 0 && block_size % 8 == 0);
    let bytes = data.len() * 8;
    let padded = bytes.div_ceil(block_size) * block_size;
    let mut out = Vec::with_capacity(padded);
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out.resize(padded, 0);
    out
}

/// Deserialize `count` `u64` values from block bytes.
pub fn blocks_to_u64s(bytes: &[u8], count: usize) -> Vec<u64> {
    assert!(bytes.len() >= count * 8);
    bytes[..count * 8]
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

/// Number of blocks needed to hold `n` f32 values.
pub fn f32_blocks_needed(n: usize, block_size: usize) -> usize {
    (n * 4).div_ceil(block_size)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_exact_fit() {
        let data: Vec<f32> = (0..32).map(|i| i as f32 * 0.5).collect();
        let blocks = f32s_to_blocks(&data, 64); // 128 bytes = 2 blocks
        assert_eq!(blocks.len(), 128);
        assert_eq!(blocks_to_f32s(&blocks, 32), data);
    }

    #[test]
    fn f32_roundtrip_with_padding() {
        let data = vec![1.5f32, -2.25, 3.75];
        let blocks = f32s_to_blocks(&data, 64);
        assert_eq!(blocks.len(), 64);
        assert_eq!(blocks_to_f32s(&blocks, 3), data);
        assert!(blocks[12..].iter().all(|&b| b == 0));
    }

    #[test]
    fn u64_roundtrip() {
        let data = vec![u64::MAX, 0, 42, 1 << 40];
        let blocks = u64s_to_blocks(&data, 64);
        assert_eq!(blocks.len(), 64);
        assert_eq!(blocks_to_u64s(&blocks, 4), data);
    }

    #[test]
    fn blocks_needed() {
        assert_eq!(f32_blocks_needed(16, 64), 1);
        assert_eq!(f32_blocks_needed(17, 64), 2);
        assert_eq!(f32_blocks_needed(0, 64), 0);
    }
}
