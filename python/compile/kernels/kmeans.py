"""L1 Pallas kernel: fused k-means assignment step.

The paper's k-means application (§VI-C, Fig 5) assigns each local point to
its nearest center and accumulates per-center partial sums which are then
all-reduced across PEs. The hot spot is the pairwise distance computation —
here cast as a tiled matmul so it maps onto the TPU MXU (DESIGN.md §2):

    ||x - c||^2 = ||x||^2 - 2 x.cT + ||c||^2

The kernel tiles points into (TILE, D) VMEM blocks; centers are small and
live fully in VMEM for all grid steps. Per grid step the kernel emits
per-tile partial results (sums, counts, inertia); the L2 model reduces over
tiles. This avoids cross-grid-step accumulation, which keeps the kernel
trivially data-parallel (double-buffering friendly on real hardware).

Lowered with interpret=True: CPU PJRT cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile: 2048 x 32 f32 = 256 KiB of points per grid step; together
# with the distance tile (2048 x K) and partials this stays well under 1 MiB
# of VMEM (DESIGN.md §7).
DEFAULT_TILE = 2048


def _kmeans_tile_kernel(x_ref, c_ref, sums_ref, counts_ref, inertia_ref):
    """One grid step: assignment + partials for a (TILE, D) block of points.

    Block shapes:
      x_ref:       (TILE, D)  points block
      c_ref:       (K, D)     all centers (same block every step)
      sums_ref:    (1, K, D)  per-tile partial sums (output)
      counts_ref:  (1, K)     per-tile partial counts (output)
      inertia_ref: (1, 1)     per-tile partial inertia (output)
    """
    x = x_ref[...]
    c = c_ref[...]
    k = c.shape[0]

    # Distance matrix via MXU matmul: (TILE, D) @ (D, K).
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (TILE, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]  # (1, K)
    d2 = x2 - 2.0 * jnp.dot(x, c.T, preferred_element_type=jnp.float32) + c2

    assign = jnp.argmin(d2, axis=1)  # (TILE,)
    onehot = (assign[:, None] == jnp.arange(k)[None, :]).astype(x.dtype)

    # Partial reductions, fused in-VMEM (the epilogue that on GPU would be a
    # shared-memory scatter; on TPU a second small MXU matmul).
    sums_ref[0] = jnp.dot(onehot.T, x, preferred_element_type=jnp.float32)
    counts_ref[0] = jnp.sum(onehot, axis=0)
    inertia_ref[0, 0] = jnp.sum(jnp.min(d2, axis=1))


@functools.partial(jax.jit, static_argnames=("tile",))
def kmeans_assign(points, centers, *, tile=DEFAULT_TILE):
    """Fused assignment step. Returns (sums (K,D), counts (K,), inertia ()).

    `points.shape[0]` must be a multiple of `tile` (the AOT artifacts are
    compiled for fixed shapes; model.py picks a dividing tile).
    """
    n, d = points.shape
    k = centers.shape[0]
    if n % tile != 0:
        raise ValueError(f"point count {n} not divisible by tile {tile}")
    grid = n // tile

    sums, counts, inertia = pl.pallas_call(
        _kmeans_tile_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid, k, d), points.dtype),
            jax.ShapeDtypeStruct((grid, k), points.dtype),
            jax.ShapeDtypeStruct((grid, 1), points.dtype),
        ],
        interpret=True,
    )(points, centers)

    # Tile reduction happens in the surrounding jit — XLA fuses it with the
    # kernel output layout, so no extra HBM round trip on real hardware.
    return jnp.sum(sums, axis=0), jnp.sum(counts, axis=0), jnp.sum(inertia)
