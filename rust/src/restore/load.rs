//! The load (recovery) path — what runs after every failure (§IV-A/§V).
//!
//! Two-phase protocol, the paper's preferred API mode 2 ("providing exactly
//! those ID ranges each individual PE needs on exactly that PE"):
//!
//! 1. **Request resolution + request all-to-all.** Each requester maps its
//!    block ranges to permuted pieces, picks one *serving PE* per piece
//!    among the surviving replica holders (successive blocks with the same
//!    holder set get the same server — minimizing the bottleneck number of
//!    messages, §IV-A), and sends each chosen server one request message.
//! 2. **Data sparse all-to-all.** Servers answer with one coalesced data
//!    message per requester.
//!
//! **Self-send semantics** (consistent across both phases): a requester
//! that serves a piece from its own store exchanges no protocol message at
//! all — the request phase skips the pair entirely — and the data phase
//! charges only the local memory-bandwidth copy of the payload (via the
//! [`Accumulator`]'s self-message handling), never NIC, latency, or
//! fragment costs. A requester loading its own surviving slice therefore
//! costs **zero network** (pinned by the `self_served_load_costs_zero_network`
//! golden test).
//!
//! **Versioned (mutable) datasets:** loads always serve the latest
//! *committed* version. An in-flight [`crate::restore::resubmit`]
//! replicates into a separate staging store (double-buffered), which the
//! router below never reads — a load racing a checkpoint returns the
//! previous complete version, never a torn mix of old and new blocks.
//!
//! ## The routing pipeline (perf)
//!
//! Recovery latency is the paper's headline number ("in the range of
//! milliseconds on up to 24 576 processors"), so the simulator's load path
//! must not be dominated by its own bookkeeping. The pipeline performs no
//! per-piece heap allocation in steady state — all per-piece intermediate
//! state, including the per-phase cost `Accumulator` counters, lives in a
//! [`LoadScratch`] owned by each [`Dataset`] and reused across calls
//! (the only remaining per-call allocation is the output shards). With the
//! `rayon` feature, request resolution additionally fans out across
//! requesters (serial-identical by construction; see `resolve_all`). The
//! greedy `LeastLoaded` policy parallelizes through a deterministic
//! two-pass split: pass 1 resolves every piece's alive-holder candidate
//! set in parallel (liveness, deterministic holders, post-repair index
//! fallback — the per-piece work), pass 2 replays the greedy
//! minimum-load assignment serially in request order over those fixed
//! candidate sets — bit-identical to the single-pass serial router, since
//! the candidate sets never depend on the running load table:
//!
//! * **Resolve** — block ranges → [`PermutedPiece`]s via the precomputed
//!   placement index ([`crate::restore::distribution`]), no Feistel work on
//!   the hot path.
//! * **Route** — `pick_server` walks the ≤ `r` holders through a
//!   fixed-size stack buffer (no per-piece `Vec`), tracking per-server load
//!   for the `LeastLoaded` policy in a generation-stamped per-PE table
//!   ([`StampedLoad`]) that clears in O(1) instead of re-zeroing `p`
//!   entries per load.
//! * **Coalesce** — adjacent routed pieces with the same (requester,
//!   server) and contiguous permuted ranges inside one slice merge into
//!   single *runs*: one memcpy and one pack/unpack fragment each, matching
//!   the paper's "one coalesced message per pair" semantics. Byte and
//!   bottleneck totals are unchanged by construction (each run still
//!   carries one 24-byte descriptor *per merged piece* and the sum of its
//!   pieces' payload bytes); only fragment counts can drop. Merges require
//!   consecutive units to land on adjacent permuted slots in one slice, so
//!   they are rare under the Feistel permutation — the guaranteed wins are
//!   the scratch reuse and the sort-based aggregation below.
//! * **Aggregate** — runs are sorted by (requester, server) and both
//!   message phases are charged by run-length grouping over that order —
//!   no tuple-keyed hash maps.
//! * **Assemble** — each run resolves its source slice once via the sorted
//!   binary-searched [`crate::restore::store::PeStore`] and performs a
//!   single contiguous copy.
//!
//! The request-pattern helpers at the bottom generate the paper's three
//! benchmark operations (§VI-B2) and the two recovery styles of §VI-D.2
//! (single-target substitute-style and scattered shrinking-style).
//! Throughput is tracked by `benches/hotpath.rs` and `benches/
//! load_scale.rs`; before/after numbers live in `EXPERIMENTS.md §Perf`.

use crate::config::ServerSelection;
use crate::error::{Error, Result};
use crate::restore::block::{BlockRange, RangeSet};
use crate::restore::distribution::{Distribution, PermutedPiece};
use crate::restore::hashing::seeded_hash;
use crate::restore::registry::{
    Dataset, DatasetId, LoadManyOutput, LoadManyPart, PooledLoadOutput, PooledPart, PooledShard,
};
use crate::restore::{LoadOutput, LoadRequest, LoadedShard, ReStore};
use crate::simnet::cluster::Cluster;
use crate::simnet::network::{Accumulator, PhaseCost};

#[cfg(feature = "rayon")]
use rayon::prelude::*;

/// Bytes per piece descriptor in a request message (perm_start, len, dest
/// offset — what the sparse all-to-all of §V carries).
const REQUEST_HEADER_BYTES: u64 = 24;

/// Replication levels up to this route through a fixed-size stack buffer
/// in `pick_server`; larger `r` (and the rare post-repair fallback) use a
/// reusable scratch vector instead.
const INLINE_HOLDERS: usize = 16;

/// Below this many routed pieces (or runs) the coalesce and sort stages
/// stay serial even with the `rayon` feature — the fork/join overhead
/// dwarfs the work, and keeping tiny workloads serial also keeps the
/// allocation-count assertions (`rust/tests/alloc_counts.rs`) exact.
#[cfg(feature = "rayon")]
const PAR_MIN_ITEMS: usize = 4096;

/// A piece with its chosen server, requester, and output offset.
#[derive(Debug, Clone, Copy)]
struct RoutedPiece {
    piece: PermutedPiece,
    requester: usize,
    /// Index into the `requests` slice (a PE may appear in several
    /// requests; assembly is per-request, messaging per-PE).
    req_idx: usize,
    server: usize,
    /// Byte offset in the request's output buffer.
    out_offset: u64,
}

/// One piece with its precomputed load-independent candidate servers —
/// pass 1 output of the two-pass `LeastLoaded` resolution. `n_holders == 0`
/// marks an oversized post-repair fallback set; pass 2 re-resolves those
/// through `pick_server`.
#[cfg(feature = "rayon")]
#[derive(Debug, Clone, Copy)]
struct Candidate {
    piece: PermutedPiece,
    out_offset: u64,
    n_holders: u8,
    holders: [u32; INLINE_HOLDERS],
}

/// A maximal merge of adjacent routed pieces with the same (requester,
/// server) and contiguous permuted positions inside one slice: one memcpy,
/// one pack fragment, one unpack fragment.
#[derive(Debug, Clone, Copy)]
struct Run {
    requester: usize,
    req_idx: usize,
    server: usize,
    perm_start: u64,
    /// Length in blocks.
    len: u64,
    /// Number of request descriptors merged into this run (cost accounting
    /// stays per-piece so totals are identical to the uncoalesced schedule).
    pieces: u64,
    out_offset: u64,
    /// End of the slice containing this run. Runs never cross slice edges
    /// (pieces are pre-split there), so caching the boundary makes the
    /// same-slice merge check one compare instead of a `slice_of` per
    /// appended piece on the hot coalescing loop.
    slice_end: u64,
}

/// Generation-stamped per-PE byte table for the `LeastLoaded` policy.
///
/// The dense predecessor was re-zeroed with `resize(p, 0)` on every load
/// — an O(p) clear even for a one-piece request. Here [`StampedLoad::begin`]
/// bumps a generation counter instead: entries whose stamp lags the
/// current generation read as 0, so clearing is O(1) and only the PEs the
/// router actually charges are ever written. The backing tables are
/// grow-only (capacity is retained across calls and across cluster
/// shrinks, exactly like the pooled [`Accumulator`] stamp tables), and
/// the generation is a `u64` starting at 1 so stale stamps (0) can never
/// alias a live generation.
#[derive(Debug, Default)]
pub(crate) struct StampedLoad {
    loads: Vec<u64>,
    stamps: Vec<u64>,
    gen: u64,
}

impl StampedLoad {
    /// Start a fresh load over `world` PEs: O(1) in steady state (the
    /// resize only runs while the table is still growing).
    fn begin(&mut self, world: usize) {
        self.gen += 1;
        if self.loads.len() < world {
            self.loads.resize(world, 0);
            self.stamps.resize(world, 0);
        }
    }

    #[inline]
    fn get(&self, pe: usize) -> u64 {
        if self.stamps[pe] == self.gen {
            self.loads[pe]
        } else {
            0
        }
    }

    #[inline]
    fn add(&mut self, pe: usize, bytes: u64) {
        if self.stamps[pe] != self.gen {
            self.stamps[pe] = self.gen;
            self.loads[pe] = 0;
        }
        self.loads[pe] += bytes;
    }

    #[cfg(test)]
    pub(crate) fn capacity(&self) -> usize {
        self.loads.capacity()
    }
}

/// Reusable buffers for [`ReStore::load`]: steady-state calls perform no
/// per-piece heap allocation — only the output shards are allocated.
#[derive(Debug, Default)]
pub(crate) struct LoadScratch {
    routed: Vec<RoutedPiece>,
    pieces: Vec<PermutedPiece>,
    runs: Vec<Run>,
    /// Stamped per-PE byte counters for the `LeastLoaded` policy —
    /// cleared in O(1) per load by a generation bump.
    server_load: StampedLoad,
    /// Holder list for `r > INLINE_HOLDERS` and the repair fallback.
    holders: Vec<usize>,
    /// Pooled cost accumulator shared by the request and data phases
    /// (reset-and-reused via [`Cluster::phase_pooled`]) — formerly the last
    /// O(p) allocation per `load` call. Crate-visible so
    /// [`Dataset::last_phase_touched`] can report its touched-entry counts.
    pub(crate) acc: Accumulator,
}

impl Dataset {
    /// Load data after failures. `requests` lists, per requesting PE, the
    /// original block ID ranges it needs (PEs with no needs may be absent).
    ///
    /// Returns the loaded bytes per requester (execution mode) and the
    /// phase costs. Errors with [`Error::IrrecoverableDataLoss`] if all
    /// `r` holders of some requested range are dead — the caller then falls
    /// back to reloading input from disk, as the paper prescribes (§VI-B1).
    pub fn load(&mut self, cluster: &mut Cluster, requests: &[LoadRequest]) -> Result<LoadOutput> {
        self.ensure_submitted()?;
        // Shrink handshake: after `ulfm::shrink` the layout must first be
        // rebalanced (or the shrink acknowledged) — §IV-B.
        self.ensure_current_epoch(cluster)?;
        // Detach the scratch so `&self` stays free for routing lookups; it
        // is returned (with its grown capacity) even on error.
        let mut scratch = std::mem::take(&mut self.scratch);
        let result = self.load_with_scratch(cluster, requests, &mut scratch);
        self.scratch = scratch;
        result
    }

    /// The planning front half of a load: resolve, route, coalesce, and
    /// sort `requests` into `scratch.runs` — everything up to (but not
    /// including) charging the message phases. Pure with respect to the
    /// cluster clock, so [`ReStore::load_many`] can plan every dataset
    /// first and then charge the merged phases once.
    fn plan_into(
        &self,
        cluster: &Cluster,
        requests: &[LoadRequest],
        scratch: &mut LoadScratch,
    ) -> Result<()> {
        let dist = &self.dist;
        let bs = self.cfg.block_size as u64;

        // --- Phase 1a: request resolution (local, per requester) --------
        for req in requests {
            if !cluster.is_alive(req.pe) {
                return Err(Error::DeadPe(req.pe));
            }
            // Range sets are sorted, so the last range's end bounds the
            // whole request — an O(1), allocation-free check that turns an
            // out-of-space request into a routing error instead of a panic
            // deep inside the permutation.
            if let Some(last) = req.ranges.ranges().last() {
                if last.end > dist.n_blocks() {
                    return Err(Error::Config(format!(
                        "load: request for PE {} addresses blocks up to {} but dataset {} \
                         holds [0, {})",
                        req.pe,
                        last.end,
                        self.id,
                        dist.n_blocks()
                    )));
                }
            }
        }
        scratch.routed.clear();
        // Sized by the *cluster* world, not dist.world(): the LeastLoaded
        // table is indexed by cluster ranks, which keep their original
        // numbering after a rebalance shrinks the distribution to p'.
        // O(1) generation bump, not an O(p) re-zero.
        scratch.server_load.begin(self.stores.len());
        self.resolve_all(cluster, requests, scratch)?;

        // --- Run coalescing ---------------------------------------------
        // Merge adjacent pieces with the same (request, server) that are
        // contiguous in both the permuted space (within one slice, so a
        // single stored buffer covers the run) and the output buffer. A run
        // never crosses a request boundary, so with the `rayon` feature the
        // per-request segments coalesce in parallel and concatenate back in
        // request order — byte-identical to the serial pass.
        Self::coalesce_all(requests.len(), dist, bs, scratch);

        // Group runs per (requester, server) pair by sorting; both message
        // phases below are single run-length passes over this order. The
        // key is a *total* order — (req_idx, out_offset) is unique per run
        // — so serial, parallel, stable, and unstable sorts all produce
        // the same permutation and the schedule stays byte-identical
        // across feature sets.
        let run_key = |r: &Run| (r.requester, r.server, r.req_idx, r.out_offset);
        #[cfg(feature = "rayon")]
        {
            if scratch.runs.len() >= PAR_MIN_ITEMS {
                scratch.runs.par_sort_unstable_by_key(run_key);
            } else {
                scratch.runs.sort_unstable_by_key(run_key);
            }
        }
        #[cfg(not(feature = "rayon"))]
        scratch.runs.sort_unstable_by_key(run_key);
        Ok(())
    }

    /// Assemble the per-request output shards from planned `runs`
    /// (execution mode copies the payload; cost-model mode returns `None`
    /// bytes) — the back half shared by [`Dataset::load`] and
    /// [`ReStore::load_many`].
    ///
    /// Every run is checksum-verified against the sums latched at submit
    /// time before a single byte is copied, so silent corruption (bit rot,
    /// a torn write) surfaces as [`Error::CorruptBlock`] instead of
    /// garbage in the output shards. Verification is read-only — it names
    /// the corrupt holder so the caller can `Dataset::scrub` to quarantine
    /// and repair it, but a failed load never mutates the store.
    fn assemble_shards(
        &self,
        requests: &[LoadRequest],
        runs: &[Run],
    ) -> Result<Vec<LoadedShard>> {
        let bs = self.cfg.block_size as u64;
        let execution = self.is_execution_mode();
        let mut shards: Vec<LoadedShard> = requests
            .iter()
            .map(|r| LoadedShard {
                pe: r.pe,
                bytes: execution.then(|| vec![0u8; (r.ranges.total_blocks() * bs) as usize]),
            })
            .collect();
        if execution {
            for run in runs {
                let src = self.verify_and_read(run)?;
                let dst = shards[run.req_idx].bytes.as_mut().unwrap();
                let off = run.out_offset as usize;
                dst[off..off + src.len()].copy_from_slice(src);
            }
        }
        Ok(shards)
    }

    /// The arena-backed assembly of [`ReStore::load_many_pooled`]: verify
    /// and copy planned `runs` into the shared `arena`, each request's
    /// bytes landing at its [`PooledShard`] span. Same checksum contract
    /// as [`Dataset::assemble_shards`] — corrupt copies surface as
    /// [`Error::CorruptBlock`] before a single byte is copied for that
    /// run, and a failed assembly never mutates the store. Cost-model
    /// datasets (`None` spans) copy nothing.
    fn assemble_into_arena(
        &self,
        runs: &[Run],
        shards: &[PooledShard],
        arena: &mut [u8],
    ) -> Result<()> {
        if !self.is_execution_mode() {
            return Ok(());
        }
        for run in runs {
            let src = self.verify_and_read(run)?;
            let span = shards[run.req_idx].span.as_ref().expect("execution mode has spans");
            let off = span.start + run.out_offset as usize;
            arena[off..off + src.len()].copy_from_slice(src);
        }
        Ok(())
    }

    /// Checksum-verify one run against the sums latched at submit time and
    /// return its stored bytes — the shared kernel of both assembly paths.
    fn verify_and_read(&self, run: &Run) -> Result<&[u8]> {
        if let Some(y) = self.stores[run.server].verify(run.perm_start, run.len) {
            return Err(Error::CorruptBlock {
                dataset: self.id,
                block: self.dist.unpermute_block(y),
                holder: run.server,
            });
        }
        Ok(self.stores[run.server]
            .read(run.perm_start, run.len)
            .expect("execution-mode store must hold real bytes"))
    }

    fn load_with_scratch(
        &self,
        cluster: &mut Cluster,
        requests: &[LoadRequest],
        scratch: &mut LoadScratch,
    ) -> Result<LoadOutput> {
        let bs = self.cfg.block_size as u64;
        self.plan_into(cluster, requests, scratch)?;

        // --- Phase 1b: request sparse all-to-all -------------------------
        // One message per distinct (requester, server) pair carrying the
        // per-piece descriptors. A requester serving itself sends no
        // request at all — resolution is local bookkeeping, so self pairs
        // are skipped entirely (not even a local-copy charge; see the
        // module docs on self-send semantics). Both phases run on the
        // scratch-pooled accumulator: no O(p) counter allocation per call.
        let mut phase = cluster.phase_pooled(&mut scratch.acc);
        let mut i = 0;
        while i < scratch.runs.len() {
            let (requester, server) = (scratch.runs[i].requester, scratch.runs[i].server);
            let mut bytes = 0u64;
            while i < scratch.runs.len()
                && scratch.runs[i].requester == requester
                && scratch.runs[i].server == server
            {
                bytes += scratch.runs[i].pieces * REQUEST_HEADER_BYTES;
                i += 1;
            }
            if requester != server {
                phase.add(requester, server, bytes)?;
            }
        }
        let request_cost = phase.commit();

        // --- Phase 2: data sparse all-to-all ------------------------------
        // One message per (server, requester) pair; every run is one pack
        // fragment on the server and one unpack fragment on the requester.
        // Self pairs (requester serves itself) still go through `add`: the
        // Accumulator books them as a pure local memory copy — the output
        // assembly genuinely copies the payload — with zero network bytes,
        // messages, or fragments (hence the matching `frag` skip).
        let mut phase = cluster.phase_pooled(&mut scratch.acc);
        let mut i = 0;
        while i < scratch.runs.len() {
            let (requester, server) = (scratch.runs[i].requester, scratch.runs[i].server);
            let start = i;
            let mut bytes = 0u64;
            while i < scratch.runs.len()
                && scratch.runs[i].requester == requester
                && scratch.runs[i].server == server
            {
                bytes += scratch.runs[i].len * bs;
                i += 1;
            }
            phase.add(server, requester, bytes)?;
            if server != requester {
                phase.frag(server, (i - start) as u64);
                phase.frag(requester, (i - start) as u64);
            }
        }
        let data_cost = phase.commit();

        // --- Assemble outputs (execution mode) ---------------------------
        let shards = self.assemble_shards(requests, &scratch.runs)?;

        Ok(LoadOutput {
            shards,
            request_cost,
            data_cost,
            cost: request_cost.then(data_cost),
        })
    }

    /// Coalesce `scratch.routed` into `scratch.runs` (cleared first).
    ///
    /// Runs only ever merge pieces with equal `req_idx`, so the result of
    /// coalescing the whole routed list equals the concatenation of
    /// coalescing each request's segment independently — which is exactly
    /// what the `rayon` path does for large workloads, preserving the
    /// serial output byte for byte (CI proves it by running the golden
    /// parity suite under both feature sets).
    #[cfg_attr(not(feature = "rayon"), allow(unused_variables))]
    fn coalesce_all(
        n_requests: usize,
        dist: &Distribution,
        bs: u64,
        scratch: &mut LoadScratch,
    ) {
        scratch.runs.clear();
        #[cfg(feature = "rayon")]
        if n_requests > 1 && scratch.routed.len() >= PAR_MIN_ITEMS {
            let routed = &scratch.routed;
            // request segment boundaries (routed is in request order)
            let mut bounds: Vec<(usize, usize)> = Vec::with_capacity(n_requests);
            let mut s = 0usize;
            for i in 1..=routed.len() {
                if i == routed.len() || routed[i].req_idx != routed[s].req_idx {
                    bounds.push((s, i));
                    s = i;
                }
            }
            let per_segment: Vec<Vec<Run>> = bounds
                .par_iter()
                .map(|&(a, b)| {
                    let mut out = Vec::new();
                    coalesce_runs(&routed[a..b], dist, bs, &mut out);
                    out
                })
                .collect();
            // deterministic merge: request order, same as the serial pass
            for seg in per_segment {
                scratch.runs.extend(seg);
            }
            return;
        }
        coalesce_runs(&scratch.routed, dist, bs, &mut scratch.runs);
    }

    /// Resolve every request into routed pieces appended to
    /// `scratch.routed` in requester order.
    ///
    /// With the `rayon` feature enabled and a server-selection policy whose
    /// per-piece choice is independent of other requesters (`Random`,
    /// `Primary`), requesters are resolved in parallel and the per-requester
    /// results are concatenated back in request order — routing, costs, and
    /// bytes are identical to the serial path by construction (enforced by
    /// the `golden` parity suite, which CI runs under both feature sets).
    /// The greedy `LeastLoaded` policy reads the running per-server byte
    /// table, so its per-piece *choice* is inherently sequential — but the
    /// per-piece *candidate set* (alive §IV-A holders, or the post-repair
    /// index fallback) is not: past the `PAR_MIN_ITEMS` workload estimate
    /// it resolves through the deterministic two-pass split
    /// ([`Dataset::resolve_least_loaded_two_pass`]), below it serially.
    fn resolve_all(
        &self,
        cluster: &Cluster,
        requests: &[LoadRequest],
        scratch: &mut LoadScratch,
    ) -> Result<()> {
        #[cfg(feature = "rayon")]
        if requests.len() > 1 {
            if !matches!(self.cfg.server_selection, ServerSelection::LeastLoaded) {
                let per_req: Vec<Result<Vec<RoutedPiece>>> = requests
                    .par_iter()
                    .enumerate()
                    .map(|(req_idx, req)| {
                        let mut routed = Vec::new();
                        let mut pieces = Vec::new();
                        let mut holders = Vec::new();
                        // Policies other than LeastLoaded never read the
                        // load table; an empty stamped table (no backing
                        // allocation) stands in for the shared one.
                        let mut unused_load = StampedLoad::default();
                        self.resolve_request(
                            cluster,
                            req,
                            req_idx,
                            &mut unused_load,
                            &mut pieces,
                            &mut holders,
                            &mut routed,
                        )?;
                        Ok(routed)
                    })
                    .collect();
                // Deterministic merge: request order; the first requester's
                // error wins, exactly as in the serial loop.
                for r in per_req {
                    scratch.routed.extend(r?);
                }
                return Ok(());
            }
            // LeastLoaded: the two-pass split pays off only when the
            // per-piece candidate work dominates the fork/join overhead —
            // estimate the piece count from the requested volume (a lower
            // bound: slice/unit splits only add pieces). Small workloads
            // stay on the single-pass serial path, which also keeps the
            // allocation-count assertions exact at test scales.
            let est_pieces: u64 = requests
                .iter()
                .map(|r| r.ranges.total_blocks() / self.dist.perm_range_blocks().max(1))
                .sum();
            if est_pieces >= PAR_MIN_ITEMS as u64 && self.dist.replicas() <= INLINE_HOLDERS {
                return self.resolve_least_loaded_two_pass(cluster, requests, scratch);
            }
        }

        for (req_idx, req) in requests.iter().enumerate() {
            self.resolve_request(
                cluster,
                req,
                req_idx,
                &mut scratch.server_load,
                &mut scratch.pieces,
                &mut scratch.holders,
                &mut scratch.routed,
            )?;
        }
        Ok(())
    }

    /// Pass 1 of the two-pass `LeastLoaded` resolution: the fixed,
    /// load-independent candidate set of one piece — the alive
    /// deterministic §IV-A holders in holder order, or (all dead) the
    /// alive post-repair index holders in index order; exactly the `alive`
    /// slice [`Dataset::pick_server`] would walk. `n_holders == 0` marks
    /// the rare oversized fallback set (> [`INLINE_HOLDERS`] repair-created
    /// replicas): pass 2 re-resolves those serially through `pick_server`.
    #[cfg(feature = "rayon")]
    fn candidate_for(
        &self,
        cluster: &Cluster,
        piece: &PermutedPiece,
        out_offset: u64,
    ) -> Result<Candidate> {
        let r = self.dist.replicas();
        let mut holders = [0u32; INLINE_HOLDERS];
        let mut n = 0usize;
        for k in 0..r {
            // same alive + holds (quarantine-aware) walk as `pick_server`
            let pe = self.cluster_rank(self.dist.holder(piece.perm_start, k));
            if cluster.is_alive(pe) && self.stores[pe].holds(piece.perm_start, piece.len) {
                holders[n] = pe as u32;
                n += 1;
            }
        }
        if n == 0 {
            let slot = self.dist.slice_of(piece.perm_start);
            let mut count = 0usize;
            for &pe in self.holder_index.holders_of(slot) {
                if cluster.is_alive(pe as usize) {
                    if count < INLINE_HOLDERS {
                        holders[count] = pe;
                    }
                    count += 1;
                }
            }
            if count == 0 {
                let orig = self.dist.unpermute_block(piece.perm_start);
                return Err(Error::IrrecoverableDataLoss {
                    dataset: self.id,
                    start: orig,
                    end: orig + piece.len,
                });
            }
            n = if count <= INLINE_HOLDERS { count } else { 0 };
        }
        Ok(Candidate { piece: *piece, out_offset, n_holders: n as u8, holders })
    }

    /// The `LeastLoaded`-compatible parallel resolution (the last ROADMAP
    /// perf lever): pass 1 resolves every requester's pieces and their
    /// alive-holder candidate sets in parallel (the per-piece load
    /// estimation inputs — liveness walks, holder arithmetic, index
    /// fallback); pass 2 replays the greedy minimum-load assignment
    /// serially in request (rank) order over the fixed candidate sets.
    /// Candidate sets do not depend on the running per-server byte table,
    /// and pass 2 performs comparisons in exactly the serial order with
    /// exactly the serial first-minimum tie-break — so the routed output
    /// is bit-identical to the single-pass serial router (pinned by the
    /// golden parity suite under CI's 3-feature matrix, including the
    /// large-scale case that crosses the threshold).
    #[cfg(feature = "rayon")]
    fn resolve_least_loaded_two_pass(
        &self,
        cluster: &Cluster,
        requests: &[LoadRequest],
        scratch: &mut LoadScratch,
    ) -> Result<()> {
        let bs = self.cfg.block_size as u64;
        // Pass 1: parallel per-requester candidate resolution.
        let per_req: Vec<Result<Vec<Candidate>>> = requests
            .par_iter()
            .map(|req| {
                let mut out: Vec<Candidate> = Vec::new();
                let mut pieces: Vec<PermutedPiece> = Vec::new();
                let mut out_offset = 0u64;
                for range in req.ranges.ranges() {
                    pieces.clear();
                    self.dist.permuted_pieces(*range, &mut pieces);
                    for piece in &pieces {
                        out.push(self.candidate_for(cluster, piece, out_offset)?);
                        out_offset += piece.len * bs;
                    }
                }
                Ok(out)
            })
            .collect();
        // Pass 2: serial greedy assignment in request order (the first
        // requester's error wins, exactly as in the serial loop).
        for (req_idx, (req, cands)) in requests.iter().zip(per_req).enumerate() {
            for cand in cands? {
                let server = if cand.n_holders == 0 {
                    // oversized post-repair fallback set: re-resolve
                    // serially (identical to the single-pass path)
                    self.pick_server(
                        cluster,
                        req.pe,
                        &cand.piece,
                        &mut scratch.server_load,
                        &mut scratch.holders,
                    )?
                } else {
                    let alive = &cand.holders[..cand.n_holders as usize];
                    // Mirrors `pick_server`: on ties the FIRST minimal
                    // holder wins.
                    let mut best = alive[0] as usize;
                    for &pe in &alive[1..] {
                        if scratch.server_load.get(pe as usize) < scratch.server_load.get(best) {
                            best = pe as usize;
                        }
                    }
                    scratch.server_load.add(best, cand.piece.len * bs);
                    best
                };
                scratch.routed.push(RoutedPiece {
                    piece: cand.piece,
                    requester: req.pe,
                    req_idx,
                    server,
                    out_offset: cand.out_offset,
                });
            }
        }
        Ok(())
    }

    /// Resolve one request: map its block ranges to permuted pieces and
    /// pick a server per piece, appending to `routed`.
    fn resolve_request(
        &self,
        cluster: &Cluster,
        req: &LoadRequest,
        req_idx: usize,
        server_load: &mut StampedLoad,
        pieces: &mut Vec<PermutedPiece>,
        holders: &mut Vec<usize>,
        routed: &mut Vec<RoutedPiece>,
    ) -> Result<()> {
        let bs = self.cfg.block_size as u64;
        let mut out_offset = 0u64;
        for range in req.ranges.ranges() {
            pieces.clear();
            self.dist.permuted_pieces(*range, pieces);
            for i in 0..pieces.len() {
                let piece = pieces[i];
                let server = self.pick_server(cluster, req.pe, &piece, server_load, holders)?;
                routed.push(RoutedPiece {
                    piece,
                    requester: req.pe,
                    req_idx,
                    server,
                    out_offset,
                });
                out_offset += piece.len * bs;
            }
        }
        Ok(())
    }

    /// Pick the serving PE for one piece among the surviving holders.
    ///
    /// The ≤ `r` deterministic §IV-A holders are walked through a
    /// fixed-size stack buffer; `holders_scratch` only backs oversized `r`
    /// and the repair fallback, so the steady state allocates nothing.
    /// `server_load` is only touched under the `LeastLoaded` policy (the
    /// parallel resolution path passes an empty table for the others).
    fn pick_server(
        &self,
        cluster: &Cluster,
        requester: usize,
        piece: &PermutedPiece,
        server_load: &mut StampedLoad,
        holders_scratch: &mut Vec<usize>,
    ) -> Result<usize> {
        let dist = &self.dist;
        let r = dist.replicas();
        let mut inline = [0usize; INLINE_HOLDERS];
        let use_inline = r <= INLINE_HOLDERS;
        if !use_inline {
            holders_scratch.clear();
        }
        let mut n_alive = 0usize;
        for k in 0..r {
            // Distribution ranks live in the (possibly rebalanced) compact
            // world; translate to cluster ranks for liveness and routing.
            // `holds` (one binary search, allocation-free) additionally
            // skips holders whose copy `Dataset::scrub` quarantined: the
            // PE is alive but its slice was removed pending repair.
            let pe = self.cluster_rank(dist.holder(piece.perm_start, k));
            if cluster.is_alive(pe) && self.stores[pe].holds(piece.perm_start, piece.len) {
                if use_inline {
                    inline[n_alive] = pe;
                } else {
                    holders_scratch.push(pe);
                }
                n_alive += 1;
            }
        }
        let alive: &[usize] = if n_alive > 0 {
            if use_inline {
                &inline[..n_alive]
            } else {
                holders_scratch.as_slice()
            }
        } else {
            // All deterministic §IV-A holders are dead — consult replicas
            // re-created by §IV-E repair through the reverse holder index
            // (slot-granular: submit and repair both place whole slices,
            // so slot membership implies the piece is held). Formerly an
            // O(p) store sweep per fallback piece.
            holders_scratch.clear();
            let slot = dist.slice_of(piece.perm_start);
            for &pe in self.holder_index.holders_of(slot) {
                let pe = pe as usize;
                if cluster.is_alive(pe) {
                    debug_assert!(self.stores[pe].holds(piece.perm_start, piece.len));
                    holders_scratch.push(pe);
                }
            }
            if holders_scratch.is_empty() {
                let orig = dist.unpermute_block(piece.perm_start);
                return Err(Error::IrrecoverableDataLoss {
                    dataset: self.id,
                    start: orig,
                    end: orig + piece.len,
                });
            }
            holders_scratch.as_slice()
        };
        let chosen = match self.cfg.server_selection {
            ServerSelection::Random => {
                // Same (requester, slice, epoch) -> same server: successive
                // blocks with the same holder set share one sender (§IV-A).
                let slice = dist.slice_of(piece.perm_start) as u64;
                let h = seeded_hash(
                    self.cfg.seed ^ cluster.epoch(),
                    ((requester as u64) << 32) ^ slice,
                );
                alive[(h % alive.len() as u64) as usize]
            }
            ServerSelection::LeastLoaded => {
                // Mirrors `Iterator::min_by_key`: on ties the FIRST minimal
                // holder wins (keeps parity with the reference router).
                let mut best = alive[0];
                for &pe in &alive[1..] {
                    if server_load.get(pe) < server_load.get(best) {
                        best = pe;
                    }
                }
                best
            }
            ServerSelection::Primary => alive[0],
        };
        if matches!(self.cfg.server_selection, ServerSelection::LeastLoaded) {
            server_load.add(chosen, piece.len * self.cfg.block_size as u64);
        }
        Ok(chosen)
    }
}

impl ReStore {
    /// Load from several datasets in ONE two-phase recovery round: the
    /// per-dataset message plans are merged so the whole operation costs a
    /// single request sparse all-to-all and a single data sparse
    /// all-to-all — one message per distinct (requester, server) pair
    /// *across all datasets*, carrying the pair's dataset-tagged runs
    /// concatenated. §IV-C's startup-overhead argument applied across
    /// datasets: bytes are identical to driving the k loads sequentially,
    /// message counts are strictly lower whenever two datasets share a
    /// requester→server pair, and the returned shards are byte-identical
    /// to the k sequential [`Dataset::load`]s (golden-pinned).
    ///
    /// `parts` lists (dataset, requests) pairs; each dataset may appear at
    /// most once (union the request sets per PE instead — see
    /// [`RangeSet::union`]). Requests are bounds-checked against each
    /// dataset's block space. Self-send semantics are unchanged: a
    /// requester serving itself exchanges no request message and pays only
    /// the local copy in the data phase, for every dataset.
    pub fn load_many(
        &mut self,
        cluster: &mut Cluster,
        parts: &[(DatasetId, Vec<LoadRequest>)],
    ) -> Result<LoadManyOutput> {
        // Scratches are detached per dataset while planning; reattach them
        // (with their grown capacity) on every exit path.
        let mut taken: Vec<(usize, LoadScratch)> = Vec::with_capacity(parts.len());
        let result = self.load_many_inner(cluster, parts, &mut taken);
        for (di, scratch) in taken {
            self.datasets[di].scratch = scratch;
        }
        result
    }

    /// Load from several datasets into ONE pooled output arena: identical
    /// two fused phases (and costs) as [`ReStore::load_many`], but the
    /// assembly stage performs a **single** `Vec<u8>` allocation covering
    /// every request of every dataset instead of one `vec![0u8; …]` per
    /// request per dataset — the shape for requester pools that recover
    /// many datasets at once and hand each shard out by slice. Bytes are
    /// identical to `load_many` span for span (golden-pinned); cost-model
    /// datasets contribute `None` spans, exactly as their `LoadedShard`
    /// bytes would be `None`.
    pub fn load_many_pooled(
        &mut self,
        cluster: &mut Cluster,
        parts: &[(DatasetId, Vec<LoadRequest>)],
    ) -> Result<PooledLoadOutput> {
        let mut taken: Vec<(usize, LoadScratch)> = Vec::with_capacity(parts.len());
        let result = self.load_many_pooled_inner(cluster, parts, &mut taken);
        for (di, scratch) in taken {
            self.datasets[di].scratch = scratch;
        }
        result
    }

    fn load_many_inner(
        &mut self,
        cluster: &mut Cluster,
        parts: &[(DatasetId, Vec<LoadRequest>)],
        taken: &mut Vec<(usize, LoadScratch)>,
    ) -> Result<LoadManyOutput> {
        let (request_cost, data_cost) = self.plan_and_charge_many(cluster, parts, taken)?;

        // --- assemble per-dataset outputs --------------------------------
        let mut out_parts: Vec<LoadManyPart> = Vec::with_capacity(parts.len());
        for ((di, scratch), (id, requests)) in taken.iter().zip(parts) {
            let ds = &self.datasets[*di];
            out_parts.push(LoadManyPart {
                dataset: *id,
                shards: ds.assemble_shards(requests, &scratch.runs)?,
            });
        }
        Ok(LoadManyOutput {
            parts: out_parts,
            request_cost,
            data_cost,
            cost: request_cost.then(data_cost),
        })
    }

    fn load_many_pooled_inner(
        &mut self,
        cluster: &mut Cluster,
        parts: &[(DatasetId, Vec<LoadRequest>)],
        taken: &mut Vec<(usize, LoadScratch)>,
    ) -> Result<PooledLoadOutput> {
        let (request_cost, data_cost) = self.plan_and_charge_many(cluster, parts, taken)?;

        // --- size the single arena across ALL datasets -------------------
        let mut out_parts: Vec<PooledPart> = Vec::with_capacity(parts.len());
        let mut total = 0usize;
        for ((di, _), (id, requests)) in taken.iter().zip(parts) {
            let ds = &self.datasets[*di];
            let bs = ds.cfg.block_size as u64;
            let execution = ds.is_execution_mode();
            let shards: Vec<PooledShard> = requests
                .iter()
                .map(|r| {
                    let span = execution.then(|| {
                        let len = (r.ranges.total_blocks() * bs) as usize;
                        let span = total..total + len;
                        total += len;
                        span
                    });
                    PooledShard { pe: r.pe, span }
                })
                .collect();
            out_parts.push(PooledPart { dataset: *id, shards });
        }

        // --- the one pooled allocation + per-dataset verified copies -----
        let mut arena = vec![0u8; total];
        for ((di, scratch), part) in taken.iter().zip(&out_parts) {
            self.datasets[*di].assemble_into_arena(&scratch.runs, &part.shards, &mut arena)?;
        }
        Ok(PooledLoadOutput {
            arena,
            parts: out_parts,
            request_cost,
            data_cost,
            cost: request_cost.then(data_cost),
        })
    }

    /// The shared front of [`ReStore::load_many`] and
    /// [`ReStore::load_many_pooled`]: validate + plan every dataset
    /// (clock-pure), then charge the two fused sparse all-to-alls.
    fn plan_and_charge_many(
        &mut self,
        cluster: &mut Cluster,
        parts: &[(DatasetId, Vec<LoadRequest>)],
        taken: &mut Vec<(usize, LoadScratch)>,
    ) -> Result<(PhaseCost, PhaseCost)> {
        // --- validate + plan every dataset (clock-pure) ------------------
        for (id, requests) in parts {
            let di = self.index_of(*id)?;
            if taken.iter().any(|(d, _)| *d == di) {
                return Err(Error::Config(format!(
                    "load_many: dataset {id} appears twice; union the request sets per PE instead"
                )));
            }
            let ds = &self.datasets[di];
            ds.ensure_submitted()?;
            ds.ensure_current_epoch(cluster)?;
            // Bounds check through the RangeSet algebra: anything outside
            // the dataset's block space is a routing error, not a panic
            // deep inside the permutation. `plan_into` backstops the same
            // condition with an O(1) check (covering direct `Dataset::load`
            // too); the subtract here buys the exact offending ranges in
            // the error on a path that already allocates its outputs.
            let space = RangeSet::new(vec![BlockRange::new(0, ds.dist.n_blocks())]);
            for req in requests {
                let oob = req.ranges.subtract(&space);
                if !oob.is_empty() {
                    return Err(Error::Config(format!(
                        "load_many: dataset {id} request for PE {} addresses blocks {:?} \
                         outside [0, {})",
                        req.pe,
                        oob.ranges(),
                        ds.dist.n_blocks()
                    )));
                }
            }
            let mut scratch = std::mem::take(&mut self.datasets[di].scratch);
            let planned = self.datasets[di].plan_into(cluster, requests, &mut scratch);
            taken.push((di, scratch));
            planned?;
        }

        // --- fused phase 1b: ONE request sparse all-to-all ---------------
        // Each dataset's runs are sorted by (requester, server, ...); a
        // k-way merge on the pair key visits every distinct pair once and
        // concatenates the datasets' descriptor payloads into one message.
        let bs: Vec<u64> =
            taken.iter().map(|(di, _)| self.datasets[*di].cfg.block_size as u64).collect();
        let mut idx: Vec<usize> = vec![0; taken.len()];
        let mut phase = cluster.phase_pooled(&mut self.fused_acc);
        loop {
            let Some((requester, server)) = next_pair(taken, &idx) else { break };
            let mut bytes = 0u64;
            for (d, (_, scratch)) in taken.iter().enumerate() {
                let runs = &scratch.runs[..];
                let mut i = idx[d];
                while i < runs.len()
                    && runs[i].requester == requester
                    && runs[i].server == server
                {
                    bytes += runs[i].pieces * REQUEST_HEADER_BYTES;
                    i += 1;
                }
                idx[d] = i;
            }
            if requester != server {
                phase.add(requester, server, bytes)?;
            }
        }
        let request_cost = phase.commit();

        // --- fused phase 2: ONE data sparse all-to-all -------------------
        // Same merge; every run still costs one pack fragment on the
        // server and one unpack fragment on the requester (self pairs:
        // local copy only, as in the single-dataset path).
        let mut idx: Vec<usize> = vec![0; taken.len()];
        let mut phase = cluster.phase_pooled(&mut self.fused_acc);
        loop {
            let Some((requester, server)) = next_pair(taken, &idx) else { break };
            let mut bytes = 0u64;
            for (d, (_, scratch)) in taken.iter().enumerate() {
                let runs = &scratch.runs[..];
                let mut i = idx[d];
                let mut n_runs = 0u64;
                while i < runs.len()
                    && runs[i].requester == requester
                    && runs[i].server == server
                {
                    bytes += runs[i].len * bs[d];
                    n_runs += 1;
                    i += 1;
                }
                idx[d] = i;
                if server != requester && n_runs > 0 {
                    phase.frag(server, n_runs);
                    phase.frag(requester, n_runs);
                }
            }
            phase.add(server, requester, bytes)?;
        }
        let data_cost = phase.commit();
        Ok((request_cost, data_cost))
    }
}

/// Smallest (requester, server) pair at or after the per-dataset cursors —
/// the k-way-merge step of the fused phases.
fn next_pair(taken: &[(usize, LoadScratch)], idx: &[usize]) -> Option<(usize, usize)> {
    let mut best: Option<(usize, usize)> = None;
    for (d, (_, scratch)) in taken.iter().enumerate() {
        if let Some(run) = scratch.runs.get(idx[d]) {
            let key = (run.requester, run.server);
            if best.map_or(true, |b| key < b) {
                best = Some(key);
            }
        }
    }
    best
}

/// The serial coalescing kernel: merge adjacent routed pieces of one
/// routed segment into maximal runs, appending to `out`. Shared by the
/// serial whole-list pass and the rayon per-request fan-out. The
/// same-slice check routes through [`Distribution::slice_of`] — with
/// balanced unequal slices a run's slice membership is no longer a fixed
/// `blocks_per_pe` division.
fn coalesce_runs(
    routed: &[RoutedPiece],
    dist: &Distribution,
    bs: u64,
    out: &mut Vec<Run>,
) {
    for rp in routed {
        if let Some(last) = out.last_mut() {
            // Same slice ⇔ the next piece starts before the run's cached
            // slice boundary (every piece lies wholly inside one slice, so
            // a contiguous successor either continues the slice or starts
            // exactly at `slice_end`).
            if last.req_idx == rp.req_idx
                && last.server == rp.server
                && last.perm_start + last.len == rp.piece.perm_start
                && rp.piece.perm_start < last.slice_end
                && last.out_offset + last.len * bs == rp.out_offset
            {
                last.len += rp.piece.len;
                last.pieces += 1;
                continue;
            }
        }
        out.push(Run {
            requester: rp.requester,
            req_idx: rp.req_idx,
            server: rp.server,
            perm_start: rp.piece.perm_start,
            len: rp.piece.len,
            pieces: 1,
            out_offset: rp.out_offset,
            slice_end: dist.slice_end(dist.slice_of(rp.piece.perm_start)),
        });
    }
}

/// Requests that redistribute the `failed` PEs' shards evenly over the
/// survivors — the *shrinking* recovery of §IV-B: survivor number `j` (in
/// survivor order) receives blocks
/// `[i·n/p + j·n/(p·(p-1)), i·n/p + (j+1)·n/(p·(p-1)))` of failed PE `i`.
///
/// "Shard of failed PE `i`" means the blocks `i` submitted — the
/// *submit-time* decomposition (`config().blocks_per_pe`), which stays
/// meaningful after a [`ReStore::rebalance`] shrank the distribution to
/// `p'` (the current `Distribution::shard_of` would then describe the new
/// world's slices, and a dead old rank `>= p'` has none).
pub fn scatter_requests(store: &ReStore, cluster: &Cluster, failed: &[usize]) -> Vec<LoadRequest> {
    let bpp0 = store.config().blocks_per_pe as u64;
    let survivors = cluster.survivors();
    let ns = survivors.len() as u64;
    if ns == 0 {
        return Vec::new();
    }
    let mut per_pe: Vec<Vec<BlockRange>> = vec![Vec::new(); survivors.len()];
    for &dead in failed {
        let shard = BlockRange::new(dead as u64 * bpp0, (dead as u64 + 1) * bpp0);
        let len = shard.len();
        for (j, ranges) in per_pe.iter_mut().enumerate() {
            let start = shard.start + (j as u64 * len) / ns;
            let end = shard.start + ((j as u64 + 1) * len) / ns;
            if start < end {
                ranges.push(BlockRange::new(start, end));
            }
        }
    }
    survivors
        .iter()
        .zip(per_pe)
        .filter(|(_, ranges)| !ranges.is_empty())
        .map(|(&pe, ranges)| LoadRequest { pe, ranges: RangeSet::new(ranges) })
        .collect()
}

/// Wrap a load-balancer output (per-PE gained range sets) into requests.
pub fn scatter_requests_for_ranges(gained: &[(usize, RangeSet)]) -> Vec<LoadRequest> {
    gained
        .iter()
        .filter(|(_, set)| !set.is_empty())
        .map(|(pe, set)| LoadRequest { pe: *pe, ranges: set.clone() })
        .collect()
}

/// Requests that send the `failed` PEs' whole shards to a single `target`
/// PE — the *substitute*-style recovery benchmarked in §VI-D.2. Shards are
/// the submit-time decomposition (see [`scatter_requests`]).
pub fn single_target_requests(
    store: &ReStore,
    failed: &[usize],
    target: usize,
) -> Vec<LoadRequest> {
    let bpp0 = store.config().blocks_per_pe as u64;
    let ranges: Vec<BlockRange> = failed
        .iter()
        .map(|&pe| BlockRange::new(pe as u64 * bpp0, (pe as u64 + 1) * bpp0))
        .collect();
    vec![LoadRequest { pe: target, ranges: RangeSet::new(ranges) }]
}

/// The paper's *load 1 % data* benchmark op (§VI-B2): the contiguous data
/// of 1 % of the PEs (starting at a random PE `i`), spread evenly over all
/// alive PEs.
pub fn load_percent_requests(
    store: &ReStore,
    cluster: &Cluster,
    percent: f64,
    start_pe: usize,
) -> Vec<LoadRequest> {
    let dist = store.distribution();
    let p = dist.world();
    let blocks = (dist.n_blocks() as f64 * percent / 100.0).round() as u64;
    let start = dist.slice_start(start_pe % p);
    let end = (start + blocks).min(dist.n_blocks());
    let survivors = cluster.survivors();
    let ns = survivors.len() as u64;
    let len = end - start;
    survivors
        .iter()
        .enumerate()
        .filter_map(|(j, &pe)| {
            let s = start + (j as u64 * len) / ns;
            let e = start + ((j as u64 + 1) * len) / ns;
            (s < e).then(|| LoadRequest {
                pe,
                ranges: RangeSet::new(vec![BlockRange::new(s, e)]),
            })
        })
        .collect()
}

/// The paper's *load all data* benchmark op (§VI-B2): all data, evenly
/// distributed, "in a way that no PE loads the same data it originally
/// submitted" — survivor `j` loads the shard-rotated region starting one
/// whole shard after its own.
pub fn load_all_requests(store: &ReStore, cluster: &Cluster) -> Vec<LoadRequest> {
    let dist = store.distribution();
    let n = dist.n_blocks();
    let survivors = cluster.survivors();
    let ns = survivors.len() as u64;
    // Rotate the even partition of [0, n) by exactly one shard: with all
    // PEs alive and equal slices, survivor j loads precisely PE j+1's
    // shard — never its own. (After a reshape to unequal slices the shift
    // is the first shard's length; the partition stays seamless.)
    let shift = dist.slice_len(0) % n;
    survivors
        .iter()
        .enumerate()
        .map(|(j, &pe)| {
            let s = (j as u64 * n) / ns;
            let e = ((j as u64 + 1) * n) / ns;
            let (rs, re) = ((s + shift) % n, (e + shift) % n);
            let ranges = if rs < re || e == s {
                vec![BlockRange::new(rs, re.max(rs))]
            } else {
                vec![BlockRange::new(rs, n), BlockRange::new(0, re)]
            };
            LoadRequest { pe, ranges: RangeSet::new(ranges) }
        })
        .collect()
}

/// Fold a set of point keys (block ids) into the minimal [`RangeSet`]:
/// sort, dedup, and coalesce consecutive keys into maximal runs. Sorts
/// `keys` in place so batch planning can reuse one scratch buffer without
/// allocating per group (the KV batched-get path, [`crate::restore::kv`]).
pub fn point_get_ranges(keys: &mut Vec<u64>) -> RangeSet {
    keys.sort_unstable();
    keys.dedup();
    let mut ranges: Vec<BlockRange> = Vec::new();
    for &k in keys.iter() {
        match ranges.last_mut() {
            Some(r) if r.end == k => r.end = k + 1,
            _ => ranges.push(BlockRange::new(k, k + 1)),
        }
    }
    RangeSet::new(ranges)
}

/// One requester's point gets as a single [`LoadRequest`]: `pe` wants
/// each block id in `keys` (sorted in place, deduplicated, adjacent keys
/// coalesced). Feeding these per-requester requests into
/// [`ReStore::load_many_pooled`] fuses a whole batch of point gets into
/// one request + one data sparse all-to-all.
pub fn point_get_requests(pe: usize, keys: &mut Vec<u64>) -> LoadRequest {
    LoadRequest { pe, ranges: point_get_ranges(keys) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RestoreConfig;

    fn setup(
        p: usize,
        bpp: usize,
        r: usize,
        s_pr: Option<usize>,
    ) -> (Cluster, ReStore, Vec<Vec<u8>>) {
        let cfg = RestoreConfig::builder(p, 8, bpp)
            .replicas(r)
            .perm_range_blocks(s_pr)
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p, 4.min(p));
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards: Vec<Vec<u8>> = (0..p)
            .map(|pe| (0..bpp * 8).map(|i| (pe * 131 + i * 7) as u8).collect())
            .collect();
        rs.submit(&mut cluster, &shards).unwrap();
        (cluster, rs, shards)
    }

    fn expected_bytes(shards: &[Vec<u8>], ranges: &RangeSet, bpp: u64) -> Vec<u8> {
        let mut out = Vec::new();
        for r in ranges.ranges() {
            for x in r.start..r.end {
                let pe = (x / bpp) as usize;
                let off = ((x % bpp) * 8) as usize;
                out.extend_from_slice(&shards[pe][off..off + 8]);
            }
        }
        out
    }

    #[test]
    fn scattered_recovery_restores_exact_bytes() {
        let (mut cluster, mut rs, shards) = setup(8, 64, 4, Some(16));
        cluster.kill(&[3]);
        let reqs = scatter_requests(&rs, &cluster, &[3]);
        assert_eq!(reqs.len(), 7);
        let total: u64 = reqs.iter().map(|r| r.ranges.total_blocks()).sum();
        assert_eq!(total, 64); // the whole lost shard
        let out = rs.load(&mut cluster, &reqs).unwrap();
        for (req, shard) in reqs.iter().zip(&out.shards) {
            assert_eq!(shard.pe, req.pe);
            assert_eq!(
                shard.bytes.as_deref().unwrap(),
                expected_bytes(&shards, &req.ranges, 64),
                "PE {}",
                req.pe
            );
        }
    }

    #[test]
    fn single_target_recovery_restores_exact_bytes() {
        let (mut cluster, mut rs, shards) = setup(8, 64, 4, None);
        cluster.kill(&[5]);
        let reqs = single_target_requests(&rs, &[5], 0);
        let out = rs.load(&mut cluster, &reqs).unwrap();
        assert_eq!(
            out.shards[0].bytes.as_deref().unwrap(),
            expected_bytes(&shards, &reqs[0].ranges, 64)
        );
    }

    #[test]
    fn load_survives_r_minus_1_failures_of_a_group() {
        let (mut cluster, mut rs, shards) = setup(8, 64, 4, Some(16));
        // group stride p/r = 2; PEs {1, 3, 5, 7} form a group. Kill 3 of 4.
        cluster.kill(&[1, 3, 5]);
        let reqs = scatter_requests(&rs, &cluster, &[1, 3, 5]);
        let out = rs.load(&mut cluster, &reqs).unwrap();
        let total: usize = out.shards.iter().map(|s| s.bytes.as_ref().unwrap().len()).sum();
        assert_eq!(total, 3 * 64 * 8);
        for (req, shard) in reqs.iter().zip(&out.shards) {
            assert_eq!(
                shard.bytes.as_deref().unwrap(),
                expected_bytes(&shards, &req.ranges, 64)
            );
        }
    }

    #[test]
    fn idl_detected_when_whole_group_dies() {
        let (mut cluster, mut rs, _) = setup(8, 64, 4, Some(16));
        cluster.kill(&[1, 3, 5, 7]); // an entire §IV-D group
        let reqs = scatter_requests(&rs, &cluster, &[1]);
        match rs.load(&mut cluster, &reqs) {
            Err(Error::IrrecoverableDataLoss { .. }) => {}
            other => panic!("expected IDL, got {other:?}"),
        }
    }

    #[test]
    fn corrupt_block_fails_load_naming_block_and_holder() {
        let (mut cluster, mut rs, _) = setup(8, 64, 4, Some(16));
        // Flip a bit in EVERY copy of original block 5, so whichever
        // holder the router picks serves corrupt bytes — detection must
        // not depend on the replica choice.
        let x = 5u64;
        let ds = &mut rs.datasets[0];
        let y = ds.dist.permute_block(x);
        for k in 0..ds.dist.replicas() {
            let pe = ds.cluster_rank(ds.dist.holder(y, k));
            assert!(ds.stores[pe].corrupt_block_bit(y, 2));
        }
        let reqs = vec![LoadRequest {
            pe: 0,
            ranges: RangeSet::new(vec![BlockRange::new(x, x + 1)]),
        }];
        match rs.load(&mut cluster, &reqs) {
            Err(Error::CorruptBlock { dataset, block, holder }) => {
                assert_eq!(dataset, DatasetId::FIRST);
                assert_eq!(block, x, "error names the ORIGINAL block id");
                assert!(cluster.is_alive(holder));
            }
            other => panic!("expected CorruptBlock, got {other:?}"),
        }
        // Loads that never touch the corrupt block still succeed — the
        // failed load mutated nothing.
        let reqs = vec![LoadRequest {
            pe: 1,
            ranges: RangeSet::new(vec![BlockRange::new(x + 1, x + 5)]),
        }];
        rs.load(&mut cluster, &reqs).unwrap();
    }

    #[test]
    fn load_before_submit_fails() {
        let cfg = RestoreConfig::builder(4, 8, 16).replicas(2).build().unwrap();
        let mut cluster = Cluster::new_execution(4, 2);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        assert!(matches!(
            rs.load(&mut cluster, &[]),
            Err(Error::NotSubmitted)
        ));
    }

    #[test]
    fn dead_requester_rejected() {
        let (mut cluster, mut rs, _) = setup(4, 16, 2, None);
        cluster.kill(&[2]);
        let reqs = vec![LoadRequest {
            pe: 2,
            ranges: RangeSet::new(vec![BlockRange::new(0, 4)]),
        }];
        assert!(matches!(rs.load(&mut cluster, &reqs), Err(Error::DeadPe(2))));
    }

    #[test]
    fn permutation_spreads_servers_for_contiguous_request() {
        // §IV-B: with permutation, a failed PE's shard is served by many
        // senders; without, by at most r (minus failures).
        let (mut c1, mut rs1, _) = setup(16, 256, 4, Some(8));
        let (mut c2, mut rs2, _) = setup(16, 256, 4, None);
        c1.kill(&[0]);
        c2.kill(&[0]);
        let r1 = scatter_requests(&rs1, &c1, &[0]);
        let r2 = scatter_requests(&rs2, &c2, &[0]);
        let o1 = rs1.load(&mut c1, &r1).unwrap();
        let o2 = rs2.load(&mut c2, &r2).unwrap();
        assert!(
            o1.data_cost.total_msgs > o2.data_cost.total_msgs,
            "perm {} !> plain {}",
            o1.data_cost.total_msgs,
            o2.data_cost.total_msgs
        );
        // ...and the permuted bottleneck volume is lower
        assert!(o1.data_cost.bottleneck_bytes <= o2.data_cost.bottleneck_bytes);
    }

    #[test]
    fn load_percent_requests_cover_expected_volume() {
        let (cluster, rs, _) = setup(16, 256, 4, Some(8));
        // 25 % of 16 PEs = 4 shards' worth of blocks
        let reqs = load_percent_requests(&rs, &cluster, 25.0, 3);
        let total: u64 = reqs.iter().map(|r| r.ranges.total_blocks()).sum();
        assert_eq!(total, 4 * 256);
    }

    #[test]
    fn load_all_covers_everything_and_avoids_own_shard() {
        let (mut cluster, mut rs, shards) = setup(8, 64, 4, None);
        let reqs = load_all_requests(&rs, &cluster);
        let total: u64 = reqs.iter().map(|r| r.ranges.total_blocks()).sum();
        assert_eq!(total, 8 * 64);
        // no PE requests its own shard
        for req in &reqs {
            let own = rs.distribution().shard_of(req.pe);
            for r in req.ranges.ranges() {
                assert!(r.intersect(&own).is_none(), "PE {} loads own data", req.pe);
            }
        }
        let out = rs.load(&mut cluster, &reqs).unwrap();
        for (req, shard) in reqs.iter().zip(&out.shards) {
            assert_eq!(
                shard.bytes.as_deref().unwrap(),
                expected_bytes(&shards, &req.ranges, 64)
            );
        }
    }

    #[test]
    fn server_selection_policies_all_recover() {
        for policy in [
            ServerSelection::Random,
            ServerSelection::LeastLoaded,
            ServerSelection::Primary,
        ] {
            let cfg = RestoreConfig::builder(8, 8, 64)
                .replicas(4)
                .perm_range_blocks(Some(16))
                .server_selection(policy)
                .build();
            let cfg = match cfg {
                Ok(c) => c,
                Err(e) => panic!("{e}"),
            };
            let mut cluster = Cluster::new_execution(8, 4);
            let mut rs = ReStore::new(cfg, &cluster).unwrap();
            let shards: Vec<Vec<u8>> =
                (0..8).map(|pe| vec![pe as u8; 64 * 8]).collect();
            rs.submit(&mut cluster, &shards).unwrap();
            cluster.kill(&[2]);
            let reqs = scatter_requests(&rs, &cluster, &[2]);
            let out = rs.load(&mut cluster, &reqs).unwrap();
            let total: usize =
                out.shards.iter().map(|s| s.bytes.as_ref().unwrap().len()).sum();
            assert_eq!(total, 64 * 8, "policy {policy:?}");
            for s in &out.shards {
                assert!(s.bytes.as_ref().unwrap().iter().all(|&b| b == 2));
            }
        }
    }

    #[test]
    fn scatter_requests_for_ranges_filters_and_maps() {
        let gained = vec![
            (3usize, RangeSet::new(vec![BlockRange::new(0, 4), BlockRange::new(10, 12)])),
            (5, RangeSet::new(vec![])), // no gained data -> no request
            (0, RangeSet::new(vec![BlockRange::new(4, 10)])),
        ];
        let reqs = scatter_requests_for_ranges(&gained);
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].pe, 3);
        assert_eq!(reqs[0].ranges.total_blocks(), 6);
        assert_eq!(
            reqs[0].ranges.ranges(),
            &[BlockRange::new(0, 4), BlockRange::new(10, 12)]
        );
        assert_eq!(reqs[1].pe, 0);
        assert_eq!(reqs[1].ranges.ranges(), &[BlockRange::new(4, 10)]);
    }

    #[test]
    fn scatter_requests_for_ranges_feeds_load() {
        let (mut cluster, mut rs, shards) = setup(8, 64, 4, Some(16));
        cluster.kill(&[3]);
        // a load balancer handed PE 0 and PE 4 halves of the lost shard
        let lost = rs.distribution().shard_of(3);
        let mid = lost.start + lost.len() / 2;
        let gained = vec![
            (0usize, RangeSet::new(vec![BlockRange::new(lost.start, mid)])),
            (4, RangeSet::new(vec![BlockRange::new(mid, lost.end)])),
        ];
        let reqs = scatter_requests_for_ranges(&gained);
        let out = rs.load(&mut cluster, &reqs).unwrap();
        for (req, shard) in reqs.iter().zip(&out.shards) {
            assert_eq!(
                shard.bytes.as_deref().unwrap(),
                expected_bytes(&shards, &req.ranges, 64)
            );
        }
    }
}

/// Golden parity suite: the optimized pipeline must be byte- and
/// cost-identical to a straightforward per-piece reference implementation
/// (fragment counts — hence simulated time — may only decrease).
#[cfg(test)]
mod golden {
    use super::*;
    use crate::config::RestoreConfig;
    use crate::restore::repair::RepairScheme;
    use crate::restore::store::SliceBuf;
    use crate::simnet::network::{Accumulator, PhaseCost};
    use std::collections::HashMap;

    struct RefLoad {
        shards: Vec<Option<Vec<u8>>>,
        request_cost: PhaseCost,
        data_cost: PhaseCost,
        /// Data bytes per (server, requester) pair — includes self-pairs.
        data_pairs: HashMap<(usize, usize), u64>,
    }

    /// The seed implementation, kept verbatim as the oracle: per-piece
    /// routing with a freshly allocated holder `Vec`, tuple-keyed hash-map
    /// message aggregation, per-piece fragments and per-piece copies.
    fn reference_load(rs: &ReStore, cluster: &Cluster, requests: &[LoadRequest]) -> RefLoad {
        struct Routed {
            piece: PermutedPiece,
            requester: usize,
            req_idx: usize,
            server: usize,
            out_offset: u64,
        }
        let dist = rs.distribution();
        let cfg = rs.config();
        let bs = cfg.block_size as u64;

        let mut routed: Vec<Routed> = Vec::new();
        let mut server_load: HashMap<usize, u64> = HashMap::new();
        let mut pieces: Vec<PermutedPiece> = Vec::new();
        for (req_idx, req) in requests.iter().enumerate() {
            assert!(cluster.is_alive(req.pe));
            let mut out_offset = 0u64;
            for range in req.ranges.ranges() {
                pieces.clear();
                dist.permuted_pieces(*range, &mut pieces);
                for piece in &pieces {
                    let mut alive: Vec<usize> = (0..dist.replicas())
                        .map(|k| dist.holder(piece.perm_start, k))
                        .filter(|&pe| cluster.is_alive(pe))
                        .collect();
                    if alive.is_empty() {
                        alive = cluster
                            .survivors()
                            .into_iter()
                            .filter(|&pe| rs.stores()[pe].holds(piece.perm_start, piece.len))
                            .collect();
                    }
                    assert!(!alive.is_empty(), "reference hit IDL");
                    let server = match cfg.server_selection {
                        ServerSelection::Random => {
                            let slice = dist.slice_of(piece.perm_start) as u64;
                            let h = seeded_hash(
                                cfg.seed ^ cluster.epoch(),
                                ((req.pe as u64) << 32) ^ slice,
                            );
                            alive[(h % alive.len() as u64) as usize]
                        }
                        ServerSelection::LeastLoaded => *alive
                            .iter()
                            .min_by_key(|&&pe| server_load.get(&pe).copied().unwrap_or(0))
                            .unwrap(),
                        ServerSelection::Primary => alive[0],
                    };
                    *server_load.entry(server).or_insert(0) += piece.len * bs;
                    routed.push(Routed {
                        piece: *piece,
                        requester: req.pe,
                        req_idx,
                        server,
                        out_offset,
                    });
                    out_offset += piece.len * bs;
                }
            }
        }

        // self-served pieces need no request message at all (see the
        // module docs on self-send semantics)
        let mut req_msgs: HashMap<(usize, usize), u64> = HashMap::new();
        for rp in &routed {
            if rp.requester != rp.server {
                *req_msgs.entry((rp.requester, rp.server)).or_insert(0) += REQUEST_HEADER_BYTES;
            }
        }
        let mut acc = Accumulator::new(cluster.network(), cluster.topology());
        for (&(s, d), &b) in &req_msgs {
            acc.msg(s, d, b);
        }
        let request_cost = acc.finish();

        let mut data_pairs: HashMap<(usize, usize), u64> = HashMap::new();
        for rp in &routed {
            *data_pairs.entry((rp.server, rp.requester)).or_insert(0) += rp.piece.len * bs;
        }
        let mut acc = Accumulator::new(cluster.network(), cluster.topology());
        for (&(s, d), &b) in &data_pairs {
            acc.msg(s, d, b);
        }
        for rp in &routed {
            if rp.server != rp.requester {
                acc.frag(rp.server, 1);
                acc.frag(rp.requester, 1);
            }
        }
        let data_cost = acc.finish();

        let execution = rs.stores().iter().any(|st| {
            st.slices().first().is_some_and(|s| matches!(s.buf, SliceBuf::Real(_)))
        });
        let mut shards: Vec<Option<Vec<u8>>> = requests
            .iter()
            .map(|r| execution.then(|| vec![0u8; (r.ranges.total_blocks() * bs) as usize]))
            .collect();
        if execution {
            for rp in &routed {
                let src = rs.stores()[rp.server]
                    .read(rp.piece.perm_start, rp.piece.len)
                    .expect("execution-mode store must hold real bytes");
                let dst = shards[rp.req_idx].as_mut().unwrap();
                let off = rp.out_offset as usize;
                dst[off..off + src.len()].copy_from_slice(src);
            }
        }

        RefLoad { shards, request_cost, data_cost, data_pairs }
    }

    fn assert_parity(rs: &mut ReStore, cluster: &mut Cluster, reqs: &[LoadRequest], tag: &str) {
        let reference = reference_load(rs, cluster, reqs);
        let out = rs.load(cluster, reqs).unwrap();
        // bytes
        for (i, (got, want)) in out.shards.iter().zip(&reference.shards).enumerate() {
            assert_eq!(got.bytes.as_deref(), want.as_deref(), "{tag}: shard {i} bytes");
        }
        // request phase: no fragments are charged, so the whole cost —
        // including simulated time — must match exactly
        assert_eq!(out.request_cost, reference.request_cost, "{tag}: request cost");
        // data phase: byte/message totals and bottlenecks identical;
        // coalescing may only reduce fragment charges, i.e. simulated time
        let (o, r) = (&out.data_cost, &reference.data_cost);
        assert_eq!(o.total_bytes, r.total_bytes, "{tag}: data total bytes");
        assert_eq!(o.bottleneck_bytes, r.bottleneck_bytes, "{tag}: data bottleneck bytes");
        assert_eq!(o.total_msgs, r.total_msgs, "{tag}: data total msgs");
        assert_eq!(o.bottleneck_msgs, r.bottleneck_msgs, "{tag}: data bottleneck msgs");
        assert!(
            o.sim_time_s <= r.sim_time_s + 1e-15,
            "{tag}: optimized data phase slower ({} > {})",
            o.sim_time_s,
            r.sim_time_s
        );
    }

    fn build(
        p: usize,
        bpp: usize,
        r: usize,
        s_pr: Option<usize>,
        policy: ServerSelection,
    ) -> (Cluster, ReStore) {
        let cfg = RestoreConfig::builder(p, 8, bpp)
            .replicas(r)
            .perm_range_blocks(s_pr)
            .server_selection(policy)
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p, 4.min(p));
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards: Vec<Vec<u8>> = (0..p)
            .map(|pe| (0..bpp * 8).map(|i| (pe * 131 + i * 7) as u8).collect())
            .collect();
        rs.submit(&mut cluster, &shards).unwrap();
        (cluster, rs)
    }

    const POLICIES: [ServerSelection; 3] = [
        ServerSelection::Random,
        ServerSelection::LeastLoaded,
        ServerSelection::Primary,
    ];

    #[test]
    fn parity_across_policies_perms_and_failures() {
        for policy in POLICIES {
            for s_pr in [Some(16), None] {
                let tag = |name: &str| format!("{policy:?}/{s_pr:?}/{name}");

                // no failures: the load-all benchmark op
                let (mut cluster, mut rs) = build(8, 64, 4, s_pr, policy);
                let reqs = load_all_requests(&rs, &cluster);
                assert_parity(&mut rs, &mut cluster, &reqs, &tag("load-all"));

                // single failure: scattered shrink-style recovery
                let (mut cluster, mut rs) = build(8, 64, 4, s_pr, policy);
                cluster.kill(&[3]);
                let reqs = scatter_requests(&rs, &cluster, &[3]);
                assert_parity(&mut rs, &mut cluster, &reqs, &tag("scatter-1"));

                // r-1 failures of one §IV-D group
                let (mut cluster, mut rs) = build(8, 64, 4, s_pr, policy);
                cluster.kill(&[1, 3, 5]);
                let reqs = scatter_requests(&rs, &cluster, &[1, 3, 5]);
                assert_parity(&mut rs, &mut cluster, &reqs, &tag("scatter-group"));

                // substitute-style recovery onto a single target
                let (mut cluster, mut rs) = build(8, 64, 4, s_pr, policy);
                cluster.kill(&[5]);
                let reqs = single_target_requests(&rs, &[5], 0);
                assert_parity(&mut rs, &mut cluster, &reqs, &tag("single-target"));
            }
        }
    }

    /// Parity at a piece count large enough to cross the `rayon`
    /// coalesce/sort thresholds (PAR_MIN_ITEMS): CI runs this identical
    /// assertion under the default, `--no-default-features`, and
    /// `--features rayon` builds — the serial-parity matrix for the
    /// parallel coalesce and run-sort stages.
    #[test]
    fn large_scale_parity_across_coalesce_and_sort() {
        for policy in [ServerSelection::Random, ServerSelection::Primary] {
            // 8 PEs x 8192 blocks, 8-block units -> a load-all resolves
            // 8192 permuted pieces, comfortably past PAR_MIN_ITEMS (4096)
            // even if some pieces coalesce before the sort
            let (mut cluster, mut rs) = build(8, 8192, 4, Some(8), policy);
            let reqs = load_all_requests(&rs, &cluster);
            assert_parity(&mut rs, &mut cluster, &reqs, &format!("{policy:?}/large-load-all"));

            // 6 lost shards over 2 survivors: ~6144 pieces, so the scatter
            // pattern crosses the thresholds too (3 dead per group of 4)
            let (mut cluster, mut rs) = build(8, 8192, 4, Some(8), policy);
            let dead = [0usize, 2, 4, 1, 3, 5];
            cluster.kill(&dead);
            let reqs = scatter_requests(&rs, &cluster, &dead);
            assert_parity(&mut rs, &mut cluster, &reqs, &format!("{policy:?}/large-scatter"));
        }
    }

    /// Parity for the two-pass `LeastLoaded` resolution at a piece count
    /// past its engagement threshold (est. pieces >= PAR_MIN_ITEMS): the
    /// parallel candidate pass + serial greedy replay must be bit-identical
    /// to the single-pass serial router (the reference oracle). CI runs
    /// this under the default, `--no-default-features`, and
    /// `--features rayon` builds — closing the ROADMAP "LeastLoaded-
    /// compatible parallel resolution" lever with the same serial-parity
    /// matrix as the other rayon stages.
    #[test]
    fn large_scale_least_loaded_two_pass_parity() {
        // 8 PEs x 8192 blocks, 8-block units -> load-all resolves ~8192
        // pieces; the volume estimate (65536 / 8 = 8192) crosses
        // PAR_MIN_ITEMS (4096), so the rayon build takes the two-pass path.
        let (mut cluster, mut rs) = build(8, 8192, 4, Some(8), ServerSelection::LeastLoaded);
        let reqs = load_all_requests(&rs, &cluster);
        assert_parity(&mut rs, &mut cluster, &reqs, "LeastLoaded/large-load-all");

        // ...and through failures (candidate sets shrink, order preserved)
        let (mut cluster, mut rs) = build(8, 8192, 4, Some(8), ServerSelection::LeastLoaded);
        let dead = [0usize, 2, 4, 1, 3, 5];
        cluster.kill(&dead);
        let reqs = scatter_requests(&rs, &cluster, &dead);
        assert_parity(&mut rs, &mut cluster, &reqs, "LeastLoaded/large-scatter");
    }

    #[test]
    fn parity_through_repair_fallback() {
        // Kill a PE, repair its replicas onto probing-sequence homes, then
        // kill the remaining deterministic holder: serving now depends on
        // the repair-created replicas (the store-scan fallback), which must
        // stay in parity too.
        for policy in POLICIES {
            for s_pr in [Some(8), None] {
                let (mut cluster, mut rs) = build(4, 32, 2, s_pr, policy);
                cluster.kill(&[2]);
                rs.repair_replicas(&mut cluster, RepairScheme::DoubleHashing).unwrap();
                cluster.kill(&[0]);
                let reqs = scatter_requests(&rs, &cluster, &[0, 2]);
                assert_parity(
                    &mut rs,
                    &mut cluster,
                    &reqs,
                    &format!("{policy:?}/{s_pr:?}/repair-fallback"),
                );
            }
        }
    }

    #[test]
    fn least_loaded_balances_scattered_recovery() {
        // Greedy LeastLoaded with many small pieces must keep the maximum
        // per-server data volume within 2x of the mean over active servers.
        let (mut cluster, mut rs) = build(16, 256, 4, Some(8), ServerSelection::LeastLoaded);
        cluster.kill(&[3, 6]);
        let reqs = scatter_requests(&rs, &cluster, &[3, 6]);
        let reference = reference_load(&rs, &cluster, &reqs);
        // parity first: the reference pair map then describes the real run
        assert_parity(&mut rs, &mut cluster, &reqs, "LeastLoaded/balance");
        let mut sent: HashMap<usize, u64> = HashMap::new();
        for (&(server, _), &bytes) in &reference.data_pairs {
            *sent.entry(server).or_insert(0) += bytes;
        }
        let max = sent.values().copied().max().unwrap();
        let mean = sent.values().copied().sum::<u64>() as f64 / sent.len() as f64;
        assert!(
            (max as f64) <= 2.0 * mean,
            "LeastLoaded imbalance: max {max} > 2x mean {mean:.1} over {} servers",
            sent.len()
        );
    }

    /// The self-send golden cost contract (see the module docs): a
    /// requester loading its own surviving slice must cost ZERO network —
    /// no request message, no data message, no fragments — and exactly one
    /// local memory-bandwidth copy of the payload in the data phase.
    #[test]
    fn self_served_load_costs_zero_network() {
        // p=4, r=2, no permutation, Primary policy: requester 0's slice
        // [0, bpp) has itself as the primary holder.
        let (mut cluster, mut rs) = build(4, 64, 2, None, ServerSelection::Primary);
        let reqs = vec![LoadRequest {
            pe: 0,
            ranges: RangeSet::new(vec![BlockRange::new(0, 64)]),
        }];
        let out = rs.load(&mut cluster, &reqs).unwrap();
        // request phase: nothing at all — self pairs are skipped entirely
        assert_eq!(out.request_cost, PhaseCost::default());
        // data phase: zero network in every counter...
        assert_eq!(out.data_cost.total_bytes, 0);
        assert_eq!(out.data_cost.total_msgs, 0);
        assert_eq!(out.data_cost.bottleneck_bytes, 0);
        assert_eq!(out.data_cost.bottleneck_msgs, 0);
        // ...but exactly the local copy of the payload on the sim clock
        let payload = 64.0 * 8.0;
        let want = payload / cluster.network().pe_mem_bw_bytes_per_s;
        assert!(
            (out.data_cost.sim_time_s - want).abs() < 1e-15,
            "data phase must charge exactly one local copy: {} vs {}",
            out.data_cost.sim_time_s,
            want
        );
        // bytes are still correct (the local copy is real)
        let want_bytes: Vec<u8> = (0..64usize * 8).map(|i| (i * 7) as u8).collect(); // PE 0 shard
        assert_eq!(out.shards[0].bytes.as_deref().unwrap(), &want_bytes[..]);
    }

    #[test]
    fn steady_state_load_reuses_scratch_capacity() {
        // After a warm-up call, repeated identical loads must not grow the
        // scratch buffers (the allocation-free steady-state contract).
        let (mut cluster, mut rs) = build(8, 64, 4, Some(16), ServerSelection::Random);
        cluster.kill(&[3]);
        let reqs = scatter_requests(&rs, &cluster, &[3]);
        rs.load(&mut cluster, &reqs).unwrap();
        let caps = |rs: &ReStore| {
            let s = &rs.datasets[0].scratch;
            (
                s.routed.capacity(),
                s.pieces.capacity(),
                s.runs.capacity(),
                s.server_load.capacity(),
                s.holders.capacity(),
                s.acc.pe_capacity(),
            )
        };
        let warm = caps(&rs);
        for _ in 0..5 {
            rs.load(&mut cluster, &reqs).unwrap();
        }
        assert_eq!(
            warm,
            caps(&rs),
            "scratch buffers grew across identical steady-state loads"
        );
    }
}
