//! Irrecoverable-data-loss analysis (§IV-D) and the Fig 3 failure
//! simulator.
//!
//! With `r | p`, the PEs fall into `g = p/r` groups that store identical
//! data; an IDL happens iff some group fails completely. This module
//! provides the paper's exact inclusion–exclusion probability, the small-f
//! approximation `g·(f/p)^r`, the expected number of failures until IDL,
//! and a Monte-Carlo simulator that kills random PEs against the *actual*
//! group structure until data is lost (what Fig 3a plots and Fig 3b
//! validates the formula against).

use crate::util::rng::Rng;

/// ln Γ(x) via the Lanczos approximation (g = 7, n = 9) — enough precision
/// for binomial ratios at any p we simulate.
pub fn ln_gamma(x: f64) -> f64 {
    const COEFFS: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_1,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection formula
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEFFS[0];
    let t = x + 7.5;
    for (i, &c) in COEFFS.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// ln C(n, k); -inf when the binomial is 0.
pub fn ln_binom(n: u64, k: u64) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    ln_gamma(n as f64 + 1.0) - ln_gamma(k as f64 + 1.0) - ln_gamma((n - k) as f64 + 1.0)
}

/// `P_IDL^<=(f)`: probability that after `f` uniformly random failures out
/// of `p` PEs (replication `r`, `r | p`), at least one complete group has
/// failed. Exact inclusion–exclusion; terms are summed until they fall
/// below relative 1e-16, which keeps it O(f/r) instead of O(g).
pub fn p_idl_leq(p: u64, r: u64, f: u64) -> f64 {
    assert!(r > 0 && p % r == 0, "requires r | p");
    let g = p / r;
    if f < r {
        return 0.0;
    }
    if f >= p {
        return 1.0; // all PEs dead: certain IDL (avoids cancellation noise)
    }
    let ln_cpf = ln_binom(p, f);
    // First inclusion–exclusion term = E[#completely-failed groups] = µ.
    // For µ >= 20 the alternating sum needs terms of size ~e^µ that cancel
    // to <= 1 — catastrophic in f64 — while P itself is 1 - O(e^-µ): we
    // return 1 with error < 1e-8 instead of cancellation noise.
    let mu = (ln_binom(g, 1) + ln_binom(p - r, f - r) - ln_cpf).exp();
    if mu >= 20.0 {
        return 1.0;
    }
    let mut sum = 0.0f64;
    let jmax = g.min(f / r);
    for j in 1..=jmax {
        let ln_term = ln_binom(g, j) + ln_binom(p - j * r, f - j * r) - ln_cpf;
        let term = ln_term.exp();
        let signed = if j % 2 == 1 { term } else { -term };
        sum += signed;
        if term < sum.abs() * 1e-16 && j > 2 {
            break;
        }
    }
    sum.clamp(0.0, 1.0)
}

/// `P_IDL^=(f) = P<=(f) − P<=(f−1)`: probability the IDL happens exactly at
/// failure `f`.
pub fn p_idl_eq(p: u64, r: u64, f: u64) -> f64 {
    if f == 0 {
        return 0.0;
    }
    (p_idl_leq(p, r, f) - p_idl_leq(p, r, f - 1)).max(0.0)
}

/// Expected number of failures until the first IDL.
pub fn expected_failures_until_idl(p: u64, r: u64) -> f64 {
    (r..=p).map(|f| p_idl_eq(p, r, f) * f as f64).sum()
}

/// The reviewer-famous small-f approximation `g · (f/p)^r` (§IV-D).
pub fn p_idl_approx(p: u64, r: u64, f: u64) -> f64 {
    let g = (p / r) as f64;
    (g * (f as f64 / p as f64).powi(r as i32)).min(1.0)
}

/// Fraction of failed PEs at which the approximation reaches 1:
/// `(r/p)^(1/r)` — the paper's `O(p^{-1/r})` scaling argument.
pub fn critical_failure_fraction(p: u64, r: u64) -> f64 {
    (r as f64 / p as f64).powf(1.0 / r as f64)
}

/// Probability that a single replica of `bytes` bytes suffers at least one
/// bit flip over a window of `interval_s` seconds at `byte_flip_rate_per_s`
/// flips per byte per second: `1 − exp(−rate · bytes · t)`. This is the
/// `q_corrupt` input to [`p_idl_with_corruption_approx`] and matches the
/// Poisson strike process of `simnet::failure::CorruptionModel`.
pub fn replica_corruption_prob(byte_flip_rate_per_s: f64, bytes: u64, interval_s: f64) -> f64 {
    assert!(byte_flip_rate_per_s >= 0.0 && interval_s >= 0.0);
    -(-byte_flip_rate_per_s * bytes as f64 * interval_s).exp_m1()
}

/// §IV-D approximation extended with silent corruption: a copy of a group's
/// data is unusable if its holder is *dead* (probability `f/p` per the
/// small-f argument) **or** alive but corrupt with the scrubber yet to
/// repair it (probability `q_corrupt`, independent per replica). Data is
/// lost only when all `r` copies are unusable, so
///
/// `P ≈ g · (f/p + (1 − f/p) · q_corrupt)^r`.
///
/// With `q_corrupt = 0` this reduces exactly to [`p_idl_approx`].
pub fn p_idl_with_corruption_approx(p: u64, r: u64, f: u64, q_corrupt: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q_corrupt), "q_corrupt must be a probability");
    let g = (p / r) as f64;
    let dead = f as f64 / p as f64;
    let unusable = dead + (1.0 - dead) * q_corrupt;
    (g * unusable.powi(r as i32)).min(1.0)
}

/// Monte-Carlo simulation of Fig 3a: kill uniformly random PEs one at a
/// time until some group of the *actual* shared-copy distribution has
/// fully failed; returns the number of failures at which the IDL occurred.
///
/// O(p) memory (a shuffled kill order + one u32 counter per group) and
/// O(1) per kill — this is what lets the bench run p = 2^25.
pub fn simulate_failures_until_idl(p: u64, r: u64, rng: &mut Rng) -> u64 {
    assert!(r > 0 && p % r == 0);
    let g = (p / r) as usize;
    let mut order: Vec<u32> = (0..p as u32).collect();
    rng.shuffle(&mut order);
    let mut dead_in_group = vec![0u32; g];
    for (killed, pe) in order.iter().enumerate() {
        let grp = (*pe as usize) % g;
        dead_in_group[grp] += 1;
        if dead_in_group[grp] == r as u32 {
            return killed as u64 + 1;
        }
    }
    p // r=1 edge case is caught on the first kill; unreachable for r<=p
}

/// Ablation (§IV-B, last paragraph): with a *distinct* permutation per
/// copy, permutation ranges are no longer co-located in fixed groups; data
/// is lost as soon as the r holders of *any* permutation range are all
/// dead. Simulates `units` permutation ranges with independent pseudorandom
/// holder sets; returns failures until first loss.
pub fn simulate_failures_until_idl_distinct(
    p: u64,
    r: u64,
    units: u64,
    rng: &mut Rng,
) -> u64 {
    use crate::restore::hashing::seeded_hash;
    let seed: u64 = rng.next_u64();
    // holder k of unit u: primary(u) offset by a per-copy pseudorandom
    // shift — mirrors "a distinct permutation for each copy".
    let holder = |u: u64, k: u64| -> u64 {
        let prim = (seeded_hash(seed ^ k, u)) % p;
        (prim + k * (p / r)) % p
    };
    // per-PE inverted index: which (unit, copy) pairs live on each PE
    let mut held: Vec<Vec<u32>> = vec![Vec::new(); p as usize];
    for u in 0..units {
        for k in 0..r {
            held[holder(u, k) as usize].push(u as u32);
        }
    }
    let mut alive_copies: Vec<u32> = vec![r as u32; units as usize];
    let mut order: Vec<u32> = (0..p as u32).collect();
    rng.shuffle(&mut order);
    for (killed, pe) in order.iter().enumerate() {
        for &u in &held[*pe as usize] {
            alive_copies[u as usize] -= 1;
            if alive_copies[u as usize] == 0 {
                return killed as u64 + 1;
            }
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_matches_factorials() {
        for n in 1..15u64 {
            let fact: f64 = (1..=n).map(|i| i as f64).product();
            assert!((ln_gamma(n as f64 + 1.0) - fact.ln()).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn ln_binom_small_values() {
        assert!((ln_binom(5, 2) - 10f64.ln()).abs() < 1e-9);
        assert!((ln_binom(10, 0)).abs() < 1e-9);
        assert_eq!(ln_binom(3, 5), f64::NEG_INFINITY);
    }

    #[test]
    fn p_idl_boundary_cases() {
        assert_eq!(p_idl_leq(16, 4, 3), 0.0); // fewer than r failures
        assert!((p_idl_leq(16, 4, 16) - 1.0).abs() < 1e-12); // all dead
        // r = 1: any failure is an IDL
        assert!((p_idl_leq(16, 1, 1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn p_idl_exact_tiny_case_by_enumeration() {
        // p=4, r=2, g=2 (groups {0,2}, {1,3}), f=2: the 6 failure pairs
        // contain exactly 2 full groups -> P = 2/6.
        let p = p_idl_leq(4, 2, 2);
        assert!((p - 2.0 / 6.0).abs() < 1e-12, "{p}");
        // f=3: any 3 of 4 PEs always contain a full group -> P = 1.
        assert!((p_idl_leq(4, 2, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn p_idl_is_monotone_in_f() {
        // tolerance 1e-9: the alternating inclusion–exclusion sum carries
        // ~1e-10 cancellation noise near P = 1 (documented in the fn docs)
        let mut last = 0.0;
        for f in 0..=48 {
            let v = p_idl_leq(48, 4, f);
            assert!(v + 1e-9 >= last, "f={f}: {v} < {last}");
            last = v;
        }
    }

    #[test]
    fn p_idl_eq_sums_to_one() {
        let total: f64 = (0..=48).map(|f| p_idl_eq(48, 4, f)).sum();
        assert!((total - 1.0).abs() < 1e-9, "{total}");
    }

    #[test]
    fn approximation_close_for_small_f() {
        // §IV-D approximation g·(f/p)^r: an overestimate whose ratio to the
        // exact value tends to 1 as f grows (with f/p still small) — the
        // regime the anonymous reviewer's remark is about. At f ~ r the
        // ratio is f^r/(f·(f-1)···(f-r+1)) > 1.
        let (p, r) = (4096, 4);
        let mut last_ratio = f64::INFINITY;
        for f in [8u64, 16, 32, 64, 128, 256] {
            let exact = p_idl_leq(p, r, f);
            let approx = p_idl_approx(p, r, f);
            assert!(approx >= exact * 0.95, "f={f}: approximation must overestimate");
            let ratio = approx / exact;
            assert!(ratio < last_ratio + 1e-9, "ratio should improve with f");
            last_ratio = ratio;
        }
        assert!(last_ratio < 1.05, "at f=256 the approximation is within 5 %: {last_ratio}");
    }

    #[test]
    fn corruption_term_reduces_to_plain_approximation_at_zero() {
        for (p, r, f) in [(4096u64, 4u64, 64u64), (256, 2, 8), (1024, 3, 33)] {
            let plain = p_idl_approx(p, r, f);
            let with_q = p_idl_with_corruption_approx(p, r, f, 0.0);
            assert!((plain - with_q).abs() < 1e-15, "p={p} r={r} f={f}");
        }
    }

    #[test]
    fn corruption_term_is_monotone_and_saturates() {
        let (p, r, f) = (4096u64, 4u64, 64u64);
        let mut last = 0.0;
        for i in 0..=20 {
            let q = i as f64 / 20.0;
            let v = p_idl_with_corruption_approx(p, r, f, q);
            assert!(v >= last, "q={q}: {v} < {last}");
            last = v;
        }
        // q = 1: every replica corrupt -> certain loss (clamped to 1).
        assert!((p_idl_with_corruption_approx(p, r, f, 1.0) - 1.0).abs() < 1e-12);
        // corruption alone (f = 0) can still lose data
        assert!(p_idl_with_corruption_approx(p, r, 0, 0.5) > 0.0);
    }

    #[test]
    fn replica_corruption_prob_behaves_like_an_exponential() {
        // zero rate, zero bytes, or zero window -> no corruption
        assert_eq!(replica_corruption_prob(0.0, 1 << 30, 1e6), 0.0);
        assert_eq!(replica_corruption_prob(1e-9, 0, 1e6), 0.0);
        assert_eq!(replica_corruption_prob(1e-9, 1 << 30, 0.0), 0.0);
        // small argument: q ~ rate*bytes*t
        let q = replica_corruption_prob(1e-18, 1 << 20, 1.0);
        let lin = 1e-18 * (1u64 << 20) as f64;
        assert!((q - lin).abs() < lin * 1e-6, "{q} vs {lin}");
        // large argument saturates at 1 and is monotone in the window
        let a = replica_corruption_prob(1e-9, 1 << 30, 1.0);
        let b = replica_corruption_prob(1e-9, 1 << 30, 100.0);
        assert!(b > a && b <= 1.0);
        assert!((replica_corruption_prob(1.0, 1 << 30, 1e9) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn simulation_matches_formula() {
        // Fig 3b: empirical CDF of failures-until-IDL vs P<=(f).
        let (p, r) = (256u64, 2u64);
        let mut rng = Rng::seed_from_u64(3);
        let runs = 4000;
        let mut results: Vec<u64> =
            (0..runs).map(|_| simulate_failures_until_idl(p, r, &mut rng)).collect();
        results.sort_unstable();
        for f in [8u64, 16, 24, 40, 64] {
            let emp = results.iter().filter(|&&x| x <= f).count() as f64 / runs as f64;
            let exact = p_idl_leq(p, r, f);
            assert!(
                (emp - exact).abs() < 0.03,
                "f={f}: empirical {emp:.4} vs exact {exact:.4}"
            );
        }
    }

    #[test]
    fn expected_failures_reasonable() {
        // r=1: first failure is always an IDL.
        assert!((expected_failures_until_idl(64, 1) - 1.0).abs() < 1e-6);
        // more replicas -> more failures tolerated
        let e2 = expected_failures_until_idl(64, 2);
        let e4 = expected_failures_until_idl(64, 4);
        assert!(e4 > e2 && e2 > 1.0, "e2={e2} e4={e4}");
    }

    #[test]
    fn critical_fraction_shrinks_with_p() {
        // §IV-D: f/p at P≈1 scales as p^{-1/r}.
        let a = critical_failure_fraction(1 << 10, 4);
        let b = critical_failure_fraction(1 << 20, 4);
        assert!(b < a);
        assert!((a / b - (1024f64).powf(0.25)).abs() < 1e-9);
    }

    #[test]
    fn distinct_permutation_loses_data_earlier() {
        // §IV-B's argument for sharing one permutation across copies: with
        // distinct permutations there are ~units·(not 1) fatal PE sets.
        let (p, r, units) = (256u64, 2u64, 2048u64);
        let mut rng = Rng::seed_from_u64(11);
        let shared: u64 =
            (0..300).map(|_| simulate_failures_until_idl(p, r, &mut rng)).sum();
        let distinct: u64 = (0..300)
            .map(|_| simulate_failures_until_idl_distinct(p, r, units, &mut rng))
            .sum();
        assert!(
            distinct < shared,
            "distinct {} should lose data earlier than shared {}",
            distinct,
            shared
        );
    }
}
