//! Fault-tolerant k-means (§VI-C, Fig 5).
//!
//! The paper's setup: each PE holds 65 536 points in 32 dimensions
//! (16 MiB), all PEs share 20 random starting centers, 500 Lloyd
//! iterations, and an expected 1 % of PEs fail during the run (discrete
//! exponential decay). On failure the survivors split the dead PEs' points
//! evenly (the shrinking strategy) by loading them from ReStore.
//!
//! Compute is the AOT-compiled Pallas kernel (`kmeans_step*` artifacts)
//! executed via PJRT. The artifact has a fixed point count `N`; PEs whose
//! working set grew past a multiple of `N` run multiple passes with the
//! final pass zero-padded — the padding's exact contribution (pad points
//! sit at the origin and all land in one known cluster) is subtracted
//! analytically, so results are bit-accurate modulo f32 summation order.
//!
//! Two run modes mirror the rest of the system: **execution** (real data,
//! real PJRT compute, small p) and **cost-model** (schedules + calibrated
//! per-iteration compute time, the paper's PE counts).

use crate::apps::{checkpoint_state, checkpoint_state_virtual, secondary_replicas, Ownership};
use crate::config::RestoreConfig;
use crate::error::{Error, Result};
use crate::restore::block::{BlockRange, RangeSet};
use crate::restore::load::scatter_requests_for_ranges;
use crate::restore::serialize::{blocks_to_f32s, f32s_to_blocks};
use crate::restore::{DatasetId, LoadRequest, ReStore};
use crate::runtime::Engine;
use crate::simnet::cluster::Cluster;
use crate::simnet::failure::ExpDecaySchedule;
use crate::simnet::ulfm;
use crate::util::rng::Rng;

/// k-means run parameters.
#[derive(Debug, Clone)]
pub struct KmeansParams {
    /// Points per PE at start (the artifact's N divides the working set
    /// into passes; paper: 65 536).
    pub points_per_pe: usize,
    /// Dimensions (paper: 32).
    pub dims: usize,
    /// Cluster count (paper: 20).
    pub k: usize,
    /// Lloyd iterations (paper: 500).
    pub iterations: usize,
    /// Expected total fraction of PEs failing during the run (paper: 1 %).
    pub failure_fraction: f64,
    pub seed: u64,
    /// Artifact names (`kmeans_step`/`kmeans_update` or `*_tiny`...).
    pub step_variant: String,
    pub update_variant: String,
}

impl KmeansParams {
    /// The paper's configuration (needs the full-size artifacts).
    pub fn paper() -> Self {
        KmeansParams {
            points_per_pe: 65536,
            dims: 32,
            k: 20,
            iterations: 500,
            failure_fraction: 0.01,
            seed: 42,
            step_variant: "kmeans_step".into(),
            update_variant: "kmeans_update".into(),
        }
    }

    /// Small configuration for tests/examples (uses `*_tiny` artifacts:
    /// N=256, D=8, K=4).
    pub fn tiny(iterations: usize) -> Self {
        KmeansParams {
            points_per_pe: 256,
            dims: 8,
            k: 4,
            iterations,
            failure_fraction: 0.0,
            seed: 42,
            step_variant: "kmeans_step_tiny".into(),
            update_variant: "kmeans_update_tiny".into(),
        }
    }
}

/// Timing/outcome report, split the way Fig 5 splits its bars.
#[derive(Debug, Clone, Default)]
pub struct KmeansReport {
    pub iterations_run: usize,
    pub failures: usize,
    pub failure_events: usize,
    pub final_inertia: f64,
    /// Simulated wall time of the whole run.
    pub sim_total_s: f64,
    /// ... of the core clustering loop (compute + allreduce) — "k-means
    /// loop" in Fig 5.
    pub sim_kmeans_loop_s: f64,
    /// ... spent in ReStore functions (submit + loads) — "Restore
    /// overhead" in Fig 5.
    pub sim_restore_s: f64,
    /// ... spent in MPI/ULFM recovery + load balancing — the rest of the
    /// "overall" bar in Fig 5.
    pub sim_mpi_recovery_s: f64,
    /// Real wall-clock seconds spent in PJRT kernel executions.
    pub wall_compute_s: f64,
    pub final_centers: Vec<f32>,
    /// Order-independent hash of the multiset of all survivors' points.
    /// Identical across runs iff recovery reproduced the data bit-exactly
    /// (k-means inertia itself is chaotic under f32 reordering).
    pub points_checksum: u64,
}

/// Per-PE working state (execution mode).
struct PeWork {
    /// Flat point coordinates, `dims`-major per point.
    points: Vec<f32>,
}

/// Generate PE `pe`'s shard: points drawn around `k` well-separated true
/// centers (mixture of Gaussians), deterministic in (seed, pe).
pub fn generate_points(seed: u64, pe: usize, n: usize, dims: usize, k: usize) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed ^ (pe as u64).wrapping_mul(0x9E37_79B9));
    let mut true_centers = vec![0f32; k * dims];
    let mut crng = Rng::seed_from_u64(seed); // shared across PEs
    for c in true_centers.iter_mut() {
        *c = crng.gen_range_f32(-8.0, 8.0);
    }
    let mut out = Vec::with_capacity(n * dims);
    for _ in 0..n {
        let c = rng.gen_index(k);
        for d in 0..dims {
            out.push(true_centers[c * dims + d] + rng.gen_range_f32(-0.5, 0.5));
        }
    }
    out
}

/// Shared random starting centers (identical on every PE, as in the paper).
pub fn starting_centers(seed: u64, k: usize, dims: usize) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed ^ 0xCE17E55);
    (0..k * dims).map(|_| rng.gen_range_f32(-8.0, 8.0)).collect()
}

/// The §V per-datatype config for the starting-centroid dataset: its own
/// `r`/`b` choice, independent of the point dataset's — the centroid
/// checkpoint is tiny, so it takes small 32 B blocks, a lower replication
/// level, and no permutation (a contiguous shard per PE).
pub fn centroid_restore_cfg(p: usize, k: usize, dims: usize) -> Result<RestoreConfig> {
    let bs = 32usize;
    let blocks = (k * dims * 4).div_ceil(bs);
    RestoreConfig::builder(p, bs, blocks)
        .replicas(secondary_replicas(p))
        .seed(0xCE17E55)
        .build()
}

/// Run fault-tolerant k-means in **execution mode**: real points, real
/// PJRT kernels, real recovery, on the (small) simulated cluster.
pub fn run_execution(
    cluster: &mut Cluster,
    engine: &mut Engine,
    restore_cfg: &RestoreConfig,
    params: &KmeansParams,
) -> Result<KmeansReport> {
    let p = cluster.world();
    let dims = params.dims;
    let n_art = engine.entry(&params.step_variant)?.args[0].shape[0];
    let bs = restore_cfg.block_size;
    let floats_per_pe = params.points_per_pe * dims;
    let bytes_per_pe = floats_per_pe * 4;
    if restore_cfg.blocks_per_pe * bs != bytes_per_pe {
        return Err(Error::Config(format!(
            "restore config holds {} B/PE but k-means needs {bytes_per_pe} B/PE",
            restore_cfg.blocks_per_pe * bs
        )));
    }
    // record alignment: the load balancer may never split a point
    let point_bytes = dims * 4;
    if bs % point_bytes != 0 && point_bytes % bs != 0 {
        return Err(Error::Config(format!(
            "block size {bs} incompatible with {point_bytes} B points"
        )));
    }
    let align = (point_bytes / bs).max(1) as u64;

    let mut report = KmeansReport::default();
    let mut rng = Rng::seed_from_u64(params.seed ^ 0xFA11);
    let schedule = ExpDecaySchedule::new(params.failure_fraction.max(0.0).min(0.999), params.iterations);

    // --- generate input + submit to ReStore --------------------------------
    let mut work: Vec<PeWork> = (0..p)
        .map(|pe| PeWork {
            points: generate_points(params.seed, pe, params.points_per_pe, dims, params.k),
        })
        .collect();
    let shards: Vec<Vec<u8>> = work.iter().map(|w| f32s_to_blocks(&w.points, bs)).collect();
    let mut store = ReStore::new(restore_cfg.clone(), cluster)?;
    let points_ds = DatasetId::FIRST;
    let t0 = cluster.now();
    let submit = store.submit(cluster, &shards)?;
    report.sim_restore_s += submit.cost.sim_time_s;
    drop(shards);

    let mut centers = starting_centers(params.seed, params.k, dims);

    // Second dataset (§V: one ReStore object per datatype): the shared
    // centroids, checkpointed with their own r/b — every PE submits the
    // identical serialization, so any survivor can re-fetch a bit-exact
    // copy after a failure (verified below). The centers evolve, so each
    // iteration resubmits them as a new version; `centroid_blocks` tracks
    // the latest *committed* serialization — exactly what loads serve.
    let centroid_cfg = centroid_restore_cfg(p, params.k, dims)?;
    let centroid_bpp = centroid_cfg.blocks_per_pe as u64;
    let mut centroid_blocks = f32s_to_blocks(&centers, centroid_cfg.block_size);
    let centroid_ds = store.create_dataset(centroid_cfg, cluster)?;
    let centroid_shards: Vec<Vec<u8>> = vec![centroid_blocks.clone(); p];
    let submit_c = store.dataset_mut(centroid_ds)?.submit(cluster, &centroid_shards)?;
    report.sim_restore_s += submit_c.cost.sim_time_s;
    drop(centroid_shards);

    let mut ownership = Ownership::identity(p, restore_cfg.blocks_per_pe as u64);

    // exact padding correction: a zero point's distance² to each center
    let pad_assign = |centers: &[f32]| -> (usize, f32) {
        let mut best = (0usize, f32::INFINITY);
        for c in 0..params.k {
            let d2: f32 = centers[c * dims..(c + 1) * dims].iter().map(|v| v * v).sum();
            if d2 < best.1 {
                best = (c, d2);
            }
        }
        best
    };

    for iter in 0..params.iterations {
        // ---- compute phase: every alive PE runs the PJRT kernel ----------
        let loop_t0 = cluster.now();
        let mut partials: Vec<Vec<f32>> = Vec::new(); // per-PE [sums|counts|inertia]
        let mut max_pe_compute = 0f64;
        for pe in cluster.survivors() {
            let w = &work[pe];
            let n_pts = w.points.len() / dims;
            let passes = n_pts.div_ceil(n_art).max(1);
            let mut sums = vec![0f32; params.k * dims];
            let mut counts = vec![0f32; params.k];
            let mut inertia = 0f32;
            let wall0 = engine.exec_seconds;
            for pass in 0..passes {
                let lo = pass * n_art * dims;
                let hi = ((pass + 1) * n_art * dims).min(w.points.len());
                let mut buf = w.points[lo..hi].to_vec();
                let pad_pts = n_art - (hi - lo) / dims;
                buf.resize(n_art * dims, 0.0);
                let out = engine.execute_f32(&params.step_variant, &[&buf, &centers])?;
                for (s, v) in sums.iter_mut().zip(&out[0]) {
                    *s += v;
                }
                for (c, v) in counts.iter_mut().zip(&out[1]) {
                    *c += v;
                }
                inertia += out[2][0];
                if pad_pts > 0 {
                    let (c0, d20) = pad_assign(&centers);
                    counts[c0] -= pad_pts as f32;
                    inertia -= pad_pts as f32 * d20;
                    // zero points add nothing to sums
                }
            }
            max_pe_compute = max_pe_compute.max(engine.exec_seconds - wall0);
            let mut flat = sums;
            flat.extend_from_slice(&counts);
            flat.push(inertia);
            partials.push(flat);
        }
        // PEs run in parallel on the real machine: charge the slowest PE.
        cluster.tick_compute(max_pe_compute);

        // ---- allreduce + center update ------------------------------------
        let refs: Vec<&[f32]> = partials.iter().map(|v| v.as_slice()).collect();
        let (reduced, _cost) = cluster.allreduce_f32(&refs)?;
        let sums = &reduced[..params.k * dims];
        let counts = &reduced[params.k * dims..params.k * dims + params.k];
        report.final_inertia = reduced[params.k * dims + params.k] as f64;
        let upd = engine.execute_f32(&params.update_variant, &[sums, counts, &centers])?;
        centers = upd.into_iter().next().unwrap();
        report.sim_kmeans_loop_s += cluster.now() - loop_t0;

        // ---- per-iteration centroid checkpoint -----------------------------
        // Resubmit the updated centers as a delta version, overlapped
        // against this iteration's (already charged) compute time; a layout
        // that can't take a resubmit — e.g. after an acknowledge-only
        // shrink — skips the checkpoint and keeps serving the last
        // committed version.
        let ck_t0 = cluster.now();
        let new_blocks = f32s_to_blocks(&centers, centroid_cfg.block_size);
        let global = new_blocks.repeat(p); // every PE's region: same bytes
        if checkpoint_state(
            store.dataset_mut(centroid_ds)?,
            cluster,
            &global,
            max_pe_compute,
        )?
        .is_some()
        {
            centroid_blocks = new_blocks;
        }
        report.sim_restore_s += cluster.now() - ck_t0;

        // ---- failure injection + recovery ---------------------------------
        let dead = schedule.sample(&mut rng, &cluster.survivors());
        let dead: Vec<usize> =
            dead.into_iter().take(cluster.n_alive().saturating_sub(1)).collect();
        if !dead.is_empty() {
            report.failures += dead.len();
            report.failure_events += 1;
            cluster.kill(&dead);

            // MPI/ULFM recovery (agree + shrink) — the non-ReStore overhead
            let mpi_t0 = cluster.now();
            let (_failed, map, _cost) = ulfm::recover(cluster);
            report.sim_mpi_recovery_s += cluster.now() - mpi_t0;

            // §IV-B shrinking recovery, fused across BOTH datasets: one
            // handshake rewrites every feasible layout (points AND
            // centroids) under the single post-shrink epoch; infeasible or
            // data-lost datasets degrade to acknowledge individually.
            let rs_t0 = cluster.now();
            store.rebalance_or_acknowledge(cluster, &map)?;

            // load balancer: deal the dead PEs' owned ranges to survivors
            let survivors = cluster.survivors();
            let gained = ownership.rebalance(&dead, &survivors, align);

            // ONE fused recovery round for both datasets: the survivors'
            // scattered point loads and the centroid re-fetch share a
            // single request all-to-all and a single data all-to-all.
            let requests: Vec<LoadRequest> = gained
                .iter()
                .map(|(pe, set)| LoadRequest { pe: *pe, ranges: set.clone() })
                .collect();
            let centroid_reqs = vec![LoadRequest {
                pe: survivors[0],
                ranges: RangeSet::new(
                    dead.iter()
                        .map(|&d| {
                            BlockRange::new(d as u64 * centroid_bpp, (d as u64 + 1) * centroid_bpp)
                        })
                        .collect(),
                ),
            }];
            let parts = [(points_ds, requests), (centroid_ds, centroid_reqs)];
            let point_shards_out = match store.load_many(cluster, &parts) {
                Ok(fused) => {
                    // the recovered centroid shards must be bit-exact
                    // copies of the latest *committed* centroid version
                    let got = fused.parts[1].shards[0].bytes.as_ref().expect("execution mode");
                    for (i, chunk) in got.chunks(centroid_blocks.len()).enumerate() {
                        assert_eq!(
                            chunk,
                            &centroid_blocks[..],
                            "recovered centroid shard {i} diverged"
                        );
                    }
                    fused.parts.into_iter().next().unwrap().shards
                }
                // The low-replication centroid dataset (r = 2) can lose
                // whole slots under heavy waves; every PE still holds the
                // centers in app memory, so degrade to a points-only load
                // — exactly what the app did before the second dataset.
                Err(Error::IrrecoverableDataLoss { dataset, .. }) if dataset == centroid_ds => {
                    store.load(cluster, &parts[0].1)?.shards
                }
                Err(e) => return Err(e),
            };
            for (req, shard) in parts[0].1.iter().zip(&point_shards_out) {
                let bytes = shard.bytes.as_ref().expect("execution mode");
                let floats = blocks_to_f32s(bytes, (req.ranges.total_blocks() as usize * bs) / 4);
                work[req.pe].points.extend_from_slice(&floats);
            }
            report.sim_restore_s += cluster.now() - rs_t0;
        }
        report.iterations_run = iter + 1;
    }

    report.sim_total_s = cluster.now() - t0;
    report.wall_compute_s = engine.exec_seconds;
    report.final_centers = centers;
    report.points_checksum = points_checksum(
        cluster.survivors().iter().map(|&pe| work[pe].points.as_slice()),
        dims,
    );
    Ok(report)
}

/// Order-independent multiset hash over points (each point hashed from its
/// coordinate bit patterns, then wrapping-summed).
pub fn points_checksum<'a>(shards: impl Iterator<Item = &'a [f32]>, dims: usize) -> u64 {
    use crate::restore::hashing::splitmix64;
    let mut acc = 0u64;
    for shard in shards {
        for point in shard.chunks(dims) {
            let mut h = 0xC0FFEE_u64;
            for v in point {
                h = splitmix64(h ^ v.to_bits() as u64);
            }
            acc = acc.wrapping_add(h);
        }
    }
    acc
}

/// Run fault-tolerant k-means in **cost-model mode** at arbitrary `p`:
/// identical control flow and communication schedules, but compute time is
/// `compute_s_per_iter` (calibrate once with [`run_execution`]) and no
/// point data is materialized.
pub fn run_cost_model(
    cluster: &mut Cluster,
    restore_cfg: &RestoreConfig,
    params: &KmeansParams,
    compute_s_per_iter: f64,
) -> Result<KmeansReport> {
    let p = cluster.world();
    let mut report = KmeansReport::default();
    let mut rng = Rng::seed_from_u64(params.seed ^ 0xFA11);
    let schedule = ExpDecaySchedule::new(params.failure_fraction.max(0.0).min(0.999), params.iterations);

    let mut store = ReStore::new(restore_cfg.clone(), cluster)?;
    let points_ds = DatasetId::FIRST;
    let t0 = cluster.now();
    let submit = store.submit_virtual(cluster)?;
    report.sim_restore_s += submit.cost.sim_time_s;
    // centroid dataset (same §V split as the execution-mode run)
    let centroid_cfg = centroid_restore_cfg(p, params.k, params.dims)?;
    let centroid_bpp = centroid_cfg.blocks_per_pe as u64;
    let centroid_ds = store.create_dataset(centroid_cfg, cluster)?;
    let submit_c = store.dataset_mut(centroid_ds)?.submit_virtual(cluster)?;
    report.sim_restore_s += submit_c.cost.sim_time_s;
    let mut ownership = Ownership::identity(p, restore_cfg.blocks_per_pe as u64);

    let reduce_bytes = ((params.k * params.dims + params.k + 1) * 4) as u64;
    for iter in 0..params.iterations {
        let loop_t0 = cluster.now();
        cluster.tick_compute(compute_s_per_iter);
        cluster.allreduce_cost_only(reduce_bytes);
        report.sim_kmeans_loop_s += cluster.now() - loop_t0;

        // per-iteration centroid checkpoint (cost model): the schedule of a
        // full-vector resubmit, overlapped against the iteration's compute
        let ck_t0 = cluster.now();
        checkpoint_state_virtual(store.dataset_mut(centroid_ds)?, cluster, compute_s_per_iter)?;
        report.sim_restore_s += cluster.now() - ck_t0;

        let dead = schedule.sample(&mut rng, &cluster.survivors());
        let dead: Vec<usize> =
            dead.into_iter().take(cluster.n_alive().saturating_sub(1)).collect();
        if !dead.is_empty() {
            report.failures += dead.len();
            report.failure_events += 1;
            cluster.kill(&dead);
            let mpi_t0 = cluster.now();
            let (_failed, map, _cost) = ulfm::recover(cluster);
            report.sim_mpi_recovery_s += cluster.now() - mpi_t0;

            let rs_t0 = cluster.now();
            store.rebalance_or_acknowledge(cluster, &map)?;
            let survivors = cluster.survivors();
            let gained = ownership.rebalance(&dead, &survivors, 1);
            let requests = scatter_requests_for_ranges(&gained);
            let centroid_reqs = vec![LoadRequest {
                pe: survivors[0],
                ranges: RangeSet::new(
                    dead.iter()
                        .map(|&d| {
                            BlockRange::new(d as u64 * centroid_bpp, (d as u64 + 1) * centroid_bpp)
                        })
                        .collect(),
                ),
            }];
            let parts = [(points_ds, requests), (centroid_ds, centroid_reqs)];
            match store.load_many(cluster, &parts) {
                Ok(_) => {}
                // lost centroid slots: degrade to a points-only load (see
                // the execution-mode run)
                Err(Error::IrrecoverableDataLoss { dataset, .. }) if dataset == centroid_ds => {
                    store.load(cluster, &parts[0].1)?;
                }
                Err(e) => return Err(e),
            }
            report.sim_restore_s += cluster.now() - rs_t0;
        }
        report.iterations_run = iter + 1;
    }
    report.sim_total_s = cluster.now() - t0;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_points_deterministic_and_shaped() {
        let a = generate_points(1, 3, 128, 8, 4);
        let b = generate_points(1, 3, 128, 8, 4);
        assert_eq!(a, b);
        assert_eq!(a.len(), 128 * 8);
        let c = generate_points(1, 4, 128, 8, 4);
        assert_ne!(a, c);
    }

    #[test]
    fn starting_centers_shared() {
        assert_eq!(starting_centers(9, 4, 8), starting_centers(9, 4, 8));
    }

    #[test]
    fn cost_model_run_with_failures_completes() {
        let mut cluster = Cluster::new_execution(48, 48);
        let cfg = RestoreConfig::builder(48, 64, 4096)
            .replicas(4)
            .perm_range_bytes(Some(16 * 1024))
            .build()
            .unwrap();
        let mut params = KmeansParams::tiny(50);
        params.failure_fraction = 0.1;
        params.seed = 7;
        let rep = run_cost_model(&mut cluster, &cfg, &params, 1e-3).unwrap();
        assert_eq!(rep.iterations_run, 50);
        assert!(rep.sim_total_s > 50.0 * 1e-3);
        assert!(rep.sim_restore_s > 0.0);
        if rep.failures > 0 {
            assert!(rep.sim_mpi_recovery_s > 0.0);
        }
    }

    // Execution-mode tests live in rust/tests/integration_apps.rs (need
    // artifacts).
}
