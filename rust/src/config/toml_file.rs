//! TOML experiment files: a complete description of a run (cluster +
//! ReStore + app parameters) loadable by the `restore` CLI launcher.
//! Parsed with the in-tree TOML subset parser (`util::toml`).

use crate::config::{NetworkConfig, PfsConfig, RestoreConfig, ServerSelection};
use crate::error::{Error, Result};
use crate::util::toml::{escape_str, TomlDoc};

/// App selector for the launcher.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AppKind {
    Kmeans,
    Raxml,
    Pagerank,
}

impl AppKind {
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "kmeans" => Ok(AppKind::Kmeans),
            "raxml" => Ok(AppKind::Raxml),
            "pagerank" => Ok(AppKind::Pagerank),
            other => Err(Error::Config(format!("unknown app kind '{other}'"))),
        }
    }

    fn as_str(&self) -> &'static str {
        match self {
            AppKind::Kmeans => "kmeans",
            AppKind::Raxml => "raxml",
            AppKind::Pagerank => "pagerank",
        }
    }
}

/// App-level knobs shared by the launchable applications.
#[derive(Debug, Clone)]
pub struct AppConfig {
    pub kind: AppKind,
    /// Iterations (k-means/pagerank) or likelihood evaluations (raxml).
    pub iterations: usize,
    /// Expected fraction of PEs failing over the run (§VI-C uses 1 %),
    /// injected with the paper's discrete exponential decay schedule.
    pub failure_fraction: f64,
    /// RNG seed for data generation and the failure schedule.
    pub seed: u64,
}

impl Default for AppConfig {
    fn default() -> Self {
        AppConfig { kind: AppKind::Kmeans, iterations: 500, failure_fraction: 0.01, seed: 42 }
    }
}

/// A full experiment description (what a SLURM job file is to the paper).
#[derive(Debug, Clone)]
pub struct ExperimentFile {
    /// World size `p`.
    pub world: usize,
    /// PEs per node (failure domains / NIC sharing).
    pub pes_per_node: usize,
    pub restore: RestoreConfig,
    pub network: NetworkConfig,
    pub pfs: PfsConfig,
    pub app: AppConfig,
}

impl ExperimentFile {
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text).map_err(|e| match e {
            Error::Config(m) => Error::Config(format!("{path}: {m}")),
            Error::Parse(m) => Error::Parse(format!("{path}: {m}")),
            other => other,
        })
    }

    pub fn parse(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let world = doc
            .get_usize("world")
            .ok_or_else(|| Error::Config("missing 'world'".into()))?;
        let pes_per_node =
            doc.get_usize("pes_per_node").unwrap_or(crate::config::DEFAULT_PES_PER_NODE);

        // [restore]
        let block_size =
            doc.get_usize("restore.block_size").unwrap_or(crate::config::DEFAULT_BLOCK_SIZE);
        let blocks_per_pe = doc.get_usize("restore.blocks_per_pe").unwrap_or(
            crate::config::DEFAULT_BYTES_PER_PE / crate::config::DEFAULT_BLOCK_SIZE,
        );
        let mut b = RestoreConfig::builder(world, block_size, blocks_per_pe)
            .replicas(doc.get_usize("restore.replicas").unwrap_or(crate::config::DEFAULT_REPLICAS))
            .seed(doc.get_usize("restore.seed").unwrap_or(0x5e5705e) as u64);
        if let Some(bytes) = doc.get_usize("restore.perm_range_bytes") {
            b = b.perm_range_bytes(Some(bytes));
        } else if doc.get_bool("restore.permutation") == Some(true) {
            b = b.perm_range_bytes(Some(crate::config::DEFAULT_PERM_RANGE_BYTES));
        }
        if let Some(sel) = doc.get_str("restore.server_selection") {
            b = b.server_selection(match sel {
                "random" => ServerSelection::Random,
                "least_loaded" => ServerSelection::LeastLoaded,
                "primary" => ServerSelection::Primary,
                other => {
                    return Err(Error::Config(format!("unknown server_selection '{other}'")))
                }
            });
        }
        let restore = b.build()?;

        // [network]
        let mut network = NetworkConfig { pes_per_node, ..NetworkConfig::default() };
        if let Some(v) = doc.get_f64("network.alpha_s") {
            network.alpha_s = v;
        }
        if let Some(v) = doc.get_f64("network.node_bw_bytes_per_s") {
            network.node_bw_bytes_per_s = v;
        }
        if let Some(v) = doc.get_f64("network.pe_mem_bw_bytes_per_s") {
            network.pe_mem_bw_bytes_per_s = v;
        }

        // [pfs]
        let mut pfs = PfsConfig::default();
        if let Some(v) = doc.get_f64("pfs.aggregate_bw_bytes_per_s") {
            pfs.aggregate_bw_bytes_per_s = v;
        }
        if let Some(v) = doc.get_f64("pfs.per_client_bw_bytes_per_s") {
            pfs.per_client_bw_bytes_per_s = v;
        }
        if let Some(v) = doc.get_f64("pfs.open_latency_s") {
            pfs.open_latency_s = v;
        }
        if let Some(v) = doc.get_usize("pfs.osts") {
            pfs.osts = v;
        }

        // [app]
        let mut app = AppConfig::default();
        if let Some(kind) = doc.get_str("app.kind") {
            app.kind = AppKind::from_str(kind)?;
        }
        if let Some(v) = doc.get_usize("app.iterations") {
            app.iterations = v;
        }
        if let Some(v) = doc.get_f64("app.failure_fraction") {
            app.failure_fraction = v;
        }
        if let Some(v) = doc.get_usize("app.seed") {
            app.seed = v as u64;
        }

        Ok(ExperimentFile { world, pes_per_node, restore, network, pfs, app })
    }

    /// Serialize back to TOML (used to generate example experiment files).
    pub fn to_toml(&self) -> String {
        let r = &self.restore;
        let mut out = String::new();
        out.push_str(&format!("world = {}\npes_per_node = {}\n\n", self.world, self.pes_per_node));
        out.push_str("[restore]\n");
        out.push_str(&format!("block_size = {}\n", r.block_size));
        out.push_str(&format!("blocks_per_pe = {}\n", r.blocks_per_pe));
        out.push_str(&format!("replicas = {}\n", r.replicas));
        if let Some(s) = r.perm_range_blocks {
            out.push_str(&format!("perm_range_bytes = {}\n", s * r.block_size));
        }
        out.push_str(&format!("seed = {}\n", r.seed));
        out.push_str(&format!(
            "server_selection = {}\n\n",
            escape_str(match r.server_selection {
                ServerSelection::Random => "random",
                ServerSelection::LeastLoaded => "least_loaded",
                ServerSelection::Primary => "primary",
            })
        ));
        out.push_str("[network]\n");
        out.push_str(&format!("alpha_s = {}\n", self.network.alpha_s));
        out.push_str(&format!("node_bw_bytes_per_s = {}\n\n", self.network.node_bw_bytes_per_s));
        out.push_str("[app]\n");
        out.push_str(&format!("kind = {}\n", escape_str(self.app.kind.as_str())));
        out.push_str(&format!("iterations = {}\n", self.app.iterations));
        out.push_str(&format!("failure_fraction = {}\n", self.app.failure_fraction));
        out.push_str(&format!("seed = {}\n", self.app.seed));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ExperimentFile {
        ExperimentFile {
            world: 48,
            pes_per_node: 48,
            restore: RestoreConfig::paper_default(48).unwrap(),
            network: NetworkConfig::default(),
            pfs: PfsConfig::default(),
            app: AppConfig::default(),
        }
    }

    #[test]
    fn roundtrips_through_toml() {
        let f = sample();
        let back = ExperimentFile::parse(&f.to_toml()).unwrap();
        assert_eq!(back.world, 48);
        assert_eq!(back.restore.blocks_per_pe, f.restore.blocks_per_pe);
        assert_eq!(back.restore.perm_range_blocks, f.restore.perm_range_blocks);
        assert_eq!(back.app.iterations, 500);
        assert_eq!(back.app.kind, AppKind::Kmeans);
    }

    #[test]
    fn minimal_file_gets_paper_defaults() {
        let f = ExperimentFile::parse("world = 96").unwrap();
        assert_eq!(f.restore.block_size, 64);
        assert_eq!(f.restore.replicas, 4);
        assert_eq!(f.restore.perm_range_blocks, None); // off unless asked
        assert_eq!(f.pes_per_node, 48);
    }

    #[test]
    fn permutation_flag_enables_paper_default_range() {
        let f = ExperimentFile::parse("world = 48\n[restore]\npermutation = true").unwrap();
        assert_eq!(f.restore.perm_range_blocks, Some(256 * 1024 / 64));
    }

    #[test]
    fn invalid_app_kind_rejected() {
        let err = ExperimentFile::parse("world = 4\n[app]\nkind = \"tetris\"").unwrap_err();
        assert!(format!("{err}").contains("tetris"));
    }

    #[test]
    fn invalid_restore_config_rejected() {
        // replicas must divide world
        assert!(ExperimentFile::parse("world = 10\n[restore]\nreplicas = 4").is_err());
    }
}
