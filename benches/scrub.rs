//! Integrity-scrub benchmark (EXPERIMENTS.md §Integrity).
//!
//! Two questions, two sections:
//!
//! * **How fast does the scrubber verify resident data?** Execution mode
//!   at p = 256 with real bytes: a full cursor wrap cross-checks every
//!   alive copy of every slot against its latched checksum. Reported as
//!   `scrub throughput-blocks-per-s` (blocks verified per wall second) and
//!   the wall time of one wrap — this bounds the detection latency a given
//!   scrub budget buys (scan period = resident blocks / throughput).
//!
//! * **What does the repair phase cost at production scale?** Cost-model
//!   mode at p = 1536 and p = 24576 (paper's largest configuration): a
//!   handful of holders lose one replica each and the §IV-E
//!   probing-sequence repair round — the same `plan_repair`/
//!   `charge_repair_plans`/`apply_repair` machinery a scrub quarantine
//!   triggers — re-creates them. Reported as simulated nanoseconds and
//!   migrated bytes per repair round.
//!
//! With `BENCH_SHORT=1` the p = 24576 configuration is skipped and the
//! repetition count is cut (the CI schema smoke — see `make
//! bench-json-short`). Emits `BENCH_scrub.json` in the
//! `{name, ns_per_iter}` artifact schema (the name states the unit).

use std::time::Instant;

use restore::config::RestoreConfig;
use restore::restore::repair::RepairScheme;
use restore::restore::ReStore;
use restore::simnet::cluster::Cluster;
use restore::util::bench::{black_box, short_mode, write_json_artifact, BenchResult};

const PPN: usize = 48;

/// Execution-mode scrub throughput: p PEs, real bytes, full cursor wrap.
fn scrub_throughput(results: &mut Vec<BenchResult>) {
    const P: usize = 256;
    const BPP: usize = 256;
    const BS: usize = 64;
    const R: usize = 4;
    let reps = if short_mode() { 3 } else { 10 };

    let cfg = RestoreConfig::builder(P, BS, BPP).replicas(R).build().unwrap();
    let mut cluster = Cluster::new_execution(P, 32);
    let mut store = ReStore::new(cfg, &cluster).unwrap();
    let shards: Vec<Vec<u8>> = (0..P)
        .map(|pe| (0..BPP * BS).map(|i| (pe * 37 + i * 11) as u8).collect())
        .collect();
    store.submit(&mut cluster, &shards).unwrap();

    // warmup + timed full wraps over a clean store (the steady-state case:
    // scrubbing is overwhelmingly reads-that-pass)
    let mut scanned = 0u64;
    let mut wall = 0.0f64;
    for rep in 0..reps + 1 {
        let t0 = Instant::now();
        let report = store.scrub(&mut cluster, u64::MAX).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        assert!(report.wrapped && report.corrupt_blocks == 0);
        if rep > 0 {
            scanned += report.scanned_blocks;
            wall += dt;
        }
        black_box(report.scanned_blocks);
    }
    let blocks_per_s = scanned as f64 / wall;
    let per_wrap = scanned / reps as u64;
    println!(
        "scrub p={P}: {per_wrap} blocks/wrap ({BS} B each), {:.1} Mblocks/s, \
         {:.2} ms per full wrap",
        blocks_per_s / 1e6,
        wall / reps as f64 * 1e3,
    );
    results.push(BenchResult::from_value(
        &format!("scrub throughput-blocks-per-s p={P}"),
        blocks_per_s,
    ));
    results.push(BenchResult::from_value(
        &format!("scrub full-wrap wall p={P}"),
        wall / reps as f64 * 1e9,
    ));
}

/// Cost-model repair phase at scale: what a scrub quarantine's §IV-E
/// repair round costs when the dataset spans p PEs.
fn repair_cost_at(p: usize, results: &mut Vec<BenchResult>) {
    let cfg = RestoreConfig::paper_default(p).unwrap();
    let mut cluster = Cluster::with_spares(p, PPN, 0);
    let mut store = ReStore::new(cfg, &cluster).unwrap();
    store.submit_virtual(&mut cluster).unwrap();
    let r = store.distribution().replicas();

    // Lose one replica each from a few holders: killing `kills` *adjacent*
    // ranks takes at most one of any slot's r stride-spaced copies, so
    // every slice keeps a survivor to repair from — the exact situation a
    // scrub quarantine leaves behind.
    let kills: Vec<usize> = (0..r.min(4)).collect();
    cluster.kill(&kills);
    let wall0 = Instant::now();
    let rep = store.repair_replicas(&mut cluster, RepairScheme::DoubleHashing).unwrap();
    let wall = wall0.elapsed().as_secs_f64();
    assert!(rep.transfers > 0 && rep.unrepairable == 0);

    let tag = format!("p={p}");
    println!(
        "repair {tag}: {} transfers for {} lost holders -> sim {:.2} ms, \
         {:.1} MiB migrated, wall {:.1} ms",
        rep.transfers,
        kills.len(),
        rep.cost.sim_time_s * 1e3,
        rep.cost.total_bytes as f64 / (1u64 << 20) as f64,
        wall * 1e3,
    );
    results.push(BenchResult::from_value(
        &format!("scrub repair-sim-ns {tag}"),
        rep.cost.sim_time_s * 1e9,
    ));
    results.push(BenchResult::from_value(
        &format!("scrub repair-migrated-bytes {tag}"),
        rep.cost.total_bytes as f64,
    ));
    results.push(BenchResult::from_value(&format!("scrub repair-wall {tag}"), wall * 1e9));
}

fn main() {
    println!("=== integrity-scrub benchmarks ===\n");
    let mut results: Vec<BenchResult> = Vec::new();
    scrub_throughput(&mut results);
    let scales: &[usize] = &[1536, 24576];
    let scales = if short_mode() { &scales[..1] } else { scales };
    for &p in scales {
        repair_cost_at(p, &mut results);
    }
    write_json_artifact("BENCH_scrub.json", &results).expect("write BENCH_scrub.json");
    println!("\nwrote BENCH_scrub.json ({} entries)", results.len());
}
