#!/usr/bin/env python3
"""Render the EXPERIMENTS.md measured tables from BENCH_*.json files.

The bench binaries (and CI's bench smoke steps) emit one JSON object per
line: {"name": ..., "ns_per_iter": ...}. Entries named `... wall/sim-ns/
migrated-bytes/idl-prob/throughput-frac ...` carry those raw metrics in
the ns_per_iter field (see util::bench::BenchResult::from_value). This
script merges any number of such files into a markdown table, ready to
paste into (or diff against) EXPERIMENTS.md:

    python3 tools/perf_table.py BENCH_hotpath.json BENCH_load_scale.json \
        BENCH_rebalance.json

CI's "render perf table" step runs the plain form and ships the rendered
table as PERF_TABLE.md inside the bench-json artifact (a CI job cannot
commit back to the repo). To land the numbers in the tree, download that
artifact and run with --update EXPERIMENTS.md: it rewrites the block
between the `<!-- perf-table:begin -->` / `<!-- perf-table:end -->`
markers in place. A different marked block can be targeted with
--marker: `--marker policy-table` rewrites the
`<!-- policy-table:begin/end -->` block (EXPERIMENTS.md §Policies, fed
from BENCH_policies.json).
"""

import argparse
import json
import sys


def fmt(name: str, value: float) -> str:
    if "migrated-bytes" in name:
        return f"{value / 2**30:.2f} GiB"
    if "-bytes" in name:
        # byte counters with a wide dynamic range (e.g. full vs delta
        # checkpoint sizes in BENCH_checkpoint.json): pick a unit
        for unit, scale in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
            if value >= scale:
                return f"{value / scale:.2f} {unit}"
        return f"{value:.0f} B"
    if "idl-prob" in name:
        return f"{value:.2e}"
    if "-frac" in name:
        return f"{value:.4f}"
    if "-count" in name:
        # message/event counters (e.g. the kv bench's batched-msgs-count
        # and zero-ok stale-serves-count rows): integers, never durations
        return f"{value:,.0f}"
    if "-per-s" in name:
        # rates (e.g. scrub throughput-blocks-per-s) ride the field raw
        return f"{value / 1e6:.1f} M/s" if value >= 1e6 else f"{value:,.0f}/s"
    if "touched" in name:
        # sparse-accumulator touched-entry counters (BENCH_million.json)
        return f"{value:,.0f} entries"
    # everything else is nanoseconds (wall, sim-ns, or ns_per_iter proper)
    if value >= 1e9:
        return f"{value / 1e9:.2f} s"
    if value >= 1e6:
        return f"{value / 1e6:.2f} ms"
    if value >= 1e3:
        return f"{value / 1e3:.2f} µs"
    return f"{value:.0f} ns"


def load(paths):
    rows = []
    for path in paths:
        try:
            with open(path, encoding="utf-8") as fh:
                for line in fh:
                    line = line.strip()
                    if not line:
                        continue
                    obj = json.loads(line)
                    rows.append((obj["name"], float(obj["ns_per_iter"]), path))
        except FileNotFoundError:
            print(f"warning: {path} not found (skipped)", file=sys.stderr)
    return rows


def merge_percentiles(rows):
    """Fold ` p50 ` / ` p99 ` row pairs into one `p50/p99` row.

    The latency benches emit percentile pairs as separate JSON entries
    (the artifact schema is strictly one scalar per line); the rendered
    table reads better with both on one row. Rows whose names differ only
    by the percentile token are merged in place — the p50 row's position
    is kept, the p99 row is dropped — with the combined value rendered as
    `fmt(p50) / fmt(p99)`. Unpaired percentile rows pass through as-is.
    """
    merged = []
    pending = {}  # base name -> index into merged (the p50 row)
    for name, value, path in rows:
        if " p50 " in name:
            pending[name.replace(" p50 ", " ", 1)] = len(merged)
            merged.append((name, fmt(name, value), path))
        elif " p99 " in name:
            base = name.replace(" p99 ", " ", 1)
            if base in pending:
                i = pending.pop(base)
                p50_name, p50_text, p50_path = merged[i]
                merged[i] = (
                    p50_name.replace(" p50 ", " p50/p99 ", 1),
                    f"{p50_text} / {fmt(name, value)}",
                    p50_path,
                )
            else:
                merged.append((name, fmt(name, value), path))
        else:
            merged.append((name, fmt(name, value), path))
    return merged


def render(rows) -> str:
    out = ["| bench | measured | source |", "|---|---|---|"]
    for name, text, path in merge_percentiles(rows):
        out.append(f"| `{name}` | {text} | {path} |")
    return "\n".join(out)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("json_files", nargs="+")
    ap.add_argument("--update", metavar="MARKDOWN", help="rewrite the marked block in this file")
    ap.add_argument(
        "--marker",
        default="perf-table",
        help="marker name bounding the block --update rewrites (default: perf-table)",
    )
    args = ap.parse_args()
    table = render(load(args.json_files))
    if not args.update:
        print(table)
        return 0
    begin, end = f"<!-- {args.marker}:begin -->", f"<!-- {args.marker}:end -->"
    with open(args.update, encoding="utf-8") as fh:
        text = fh.read()
    if begin not in text or end not in text:
        print(f"error: {args.update} lacks {begin}/{end} markers", file=sys.stderr)
        return 1
    head, rest = text.split(begin, 1)
    _, tail = rest.split(end, 1)
    with open(args.update, "w", encoding="utf-8") as fh:
        fh.write(f"{head}{begin}\n{table}\n{end}{tail}")
    print(f"updated {args.update}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
