//! The ReStore library core (§IV + §V of the paper).
//!
//! * [`block`] — block IDs, ranges, range sets (with the set algebra the
//!   multi-dataset request router uses).
//! * [`distribution`] — the placement function `L(x,k)` with permutation
//!   ranges and the precomputed unit→slot placement index shared by
//!   submit, load, and repair.
//! * [`permutation`] — Feistel range permutation (and identity).
//! * [`registry`] — the multi-dataset registry: [`Dataset`] (one per
//!   datatype, each with independent `n`/`r`/`b`/seed, §V) and the
//!   [`DatasetId`] key; [`ReStore`] owns a `Vec<Dataset>` and keeps the
//!   historical single-dataset API as a facade over dataset 0.
//! * [`store`] — per-PE in-memory replica storage.
//! * [`submit`] — the initial checkpoint creation path (version 1).
//! * [`resubmit`] — the mutable-dataset write path: versioned
//!   [`Dataset::resubmit`] (full / dirty-range / checksum-delta) with
//!   double-buffered staging, GASPI-style compute overlap, and an
//!   epoch-tagged atomic commit that aborts to the previous committed
//!   version on any mid-flight failure; plus the shape-changing
//!   [`Dataset::resubmit_reshaped`] and [`ReStore::delete_dataset`].
//! * [`load`] — the recovery path (request resolution + sparse all-to-all),
//!   the fused cross-dataset [`ReStore::load_many`], plus the
//!   request-pattern helpers for the paper's three benchmark operations.
//! * [`idl`] — §IV-D irrecoverable-data-loss probabilities (exact
//!   inclusion–exclusion, the small-f approximation, and the Monte-Carlo
//!   failure simulator behind Fig 3).
//! * [`integrity`] — incremental checksum scrubbing: [`Dataset::scrub`]
//!   walks a persistent cursor over the resident replicas, quarantines
//!   copies that fail verification, and heals them through the §IV-E
//!   repair machinery.
//! * [`kv`] — the KV serving front-end over the registry: point gets
//!   through the load router, [`KvBatch`] fusing many gets (across
//!   datasets) into one request + one data sparse all-to-all, a bounded
//!   per-PE read cache with O(1) stamp invalidation, and point writes /
//!   range scans riding the resubmit and load paths.
//! * [`rebalance`] — §IV-B layout migration: rewrite the layout over the
//!   `p'`-member communicator after any `ulfm` reshape (shrink,
//!   substitute, or grow) with a minimal migration schedule, under a
//!   bumped communicator epoch — fused across every feasible dataset by
//!   [`ReStore::rebalance_or_acknowledge`].
//! * [`policy`] — the recovery-policy subsystem: [`RecoveryPolicy`]
//!   drives the full agree → {shrink | substitute | grow} → reshape
//!   handshake under the [`policy::Shrink`], [`policy::Substitute`], and
//!   [`policy::ShrinkThenRegrow`] strategies, with per-policy fallback.
//! * [`repair`] — §IV-E replica re-creation after failures (Appendix
//!   Distributions A and B), fused across datasets by
//!   [`ReStore::repair_replicas_all`].
//! * [`serialize`] — typed helpers to move `f32`/`u64` app data in and out
//!   of block payloads.

pub mod block;
pub mod distribution;
pub mod hashing;
pub mod idl;
pub mod integrity;
pub mod kv;
pub mod load;
pub mod permutation;
pub mod policy;
pub mod rebalance;
pub mod registry;
pub mod repair;
pub mod resubmit;
pub mod serialize;
pub mod store;
pub mod submit;

use crate::config::RestoreConfig;
use crate::error::{Error, Result};
use crate::simnet::cluster::Cluster;
use crate::simnet::network::{Accumulator, PhaseCost};
use crate::simnet::ulfm::RankMap;

use block::RangeSet;
use distribution::Distribution;
use rebalance::{charge_reshape_plans, RebalanceReport, ReshapePlan};
use repair::{charge_repair_plans, RepairPlan, RepairReport, RepairScheme};
use store::{HolderIndex, PeStore};

pub use integrity::{ScrubReport, SCRUB_REPAIR_SCHEME};
pub use kv::{
    KvBatch, KvBatchGet, KvBatchOutput, KvBytes, KvCacheAudit, KvGet, KvScan, KvStats, KvStore,
    Zipf,
};
pub use policy::{
    RecoveryAction, RecoveryOutcome, RecoveryPolicy, RecoveryStep, MAX_RECOVERY_ATTEMPTS,
};
pub use registry::{
    Dataset, DatasetId, LoadManyOutput, LoadManyPart, PooledLoadOutput, PooledPart, PooledShard,
};
pub use resubmit::{Overlap, ResubmitMode, ResubmitReport, ResubmitStep};

/// A per-PE load request: the *original* block ID ranges this PE wants.
/// (The paper's preferred API mode: "providing exactly those ID ranges each
/// individual PE needs on exactly that PE", §V.)
#[derive(Debug, Clone)]
pub struct LoadRequest {
    pub pe: usize,
    pub ranges: RangeSet,
}

/// Data loaded for one requesting PE, in request order.
#[derive(Debug, Clone)]
pub struct LoadedShard {
    pub pe: usize,
    /// `Some(bytes)` in execution mode, `None` in cost-model mode.
    pub bytes: Option<Vec<u8>>,
}

/// Result of a [`Dataset::load`].
#[derive(Debug, Clone)]
pub struct LoadOutput {
    pub shards: Vec<LoadedShard>,
    /// Cost of the request sparse all-to-all (phase 1).
    pub request_cost: PhaseCost,
    /// Cost of the data sparse all-to-all (phase 2).
    pub data_cost: PhaseCost,
    /// Total (= request + data).
    pub cost: PhaseCost,
}

/// Result of a [`Dataset::submit`].
#[derive(Debug, Clone)]
pub struct SubmitReport {
    pub cost: PhaseCost,
}

/// Step boundaries of the fused §IV-B reshape handshake
/// ([`ReStore::rebalance_or_acknowledge_all_with_faults`]) at which a
/// fault can be injected. Ordered as the handshake executes; the map is
/// re-validated after every boundary, so a kill at any of them aborts
/// with [`Error::StaleRankMap`] instead of proceeding against a
/// communicator that no longer exists.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReshapeStep {
    /// The map passed validation; nothing planned yet.
    Validated,
    /// Every eligible dataset's reshape plan is computed (read-only).
    Planned,
    /// The fused migration phases are charged; no store touched yet.
    Charged,
    /// Dataset `i`'s new layout was just installed (atomic per dataset:
    /// earlier datasets are complete-new, later ones complete-old).
    Installed(usize),
}

/// The replicated in-memory storage over a (simulated) cluster: a registry
/// of [`Dataset`]s (one per application datatype, §V), each with its own
/// `Distribution`, block size, replication level, and epoch.
///
/// One `ReStore` instance owns the stores of *all* PEs — the simulator's
/// global view of what, in the paper's C++ library, is one instance per MPI
/// rank. All placement, routing and scheduling decisions are computed
/// per-PE exactly as each rank would compute them locally.
///
/// ## Single-dataset facade
///
/// Every historical single-dataset method (`submit`, `load`,
/// `repair_replicas`, `rebalance`, accessors...) delegates to dataset 0 —
/// the dataset created by [`ReStore::new`] — and is byte-identical to the
/// pre-registry implementation. Additional datasets are created with
/// [`ReStore::create_dataset`] and driven through the
/// [`ReStore::dataset_mut`] handle.
///
/// ## Fused cross-dataset phases
///
/// A recovery that touches several datasets pays one sparse all-to-all
/// *round* per dataset if driven sequentially; [`ReStore::load_many`]
/// merges the per-dataset message plans into ONE request all-to-all and
/// ONE data all-to-all (per-pair messages concatenated, dataset-tagged),
/// and [`ReStore::rebalance_or_acknowledge`] rebalances every feasible
/// dataset under the single post-shrink epoch with one fused migration
/// all-to-all, degrading per dataset to acknowledge on
/// [`Error::IrrecoverableDataLoss`].
pub struct ReStore {
    pub(crate) datasets: Vec<Dataset>,
    /// Pooled accumulator backing the fused `load_many` phases (same
    /// steady-state no-O(p)-alloc contract as each dataset's own
    /// `LoadScratch` accumulator).
    pub(crate) fused_acc: Accumulator,
    /// Registry slots vacated by [`ReStore::delete_dataset`], reused (LIFO)
    /// by the next [`ReStore::create_dataset`] so surviving `DatasetId`s
    /// stay stable and the registry vec never compacts under live ids.
    pub(crate) free: Vec<u32>,
}

impl ReStore {
    /// Create an instance sized for `cluster`'s world, with `cfg` as
    /// dataset 0 (the dataset the single-dataset facade addresses).
    pub fn new(cfg: RestoreConfig, cluster: &Cluster) -> Result<Self> {
        Ok(ReStore {
            datasets: vec![Dataset::new(DatasetId(0), cfg, cluster)?],
            fused_acc: Accumulator::default(),
            free: Vec::new(),
        })
    }

    /// Register an additional dataset (its own `n`, `r`, `b`, seed — §V's
    /// "one ReStore object per datatype"). The config's world must match
    /// the cluster's; everything else is independent per dataset. Reuses
    /// the most recently [deleted](ReStore::delete_dataset) registry slot
    /// if one exists — ids of deleted datasets come back for new datasets,
    /// while ids of surviving datasets never move.
    pub fn create_dataset(&mut self, cfg: RestoreConfig, cluster: &Cluster) -> Result<DatasetId> {
        if let Some(slot) = self.free.pop() {
            let id = DatasetId(slot);
            // Build first so a config error leaves the free slot available.
            match Dataset::new(id, cfg, cluster) {
                Ok(ds) => {
                    self.datasets[id.index()] = ds;
                    Ok(id)
                }
                Err(e) => {
                    self.free.push(slot);
                    Err(e)
                }
            }
        } else {
            let id = DatasetId(self.datasets.len() as u32);
            self.datasets.push(Dataset::new(id, cfg, cluster)?);
            Ok(id)
        }
    }

    /// Delete a dataset: every replica byte is reclaimed immediately and
    /// the id answers [`Error::UnknownDataset`] until
    /// [`ReStore::create_dataset`] reuses the slot. Dataset 0 backs the
    /// single-dataset facade and cannot be deleted. Deleting twice is an
    /// `UnknownDataset` error, not a panic.
    pub fn delete_dataset(&mut self, id: DatasetId) -> Result<()> {
        if id == DatasetId::FIRST {
            return Err(Error::Config(
                "dataset 0 backs the single-dataset facade and cannot be deleted".into(),
            ));
        }
        let i = self.index_of(id)?;
        let ds = &mut self.datasets[i];
        for pe in 0..ds.stores.len() {
            ds.stores[pe].clear();
        }
        ds.holder_index = HolderIndex::new(ds.dist.world());
        ds.staging = None;
        ds.submitted = false;
        ds.execution = false;
        ds.deleted = true;
        self.free.push(id.0);
        Ok(())
    }

    /// Number of registry slots (≥ 1), **including** tombstones of deleted
    /// datasets awaiting slot reuse — the upper bound on live ids, not the
    /// live count.
    pub fn n_datasets(&self) -> usize {
        self.datasets.len()
    }

    /// All registry slots in id order, including deleted tombstones (test
    /// with [`ReStore::dataset`], which rejects deleted ids, before
    /// trusting a slot).
    pub fn datasets(&self) -> &[Dataset] {
        &self.datasets
    }

    pub(crate) fn index_of(&self, id: DatasetId) -> Result<usize> {
        if id.index() < self.datasets.len() && !self.datasets[id.index()].deleted {
            Ok(id.index())
        } else {
            Err(Error::UnknownDataset { dataset: id.0, datasets: self.datasets.len() })
        }
    }

    /// The dataset handle for `id`.
    pub fn dataset(&self, id: DatasetId) -> Result<&Dataset> {
        let i = self.index_of(id)?;
        Ok(&self.datasets[i])
    }

    /// The mutable dataset handle for `id` — every routing operation
    /// (`submit`/`load`/`repair`/`rebalance`/`acknowledge_shrink`) is a
    /// method of the handle.
    pub fn dataset_mut(&mut self, id: DatasetId) -> Result<&mut Dataset> {
        let i = self.index_of(id)?;
        Ok(&mut self.datasets[i])
    }

    // --- single-dataset facade (dataset 0) -------------------------------

    fn ds0(&self) -> &Dataset {
        &self.datasets[0]
    }

    fn ds0_mut(&mut self) -> &mut Dataset {
        &mut self.datasets[0]
    }

    pub fn config(&self) -> &RestoreConfig {
        self.ds0().config()
    }

    pub fn distribution(&self) -> &Distribution {
        self.ds0().distribution()
    }

    pub fn stores(&self) -> &[PeStore] {
        self.ds0().stores()
    }

    pub fn is_submitted(&self) -> bool {
        self.ds0().is_submitted()
    }

    /// The reverse holder index of dataset 0 (permuted slot → storing PEs).
    pub fn holder_index(&self) -> &HolderIndex {
        self.ds0().holder_index()
    }

    /// Communicator epoch dataset 0's layout addresses.
    pub fn epoch(&self) -> u64 {
        self.ds0().epoch()
    }

    /// `(pes, nodes)` dataset 0's pooled accumulator touched in its most
    /// recent communication phase (see [`Dataset::last_phase_touched`]).
    pub fn last_phase_touched(&self) -> (usize, usize) {
        self.ds0().last_phase_touched()
    }

    /// Cluster rank of dataset 0's distribution rank `dist_rank`.
    #[inline]
    pub fn cluster_rank(&self, dist_rank: usize) -> usize {
        self.ds0().cluster_rank(dist_rank)
    }

    /// Does the current survivor count admit the balanced §IV-A layout for
    /// dataset 0 (see [`Dataset::can_rebalance`])?
    pub fn can_rebalance(&self, cluster: &Cluster) -> bool {
        self.ds0().can_rebalance(cluster)
    }

    /// Submit real data into dataset 0 (see [`Dataset::submit`]).
    pub fn submit(&mut self, cluster: &mut Cluster, shards: &[Vec<u8>]) -> Result<SubmitReport> {
        self.ds0_mut().submit(cluster, shards)
    }

    /// Cost-model submit into dataset 0 (see [`Dataset::submit_virtual`]).
    pub fn submit_virtual(&mut self, cluster: &mut Cluster) -> Result<SubmitReport> {
        self.ds0_mut().submit_virtual(cluster)
    }

    /// Committed data version of dataset 0 (see [`Dataset::version`]).
    pub fn version(&self) -> u64 {
        self.ds0().version()
    }

    /// Publish a new version of dataset 0 (see [`Dataset::resubmit`]).
    pub fn resubmit(
        &mut self,
        cluster: &mut Cluster,
        shards: &[Vec<u8>],
        mode: ResubmitMode<'_>,
        overlap: Overlap,
    ) -> Result<ResubmitReport> {
        self.ds0_mut().resubmit(cluster, shards, mode, overlap)
    }

    /// Cost-model resubmit of dataset 0 (see [`Dataset::resubmit_virtual`]).
    pub fn resubmit_virtual(
        &mut self,
        cluster: &mut Cluster,
        dirty: &RangeSet,
        overlap: Overlap,
    ) -> Result<ResubmitReport> {
        self.ds0_mut().resubmit_virtual(cluster, dirty, overlap)
    }

    /// Load from dataset 0 (see [`Dataset::load`]).
    pub fn load(&mut self, cluster: &mut Cluster, requests: &[LoadRequest]) -> Result<LoadOutput> {
        self.ds0_mut().load(cluster, requests)
    }

    /// §IV-E replica repair of dataset 0 (see [`Dataset::repair_replicas`]).
    pub fn repair_replicas(
        &mut self,
        cluster: &mut Cluster,
        scheme: repair::RepairScheme,
    ) -> Result<repair::RepairReport> {
        self.ds0_mut().repair_replicas(cluster, scheme)
    }

    /// §IV-B rebalance of dataset 0 alone (see [`Dataset::rebalance`]).
    /// Applications with several datasets should prefer the fused
    /// [`ReStore::rebalance_or_acknowledge`], which adopts the shrink for
    /// every dataset at once.
    pub fn rebalance(&mut self, cluster: &mut Cluster, map: &RankMap) -> Result<RebalanceReport> {
        self.ds0_mut().rebalance(cluster, map)
    }

    /// Adopt a shrunk communicator without rewriting any layout, for
    /// **every** dataset (see [`Dataset::acknowledge_shrink`]): all dead
    /// stores reclaimed, all dataset epochs caught up to the cluster's.
    pub fn acknowledge_shrink(&mut self, cluster: &Cluster) -> Result<()> {
        for ds in &mut self.datasets {
            if !ds.deleted {
                ds.acknowledge_shrink(cluster)?;
            }
        }
        Ok(())
    }

    // --- fused reshape handshake -----------------------------------------

    /// The full §IV-B reshape handshake across **all** datasets, for ANY
    /// epoch-bumping communicator change — a shrink (`p' < p`), a
    /// substitution (`p' = p`, spares seated in the dead ranks'
    /// positions), or a grow (`p' > p`): rewrite the layout over the
    /// `map`'s members for every dataset whose new world admits the
    /// balanced §IV-A distribution, acknowledge (reclaiming dead stores)
    /// for the rest — all under the single post-reshape cluster epoch,
    /// with the per-dataset migration plans merged into ONE local copy
    /// charge and ONE migration sparse all-to-all (per-pair messages
    /// concatenated across datasets). Returns the per-dataset outcomes in
    /// id order: `Some(report)` where a rebalance ran, `None` where the
    /// dataset acknowledged.
    ///
    /// The `map` is validated against the cluster's *current* alive set
    /// **before** any policy branch: a stale `RankMap` from an earlier
    /// epoch would otherwise silently steer the policy — surfaced as
    /// [`Error::StaleRankMap`] with every dataset untouched.
    ///
    /// If a dataset's rebalance plan discovers an interval with no
    /// surviving holder ([`Error::IrrecoverableDataLoss`]), that dataset —
    /// and only that dataset — degrades to acknowledging: data it still
    /// holds stays loadable in the dead world, and a *targeted* load of
    /// the lost ranges reports the loss (tagged with the dataset id).
    ///
    /// The strategy choosing which `ulfm` primitive produced the map
    /// (shrink vs substitute vs shrink-then-regrow, with pool-exhaustion
    /// fallback) lives one layer up in [`policy`].
    pub fn rebalance_or_acknowledge_all(
        &mut self,
        cluster: &mut Cluster,
        map: &RankMap,
    ) -> Result<Vec<Option<RebalanceReport>>> {
        self.rebalance_or_acknowledge_all_with_faults(cluster, map, &mut |_, _| {})
    }

    /// [`ReStore::rebalance_or_acknowledge_all`] with a fault-injection
    /// hook fired at every [`ReshapeStep`] boundary — the harness behind
    /// the mid-recovery-kill tests: `inject` may kill PEs (or do nothing),
    /// and the handshake re-validates the map after EVERY boundary, so a
    /// failure that lands between planning and install surfaces as
    /// [`Error::StaleRankMap`] *before* any dataset is torn. The atomicity
    /// contract this proves:
    ///
    /// * an abort before the first `Installed(i)` leaves every dataset on
    ///   its complete OLD layout, byte-intact (planning and charging never
    ///   touch the stores; `apply_reshape` installs atomically-on-success);
    /// * an abort after `Installed(i)` leaves datasets `≤ i` on their
    ///   complete NEW layout and the rest on their complete old one —
    ///   never a torn mixture. The caller retries with a fresh map
    ///   ([`policy`] bounds the attempts); already-installed datasets are
    ///   then `layout_current` and degrade to the O(1) acknowledge.
    pub fn rebalance_or_acknowledge_all_with_faults(
        &mut self,
        cluster: &mut Cluster,
        map: &RankMap,
        inject: &mut dyn FnMut(ReshapeStep, &mut Cluster),
    ) -> Result<Vec<Option<RebalanceReport>>> {
        map.validate_against(cluster)?;
        inject(ReshapeStep::Validated, cluster);
        map.validate_against(cluster)?;
        // Plan FIRST, for every eligible dataset: planning is pure (no
        // clock, no store mutation), so a non-IDL error here leaves the
        // whole registry untouched. A reshape that changed nothing (same
        // world, same member seating as the dataset's pe_map — e.g. a
        // shrink after deaths that were already acknowledged) leaves each
        // layout already correct: adopting the epoch (acknowledge) is the
        // O(1) action, not a keep-everything rebalance.
        let mut plans: Vec<(usize, ReshapePlan)> = Vec::new();
        for (i, ds) in self.datasets.iter().enumerate() {
            let layout_current = map.new_world() == ds.dist.world()
                && map.new_to_old.iter().zip(ds.pe_map.iter()).all(|(&o, &c)| o == c as usize);
            let eligible = ds.submitted
                && cluster.epoch() > ds.epoch
                && !layout_current
                && ds.dist.reshape_feasible(map.new_world());
            if !eligible {
                continue;
            }
            match ds.plan_reshape(cluster, map) {
                Ok(plan) => plans.push((i, plan)),
                // This dataset has an interval with no surviving holder:
                // degrade it (alone) to acknowledge; targeted loads surface
                // the real losses, exactly as the single-dataset policy did.
                Err(Error::IrrecoverableDataLoss { .. }) => {}
                Err(e) => return Err(e),
            }
        }
        inject(ReshapeStep::Planned, cluster);
        map.validate_against(cluster)?;

        // ONE fused local-copy charge + ONE fused migration all-to-all for
        // every planned dataset (identical to the single-dataset charges
        // when only one dataset planned).
        let mut outcomes: Vec<Option<RebalanceReport>> = Vec::new();
        outcomes.resize_with(self.datasets.len(), || None);
        if !plans.is_empty() {
            let tagged: Vec<(&ReshapePlan, u64)> = plans
                .iter()
                .map(|(i, plan)| (plan, self.datasets[*i].cfg.block_size as u64))
                .collect();
            let (local_cost, net_cost) = charge_reshape_plans(cluster, &tagged)?;
            inject(ReshapeStep::Charged, cluster);
            map.validate_against(cluster)?;
            let shared = local_cost.then(net_cost);
            for (i, plan) in plans {
                let report = self.datasets[i].apply_reshape(cluster, plan, shared)?;
                outcomes[i] = Some(report);
                inject(ReshapeStep::Installed(i), cluster);
                map.validate_against(cluster)?;
            }
        }
        for (i, ds) in self.datasets.iter_mut().enumerate() {
            if outcomes[i].is_none() && !ds.deleted {
                ds.acknowledge_shrink(cluster)?;
            }
        }
        Ok(outcomes)
    }

    /// The single-dataset view of the fused reshape handshake: runs
    /// [`ReStore::rebalance_or_acknowledge_all`] (every dataset adopts the
    /// new communicator — shrink, substitution, and grow maps alike) and
    /// returns dataset 0's outcome — exactly the historical single-dataset
    /// behavior when only one dataset is registered.
    pub fn rebalance_or_acknowledge(
        &mut self,
        cluster: &mut Cluster,
        map: &RankMap,
    ) -> Result<Option<RebalanceReport>> {
        let mut outcomes = self.rebalance_or_acknowledge_all(cluster, map)?;
        Ok(outcomes.swap_remove(0))
    }

    // --- fused cross-dataset §IV-E repair --------------------------------

    /// §IV-E replica repair across **every** submitted dataset in ONE
    /// merged sparse all-to-all: each dataset's repair transfers are
    /// planned exactly as its own [`Dataset::repair_replicas`] would plan
    /// them, then charged as a single fused phase and applied per dataset.
    /// Each re-created replica stays its own point-to-point message (the
    /// per-transfer cost model the repair golden tests pin), so fusing
    /// collapses the former per-dataset repair *rounds* — one phase
    /// latency and one bottleneck reduction instead of one per dataset —
    /// while the bytes and message counts match the sequential charges
    /// exactly. Returns per-dataset reports in id order; datasets not yet
    /// submitted are skipped (`None`).
    pub fn repair_replicas_all(
        &mut self,
        cluster: &mut Cluster,
        scheme: RepairScheme,
    ) -> Result<Vec<Option<RepairReport>>> {
        let mut plans: Vec<(usize, RepairPlan)> = Vec::new();
        for (i, ds) in self.datasets.iter().enumerate() {
            if !ds.submitted {
                continue;
            }
            plans.push((i, ds.plan_repair(cluster, scheme)?));
        }
        let mut outcomes: Vec<Option<RepairReport>> = Vec::new();
        outcomes.resize_with(self.datasets.len(), || None);
        if !plans.is_empty() {
            let tagged: Vec<(&RepairPlan, u64)> = plans
                .iter()
                .map(|(i, plan)| (plan, self.datasets[*i].cfg.block_size as u64))
                .collect();
            let cost = charge_repair_plans(cluster, &tagged)?;
            for (i, plan) in plans {
                outcomes[i] = Some(self.datasets[i].apply_repair(plan, cost)?);
            }
        }
        Ok(outcomes)
    }
}
