//! The ReStore library core (§IV + §V of the paper).
//!
//! * [`block`] — block IDs, ranges, range sets.
//! * [`distribution`] — the placement function `L(x,k)` with permutation
//!   ranges and the precomputed unit→slot placement index shared by
//!   submit, load, and repair.
//! * [`permutation`] — Feistel range permutation (and identity).
//! * [`store`] — per-PE in-memory replica storage.
//! * [`submit`] — the one-time checkpoint creation path.
//! * [`load`] — the recovery path (request resolution + sparse all-to-all),
//!   plus the request-pattern helpers for the paper's three benchmark
//!   operations (*load 1 %*, *load all*, scattered/single-target recovery).
//! * [`idl`] — §IV-D irrecoverable-data-loss probabilities (exact
//!   inclusion–exclusion, the small-f approximation, and the Monte-Carlo
//!   failure simulator behind Fig 3).
//! * [`repair`] — §IV-E replica re-creation after failures (Appendix
//!   Distributions A and B).
//! * [`serialize`] — typed helpers to move `f32`/`u64` app data in and out
//!   of block payloads.

pub mod block;
pub mod distribution;
pub mod hashing;
pub mod idl;
pub mod load;
pub mod permutation;
pub mod repair;
pub mod serialize;
pub mod store;
pub mod submit;

use crate::config::RestoreConfig;
use crate::error::{Error, Result};
use crate::simnet::cluster::Cluster;
use crate::simnet::network::PhaseCost;

use block::RangeSet;
use distribution::Distribution;
use store::{HolderIndex, PeStore};

/// A per-PE load request: the *original* block ID ranges this PE wants.
/// (The paper's preferred API mode: "providing exactly those ID ranges each
/// individual PE needs on exactly that PE", §V.)
#[derive(Debug, Clone)]
pub struct LoadRequest {
    pub pe: usize,
    pub ranges: RangeSet,
}

/// Data loaded for one requesting PE, in request order.
#[derive(Debug, Clone)]
pub struct LoadedShard {
    pub pe: usize,
    /// `Some(bytes)` in execution mode, `None` in cost-model mode.
    pub bytes: Option<Vec<u8>>,
}

/// Result of a [`ReStore::load`].
#[derive(Debug, Clone)]
pub struct LoadOutput {
    pub shards: Vec<LoadedShard>,
    /// Cost of the request sparse all-to-all (phase 1).
    pub request_cost: PhaseCost,
    /// Cost of the data sparse all-to-all (phase 2).
    pub data_cost: PhaseCost,
    /// Total (= request + data).
    pub cost: PhaseCost,
}

/// Result of a [`ReStore::submit`].
#[derive(Debug, Clone)]
pub struct SubmitReport {
    pub cost: PhaseCost,
}

/// The replicated in-memory storage over a (simulated) cluster.
///
/// One `ReStore` instance owns the stores of *all* PEs — the simulator's
/// global view of what, in the paper's C++ library, is one instance per MPI
/// rank. All placement, routing and scheduling decisions are computed
/// per-PE exactly as each rank would compute them locally.
pub struct ReStore {
    cfg: RestoreConfig,
    dist: Distribution,
    stores: Vec<PeStore>,
    submitted: bool,
    /// Reverse holder index (permuted slot → storing PEs), maintained
    /// incrementally by submit and §IV-E repair; consulted by repair
    /// planning and the load path's post-repair fallback instead of an
    /// O(p) store sweep.
    holder_index: HolderIndex,
    /// Reusable buffers for the load pipeline — grown on first use, then
    /// reused so steady-state `load()` calls allocate nothing per piece.
    scratch: load::LoadScratch,
}

impl ReStore {
    /// Create an instance sized for `cluster`'s world.
    pub fn new(cfg: RestoreConfig, cluster: &Cluster) -> Result<Self> {
        cfg.validate()?;
        if cfg.world != cluster.world() {
            return Err(Error::Config(format!(
                "config world {} != cluster world {}",
                cfg.world,
                cluster.world()
            )));
        }
        let dist = Distribution::new(&cfg);
        let stores = (0..cfg.world).map(|_| PeStore::new(cfg.block_size)).collect();
        let holder_index = HolderIndex::new(cluster.world());
        Ok(ReStore {
            cfg,
            dist,
            stores,
            submitted: false,
            holder_index,
            scratch: load::LoadScratch::default(),
        })
    }

    pub fn config(&self) -> &RestoreConfig {
        &self.cfg
    }

    pub fn distribution(&self) -> &Distribution {
        &self.dist
    }

    pub fn stores(&self) -> &[PeStore] {
        &self.stores
    }

    pub fn is_submitted(&self) -> bool {
        self.submitted
    }

    /// The reverse holder index (permuted slot → storing PEs).
    pub fn holder_index(&self) -> &HolderIndex {
        &self.holder_index
    }

    /// Reclaim a dead PE's replica memory: drop its stored slices and
    /// remove it from the reverse holder index. The shrink-style recovery
    /// of §IV-B never reads a dead PE's store (routing filters on the
    /// survivor set), so this only frees memory — but it must go through
    /// this method, not the raw store, to keep the index consistent.
    pub fn drop_pe(&mut self, cluster: &Cluster, pe: usize) -> Result<()> {
        if pe >= self.cfg.world {
            return Err(Error::RankOutOfRange { rank: pe, world: self.cfg.world });
        }
        if cluster.is_alive(pe) {
            return Err(Error::Config(format!(
                "drop_pe: PE {pe} is alive; only failed PEs' stores may be reclaimed"
            )));
        }
        self.stores[pe].clear();
        self.holder_index.drop_pe(pe);
        Ok(())
    }

    pub(crate) fn stores_mut(&mut self) -> &mut Vec<PeStore> {
        &mut self.stores
    }

    pub(crate) fn holder_index_mut(&mut self) -> &mut HolderIndex {
        &mut self.holder_index
    }

    pub(crate) fn mark_submitted(&mut self) -> Result<()> {
        if self.submitted {
            return Err(Error::AlreadySubmitted);
        }
        self.submitted = true;
        Ok(())
    }

    pub(crate) fn ensure_submitted(&self) -> Result<()> {
        if !self.submitted {
            return Err(Error::NotSubmitted);
        }
        Ok(())
    }
}
