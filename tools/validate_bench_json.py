#!/usr/bin/env python3
"""Validate BENCH_*.json perf artifacts against the CI schema.

The bench binaries emit one JSON object per line:

    {"name": <non-empty string>, "ns_per_iter": <finite number > 0>}

`tools/perf_table.py` (and the cross-PR perf-trajectory tooling) silently
skips nothing — a malformed line used to surface only when someone tried
to render the table months later. This validator fails loudly instead:
CI's `bench-json-short` smoke step runs every bench binary in short mode
and then checks every produced artifact line-by-line.

Exit status: 0 if every file exists, is non-empty, and every line parses
with exactly the expected fields; 1 otherwise (all problems are listed).

Usage:
    python3 tools/validate_bench_json.py BENCH_hotpath.json \
        BENCH_load_scale.json BENCH_rebalance.json
"""

import json
import math
import sys


def validate_file(path: str) -> list:
    problems = []
    try:
        with open(path, encoding="utf-8") as fh:
            lines = fh.read().splitlines()
    except FileNotFoundError:
        return [f"{path}: missing (bench did not write its artifact)"]
    entries = 0
    for lineno, line in enumerate(lines, start=1):
        if not line.strip():
            continue
        where = f"{path}:{lineno}"
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            problems.append(f"{where}: not valid JSON ({e}): {line!r}")
            continue
        if not isinstance(obj, dict):
            problems.append(f"{where}: expected an object, got {type(obj).__name__}")
            continue
        extra = sorted(set(obj) - {"name", "ns_per_iter"})
        missing = sorted({"name", "ns_per_iter"} - set(obj))
        if missing:
            problems.append(f"{where}: missing field(s) {missing}")
        if extra:
            problems.append(f"{where}: unexpected field(s) {extra}")
        name = obj.get("name")
        if not isinstance(name, str) or not name.strip():
            problems.append(f"{where}: 'name' must be a non-empty string, got {name!r}")
        value = obj.get("ns_per_iter")
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            problems.append(f"{where}: 'ns_per_iter' must be a number, got {value!r}")
        elif not math.isfinite(value):
            problems.append(f"{where}: 'ns_per_iter' must be finite, got {value!r}")
        elif value < 0:
            problems.append(f"{where}: 'ns_per_iter' must be >= 0, got {value!r}")
        elif value == 0 and not (isinstance(name, str) and "zero-ok" in name):
            # Every metric the benches emit (durations, byte counts,
            # probabilities, fractions) is strictly positive when actually
            # measured; a NaN-free 0.0 or negative value means a broken
            # measurement or formatting truncation, not a fast run — EXCEPT
            # counters whose healthy value IS zero (e.g. the kv bench's
            # stale-serve tripwire), which opt in by carrying the literal
            # `zero-ok` tag in their name.
            problems.append(
                f"{where}: 'ns_per_iter' must be > 0 (tag the name 'zero-ok' if "
                f"zero is the healthy value), got {value!r}"
            )
        entries += 1
    if not entries:
        problems.append(f"{path}: no entries (empty artifact)")
    return problems


def main() -> int:
    if len(sys.argv) < 2:
        print(__doc__, file=sys.stderr)
        return 2
    all_problems = []
    for path in sys.argv[1:]:
        problems = validate_file(path)
        if problems:
            all_problems.extend(problems)
        else:
            print(f"{path}: OK")
    for problem in all_problems:
        print(f"error: {problem}", file=sys.stderr)
    return 1 if all_problems else 0


if __name__ == "__main__":
    sys.exit(main())
