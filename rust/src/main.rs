//! `restore` — launcher CLI for the ReStore reproduction.
//!
//! ```text
//! restore run --config exp.toml     launch a fault-tolerant app run
//! restore idl [--p N] [--r R] [--f F]...   §IV-D IDL probabilities
//! restore smoke                     end-to-end self-check
//! restore gen-config PATH           write a paper-default experiment file
//! ```
//!
//! The figure benches live in `benches/` (`cargo bench --bench fig…`).

use restore::apps::{kmeans, pagerank};
use restore::config::{AppKind, ExperimentFile};
use restore::metrics::fmt_time;
use restore::restore::idl;
use restore::runtime::Engine;
use restore::simnet::cluster::Cluster;

/// CLI-level result: any error bubbles up as a printable message.
type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

const USAGE: &str = "usage: restore <run|idl|smoke|gen-config> [options]
  run --config <exp.toml>
  idl [--p <pes>] [--r <replicas>] [--f <failures>]...
  smoke
  gen-config <path>";

/// Tiny argv parser: `--key value` pairs plus positionals.
struct Args {
    flags: Vec<(String, String)>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val =
                    it.next().ok_or_else(|| format!("--{key} needs a value"))?.clone();
                flags.push((key.to_string(), val));
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { flags, positional })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags.iter().filter(|(k, _)| k == key).map(|(_, v)| v.as_str()).collect()
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "run" => run_app(args.get("config").ok_or("run needs --config <exp.toml>")?),
        "idl" => {
            let p: u64 = args.get("p").unwrap_or("24576").parse()?;
            let r: u64 = args.get("r").unwrap_or("4").parse()?;
            let fs: Vec<u64> = args
                .get_all("f")
                .iter()
                .map(|s| s.parse::<u64>())
                .collect::<std::result::Result<_, _>>()?;
            print_idl(p, r, &fs);
            Ok(())
        }
        "smoke" => smoke(),
        "gen-config" => {
            let path = args.positional.first().ok_or("gen-config needs a path")?;
            let exp = ExperimentFile {
                world: 48,
                pes_per_node: 48,
                restore: restore::config::RestoreConfig::paper_default(48)?,
                network: Default::default(),
                pfs: Default::default(),
                app: Default::default(),
            };
            std::fs::write(path, exp.to_toml())?;
            println!("wrote {path}");
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{USAGE}").into()),
    }
}

fn run_app(path: &str) -> Result<()> {
    let exp = ExperimentFile::load(path)?;
    let mut cluster = Cluster::with_network(exp.world, exp.pes_per_node, exp.network.clone());
    match exp.app.kind {
        AppKind::Kmeans => {
            let mut engine = Engine::load_default()?;
            let mut params = kmeans::KmeansParams::tiny(exp.app.iterations);
            params.failure_fraction = exp.app.failure_fraction;
            params.seed = exp.app.seed;
            // derive point shape from the restore config payload
            let floats = exp.restore.blocks_per_pe * exp.restore.block_size / 4;
            params.points_per_pe = floats / params.dims;
            let rep = kmeans::run_execution(&mut cluster, &mut engine, &exp.restore, &params)?;
            println!("k-means: {} iterations, {} failures", rep.iterations_run, rep.failures);
            println!("  final inertia      {:.3}", rep.final_inertia);
            println!("  sim total          {}", fmt_time(rep.sim_total_s));
            println!("  k-means loop       {}", fmt_time(rep.sim_kmeans_loop_s));
            println!("  ReStore overhead   {}", fmt_time(rep.sim_restore_s));
            println!("  MPI recovery       {}", fmt_time(rep.sim_mpi_recovery_s));
            println!("  PJRT wall compute  {}", fmt_time(rep.wall_compute_s));
        }
        AppKind::Pagerank => {
            let mut params = pagerank::PagerankParams {
                iterations: exp.app.iterations,
                failure_fraction: exp.app.failure_fraction,
                seed: exp.app.seed,
                ..Default::default()
            };
            let bs = exp.restore.block_size;
            params.vertices_per_pe =
                exp.restore.blocks_per_pe * bs / (8 * params.edges_per_vertex);
            let rep = pagerank::run(&mut cluster, &exp.restore, &params)?;
            println!("pagerank: {} iterations, {} failures", rep.iterations_run, rep.failures);
            println!("  final delta        {:.3e}", rep.final_delta);
            println!("  sim total          {}", fmt_time(rep.sim_total_s));
            println!("  ReStore overhead   {}", fmt_time(rep.sim_restore_s));
        }
        AppKind::Raxml => {
            let times = restore::apps::raxml::measure_recovery(
                exp.world,
                exp.pes_per_node,
                (exp.restore.blocks_per_pe * exp.restore.block_size) as u64,
                (exp.world as f64 * exp.app.failure_fraction).ceil() as usize,
                &exp.pfs,
                exp.app.seed,
            )?;
            println!("raxml recovery (p={}):", exp.world);
            println!("  ReStore submit     {}", fmt_time(times.restore_submit_s));
            println!("  ReStore load       {}", fmt_time(times.restore_load_s));
            println!("  PFS uncached       {}", fmt_time(times.pfs_uncached_s));
            println!("  PFS cached         {}", fmt_time(times.pfs_cached_s));
        }
    }
    Ok(())
}

fn print_idl(p: u64, r: u64, failures: &[u64]) {
    let fs: Vec<u64> = if failures.is_empty() {
        (0..).map(|i| 1u64 << i).take_while(|&f| f <= p).collect()
    } else {
        failures.to_vec()
    };
    println!("p={p} r={r} (g={} groups)", p / r);
    println!("{:>12} {:>14} {:>14}", "failures", "P_IDL<=(f)", "approx");
    for f in fs {
        println!(
            "{:>12} {:>14.6e} {:>14.6e}",
            f,
            idl::p_idl_leq(p, r, f),
            idl::p_idl_approx(p, r, f)
        );
    }
    println!(
        "E[failures until IDL] = {:.1} ({:.2} % of p)",
        idl::expected_failures_until_idl(p, r),
        100.0 * idl::expected_failures_until_idl(p, r) / p as f64
    );
}

fn smoke() -> Result<()> {
    use restore::config::RestoreConfig;
    use restore::restore::load::scatter_requests;
    use restore::restore::ReStore;

    // 1. artifacts + PJRT (skipped — not failed — when the binary was
    // built without the `pjrt` feature or `make artifacts` has not run;
    // the ReStore round trip below needs neither)
    match Engine::load_default() {
        Ok(mut engine) => {
            let points = kmeans::generate_points(1, 0, 256, 8, 4);
            let centers = kmeans::starting_centers(1, 4, 8);
            let out = engine.execute_f32("kmeans_step_tiny", &[&points, &centers])?;
            let total: f32 = out[1].iter().sum();
            if total != 256.0 {
                return Err(format!("kernel counts {total} != 256").into());
            }
            println!(
                "PJRT kernel OK ({} exec in {})",
                engine.exec_calls,
                fmt_time(engine.exec_seconds)
            );
        }
        Err(e) => println!("PJRT kernel check skipped: {e}"),
    }

    // 2. store round trip under failures
    let cfg = RestoreConfig::builder(16, 64, 1024)
        .replicas(4)
        .perm_range_bytes(Some(4096))
        .build()?;
    let mut cluster = Cluster::new_execution(16, 4);
    let mut store = ReStore::new(cfg, &cluster)?;
    let shards: Vec<Vec<u8>> = (0..16).map(|pe| vec![pe as u8; 64 * 1024]).collect();
    store.submit(&mut cluster, &shards)?;
    cluster.kill(&[3, 7]);
    let reqs = scatter_requests(&store, &cluster, &[3, 7]);
    let out = store.load(&mut cluster, &reqs)?;
    let bytes: usize = out.shards.iter().map(|s| s.bytes.as_ref().unwrap().len()).sum();
    if bytes != 2 * 64 * 1024 {
        return Err(format!("recovered {bytes} bytes").into());
    }
    println!("ReStore recovery OK ({} in sim time)", fmt_time(out.cost.sim_time_s));
    println!("smoke OK");
    Ok(())
}
