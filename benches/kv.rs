//! KV serving benchmark (EXPERIMENTS.md §KV).
//!
//! Drives the Zipf serving trace (`apps::kvserve`) at the paper's
//! production scales (p = 1536 and p = 24576) to answer two questions:
//!
//! * **What does batching buy?** The same read-only Zipf trace served
//!   twice with the cache disabled — once fused 256 gets per `KvBatch`,
//!   once one get at a time. The fused run must send strictly fewer
//!   messages for at most the same bytes (the §IV-C fewer-messages
//!   argument applied to point reads; the EXACT per-get byte/message
//!   golden contract lives in `rust/tests/kv_store.rs`). Reported as the
//!   message-savings fraction.
//!
//! * **What does the cache buy under failures?** The read-heavy trace
//!   (Zipf(1.1), 8 frontends, write rounds every 16 batches) with MTBF
//!   failures landing mid-trace and the Shrink policy recovering, served
//!   cached vs uncached. Cached p50 must be strictly below the uncached
//!   ablation; stale serves must be zero across every epoch/version bump.
//!   Also reported: hit rate, p99, and the recovery blast radius (miss
//!   fraction of the first reads after each recovery, i.e. how much of
//!   the cache one epoch bump strands).
//!
//! With `BENCH_SHORT=1` only p = 1536 runs and the trace shrinks (the CI
//! schema smoke — see `make bench-json-short`). Emits `BENCH_kv.json` in
//! the `{name, ns_per_iter}` artifact schema (names carry units; the
//! always-zero stale-serve counter is tagged `zero-ok` for the
//! validator).

use restore::apps::kvserve::{run_zipf_trace, KvTraceConfig};
use restore::restore::policy::Shrink;
use restore::util::bench::{short_mode, write_json_artifact, BenchResult};

/// Section 1: batched vs unbatched message counts, cache off.
fn msg_savings_at(p: usize, ops: usize, results: &mut Vec<BenchResult>) {
    let mut cfg = KvTraceConfig::read_heavy(p, ops, 0xB47C);
    cfg.cache_capacity = 0;
    cfg.write_every_batches = 0; // read-only: byte totals must be comparable
    let mut unb = cfg.clone();
    unb.batch = 1;

    let batched = run_zipf_trace(&cfg, &mut Shrink).unwrap();
    let unbatched = run_zipf_trace(&unb, &mut Shrink).unwrap();
    assert!(
        batched.total_msgs < unbatched.total_msgs,
        "fused batches must send strictly fewer messages ({} vs {})",
        batched.total_msgs,
        unbatched.total_msgs
    );
    // Zipf duplicates dedup and adjacent keys coalesce inside a batch, so
    // fused bytes may drop below sequential — never above.
    assert!(batched.total_bytes <= unbatched.total_bytes);
    let savings = 1.0 - batched.total_msgs as f64 / unbatched.total_msgs as f64;

    let tag = format!("p={p}");
    println!(
        "kv {tag}: batch=256 sent {} msgs vs {} unbatched -> {:.1}% fewer \
         ({} vs {} bytes)",
        batched.total_msgs,
        unbatched.total_msgs,
        savings * 1e2,
        batched.total_bytes,
        unbatched.total_bytes,
    );
    results.push(BenchResult::from_value(&format!("kv msg-savings-frac {tag}"), savings));
    results.push(BenchResult::from_value(
        &format!("kv batched-msgs-count {tag}"),
        batched.total_msgs as f64,
    ));
    results.push(BenchResult::from_value(
        &format!("kv unbatched-msgs-count {tag}"),
        unbatched.total_msgs as f64,
    ));
}

/// Section 2: cached vs uncached latency under MTBF failures.
fn latency_at(p: usize, ops: usize, results: &mut Vec<BenchResult>) {
    let mut cfg = KvTraceConfig::read_heavy(p, ops, 0xCAC4E);
    cfg.pe_mtbf_s = p as f64 * 0.02;
    cfg.min_failures = 1;
    let mut uncached_cfg = cfg.clone();
    uncached_cfg.cache_capacity = 0;

    let cached = run_zipf_trace(&cfg, &mut Shrink).unwrap();
    let uncached = run_zipf_trace(&uncached_cfg, &mut Shrink).unwrap();
    assert!(
        cached.p50_s < uncached.p50_s,
        "cached p50 must beat the uncached ablation ({:.3e} vs {:.3e} s)",
        cached.p50_s,
        uncached.p50_s
    );
    assert_eq!(cached.stale_serves, 0, "no cached value may survive a stamp bump");
    assert_eq!(uncached.stale_serves, 0);
    assert!(cached.failures >= 1, "the storm must land mid-trace");

    let tag = format!("p={p}");
    println!(
        "kv {tag}: cached p50 {:.2} us / p99 {:.2} us (hit rate {:.1}%), uncached p50 \
         {:.2} us / p99 {:.2} us; {} failures, blast radius {:.1}%, stale serves 0",
        cached.p50_s * 1e6,
        cached.p99_s * 1e6,
        cached.hit_rate * 1e2,
        uncached.p50_s * 1e6,
        uncached.p99_s * 1e6,
        cached.failures,
        cached.blast_radius() * 1e2,
    );
    results.push(BenchResult::from_value(
        &format!("kv cached p50 sim-ns {tag}"),
        cached.p50_s * 1e9,
    ));
    results.push(BenchResult::from_value(
        &format!("kv cached p99 sim-ns {tag}"),
        cached.p99_s * 1e9,
    ));
    results.push(BenchResult::from_value(
        &format!("kv uncached p50 sim-ns {tag}"),
        uncached.p50_s * 1e9,
    ));
    results.push(BenchResult::from_value(
        &format!("kv uncached p99 sim-ns {tag}"),
        uncached.p99_s * 1e9,
    ));
    results.push(BenchResult::from_value(
        &format!("kv hit-rate-frac {tag}"),
        cached.hit_rate,
    ));
    results.push(BenchResult::from_value(
        &format!("kv blast-radius-frac {tag}"),
        cached.blast_radius(),
    ));
    results.push(BenchResult::from_value(
        &format!("kv stale-serves-count zero-ok {tag}"),
        cached.stale_serves as f64,
    ));
}

fn main() {
    println!("=== kv serving benchmarks ===\n");
    let mut results: Vec<BenchResult> = Vec::new();
    let scales: &[usize] = &[1536, 24576];
    let scales = if short_mode() { &scales[..1] } else { scales };
    let ops = if short_mode() { 8192 } else { 32768 };
    for &p in scales {
        msg_savings_at(p, ops, &mut results);
        latency_at(p, ops, &mut results);
    }
    write_json_artifact("BENCH_kv.json", &results).expect("write BENCH_kv.json");
    println!("\nwrote BENCH_kv.json ({} entries)", results.len());
}
