//! A mutable dataset checkpointed every iteration — and a checkpoint
//! killed mid-replication that falls back to the previous version.
//!
//! The write-once library of the paper keeps ONE version of a dataset: a
//! kmeans-style app that wants per-iteration checkpoints must tear down
//! and resubmit from scratch. The mutable-dataset extension makes the
//! checkpoint loop first-class:
//!
//! 1. an iterative solver updates its state each iteration and calls
//!    `resubmit` with `ResubmitMode::DeltaByChecksum` — only blocks whose
//!    content actually changed are re-replicated (here: one hot region,
//!    so the delta is a small fraction of the dataset);
//! 2. replication of version v+1 runs double-buffered against a staging
//!    store while version v keeps serving loads;
//! 3. a failure landing INSIDE the replication window aborts the staged
//!    version — `Error::ResubmitAborted` — and after ULFM recovery every
//!    load still returns version v's bytes byte-for-byte. No torn state,
//!    ever.
//!
//! Run with: `cargo run --release --example iterative_checkpoint`

use restore::config::RestoreConfig;
use restore::error::Error;
use restore::restore::block::{BlockRange, RangeSet};
use restore::restore::{
    DatasetId, LoadRequest, Overlap, ReStore, ResubmitMode, ResubmitStep,
};
use restore::simnet::cluster::Cluster;
use restore::simnet::ulfm;

const P: usize = 16;
const BS: usize = 64;
const BPP: usize = 64;
const R: usize = 4;
const N_BLOCKS: u64 = (P * BPP) as u64;
const ITERS: usize = 6;

/// The solver "computes": iteration i rewrites a 32-block hot region.
fn step(state: &mut [u8], iter: usize) {
    let hot = (iter * 32) % (N_BLOCKS as usize - 32);
    for b in &mut state[hot * BS..(hot + 32) * BS] {
        *b = b.wrapping_mul(167).wrapping_add(iter as u8);
    }
}

fn shards_of(store: &ReStore, flat: &[u8]) -> Vec<Vec<u8>> {
    let dist = store.distribution();
    (0..dist.world())
        .map(|j| {
            let r = dist.shard_of(j);
            flat[r.start as usize * BS..r.end as usize * BS].to_vec()
        })
        .collect()
}

fn load_all(store: &mut ReStore, cluster: &mut Cluster) -> Vec<u8> {
    let pe = cluster.survivors()[0];
    let reqs = vec![LoadRequest {
        pe,
        ranges: RangeSet::new(vec![BlockRange::new(0, N_BLOCKS)]),
    }];
    store.load(cluster, &reqs).unwrap().shards[0].bytes.clone().unwrap()
}

fn main() {
    let cfg = RestoreConfig::builder(P, BS, BPP).replicas(R).build().unwrap();
    let mut cluster = Cluster::new_execution(P, 4);
    let mut store = ReStore::new(cfg, &cluster).unwrap();

    let mut state: Vec<u8> = (0..N_BLOCKS as usize * BS).map(|i| i as u8).collect();
    store.submit(&mut cluster, &shards_of(&store, &state)).unwrap();
    println!(
        "submitted {} blocks x {BS} B on p={P} (r={R}) -> version {}",
        N_BLOCKS,
        store.version()
    );

    // -- the checkpoint loop: delta-by-checksum, overlapped with compute --
    for iter in 0..ITERS {
        step(&mut state, iter);
        let shards = shards_of(&store, &state);
        let rep = store
            .resubmit(&mut cluster, &shards, ResubmitMode::DeltaByChecksum, Overlap::Compute(1e-3))
            .unwrap();
        println!(
            "iter {iter}: checkpointed {:>3} dirty blocks ({} B replicated) -> \
             version {}, exposed {:.1} us",
            rep.dirty_blocks,
            rep.replicated_bytes,
            rep.version,
            rep.exposed_s * 1e6,
        );
        assert!(rep.dirty_blocks <= 33, "delta should track the hot region");
    }
    let committed = state.clone();
    let committed_version = store.version();
    assert_eq!(load_all(&mut store, &mut cluster), committed);

    // -- a failure lands mid-replication of the NEXT checkpoint --
    step(&mut state, ITERS);
    let shards = shards_of(&store, &state);
    let err = store
        .dataset_mut(DatasetId::FIRST)
        .unwrap()
        .resubmit_with_faults(
            &mut cluster,
            &shards,
            ResubmitMode::DeltaByChecksum,
            Overlap::Compute(1e-3),
            &mut |step, cluster| {
                if step == ResubmitStep::Staged {
                    let staging_v = committed_version + 1;
                    println!("\n*** PE 5 dies while version {staging_v} is staging ***");
                    cluster.kill(&[5]);
                }
            },
        )
        .unwrap_err();
    match err {
        Error::ResubmitAborted { version, .. } => {
            assert_eq!(version, committed_version);
            println!("staged version aborted; dataset still serves version {version}");
        }
        other => panic!("expected ResubmitAborted, got {other}"),
    }

    // -- recover and prove the fallback is byte-exact --
    let (_failed, map, _cost) = ulfm::recover(&mut cluster);
    store.rebalance_or_acknowledge(&mut cluster, &map).unwrap();
    let served = load_all(&mut store, &mut cluster);
    assert_eq!(store.version(), committed_version);
    assert_eq!(served, committed, "fallback must be the full previous version");
    assert_ne!(served, state, "the torn version must NOT be visible");
    println!(
        "after recovery: all {} blocks match version {} exactly (torn v{} invisible)",
        N_BLOCKS,
        committed_version,
        committed_version + 1
    );
    println!("iterative_checkpoint: OK");
}
