//! §VI-D.2 — comparison with reported measurements of other in-memory
//! checkpointing libraries (Fenix, GPI_CP, Lu).
//!
//! ReStore's own numbers reproduce the paper's SuperMUC-NG measurements:
//! 16 MiB per rank on 1536 ranks (32 nodes), data always crossing nodes.
//!
//! | configuration                                   | paper (ReStore) |
//! |--------------------------------------------------|-----------------|
//! | submit, r=1, consecutive IDs                     | 126 ± 3 ms      |
//! | restore 1 rank -> 1 rank                         | 21 ± 2 ms       |
//! | restore 1 rank -> scattered                      | 20 ± 5 ms       |
//! | submit, r=1, ID permutations                     | 215 ± 9 ms      |
//! | restore 1 rank -> 1 rank   (perms)               | 15 ± 3 ms       |
//! | restore 1 rank -> scattered (perms)              | 0.9 ± 0.2 ms    |
//!
//! Reported comparators: Fenix ~115 ms checkpoint @14.8 MB/rank/1000 ranks;
//! GPI_CP ~1 s init, ~200 ms checkpoint, ~15 ms restore; Lu ~1 s create /
//! ~2 s restore per 16 MiB (erasure-coded).

use restore::config::RestoreConfig;
use restore::metrics::{fmt_time, Stats, Table};
use restore::restore::load::{scatter_requests, single_target_requests};
use restore::restore::ReStore;
use restore::simnet::cluster::Cluster;
use restore::util::bench::sim_samples;

const P: usize = 1536;
const BYTES_PER_PE: usize = 16 * 1024 * 1024;
const BLOCK: usize = 64;
const REPS: usize = 10;

fn main() {
    println!("=== §VI-D.2: ReStore configured like the reported comparisons ===");
    println!("(p = {P}, 48 PEs/node, 16 MiB per rank, 10 repetitions)\n");

    let mut table = Table::new(vec!["operation", "paper", "measured (mean)", "p10..p90"]);
    let rows: Vec<(&str, &str, Stats)> = vec![
        ("submit, r=1, consecutive IDs", "126 ms", bench_op(Op::Submit, false, 1)),
        ("restore 1 rank -> 1 rank", "21 ms", bench_op(Op::LoadSingle, false, 1)),
        ("restore 1 rank -> scattered", "20 ms", bench_op(Op::LoadScattered, false, 1)),
        ("submit, r=1, ID permutations", "215 ms", bench_op(Op::Submit, true, 1)),
        ("restore 1 rank -> 1 rank (perms)", "15 ms", bench_op(Op::LoadSingle, true, 1)),
        ("restore 1 rank -> scattered (perms)", "0.9 ms", bench_op(Op::LoadScattered, true, 1)),
        ("submit, r=4 (paper default)", "-", bench_op(Op::Submit, true, 4)),
        ("restore 1 rank -> scattered (r=4, perms)", "-", bench_op(Op::LoadScattered, true, 4)),
    ];
    for (name, paper, stats) in rows {
        table.row(vec![
            name.to_string(),
            paper.to_string(),
            fmt_time(stats.mean),
            format!("{}..{}", fmt_time(stats.p10), fmt_time(stats.p90)),
        ]);
    }
    println!("{}", table.render());

    println!("reported numbers from the papers cited in §VI-D.2 (for context):");
    println!("  Fenix  [3]: ~115 ms checkpoint (14.8 MB/rank, 1000 ranks, r=1, Cray XK7)");
    println!("  GPI_CP[15]: ~1 s init, ~200 ms checkpoint, ~15 ms restore");
    println!("  Lu    [14]: ~1 s create / ~2 s restore per 16 MiB (erasure codes)");
    println!();
    println!("paper conclusion to verify: ReStore can be configured to checkpoint/restore");
    println!("in roughly the time of existing systems, and ID permutations cut scattered");
    println!("restore times by an order of magnitude while roughly doubling submit time.");
    let sub_plain = bench_op(Op::Submit, false, 1);
    let sub_perm = bench_op(Op::Submit, true, 1);
    let sc_plain = bench_op(Op::LoadScattered, false, 1);
    let sc_perm = bench_op(Op::LoadScattered, true, 1);
    println!(
        "  submit slowdown with perms: {:.2}x (paper: 215/126 = 1.7x) {}",
        sub_perm.mean / sub_plain.mean,
        ok((1.0..4.0).contains(&(sub_perm.mean / sub_plain.mean)))
    );
    println!(
        "  scattered-restore speedup with perms: {:.1}x (paper: 20/0.9 = 22x) {}",
        sc_plain.mean / sc_perm.mean,
        ok(sc_plain.mean / sc_perm.mean > 5.0)
    );
}

fn ok(b: bool) -> &'static str {
    if b {
        "[OK]"
    } else {
        "[MISMATCH]"
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Op {
    Submit,
    LoadSingle,
    LoadScattered,
}

fn bench_op(op: Op, perms: bool, r: usize) -> Stats {
    sim_samples(REPS, |rep| {
        // placement_offset=1: even r=1 stores the copy on the next rank
        // (Fenix's partner-copy scheme, see RestoreConfig docs)
        let cfg = RestoreConfig::builder(P, BLOCK, BYTES_PER_PE / BLOCK)
            .replicas(r)
            .perm_range_bytes(perms.then_some(256 * 1024))
            .placement_offset(1)
            .seed(0x7AB + rep)
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(P, 48);
        let mut store = ReStore::new(cfg, &cluster).unwrap();
        let t0 = cluster.now();
        store.submit_virtual(&mut cluster).unwrap();
        let submit_time = cluster.now() - t0;
        if op == Op::Submit {
            return submit_time;
        }
        // one rank fails; no IDL possible at r=1 here because its copy
        // lives on the neighbouring rank (shift) or scattered (perms)
        let dead = (37 + rep as usize) % P;
        cluster.kill(&[dead]);
        let reqs = match op {
            Op::LoadSingle => {
                let target = (dead + 1) % P;
                single_target_requests(&store, &[dead], target)
            }
            _ => scatter_requests(&store, &cluster, &[dead]),
        };
        let t1 = cluster.now();
        store.load(&mut cluster, &reqs).unwrap();
        cluster.now() - t1
    })
}
