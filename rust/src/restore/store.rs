//! Per-PE in-memory replica storage.
//!
//! Each PE stores `r` permuted *slices* (one per copy level, see
//! [`Distribution::stored_slice`]). A slice is a contiguous interval of the
//! permuted block ID space, so the store is just `r` flat buffers plus
//! interval arithmetic — block lookup is O(r), and the per-PE memory is
//! exactly the `r·n/p` blocks of the paper's §IV-C analysis (asserted in
//! tests and the `ablation_memory` bench).

use crate::restore::block::BlockRange;
use crate::restore::distribution::Distribution;

/// Storage payload of one slice.
#[derive(Debug, Clone)]
pub enum SliceBuf {
    /// Execution mode: the actual serialized blocks.
    Real(Vec<u8>),
    /// Cost-model mode: byte length only.
    Virtual(u64),
}

impl SliceBuf {
    pub fn len(&self) -> u64 {
        match self {
            SliceBuf::Real(v) => v.len() as u64,
            SliceBuf::Virtual(n) => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One stored slice: its permuted interval and the bytes.
#[derive(Debug, Clone)]
pub struct StoredSlice {
    pub range: BlockRange,
    pub buf: SliceBuf,
}

/// The replica store of a single PE.
#[derive(Debug, Clone, Default)]
pub struct PeStore {
    slices: Vec<StoredSlice>,
    block_size: usize,
}

impl PeStore {
    pub fn new(block_size: usize) -> Self {
        PeStore { slices: Vec::new(), block_size }
    }

    pub fn insert(&mut self, range: BlockRange, buf: SliceBuf) {
        debug_assert_eq!(buf.len(), range.len() * self.block_size as u64);
        self.slices.push(StoredSlice { range, buf });
    }

    pub fn slices(&self) -> &[StoredSlice] {
        &self.slices
    }

    /// Total bytes resident in this PE's replica store (§IV-C accounting).
    pub fn resident_bytes(&self) -> u64 {
        self.slices.iter().map(|s| s.buf.len()).sum()
    }

    /// Read `len` blocks starting at permuted block `start`; returns the
    /// bytes (execution mode) or None (cost-model mode). Panics if the
    /// range is not stored — callers must route via the distribution.
    pub fn read(&self, start: u64, len: u64) -> Option<&[u8]> {
        let want = BlockRange::new(start, start + len);
        for s in &self.slices {
            if s.range.intersect(&want) == Some(want) {
                return match &s.buf {
                    SliceBuf::Real(v) => {
                        let off = ((start - s.range.start) * self.block_size as u64) as usize;
                        let n = (len * self.block_size as u64) as usize;
                        Some(&v[off..off + n])
                    }
                    SliceBuf::Virtual(_) => None,
                };
            }
        }
        panic!("PeStore::read: permuted range [{start}, {}) not stored", start + len);
    }

    /// Does this PE hold the given permuted range?
    pub fn holds(&self, start: u64, len: u64) -> bool {
        let want = BlockRange::new(start, start + len);
        self.slices.iter().any(|s| s.range.intersect(&want) == Some(want))
    }

    /// Write bytes into an already-inserted slice (repair path).
    pub fn write(&mut self, start: u64, bytes_or_len: &SliceBuf) {
        let len = match bytes_or_len {
            SliceBuf::Real(v) => v.len() as u64 / self.block_size as u64,
            SliceBuf::Virtual(n) => n / self.block_size as u64,
        };
        let want = BlockRange::new(start, start + len);
        for s in &mut self.slices {
            if s.range.intersect(&want) == Some(want) {
                if let (SliceBuf::Real(dst), SliceBuf::Real(src)) = (&mut s.buf, bytes_or_len) {
                    let off = ((start - s.range.start) * self.block_size as u64) as usize;
                    dst[off..off + src.len()].copy_from_slice(src);
                }
                return;
            }
        }
        panic!("PeStore::write: permuted range [{start}, {}) not stored", start + len);
    }
}

/// Verify the §IV-C memory formula for a fully submitted store set:
/// every PE holds exactly `r * n/p` blocks.
pub fn assert_memory_invariant(stores: &[PeStore], dist: &Distribution) {
    let expect = dist.replicas() as u64 * dist.blocks_per_pe();
    for (pe, st) in stores.iter().enumerate() {
        let blocks: u64 = st.slices().iter().map(|s| s.range.len()).sum();
        assert_eq!(blocks, expect, "PE {pe}: stores {blocks} blocks, expected {expect}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_from_slice() {
        let mut st = PeStore::new(4);
        let bytes: Vec<u8> = (0..32).collect();
        st.insert(BlockRange::new(8, 16), SliceBuf::Real(bytes));
        assert_eq!(st.read(8, 1), Some(&[0u8, 1, 2, 3][..]));
        assert_eq!(st.read(10, 2), Some(&[8u8, 9, 10, 11, 12, 13, 14, 15][..]));
        assert!(st.holds(8, 8));
        assert!(!st.holds(7, 2));
        assert!(!st.holds(15, 2));
        assert_eq!(st.resident_bytes(), 32);
    }

    #[test]
    #[should_panic(expected = "not stored")]
    fn read_missing_panics() {
        let st = PeStore::new(4);
        st.read(0, 1);
    }

    #[test]
    fn virtual_slice_counts_bytes() {
        let mut st = PeStore::new(64);
        st.insert(BlockRange::new(0, 100), SliceBuf::Virtual(6400));
        assert_eq!(st.read(50, 10), None);
        assert_eq!(st.resident_bytes(), 6400);
        assert!(st.holds(0, 100));
    }

    #[test]
    fn write_updates_slice() {
        let mut st = PeStore::new(2);
        st.insert(BlockRange::new(0, 4), SliceBuf::Real(vec![0; 8]));
        st.write(1, &SliceBuf::Real(vec![9, 9, 7, 7]));
        assert_eq!(st.read(0, 4).unwrap(), &[0, 0, 9, 9, 7, 7, 0, 0]);
    }
}
