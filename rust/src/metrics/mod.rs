//! Benchmark metrics: repetition statistics and paper-style table output.
//!
//! The paper plots means with 10th/90th-percentile error bars over 10
//! repetitions (§VI-A); [`Stats`] reproduces exactly those summaries and
//! [`Table`] renders the series the benches print.

/// Summary statistics over benchmark repetitions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub mean: f64,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
    pub std: f64,
    pub n: usize,
}

impl Stats {
    pub fn from(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty());
        let mut s: Vec<f64> = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            mean,
            median: percentile_sorted(&s, 50.0),
            p10: percentile_sorted(&s, 10.0),
            p90: percentile_sorted(&s, 90.0),
            std: var.sqrt(),
            n,
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (rank - lo as f64)
    }
}

/// Format seconds with an adaptive unit, the way the paper quotes times.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// A fixed-column text table, printed by every figure bench.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "ragged table row");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!("{:>w$}", cells[i], w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_known_samples() {
        let s = Stats::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert!((s.p10 - 1.4).abs() < 1e-12);
        assert!((s.p90 - 4.6).abs() < 1e-12);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn stats_single_sample() {
        let s = Stats::from(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.p10, 7.0);
        assert_eq!(s.p90, 7.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn percentile_endpoints() {
        let v = [1.0, 2.0, 3.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 3.0);
        assert_eq!(percentile_sorted(&v, 50.0), 2.0);
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5), "2.50 s");
        assert_eq!(fmt_time(0.0215), "21.50 ms");
        assert_eq!(fmt_time(6.5e-4), "650.00 µs");
        assert_eq!(fmt_time(1e-8), "10 ns");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["p", "time"]);
        t.row(vec!["48", "1.2 ms"]);
        t.row(vec!["24576", "0.9 ms"]);
        let r = t.render();
        assert!(r.contains("24576"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[1].chars().filter(|&c| c == '-').count(), lines[1].len());
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_row_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only one"]);
    }
}
