//! Integration: the fault-tolerant applications run end-to-end over the
//! simulated cluster with real PJRT compute, and failures do not change
//! the computation's results (the paper's §VI-C correctness claim: the
//! shrinking recovery reloads *exactly* the lost input).
//!
//! Requires the `pjrt` feature; each test skips itself when
//! `make artifacts` has not run.

#![cfg(feature = "pjrt")]

use restore::apps::kmeans::{self, KmeansParams};
use restore::config::RestoreConfig;
use restore::runtime::Engine;
use restore::simnet::cluster::Cluster;

/// The engine, or `None` (skip) when `make artifacts` has not run.
fn load_engine() -> Option<Engine> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping PJRT test: {dir}/manifest.json missing (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(dir).expect("artifacts present but engine failed to load"))
}

fn kmeans_cfg(p: usize, params: &KmeansParams) -> RestoreConfig {
    let bytes = params.points_per_pe * params.dims * 4;
    RestoreConfig::builder(p, 64, bytes / 64)
        .replicas(4.min(p))
        .perm_range_bytes(Some(1024))
        .build()
        .unwrap()
}

#[test]
fn kmeans_execution_without_failures_converges() {
    let Some(mut engine) = load_engine() else { return };
    let mut cluster = Cluster::new_execution(4, 2);
    let params = KmeansParams { iterations: 8, ..KmeansParams::tiny(8) };
    let cfg = kmeans_cfg(4, &params);
    let rep = kmeans::run_execution(&mut cluster, &mut engine, &cfg, &params).unwrap();
    assert_eq!(rep.iterations_run, 8);
    assert_eq!(rep.failures, 0);
    assert!(rep.final_inertia > 0.0 && rep.final_inertia.is_finite());
    // Lloyd's algorithm monotonically decreases inertia: an 8-iteration run
    // must end at most as high as a 1-iteration run (the paper's random
    // shared starting centers can still land in a poor local optimum, so no
    // absolute bound).
    let mut one_iter = params.clone();
    one_iter.iterations = 1;
    let Some(mut engine2) = load_engine() else { return };
    let mut cluster2 = Cluster::new_execution(4, 2);
    let first = kmeans::run_execution(&mut cluster2, &mut engine2, &cfg, &one_iter).unwrap();
    assert!(
        rep.final_inertia <= first.final_inertia * (1.0 + 1e-5),
        "inertia rose: {} -> {}",
        first.final_inertia,
        rep.final_inertia
    );
    assert!(rep.wall_compute_s > 0.0);
    assert!(rep.sim_kmeans_loop_s > 0.0);
}

#[test]
fn kmeans_recovery_preserves_clustering_results() {
    // Run once without failures and once with a mid-run failure; the
    // recovered run must produce (nearly) identical centers — same points,
    // same math, only the partial-sum grouping differs (f32 ordering).
    let params = KmeansParams { iterations: 6, seed: 11, ..KmeansParams::tiny(6) };
    let cfg = kmeans_cfg(8, &params);

    let Some(mut e1) = load_engine() else { return };
    let mut c1 = Cluster::new_execution(8, 4);
    let clean = kmeans::run_execution(&mut c1, &mut e1, &cfg, &params).unwrap();

    let mut failing = params.clone();
    failing.failure_fraction = 0.3; // aggressive: expect ~2-3 failures
    let Some(mut e2) = load_engine() else { return };
    let mut c2 = Cluster::new_execution(8, 4);
    let faulty = kmeans::run_execution(&mut c2, &mut e2, &cfg, &failing).unwrap();

    assert!(faulty.failures > 0, "0.3 failure fraction over 6 iters should kill someone");
    let rel = (faulty.final_inertia - clean.final_inertia).abs() / clean.final_inertia;
    assert!(rel < 1e-3, "inertia diverged by {rel} after recovery");
    for (a, b) in faulty.final_centers.iter().zip(&clean.final_centers) {
        assert!((a - b).abs() < 1e-2, "center coord {a} vs {b}");
    }
    // failure run must be slower in simulated time and attribute the extra
    // cost to restore + MPI recovery
    assert!(faulty.sim_total_s > clean.sim_total_s);
    assert!(faulty.sim_restore_s > clean.sim_restore_s);
    assert!(faulty.sim_mpi_recovery_s > 0.0);
}

#[test]
fn kmeans_survives_cascading_failures_down_to_few_pes() {
    let params = KmeansParams {
        iterations: 10,
        seed: 3,
        failure_fraction: 0.6,
        ..KmeansParams::tiny(10)
    };
    let cfg = kmeans_cfg(8, &params);
    let Some(mut e) = load_engine() else { return };
    let mut cluster = Cluster::new_execution(8, 4);
    let rep = kmeans::run_execution(&mut cluster, &mut e, &cfg, &params).unwrap();
    assert_eq!(rep.iterations_run, 10);
    assert!(rep.failures >= 2);
    assert!(cluster.n_alive() >= 1);
    // all 8*256 points still clustered: counts sum preserved through the
    // padding-corrected multi-pass compute
    assert!(rep.final_inertia.is_finite());
}

#[test]
fn raxml_likelihood_identical_after_site_redistribution() {
    use restore::apps::raxml;
    use restore::apps::Ownership;
    use restore::restore::load::scatter_requests_for_ranges;
    use restore::restore::serialize::blocks_to_f32s;
    use restore::restore::ReStore;

    let Some(mut e) = load_engine() else { return };
    let p = 4;
    let sites_per_pe = 512;
    let mut cluster = Cluster::new_execution(p, 2);
    let mut site_data: Vec<Vec<f32>> =
        (0..p).map(|pe| raxml::generate_sites(5, pe, sites_per_pe)).collect();

    // baseline loglik with everyone alive
    let ll_before =
        raxml::evaluate_loglik(&mut cluster, &mut e, "phylo_step_small", &site_data).unwrap();
    assert!(ll_before.is_finite() && ll_before < 0.0);

    // submit sites (one 64 B block per site: 36 B payload + padding, the
    // layout raxml.rs documents), kill a PE, redistribute via ReStore
    let bs = 64;
    let spf = raxml::SITE_PAYLOAD_F32S;
    let blocks_per_pe = sites_per_pe; // 1 site = 1 block
    let cfg = RestoreConfig::builder(p, bs, blocks_per_pe).replicas(2).build().unwrap();
    let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
    let shards: Vec<Vec<u8>> = site_data
        .iter()
        .map(|d| {
            let mut out = Vec::with_capacity(sites_per_pe * bs);
            for site in d.chunks(spf) {
                for v in site {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out.resize(out.len() + bs - spf * 4, 0);
            }
            out
        })
        .collect();
    store.submit(&mut cluster, &shards).unwrap();

    cluster.kill(&[2]);
    let mut ownership = Ownership::identity(p, blocks_per_pe as u64);
    let gained = ownership.rebalance(&[2], &cluster.survivors(), 1);
    let reqs = scatter_requests_for_ranges(&gained);
    let out = store.load(&mut cluster, &reqs).unwrap();
    // append recovered sites (one per block) to each survivor
    for (req, shard) in reqs.iter().zip(&out.shards) {
        let bytes = shard.bytes.as_ref().unwrap();
        for block in bytes.chunks(bs) {
            site_data[req.pe].extend(blocks_to_f32s(block, spf));
        }
    }
    site_data[2].clear();

    let ll_after =
        raxml::evaluate_loglik(&mut cluster, &mut e, "phylo_step_small", &site_data).unwrap();
    // identical site multiset modulo f32 summation order
    let rel = (ll_after - ll_before).abs() / ll_before.abs();
    assert!(rel < 1e-5, "loglik {ll_before} -> {ll_after} (rel {rel})");
}
