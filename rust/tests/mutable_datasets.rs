//! Integration: the mutable-dataset lifecycle — versioned resubmit,
//! dataset deletion with slot reuse, and torn-checkpoint safety under
//! random interleavings of mutation, failure, and recovery.
//!
//! The golden contracts this suite pins:
//!
//! * **slot reuse** — `delete_dataset` frees a registry slot that the next
//!   `create_dataset` reuses; surviving `DatasetId`s never move, deleted
//!   ids answer `UnknownDataset` (also on double delete), and dataset 0
//!   (the facade's dataset) cannot be deleted.
//! * **committed-version oracle** — after ANY random interleaving of
//!   {full resubmit, delta resubmit, kill + recover, mid-resubmit kill},
//!   a whole-space load returns exactly the latest committed version's
//!   bytes — identical to what a FRESH single-version store submitted
//!   with that content serves. A resubmit aborted by a mid-flight kill
//!   changes nothing.
//! * **torn-resubmit safety at every boundary** — exercised both
//!   exhaustively (per `ResubmitStep`) in `restore/resubmit.rs` unit tests
//!   and probabilistically here under recovery chains.

use restore::config::RestoreConfig;
use restore::error::Error;
use restore::restore::block::{BlockRange, RangeSet};
use restore::restore::{DatasetId, LoadRequest, Overlap, ReStore, ResubmitMode, ResubmitStep};
use restore::simnet::cluster::Cluster;
use restore::simnet::ulfm;
use restore::util::rng::Rng;

const P: usize = 8;
const BS: usize = 8;
const BPP: usize = 32;
const N_BLOCKS: u64 = (P * BPP) as u64;

fn cfg() -> RestoreConfig {
    RestoreConfig::builder(P, BS, BPP)
        .replicas(2)
        .perm_range_blocks(Some(16))
        .build()
        .unwrap()
}

fn small_cfg(p: usize, salt: u64) -> RestoreConfig {
    RestoreConfig::builder(p, 16, 8).replicas(2).seed(salt).build().unwrap()
}

/// Cut a flat `n_blocks * bs` buffer into the per-rank shards the
/// dataset's CURRENT distribution expects (identity before any failure,
/// the §IV-B reshaped partition after a rebalance).
fn shards_of(rs: &ReStore, flat: &[u8]) -> Vec<Vec<u8>> {
    let dist = rs.distribution();
    (0..dist.world())
        .map(|j| {
            let sh = dist.shard_of(j);
            flat[(sh.start as usize) * BS..(sh.end as usize) * BS].to_vec()
        })
        .collect()
}

/// Load the whole original block space from the first survivor.
fn load_all(rs: &mut ReStore, cluster: &mut Cluster) -> Vec<u8> {
    let pe = cluster.survivors()[0];
    let reqs = vec![LoadRequest {
        pe,
        ranges: RangeSet::new(vec![BlockRange::new(0, N_BLOCKS)]),
    }];
    let out = rs.load(cluster, &reqs).unwrap();
    out.shards[0].bytes.clone().expect("execution mode")
}

// ---------------------------------------------------------------------------
// delete_dataset / create_dataset slot reuse
// ---------------------------------------------------------------------------

#[test]
fn delete_frees_slot_and_surviving_ids_stay_stable() {
    let cluster = Cluster::new_execution(4, 2);
    let mut rs = ReStore::new(small_cfg(4, 1), &cluster).unwrap();
    let a = rs.create_dataset(small_cfg(4, 2), &cluster).unwrap();
    let b = rs.create_dataset(small_cfg(4, 3), &cluster).unwrap();
    assert_eq!((a.index(), b.index()), (1, 2));

    let mut cluster = cluster;
    let shards_b: Vec<Vec<u8>> = (0..4).map(|pe| vec![pe as u8; 8 * 16]).collect();
    rs.dataset_mut(b).unwrap().submit(&mut cluster, &shards_b).unwrap();

    rs.delete_dataset(a).unwrap();
    // deleted id answers UnknownDataset everywhere, including double delete
    assert!(matches!(rs.dataset(a), Err(Error::UnknownDataset { .. })));
    assert!(matches!(rs.dataset_mut(a), Err(Error::UnknownDataset { .. })));
    assert!(matches!(rs.delete_dataset(a), Err(Error::UnknownDataset { .. })));
    // the surviving dataset keeps its id AND its bytes
    let reqs = vec![LoadRequest {
        pe: 0,
        ranges: RangeSet::new(vec![BlockRange::new(8, 16)]),
    }];
    let out = rs.dataset_mut(b).unwrap().load(&mut cluster, &reqs).unwrap();
    assert_eq!(out.shards[0].bytes.as_deref().unwrap(), &[1u8; 8 * 16][..]);
    // registry never compacts under live ids
    assert_eq!(rs.n_datasets(), 3);

    // create-after-delete reuses the freed slot; the new dataset is fresh
    let c = rs.create_dataset(small_cfg(4, 9), &cluster).unwrap();
    assert_eq!(c, a, "freed slot must be reused");
    assert_eq!(rs.n_datasets(), 3, "no registry growth on reuse");
    let ds = rs.dataset(c).unwrap();
    assert_eq!(ds.version(), 0);
    assert!(!ds.is_submitted());
    let shards_c: Vec<Vec<u8>> = (0..4).map(|pe| vec![0x40 | pe as u8; 8 * 16]).collect();
    rs.dataset_mut(c).unwrap().submit(&mut cluster, &shards_c).unwrap();
    assert_eq!(rs.dataset(c).unwrap().version(), 1);

    // dataset 0 backs the facade and cannot be deleted
    assert!(matches!(rs.delete_dataset(DatasetId::FIRST), Err(Error::Config(_))));
    // a config error during reuse keeps the slot free for the next attempt
    rs.delete_dataset(c).unwrap();
    let wrong_world = RestoreConfig::builder(5, 16, 8).replicas(1).build().unwrap();
    assert!(rs.create_dataset(wrong_world, &cluster).is_err());
    let again = rs.create_dataset(small_cfg(4, 11), &cluster).unwrap();
    assert_eq!(again, c);
}

#[test]
fn recovery_skips_deleted_tombstones() {
    let mut cluster = Cluster::new_execution(8, 4);
    let mut rs = ReStore::new(cfg(), &cluster).unwrap();
    let extra = rs.create_dataset(small_cfg(8, 4), &cluster).unwrap();
    let flat: Vec<u8> = (0..N_BLOCKS as usize * BS).map(|i| i as u8).collect();
    rs.submit(&mut cluster, &shards_of(&rs, &flat)).unwrap();
    let extra_shards: Vec<Vec<u8>> = (0..8).map(|pe| vec![pe as u8; 8 * 16]).collect();
    rs.dataset_mut(extra).unwrap().submit(&mut cluster, &extra_shards).unwrap();
    rs.delete_dataset(extra).unwrap();

    // the fused handshake must adopt the shrink without touching (or
    // resurrecting) the tombstone
    cluster.kill(&[3]);
    let (_failed, map, _cost) = ulfm::recover(&mut cluster);
    rs.rebalance_or_acknowledge(&mut cluster, &map).unwrap();
    assert!(matches!(rs.dataset(extra), Err(Error::UnknownDataset { .. })));
    assert_eq!(load_all(&mut rs, &mut cluster), flat);
}

// ---------------------------------------------------------------------------
// property test: random mutation/failure chains vs a fresh-store oracle
// ---------------------------------------------------------------------------

/// Mutate `k` random blocks of `flat` deterministically.
fn mutate_blocks(rng: &mut Rng, flat: &mut [u8], k: usize) -> RangeSet {
    let mut ranges = Vec::new();
    for _ in 0..k {
        let x = rng.gen_u64_below(N_BLOCKS);
        for b in &mut flat[(x as usize) * BS..(x as usize + 1) * BS] {
            *b = b.wrapping_mul(31).wrapping_add(rng.gen_index(251) as u8);
        }
        ranges.push(BlockRange::new(x, x + 1));
    }
    RangeSet::new(ranges)
}

#[test]
fn random_mutation_failure_chains_always_serve_the_committed_version() {
    for scenario in 0u64..6 {
        let mut rng = Rng::seed_from_u64(0xD15C0 ^ scenario);
        let mut cluster = Cluster::new_execution(P, 2);
        let mut rs = ReStore::new(cfg(), &cluster).unwrap();

        // committed-content oracle: the flat bytes of the latest version
        let mut oracle: Vec<u8> =
            (0..N_BLOCKS as usize * BS).map(|i| (i as u8) ^ scenario as u8).collect();
        rs.submit(&mut cluster, &shards_of(&rs, &oracle)).unwrap();
        let mut expected_version = 1u64;

        for _op in 0..10 {
            match rng.gen_index(4) {
                // full resubmit of fully fresh content
                0 => {
                    let mut next = oracle.clone();
                    for b in &mut next {
                        *b = b.wrapping_add(0x11);
                    }
                    let shards = shards_of(&rs, &next);
                    rs.resubmit(&mut cluster, &shards, ResubmitMode::Full, Overlap::Blocking)
                        .unwrap();
                    oracle = next;
                    expected_version += 1;
                }
                // delta resubmit of k dirty blocks (explicit set and
                // checksum diff must both commit the same content)
                1 => {
                    let mut next = oracle.clone();
                    let dirty = mutate_blocks(&mut rng, &mut next, 1 + rng.gen_index(6));
                    let shards = shards_of(&rs, &next);
                    let mode = if rng.gen_bool(0.5) {
                        ResubmitMode::Dirty(&dirty)
                    } else {
                        ResubmitMode::DeltaByChecksum
                    };
                    let rep = rs.resubmit(&mut cluster, &shards, mode, Overlap::Blocking).unwrap();
                    assert!(rep.dirty_blocks <= dirty.total_blocks());
                    oracle = next;
                    expected_version += 1;
                }
                // kill wave + full recovery (shrink + rebalance)
                2 => {
                    if cluster.n_alive() <= 4 {
                        continue;
                    }
                    let victims = cluster.survivors();
                    let v = victims[rng.gen_index(victims.len())];
                    cluster.kill(&[v]);
                    let (_failed, map, _cost) = ulfm::recover(&mut cluster);
                    rs.rebalance_or_acknowledge(&mut cluster, &map).unwrap();
                }
                // kill landing INSIDE a resubmit: aborts to the committed
                // version, then recover so later ops see a healthy layout
                _ => {
                    if cluster.n_alive() <= 4 {
                        continue;
                    }
                    let mut next = oracle.clone();
                    let dirty = mutate_blocks(&mut rng, &mut next, 3);
                    let shards = shards_of(&rs, &next);
                    let boundary = [
                        ResubmitStep::Validated,
                        ResubmitStep::Staged,
                        ResubmitStep::Charged,
                    ][rng.gen_index(3)];
                    let victims = cluster.survivors();
                    let v = victims[rng.gen_index(victims.len())];
                    let err = rs
                        .dataset_mut(DatasetId::FIRST)
                        .unwrap()
                        .resubmit_with_faults(
                            &mut cluster,
                            &shards,
                            ResubmitMode::Dirty(&dirty),
                            Overlap::Blocking,
                            &mut |s, c| {
                                if s == boundary {
                                    c.kill(&[v]);
                                }
                            },
                        )
                        .unwrap_err();
                    assert!(
                        matches!(err, Error::ResubmitAborted { .. }),
                        "boundary {boundary:?}: {err}"
                    );
                    // oracle unchanged: the staged version never committed
                    let (_failed, map, _cost) = ulfm::recover(&mut cluster);
                    rs.rebalance_or_acknowledge(&mut cluster, &map).unwrap();
                }
            }

            // invariant after EVERY op: loads serve the oracle bytes and
            // the version counter matches the committed lineage
            assert_eq!(
                load_all(&mut rs, &mut cluster),
                oracle,
                "scenario {scenario}: committed version diverged from oracle"
            );
            assert_eq!(rs.version(), expected_version, "scenario {scenario}");
        }

        // final cross-check against a genuinely fresh single-version store:
        // submit the oracle content once and compare whole-space loads.
        let mut fresh_cluster = Cluster::new_execution(P, 2);
        let mut fresh = ReStore::new(cfg(), &fresh_cluster).unwrap();
        fresh.submit(&mut fresh_cluster, &shards_of(&fresh, &oracle)).unwrap();
        assert_eq!(
            load_all(&mut fresh, &mut fresh_cluster),
            load_all(&mut rs, &mut cluster),
            "scenario {scenario}: mutated store diverged from fresh oracle store"
        );
    }
}
