//! Failure schedules.
//!
//! The paper's application experiments (§VI-C) "simulate an expected failure
//! of 1 % of all nodes distributed uniformly at random during these
//! iterations ... by determining a suitable probability for each PE to fail
//! in each iteration" (a discrete exponential decay). Fig 3 kills PEs
//! uniformly at random one by one. Node-correlated failures (whole node
//! dies, taking its 48 PEs) are the failure mode the placement's
//! node-spreading argument (§IV-A) defends against — provided here for the
//! ablation benches.

use crate::simnet::cluster::Cluster;
use crate::simnet::topology::Topology;
use crate::util::rng::Rng;

/// Discrete exponential-decay schedule: each alive PE fails independently
/// with probability `q` per iteration, with `q` chosen so that the expected
/// surviving fraction after `iterations` equals `1 - total_fraction`.
#[derive(Debug, Clone, Copy)]
pub struct ExpDecaySchedule {
    pub per_iteration_prob: f64,
}

impl ExpDecaySchedule {
    pub fn new(total_fraction: f64, iterations: usize) -> Self {
        assert!((0.0..1.0).contains(&total_fraction));
        assert!(iterations > 0);
        // (1 - q)^iterations = 1 - total_fraction
        let q = 1.0 - (1.0 - total_fraction).powf(1.0 / iterations as f64);
        ExpDecaySchedule { per_iteration_prob: q }
    }

    /// Sample the ranks failing this iteration from `alive`.
    pub fn sample(&self, rng: &mut Rng, alive: &[usize]) -> Vec<usize> {
        alive
            .iter()
            .copied()
            .filter(|_| rng.gen_bool(self.per_iteration_prob))
            .collect()
    }
}

/// Kill `count` PEs chosen uniformly at random from `alive` (Fig 3 setup).
pub fn uniform_kills(rng: &mut Rng, alive: &[usize], count: usize) -> Vec<usize> {
    let mut pool: Vec<usize> = alive.to_vec();
    rng.shuffle(&mut pool);
    pool.truncate(count.min(pool.len()));
    pool
}

/// Whole-node failure: all PEs of `node` die together.
pub fn node_failure(topo: &Topology, node: usize) -> Vec<usize> {
    topo.ranks_on_node(node).collect()
}

/// One silent-corruption strike: flip `bit` (0–7) of resident byte `byte`
/// on PE `pe`. `byte` indexes the concatenation of that PE's real replica
/// payloads, exactly the addressing of
/// [`Dataset::corrupt_bit`](crate::restore::Dataset::corrupt_bit) /
/// `PeStore::corrupt_bit_at` — apply a strike by forwarding the triple.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CorruptionStrike {
    pub pe: usize,
    pub byte: u64,
    pub bit: u8,
}

/// Silent-corruption model: bit flips arrive as a Poisson process against
/// the cluster clock, at `byte_flip_rate_per_s` per *resident byte* per
/// second (so a PE holding twice the replica bytes soaks up twice the
/// strikes — the standard memory-fault scaling). With probability
/// `node_burst_prob` a strike is *node-correlated*: `burst_flips` extra
/// flips pepper random PEs of the victim's node (the DRAM-channel /
/// row-hammer-style burst the per-block checksums must catch copy by
/// copy). The model owns its RNG, so attaching it to a [`MtbfStorm`]
/// leaves the storm's kill sequence bit-for-bit unchanged.
#[derive(Debug, Clone)]
pub struct CorruptionModel {
    byte_flip_rate_per_s: f64,
    node_burst_prob: f64,
    burst_flips: usize,
    rng: Rng,
    /// Reusable Fenwick (binary indexed) tree over the alive residents,
    /// rebuilt once per sampled window: strikes then locate their victim
    /// byte in O(log p) instead of an O(p) prefix walk per strike.
    fenwick: Vec<u64>,
}

impl CorruptionModel {
    pub fn new(
        byte_flip_rate_per_s: f64,
        node_burst_prob: f64,
        burst_flips: usize,
        seed: u64,
    ) -> Self {
        assert!(byte_flip_rate_per_s >= 0.0);
        assert!((0.0..=1.0).contains(&node_burst_prob));
        CorruptionModel {
            byte_flip_rate_per_s,
            node_burst_prob,
            burst_flips,
            rng: Rng::seed_from_u64(seed),
            fenwick: Vec::new(),
        }
    }

    /// Sample the strikes landing in the window `[t0, t1)`. `resident[pe]`
    /// is the corruptible (real) byte count of cluster rank `pe` — what
    /// `PeStore::real_bytes` reports, summed across datasets; missing
    /// entries count as 0. Victim bytes are drawn uniformly over the alive
    /// resident payload — a Fenwick tree built once per window locates each
    /// strike in O(log p), landing on exactly the (victim, byte) the
    /// verbatim prefix walk over `survivors_iter` would — so strikes
    /// concentrate where the data is. Deterministic per seed.
    pub fn sample_window(
        &mut self,
        cluster: &Cluster,
        t0: f64,
        t1: f64,
        resident: &[u64],
    ) -> Vec<CorruptionStrike> {
        let mut strikes = Vec::new();
        if t1 <= t0 || self.byte_flip_rate_per_s <= 0.0 {
            return strikes;
        }
        // Build the Fenwick tree over the alive residents in increasing
        // rank order (1-based; entry i owns positions (i - lowbit(i), i]).
        let alive = cluster.alive_ranks();
        let n = alive.len();
        self.fenwick.clear();
        self.fenwick.resize(n + 1, 0);
        let mut total = 0u64;
        for i in 1..=n {
            let r = resident.get(alive[i - 1] as usize).copied().unwrap_or(0);
            total += r;
            self.fenwick[i] += r;
            let parent = i + (i & i.wrapping_neg());
            if parent <= n {
                self.fenwick[parent] += self.fenwick[i];
            }
        }
        if total == 0 {
            return strikes;
        }
        let rate = self.byte_flip_rate_per_s * total as f64;
        let mut t = t0;
        loop {
            t += -(1.0 - self.rng.gen_f64()).ln() / rate;
            if t >= t1 {
                return strikes;
            }
            let target = self.rng.gen_index(total as usize) as u64;
            // Descend: largest alive-list prefix whose resident sum stays
            // <= target; the next entry is the victim, the remainder the
            // byte offset inside its payload (identical to the linear walk,
            // zero-resident survivors skipped for free).
            let mut pos = 0usize;
            let mut rem = target;
            let mut step = n.next_power_of_two();
            while step > 0 {
                let next = pos + step;
                if next <= n && self.fenwick[next] <= rem {
                    rem -= self.fenwick[next];
                    pos = next;
                }
                step >>= 1;
            }
            debug_assert!(pos < n, "descend must land inside total");
            let victim = alive[pos] as usize;
            let target = rem;
            let bit = self.rng.gen_index(8) as u8;
            strikes.push(CorruptionStrike { pe: victim, byte: target, bit });
            if self.rng.gen_bool(self.node_burst_prob) {
                let topo = cluster.topology();
                let peers: Vec<usize> = topo
                    .ranks_on_node(topo.node_of(victim))
                    .filter(|&pe| {
                        cluster.is_alive(pe) && resident.get(pe).copied().unwrap_or(0) > 0
                    })
                    .collect();
                for _ in 0..self.burst_flips {
                    let pe = peers[self.rng.gen_index(peers.len())];
                    let byte = self.rng.gen_index(resident[pe] as usize) as u64;
                    let bit = self.rng.gen_index(8) as u8;
                    strikes.push(CorruptionStrike { pe, byte, bit });
                }
            }
        }
    }
}

/// One storm arrival: the wall-clock the failure strikes at, the ranks it
/// takes down, and the silent-corruption strikes that accumulated since
/// the previous event (empty unless a [`CorruptionModel`] is attached).
#[derive(Debug, Clone, PartialEq)]
pub struct StormEvent {
    /// Simulated absolute time of the failure (seconds; compare against
    /// `Cluster::now()`).
    pub at_s: f64,
    /// Cluster ranks killed by this event (one PE, or a whole node for a
    /// correlated burst).
    pub kills: Vec<usize>,
    /// Bit flips that landed in `[previous event, at_s)` — apply them to
    /// the stores *before* processing the kills (the rot happened while
    /// the machine was still running).
    pub corruption: Vec<CorruptionStrike>,
}

/// MTBF-driven failure storm: failures arrive as a Poisson process against
/// the simulated cluster clock. Each *PE* has mean time between failures
/// `pe_mtbf_s`, so with `a` alive communicator members the cluster-level
/// failure rate is `a / pe_mtbf_s` and inter-arrival gaps are exponential
/// with that rate — the standard memoryless large-machine failure model
/// (and the continuous-time version of the paper's §VI-C per-iteration
/// failure probability). With probability `node_burst_prob` an arrival is
/// *node-correlated*: the victim's whole node dies together, the failure
/// mode §IV-A's node-spreading placement defends against.
#[derive(Debug, Clone)]
pub struct MtbfStorm {
    pe_mtbf_s: f64,
    node_burst_prob: f64,
    rng: Rng,
    corruption: Option<CorruptionModel>,
}

impl MtbfStorm {
    pub fn new(pe_mtbf_s: f64, node_burst_prob: f64, seed: u64) -> Self {
        assert!(pe_mtbf_s > 0.0, "MTBF must be positive");
        assert!((0.0..=1.0).contains(&node_burst_prob));
        MtbfStorm { pe_mtbf_s, node_burst_prob, rng: Rng::seed_from_u64(seed), corruption: None }
    }

    /// Attach a silent-corruption model: every event sampled through
    /// [`MtbfStorm::next_event_in`] then carries the bit flips that landed
    /// between the previous event and this one. The model has its own RNG,
    /// so the kill sequence is bit-for-bit the one the plain storm
    /// produces with the same seed.
    pub fn with_corruption(mut self, model: CorruptionModel) -> Self {
        self.corruption = Some(model);
        self
    }

    /// Sample the next failure event after `cluster.now()`. Returns `None`
    /// once fewer than two communicator members survive (no storm left to
    /// weather). The victim is drawn uniformly from the alive members via
    /// the allocation-free survivor iterator; a node burst widens it to
    /// the victim's whole node (already-dead neighbors are no-ops at
    /// `Cluster::kill`). Any attached corruption model is skipped (no
    /// resident-byte map given) — use [`MtbfStorm::next_event_in`].
    pub fn next_event(&mut self, cluster: &Cluster) -> Option<StormEvent> {
        self.sample_kill_event(cluster)
    }

    /// [`MtbfStorm::next_event`] plus silent corruption: `resident[pe]`
    /// gives each cluster rank's corruptible byte count (see
    /// [`CorruptionModel::sample_window`]), and the returned event's
    /// `corruption` holds the strikes accumulated over the inter-arrival
    /// window `[cluster.now(), event.at_s)`.
    pub fn next_event_in(&mut self, cluster: &Cluster, resident: &[u64]) -> Option<StormEvent> {
        let mut ev = self.sample_kill_event(cluster)?;
        if let Some(model) = &mut self.corruption {
            ev.corruption = model.sample_window(cluster, cluster.now(), ev.at_s, resident);
        }
        Some(ev)
    }

    fn sample_kill_event(&mut self, cluster: &Cluster) -> Option<StormEvent> {
        let alive = cluster.n_alive();
        if alive < 2 {
            return None;
        }
        let rate = alive as f64 / self.pe_mtbf_s;
        let gap_s = -(1.0 - self.rng.gen_f64()).ln() / rate;
        // O(1) pick from the cluster's dense alive list — same increasing
        // rank order as `survivors_iter().nth(..)`, so the victim sequence
        // per seed is unchanged.
        let victim = cluster.alive_ranks()[self.rng.gen_index(alive)] as usize;
        let kills = if self.rng.gen_bool(self.node_burst_prob) {
            let topo = cluster.topology();
            topo.ranks_on_node(topo.node_of(victim)).collect()
        } else {
            vec![victim]
        };
        Some(StormEvent { at_s: cluster.now() + gap_s, kills, corruption: Vec::new() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_decay_hits_target_fraction_in_expectation() {
        let sched = ExpDecaySchedule::new(0.01, 500);
        // survival after 500 iterations = (1-q)^500 = 0.99
        let survive = (1.0 - sched.per_iteration_prob).powi(500);
        assert!((survive - 0.99).abs() < 1e-12);
    }

    #[test]
    fn exp_decay_samples_roughly_one_percent() {
        let mut rng = Rng::seed_from_u64(7);
        let sched = ExpDecaySchedule::new(0.01, 500);
        let mut alive: Vec<usize> = (0..24576).collect();
        for _ in 0..500 {
            let dead = sched.sample(&mut rng, &alive);
            alive.retain(|r| !dead.contains(r));
        }
        let frac = 1.0 - alive.len() as f64 / 24576.0;
        // paper observed "up to 262 PEs failing" at 24576 (≈1.07 %)
        assert!(frac > 0.005 && frac < 0.02, "fraction {frac}");
    }

    #[test]
    fn uniform_kills_are_distinct_and_alive() {
        let mut rng = Rng::seed_from_u64(1);
        let alive: Vec<usize> = (0..100).step_by(2).collect();
        let k = uniform_kills(&mut rng, &alive, 10);
        assert_eq!(k.len(), 10);
        let set: std::collections::HashSet<_> = k.iter().collect();
        assert_eq!(set.len(), 10);
        for r in &k {
            assert!(alive.contains(r));
        }
    }

    #[test]
    fn uniform_kills_caps_at_pool() {
        let mut rng = Rng::seed_from_u64(2);
        assert_eq!(uniform_kills(&mut rng, &[1, 2, 3], 10).len(), 3);
    }

    #[test]
    fn node_failure_kills_whole_node() {
        let topo = Topology::new(100, 48);
        assert_eq!(node_failure(&topo, 1), (48..96).collect::<Vec<_>>());
        assert_eq!(node_failure(&topo, 2), (96..100).collect::<Vec<_>>());
    }

    #[test]
    fn mtbf_storm_gaps_have_exponential_mean() {
        // 64 PEs at 6400 s MTBF each -> cluster rate 1/100 s^-1, so the
        // mean inter-arrival gap is ~100 s (law of large numbers check)
        let cluster = Cluster::new_execution(64, 8);
        let mut storm = MtbfStorm::new(6400.0, 0.0, 42);
        let n = 4000;
        let mut total = 0.0;
        for _ in 0..n {
            let ev = storm.next_event(&cluster).unwrap();
            assert_eq!(ev.kills.len(), 1);
            assert!(cluster.is_alive(ev.kills[0]));
            total += ev.at_s - cluster.now();
        }
        let mean = total / n as f64;
        assert!((90.0..110.0).contains(&mean), "mean gap {mean}");
    }

    #[test]
    fn mtbf_storm_is_deterministic_and_rate_scales_with_survivors() {
        let mut a = MtbfStorm::new(1000.0, 0.25, 7);
        let mut b = MtbfStorm::new(1000.0, 0.25, 7);
        let mut cluster = Cluster::new_execution(32, 8);
        for _ in 0..20 {
            let ea = a.next_event(&cluster).unwrap();
            let eb = b.next_event(&cluster).unwrap();
            assert_eq!(ea, eb);
            cluster.kill(&ea.kills);
            if cluster.n_alive() < 2 {
                break;
            }
        }
        // once fewer than two members survive the storm ends
        let mut tiny = Cluster::new_execution(2, 2);
        tiny.kill(&[0]);
        assert!(a.next_event(&tiny).is_none());
    }

    #[test]
    fn mtbf_storm_node_bursts_take_whole_nodes() {
        let cluster = Cluster::new_execution(96, 48);
        let mut storm = MtbfStorm::new(100.0, 1.0, 3);
        let ev = storm.next_event(&cluster).unwrap();
        assert_eq!(ev.kills.len(), 48);
        let node = cluster.topology().node_of(ev.kills[0]);
        assert_eq!(ev.kills, node_failure(cluster.topology(), node));
    }

    #[test]
    fn corruption_model_is_deterministic_and_in_bounds() {
        let mut cluster = Cluster::new_execution(16, 4);
        cluster.kill(&[3, 7]);
        let resident: Vec<u64> = (0..16).map(|pe| (pe as u64 + 1) * 512).collect();
        let mut a = CorruptionModel::new(1.0e-5, 0.3, 2, 99);
        let mut b = CorruptionModel::new(1.0e-5, 0.3, 2, 99);
        let sa = a.sample_window(&cluster, 0.0, 5000.0, &resident);
        let sb = b.sample_window(&cluster, 0.0, 5000.0, &resident);
        assert_eq!(sa, sb, "same seed, same strikes");
        assert!(!sa.is_empty(), "rate · bytes · window ≫ 1 must strike");
        for s in &sa {
            assert!(cluster.is_alive(s.pe), "dead PEs hold nothing corruptible");
            assert!(s.byte < resident[s.pe], "strike inside the resident payload");
            assert!(s.bit < 8);
        }
    }

    /// The Fenwick descend must land every strike on exactly the
    /// (victim, byte) the seed reference's O(p)-per-strike linear prefix
    /// walk produced — replayed here verbatim against the same RNG stream,
    /// over a lumpy resident map with dead PEs, parked/lost spares,
    /// zero-resident survivors, and node bursts.
    #[test]
    fn fenwick_strikes_match_verbatim_prefix_walk() {
        let mut cluster = Cluster::with_spares(24, 4, 4);
        cluster.kill(&[2, 11, 17, 25]);
        let resident: Vec<u64> = (0..cluster.world() as u64)
            .map(|pe| if pe % 5 == 0 { 0 } else { (pe * 37) % 900 + 1 })
            .collect();
        let (rate_per_byte, burst_prob, burst_flips, seed) = (2.0e-5, 0.4, 2usize, 123u64);
        let mut model = CorruptionModel::new(rate_per_byte, burst_prob, burst_flips, seed);
        let got = model.sample_window(&cluster, 0.0, 4000.0, &resident);
        assert!(!got.is_empty(), "rate · bytes · window ≫ 1 must strike");

        let mut rng = Rng::seed_from_u64(seed);
        let total: u64 = cluster.survivors_iter().map(|pe| resident[pe]).sum();
        let rate = rate_per_byte * total as f64;
        let mut want = Vec::new();
        let mut t = 0.0;
        loop {
            t += -(1.0 - rng.gen_f64()).ln() / rate;
            if t >= 4000.0 {
                break;
            }
            let mut target = rng.gen_index(total as usize) as u64;
            let mut victim = usize::MAX;
            for pe in cluster.survivors_iter() {
                if target < resident[pe] {
                    victim = pe;
                    break;
                }
                target -= resident[pe];
            }
            let bit = rng.gen_index(8) as u8;
            want.push(CorruptionStrike { pe: victim, byte: target, bit });
            if rng.gen_bool(burst_prob) {
                let topo = cluster.topology();
                let peers: Vec<usize> = topo
                    .ranks_on_node(topo.node_of(victim))
                    .filter(|&pe| cluster.is_alive(pe) && resident[pe] > 0)
                    .collect();
                for _ in 0..burst_flips {
                    let pe = peers[rng.gen_index(peers.len())];
                    let byte = rng.gen_index(resident[pe] as usize) as u64;
                    let bit = rng.gen_index(8) as u8;
                    want.push(CorruptionStrike { pe, byte, bit });
                }
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn corruption_rate_scales_with_resident_bytes_and_window() {
        let cluster = Cluster::new_execution(8, 4);
        let resident = vec![100_000u64; 8]; // 8e5 bytes total
        // rate 2e-5 per byte-second over 1000 s → mean 8e5·2e-5·1000 = 16e3?
        // keep it small: 2.5e-8 → mean 0.02/s · 1000 s = 20 strikes
        let mut model = CorruptionModel::new(2.5e-8, 0.0, 0, 17);
        let mut n = 0usize;
        let windows = 50;
        for w in 0..windows {
            let t0 = w as f64 * 1000.0;
            n += model.sample_window(&cluster, t0, t0 + 1000.0, &resident).len();
        }
        let mean = n as f64 / windows as f64;
        assert!((14.0..26.0).contains(&mean), "mean strikes per window {mean}");
    }

    #[test]
    fn corruption_empty_window_or_payload_is_quiet() {
        let cluster = Cluster::new_execution(4, 2);
        let mut model = CorruptionModel::new(1.0, 0.5, 3, 1);
        assert!(model.sample_window(&cluster, 10.0, 10.0, &[64u64; 4]).is_empty());
        assert!(model.sample_window(&cluster, 0.0, 100.0, &[0u64; 4]).is_empty());
        assert!(model.sample_window(&cluster, 0.0, 100.0, &[]).is_empty());
        let mut zero = CorruptionModel::new(0.0, 0.0, 0, 1);
        assert!(zero.sample_window(&cluster, 0.0, 1.0e9, &[64u64; 4]).is_empty());
    }

    #[test]
    fn corruption_bursts_stay_on_the_victims_node() {
        let cluster = Cluster::new_execution(16, 4);
        let resident = vec![4096u64; 16];
        let mut model = CorruptionModel::new(1.0e-6, 1.0, 3, 5);
        let strikes = model.sample_window(&cluster, 0.0, 2000.0, &resident);
        assert!(strikes.len() >= 4, "every strike drags 3 burst flips along");
        assert_eq!(strikes.len() % 4, 0);
        let topo = cluster.topology();
        for group in strikes.chunks(4) {
            let node = topo.node_of(group[0].pe);
            for s in group {
                assert_eq!(topo.node_of(s.pe), node, "burst flip left the node");
            }
        }
    }

    #[test]
    fn storm_with_corruption_keeps_kills_and_fills_the_window() {
        let cluster = Cluster::new_execution(32, 8);
        let resident = vec![1u64 << 20; 32];
        let mut plain = MtbfStorm::new(1000.0, 0.0, 7);
        let mut rotten = MtbfStorm::new(1000.0, 0.0, 7)
            .with_corruption(CorruptionModel::new(1.0e-8, 0.0, 0, 11));
        let pe = plain.next_event(&cluster).unwrap();
        let re = rotten.next_event_in(&cluster, &resident).unwrap();
        assert_eq!(pe.kills, re.kills, "kill sequence unchanged by the model");
        assert_eq!(pe.at_s, re.at_s);
        assert!(pe.corruption.is_empty());
        // ~32 MiB · 1e-8/Bs ≈ 0.33 strikes/s over a ~31 s mean gap: usually
        // some strikes, always inside the window's payload bounds
        for s in &re.corruption {
            assert!(s.byte < resident[s.pe]);
        }
        // next_event on a corruption-armed storm stays quiet (no resident map)
        assert!(rotten.next_event(&cluster).unwrap().corruption.is_empty());
    }
}
