//! Recovery policies: *what to do* with a failure, built from the `ulfm`
//! primitives and the fused reshape/repair handshakes.
//!
//! The paper's library (§IV-B) always **shrinks**: survivors adopt a
//! smaller communicator and ReStore rewrites its layout over the `p' < p`
//! world. The fault-tolerance literature calls this one corner of the
//! "shrink or substitute" design space — the alternative keeps the world
//! size by seating standby (spare) PEs in the dead ranks' positions
//! (FTHP-MPI-style replacement), or shrinks now and *re-grows* to the
//! target size once spares are available. This module packages all three
//! as interchangeable [`RecoveryPolicy`] strategies over the same
//! handshake skeleton:
//!
//! 1. `ulfm::agree` — survivors agree on the failure set;
//! 2. one of `ulfm::shrink` / `ulfm::substitute` / `ulfm::grow` — the
//!    communicator is reshaped (epoch bump), yielding a [`RankMap`];
//! 3. [`ReStore::rebalance_or_acknowledge_all`] — every dataset adopts the
//!    new world with ONE fused migration all-to-all (or acknowledges);
//! 4. if any acknowledged dataset still references dead ranks, ONE fused
//!    [`ReStore::repair_replicas_all`] round restores its replication
//!    level in place (§IV-E).
//!
//! Reconfiguration is version-safe for mutable datasets: both adoption
//! paths (rebalance and acknowledge) drop any in-flight `resubmit` staging
//! and carry only the latest *committed* version forward — a checkpoint
//! interrupted by a failure storm aborts to the previous complete version
//! rather than migrating half-replicated state.
//!
//! Each policy degrades gracefully instead of failing: [`Substitute`]
//! falls back to a plain shrink when the spare pool cannot cover the dead
//! (`degraded = true` in the outcome), and [`ShrinkThenRegrow`] re-grows
//! as far as the pool allows. Policies are driven repeatedly by the
//! MTBF failure storms in `simnet::failure` (see
//! `examples/failure_storm.rs` and `benches/policies.rs`).
//!
//! ## Mid-recovery failures
//!
//! A PE can die *while* the handshake runs. The epoch discipline makes
//! that safe — a kill between the `ulfm` reshape and the fused rebalance
//! invalidates the map, and the rebalance aborts with
//! [`Error::StaleRankMap`] before any dataset layout is touched — but
//! safe-and-stuck is not recovery. [`RecoveryPolicy::recover_with_faults`]
//! closes the loop: the handshake is retried against the fresh survivor
//! set (a new agree + reshape each attempt, each under a new epoch), up to
//! [`MAX_RECOVERY_ATTEMPTS`] times. If failures outpace every attempt,
//! the policy degrades to the always-convergent floor: one final shrink
//! plus an acknowledge-only adoption (epoch catch-up and dead-store
//! reclaim, no migration — an epoch-only step no concurrent kill can
//! invalidate), reported with `degraded = true`. The injection hook fires
//! at every [`RecoveryStep`] boundary, so tests and storms can land kills
//! at each window of the handshake.
//!
//! [`Error::StaleRankMap`]: crate::error::Error::StaleRankMap

use crate::error::{Error, Result};
use crate::restore::rebalance::RebalanceReport;
use crate::restore::repair::{RepairReport, RepairScheme};
use crate::restore::ReStore;
use crate::simnet::cluster::Cluster;
use crate::simnet::network::PhaseCost;
use crate::simnet::ulfm::{self, RankMap};

/// How a [`RecoveryPolicy`] reshaped the communicator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryAction {
    /// Survivors adopted a smaller communicator (`p' ≤ p`).
    Shrunk { new_world: usize },
    /// Spares were seated in the dead ranks' positions (`p' = p`).
    Substituted { replaced: usize },
    /// Survivors shrank, then re-grew with spares (`p'` may still be
    /// below the policy's target if the pool ran short).
    Regrown { shrunk_to: usize, regrown_to: usize },
}

/// Everything one [`RecoveryPolicy::recover`] call did.
#[derive(Debug, Clone)]
pub struct RecoveryOutcome {
    /// The agreed failure set (every rank that has died while active,
    /// cumulative across waves — what `ulfm::agree` returns).
    pub failed: Vec<usize>,
    /// Which communicator reshape the policy chose.
    pub action: RecoveryAction,
    /// The policy could not do what it was asked and fell back: a
    /// [`Substitute`] that shrank for lack of spares, or a
    /// [`ShrinkThenRegrow`] that stopped short of its target world.
    pub degraded: bool,
    /// The rank map of the final communicator (the one every dataset's
    /// layout now addresses).
    pub map: RankMap,
    /// Per-dataset reshape outcomes in id order: `Some(report)` where a
    /// §IV-B rebalance ran, `None` where the dataset acknowledged.
    pub dataset_outcomes: Vec<Option<RebalanceReport>>,
    /// Per-dataset §IV-E repair reports, when an in-place repair round
    /// ran (only when some acknowledged dataset still referenced dead
    /// ranks); `None` when no repair was needed.
    pub repair_outcomes: Option<Vec<Option<RepairReport>>>,
    /// Agreement + reshape cost (the `ulfm` share of the recovery; the
    /// migration/repair costs are in the per-dataset reports).
    pub ulfm_cost: PhaseCost,
    /// Simulated wall-clock the whole recovery took (`Cluster::now`
    /// delta: agree + reshape + fused migration + fused repair).
    pub recovery_time_s: f64,
}

/// Step boundaries of one recovery attempt at which
/// [`RecoveryPolicy::recover_with_faults`] fires its injection hook —
/// the windows where a concurrent failure can land.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryStep {
    /// After `ulfm::agree`, before the communicator reshape. A kill here
    /// is absorbed silently: the reshape reads the cluster's current
    /// state, so the map it produces is already consistent with the death
    /// (the reported `failed` set lags one wave, as real ULFM agreement
    /// would).
    Agreed,
    /// After the `ulfm` reshape (epoch bumped, map produced), before the
    /// fused rebalance installs any layout — the critical window: a kill
    /// here stales the map, the rebalance aborts with every dataset's old
    /// layout byte-intact, and the handshake retries.
    Reshaped,
    /// After the fused rebalance/acknowledge, before the repair round. A
    /// kill here is absorbed: `needs_repair` is evaluated after the
    /// injection, so freshly lost replicas of acknowledged datasets join
    /// this round's repair; rebalanced datasets heal on the next recover.
    Rebalanced,
}

/// A strategy for bringing cluster *and* store from "some members died"
/// back to "every dataset loadable at full replication" — the full
/// agree → reshape → rebalance/acknowledge → repair handshake.
pub trait RecoveryPolicy {
    /// Short stable name for reports and bench rows.
    fn name(&self) -> &'static str;

    /// Run one full recovery against the current failure set.
    fn recover(&mut self, cluster: &mut Cluster, store: &mut ReStore) -> Result<RecoveryOutcome> {
        self.recover_with_faults(cluster, store, &mut |_, _| {})
    }

    /// [`RecoveryPolicy::recover`] with a fault-injection hook fired at
    /// every [`RecoveryStep`] boundary. The handshake retries (fresh
    /// agree + reshape under a new epoch) whenever an injected failure
    /// stales the map mid-attempt, up to [`MAX_RECOVERY_ATTEMPTS`] times,
    /// then degrades to the acknowledge-only floor (`degraded = true`).
    fn recover_with_faults(
        &mut self,
        cluster: &mut Cluster,
        store: &mut ReStore,
        inject: &mut dyn FnMut(RecoveryStep, &mut Cluster),
    ) -> Result<RecoveryOutcome>;
}

/// Probing scheme used by the policies' in-place repair rounds.
const REPAIR_SCHEME: RepairScheme = RepairScheme::DoubleHashing;

/// Attempts one [`RecoveryPolicy::recover_with_faults`] call makes before
/// degrading to the acknowledge-only floor. Each attempt is a fresh
/// agree + reshape under a new epoch, so the bound caps how long a storm
/// that keeps killing PEs mid-handshake can stall a recovery.
pub const MAX_RECOVERY_ATTEMPTS: usize = 4;

/// Steps 3–4 of the handshake, shared by every policy: fused reshape
/// across all datasets, then — only if some acknowledged dataset still
/// references dead ranks (its replicas died with them) — one fused §IV-E
/// repair round to restore the replication level in place. The
/// `Rebalanced` injection fires between the two, and `needs_repair` is
/// evaluated *after* it: a kill in that window is absorbed (its lost
/// replicas join this same repair round where possible; the rest wait for
/// the next recover call).
#[allow(clippy::too_many_arguments)]
fn reshape_and_repair(
    cluster: &mut Cluster,
    store: &mut ReStore,
    failed: Vec<usize>,
    action: RecoveryAction,
    degraded: bool,
    map: RankMap,
    ulfm_cost: PhaseCost,
    t0: f64,
    inject: &mut dyn FnMut(RecoveryStep, &mut Cluster),
) -> Result<RecoveryOutcome> {
    let dataset_outcomes = store.rebalance_or_acknowledge_all(cluster, &map)?;
    inject(RecoveryStep::Rebalanced, cluster);
    let needs_repair = store.datasets().iter().zip(&dataset_outcomes).any(|(ds, outcome)| {
        ds.is_submitted()
            && outcome.is_none()
            && ds.pe_map.iter().any(|&c| !cluster.is_alive(c as usize))
    });
    let repair_outcomes = if needs_repair {
        Some(store.repair_replicas_all(cluster, REPAIR_SCHEME)?)
    } else {
        None
    };
    Ok(RecoveryOutcome {
        failed,
        action,
        degraded,
        map,
        dataset_outcomes,
        repair_outcomes,
        ulfm_cost,
        recovery_time_s: cluster.now() - t0,
    })
}

/// What one recovery attempt agreed and reshaped:
/// `(failed, action, degraded, map, ulfm_cost)`.
type AttemptResult = Result<(Vec<usize>, RecoveryAction, bool, RankMap, PhaseCost)>;

/// The bounded-retry skeleton every policy shares. `attempt` runs steps
/// 1–2 (agree + reshape, firing `RecoveryStep::Agreed` in between);
/// `RecoveryStep::Reshaped` fires after it — the critical window between
/// the epoch bump and the layout install. A [`Error::StaleRankMap`] /
/// [`Error::StaleEpoch`] abort (an injected kill invalidated the map
/// before any layout moved) triggers a fresh attempt; after
/// [`MAX_RECOVERY_ATTEMPTS`] the recovery degrades to one final shrink +
/// acknowledge-only adoption — an epoch-only step that cannot go stale —
/// with `degraded = true` and no dataset rebalanced or repaired.
fn retry_handshake(
    cluster: &mut Cluster,
    store: &mut ReStore,
    inject: &mut dyn FnMut(RecoveryStep, &mut Cluster),
    attempt: &mut dyn FnMut(&mut Cluster, &mut dyn FnMut(RecoveryStep, &mut Cluster)) -> AttemptResult,
) -> Result<RecoveryOutcome> {
    let t0 = cluster.now();
    for _ in 0..MAX_RECOVERY_ATTEMPTS {
        let (failed, action, degraded, map, ulfm_cost) = attempt(cluster, &mut *inject)?;
        inject(RecoveryStep::Reshaped, cluster);
        match reshape_and_repair(
            cluster, store, failed, action, degraded, map, ulfm_cost, t0, &mut *inject,
        ) {
            Err(Error::StaleRankMap { .. }) | Err(Error::StaleEpoch { .. }) => continue,
            done => return done,
        }
    }
    // Attempts exhausted: the storm outpaced every reshape. Converge on
    // the floor no kill can invalidate — shrink once more (the epoch bump
    // the acknowledge adopts) and acknowledge every dataset in place. No
    // migration, no repair: loads route around the dead ranks until a
    // calmer recover call finishes the job.
    let (failed, agree_cost) = ulfm::agree(cluster);
    let (map, shrink_cost) = ulfm::shrink(cluster);
    store.acknowledge_shrink(cluster)?;
    let n = store.n_datasets();
    Ok(RecoveryOutcome {
        failed,
        action: RecoveryAction::Shrunk { new_world: map.new_world() },
        degraded: true,
        map,
        dataset_outcomes: vec![None; n],
        repair_outcomes: None,
        ulfm_cost: agree_cost.then(shrink_cost),
        recovery_time_s: cluster.now() - t0,
    })
}

/// The paper's policy: agree, shrink to the survivors, rebalance.
#[derive(Debug, Clone, Copy, Default)]
pub struct Shrink;

impl RecoveryPolicy for Shrink {
    fn name(&self) -> &'static str {
        "shrink"
    }

    fn recover_with_faults(
        &mut self,
        cluster: &mut Cluster,
        store: &mut ReStore,
        inject: &mut dyn FnMut(RecoveryStep, &mut Cluster),
    ) -> Result<RecoveryOutcome> {
        retry_handshake(cluster, store, inject, &mut |cluster, inject| {
            let (failed, agree_cost) = ulfm::agree(cluster);
            inject(RecoveryStep::Agreed, cluster);
            let (map, shrink_cost) = ulfm::shrink(cluster);
            let action = RecoveryAction::Shrunk { new_world: map.new_world() };
            Ok((failed, action, false, map, agree_cost.then(shrink_cost)))
        })
    }
}

/// Keep the world size: seat spares in the dead ranks' positions. Falls
/// back to [`Shrink`] (with `degraded = true`) when the pool cannot cover
/// the dead.
#[derive(Debug, Clone, Copy, Default)]
pub struct Substitute;

impl RecoveryPolicy for Substitute {
    fn name(&self) -> &'static str {
        "substitute"
    }

    fn recover_with_faults(
        &mut self,
        cluster: &mut Cluster,
        store: &mut ReStore,
        inject: &mut dyn FnMut(RecoveryStep, &mut Cluster),
    ) -> Result<RecoveryOutcome> {
        retry_handshake(cluster, store, inject, &mut |cluster, inject| {
            let (failed, agree_cost) = ulfm::agree(cluster);
            inject(RecoveryStep::Agreed, cluster);
            // counted after the injection: a kill at `Agreed` joins this
            // very attempt's substitution arithmetic
            let n_dead = cluster.comm().iter().filter(|&&r| !cluster.is_alive(r)).count();
            if n_dead > 0 && cluster.n_spares() >= n_dead {
                let (map, sub_cost) = ulfm::substitute(cluster)?;
                let action = RecoveryAction::Substituted { replaced: n_dead };
                Ok((failed, action, false, map, agree_cost.then(sub_cost)))
            } else {
                let (map, shrink_cost) = ulfm::shrink(cluster);
                let action = RecoveryAction::Shrunk { new_world: map.new_world() };
                // degraded only when there *were* failures the pool could
                // not cover — a no-failure call shrinking to the same
                // members is the policy doing exactly what it should.
                Ok((failed, action, n_dead > 0, map, agree_cost.then(shrink_cost)))
            }
        })
    }
}

/// Shrink now, then re-grow toward `target_world` with whatever spares
/// the pool still holds (elastic recovery: one reshape handshake against
/// the *final* map, not one per step). `degraded = true` when the pool
/// ran short of the target.
#[derive(Debug, Clone, Copy)]
pub struct ShrinkThenRegrow {
    /// World size to grow back toward (typically the original `p`).
    pub target_world: usize,
}

impl RecoveryPolicy for ShrinkThenRegrow {
    fn name(&self) -> &'static str {
        "shrink+regrow"
    }

    fn recover_with_faults(
        &mut self,
        cluster: &mut Cluster,
        store: &mut ReStore,
        inject: &mut dyn FnMut(RecoveryStep, &mut Cluster),
    ) -> Result<RecoveryOutcome> {
        let target_world = self.target_world;
        retry_handshake(cluster, store, inject, &mut |cluster, inject| {
            let (failed, agree_cost) = ulfm::agree(cluster);
            inject(RecoveryStep::Agreed, cluster);
            let (shrink_map, shrink_cost) = ulfm::shrink(cluster);
            let shrunk_to = shrink_map.new_world();
            let want = target_world.saturating_sub(shrunk_to).min(cluster.n_spares());
            if want > 0 {
                // The datasets never see the intermediate shrunk world:
                // the grow map supersedes the shrink map under the final
                // epoch, and the single reshape migrates straight to it.
                let (grow_map, grow_cost) = ulfm::grow(cluster, want)?;
                let regrown_to = shrunk_to + want;
                let action = RecoveryAction::Regrown { shrunk_to, regrown_to };
                let degraded = regrown_to < target_world;
                let cost = agree_cost.then(shrink_cost).then(grow_cost);
                Ok((failed, action, degraded, grow_map, cost))
            } else {
                let action = RecoveryAction::Shrunk { new_world: shrunk_to };
                let degraded = shrunk_to < target_world;
                Ok((failed, action, degraded, shrink_map, agree_cost.then(shrink_cost)))
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RestoreConfig;
    use crate::restore::block::{BlockRange, RangeSet};
    use crate::restore::store::HolderIndex;
    use crate::restore::LoadRequest;

    const BS: usize = 8; // bytes per block
    const BPP: usize = 64; // blocks per PE

    fn build(cluster: &Cluster, p: usize) -> (ReStore, Vec<Vec<u8>>) {
        let cfg = RestoreConfig::builder(p, BS, BPP).replicas(4).build().unwrap();
        let rs = ReStore::new(cfg, cluster).unwrap();
        let shards: Vec<Vec<u8>> = (0..p)
            .map(|pe| (0..BPP * BS).map(|i| (pe * 31 + i * 7) as u8).collect())
            .collect();
        (rs, shards)
    }

    /// Oracle: a full reload from one survivor is byte-identical to the
    /// originally submitted shards.
    fn assert_full_reload(rs: &mut ReStore, cluster: &mut Cluster, shards: &[Vec<u8>]) {
        let pe = cluster.survivors()[0];
        let n = (shards.len() * BPP) as u64;
        let reqs =
            vec![LoadRequest { pe, ranges: RangeSet::new(vec![BlockRange::new(0, n)]) }];
        let out = rs.load(cluster, &reqs).unwrap();
        let mut want = Vec::with_capacity(shards.len() * BPP * BS);
        for x in 0..n as usize {
            let (pe, off) = (x / BPP, (x % BPP) * BS);
            want.extend_from_slice(&shards[pe][off..off + BS]);
        }
        assert_eq!(out.shards[0].bytes.as_deref().unwrap(), &want[..]);
        assert_eq!(
            *rs.holder_index(),
            HolderIndex::rebuild(rs.stores(), rs.distribution()),
            "holder index drifted"
        );
    }

    /// Golden layout: dist rank `d`'s store (at cluster rank `pe_map[d]`)
    /// is identical to the store a FRESH submission at the same world
    /// places on rank `d` — i.e. the reshaped layout equals
    /// `Distribution::new_balanced` at the new world, byte for byte.
    fn assert_golden_layout(rs: &ReStore, shards: &[Vec<u8>]) {
        use crate::restore::store::SliceBuf;
        let p = shards.len();
        let mut fresh_cluster = Cluster::new_execution(p, 4);
        let (mut fresh, _) = build(&fresh_cluster, p);
        fresh.submit(&mut fresh_cluster, shards).unwrap();
        let ds = &rs.datasets()[0];
        for d in 0..p {
            let got = rs.stores()[ds.pe_map[d] as usize].slices();
            let want = fresh.stores()[d].slices();
            assert_eq!(got.len(), want.len(), "dist rank {d} slice count");
            for (g, w) in got.iter().zip(want) {
                assert_eq!(g.range, w.range, "dist rank {d}");
                match (&g.buf, &w.buf) {
                    (SliceBuf::Real(a), SliceBuf::Real(b)) => assert_eq!(a, b, "rank {d}"),
                    (SliceBuf::Virtual(a), SliceBuf::Virtual(b)) => assert_eq!(a, b),
                    _ => panic!("dist rank {d}: buffer kind mismatch"),
                }
            }
        }
    }

    #[test]
    fn shrink_policy_runs_the_full_handshake() {
        let mut cluster = Cluster::new_execution(8, 4);
        let (mut rs, shards) = build(&cluster, 8);
        rs.submit(&mut cluster, &shards).unwrap();
        cluster.kill(&[1, 2]);
        let out = Shrink.recover(&mut cluster, &mut rs).unwrap();
        assert_eq!(out.action, RecoveryAction::Shrunk { new_world: 6 });
        assert!(!out.degraded);
        assert_eq!(out.failed, vec![1, 2]);
        assert!(out.dataset_outcomes[0].is_some(), "survivable shrink rebalances");
        assert!(out.repair_outcomes.is_none(), "rebalanced: nothing left to repair");
        assert!(out.recovery_time_s > 0.0);
        assert_full_reload(&mut rs, &mut cluster, &shards);

        // a recover with no new deaths is an O(1) acknowledge, no repair
        let quiet = Shrink.recover(&mut cluster, &mut rs).unwrap();
        assert!(quiet.dataset_outcomes[0].is_none());
        assert!(quiet.repair_outcomes.is_none());
    }

    #[test]
    fn substitute_policy_is_repair_shaped_and_golden() {
        let mut cluster = Cluster::with_spares(8, 4, 2);
        let (mut rs, shards) = build(&cluster, 8);
        rs.submit(&mut cluster, &shards).unwrap();
        let dead_bytes: u64 = rs.stores()[3]
            .slices()
            .iter()
            .map(|s| (s.range.end - s.range.start) * BS as u64)
            .sum();
        cluster.kill(&[3]);
        let out = Substitute.recover(&mut cluster, &mut rs).unwrap();
        assert_eq!(out.action, RecoveryAction::Substituted { replaced: 1 });
        assert!(!out.degraded);
        assert_eq!(out.map.new_world(), 8, "substitution keeps the world size");
        let report = out.dataset_outcomes[0].as_ref().unwrap();
        // repair-shaped: ONLY the dead rank's replicas move (onto its spare)
        assert_eq!(report.migrated_bytes, dead_bytes);
        assert_golden_layout(&rs, &shards);
        assert_full_reload(&mut rs, &mut cluster, &shards);
    }

    #[test]
    fn substitute_policy_degrades_to_shrink_when_pool_exhausted() {
        let mut cluster = Cluster::with_spares(8, 4, 1);
        let (mut rs, shards) = build(&cluster, 8);
        rs.submit(&mut cluster, &shards).unwrap();
        cluster.kill(&[2, 5]);
        let out = Substitute.recover(&mut cluster, &mut rs).unwrap();
        assert_eq!(out.action, RecoveryAction::Shrunk { new_world: 6 });
        assert!(out.degraded, "pool of 1 cannot cover 2 dead");
        assert_eq!(cluster.n_spares(), 1, "fallback shrink leaves the pool untouched");
        assert_full_reload(&mut rs, &mut cluster, &shards);
    }

    #[test]
    fn shrink_then_regrow_reaches_target_and_is_golden() {
        let mut cluster = Cluster::with_spares(8, 4, 3);
        let (mut rs, shards) = build(&cluster, 8);
        rs.submit(&mut cluster, &shards).unwrap();
        cluster.kill(&[1, 4]);
        let out = ShrinkThenRegrow { target_world: 8 }.recover(&mut cluster, &mut rs).unwrap();
        assert_eq!(out.action, RecoveryAction::Regrown { shrunk_to: 6, regrown_to: 8 });
        assert!(!out.degraded);
        assert_eq!(out.map.new_world(), 8);
        // shrink + grow are two epoch bumps but ONE dataset reshape
        assert_eq!(cluster.epoch(), 2);
        assert_eq!(rs.epoch(), 2);
        assert_golden_layout(&rs, &shards);
        assert_full_reload(&mut rs, &mut cluster, &shards);
    }

    #[test]
    fn regrow_stops_at_the_pool_and_reports_degraded() {
        let mut cluster = Cluster::with_spares(8, 4, 1);
        let (mut rs, shards) = build(&cluster, 8);
        rs.submit(&mut cluster, &shards).unwrap();
        cluster.kill(&[2, 3]);
        let out = ShrinkThenRegrow { target_world: 8 }.recover(&mut cluster, &mut rs).unwrap();
        assert_eq!(out.action, RecoveryAction::Regrown { shrunk_to: 6, regrown_to: 7 });
        assert!(out.degraded, "one spare cannot reach the target of 8");
        assert_full_reload(&mut rs, &mut cluster, &shards);

        // pool now empty: the next wave degenerates to a plain shrink
        cluster.kill(&[6]);
        let out2 = ShrinkThenRegrow { target_world: 8 }.recover(&mut cluster, &mut rs).unwrap();
        assert_eq!(out2.action, RecoveryAction::Shrunk { new_world: 6 });
        assert!(out2.degraded);
        assert_full_reload(&mut rs, &mut cluster, &shards);
    }

    #[test]
    fn acknowledged_datasets_get_a_fused_repair_round() {
        // 8 PEs, r = 4: shrinking to 3 survivors is below the replication
        // level, so the dataset acknowledges — and the policy restores
        // what replication it can in place with a §IV-E repair round.
        let mut cluster = Cluster::new_execution(8, 4);
        let (mut rs, shards) = build(&cluster, 8);
        rs.submit(&mut cluster, &shards).unwrap();
        cluster.kill(&[0, 1, 2, 3, 4]);
        let out = Shrink.recover(&mut cluster, &mut rs).unwrap();
        assert_eq!(out.action, RecoveryAction::Shrunk { new_world: 3 });
        assert!(out.dataset_outcomes[0].is_none(), "3 < r = 4: acknowledge");
        let repairs = out.repair_outcomes.as_ref().expect("dead replicas need repair");
        assert!(repairs[0].is_some());
        assert_eq!(
            *rs.holder_index(),
            HolderIndex::rebuild(rs.stores(), rs.distribution())
        );
    }

    #[test]
    fn kill_between_reshape_and_install_retries_and_converges() {
        let mut cluster = Cluster::new_execution(8, 4);
        let (mut rs, shards) = build(&cluster, 8);
        rs.submit(&mut cluster, &shards).unwrap();
        cluster.kill(&[1]);
        let mut fired = 0usize;
        let out = Shrink
            .recover_with_faults(&mut cluster, &mut rs, &mut |step, cluster| {
                if step == RecoveryStep::Reshaped && fired == 0 {
                    fired += 1;
                    cluster.kill(&[2]);
                }
            })
            .unwrap();
        assert_eq!(fired, 1);
        assert!(!out.degraded, "one retry finished a clean handshake");
        assert_eq!(out.action, RecoveryAction::Shrunk { new_world: 6 });
        assert_eq!(out.failed, vec![1, 2], "the retry's agree sees the mid-recovery death");
        assert_eq!(cluster.epoch(), 2, "one staled shrink + the good one");
        assert_eq!(rs.epoch(), 2, "only the second map was installed");
        assert!(out.dataset_outcomes[0].is_some(), "the retry rebalanced normally");
        assert_full_reload(&mut rs, &mut cluster, &shards);
    }

    #[test]
    fn relentless_mid_recovery_kills_degrade_within_the_attempt_bound() {
        let mut cluster = Cluster::new_execution(16, 4);
        let (mut rs, shards) = build(&cluster, 16);
        rs.submit(&mut cluster, &shards).unwrap();
        cluster.kill(&[0]);
        // one fresh victim per Reshaped window: every attempt's map goes
        // stale before any layout is installed
        let mut victims = 1usize..;
        let mut reshaped_fires = 0usize;
        let out = Shrink
            .recover_with_faults(&mut cluster, &mut rs, &mut |step, cluster| {
                if step == RecoveryStep::Reshaped {
                    reshaped_fires += 1;
                    cluster.kill(&[victims.next().unwrap()]);
                }
            })
            .unwrap();
        assert_eq!(reshaped_fires, MAX_RECOVERY_ATTEMPTS, "retry count is bounded");
        assert!(out.degraded, "the floor is reported as a degradation");
        assert!(out.dataset_outcomes.iter().all(|o| o.is_none()), "acknowledge-only");
        assert!(out.repair_outcomes.is_none());
        let survivors = 16 - 1 - MAX_RECOVERY_ATTEMPTS;
        assert_eq!(out.action, RecoveryAction::Shrunk { new_world: survivors });
        assert_eq!(out.failed.len(), 1 + MAX_RECOVERY_ATTEMPTS);
        assert_eq!(rs.epoch(), cluster.epoch(), "the floor still adopts the epoch");
        // every surviving byte stays loadable in the dead world
        assert_full_reload(&mut rs, &mut cluster, &shards);
    }

    #[test]
    fn policy_names_are_stable() {
        assert_eq!(Shrink.name(), "shrink");
        assert_eq!(Substitute.name(), "substitute");
        assert_eq!(ShrinkThenRegrow { target_world: 8 }.name(), "shrink+regrow");
    }
}
