//! The paper's fault-tolerant applications (§VI-C).
//!
//! * [`kmeans`] — the k-means clustering benchmark of Fig 5: PJRT-executed
//!   assignment kernel, allreduce of partials, ReStore-backed shrinking
//!   recovery under the §VI-C exponential-decay failure schedule.
//! * [`raxml`] — the FT-RAxML-NG proxy of Fig 6: a phylogenetic
//!   likelihood evaluation loop whose per-PE MSA site shards are reloaded
//!   through ReStore (vs. the RBA-file-on-PFS baseline) after failures.
//! * [`pagerank`] — the third application the paper names (§IV-C): a
//!   vertex-partitioned PageRank whose edge shards live in ReStore.
//! * [`kvserve`] — the Zipf KV serving trace behind `benches/kv.rs`:
//!   batched cached point reads + write rounds under an MTBF failure
//!   storm, reporting p50/p99 latency, hit rate, and recovery blast
//!   radius.
//!
//! All three share the same skeleton: generate per-PE input, `submit` once,
//! iterate compute + allreduce, and on failure run the ULFM recovery
//! (`agree` + `shrink`), rebalance the lost shards over the survivors with
//! a scattered `load`, and keep going — the paper's shrinking strategy.
//!
//! Each app checkpoints TWO datasets (§V: "one ReStore object per
//! datatype"): its bulk input (points / edges / MSA sites, r = 4, 64 B
//! blocks) and a small *mutable* state dataset (centroids / rank vector /
//! model state, [`secondary_replicas`], 32 B blocks). The state evolves
//! every iteration, so the apps resubmit it as a new version per iteration
//! ([`checkpoint_state`]) — a checksum delta overlapped against the
//! iteration's compute, GASPI-style — and failure recovery re-fetches the
//! latest *committed* version through the same fused `load_many` round and
//! fused shrink handshake as the bulk input.

pub mod kmeans;
pub mod kvserve;
pub mod pagerank;
pub mod raxml;

use crate::error::{Error, Result};
use crate::restore::block::{BlockRange, RangeSet};
use crate::restore::registry::Dataset;
use crate::restore::resubmit::{Overlap, ResubmitMode};
use crate::simnet::cluster::Cluster;

/// Replication level for an application's *secondary* dataset (centroids,
/// rank vectors, model state): lower than the point/edge/site data's
/// `r = 4`, but still subject to the config's `r | p` constraint — 2 on
/// even worlds, 1 otherwise.
pub fn secondary_replicas(world: usize) -> usize {
    if world >= 2 && world % 2 == 0 {
        2
    } else {
        1
    }
}

/// Cut a full serialized state buffer (`n_blocks * block_size` bytes, in
/// original block order) into the per-slice shards [`Dataset::resubmit`]
/// expects under the dataset's *current* distribution — the identity
/// partition before any failure, the rewritten §IV-A layout after a
/// rebalance.
pub fn checkpoint_shards(ds: &Dataset, global: &[u8]) -> Vec<Vec<u8>> {
    let dist = ds.distribution();
    let bs = ds.config().block_size;
    (0..dist.world())
        .map(|j| {
            let r = dist.slice_range(j);
            global[r.start as usize * bs..r.end as usize * bs].to_vec()
        })
        .collect()
}

/// Per-iteration checkpoint of an evolving state dataset: resubmit the new
/// serialization as a delta version (unchanged blocks detected by the PR 7
/// per-block checksums), overlapped against the iteration's already-charged
/// compute time so only the exposed remainder costs wall clock.
///
/// Degrades to a no-op (`Ok(None)`) when the current layout cannot accept a
/// resubmit — dead submitters after an acknowledge-only shrink, or whole
/// slots lost on a low-replication dataset — since the state also lives in
/// app memory; the dataset then keeps serving its last committed version.
/// Returns `Some(exposed_seconds)` when the new version committed.
pub fn checkpoint_state(
    ds: &mut Dataset,
    cluster: &mut Cluster,
    global: &[u8],
    compute_overlap_s: f64,
) -> Result<Option<f64>> {
    let shards = checkpoint_shards(ds, global);
    match ds.resubmit(
        cluster,
        &shards,
        ResubmitMode::DeltaByChecksum,
        Overlap::Compute(compute_overlap_s),
    ) {
        Ok(rep) => Ok(Some(rep.exposed_s)),
        Err(Error::DeadPe(_)) | Err(Error::IrrecoverableDataLoss { .. }) => Ok(None),
        Err(e) => Err(e),
    }
}

/// Cost-model twin of [`checkpoint_state`]: charges the schedule of a
/// full-vector resubmit (every block dirty — iterative state rarely leaves
/// a block untouched) overlapped against the iteration's compute, without
/// materializing bytes. Same degradation rules.
pub fn checkpoint_state_virtual(
    ds: &mut Dataset,
    cluster: &mut Cluster,
    compute_overlap_s: f64,
) -> Result<Option<f64>> {
    let dirty = RangeSet::new(vec![BlockRange::new(0, ds.distribution().n_blocks())]);
    match ds.resubmit_virtual(cluster, &dirty, Overlap::Compute(compute_overlap_s)) {
        Ok(rep) => Ok(Some(rep.exposed_s)),
        Err(Error::DeadPe(_)) | Err(Error::IrrecoverableDataLoss { .. }) => Ok(None),
        Err(e) => Err(e),
    }
}

/// Per-PE ownership ledger: which *original* block ranges each PE is
/// currently working on. Starts as the identity partition (PE i owns its
/// own shard) and is updated by the load balancer after every failure.
#[derive(Debug, Clone)]
pub struct Ownership {
    /// Indexed by original rank; dead PEs keep their (now stale) entry.
    pub owned: Vec<RangeSet>,
}

impl Ownership {
    pub fn identity(world: usize, blocks_per_pe: u64) -> Self {
        Ownership {
            owned: (0..world as u64)
                .map(|pe| {
                    RangeSet::new(vec![BlockRange::new(
                        pe * blocks_per_pe,
                        (pe + 1) * blocks_per_pe,
                    )])
                })
                .collect(),
        }
    }

    /// The simple even load balancer the paper's k-means uses: collect the
    /// ranges owned by `failed` PEs and deal them out evenly (by block
    /// count) over `survivors`, in order. Returns the per-survivor gained
    /// ranges and records them in the ledger.
    ///
    /// `align` is the application's record size in blocks (e.g. a 32-dim
    /// f32 point is two 64 B blocks): split boundaries are multiples of it
    /// so no survivor ever receives a fraction of a record. All owned
    /// ranges must already be `align`-multiples (true when `blocks_per_pe`
    /// is).
    pub fn rebalance(
        &mut self,
        failed: &[usize],
        survivors: &[usize],
        align: u64,
    ) -> Vec<(usize, RangeSet)> {
        assert!(align > 0);
        // collect the dead PEs' holdings into ONE normalization pass (an
        // incremental union per failed PE would re-sort the accumulated
        // set f times)
        let mut lost_ranges: Vec<BlockRange> = Vec::new();
        for &f in failed {
            lost_ranges.extend(std::mem::take(&mut self.owned[f]).ranges().iter().copied());
        }
        let lost = RangeSet::new(lost_ranges);
        let total: u64 = lost.total_blocks();
        let ns = survivors.len() as u64;
        if ns == 0 || total == 0 {
            return Vec::new();
        }
        debug_assert_eq!(total % align, 0, "lost ranges must be record-aligned");
        let units = total / align;
        // walk the lost ranges, cutting them into ns contiguous portions of
        // whole `align`-block records
        let mut out: Vec<(usize, RangeSet)> = Vec::new();
        let mut iter = lost.ranges().iter().copied();
        let mut cur = iter.next();
        for (j, &pe) in survivors.iter().enumerate() {
            let want_start = (j as u64 * units) / ns * align;
            let want_end = ((j as u64 + 1) * units) / ns * align;
            let mut need = want_end - want_start;
            let mut mine: Vec<BlockRange> = Vec::new();
            while need > 0 {
                let Some(r) = cur else { break };
                let take = need.min(r.len());
                mine.push(BlockRange::new(r.start, r.start + take));
                need -= take;
                cur = if take == r.len() {
                    iter.next()
                } else {
                    Some(BlockRange::new(r.start + take, r.end))
                };
            }
            if !mine.is_empty() {
                let set = RangeSet::new(mine);
                self.owned[pe] = self.owned[pe].union(&set);
                out.push((pe, set));
            }
        }
        out
    }

    /// Total blocks owned by `pe`.
    pub fn blocks_of(&self, pe: usize) -> u64 {
        self.owned[pe].total_blocks()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_partition() {
        let o = Ownership::identity(4, 100);
        assert_eq!(o.owned[2].ranges(), &[BlockRange::new(200, 300)]);
        assert_eq!(o.blocks_of(3), 100);
    }

    #[test]
    fn secondary_replicas_respects_divisibility() {
        assert_eq!(secondary_replicas(8), 2);
        assert_eq!(secondary_replicas(48), 2);
        assert_eq!(secondary_replicas(3), 1);
        assert_eq!(secondary_replicas(1), 1);
    }

    #[test]
    fn rebalance_splits_evenly_and_conserves_blocks() {
        let mut o = Ownership::identity(5, 100);
        let gained = o.rebalance(&[1], &[0, 2, 3, 4], 1);
        let total: u64 = gained.iter().map(|(_, s)| s.total_blocks()).sum();
        assert_eq!(total, 100);
        for (_, s) in &gained {
            assert_eq!(s.total_blocks(), 25);
        }
        assert_eq!(o.blocks_of(0), 125);
        assert!(o.owned[1].is_empty()); // emptied
    }

    #[test]
    fn rebalance_handles_cascading_failures() {
        let mut o = Ownership::identity(4, 100);
        o.rebalance(&[1], &[0, 2, 3], 1);
        // now PE 2 (owning ~133 blocks) dies too
        let gained = o.rebalance(&[2], &[0, 3], 1);
        let total: u64 = gained.iter().map(|(_, s)| s.total_blocks()).sum();
        // PE 2 owned 100 own blocks + ~33 gained from PE 1
        assert!((132..=135).contains(&total), "redistributed {total}");
        assert!(o.blocks_of(2) == 0);
        // all 400 blocks still owned by survivors
        assert_eq!(o.blocks_of(0) + o.blocks_of(3), 400);
    }

    #[test]
    fn rebalance_uneven_counts_differ_by_at_most_one_block() {
        let mut o = Ownership::identity(4, 100);
        let gained = o.rebalance(&[0], &[1, 2, 3], 1);
        let counts: Vec<u64> = gained.iter().map(|(_, s)| s.total_blocks()).collect();
        assert_eq!(counts.iter().sum::<u64>(), 100);
        let min = counts.iter().min().unwrap();
        let max = counts.iter().max().unwrap();
        assert!(max - min <= 1);
    }
}
