//! Fig 4a — optimizing the number of bytes per permutation range (§VI-B2).
//!
//! 16 MiB of 64 B blocks per PE; sweep the permutation-range size from
//! 64 B to 16 MiB and measure *submit* and *load 1 % data* (the simulated
//! time produced by the exact communication schedules).
//!
//! Paper shape: both operations are up to an order of magnitude slower at
//! the left edge (tiny ranges -> huge bottleneck message counts); load
//! degrades again toward 16 MiB (only r senders); a broad sweet spot lies
//! between — the paper picks 256 KiB (0.65–2.27 ms load-1% on 48–6144 PEs).

use restore::config::RestoreConfig;
use restore::metrics::{fmt_time, Stats, Table};
use restore::restore::load::load_percent_requests;
use restore::restore::ReStore;
use restore::simnet::cluster::Cluster;
use restore::util::bench::sim_samples;

const BYTES_PER_PE: usize = 16 * 1024 * 1024;
const BLOCK: usize = 64;
/// Skip configurations whose submit schedule exceeds this many entries
/// (p * units_per_pe * r) — single-core testbed guard; the paper's cluster
/// sweep covers them, the shape is already fixed by the smaller p series.
const MAX_SCHEDULE_ENTRIES: u64 = 400_000_000;

fn main() {
    let reps = 5u64;
    let pes = [48usize, 384, 1536, 6144];
    let range_bytes: Vec<usize> =
        (6..=24).step_by(2).map(|e| 1usize << e).collect(); // 64 B .. 16 MiB

    for &op in &["submit", "load 1% data"] {
        println!("=== Fig 4a: {op} vs bytes per permutation range ===\n");
        let mut header = vec!["range bytes".to_string()];
        header.extend(pes.iter().map(|p| format!("p={p}")));
        let mut table = Table::new(header);
        for &rb in &range_bytes {
            let mut cells = vec![human(rb)];
            for &p in &pes {
                let units = (BYTES_PER_PE / rb.max(BLOCK)) as u64;
                if p as u64 * units * 4 > MAX_SCHEDULE_ENTRIES {
                    cells.push("(skipped)".into());
                    continue;
                }
                let stats = run_op(op, p, rb, reps);
                cells.push(fmt_time(stats.mean));
            }
            table.row(cells);
        }
        println!("{}", table.render());
    }

    // the paper's chosen point
    let stats48 = run_op("load 1% data", 48, 256 * 1024, reps);
    let stats6144 = run_op("load 1% data", 6144, 256 * 1024, reps);
    println!(
        "paper anchor: load-1% @256 KiB ranges = 0.65..2.27 ms on 48..6144 PEs\n\
         measured:     {} (p=48) .. {} (p=6144)",
        fmt_time(stats48.mean),
        fmt_time(stats6144.mean)
    );
}

fn run_op(op: &str, p: usize, range_bytes: usize, reps: u64) -> Stats {
    sim_samples(reps as usize, |rep| {
        let cfg = RestoreConfig::builder(p, BLOCK, BYTES_PER_PE / BLOCK)
            .replicas(4)
            .perm_range_bytes(Some(range_bytes.max(BLOCK)))
            .seed(0xF16_4A + rep)
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p, 48.min(p));
        let mut store = ReStore::new(cfg, &cluster).unwrap();
        let t0 = cluster.now();
        let sub = store.submit_virtual(&mut cluster).unwrap();
        if op == "submit" {
            return sub.cost.sim_time_s;
        }
        let start_pe = (rep as usize * 7) % p;
        let reqs = load_percent_requests(&store, &cluster, 1.0, start_pe);
        let t1 = cluster.now();
        store.load(&mut cluster, &reqs).unwrap();
        let _ = t0;
        cluster.now() - t1
    })
}

fn human(b: usize) -> String {
    if b >= 1 << 20 {
        format!("{} MiB", b >> 20)
    } else if b >= 1 << 10 {
        format!("{} KiB", b >> 10)
    } else {
        format!("{b} B")
    }
}
