//! ULFM-style fault-tolerance operations over the simulated cluster.
//!
//! Mirrors the recovery sequence of the paper's applications (§VI-A/§VI-C):
//! after a failure is detected, the survivors run an *agreement* on the set
//! of failed ranks (`MPIX_Comm_agree`-like) and then *shrink* the
//! communicator (`MPIX_Comm_shrink`-like), producing a dense re-ranking.
//! The paper could not benchmark real ULFM (it was too unstable — they
//! filed the bug) and replaced these with functionally similar MPI calls;
//! we model their cost with a latency term that matches the observation in
//! §VI-C that "the overall running time increases ... mainly due to MPI
//! operations used to restore a functioning communicator".

use crate::error::{Error, Result};
use crate::simnet::cluster::Cluster;
use crate::simnet::network::PhaseCost;

/// Fixed agreement/shrink overhead (connection teardown, group bookkeeping).
pub const SHRINK_BASE_S: f64 = 1.0e-3;
/// Per-log2(p) cost of the agreement + shrink collectives.
pub const SHRINK_PER_LOG_S: f64 = 1.5e-3;

/// Rank translation between the pre-failure and post-shrink communicators.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RankMap {
    /// old rank -> new rank (None for failed PEs).
    pub old_to_new: Vec<Option<usize>>,
    /// new rank -> old rank.
    pub new_to_old: Vec<usize>,
}

impl RankMap {
    /// Identity map over `p` alive ranks.
    pub fn identity(p: usize) -> Self {
        RankMap {
            old_to_new: (0..p).map(Some).collect(),
            new_to_old: (0..p).collect(),
        }
    }

    pub fn new_world(&self) -> usize {
        self.new_to_old.len()
    }

    /// Verify this map describes `cluster`'s *current* survivor set: every
    /// new rank maps to an alive old rank, the survivors are covered
    /// exactly once in old-rank order, and the two directions agree. The
    /// rebalance policy (`ReStore::rebalance` and
    /// `ReStore::rebalance_or_acknowledge`) calls this before ANY layout
    /// decision — a stale map (from an earlier shrink) silently addressing
    /// dead ranks is the bug class this guards against. Failures surface
    /// as the dedicated [`Error::StaleRankMap`].
    pub fn validate_against(&self, cluster: &Cluster) -> Result<()> {
        let err = |m: String| Err(Error::StaleRankMap(m));
        if self.old_to_new.len() != cluster.world() {
            return err(format!(
                "rank map covers {} old ranks, cluster world is {}",
                self.old_to_new.len(),
                cluster.world()
            ));
        }
        if self.new_world() != cluster.n_alive() {
            return err(format!(
                "rank map has {} new ranks, cluster has {} survivors (stale map?)",
                self.new_world(),
                cluster.n_alive()
            ));
        }
        let mut prev_old: Option<usize> = None;
        for (new, &old) in self.new_to_old.iter().enumerate() {
            if !cluster.is_alive(old) {
                return err(format!("rank map: new rank {new} maps to dead PE {old}"));
            }
            if self.old_to_new.get(old).copied().flatten() != Some(new) {
                return err(format!("rank map: directions disagree at old rank {old}"));
            }
            if prev_old.is_some_and(|p| p >= old) {
                return err("rank map: new ranks must preserve old-rank order".into());
            }
            prev_old = Some(old);
        }
        for (old, &new) in self.old_to_new.iter().enumerate() {
            if new.is_some() != cluster.is_alive(old) {
                return err(format!(
                    "rank map: old rank {old} mapping disagrees with its alive state"
                ));
            }
        }
        Ok(())
    }
}

/// Agreement on the failed set: every survivor learns which PEs died.
/// Cost: a fault-tolerant allreduce over a bitmap (3 log p rounds — the
/// two-phase commit structure of `MPIX_Comm_agree`).
pub fn agree(cluster: &mut Cluster) -> (Vec<usize>, PhaseCost) {
    let p = cluster.n_alive().max(2) as f64;
    let rounds = 3 * p.log2().ceil() as u64;
    let cost = PhaseCost::latency(cluster.network(), rounds);
    cluster.advance(&cost);
    (cluster.failed(), cost)
}

/// Shrink the communicator: survivors get dense new ranks preserving the
/// old order (exactly what `MPI_Comm_split(comm, alive, old_rank)` does in
/// the paper's simulation methodology).
pub fn shrink(cluster: &mut Cluster) -> (RankMap, PhaseCost) {
    let world = cluster.world();
    let mut old_to_new = vec![None; world];
    let mut new_to_old = Vec::with_capacity(cluster.n_alive());
    for old in 0..world {
        if cluster.is_alive(old) {
            old_to_new[old] = Some(new_to_old.len());
            new_to_old.push(old);
        }
    }
    let p = cluster.n_alive().max(2) as f64;
    let cost = PhaseCost {
        sim_time_s: SHRINK_BASE_S + SHRINK_PER_LOG_S * p.log2(),
        bottleneck_msgs: 2 * p.log2().ceil() as u64,
        ..Default::default()
    };
    cluster.advance(&cost);
    cluster.bump_epoch();
    (RankMap { old_to_new, new_to_old }, cost)
}

/// Full recovery sequence after failures are noticed: agree + shrink.
pub fn recover(cluster: &mut Cluster) -> (Vec<usize>, RankMap, PhaseCost) {
    let (failed, c1) = agree(cluster);
    let (map, c2) = shrink(cluster);
    (failed, map, c1.then(c2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrink_densifies_ranks_in_order() {
        let mut c = Cluster::new_execution(8, 4);
        c.kill(&[2, 5]);
        let (map, cost) = shrink(&mut c);
        assert_eq!(map.new_world(), 6);
        assert_eq!(map.new_to_old, vec![0, 1, 3, 4, 6, 7]);
        assert_eq!(map.old_to_new[2], None);
        assert_eq!(map.old_to_new[3], Some(2));
        assert_eq!(map.old_to_new[7], Some(5));
        assert!(cost.sim_time_s > SHRINK_BASE_S);
        assert_eq!(c.epoch(), 1);
        map.validate_against(&c).unwrap();
    }

    #[test]
    fn stale_rank_map_is_rejected() {
        let mut c = Cluster::new_execution(8, 4);
        c.kill(&[2]);
        let (map, _) = shrink(&mut c);
        map.validate_against(&c).unwrap();
        // a later failure makes the map stale — surfaced as the dedicated
        // StaleRankMap variant, not a generic Config error
        c.kill(&[5]);
        assert!(matches!(
            map.validate_against(&c),
            Err(Error::StaleRankMap(_))
        ));
        let (map2, _) = shrink(&mut c);
        map2.validate_against(&c).unwrap();
        assert_eq!(c.epoch(), 2);
        // identity map over the wrong world
        assert!(RankMap::identity(4).validate_against(&c).is_err());
    }

    #[test]
    fn agree_reports_failed_set() {
        let mut c = Cluster::new_execution(16, 4);
        c.kill(&[0, 15]);
        let (failed, cost) = agree(&mut c);
        assert_eq!(failed, vec![0, 15]);
        assert!(cost.sim_time_s > 0.0);
    }

    #[test]
    fn recover_composes_costs() {
        let mut c = Cluster::new_execution(16, 4);
        c.kill(&[3]);
        let t0 = c.now();
        let (failed, map, cost) = recover(&mut c);
        assert_eq!(failed, vec![3]);
        assert_eq!(map.new_world(), 15);
        assert!((c.now() - t0 - cost.sim_time_s).abs() < 1e-12);
    }

    #[test]
    fn identity_map() {
        let m = RankMap::identity(4);
        assert_eq!(m.old_to_new[3], Some(3));
        assert_eq!(m.new_world(), 4);
    }

    #[test]
    fn shrink_cost_grows_slowly_with_p() {
        let mut small = Cluster::new_execution(48, 48);
        let mut big = Cluster::new_execution(24576, 48);
        let (_, cs) = shrink(&mut small);
        let (_, cb) = shrink(&mut big);
        assert!(cb.sim_time_s > cs.sim_time_s);
        assert!(cb.sim_time_s < cs.sim_time_s * 4.0);
    }
}
