//! The multi-dataset store registry (§V).
//!
//! The paper's API lets "an application ... create multiple ReStore
//! objects, e.g., one for each datatype to be stored": kmeans points vs.
//! centroids, PageRank edges vs. rank vectors, RAxML MSA sites vs. model
//! state — each with its own block size `b`, replication level `r`, block
//! count `n`, and permutation seed. This module holds the per-dataset
//! state: a [`Dataset`] is exactly the single-dataset store the crate grew
//! up as — one [`Distribution`], one [`PeStore`] set, one reverse
//! [`HolderIndex`], one communicator epoch, one reusable
//! [`LoadScratch`](crate::restore::load) — and
//! [`ReStore`](crate::restore::ReStore) is now a registry of them, keyed
//! by [`DatasetId`].
//!
//! Every routing operation goes through the dataset handle
//! ([`ReStore::dataset`] / [`ReStore::dataset_mut`]); the historical
//! single-dataset `ReStore` API survives as a thin facade over dataset 0,
//! byte-identical to the pre-registry behavior (golden-pinned by the
//! entire pre-existing test suite running unchanged). The *fused*
//! cross-dataset phases — [`ReStore::load_many`]
//! (`restore/load.rs`) and the all-dataset shrink handshake
//! [`ReStore::rebalance_or_acknowledge`] (`restore/mod.rs`) — are where
//! the registry pays off at scale: one request sparse all-to-all and one
//! data sparse all-to-all across *all* datasets instead of one round per
//! dataset (§IV-C's startup-overhead argument applied across datasets).

use crate::config::RestoreConfig;
use crate::error::{Error, Result};
use crate::restore::distribution::Distribution;
use crate::restore::load::LoadScratch;
use crate::restore::store::{HolderIndex, PeStore};
use crate::restore::LoadedShard;
use crate::simnet::cluster::Cluster;

/// Identifier of one dataset inside a [`ReStore`](crate::restore::ReStore)
/// registry. Ids are dense: the first dataset (the one the single-dataset
/// facade addresses) is always `DatasetId(0)`, and
/// [`ReStore::create_dataset`](crate::restore::ReStore::create_dataset)
/// hands out consecutive ids.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DatasetId(pub u32);

impl DatasetId {
    /// The dataset the single-dataset facade addresses.
    pub const FIRST: DatasetId = DatasetId(0);

    /// Dense index of this dataset inside the registry.
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for DatasetId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Data loaded for one dataset of a
/// [`ReStore::load_many`](crate::restore::ReStore::load_many) call.
#[derive(Debug, Clone)]
pub struct LoadManyPart {
    pub dataset: DatasetId,
    /// One entry per request of this dataset's part, in request order —
    /// exactly what the corresponding single-dataset `load` would return.
    pub shards: Vec<LoadedShard>,
}

/// Result of a [`ReStore::load_many`](crate::restore::ReStore::load_many):
/// per-dataset shards plus the costs of the TWO fused phases (one request
/// sparse all-to-all and one data sparse all-to-all across all datasets).
#[derive(Debug, Clone)]
pub struct LoadManyOutput {
    /// In input-part order.
    pub parts: Vec<LoadManyPart>,
    /// Cost of the single fused request sparse all-to-all.
    pub request_cost: crate::simnet::network::PhaseCost,
    /// Cost of the single fused data sparse all-to-all.
    pub data_cost: crate::simnet::network::PhaseCost,
    /// Total (= request + data).
    pub cost: crate::simnet::network::PhaseCost,
}

/// One request's output span inside the pooled arena of a
/// [`ReStore::load_many_pooled`](crate::restore::ReStore::load_many_pooled)
/// call. `span` is `None` for cost-model datasets, mirroring
/// [`LoadedShard`]'s `bytes`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PooledShard {
    pub pe: usize,
    /// Byte range of this request's data inside
    /// [`PooledLoadOutput::arena`].
    pub span: Option<std::ops::Range<usize>>,
}

/// Data loaded for one dataset of a
/// [`ReStore::load_many_pooled`](crate::restore::ReStore::load_many_pooled)
/// call — request order, like [`LoadManyPart`], but the bytes live in the
/// shared arena.
#[derive(Debug, Clone)]
pub struct PooledPart {
    pub dataset: DatasetId,
    pub shards: Vec<PooledShard>,
}

/// Result of a
/// [`ReStore::load_many_pooled`](crate::restore::ReStore::load_many_pooled):
/// the same two fused phase costs as [`LoadManyOutput`], with every
/// request's bytes assembled into **one** pooled `arena` allocation
/// instead of one `Vec<u8>` per request per dataset.
#[derive(Debug, Clone)]
pub struct PooledLoadOutput {
    /// The single output allocation; each shard's bytes are
    /// `&arena[shard.span]`.
    pub arena: Vec<u8>,
    /// In input-part order.
    pub parts: Vec<PooledPart>,
    pub request_cost: crate::simnet::network::PhaseCost,
    pub data_cost: crate::simnet::network::PhaseCost,
    /// Total (= request + data).
    pub cost: crate::simnet::network::PhaseCost,
}

impl PooledLoadOutput {
    /// Bytes of request `shard` of part `part` (`None` for cost-model
    /// datasets) — the slice a per-request `LoadedShard` would own.
    pub fn shard_bytes(&self, part: usize, shard: usize) -> Option<&[u8]> {
        self.parts[part].shards[shard].span.clone().map(|s| &self.arena[s])
    }
}

/// The in-flight half of a double-buffered (GASPI-style) resubmit: the
/// new version's replica slices land here while `Dataset::stores` keeps
/// serving the previous *committed* version. Commit drains these staged
/// slices into the committed stores (and, for a shape-changing resubmit,
/// swaps in the whole `new_layout`); any failure or epoch bump observed at
/// a `ResubmitStep` boundary drops the staging wholesale — loads never see
/// a torn mix. See `restore/resubmit.rs`.
pub(crate) struct Staging {
    /// Machine-sized store shells holding ONLY the staged slices.
    pub(crate) stores: Vec<PeStore>,
    /// The version this staging will commit as (committed version + 1).
    pub(crate) version: u64,
    /// Original-id blocks being re-replicated (the dirty set's cardinality).
    pub(crate) dirty_blocks: u64,
    /// Total replicated payload (Σ staged slice bytes across all holders).
    pub(crate) replicated_bytes: u64,
    /// For a shape-changing full resubmit: the complete new layout swapped
    /// in at commit (in-place delta/full resubmits leave this `None`).
    pub(crate) new_layout: Option<StagedLayout>,
}

/// New layout carried by a shape-changing resubmit's staging.
pub(crate) struct StagedLayout {
    pub(crate) dist: Distribution,
    pub(crate) pe_map: Vec<u32>,
    pub(crate) holder_index: HolderIndex,
}

/// One dataset of the registry: the per-datatype replicated store of §V
/// (its own `n`, `r`, `b`, seed — independent of every other dataset), with
/// the full versioned-mutable lifecycle: `submit` (version 1) →
/// `load`/`repair` → `resubmit` (versions 2, 3, ... — full, dirty-range, or
/// checksum-delta) → `rebalance`/`acknowledge_shrink` →
/// `ReStore::delete_dataset`. The heavy path implementations live in their
/// historical modules (`submit.rs`, `load.rs`, `repair.rs`, `rebalance.rs`,
/// `resubmit.rs`) as `impl Dataset` blocks.
pub struct Dataset {
    pub(crate) id: DatasetId,
    pub(crate) cfg: RestoreConfig,
    pub(crate) dist: Distribution,
    pub(crate) stores: Vec<PeStore>,
    pub(crate) submitted: bool,
    /// Payload mode, latched at submit time (`submit` → true,
    /// `submit_virtual` → false): whether stores hold real bytes
    /// (execution mode) or virtual lengths (cost-model mode). Replaces the
    /// former per-call O(p) store sweep on every load/rebalance.
    pub(crate) execution: bool,
    /// Reverse holder index (permuted slot → storing PEs, in *cluster*
    /// ranks), maintained incrementally by submit, §IV-E repair, and the
    /// §IV-B rebalance; consulted by repair/rebalance planning and the load
    /// path's post-repair fallback instead of an O(p) store sweep.
    pub(crate) holder_index: HolderIndex,
    /// Distribution rank → cluster rank. The identity until the first
    /// rebalance; afterwards the shrink's dense re-ranking
    /// (`RankMap::new_to_old`), so the `Distribution` computes the §IV-A
    /// layout in the compact post-shrink world while stores, requests, and
    /// the network keep addressing original cluster ranks.
    pub(crate) pe_map: Vec<u32>,
    /// Communicator epoch this layout was computed at. `submit`/`load`/
    /// `repair` refuse to run when `ulfm::shrink` has bumped the cluster
    /// epoch past it — the caller must `rebalance` (or
    /// `acknowledge_shrink`) first.
    pub(crate) epoch: u64,
    /// Reusable buffers for the load pipeline — grown on first use, then
    /// reused so steady-state `load()` calls allocate nothing per piece.
    pub(crate) scratch: LoadScratch,
    /// Incremental scrub cursor: the next permuted *slot* (slice number)
    /// `Dataset::scrub` will verify. Wraps at the distribution world and
    /// is re-clamped after a rebalance shrinks the slot space — see
    /// `restore/integrity.rs`. In-place resubmits keep the cursor (the
    /// slot space is unchanged and staged bytes re-latch their checksums
    /// at commit); a shape-changing resubmit resets it to 0.
    pub(crate) scrub_slot: usize,
    /// Committed data version: 0 before submit, 1 after `submit`, bumped
    /// by every committed `resubmit`. Orthogonal to `epoch` (which tracks
    /// the *communicator*): the epoch says which world the layout
    /// addresses, the version says which generation of bytes it serves.
    pub(crate) version: u64,
    /// In-flight double-buffered resubmit, if any (`restore/resubmit.rs`).
    /// Dropped wholesale by `install_layout`/`acknowledge_shrink` — a
    /// reconfiguration always aborts back to the committed version.
    pub(crate) staging: Option<Staging>,
    /// Tombstone set by `ReStore::delete_dataset`: the slot stays in the
    /// registry vec (so surviving `DatasetId`s remain stable) until
    /// `create_dataset` reuses it; every `index_of` lookup answers
    /// `UnknownDataset` in between.
    pub(crate) deleted: bool,
}

impl Dataset {
    /// Create a dataset sized for `cluster`'s world: the configured world
    /// must match the cluster's *base* world (its initial communicator —
    /// spare-pool PEs don't take part in submit), while the store array
    /// spans the whole machine so activated spares have slots to migrate
    /// replicas onto.
    pub(crate) fn new(id: DatasetId, cfg: RestoreConfig, cluster: &Cluster) -> Result<Self> {
        cfg.validate()?;
        if cfg.world != cluster.base_world() {
            return Err(Error::Config(format!(
                "config world {} != cluster world {}",
                cfg.world,
                cluster.base_world()
            )));
        }
        let dist = Distribution::new(&cfg);
        let stores = (0..cluster.world()).map(|_| PeStore::new(cfg.block_size)).collect();
        let holder_index = HolderIndex::new(cfg.world);
        Ok(Dataset {
            id,
            cfg,
            dist,
            stores,
            submitted: false,
            execution: false,
            holder_index,
            pe_map: (0..cfg.world as u32).collect(),
            epoch: cluster.epoch(),
            scratch: LoadScratch::default(),
            scrub_slot: 0,
            version: 0,
            staging: None,
            deleted: false,
        })
    }

    /// This dataset's id inside the registry.
    pub fn id(&self) -> DatasetId {
        self.id
    }

    pub fn config(&self) -> &RestoreConfig {
        &self.cfg
    }

    pub fn distribution(&self) -> &Distribution {
        &self.dist
    }

    pub fn stores(&self) -> &[PeStore] {
        &self.stores
    }

    pub fn is_submitted(&self) -> bool {
        self.submitted
    }

    /// The reverse holder index (permuted slot → storing PEs).
    pub fn holder_index(&self) -> &HolderIndex {
        &self.holder_index
    }

    /// Communicator epoch the current layout addresses.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Committed data version: 0 before submit, 1 after `submit`, +1 per
    /// committed [`resubmit`](Dataset::resubmit). Loads always serve
    /// exactly this version's bytes — an aborted resubmit never moves it.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// The `(epoch, version)` pair as one stamp. Anything that changes
    /// what a load would return bumps one of the two — rebalance,
    /// substitution, and re-grow bump the epoch; a committed resubmit
    /// bumps the version — so a cached read tagged with this stamp is
    /// provably current while the stamp still matches (the KV read
    /// cache's O(1) invalidation contract, [`crate::restore::kv`]).
    pub fn stamp(&self) -> (u64, u64) {
        (self.epoch, self.version)
    }

    /// Is a double-buffered resubmit staged but not yet committed? (Only
    /// observable from a fault-injection callback — the public resubmit
    /// entry points either commit or abort before returning.)
    pub fn replication_in_flight(&self) -> bool {
        self.staging.is_some()
    }

    /// `(pes, nodes)` the pooled accumulator touched in this dataset's most
    /// recent communication phase (the data phase for a load). The scale
    /// benches and the alloc-count harness assert this stays O(touched) —
    /// bounded by the endpoints a load actually visits, independent of the
    /// world size `p`.
    pub fn last_phase_touched(&self) -> (usize, usize) {
        self.scratch.acc.last_touched()
    }

    /// Cluster rank of distribution rank `dist_rank` (identity until the
    /// first rebalance).
    #[inline]
    pub fn cluster_rank(&self, dist_rank: usize) -> usize {
        self.pe_map[dist_rank] as usize
    }

    /// Does the current survivor count admit the balanced §IV-A layout for
    /// this dataset (see [`Distribution::reshape_feasible`])? A pure
    /// feasibility predicate; the full shrink handshake is
    /// [`ReStore::rebalance_or_acknowledge`](crate::restore::ReStore::rebalance_or_acknowledge).
    pub fn can_rebalance(&self, cluster: &Cluster) -> bool {
        self.submitted && self.dist.reshape_feasible(cluster.n_alive())
    }

    /// Adopt a shrunk communicator **without** rewriting the layout: the
    /// distribution keeps addressing the original world (load falls back to
    /// routing around dead ranks, repair re-replicates in place), but every
    /// dead PE's replica memory is reclaimed and the dataset's epoch
    /// catches up to the cluster's so submit/load/repair run again.
    /// Reclaiming must go through here (not the raw stores) to keep the
    /// reverse holder index consistent. Safe to call when no shrink
    /// happened (pure reclaim) and idempotent.
    pub fn acknowledge_shrink(&mut self, cluster: &Cluster) -> Result<()> {
        if cluster.world() != self.stores.len() {
            return Err(Error::Config(format!(
                "acknowledge_shrink: cluster world {} != store world {}",
                cluster.world(),
                self.stores.len()
            )));
        }
        for pe in 0..self.stores.len() {
            if !cluster.is_alive(pe) && !self.stores[pe].slices().is_empty() {
                self.stores[pe].clear();
                self.holder_index.drop_pe(pe);
            }
        }
        // Reconfiguration aborts any in-flight resubmit: the staged
        // version targeted the pre-shrink world and must never commit.
        self.staging = None;
        self.epoch = cluster.epoch();
        Ok(())
    }

    pub(crate) fn stores_mut(&mut self) -> &mut Vec<PeStore> {
        &mut self.stores
    }

    pub(crate) fn holder_index_mut(&mut self) -> &mut HolderIndex {
        &mut self.holder_index
    }

    /// Swap in a rebalanced layout (called by the §IV-B shrink machinery
    /// after the migration executed): new distribution, rank translation,
    /// stores, and holder index become current atomically, under the
    /// cluster's epoch.
    pub(crate) fn install_layout(
        &mut self,
        cluster: &Cluster,
        dist: Distribution,
        pe_map: Vec<u32>,
        stores: Vec<PeStore>,
        holder_index: HolderIndex,
    ) {
        debug_assert_eq!(pe_map.len(), dist.world());
        debug_assert_eq!(stores.len(), self.stores.len(), "store arrays span the machine");
        self.dist = dist;
        self.pe_map = pe_map;
        self.stores = stores;
        self.holder_index = holder_index;
        // The migrated layout carries the committed version only; any
        // in-flight resubmit staging addressed the old layout and is
        // dropped (never committed) on reconfiguration.
        self.staging = None;
        self.epoch = cluster.epoch();
    }

    pub(crate) fn mark_submitted(&mut self) -> Result<()> {
        if self.submitted {
            return Err(Error::AlreadySubmitted);
        }
        self.submitted = true;
        Ok(())
    }

    pub(crate) fn ensure_submitted(&self) -> Result<()> {
        if !self.submitted {
            return Err(Error::NotSubmitted);
        }
        Ok(())
    }

    /// The shrink-handshake guard on every routing operation: fail with
    /// [`Error::StaleEpoch`] when `ulfm::shrink` has produced a newer
    /// communicator than the one this layout was computed for.
    pub(crate) fn ensure_current_epoch(&self, cluster: &Cluster) -> Result<()> {
        if self.epoch != cluster.epoch() {
            return Err(Error::StaleEpoch {
                store_epoch: self.epoch,
                cluster_epoch: cluster.epoch(),
            });
        }
        Ok(())
    }

    /// Is this dataset holding real bytes (execution mode) rather than
    /// virtual lengths (cost-model mode)? A flag latched at submit time —
    /// the former implementation swept all `p` stores on every load and
    /// rebalance.
    #[inline]
    pub(crate) fn is_execution_mode(&self) -> bool {
        self.execution
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RestoreConfig;

    #[test]
    fn dataset_ids_are_dense_and_displayed_plainly() {
        assert_eq!(DatasetId::FIRST, DatasetId(0));
        assert_eq!(DatasetId(3).index(), 3);
        assert_eq!(format!("{}", DatasetId(7)), "7");
    }

    #[test]
    fn dataset_requires_matching_world() {
        let cluster = Cluster::new_execution(4, 2);
        let cfg = RestoreConfig::builder(8, 8, 16).replicas(2).build().unwrap();
        assert!(Dataset::new(DatasetId(0), cfg, &cluster).is_err());
        let cfg = RestoreConfig::builder(4, 8, 16).replicas(2).build().unwrap();
        let ds = Dataset::new(DatasetId(0), cfg, &cluster).unwrap();
        assert!(!ds.is_submitted());
        assert!(!ds.is_execution_mode());
        assert_eq!(ds.epoch(), 0);
    }
}
