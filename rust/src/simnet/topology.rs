//! Cluster topology: which PEs share a node (and therefore a NIC and a
//! failure domain).
//!
//! SuperMUC-NG (§VI-A): 48 PEs per node. The paper's placement argument
//! (§IV-A) is that the `r` copies of a block land on PEs that are far apart
//! in rank space and therefore (block cyclic job placement) on different
//! nodes/racks — `Topology` lets tests verify that property.

/// Node/PE topology of the simulated cluster.
#[derive(Debug, Clone)]
pub struct Topology {
    pes: usize,
    pes_per_node: usize,
}

impl Topology {
    pub fn new(pes: usize, pes_per_node: usize) -> Self {
        assert!(pes > 0 && pes_per_node > 0);
        Topology { pes, pes_per_node }
    }

    /// Total number of PEs.
    pub fn pes(&self) -> usize {
        self.pes
    }

    /// PEs sharing one node (and its NIC).
    pub fn pes_per_node(&self) -> usize {
        self.pes_per_node
    }

    /// Number of nodes (last node may be partially filled).
    pub fn nodes(&self) -> usize {
        self.pes.div_ceil(self.pes_per_node)
    }

    /// Node hosting `rank`.
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.pes_per_node
    }

    /// All ranks on `node`.
    pub fn ranks_on_node(&self, node: usize) -> std::ops::Range<usize> {
        let lo = node * self.pes_per_node;
        lo..(lo + self.pes_per_node).min(self.pes)
    }

    /// Do two ranks share a node (= a failure domain)?
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_mapping() {
        let t = Topology::new(100, 48);
        assert_eq!(t.nodes(), 3);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(47), 0);
        assert_eq!(t.node_of(48), 1);
        assert_eq!(t.ranks_on_node(2), 96..100);
        assert!(t.same_node(0, 47));
        assert!(!t.same_node(47, 48));
    }

    #[test]
    fn paper_placement_spreads_copies_across_nodes() {
        // r=4 copies of PE i's shard live on i + k*p/r — different nodes for
        // any p >= r * pes_per_node (the paper's §IV-A claim).
        let p = 4 * 48 * 4;
        let t = Topology::new(p, 48);
        for i in 0..p {
            let nodes: std::collections::HashSet<_> =
                (0..4).map(|k| t.node_of((i + k * p / 4) % p)).collect();
            assert_eq!(nodes.len(), 4);
        }
    }
}
