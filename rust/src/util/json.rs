//! Minimal JSON parser — in-tree replacement for `serde_json`, sufficient
//! for the artifact `manifest.json` (objects, arrays, strings, numbers,
//! bools, null; no \u escapes beyond BMP pass-through).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while self
                        .peek()
                        .is_some_and(|c| c != b'"' && c != b'\\')
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| JsonError {
            offset: start,
            msg: format!("bad number '{text}'"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "kmeans_step": {
            "file": "kmeans_step.hlo.txt",
            "args": [{"shape": [65536, 32], "dtype": "float32"}],
            "results": [{"name": "sums", "shape": [20, 32], "dtype": "float32"}],
            "sha256": "abc"
          }
        }"#;
        let v = Json::parse(doc).unwrap();
        let entry = v.get("kmeans_step").unwrap();
        assert_eq!(entry.get("file").unwrap().as_str(), Some("kmeans_step.hlo.txt"));
        let shape = entry.get("args").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(65536));
    }

    #[test]
    fn scalars_and_nesting() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
        assert_eq!(
            Json::parse("[1, [2, 3], {}]").unwrap(),
            Json::Arr(vec![
                Json::Num(1.0),
                Json::Arr(vec![Json::Num(2.0), Json::Num(3.0)]),
                Json::Obj(Default::default())
            ])
        );
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1 2]").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("{} trailing").is_err());
        assert!(Json::parse("nul").is_err());
    }
}
