//! Failure schedules.
//!
//! The paper's application experiments (§VI-C) "simulate an expected failure
//! of 1 % of all nodes distributed uniformly at random during these
//! iterations ... by determining a suitable probability for each PE to fail
//! in each iteration" (a discrete exponential decay). Fig 3 kills PEs
//! uniformly at random one by one. Node-correlated failures (whole node
//! dies, taking its 48 PEs) are the failure mode the placement's
//! node-spreading argument (§IV-A) defends against — provided here for the
//! ablation benches.

use crate::simnet::topology::Topology;
use crate::util::rng::Rng;

/// Discrete exponential-decay schedule: each alive PE fails independently
/// with probability `q` per iteration, with `q` chosen so that the expected
/// surviving fraction after `iterations` equals `1 - total_fraction`.
#[derive(Debug, Clone, Copy)]
pub struct ExpDecaySchedule {
    pub per_iteration_prob: f64,
}

impl ExpDecaySchedule {
    pub fn new(total_fraction: f64, iterations: usize) -> Self {
        assert!((0.0..1.0).contains(&total_fraction));
        assert!(iterations > 0);
        // (1 - q)^iterations = 1 - total_fraction
        let q = 1.0 - (1.0 - total_fraction).powf(1.0 / iterations as f64);
        ExpDecaySchedule { per_iteration_prob: q }
    }

    /// Sample the ranks failing this iteration from `alive`.
    pub fn sample(&self, rng: &mut Rng, alive: &[usize]) -> Vec<usize> {
        alive
            .iter()
            .copied()
            .filter(|_| rng.gen_bool(self.per_iteration_prob))
            .collect()
    }
}

/// Kill `count` PEs chosen uniformly at random from `alive` (Fig 3 setup).
pub fn uniform_kills(rng: &mut Rng, alive: &[usize], count: usize) -> Vec<usize> {
    let mut pool: Vec<usize> = alive.to_vec();
    rng.shuffle(&mut pool);
    pool.truncate(count.min(pool.len()));
    pool
}

/// Whole-node failure: all PEs of `node` die together.
pub fn node_failure(topo: &Topology, node: usize) -> Vec<usize> {
    topo.ranks_on_node(node).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_decay_hits_target_fraction_in_expectation() {
        let sched = ExpDecaySchedule::new(0.01, 500);
        // survival after 500 iterations = (1-q)^500 = 0.99
        let survive = (1.0 - sched.per_iteration_prob).powi(500);
        assert!((survive - 0.99).abs() < 1e-12);
    }

    #[test]
    fn exp_decay_samples_roughly_one_percent() {
        let mut rng = Rng::seed_from_u64(7);
        let sched = ExpDecaySchedule::new(0.01, 500);
        let mut alive: Vec<usize> = (0..24576).collect();
        for _ in 0..500 {
            let dead = sched.sample(&mut rng, &alive);
            alive.retain(|r| !dead.contains(r));
        }
        let frac = 1.0 - alive.len() as f64 / 24576.0;
        // paper observed "up to 262 PEs failing" at 24576 (≈1.07 %)
        assert!(frac > 0.005 && frac < 0.02, "fraction {frac}");
    }

    #[test]
    fn uniform_kills_are_distinct_and_alive() {
        let mut rng = Rng::seed_from_u64(1);
        let alive: Vec<usize> = (0..100).step_by(2).collect();
        let k = uniform_kills(&mut rng, &alive, 10);
        assert_eq!(k.len(), 10);
        let set: std::collections::HashSet<_> = k.iter().collect();
        assert_eq!(set.len(), 10);
        for r in &k {
            assert!(alive.contains(r));
        }
    }

    #[test]
    fn uniform_kills_caps_at_pool() {
        let mut rng = Rng::seed_from_u64(2);
        assert_eq!(uniform_kills(&mut rng, &[1, 2, 3], 10).len(), 3);
    }

    #[test]
    fn node_failure_kills_whole_node() {
        let topo = Topology::new(100, 48);
        assert_eq!(node_failure(&topo, 1), (48..96).collect::<Vec<_>>());
        assert_eq!(node_failure(&topo, 2), (96..100).collect::<Vec<_>>());
    }
}
