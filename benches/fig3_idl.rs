//! Fig 3 — fault resilience of the data distribution (§VI-B1).
//!
//! (a) Monte-Carlo simulation: kill uniformly random PEs until all copies
//!     of some data block are lost; plot the failed fraction at that point
//!     for r ∈ {1,2,3,4} and p = 2^4 … 2^25.
//! (b) The §IV-D closed form vs the simulation (empirical CDF), r = 4.
//!
//! Paper anchors: with r = 4, even at p = 2^25 more than 1 % of all PEs
//! must fail before data is lost; the formula matches the simulation
//! closely; r := 4 is chosen for all further experiments.

use restore::metrics::{Stats, Table};
use restore::restore::idl;
use restore::util::rng::Rng;

fn main() {
    println!("=== Fig 3a: % failed PEs until irrecoverable data loss ===\n");
    let mut table = Table::new(vec!["p", "r=1", "r=2", "r=3", "r=4"]);
    let exponents = [4u32, 7, 10, 13, 16, 19, 22, 25];
    for &e in &exponents {
        let p = 1u64 << e;
        let mut cells = vec![format!("2^{e}")];
        for r in 1..=4u64 {
            if p % r != 0 {
                cells.push("-".into());
                continue;
            }
            let reps = if e >= 22 { 5 } else { 10 };
            let mut rng = Rng::seed_from_u64(0xF16_3A + e as u64 * 31 + r);
            let fracs: Vec<f64> = (0..reps)
                .map(|_| idl::simulate_failures_until_idl(p, r, &mut rng) as f64 / p as f64)
                .collect();
            let s = Stats::from(&fracs);
            cells.push(format!("{:.3}%", s.mean * 100.0));
        }
        table.row(cells);
    }
    println!("{}", table.render());
    // the §VI-B1 anchor
    let mut rng = Rng::seed_from_u64(1);
    let worst: f64 = (0..5)
        .map(|_| idl::simulate_failures_until_idl(1 << 25, 4, &mut rng) as f64 / (1u64 << 25) as f64)
        .fold(f64::INFINITY, f64::min);
    println!(
        "paper anchor (r=4, p=2^25): >1 % of PEs must fail before IDL -> measured min {:.2} % {}\n",
        worst * 100.0,
        if worst > 0.01 { "[OK]" } else { "[MISMATCH]" }
    );

    println!("=== Fig 3b: closed form (§IV-D) vs simulation, r = 4 ===\n");
    for &p in &[1u64 << 10, 1 << 16] {
        let r = 4u64;
        let runs = 2000usize;
        let mut rng = Rng::seed_from_u64(0x3B + p);
        let mut results: Vec<u64> =
            (0..runs).map(|_| idl::simulate_failures_until_idl(p, r, &mut rng)).collect();
        results.sort_unstable();
        let mut t = Table::new(vec!["f/p", "P<= (formula)", "P<= (simulated)", "approx g(f/p)^r"]);
        let mut max_err = 0.0f64;
        for pct in [0.1f64, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0] {
            let f = ((pct / 100.0) * p as f64).round() as u64;
            if f == 0 {
                continue;
            }
            let exact = idl::p_idl_leq(p, r, f);
            let emp = results.iter().filter(|&&x| x <= f).count() as f64 / runs as f64;
            max_err = max_err.max((exact - emp).abs());
            t.row(vec![
                format!("{pct:.1}%"),
                format!("{exact:.4}"),
                format!("{emp:.4}"),
                format!("{:.4}", idl::p_idl_approx(p, r, f)),
            ]);
        }
        println!("p = {p} ({runs} simulation runs)");
        println!("{}", t.render());
        println!(
            "max |formula - simulation| = {max_err:.4} {}\n",
            if max_err < 0.03 { "[OK: matches closely]" } else { "[MISMATCH]" }
        );
    }

    println!("E[failures until IDL] (exact formula):");
    let mut t = Table::new(vec!["p", "r", "E[failures]", "% of p"]);
    for &(p, r) in &[(48u64, 4u64), (1536, 4), (24576, 4), (24576, 2)] {
        let e = idl::expected_failures_until_idl(p, r);
        t.row(vec![
            p.to_string(),
            r.to_string(),
            format!("{e:.1}"),
            format!("{:.2}%", 100.0 * e / p as f64),
        ]);
    }
    println!("{}", t.render());
}
