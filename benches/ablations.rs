//! Ablations over the design choices DESIGN.md calls out (beyond the
//! paper's own figures):
//!
//! A. Serving-PE selection policy (random / least-loaded / primary).
//! B. Shared permutation across copies vs a distinct permutation per copy
//!    (the §IV-B resilience argument).
//! C. §IV-E repair: Distribution A (double hashing) vs B (Feistel walk) —
//!    probe cost and repair volume.
//! D. §IV-C memory accounting: resident replica bytes = r·n/p exactly.

use restore::config::{RestoreConfig, ServerSelection};
use restore::metrics::{fmt_time, Table};
use restore::restore::load::load_percent_requests;
use restore::restore::repair::{ProbeSequences, RepairScheme};
use restore::restore::{idl, ReStore};
use restore::simnet::cluster::Cluster;
use restore::util::bench::{bench, black_box};
use restore::util::rng::Rng;

fn main() {
    ablation_server_selection();
    ablation_distinct_permutation();
    ablation_repair_schemes();
    ablation_memory_accounting();
}

fn ablation_server_selection() {
    println!("=== Ablation A: serving-PE selection policy (load 1 %, p=1536) ===\n");
    let mut table =
        Table::new(vec!["policy", "sim time", "bottleneck msgs", "bottleneck bytes"]);
    for (name, sel) in [
        ("random (paper)", ServerSelection::Random),
        ("least-loaded", ServerSelection::LeastLoaded),
        ("primary-only", ServerSelection::Primary),
    ] {
        let cfg = RestoreConfig::builder(1536, 64, 262_144)
            .replicas(4)
            .perm_range_bytes(Some(256 * 1024))
            .server_selection(sel)
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(1536, 48);
        let mut store = ReStore::new(cfg, &cluster).unwrap();
        store.submit_virtual(&mut cluster).unwrap();
        cluster.kill(&[100]);
        let reqs = load_percent_requests(&store, &cluster, 1.0, 99);
        let t = cluster.now();
        let out = store.load(&mut cluster, &reqs).unwrap();
        table.row(vec![
            name.to_string(),
            fmt_time(cluster.now() - t),
            out.data_cost.bottleneck_msgs.to_string(),
            out.data_cost.bottleneck_bytes.to_string(),
        ]);
    }
    println!("{}", table.render());
}

fn ablation_distinct_permutation() {
    println!("=== Ablation B: shared vs distinct permutation per copy (§IV-B) ===\n");
    let mut table = Table::new(vec!["p", "r", "shared: mean f@IDL", "distinct: mean f@IDL"]);
    for &(p, r) in &[(256u64, 2u64), (1024, 4), (4096, 4)] {
        let mut rng = Rng::seed_from_u64(p * 31 + r);
        let reps = 200;
        let units = p * 16;
        let shared: f64 = (0..reps)
            .map(|_| idl::simulate_failures_until_idl(p, r, &mut rng) as f64)
            .sum::<f64>()
            / reps as f64;
        let distinct: f64 = (0..reps)
            .map(|_| idl::simulate_failures_until_idl_distinct(p, r, units, &mut rng) as f64)
            .sum::<f64>()
            / reps as f64;
        table.row(vec![
            p.to_string(),
            r.to_string(),
            format!("{:.1} ({:.2}%)", shared, 100.0 * shared / p as f64),
            format!("{:.1} ({:.2}%)", distinct, 100.0 * distinct / p as f64),
        ]);
    }
    println!("{}", table.render());
    println!("(sharing one permutation across copies tolerates more failures — the\n paper's §IV-B design choice)\n");
}

fn ablation_repair_schemes() {
    println!("=== Ablation C: §IV-E probing-sequence constructions ===\n");
    let p = 24576;
    let mut table = Table::new(vec!["scheme", "probe() mean", "full r-home lookup"]);
    for (name, scheme) in [
        ("A: double hashing", RepairScheme::DoubleHashing),
        ("B: Feistel walk", RepairScheme::FeistelWalk),
    ] {
        let seqs = ProbeSequences::new(p, 7, scheme);
        let mut x = 0u64;
        let probe = bench(name, 1000, 20000, || {
            x = x.wrapping_add(1);
            black_box(seqs.probe(x, 3));
        });
        let seqs2 = ProbeSequences::new(p, 7, scheme);
        let det = |k: usize| (k * (p / 4)) % p;
        let mut y = 0u64;
        let homes = bench(name, 200, 2000, || {
            y = y.wrapping_add(1);
            black_box(seqs2.replica_homes(y, 4, |pe| pe % 97 != 0, det));
        });
        table.row(vec![
            name.to_string(),
            fmt_time(probe.stats.mean),
            fmt_time(homes.stats.mean),
        ]);
    }
    println!("{}", table.render());
}

fn ablation_memory_accounting() {
    println!("=== Ablation D: §IV-C memory formula (resident = r*n/p blocks) ===\n");
    let mut table = Table::new(vec!["p", "r", "perm", "resident/PE", "formula", "match"]);
    for &(p, r, perm) in
        &[(48usize, 4usize, true), (48, 4, false), (96, 2, true), (96, 8, false)]
    {
        let cfg = RestoreConfig::builder(p, 64, 4096)
            .replicas(r)
            .perm_range_bytes(perm.then_some(16 * 1024))
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p, 48.min(p));
        let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
        store.submit_virtual(&mut cluster).unwrap();
        let resident = store.stores()[0].resident_bytes();
        let formula = cfg.replica_bytes_per_pe() as u64;
        let all_match = store.stores().iter().all(|s| s.resident_bytes() == formula);
        table.row(vec![
            p.to_string(),
            r.to_string(),
            perm.to_string(),
            resident.to_string(),
            formula.to_string(),
            if all_match { "[OK]".into() } else { "[MISMATCH]".to_string() },
        ]);
    }
    println!("{}", table.render());
}
