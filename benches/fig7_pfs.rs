//! Fig 7 — ReStore vs loading from the parallel file system (§VI-D1).
//!
//! 16 MiB per PE. The PFS side reads the paper's ideal layout: a single
//! consecutive read per PE, either one file per PE (C++ ifstream) or one
//! shared file via MPI_File_read_at_all (MPI I/O) — "a lower bound for all
//! checkpointing libraries that have to read their data from disk".
//!
//! Paper anchors at p = 24576: ReStore outperforms ifstream by ~206×
//! (load 1 %) and ~55× (load all).

use restore::config::{PfsConfig, RestoreConfig};
use restore::metrics::{fmt_time, Stats, Table};
use restore::pfs::{CacheState, Pfs, PfsMethod};
use restore::restore::load::{load_all_requests, load_percent_requests};
use restore::restore::ReStore;
use restore::simnet::cluster::Cluster;
use restore::util::bench::sim_samples;

const BYTES_PER_PE: u64 = 16 * 1024 * 1024;
const BLOCK: usize = 64;

fn main() {
    let pfs = Pfs::new(PfsConfig::default());
    let pes = [48usize, 192, 768, 3072, 12288, 24576];
    let reps = 5;

    let mut speedup_1pct_at_max = 0.0;
    let mut speedup_all_at_max = 0.0;
    for &op in &["load 1% data", "load all data"] {
        println!("=== Fig 7: {op} — ReStore vs PFS ===\n");
        let mut table = Table::new(vec![
            "p",
            "ReStore",
            "PFS ifstream",
            "PFS MPI I/O",
            "ifstream/ReStore",
        ]);
        for &p in &pes {
            let restore_t = run_restore(op, p, reps);
            // the PFS side reads the same per-client volume that the op
            // distributes over the alive PEs
            let bytes_per_client = if op == "load 1% data" {
                (0.01 * p as f64 * BYTES_PER_PE as f64 / p as f64) as u64
            } else {
                BYTES_PER_PE
            };
            let ifs =
                pfs.read_time_s(PfsMethod::IfStream, CacheState::Uncached, p, bytes_per_client);
            let mio = pfs.read_time_s(PfsMethod::MpiIo, CacheState::Uncached, p, bytes_per_client);
            let speedup = ifs / restore_t.mean;
            if p == 24576 {
                if op == "load 1% data" {
                    speedup_1pct_at_max = speedup;
                } else {
                    speedup_all_at_max = speedup;
                }
            }
            table.row(vec![
                p.to_string(),
                fmt_time(restore_t.mean),
                fmt_time(ifs),
                fmt_time(mio),
                format!("{speedup:.0}x"),
            ]);
        }
        println!("{}", table.render());
    }

    println!(
        "paper anchors at p=24576 (vs ifstream): load-1% 206x -> measured {:.0}x {}",
        speedup_1pct_at_max,
        ok(speedup_1pct_at_max > 20.0)
    );
    println!(
        "                                        load-all  55x -> measured {:.0}x {}",
        speedup_all_at_max,
        ok(speedup_all_at_max > 5.0)
    );
}

fn ok(b: bool) -> &'static str {
    if b {
        "[OK: order of magnitude holds]"
    } else {
        "[MISMATCH]"
    }
}

fn run_restore(op: &str, p: usize, reps: usize) -> Stats {
    sim_samples(reps, |rep| {
        // paper recommendation: permutation on for partial loads, off for
        // load-all (§VI-B2)
        let perm = if op == "load 1% data" { Some(256 * 1024) } else { None };
        let cfg = RestoreConfig::builder(p, BLOCK, BYTES_PER_PE as usize / BLOCK)
            .replicas(4)
            .perm_range_bytes(perm)
            .seed(0xF167 + rep)
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p, 48.min(p));
        let mut store = ReStore::new(cfg, &cluster).unwrap();
        store.submit_virtual(&mut cluster).unwrap();
        let reqs = if op == "load 1% data" {
            load_percent_requests(&store, &cluster, 1.0, (rep as usize * 31) % p)
        } else {
            load_all_requests(&store, &cluster)
        };
        let t = cluster.now();
        store.load(&mut cluster, &reqs).unwrap();
        cluster.now() - t
    })
}
