//! The submit path: one-time checkpoint creation (§IV-A/§V).
//!
//! Every PE pushes its serialized shard to the `r` holders of each of its
//! permutation ranges. Messages to the same destination are coalesced into
//! one buffer (this is why the paper "can tolerate an increase in running
//! time of submit": with permutations a PE talks to up to
//! `min(r · ranges_per_pe, p)` destinations — the denser pattern Fig 4b
//! shows — but still sends each destination exactly one message).
//!
//! The §IV-C memory statement "the memory requirement is doubled during
//! submission as we require additional space for the send and receive
//! buffers" is charged as a local serialization copy.

use crate::error::{Error, Result};
use crate::restore::registry::Dataset;
use crate::restore::store::SliceBuf;
use crate::restore::SubmitReport;
use crate::simnet::cluster::Cluster;
use crate::simnet::network::PhaseCost;

#[cfg(feature = "rayon")]
use rayon::prelude::*;

/// Below this many permutation units the schedule's unit→slot precompute
/// stays serial even with the `rayon` feature (fork/join overhead, and the
/// allocation-count assertions stay exact at test scales).
#[cfg(feature = "rayon")]
const PAR_MIN_UNITS: usize = 4096;

impl Dataset {
    /// Submit real data: `shards[pe]` is PE `pe`'s serialized blocks
    /// (`blocks_per_pe * block_size` bytes). Execution mode.
    pub fn submit(&mut self, cluster: &mut Cluster, shards: &[Vec<u8>]) -> Result<SubmitReport> {
        let shard_bytes = self.cfg.blocks_per_pe * self.cfg.block_size;
        if shards.len() != self.cfg.world {
            return Err(Error::Config(format!(
                "submit: got {} shards for world {}",
                shards.len(),
                self.cfg.world
            )));
        }
        for (pe, s) in shards.iter().enumerate() {
            if s.len() != shard_bytes {
                return Err(Error::Config(format!(
                    "submit: PE {pe} shard has {} bytes, expected {shard_bytes}",
                    s.len()
                )));
            }
        }
        self.submit_inner(cluster, Some(shards))
    }

    /// Submit in cost-model mode: schedules and costs are identical to
    /// [`ReStore::submit`], but no bytes are materialized.
    pub fn submit_virtual(&mut self, cluster: &mut Cluster) -> Result<SubmitReport> {
        self.submit_inner(cluster, None)
    }

    fn submit_inner(
        &mut self,
        cluster: &mut Cluster,
        shards: Option<&[Vec<u8>]>,
    ) -> Result<SubmitReport> {
        self.ensure_current_epoch(cluster)?;
        self.mark_submitted()?;
        if cluster.n_alive() != self.cfg.world {
            return Err(Error::Config(
                "submit requires all PEs alive (data is submitted once, at program start)".into(),
            ));
        }
        // Latch the payload mode: every later load/rebalance reads this
        // flag instead of sweeping all p stores per call.
        self.execution = shards.is_some();

        let dist = self.dist.clone();
        let bs = self.cfg.block_size as u64;
        let s_pr = dist.perm_range_blocks();
        let r = dist.replicas();
        let p = dist.world();

        // Pre-create every PE's r slice buffers (zeroed in execution mode,
        // sized per slice) and register them in the reverse holder index.
        // This is also where integrity begins: `PeStore::insert` latches
        // per-block checksums for every Real slice and the zero-copy
        // `write_from` below refreshes them per written unit, so when
        // submit returns every stored block carries the checksum of its
        // submitted content — the reference every later load/repair/
        // rebalance/scrub verification compares against.
        for pe in 0..p {
            for k in 0..r {
                let range = dist.stored_slice(pe, k);
                let slot = dist.slice_of(range.start);
                let slice_bytes = (range.len() * bs) as usize;
                let buf = if shards.is_some() {
                    SliceBuf::Real(vec![0u8; slice_bytes])
                } else {
                    SliceBuf::Virtual(slice_bytes as u64)
                };
                self.stores[pe].insert(range, buf);
                self.holder_index_mut().insert(slot, pe);
            }
        }

        // Local serialization copy (the §IV-C "doubled during submission").
        let ser_cost = PhaseCost::local_copy(cluster.network(), shard_bytes_u64(&self.cfg));
        cluster.advance(&ser_cost);

        // Placement schedule: ONE concurrent sparse all-to-all phase.
        // Messages to the same destination are coalesced per source. The
        // holder of copy k is (slot_pe + k·stride + offset) mod p, so we
        // only count units per *slot PE* (one unit→slot lookup per unit,
        // served by the Distribution's precomputed placement index where
        // built) and expand the r copies when emitting — no per-copy
        // hashing. (§Perf: 8x faster schedule construction than the
        // HashMap version; see EXPERIMENTS.md §Perf.)
        // Submit only ever runs at the submit-time world (guarded above:
        // all PEs alive, epoch current, one-shot), where slices are equal
        // and unit-aligned — shard starts land on unit boundaries.
        debug_assert!(dist.equal_slices(), "submit runs before any reshape");
        let unit_bytes = s_pr * bs;
        let units_per_pe = (self.cfg.blocks_per_pe as u64 / s_pr) as usize;
        let stride = dist.copy_stride();
        let offset = dist.placement_offset();

        // Unit→slot lookup for the schedule: the global unit id
        // `g = src·units_per_pe + u` maps to permuted start
        // `unit_slot(g)·s_pr` (shard starts are unit-aligned). With the
        // `rayon` feature at large unit counts, all lookups are
        // precomputed in parallel across sources — `collect_into_vec`
        // preserves order, so the schedule below (and therefore every byte
        // and cost) is identical to the serial pass. Serial builds (and
        // small worlds) evaluate inline, with no O(units) temporary.
        #[cfg(feature = "rayon")]
        let unit_slots: Option<Vec<u64>> = {
            let total_units = p * units_per_pe;
            (total_units >= PAR_MIN_UNITS).then(|| {
                let mut v = Vec::with_capacity(total_units);
                (0..total_units)
                    .into_par_iter()
                    .map(|g| dist.unit_slot(g as u64))
                    .collect_into_vec(&mut v);
                v
            })
        };
        #[cfg(not(feature = "rayon"))]
        let unit_slots: Option<Vec<u64>> = None;
        let unit_slot_of = |g: usize| match &unit_slots {
            Some(v) => v[g],
            None => dist.unit_slot(g as u64),
        };

        let mut slot_units: Vec<u32> = vec![0; p];
        let mut touched: Vec<u32> = Vec::with_capacity(units_per_pe.min(p));
        let mut phase = cluster.phase();
        for src in 0..p {
            for u in 0..units_per_pe {
                let perm_start = unit_slot_of(src * units_per_pe + u) * s_pr;
                let slot_pe = dist.slice_of(perm_start);
                if slot_units[slot_pe] == 0 {
                    touched.push(slot_pe as u32);
                }
                slot_units[slot_pe] += 1;
                // Move the bytes (execution mode): write the unit straight
                // from the shard slice into each copy's slice at its
                // permuted offset — zero-copy, no `Vec` per unit×replica.
                if let Some(shards) = shards {
                    let off = (u as u64 * unit_bytes) as usize;
                    let bytes = &shards[src][off..off + unit_bytes as usize];
                    for k in 0..r {
                        let dst = (slot_pe + k * stride + offset) % p;
                        self.stores[dst].write_from(perm_start, bytes);
                    }
                }
            }
            for &slot_pe in &touched {
                let units = slot_units[slot_pe as usize] as u64;
                let b = units * unit_bytes;
                slot_units[slot_pe as usize] = 0;
                for k in 0..r {
                    let dst = (slot_pe as usize + k * stride + offset) % p;
                    phase.add(src, dst, b)?;
                    phase.frag(src, units);
                    if dst != src {
                        phase.frag(dst, units);
                    }
                }
            }
            touched.clear();
        }
        let cost = phase.commit();

        // The initial submit commits version 1 (0 = never submitted);
        // every later `Dataset::resubmit` commit bumps it further.
        self.version = 1;

        Ok(SubmitReport { cost: ser_cost.then(cost) })
    }
}

fn shard_bytes_u64(cfg: &crate::config::RestoreConfig) -> u64 {
    (cfg.blocks_per_pe * cfg.block_size) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RestoreConfig;
    use crate::restore::store::assert_memory_invariant;
    use crate::restore::ReStore;

    fn make_shards(world: usize, bytes: usize) -> Vec<Vec<u8>> {
        (0..world)
            .map(|pe| (0..bytes).map(|i| (pe * 31 + i) as u8).collect())
            .collect()
    }

    fn cfg(p: usize, bpp: usize, r: usize, s_pr: Option<usize>) -> RestoreConfig {
        RestoreConfig::builder(p, 8, bpp)
            .replicas(r)
            .perm_range_blocks(s_pr)
            .build()
            .unwrap()
    }

    #[test]
    fn submit_places_r_copies_of_every_block() {
        let cfg = cfg(8, 64, 4, Some(16));
        let mut cluster = Cluster::new_execution(8, 4);
        let mut rs = ReStore::new(cfg.clone(), &cluster).unwrap();
        let shards = make_shards(8, 64 * 8);
        rs.submit(&mut cluster, &shards).unwrap();

        // every original block readable from each of its r holders with the
        // right content
        let dist = rs.distribution().clone();
        for x in 0..dist.n_blocks() {
            let y = dist.permute_block(x);
            let pe = (x / 64) as usize;
            let off = ((x % 64) * 8) as usize;
            let expect = &shards[pe][off..off + 8];
            for k in 0..4 {
                let holder = dist.holder(y, k);
                let got = rs.stores()[holder].read(y, 1).unwrap();
                assert_eq!(got, expect, "block {x} copy {k} on PE {holder}");
            }
        }
        assert_memory_invariant(rs.stores(), &dist);
    }

    #[test]
    fn submit_without_permutation_places_whole_shards() {
        let cfg = cfg(4, 32, 2, None);
        let mut cluster = Cluster::new_execution(4, 2);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards = make_shards(4, 32 * 8);
        rs.submit(&mut cluster, &shards).unwrap();
        // copy 0 of PE i's shard is PE i itself; copy 1 is PE i + p/r = i+2
        for pe in 0..4usize {
            let start = pe as u64 * 32;
            assert_eq!(rs.stores()[pe].read(start, 32).unwrap(), &shards[pe][..]);
            let other = (pe + 2) % 4;
            assert_eq!(rs.stores()[other].read(start, 32).unwrap(), &shards[pe][..]);
        }
    }

    #[test]
    fn submit_twice_fails() {
        let cfg = cfg(4, 32, 2, None);
        let mut cluster = Cluster::new_execution(4, 2);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards = make_shards(4, 32 * 8);
        rs.submit(&mut cluster, &shards).unwrap();
        assert!(matches!(
            rs.submit(&mut cluster, &shards),
            Err(Error::AlreadySubmitted)
        ));
    }

    #[test]
    fn submit_after_failure_rejected() {
        let cfg = cfg(4, 32, 2, None);
        let mut cluster = Cluster::new_execution(4, 2);
        cluster.kill(&[1]);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        assert!(rs.submit(&mut cluster, &make_shards(4, 32 * 8)).is_err());
    }

    #[test]
    fn virtual_submit_costs_match_real() {
        let cfg = cfg(8, 64, 4, Some(16));
        let mut c1 = Cluster::new_execution(8, 4);
        let mut c2 = Cluster::new_execution(8, 4);
        let mut rs1 = ReStore::new(cfg.clone(), &c1).unwrap();
        let mut rs2 = ReStore::new(cfg, &c2).unwrap();
        let real = rs1.submit(&mut c1, &make_shards(8, 64 * 8)).unwrap();
        let virt = rs2.submit_virtual(&mut c2).unwrap();
        assert_eq!(real.cost, virt.cost);
        assert_eq!(c1.now(), c2.now());
    }

    #[test]
    fn permutation_makes_submit_denser() {
        // Fig 4b: submitting with permutations has a denser pattern (more
        // messages) than without.
        let mut c1 = Cluster::new_execution(16, 4);
        let mut c2 = Cluster::new_execution(16, 4);
        let mut plain = ReStore::new(cfg(16, 256, 4, None), &c1).unwrap();
        let mut perm = ReStore::new(cfg(16, 256, 4, Some(16)), &c2).unwrap();
        let a = plain.submit_virtual(&mut c1).unwrap();
        let b = perm.submit_virtual(&mut c2).unwrap();
        assert!(b.cost.total_msgs > a.cost.total_msgs);
        // same volume either way
        assert_eq!(
            a.cost.total_bytes + 16 * 256 * 8, // plain keeps copy 0 local
            b.cost.total_bytes + b_local_bytes(&perm, &b)
        );
    }

    /// Golden parity: the zero-copy `write_from` path must leave every
    /// store byte-identical to the seed implementation, which materialized
    /// one `Vec` per written unit × replica and went through
    /// `PeStore::write`.
    #[test]
    fn zero_copy_submit_matches_per_unit_vec_reference() {
        for s_pr in [Some(16), None] {
            let cfg = cfg(8, 64, 4, s_pr);
            let shards = make_shards(8, 64 * 8);

            // optimized path
            let mut cluster = Cluster::new_execution(8, 4);
            let mut rs = ReStore::new(cfg.clone(), &cluster).unwrap();
            let report = rs.submit(&mut cluster, &shards).unwrap();

            // reference: seed write path (fresh Vec per unit × replica)
            let dist = rs.distribution().clone();
            let bs = 8u64;
            let mut ref_stores: Vec<crate::restore::store::PeStore> =
                (0..8).map(|_| crate::restore::store::PeStore::new(8)).collect();
            for pe in 0..8 {
                for k in 0..4 {
                    let range = dist.stored_slice(pe, k);
                    let slice_bytes = (range.len() * bs) as usize;
                    ref_stores[pe].insert(range, SliceBuf::Real(vec![0u8; slice_bytes]));
                }
            }
            let s = dist.perm_range_blocks();
            let unit_bytes = (s * bs) as usize;
            for src in 0..8usize {
                for u in 0..(dist.slice_len(src) / s) as usize {
                    let orig = dist.slice_start(src) + u as u64 * s;
                    let perm_start = dist.permute_block(orig);
                    let off = u * unit_bytes;
                    let bytes = shards[src][off..off + unit_bytes].to_vec();
                    for k in 0..4 {
                        let dst = dist.holder(perm_start, k);
                        ref_stores[dst].write(perm_start, &SliceBuf::Real(bytes.clone()));
                    }
                }
            }

            for pe in 0..8 {
                let got = rs.stores()[pe].slices();
                let want = ref_stores[pe].slices();
                assert_eq!(got.len(), want.len(), "s_pr {s_pr:?}: PE {pe} slice count");
                for (g, w) in got.iter().zip(want) {
                    assert_eq!(g.range, w.range, "s_pr {s_pr:?}: PE {pe}");
                    let (SliceBuf::Real(gb), SliceBuf::Real(wb)) = (&g.buf, &w.buf) else {
                        panic!("execution mode must store real bytes");
                    };
                    assert_eq!(gb, wb, "s_pr {s_pr:?}: PE {pe} slice {:?} bytes", g.range);
                }
            }

            // ...and the cost must equal the schedule-only virtual run
            let mut c2 = Cluster::new_execution(8, 4);
            let mut rs2 = ReStore::new(cfg, &c2).unwrap();
            let virt = rs2.submit_virtual(&mut c2).unwrap();
            assert_eq!(report.cost, virt.cost, "s_pr {s_pr:?}");
        }
    }

    /// Schedule parity at a unit count large enough to cross the rayon
    /// precompute threshold: the phase cost must equal a naive per-unit
    /// reference schedule charged through a fresh accumulator. CI runs this
    /// under the serial, `--no-default-features`, and `--features rayon`
    /// builds — the serial-parity matrix for submit schedule construction.
    #[test]
    fn large_submit_schedule_matches_per_unit_reference() {
        use std::collections::HashMap;
        let cfg = RestoreConfig::builder(8, 8, 8192)
            .replicas(4)
            .perm_range_blocks(Some(8)) // 1024 units/PE, 8192 total
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(8, 4);
        let mut rs = ReStore::new(cfg.clone(), &cluster).unwrap();
        let report = rs.submit_virtual(&mut cluster).unwrap();

        // reference: the same one-message-per-(src, slot PE, copy) schedule,
        // rebuilt with direct permute_block calls and tuple-keyed maps
        // (message order is irrelevant to the accumulator — every counter
        // is a sum or a max — so only the message *granularity* must match)
        let dist = rs.distribution();
        let s = dist.perm_range_blocks();
        let unit_bytes = s * 8;
        let mut units_on: HashMap<(usize, usize), u64> = HashMap::new(); // (src, slot PE)
        for src in 0..8usize {
            let shard = dist.shard_of(src);
            for orig in (shard.start..shard.end).step_by(s as usize) {
                let y = dist.permute_block(orig);
                let slot_pe = dist.slice_of(y);
                *units_on.entry((src, slot_pe)).or_insert(0) += 1;
            }
        }
        let mut acc = crate::simnet::network::Accumulator::new(
            cluster.network(),
            cluster.topology(),
        );
        let stride = dist.copy_stride();
        for (&(src, slot_pe), &units) in &units_on {
            for k in 0..4 {
                let dst = (slot_pe + k * stride) % 8;
                acc.msg(src, dst, units * unit_bytes);
                acc.frag(src, units);
                if dst != src {
                    acc.frag(dst, units);
                }
            }
        }
        let want = acc.finish();
        let ser = PhaseCost::local_copy(cluster.network(), (cfg.blocks_per_pe * 8) as u64);
        assert_eq!(report.cost, ser.then(want));
    }

    #[test]
    fn submit_latches_checksums_for_every_stored_slice() {
        for s_pr in [Some(16), None] {
            let cfg = cfg(8, 64, 4, s_pr);
            let mut cluster = Cluster::new_execution(8, 4);
            let mut rs = ReStore::new(cfg, &cluster).unwrap();
            rs.submit(&mut cluster, &make_shards(8, 64 * 8)).unwrap();
            for pe in 0..8 {
                for s in rs.stores()[pe].slices() {
                    assert_eq!(s.sums.len() as u64, s.range.len(), "s_pr {s_pr:?} PE {pe}");
                    assert_eq!(
                        rs.stores()[pe].verify(s.range.start, s.range.len()),
                        None,
                        "s_pr {s_pr:?} PE {pe}: fresh submit must verify clean"
                    );
                }
            }
        }
    }

    #[test]
    fn submit_builds_consistent_holder_index() {
        for s_pr in [Some(16), None] {
            let cfg = cfg(8, 64, 4, s_pr);
            let mut cluster = Cluster::new_execution(8, 4);
            let mut rs = ReStore::new(cfg, &cluster).unwrap();
            rs.submit(&mut cluster, &make_shards(8, 64 * 8)).unwrap();
            let rebuilt =
                crate::restore::store::HolderIndex::rebuild(rs.stores(), rs.distribution());
            assert_eq!(*rs.holder_index(), rebuilt, "s_pr {s_pr:?}");
            // every slot has exactly r holders right after submit
            for slot in 0..8 {
                assert_eq!(rs.holder_index().holders_of(slot).len(), 4, "slot {slot}");
            }
        }
    }

    fn b_local_bytes(rs: &ReStore, _report: &SubmitReport) -> u64 {
        // bytes that stayed on their own PE under the permuted placement
        let dist = rs.distribution();
        let mut local = 0;
        let s_pr = dist.perm_range_blocks();
        for src in 0..dist.world() {
            let shard = dist.shard_of(src);
            for u in (shard.start..shard.end).step_by(s_pr as usize) {
                let y = dist.permute_block(u);
                for k in 0..dist.replicas() {
                    if dist.holder(y, k) == src {
                        local += s_pr * 8;
                    }
                }
            }
        }
        local
    }
}
