"""L1 Pallas kernels (build-time only; lowered to HLO by ../aot.py)."""

from .kmeans import kmeans_assign
from .phylo import phylo_loglik

__all__ = ["kmeans_assign", "phylo_loglik"]
