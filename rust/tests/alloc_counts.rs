//! Allocation-count assertions for the checkpoint lifecycle hot paths,
//! backed by the [`restore::util::bench::CountingAlloc`] global allocator
//! (registered here, in a dedicated test binary, so the counts are not
//! polluted by unrelated suites).
//!
//! The contract under test: execution-mode `submit` and `repair_replicas`
//! planning perform **zero per-unit heap allocations** — their allocation
//! counts must not scale with the number of permutation units (submit) or
//! with the world/unit count (repair planning), and steady-state `load`
//! calls must not allocate per routed piece.
//!
//! Everything runs inside ONE `#[test]` so the libtest harness never
//! formats or prints (allocating on the main thread) between two compared
//! measurement windows — with multiple tests those harness allocations
//! would land in the process-global counter and flake the equalities.

use restore::config::{RestoreConfig, ServerSelection};
use restore::restore::block::{BlockRange, RangeSet};
use restore::restore::load::{load_all_requests, scatter_requests};
use restore::restore::{DatasetId, KvBatch, KvStore, LoadRequest, Overlap, ResubmitMode};
use restore::restore::rebalance::{plan_rebalance, MigrationTransfer};
use restore::restore::repair::RepairScheme;
use restore::restore::ReStore;
use restore::simnet::cluster::Cluster;
use restore::simnet::ulfm;
use restore::util::bench::{alloc_count, CountingAlloc};

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs_during<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let before = alloc_count();
    let r = f();
    (alloc_count() - before, r)
}

fn make_shards(world: usize, bytes: usize) -> Vec<Vec<u8>> {
    (0..world).map(|pe| (0..bytes).map(|i| (pe * 31 + i) as u8).collect()).collect()
}

#[test]
fn alloc_counts_do_not_scale_with_units_world_or_pieces() {
    submit_allocations_do_not_scale_with_unit_count();
    repair_planning_allocations_do_not_scale_with_world();
    steady_state_load_allocations_do_not_scale_with_piece_count();
    rebalance_planning_allocations_do_not_scale_with_world();
    unequal_slice_rebalance_planning_allocations_do_not_scale_with_world();
    survivor_iteration_and_agreement_allocations_do_not_scale_with_world();
    clean_scrub_steps_allocate_nothing_at_any_world();
    execution_load_checksum_verification_allocations_do_not_scale_with_block_count();
    steady_load_touched_entries_do_not_scale_with_world();
    dirty_resubmit_allocations_do_not_scale_with_block_count();
    kv_cache_hit_path_allocates_nothing();
    kv_batch_planning_allocations_do_not_scale_with_world();
}

fn kv_cache_hit_path_allocates_nothing() {
    // The KV read cache's hit path contract: probe, stamp re-check, one
    // local-copy cost charge, and a borrowed-slice return — ZERO heap
    // allocations, with the network accumulator never touched.
    let cfg = RestoreConfig::builder(8, 8, 64).replicas(4).build().unwrap();
    let mut cluster = Cluster::new_execution(8, 4);
    let mut rs = ReStore::new(cfg, &cluster).unwrap();
    let shards = make_shards(8, 8 * 64);
    rs.submit(&mut cluster, &shards).unwrap();
    let mut kv = KvStore::new();
    kv.register(&rs, DatasetId::FIRST, 32).unwrap();
    // warm: the miss routes through the holders and fills the cache
    let warm = kv.get(&mut rs, &mut cluster, DatasetId::FIRST, 2, 11).unwrap().hit;
    assert!(!warm);
    let (n, hit) = allocs_during(|| {
        let g = kv.get(&mut rs, &mut cluster, DatasetId::FIRST, 2, 11).unwrap();
        assert!(g.bytes.is_some());
        g.hit
    });
    assert!(hit, "second identical get must hit the per-PE cache");
    assert_eq!(n, 0, "kv cache hit path allocated {n} times");
}

fn kv_batch_planning_allocations_do_not_scale_with_world() {
    // Fused batched-get planning is O(batch size): the same pinned
    // 16-get workload (requester i + 1 reads two blocks of PE i's shard;
    // Primary selection pins the servers at any world, exactly as in
    // `steady_load_touched_entries_do_not_scale_with_world`) must record
    // EQUAL allocation counts at p = 64 and p = 4096. Cache capacity 0 so
    // every get takes the planning + fused-load path.
    let count_for = |p: usize| {
        let cfg = RestoreConfig::builder(p, 8, 64)
            .replicas(4)
            .server_selection(ServerSelection::Primary)
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        rs.submit_virtual(&mut cluster).unwrap();
        let mut kv = KvStore::new();
        kv.register(&rs, DatasetId::FIRST, 0).unwrap();
        let mut batch = KvBatch::new();
        for i in 0..8u64 {
            batch.get(DatasetId::FIRST, i as usize + 1, i * 64);
            batch.get(DatasetId::FIRST, i as usize + 1, i * 64 + 7);
        }
        kv.execute(&mut rs, &mut cluster, &batch).unwrap(); // warm scratch
        let (n, out) = allocs_during(|| kv.execute(&mut rs, &mut cluster, &batch).unwrap());
        assert_eq!(out.misses, 16, "cache disabled: every get takes the planning path");
        n
    };
    let small = count_for(64);
    let large = count_for(4096);
    assert_eq!(
        small, large,
        "kv batch planning allocation count scales with p ({small} vs {large})"
    );
}

fn dirty_resubmit_allocations_do_not_scale_with_block_count() {
    // A k-dirty in-place resubmit stages and charges only the dirty
    // ranges: with the SAME fixed dirty set, the allocation count must be
    // identical at 8x the total block count (bpp 64 vs 512) — O(k) in the
    // dirty blocks, never O(n) in the dataset size.
    let count_for = |bpp: usize| {
        let cfg = RestoreConfig::builder(8, 8, bpp).replicas(4).build().unwrap();
        let mut cluster = Cluster::new_execution(8, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards = make_shards(8, 8 * bpp);
        rs.submit(&mut cluster, &shards).unwrap();
        let mut new = shards;
        for s in &mut new {
            for b in &mut s[24..56] {
                *b ^= 0xA5;
            }
        }
        let dirty = RangeSet::new(vec![BlockRange::new(3, 7), BlockRange::new(40, 44)]);
        // warm-up resubmit so staging scratch reaches steady-state size
        rs.resubmit(&mut cluster, &new, ResubmitMode::Dirty(&dirty), Overlap::Blocking).unwrap();
        let (n, rep) = allocs_during(|| {
            rs.resubmit(&mut cluster, &new, ResubmitMode::Dirty(&dirty), Overlap::Blocking)
                .unwrap()
        });
        assert_eq!(rep.dirty_blocks, 8, "fixed dirty set re-replicates 8 blocks");
        n
    };
    let small = count_for(64);
    let large = count_for(512);
    assert_eq!(
        small, large,
        "dirty resubmit allocation count scales with total blocks ({small} vs {large})"
    );
}

fn steady_load_touched_entries_do_not_scale_with_world() {
    // The pooled accumulator's per-phase reset walks only the entries the
    // previous phase touched: a fixed 8-request workload (requester i + 1
    // loads the first 16 blocks of PE i's shard; Primary selection and a
    // contiguous layout pin the servers to PEs 0..8 at any world) must
    // record EQUAL touched-entry counts at p = 64 and p = 4096 — bounded
    // by the endpoints the workload names, not the world size.
    let touched_for = |p: usize| {
        let cfg = RestoreConfig::builder(p, 8, 64)
            .replicas(4)
            .server_selection(ServerSelection::Primary)
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        rs.submit_virtual(&mut cluster).unwrap();
        let reqs: Vec<LoadRequest> = (0..8u64)
            .map(|i| LoadRequest {
                pe: i as usize + 1,
                ranges: RangeSet::new(vec![BlockRange::new(i * 64, i * 64 + 16)]),
            })
            .collect();
        rs.load(&mut cluster, &reqs).unwrap();
        rs.last_phase_touched()
    };
    let small = touched_for(64);
    let large = touched_for(4096);
    assert_eq!(
        small, large,
        "steady-load touched entries scale with world ({small:?} vs {large:?})"
    );
    let (tp, tn) = small;
    assert!(
        tp > 0 && tp <= 16 && tn <= 4,
        "workload names ~9 endpoints on 3 nodes, accumulator touched ({tp}, {tn})"
    );
}

fn clean_scrub_steps_allocate_nothing_at_any_world() {
    // The scrub clean path — the overwhelmingly common case: every copy
    // verifies — reads the reverse holder index and the per-slice checksum
    // tables in place. Both a single-slot budgeted step and a full cursor
    // wrap must make ZERO heap allocations (the quarantine list is lazily
    // allocated only when corruption is actually found), at any world.
    let check_at = |p: usize| {
        let cfg = RestoreConfig::builder(p, 8, 64).replicas(4).build().unwrap();
        let mut cluster = Cluster::new_execution(p, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards = make_shards(p, 8 * 64);
        rs.submit(&mut cluster, &shards).unwrap();
        let (n_step, rep) = allocs_during(|| rs.scrub(&mut cluster, 0).unwrap());
        assert!(rep.scanned_blocks > 0 && rep.corrupt_blocks == 0);
        assert_eq!(n_step, 0, "single-slot scrub step allocated {n_step} times at p = {p}");
        let (n_wrap, rep) = allocs_during(|| rs.scrub(&mut cluster, u64::MAX).unwrap());
        assert!(rep.wrapped && rep.corrupt_blocks == 0);
        assert_eq!(n_wrap, 0, "full clean scrub wrap allocated {n_wrap} times at p = {p}");
    };
    check_at(8);
    check_at(32);
}

fn execution_load_checksum_verification_allocations_do_not_scale_with_block_count() {
    // Same p, r, and bytes per PE; only the block granularity differs 8x
    // (512 vs 4096 blocks verified per whole-space load). The checksum
    // cross-check on load assembly must be allocation-free: after a
    // warm-up call the steady-state allocation count is the output-shard
    // bookkeeping only, identical across the two granularities.
    let count_for = |bs: usize, bpp: usize| {
        let cfg = RestoreConfig::builder(8, bs, bpp).replicas(4).build().unwrap();
        let mut cluster = Cluster::new_execution(8, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards = make_shards(8, bs * bpp);
        rs.submit(&mut cluster, &shards).unwrap();
        let reqs = load_all_requests(&rs, &cluster);
        rs.load(&mut cluster, &reqs).unwrap(); // warm every scratch buffer
        let (n, out) = allocs_during(|| rs.load(&mut cluster, &reqs).unwrap());
        assert!(out.shards.iter().all(|s| s.bytes.is_some()), "execution mode returns bytes");
        n
    };
    let coarse = count_for(64, 64); // 512 blocks, 64 B each
    let fine = count_for(8, 512); // 4096 blocks, 8 B each — same total bytes
    assert_eq!(
        coarse, fine,
        "load-path checksum verification allocations scale with block count ({coarse} vs {fine})"
    );
}

fn survivor_iteration_and_agreement_allocations_do_not_scale_with_world() {
    // The recovery policies and the failure-storm driver scan the alive /
    // failed sets every wave: `survivors_iter` / `failed_iter` must be
    // allocation-free, and `ulfm::agree` must make exactly ONE heap
    // allocation (the exact-capacity failed vector) regardless of world
    // size — the contract its doc comment promises.
    let count_for = |p: usize| {
        let mut cluster = Cluster::with_spares(p, 4, 2);
        cluster.kill(&[1, p - 1]);
        let (n_iter, checksum) = allocs_during(|| {
            let mut acc = 0usize;
            for r in cluster.survivors_iter() {
                acc += r;
            }
            for r in cluster.failed_iter() {
                acc += r + 1;
            }
            acc
        });
        assert!(checksum > 0);
        assert_eq!(n_iter, 0, "survivor/failed iteration allocated {n_iter} times at p = {p}");
        let (n_agree, (failed, _cost)) = allocs_during(|| ulfm::agree(&mut cluster));
        assert_eq!(failed, vec![1, p - 1]);
        n_agree
    };
    let small = count_for(8);
    let large = count_for(32);
    assert_eq!(small, 1, "agree must allocate exactly the failed vector ({small} allocations)");
    assert_eq!(
        small, large,
        "agreement allocation count scales with p ({small} vs {large})"
    );
}

fn submit_allocations_do_not_scale_with_unit_count() {
    // Same p, r, and bytes/PE; only the permutation-unit size differs 8x
    // (8 vs 64 units per PE). The zero-copy write path must make the
    // allocation count identical: only the p·r slice buffers and the O(p)
    // schedule scratch may allocate, never anything per unit.
    let count_for = |s_pr: usize| {
        let cfg = RestoreConfig::builder(8, 8, 512)
            .replicas(4)
            .perm_range_blocks(Some(s_pr))
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(8, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards = make_shards(8, 512 * 8);
        let (n, report) = allocs_during(|| rs.submit(&mut cluster, &shards).unwrap());
        assert!(report.cost.total_bytes > 0);
        n
    };
    let coarse = count_for(64); // 8 units/PE
    let fine = count_for(8); // 64 units/PE
    assert_eq!(
        coarse, fine,
        "submit allocation count scales with unit count ({coarse} vs {fine})"
    );
}

fn repair_planning_allocations_do_not_scale_with_world() {
    // A second repair after the same failures plans over every unit but
    // moves nothing: its allocation count is pure planning overhead and
    // must be identical at 4x the world (and unit) count.
    let count_for = |p: usize| {
        let cfg = RestoreConfig::builder(p, 8, 64)
            .replicas(4)
            .perm_range_blocks(Some(16))
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        rs.submit_virtual(&mut cluster).unwrap();
        cluster.kill(&[1, 2]);
        // first call performs the real transfers (and warms nothing global)
        let first = rs.repair_replicas(&mut cluster, RepairScheme::DoubleHashing).unwrap();
        assert!(first.transfers > 0);
        let (n, second) =
            allocs_during(|| rs.repair_replicas(&mut cluster, RepairScheme::DoubleHashing).unwrap());
        assert_eq!(second.transfers, 0, "repair must be idempotent");
        n
    };
    let small = count_for(8);
    let large = count_for(32);
    assert_eq!(
        small, large,
        "repair planning allocation count scales with p ({small} vs {large})"
    );
}

fn rebalance_planning_allocations_do_not_scale_with_world() {
    // Plan an identity-world rebalance (a shrink with zero deaths: every
    // interval is retained, nothing migrates) at two world sizes: the
    // planner walks every slot but its allocation count is pure scratch
    // overhead — a fixed number of vectors regardless of p (the migration
    // output `Vec` is caller-provided and stays empty here).
    let count_for = |p: usize| {
        let cfg = RestoreConfig::builder(p, 8, 64)
            .replicas(4)
            .perm_range_blocks(Some(16))
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        rs.submit_virtual(&mut cluster).unwrap();
        let (map, _cost) = ulfm::shrink(&mut cluster);
        let new_dist = rs.distribution().reshaped(map.new_world()).unwrap();
        let to_cluster: Vec<u32> = map.new_to_old.iter().map(|&o| o as u32).collect();
        let mut out: Vec<MigrationTransfer> = Vec::new();
        let (n, ()) = allocs_during(|| {
            plan_rebalance(
                rs.distribution(),
                &new_dist,
                rs.holder_index(),
                |pe| cluster.is_alive(pe),
                &to_cluster,
                |_pe, _start, _blocks| {},
                &mut out,
            )
            .unwrap()
        });
        assert!(out.is_empty(), "identity-world rebalance must migrate nothing");
        n
    };
    let small = count_for(8);
    let large = count_for(32);
    assert_eq!(
        small, large,
        "rebalance planning allocation count scales with p ({small} vs {large})"
    );
}

fn unequal_slice_rebalance_planning_allocations_do_not_scale_with_world() {
    // The balanced unequal-slice case: kill ONE PE so p' = p - 1 does not
    // divide n — every slice boundary is now a closed-form prefix-sum
    // lookup rather than a fixed stride, and the old/new boundary lattice
    // interleaves maximally. Planning must still use a fixed number of
    // scratch vectors regardless of p; the migration output is
    // caller-provided with enough pre-reserved capacity that pushing
    // transfers never reallocates (transfers <= r intervals <= r·(p + p')).
    let count_for = |p: usize| {
        let cfg = RestoreConfig::builder(p, 8, 64)
            .replicas(4)
            .perm_range_blocks(Some(16))
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        rs.submit_virtual(&mut cluster).unwrap();
        cluster.kill(&[0]);
        let (map, _cost) = ulfm::shrink(&mut cluster);
        assert_eq!(map.new_world(), p - 1);
        let new_dist = rs.distribution().reshaped(map.new_world()).unwrap();
        assert!(!new_dist.equal_slices(), "p' = {} must not divide n", p - 1);
        let to_cluster: Vec<u32> = map.new_to_old.iter().map(|&o| o as u32).collect();
        let mut out: Vec<MigrationTransfer> = Vec::with_capacity(4 * (2 * p + 2));
        let cap_before = out.capacity();
        let (n, ()) = allocs_during(|| {
            plan_rebalance(
                rs.distribution(),
                &new_dist,
                rs.holder_index(),
                |pe| cluster.is_alive(pe),
                &to_cluster,
                |_pe, _start, _blocks| {},
                &mut out,
            )
            .unwrap()
        });
        assert!(!out.is_empty(), "killing a PE must migrate something");
        assert_eq!(out.capacity(), cap_before, "pre-reserved capacity must suffice");
        n
    };
    let small = count_for(8);
    let large = count_for(32);
    assert_eq!(
        small, large,
        "unequal-slice rebalance planning allocation count scales with p ({small} vs {large})"
    );
}

fn steady_state_load_allocations_do_not_scale_with_piece_count() {
    // Cost-model mode: after a warm-up call, a load's allocations are the
    // output-shard bookkeeping only — identical for a whole-ID-space
    // load-all and a single lost-shard scatter despite the ~8x piece-count
    // difference. LeastLoaded at this scale stays on the single-pass
    // serial path under every feature set (its rayon two-pass split only
    // engages past the PAR_MIN_ITEMS volume estimate; parallel paths
    // trade small per-requester buffers for parallelism by design).
    let cfg = RestoreConfig::builder(8, 8, 64)
        .replicas(4)
        .perm_range_blocks(Some(8))
        .server_selection(ServerSelection::LeastLoaded)
        .build()
        .unwrap();
    let mut cluster = Cluster::new_execution(8, 4);
    let mut rs = ReStore::new(cfg, &cluster).unwrap();
    rs.submit_virtual(&mut cluster).unwrap();
    cluster.kill(&[3]);
    let all = load_all_requests(&rs, &cluster);
    let scatter = scatter_requests(&rs, &cluster, &[3]);
    assert_eq!(all.len(), scatter.len(), "same requester count by construction");
    // warm every scratch buffer with the larger workload
    rs.load(&mut cluster, &all).unwrap();
    let (n_all, _) = allocs_during(|| rs.load(&mut cluster, &all).unwrap());
    let (n_scatter, _) = allocs_during(|| rs.load(&mut cluster, &scatter).unwrap());
    assert_eq!(
        n_all, n_scatter,
        "steady-state load allocations scale with piece count ({n_all} vs {n_scatter})"
    );
}
