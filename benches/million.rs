//! Million-rank scale benchmarks (EXPERIMENTS.md §Scale).
//!
//! The O(touched) push: after the sparse epoch-stamped accumulator, the
//! generation-stamped LeastLoaded routing table, the reverse holder map,
//! the dense alive-list victim pick, and the Fenwick corruption sampler,
//! the steady-state hot paths must cost what an operation *touches*, not
//! what the machine *is*. This bench pins that at p = 2^14, 2^17, and
//! 2^20 (cost-model mode — §VI-A's simulated-cluster methodology pushed
//! two orders of magnitude past the paper's 24 576 PEs):
//!
//! * `steady-load` — a fixed 8-requester load; ns/op and the pooled
//!   accumulator's touched-entry counts must stay flat (within 2×) from
//!   2^14 to 2^20.
//! * `storm step` — one MTBF kill-event sample; O(1) per event via the
//!   cluster's dense alive list, flat across p.
//! * `corruption window` — a 4096-strike silent-corruption window; the
//!   per-window Fenwick build is O(p) but each strike locates its victim
//!   byte in O(log p) (this row scales with p by design — it amortizes
//!   the build, it does not claim flatness).
//! * `repair planning` — the full §IV-E no-op repair scan, inherently
//!   O(p·r); included as the honest non-flat baseline row.
//!
//! `BENCH_SHORT` skips the 2^20 configuration (CI schema smoke).

use restore::config::RestoreConfig;
use restore::restore::block::{BlockRange, RangeSet};
use restore::restore::repair::RepairScheme;
use restore::restore::{LoadRequest, ReStore};
use restore::simnet::cluster::Cluster;
use restore::simnet::failure::{CorruptionModel, MtbfStorm};
use restore::util::bench::{bench, black_box, short_mode, write_json_artifact, BenchResult};

/// A fixed-size steady-state load: 8 requesters, 16 blocks each, spread
/// across the block space — the touched set is O(1) regardless of p.
fn steady_requests(cluster: &Cluster, n_blocks: u64) -> Vec<LoadRequest> {
    let survivors = cluster.survivors();
    (0..8usize)
        .map(|i| {
            let start = (i as u64 * n_blocks) / 8;
            LoadRequest {
                pe: survivors[i * survivors.len() / 8],
                ranges: RangeSet::new(vec![BlockRange::new(start, start + 16)]),
            }
        })
        .collect()
}

fn run_scale(p: usize, reps: usize, results: &mut Vec<BenchResult>) {
    println!("--- p = {p} (cost-model) ---");
    let cfg = RestoreConfig::paper_default(p).unwrap();
    let n_blocks = cfg.n_blocks();
    let mut cluster = Cluster::new_execution(p, 48);
    let mut store = ReStore::new(cfg, &cluster).unwrap();
    store.submit_virtual(&mut cluster).unwrap();

    // steady-state load: ns/op must stay flat 2^14 -> 2^20
    let reqs = steady_requests(&cluster, n_blocks);
    let r = bench(&format!("steady-load resolve+route p={p}"), 1, reps, || {
        black_box(store.load(&mut cluster, &reqs).unwrap());
    });
    println!("{}", r.line());
    results.push(r);

    // touched-entry counters of the load's data phase: O(touched), so the
    // values themselves must be independent of p (and tiny)
    let (tp, tn) = store.last_phase_touched();
    for (what, v) in [("pes", tp), ("nodes", tn)] {
        let r = BenchResult::from_value(&format!("steady-load touched {what} p={p}"), v as f64);
        println!("{}", r.line());
        results.push(r);
    }

    // storm stepping: one kill-event sample per iteration, O(1) per event
    let mut storm = MtbfStorm::new(3600.0 * 24.0 * 365.0, 0.02, 0x5708);
    let r = bench(&format!("storm step p={p}"), 8, reps * 64, || {
        black_box(storm.next_event(&cluster).unwrap());
    });
    println!("{}", r.line());
    results.push(r);

    // corruption sampling: a window tuned to ~4096 strikes — O(p) build
    // amortized over O(log p) strikes (scales with p by design)
    let resident = vec![4096u64; p];
    let total_bytes = 4096.0 * p as f64;
    let mut model = CorruptionModel::new(4096.0 / total_bytes, 0.0, 0, 0xC0);
    let mut t0 = 0.0f64;
    let r = bench(&format!("corruption window (4096-strike) p={p}"), 1, reps, || {
        let s = model.sample_window(&cluster, t0, t0 + 1.0, &resident);
        t0 += 1.0;
        black_box(s.len());
    });
    println!("{}", r.line());
    results.push(r);

    // repair planning: the honest O(p·r) row (no failures — a pure scan)
    let r = bench(&format!("repair planning p={p}"), 1, reps.min(3), || {
        black_box(store.repair_replicas(&mut cluster, RepairScheme::DoubleHashing).unwrap());
    });
    println!("{}", r.line());
    results.push(r);
}

fn main() {
    println!("=== million-rank scale benchmarks ===\n");
    let mut results: Vec<BenchResult> = Vec::new();
    if short_mode() {
        // CI schema smoke: skip 2^20, minimal reps — the artifact still
        // exists, parses, and carries every row family.
        run_scale(1 << 14, 2, &mut results);
        run_scale(1 << 17, 2, &mut results);
    } else {
        run_scale(1 << 14, 10, &mut results);
        run_scale(1 << 17, 6, &mut results);
        run_scale(1 << 20, 3, &mut results);
    }
    write_json_artifact("BENCH_million.json", &results).expect("write BENCH_million.json");
    println!("\nwrote BENCH_million.json ({} entries)", results.len());
}
