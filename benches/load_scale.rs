//! Load-path scaling benchmarks (EXPERIMENTS.md §Perf).
//!
//! Resolve + route + cost throughput of the paper's benchmark operations
//! (§VI-B2) in cost-model mode at p = 1536 (the hotpath baseline scale)
//! and p = 24576 (the paper's largest configuration): *load 1 %*,
//! *load all*, and the scattered shrink-style recovery of §VI-D.2 after a
//! full-node (48 PE) failure. These are the workloads the load pipeline's
//! scratch reuse, run coalescing, and placement index target; compare the
//! `p=1536` line against `benches/hotpath.rs`'s seed baseline.

use restore::config::RestoreConfig;
use restore::restore::load::{load_all_requests, load_percent_requests, scatter_requests};
use restore::restore::ReStore;
use restore::simnet::cluster::Cluster;
use restore::util::bench::{bench, black_box, short_mode, write_json_artifact, BenchResult};

fn run_scale(p: usize, reps: usize, results: &mut Vec<BenchResult>) {
    println!("--- p = {p} (cost-model) ---");
    let cfg = RestoreConfig::paper_default(p).unwrap();
    let mut cluster = Cluster::new_execution(p, 48);
    let mut store = ReStore::new(cfg, &cluster).unwrap();
    store.submit_virtual(&mut cluster).unwrap();

    let mut rep = 0usize;
    let r = bench(&format!("load-1% resolve+route p={p}"), 1, reps, || {
        rep += 1;
        let reqs = load_percent_requests(&store, &cluster, 1.0, rep % p);
        black_box(store.load(&mut cluster, &reqs).unwrap());
    });
    println!("{}", r.line());
    results.push(r);

    let r = bench(&format!("load-all resolve+route p={p}"), 1, reps.div_ceil(2), || {
        let reqs = load_all_requests(&store, &cluster);
        black_box(store.load(&mut cluster, &reqs).unwrap());
    });
    println!("{}", r.line());
    results.push(r);

    // one full node fails; the survivors shrink-load its shards
    let failed: Vec<usize> = (0..48).collect();
    cluster.kill(&failed);
    let r = bench(&format!("scattered-recovery resolve+route p={p}"), 1, reps, || {
        let reqs = scatter_requests(&store, &cluster, &failed);
        black_box(store.load(&mut cluster, &reqs).unwrap());
    });
    println!("{}", r.line());
    results.push(r);
}

fn main() {
    println!("=== load-path scaling benchmarks ===\n");
    let mut results: Vec<BenchResult> = Vec::new();
    if short_mode() {
        // CI schema smoke (`make bench-json-short`): baseline scale only,
        // minimal reps — the artifact still exists and parses.
        run_scale(1536, 2, &mut results);
    } else {
        run_scale(1536, 10, &mut results);
        run_scale(24576, 3, &mut results);
    }
    // machine-readable perf artifact for CI's cross-PR trajectory
    write_json_artifact("BENCH_load_scale.json", &results).expect("write BENCH_load_scale.json");
    println!("\nwrote BENCH_load_scale.json ({} entries)", results.len());
}
