//! Quickstart: the 60-second tour of the ReStore API.
//!
//! Creates a 16-PE simulated cluster, submits 1 MiB per PE into the
//! replicated store, kills two PEs, and recovers their data scattered over
//! the survivors — verifying every recovered byte.
//!
//! Run with: `cargo run --example quickstart`

use restore::config::RestoreConfig;
use restore::metrics::fmt_time;
use restore::restore::load::scatter_requests;
use restore::restore::ReStore;
use restore::simnet::cluster::Cluster;
use restore::simnet::ulfm;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // A cluster of 16 PEs, 4 per node (so each node is a failure domain).
    let mut cluster = Cluster::new_execution(16, 4);

    // ReStore config: 1 MiB per PE in 64 B blocks, r = 4 replicas, 16 KiB
    // permutation ranges (the paper's §IV-B scattering).
    let cfg = RestoreConfig::builder(16, 64, 16 * 1024)
        .replicas(4)
        .perm_range_bytes(Some(16 * 1024))
        .build()?;

    // Every PE submits its serialized shard once.
    let shards: Vec<Vec<u8>> =
        (0..16u32).map(|pe| (0..1024 * 1024).map(|i| (pe as usize + i) as u8).collect()).collect();
    let mut store = ReStore::new(cfg, &cluster)?;
    let submit = store.submit(&mut cluster, &shards)?;
    println!(
        "submit: {} over the simulated network ({} messages, {} total)",
        fmt_time(submit.cost.sim_time_s),
        submit.cost.total_msgs,
        human_bytes(submit.cost.total_bytes),
    );

    // Two PEs fail. The survivors agree on the failure and shrink the
    // communicator (ULFM-style), then reload the lost shards via ReStore.
    cluster.kill(&[3, 11]);
    let (failed, map, ulfm_cost) = ulfm::recover(&mut cluster);
    println!(
        "failure: PEs {failed:?} died; communicator shrunk to {} ranks in {}",
        map.new_world(),
        fmt_time(ulfm_cost.sim_time_s)
    );

    // The shrink bumped the communicator epoch; the store must adopt the
    // new world before it will route again. With balanced unequal slices
    // every survivor count >= r admits the §IV-B rebalance, so the 14
    // survivors get a fresh layout (two slice sizes, ⌈n/14⌉ and ⌊n/14⌋)
    // with full r = 4 replication — no lingering dead-rank holes. See
    // examples/replica_repair.rs for the full story (and the repair-based
    // alternative when the application keeps the communicator).
    let rebalanced = store.rebalance_or_acknowledge(&mut cluster, &map)?;
    if let Some(report) = rebalanced {
        println!(
            "rebalance: layout rewritten over {} survivors ({} migrated)",
            report.new_world,
            human_bytes(report.migrated_bytes),
        );
    }

    let requests = scatter_requests(&store, &cluster, &failed);
    let out = store.load(&mut cluster, &requests)?;
    println!(
        "recovery: {} ({} request phase + {} data phase)",
        fmt_time(out.cost.sim_time_s),
        fmt_time(out.request_cost.sim_time_s),
        fmt_time(out.data_cost.sim_time_s)
    );

    // Verify every byte.
    let mut recovered = 0usize;
    for (req, shard) in requests.iter().zip(&out.shards) {
        let bytes = shard.bytes.as_ref().unwrap();
        let mut off = 0;
        for range in req.ranges.ranges() {
            for x in range.start..range.end {
                let pe = (x / (16 * 1024)) as usize;
                let boff = ((x % (16 * 1024)) * 64) as usize;
                assert_eq!(&bytes[off..off + 64], &shards[pe][boff..boff + 64]);
                off += 64;
            }
        }
        recovered += bytes.len();
    }
    println!("verified {} recovered bytes — bit-exact", human_bytes(recovered as u64));
    Ok(())
}

fn human_bytes(b: u64) -> String {
    if b >= 1 << 20 {
        format!("{:.1} MiB", b as f64 / (1 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1} KiB", b as f64 / (1 << 10) as f64)
    } else {
        format!("{b} B")
    }
}
