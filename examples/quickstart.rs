//! Quickstart: the 60-second tour of the ReStore API — now with the §V
//! multi-dataset registry.
//!
//! Creates a 16-PE simulated cluster, registers TWO datasets ("an
//! application can create multiple ReStore objects, e.g., one for each
//! datatype to be stored"): 1 MiB/PE of point data (r = 4, 64 B blocks,
//! permuted) and 2 KiB/PE of model state (r = 2, 32 B blocks, contiguous).
//! Kills two PEs, rebalances BOTH layouts in one fused shrink handshake,
//! and recovers both datasets' lost shards in ONE fused two-phase round
//! (`load_many`) — verifying every recovered byte and showing the message
//! savings over driving the two loads sequentially.
//!
//! Run with: `cargo run --example quickstart`

use restore::config::RestoreConfig;
use restore::metrics::fmt_time;
use restore::restore::block::{BlockRange, RangeSet};
use restore::restore::load::scatter_requests;
use restore::restore::{DatasetId, LoadRequest, ReStore};
use restore::simnet::cluster::Cluster;
use restore::simnet::ulfm;

const P: usize = 16;
const POINT_BPP: u64 = 16 * 1024; // 64 B blocks -> 1 MiB per PE
const MODEL_BPP: u64 = 64; // 32 B blocks -> 2 KiB per PE

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // A cluster of 16 PEs, 4 per node (so each node is a failure domain).
    let mut cluster = Cluster::new_execution(P, 4);

    // Dataset 0 — the bulk point data: 64 B blocks, r = 4 replicas, 16 KiB
    // permutation ranges (the paper's §IV-B scattering).
    let points_cfg = RestoreConfig::builder(P, 64, POINT_BPP as usize)
        .replicas(4)
        .perm_range_bytes(Some(16 * 1024))
        .build()?;
    // Dataset 1 — small model state with its OWN r/b: 32 B blocks, r = 2,
    // no permutation. Independent per-dataset policies are the point of
    // the registry (§V: one ReStore object per datatype).
    let model_cfg = RestoreConfig::builder(P, 32, MODEL_BPP as usize).replicas(2).build()?;

    let point_shards: Vec<Vec<u8>> = (0..P)
        .map(|pe| (0..POINT_BPP as usize * 64).map(|i| (pe + i) as u8).collect())
        .collect();
    let model_shards: Vec<Vec<u8>> = (0..P)
        .map(|pe| (0..MODEL_BPP as usize * 32).map(|i| (pe * 7 + i * 3) as u8).collect())
        .collect();

    let mut store = ReStore::new(points_cfg, &cluster)?;
    let points = DatasetId::FIRST;
    let model = store.create_dataset(model_cfg, &cluster)?;
    let s1 = store.submit(&mut cluster, &point_shards)?; // facade = dataset 0
    let s2 = store.dataset_mut(model)?.submit(&mut cluster, &model_shards)?;
    println!(
        "submit: points {} ({} msgs), model {} ({} msgs)",
        fmt_time(s1.cost.sim_time_s),
        s1.cost.total_msgs,
        fmt_time(s2.cost.sim_time_s),
        s2.cost.total_msgs,
    );

    // Two PEs fail (from different §IV-D groups of BOTH datasets — the
    // model dataset's r = 2 groups sit at stride p/r = 8, so 3 and 12 never
    // share a holder set). The survivors agree on the failure and shrink
    // the communicator (ULFM-style).
    cluster.kill(&[3, 12]);
    let (failed, map, ulfm_cost) = ulfm::recover(&mut cluster);
    println!(
        "failure: PEs {failed:?} died; communicator shrunk to {} ranks in {}",
        map.new_world(),
        fmt_time(ulfm_cost.sim_time_s)
    );

    // The shrink bumped the communicator epoch; EVERY dataset must adopt
    // the new world before it will route again. One fused handshake
    // rebalances all feasible layouts under the single epoch bump — here
    // both datasets get fresh balanced layouts over the 14 survivors with
    // full replication, their migration all-to-alls merged into one phase.
    let outcomes = store.rebalance_or_acknowledge_all(&mut cluster, &map)?;
    for (id, outcome) in outcomes.iter().enumerate() {
        if let Some(report) = outcome {
            println!(
                "rebalance: dataset {id} rewritten over {} survivors ({} migrated)",
                report.new_world,
                human_bytes(report.migrated_bytes),
            );
        }
    }

    // ONE fused recovery round for both datasets: the per-dataset message
    // plans merge into a single request all-to-all and a single data
    // all-to-all — one message per (requester, server) pair ACROSS
    // datasets (§IV-C's startup-overhead argument applied across
    // datasets).
    let point_reqs = scatter_requests(&store, &cluster, &failed);
    let survivors = cluster.survivors();
    let model_reqs: Vec<LoadRequest> = failed
        .iter()
        .enumerate()
        .map(|(i, &dead)| LoadRequest {
            pe: survivors[i % survivors.len()],
            ranges: RangeSet::new(vec![BlockRange::new(
                dead as u64 * MODEL_BPP,
                (dead as u64 + 1) * MODEL_BPP,
            )]),
        })
        .collect();
    let parts = [(points, point_reqs), (model, model_reqs)];
    let out = store.load_many(&mut cluster, &parts)?;
    println!(
        "fused recovery: {} ({} request msgs + {} data msgs across {} datasets)",
        fmt_time(out.cost.sim_time_s),
        out.request_cost.total_msgs,
        out.data_cost.total_msgs,
        parts.len(),
    );

    // Verify every byte of both datasets.
    let mut recovered = 0usize;
    for (part, (_, reqs)) in out.parts.iter().zip(&parts) {
        let (bpp, bs, shards): (u64, usize, &[Vec<u8>]) = if part.dataset == points {
            (POINT_BPP, 64, &point_shards)
        } else {
            (MODEL_BPP, 32, &model_shards)
        };
        for (req, shard) in reqs.iter().zip(&part.shards) {
            let bytes = shard.bytes.as_ref().unwrap();
            let mut off = 0;
            for range in req.ranges.ranges() {
                for x in range.start..range.end {
                    let pe = (x / bpp) as usize;
                    let boff = ((x % bpp) as usize) * bs;
                    assert_eq!(&bytes[off..off + bs], &shards[pe][boff..boff + bs]);
                    off += bs;
                }
            }
            recovered += bytes.len();
        }
    }
    println!(
        "verified {} recovered bytes across both datasets — bit-exact",
        human_bytes(recovered as u64)
    );
    Ok(())
}

fn human_bytes(b: u64) -> String {
    if b >= 1 << 20 {
        format!("{:.1} MiB", b as f64 / (1 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1} KiB", b as f64 / (1 << 10) as f64)
    } else {
        format!("{b} B")
    }
}
