//! The load (recovery) path — what runs after every failure (§IV-A/§V).
//!
//! Two-phase protocol, the paper's preferred API mode 2 ("providing exactly
//! those ID ranges each individual PE needs on exactly that PE"):
//!
//! 1. **Request resolution + request all-to-all.** Each requester maps its
//!    block ranges to permuted pieces, picks one *serving PE* per piece
//!    among the surviving replica holders (successive blocks with the same
//!    holder set get the same server — minimizing the bottleneck number of
//!    messages, §IV-A), and sends each chosen server one request message.
//! 2. **Data sparse all-to-all.** Servers answer with one coalesced data
//!    message per requester.
//!
//! The request-pattern helpers at the bottom generate the paper's three
//! benchmark operations (§VI-B2) and the two recovery styles of §VI-D.2
//! (single-target substitute-style and scattered shrinking-style).

use std::collections::HashMap;

use crate::config::ServerSelection;
use crate::error::{Error, Result};
use crate::restore::block::{BlockRange, RangeSet};
use crate::restore::distribution::PermutedPiece;
use crate::restore::hashing::seeded_hash;
use crate::restore::{LoadOutput, LoadRequest, LoadedShard, ReStore};
use crate::simnet::cluster::Cluster;

/// Bytes per piece descriptor in a request message (perm_start, len, dest
/// offset — what the sparse all-to-all of §V carries).
const REQUEST_HEADER_BYTES: u64 = 24;

/// A piece with its chosen server, requester, and output offset.
#[derive(Debug, Clone, Copy)]
struct RoutedPiece {
    piece: PermutedPiece,
    requester: usize,
    /// Index into the `requests` slice (a PE may appear in several
    /// requests; assembly is per-request, messaging per-PE).
    req_idx: usize,
    server: usize,
    /// Byte offset in the request's output buffer.
    out_offset: u64,
}

impl ReStore {
    /// Load data after failures. `requests` lists, per requesting PE, the
    /// original block ID ranges it needs (PEs with no needs may be absent).
    ///
    /// Returns the loaded bytes per requester (execution mode) and the
    /// phase costs. Errors with [`Error::IrrecoverableDataLoss`] if all
    /// `r` holders of some requested range are dead — the caller then falls
    /// back to reloading input from disk, as the paper prescribes (§VI-B1).
    pub fn load(&mut self, cluster: &mut Cluster, requests: &[LoadRequest]) -> Result<LoadOutput> {
        self.ensure_submitted()?;
        let dist = self.dist.clone();
        let bs = self.cfg.block_size as u64;

        // --- Phase 1a: request resolution (local, per requester) --------
        let mut routed: Vec<RoutedPiece> = Vec::new();
        let mut pieces: Vec<PermutedPiece> = Vec::new();
        // Greedy per-server load for the LeastLoaded policy.
        let mut server_load: HashMap<usize, u64> = HashMap::new();

        for (req_idx, req) in requests.iter().enumerate() {
            if !cluster.is_alive(req.pe) {
                return Err(Error::DeadPe(req.pe));
            }
            let mut out_offset = 0u64;
            for range in req.ranges.ranges() {
                pieces.clear();
                dist.permuted_pieces(*range, &mut pieces);
                for piece in &pieces {
                    let server =
                        self.pick_server(cluster, req.pe, piece, &mut server_load)?;
                    routed.push(RoutedPiece {
                        piece: *piece,
                        requester: req.pe,
                        req_idx,
                        server,
                        out_offset,
                    });
                    out_offset += piece.len * bs;
                }
            }
        }

        // --- Phase 1b: request sparse all-to-all -------------------------
        // One message per distinct (requester, server) pair carrying the
        // piece descriptors.
        let mut req_msgs: HashMap<(usize, usize), u64> = HashMap::new();
        for rp in &routed {
            *req_msgs.entry((rp.requester, rp.server)).or_insert(0) += REQUEST_HEADER_BYTES;
        }
        let request_cost =
            cluster.charge_phase(req_msgs.iter().map(|(&(s, d), &b)| (s, d, b)))?;

        // --- Phase 2: data sparse all-to-all ------------------------------
        let mut data_msgs: HashMap<(usize, usize), u64> = HashMap::new();
        for rp in &routed {
            *data_msgs.entry((rp.server, rp.requester)).or_insert(0) += rp.piece.len * bs;
        }
        let mut phase = cluster.phase();
        for (&(s, d), &b) in &data_msgs {
            phase.add(s, d, b)?;
        }
        // every piece is a pack fragment on the server and an unpack
        // fragment on the requester
        for rp in &routed {
            if rp.server != rp.requester {
                phase.frag(rp.server, 1);
                phase.frag(rp.requester, 1);
            }
        }
        let data_cost = phase.commit();

        // --- Assemble outputs (execution mode) ---------------------------
        let execution = self
            .stores
            .iter()
            .any(|st| st.slices().first().is_some_and(|s| matches!(s.buf, crate::restore::store::SliceBuf::Real(_))));
        let mut shards: Vec<LoadedShard> = requests
            .iter()
            .map(|r| LoadedShard {
                pe: r.pe,
                bytes: execution
                    .then(|| vec![0u8; (r.ranges.total_blocks() * bs) as usize]),
            })
            .collect();
        if execution {
            for rp in &routed {
                let src = self.stores[rp.server]
                    .read(rp.piece.perm_start, rp.piece.len)
                    .expect("execution-mode store must hold real bytes");
                let dst = shards[rp.req_idx].bytes.as_mut().unwrap();
                let off = rp.out_offset as usize;
                dst[off..off + src.len()].copy_from_slice(src);
            }
        }

        Ok(LoadOutput {
            shards,
            request_cost,
            data_cost,
            cost: request_cost.then(data_cost),
        })
    }

    /// Pick the serving PE for one piece among the surviving holders.
    fn pick_server(
        &self,
        cluster: &Cluster,
        requester: usize,
        piece: &PermutedPiece,
        server_load: &mut HashMap<usize, u64>,
    ) -> Result<usize> {
        let dist = &self.dist;
        let mut alive: Vec<usize> = (0..dist.replicas())
            .map(|k| dist.holder(piece.perm_start, k))
            .filter(|&pe| cluster.is_alive(pe))
            .collect();
        if alive.is_empty() {
            // All deterministic §IV-A holders are dead — consult replicas
            // re-created by §IV-E repair (in the paper's design a repaired
            // placement is recomputable from the probing sequence; the
            // simulator checks the stores directly, which is equivalent).
            alive = cluster
                .survivors()
                .into_iter()
                .filter(|&pe| self.stores[pe].holds(piece.perm_start, piece.len))
                .collect();
        }
        if alive.is_empty() {
            let orig = dist.unpermute_block(piece.perm_start);
            return Err(Error::IrrecoverableDataLoss { start: orig, end: orig + piece.len });
        }
        let chosen = match self.cfg.server_selection {
            ServerSelection::Random => {
                // Same (requester, slice, epoch) -> same server: successive
                // blocks with the same holder set share one sender (§IV-A).
                let slice = piece.perm_start / dist.blocks_per_pe();
                let h = seeded_hash(
                    self.cfg.seed ^ cluster.epoch,
                    ((requester as u64) << 32) ^ slice,
                );
                alive[(h % alive.len() as u64) as usize]
            }
            ServerSelection::LeastLoaded => *alive
                .iter()
                .min_by_key(|pe| server_load.get(pe).copied().unwrap_or(0))
                .unwrap(),
            ServerSelection::Primary => alive[0],
        };
        *server_load.entry(chosen).or_insert(0) += piece.len * self.cfg.block_size as u64;
        Ok(chosen)
    }
}

/// Requests that redistribute the `failed` PEs' shards evenly over the
/// survivors — the *shrinking* recovery of §IV-B: survivor number `j` (in
/// survivor order) receives blocks
/// `[i·n/p + j·n/(p·(p-1)), i·n/p + (j+1)·n/(p·(p-1)))` of failed PE `i`.
pub fn scatter_requests(store: &ReStore, cluster: &Cluster, failed: &[usize]) -> Vec<LoadRequest> {
    let dist = store.distribution();
    let survivors = cluster.survivors();
    let ns = survivors.len() as u64;
    if ns == 0 {
        return Vec::new();
    }
    let mut per_pe: Vec<Vec<BlockRange>> = vec![Vec::new(); survivors.len()];
    for &dead in failed {
        let shard = dist.shard_of(dead);
        let len = shard.len();
        for (j, ranges) in per_pe.iter_mut().enumerate() {
            let start = shard.start + (j as u64 * len) / ns;
            let end = shard.start + ((j as u64 + 1) * len) / ns;
            if start < end {
                ranges.push(BlockRange::new(start, end));
            }
        }
    }
    survivors
        .iter()
        .zip(per_pe)
        .filter(|(_, ranges)| !ranges.is_empty())
        .map(|(&pe, ranges)| LoadRequest { pe, ranges: RangeSet::new(ranges) })
        .collect()
}

/// Wrap a load-balancer output (per-PE gained range sets) into requests.
pub fn scatter_requests_for_ranges(gained: &[(usize, RangeSet)]) -> Vec<LoadRequest> {
    gained
        .iter()
        .filter(|(_, set)| !set.is_empty())
        .map(|(pe, set)| LoadRequest { pe: *pe, ranges: set.clone() })
        .collect()
}

/// Requests that send the `failed` PEs' whole shards to a single `target`
/// PE — the *substitute*-style recovery benchmarked in §VI-D.2.
pub fn single_target_requests(
    store: &ReStore,
    failed: &[usize],
    target: usize,
) -> Vec<LoadRequest> {
    let dist = store.distribution();
    let ranges: Vec<BlockRange> = failed.iter().map(|&pe| dist.shard_of(pe)).collect();
    vec![LoadRequest { pe: target, ranges: RangeSet::new(ranges) }]
}

/// The paper's *load 1 % data* benchmark op (§VI-B2): the contiguous data
/// of 1 % of the PEs (starting at a random PE `i`), spread evenly over all
/// alive PEs.
pub fn load_percent_requests(
    store: &ReStore,
    cluster: &Cluster,
    percent: f64,
    start_pe: usize,
) -> Vec<LoadRequest> {
    let dist = store.distribution();
    let p = dist.world();
    let bpp = dist.blocks_per_pe();
    let blocks = ((p as f64 * percent / 100.0) * bpp as f64).round() as u64;
    let start = (start_pe as u64 * bpp) % dist.n_blocks();
    let end = (start + blocks).min(dist.n_blocks());
    let survivors = cluster.survivors();
    let ns = survivors.len() as u64;
    let len = end - start;
    survivors
        .iter()
        .enumerate()
        .filter_map(|(j, &pe)| {
            let s = start + (j as u64 * len) / ns;
            let e = start + ((j as u64 + 1) * len) / ns;
            (s < e).then(|| LoadRequest {
                pe,
                ranges: RangeSet::new(vec![BlockRange::new(s, e)]),
            })
        })
        .collect()
}

/// The paper's *load all data* benchmark op (§VI-B2): all data, evenly
/// distributed, "in a way that no PE loads the same data it originally
/// submitted" — survivor `j` loads the shard-rotated region starting one
/// whole shard after its own.
pub fn load_all_requests(store: &ReStore, cluster: &Cluster) -> Vec<LoadRequest> {
    let dist = store.distribution();
    let n = dist.n_blocks();
    let survivors = cluster.survivors();
    let ns = survivors.len() as u64;
    // Rotate the even partition of [0, n) by exactly one shard: with all
    // PEs alive, survivor j loads precisely PE j+1's shard — never its own.
    let shift = dist.blocks_per_pe() % n;
    survivors
        .iter()
        .enumerate()
        .map(|(j, &pe)| {
            let s = (j as u64 * n) / ns;
            let e = ((j as u64 + 1) * n) / ns;
            let (rs, re) = ((s + shift) % n, (e + shift) % n);
            let ranges = if rs < re || e == s {
                vec![BlockRange::new(rs, re.max(rs))]
            } else {
                vec![BlockRange::new(rs, n), BlockRange::new(0, re)]
            };
            LoadRequest { pe, ranges: RangeSet::new(ranges) }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RestoreConfig;

    fn setup(
        p: usize,
        bpp: usize,
        r: usize,
        s_pr: Option<usize>,
    ) -> (Cluster, ReStore, Vec<Vec<u8>>) {
        let cfg = RestoreConfig::builder(p, 8, bpp)
            .replicas(r)
            .perm_range_blocks(s_pr)
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p, 4.min(p));
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards: Vec<Vec<u8>> = (0..p)
            .map(|pe| (0..bpp * 8).map(|i| (pe * 131 + i * 7) as u8).collect())
            .collect();
        rs.submit(&mut cluster, &shards).unwrap();
        (cluster, rs, shards)
    }

    fn expected_bytes(shards: &[Vec<u8>], ranges: &RangeSet, bpp: u64) -> Vec<u8> {
        let mut out = Vec::new();
        for r in ranges.ranges() {
            for x in r.start..r.end {
                let pe = (x / bpp) as usize;
                let off = ((x % bpp) * 8) as usize;
                out.extend_from_slice(&shards[pe][off..off + 8]);
            }
        }
        out
    }

    #[test]
    fn scattered_recovery_restores_exact_bytes() {
        let (mut cluster, mut rs, shards) = setup(8, 64, 4, Some(16));
        cluster.kill(&[3]);
        let reqs = scatter_requests(&rs, &cluster, &[3]);
        assert_eq!(reqs.len(), 7);
        let total: u64 = reqs.iter().map(|r| r.ranges.total_blocks()).sum();
        assert_eq!(total, 64); // the whole lost shard
        let out = rs.load(&mut cluster, &reqs).unwrap();
        for (req, shard) in reqs.iter().zip(&out.shards) {
            assert_eq!(shard.pe, req.pe);
            assert_eq!(
                shard.bytes.as_deref().unwrap(),
                expected_bytes(&shards, &req.ranges, 64),
                "PE {}",
                req.pe
            );
        }
    }

    #[test]
    fn single_target_recovery_restores_exact_bytes() {
        let (mut cluster, mut rs, shards) = setup(8, 64, 4, None);
        cluster.kill(&[5]);
        let reqs = single_target_requests(&rs, &[5], 0);
        let out = rs.load(&mut cluster, &reqs).unwrap();
        assert_eq!(
            out.shards[0].bytes.as_deref().unwrap(),
            expected_bytes(&shards, &reqs[0].ranges, 64)
        );
    }

    #[test]
    fn load_survives_r_minus_1_failures_of_a_group() {
        let (mut cluster, mut rs, shards) = setup(8, 64, 4, Some(16));
        // group stride p/r = 2; PEs {1, 3, 5, 7} form a group. Kill 3 of 4.
        cluster.kill(&[1, 3, 5]);
        let reqs = scatter_requests(&rs, &cluster, &[1, 3, 5]);
        let out = rs.load(&mut cluster, &reqs).unwrap();
        let total: usize = out.shards.iter().map(|s| s.bytes.as_ref().unwrap().len()).sum();
        assert_eq!(total, 3 * 64 * 8);
        for (req, shard) in reqs.iter().zip(&out.shards) {
            assert_eq!(
                shard.bytes.as_deref().unwrap(),
                expected_bytes(&shards, &req.ranges, 64)
            );
        }
    }

    #[test]
    fn idl_detected_when_whole_group_dies() {
        let (mut cluster, mut rs, _) = setup(8, 64, 4, Some(16));
        cluster.kill(&[1, 3, 5, 7]); // an entire §IV-D group
        let reqs = scatter_requests(&rs, &cluster, &[1]);
        match rs.load(&mut cluster, &reqs) {
            Err(Error::IrrecoverableDataLoss { .. }) => {}
            other => panic!("expected IDL, got {other:?}"),
        }
    }

    #[test]
    fn load_before_submit_fails() {
        let cfg = RestoreConfig::builder(4, 8, 16).replicas(2).build().unwrap();
        let mut cluster = Cluster::new_execution(4, 2);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        assert!(matches!(
            rs.load(&mut cluster, &[]),
            Err(Error::NotSubmitted)
        ));
    }

    #[test]
    fn dead_requester_rejected() {
        let (mut cluster, mut rs, _) = setup(4, 16, 2, None);
        cluster.kill(&[2]);
        let reqs = vec![LoadRequest {
            pe: 2,
            ranges: RangeSet::new(vec![BlockRange::new(0, 4)]),
        }];
        assert!(matches!(rs.load(&mut cluster, &reqs), Err(Error::DeadPe(2))));
    }

    #[test]
    fn permutation_spreads_servers_for_contiguous_request() {
        // §IV-B: with permutation, a failed PE's shard is served by many
        // senders; without, by at most r (minus failures).
        let (mut c1, mut rs1, _) = setup(16, 256, 4, Some(8));
        let (mut c2, mut rs2, _) = setup(16, 256, 4, None);
        c1.kill(&[0]);
        c2.kill(&[0]);
        let r1 = scatter_requests(&rs1, &c1, &[0]);
        let r2 = scatter_requests(&rs2, &c2, &[0]);
        let o1 = rs1.load(&mut c1, &r1).unwrap();
        let o2 = rs2.load(&mut c2, &r2).unwrap();
        assert!(
            o1.data_cost.total_msgs > o2.data_cost.total_msgs,
            "perm {} !> plain {}",
            o1.data_cost.total_msgs,
            o2.data_cost.total_msgs
        );
        // ...and the permuted bottleneck volume is lower
        assert!(o1.data_cost.bottleneck_bytes <= o2.data_cost.bottleneck_bytes);
    }

    #[test]
    fn load_percent_requests_cover_expected_volume() {
        let (cluster, rs, _) = setup(16, 256, 4, Some(8));
        // 25 % of 16 PEs = 4 shards' worth of blocks
        let reqs = load_percent_requests(&rs, &cluster, 25.0, 3);
        let total: u64 = reqs.iter().map(|r| r.ranges.total_blocks()).sum();
        assert_eq!(total, 4 * 256);
    }

    #[test]
    fn load_all_covers_everything_and_avoids_own_shard() {
        let (mut cluster, mut rs, shards) = setup(8, 64, 4, None);
        let reqs = load_all_requests(&rs, &cluster);
        let total: u64 = reqs.iter().map(|r| r.ranges.total_blocks()).sum();
        assert_eq!(total, 8 * 64);
        // no PE requests its own shard
        for req in &reqs {
            let own = rs.distribution().shard_of(req.pe);
            for r in req.ranges.ranges() {
                assert!(r.intersect(&own).is_none(), "PE {} loads own data", req.pe);
            }
        }
        let out = rs.load(&mut cluster, &reqs).unwrap();
        for (req, shard) in reqs.iter().zip(&out.shards) {
            assert_eq!(
                shard.bytes.as_deref().unwrap(),
                expected_bytes(&shards, &req.ranges, 64)
            );
        }
    }

    #[test]
    fn server_selection_policies_all_recover() {
        for policy in [
            ServerSelection::Random,
            ServerSelection::LeastLoaded,
            ServerSelection::Primary,
        ] {
            let cfg = RestoreConfig::builder(8, 8, 64, )
                .replicas(4)
                .perm_range_blocks(Some(16))
                .server_selection(policy)
                .build();
            let cfg = match cfg {
                Ok(c) => c,
                Err(e) => panic!("{e}"),
            };
            let mut cluster = Cluster::new_execution(8, 4);
            let mut rs = ReStore::new(cfg, &cluster).unwrap();
            let shards: Vec<Vec<u8>> =
                (0..8).map(|pe| vec![pe as u8; 64 * 8]).collect();
            rs.submit(&mut cluster, &shards).unwrap();
            cluster.kill(&[2]);
            let reqs = scatter_requests(&rs, &cluster, &[2]);
            let out = rs.load(&mut cluster, &reqs).unwrap();
            let total: usize =
                out.shards.iter().map(|s| s.bytes.as_ref().unwrap().len()).sum();
            assert_eq!(total, 64 * 8, "policy {policy:?}");
            for s in &out.shards {
                assert!(s.bytes.as_ref().unwrap().iter().all(|&b| b == 2));
            }
        }
    }
}
