//! FT-RAxML-NG proxy (§VI-C, Fig 6).
//!
//! RAxML-NG distributes the columns ("sites") of a multiple-sequence
//! alignment over the PEs; each PE evaluates the likelihood of its site
//! shard and the per-tree log-likelihood is the allreduce-sum over shards.
//! After a failure FT-RAxML-NG *redistributes the input data among all
//! surviving PEs* — which is why the paper deactivates permutation ranges
//! for this application (a load-all-style pattern, §VI-C) — and compares
//! ReStore against re-reading the RBA binary file from the PFS
//! (cached/uncached).
//!
//! The proxy keeps the real compute (the `phylo_step` Pallas artifact —
//! Felsenstein CLV update + log-likelihood) and the real recovery paths;
//! the tree search itself is out of scope (Fig 6 measures only data
//! loading). Per-site payload: 2 child CLVs (4 f32 each) + weight
//! = 36 B/site, padded to 64 B blocks: 1 site = 1 block, which conveniently
//! matches the paper's 64 B block granularity.

use crate::apps::{checkpoint_state_virtual, secondary_replicas};
use crate::config::{PfsConfig, RestoreConfig};
use crate::error::Result;
use crate::pfs::{CacheState, Pfs, PfsMethod};
use crate::restore::block::{BlockRange, RangeSet};
use crate::restore::load::scatter_requests_for_ranges;
use crate::restore::{DatasetId, LoadRequest, ReStore};
use crate::runtime::Engine;
use crate::simnet::cluster::Cluster;
use crate::simnet::ulfm;
use crate::util::rng::Rng;

/// Bytes of payload per MSA site (2 CLVs × 4 f32 + 1 f32 weight).
pub const SITE_PAYLOAD_F32S: usize = 9;

/// A named dataset: sites per PE (the paper's Fig 6a datasets are defined
/// by their per-PE input volume).
#[derive(Debug, Clone)]
pub struct PhyloDataset {
    pub name: String,
    pub pes: usize,
    pub bytes_per_pe: u64,
}

impl PhyloDataset {
    /// The empirical datasets of Fig 6a (name, PEs, input per PE) and the
    /// 19.1 GiB synthetic dataset of Fig 6b. Volumes follow the paper's
    /// axis labels.
    pub fn paper_datasets() -> Vec<PhyloDataset> {
        let mib = 1024.0 * 1024.0;
        let datasets = [
            ("AminoAcid (1.2 GiB)", 1024usize, 1.2 * 1024.0 * mib / 1024.0),
            ("DNA (0.5 GiB)", 512, 0.5 * 1024.0 * mib / 512.0),
            ("SyntheticDNA (19.1 GiB)", 6144, 19.1 * 1024.0 * mib / 6144.0),
        ];
        datasets
            .iter()
            .map(|(n, p, b)| PhyloDataset {
                name: n.to_string(),
                pes: *p,
                bytes_per_pe: *b as u64,
            })
            .collect()
    }
}

/// Fig 6 measurement for one configuration.
#[derive(Debug, Clone, Default)]
pub struct RecoveryTimes {
    /// ReStore submit (one-time).
    pub restore_submit_s: f64,
    /// Exposed (non-overlapped) time of the per-round model-state
    /// checkpoints before the failure.
    pub restore_checkpoint_s: f64,
    /// ReStore load after a failure (redistribution to all survivors).
    pub restore_load_s: f64,
    /// RBA file from PFS, OS cache cold.
    pub pfs_uncached_s: f64,
    /// RBA file from PFS, OS cache warm.
    pub pfs_cached_s: f64,
}

/// Generate one PE's site data: CLVs in (0,1], integer weights.
pub fn generate_sites(seed: u64, pe: usize, sites: usize) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed ^ (pe as u64).wrapping_mul(0x51AB));
    let mut out = Vec::with_capacity(sites * SITE_PAYLOAD_F32S);
    for _ in 0..sites {
        for _ in 0..8 {
            out.push(rng.gen_range_f32(0.05, 1.0));
        }
        out.push(rng.gen_range_f32(1.0, 4.0).floor());
    }
    out
}

/// Row-stochastic 4×4 transition matrix (expm(Qt)-like) for the proxy.
pub fn transition_matrix(seed: u64) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut m = vec![0f32; 16];
    for row in 0..4 {
        let mut sum = 0f32;
        for col in 0..4 {
            let v: f32 = if row == col { rng.gen_range_f32(3.0, 6.0) } else { rng.gen_range_f32(0.1, 1.0) };
            m[row * 4 + col] = v;
            sum += v;
        }
        for col in 0..4 {
            m[row * 4 + col] /= sum;
        }
    }
    m
}

/// Execution-mode likelihood evaluation over all survivors (one round),
/// returning the global log-likelihood. `sites_per_pe` must match the
/// artifact's site count in shape (padding handled via zero weights).
pub fn evaluate_loglik(
    cluster: &mut Cluster,
    engine: &mut Engine,
    variant: &str,
    site_data: &[Vec<f32>],
) -> Result<f64> {
    let s_art = engine.entry(variant)?.args[0].shape[0];
    let p_l = transition_matrix(17);
    let p_r = transition_matrix(23);
    let freqs = vec![0.25f32; 4];
    let mut partials: Vec<Vec<f32>> = Vec::new();
    let mut max_pe = 0f64;
    for pe in cluster.survivors() {
        let data = &site_data[pe];
        let n_sites = data.len() / SITE_PAYLOAD_F32S;
        let passes = n_sites.div_ceil(s_art).max(1);
        let mut ll = 0f64;
        let wall0 = engine.exec_seconds;
        for pass in 0..passes {
            let lo = pass * s_art;
            let hi = ((pass + 1) * s_art).min(n_sites);
            let mut clv_l = vec![1f32; s_art * 4];
            let mut clv_r = vec![1f32; s_art * 4];
            let mut weights = vec![0f32; s_art]; // zero weight = exact pad
            for (i, s) in (lo..hi).enumerate() {
                let base = s * SITE_PAYLOAD_F32S;
                clv_l[i * 4..i * 4 + 4].copy_from_slice(&data[base..base + 4]);
                clv_r[i * 4..i * 4 + 4].copy_from_slice(&data[base + 4..base + 8]);
                weights[i] = data[base + 8];
            }
            let out =
                engine.execute_f32(variant, &[&clv_l, &clv_r, &p_l, &p_r, &freqs, &weights])?;
            ll += out[1][0] as f64;
        }
        max_pe = max_pe.max(engine.exec_seconds - wall0);
        partials.push(vec![ll as f32]);
    }
    cluster.tick_compute(max_pe);
    let refs: Vec<&[f32]> = partials.iter().map(|v| v.as_slice()).collect();
    let (total, _) = cluster.allreduce_f32(&refs)?;
    Ok(total[0] as f64)
}

/// The §V per-datatype config for the model-state dataset riding along the
/// MSA sites: per-PE evolutionary-model state (transition matrices, base
/// frequencies, rate categories — ~1 KiB), in 32 B blocks with a lower
/// replication level, permutation off like the site data.
pub fn model_state_cfg(world: usize, seed: u64) -> Result<RestoreConfig> {
    let bs = 32usize;
    let model_bytes = 1024usize;
    RestoreConfig::builder(world, bs, model_bytes / bs)
        .replicas(secondary_replicas(world))
        .perm_range_blocks(None)
        .seed(seed ^ 0x40DE1)
        .build()
}

/// The Fig 6 experiment (cost-model mode): submit once, fail `kill_count`
/// PEs, redistribute their data over all survivors via ReStore — the MSA
/// site dataset AND the model-state dataset in ONE fused `load_many`
/// round — and compare against re-reading the per-PE input from the PFS.
pub fn measure_recovery(
    world: usize,
    pes_per_node: usize,
    bytes_per_pe: u64,
    kill_count: usize,
    pfs_cfg: &PfsConfig,
    seed: u64,
) -> Result<RecoveryTimes> {
    let block = 64usize;
    let blocks_per_pe = (bytes_per_pe as usize).div_ceil(block);
    // FT-RAxML-NG redistributes among all survivors -> permutation off §VI-C
    let cfg = RestoreConfig::builder(world, block, blocks_per_pe)
        .replicas(4.min(world))
        .perm_range_blocks(None)
        .seed(seed)
        .build()?;
    let mut cluster = Cluster::new_execution(world, pes_per_node);
    let mut store = ReStore::new(cfg.clone(), &cluster)?;
    let sites_ds = DatasetId::FIRST;
    let t0 = cluster.now();
    store.submit_virtual(&mut cluster)?;
    // second dataset: the per-PE model state, with its own r/b (§V)
    let model_cfg = model_state_cfg(world, seed)?;
    let model_bpp = model_cfg.blocks_per_pe as u64;
    let model_ds = store.create_dataset(model_cfg, &cluster)?;
    store.dataset_mut(model_ds)?.submit_virtual(&mut cluster)?;
    let submit_s = cluster.now() - t0;

    // RAxML-NG re-optimizes the evolutionary model between tree moves:
    // checkpoint the evolving model state as new versions (one resubmit
    // per optimization round, overlapped against the round's likelihood
    // compute) so the recovery below serves the latest committed model.
    let ck_t0 = cluster.now();
    for _round in 0..3 {
        checkpoint_state_virtual(store.dataset_mut(model_ds)?, &mut cluster, 0.01)?;
    }
    let checkpoint_s = cluster.now() - ck_t0;

    let dead: Vec<usize> = (0..kill_count.min(world - 1)).map(|i| i * 7 % world).collect();
    let dead: Vec<usize> = {
        let mut d = dead;
        d.sort_unstable();
        d.dedup();
        d
    };
    cluster.kill(&dead);
    let (_failed, map, _cost) = ulfm::recover(&mut cluster);
    // §IV-B: the fused handshake rewrites BOTH layouts over the survivors
    // when the shrunken world admits the §IV-A distribution, else
    // acknowledges per dataset and routes around the holes (arbitrary
    // 1 %-style kill counts rarely divide the block space).
    store.rebalance_or_acknowledge(&mut cluster, &map)?;

    // redistribute the lost shards evenly over all survivors; the dead
    // PEs' model state goes to the survivors that take over their sites —
    // fused with the site loads into one two-phase round
    let mut ownership = crate::apps::Ownership::identity(world, cfg.blocks_per_pe as u64);
    let gained = ownership.rebalance(&dead, &cluster.survivors(), 1);
    let survivors = cluster.survivors();
    let model_reqs: Vec<LoadRequest> = dead
        .iter()
        .enumerate()
        .map(|(i, &d)| LoadRequest {
            pe: survivors[i % survivors.len()],
            ranges: RangeSet::new(vec![BlockRange::new(
                d as u64 * model_bpp,
                (d as u64 + 1) * model_bpp,
            )]),
        })
        .collect();
    let t1 = cluster.now();
    let parts = [(sites_ds, scatter_requests_for_ranges(&gained)), (model_ds, model_reqs)];
    match store.load_many(&mut cluster, &parts) {
        Ok(_) => {}
        // lost model-state slots (r = 2): the model is re-derivable from
        // the run configuration, so degrade to the sites-only load the
        // measurement always performed.
        Err(crate::error::Error::IrrecoverableDataLoss { dataset, .. })
            if dataset == model_ds =>
        {
            store.load(&mut cluster, &parts[0].1)?;
        }
        Err(e) => return Err(e),
    }
    let load_s = cluster.now() - t1;

    // PFS baseline: after the failure *every* survivor re-reads its (new)
    // partition from the RBA file — FT-RAxML-NG's current mechanism reloads
    // the required subset on all ranks.
    let pfs = Pfs::new(pfs_cfg.clone());
    let survivors = cluster.n_alive();
    let pfs_bytes = bytes_per_pe * dead.len() as u64 / survivors as u64;
    let uncached = pfs.read_time_s(PfsMethod::IfStream, CacheState::Uncached, survivors, pfs_bytes);
    let cached = pfs.read_time_s(PfsMethod::IfStream, CacheState::Cached, survivors, pfs_bytes);

    Ok(RecoveryTimes {
        restore_submit_s: submit_s,
        restore_checkpoint_s: checkpoint_s,
        restore_load_s: load_s,
        pfs_uncached_s: uncached,
        pfs_cached_s: cached,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn site_data_deterministic() {
        assert_eq!(generate_sites(1, 2, 64), generate_sites(1, 2, 64));
        assert_eq!(generate_sites(1, 2, 64).len(), 64 * SITE_PAYLOAD_F32S);
    }

    #[test]
    fn transition_matrix_is_row_stochastic() {
        let m = transition_matrix(5);
        for row in 0..4 {
            let s: f32 = m[row * 4..row * 4 + 4].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(m[row * 4 + row] > 0.5, "diagonally dominant");
        }
    }

    #[test]
    fn recovery_measurement_restore_beats_uncached_pfs() {
        // Fig 6's headline: ReStore load is faster than the RBA/PFS reload,
        // often by more than an order of magnitude.
        let times = measure_recovery(
            1536,
            48,
            16 * 1024 * 1024,
            15,
            &PfsConfig::default(),
            3,
        )
        .unwrap();
        assert!(times.restore_load_s < times.pfs_uncached_s / 10.0,
            "load {} vs pfs {}", times.restore_load_s, times.pfs_uncached_s);
        assert!(times.restore_load_s > 0.0);
        assert!(times.restore_submit_s > 0.0);
    }

    #[test]
    fn paper_datasets_listed() {
        let ds = PhyloDataset::paper_datasets();
        assert_eq!(ds.len(), 3);
        assert!(ds.iter().any(|d| d.name.contains("19.1")));
    }
}
