//! Recovery-policy benchmark (EXPERIMENTS.md §Policies).
//!
//! Drives the same MTBF failure storm (Poisson arrivals with
//! node-correlated bursts) through all three recovery policies at the
//! hotpath baseline scale (p = 1536) and the paper's largest
//! configuration (p = 24576), in cost-model mode. Every wave runs the
//! full agree → {shrink | substitute | grow} → fused reshape (→ fused
//! repair) handshake; the rows compare what each policy buys:
//!
//! * `policy <name> recovery-sim-ns ...` — simulated cluster time spent
//!   recovering, summed over the storm (agreement + reshape + migration
//!   + repair phases);
//! * `policy <name> recovery-wall ...` — wall-clock nanoseconds of the
//!   planners/executors for the same waves;
//! * `policy <name> idl-prob ...` — §IV-D small-f IDL probability for
//!   `f = max(r, p/100)` further failures at the post-storm world (the
//!   risk level the storm leaves you at);
//! * `policy <name> throughput-frac ...` — alive compute fraction after
//!   the storm (steady-state throughput proxy: shrink loses workers,
//!   substitution/re-grow buy them back from the spare pool).
//!
//! With `BENCH_SHORT=1` only the p = 1536 configurations run (the CI
//! schema smoke — see `make bench-json-short`). Emits
//! `BENCH_policies.json` in the `{name, ns_per_iter}` artifact schema
//! (the name states the unit).

use std::time::Instant;

use restore::config::RestoreConfig;
use restore::restore::idl;
use restore::restore::policy::{RecoveryPolicy, Shrink, ShrinkThenRegrow, Substitute};
use restore::restore::ReStore;
use restore::simnet::cluster::Cluster;
use restore::simnet::failure::MtbfStorm;
use restore::simnet::network::PhaseCost;
use restore::util::bench::{short_mode, write_json_artifact, BenchResult};

const PPN: usize = 48;
const WAVES: usize = 4;
const NODE_BURST_PROB: f64 = 0.25;

fn storm_under(
    p: usize,
    policy: &mut dyn RecoveryPolicy,
    results: &mut Vec<BenchResult>,
) {
    let cfg = RestoreConfig::paper_default(p).unwrap();
    // Pool sized for the storm: enough spares to substitute a few whole
    // 48-PE node bursts before degrading to shrink.
    let spares = p / 8;
    let mut cluster = Cluster::with_spares(p, PPN, spares);
    let mut store = ReStore::new(cfg, &cluster).unwrap();
    store.submit_virtual(&mut cluster).unwrap();
    let r = store.distribution().replicas() as u64;

    let mut storm = MtbfStorm::new(1.0e5, NODE_BURST_PROB, 0xBEEF ^ p as u64);
    let mut sim_total = 0.0_f64;
    let mut killed = 0usize;
    let wall0 = Instant::now();
    for _ in 0..WAVES {
        let ev = storm.next_event(&cluster).expect("storm survivors");
        let gap = PhaseCost { sim_time_s: ev.at_s - cluster.now(), ..Default::default() };
        cluster.advance(&gap);
        cluster.kill(&ev.kills);
        killed += ev.kills.len();
        let out = policy.recover(&mut cluster, &mut store).unwrap();
        sim_total += out.recovery_time_s;
    }
    let wall = wall0.elapsed().as_secs_f64();

    let p_final = store.distribution().world() as u64;
    let f_next = (p as u64 / 100).max(r);
    let idl_prob = idl::p_idl_approx(p_final, r, f_next);
    let alive_frac = cluster.n_alive() as f64 / p as f64;

    let tag = format!("p={p}");
    let name = policy.name();
    println!(
        "policy {name} {tag}: {killed} killed over {WAVES} waves -> world {p_final}, \
         alive frac {alive_frac:.4}, P(IDL|f={f_next}) {idl_prob:.2e}, \
         recovery sim {:.2} ms, wall {:.1} ms",
        sim_total * 1e3,
        wall * 1e3,
    );
    results.push(BenchResult::from_value(
        &format!("policy {name} recovery-sim-ns {tag}"),
        sim_total * 1e9,
    ));
    results.push(BenchResult::from_value(
        &format!("policy {name} recovery-wall {tag}"),
        wall * 1e9,
    ));
    results.push(BenchResult::from_value(&format!("policy {name} idl-prob {tag}"), idl_prob));
    results.push(BenchResult::from_value(
        &format!("policy {name} throughput-frac {tag}"),
        alive_frac,
    ));
}

fn main() {
    println!("=== recovery-policy benchmarks (cost-model) ===\n");
    let mut results: Vec<BenchResult> = Vec::new();
    let scales: &[usize] = &[1536, 24576];
    let scales = if short_mode() { &scales[..1] } else { scales };
    for &p in scales {
        let mut policies: Vec<Box<dyn RecoveryPolicy>> = vec![
            Box::new(Shrink),
            Box::new(Substitute),
            Box::new(ShrinkThenRegrow { target_world: p }),
        ];
        for policy in policies.iter_mut() {
            storm_under(p, policy.as_mut(), &mut results);
        }
    }
    write_json_artifact("BENCH_policies.json", &results).expect("write BENCH_policies.json");
    println!("\nwrote BENCH_policies.json ({} entries)", results.len());
}
