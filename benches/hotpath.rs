//! Hot-path micro-benchmarks — the §Perf baseline (EXPERIMENTS.md).
//!
//! Wall-clock throughput of the pieces that dominate real runs:
//! * Feistel permutation application (every block-range mapping),
//! * submit schedule construction (cost-model, p=1536, 16 MiB/PE),
//! * load-1% request resolution + routing,
//! * Monte-Carlo IDL simulation step,
//! * PJRT kernel execution latency (tiny + small k-means artifacts).
//!
//! Paper-scale (p = 24576) load-path numbers live in
//! `benches/load_scale.rs`.

use restore::config::RestoreConfig;
use restore::metrics::fmt_time;
use restore::restore::load::load_percent_requests;
use restore::restore::permutation::{Feistel, RangePermutation};
use restore::restore::ReStore;
use restore::runtime::Engine;
use restore::simnet::cluster::Cluster;
use restore::util::bench::{bench, black_box, short_mode, write_json_artifact, BenchResult};
use restore::util::rng::Rng;

fn main() {
    println!("=== hot-path micro-benchmarks ===\n");
    let mut results: Vec<BenchResult> = Vec::new();
    // `make bench-json-short` (CI schema smoke): cut repetition counts;
    // every bench still runs once so the artifact exists and parses.
    let reps = |full: usize| if short_mode() { full.div_ceil(10).max(1) } else { full };

    // Feistel throughput
    let f = Feistel::new(1_572_864, 0xF00D); // 24576 PEs * 64 ranges
    let mut i = 0u64;
    let r = bench("feistel apply (per call)", 10_000, reps(200_000), || {
        i = (i + 1) % 1_572_864;
        black_box(f.apply(i));
    });
    println!("{}", r.line());
    results.push(r);

    // submit schedule, p=1536, paper default (64 units/PE * r=4)
    let r = bench("submit schedule p=1536 16MiB/PE r=4 perm", 1, reps(5), || {
        let cfg = RestoreConfig::paper_default(1536).unwrap();
        let mut cluster = Cluster::new_execution(1536, 48);
        let mut store = ReStore::new(cfg, &cluster).unwrap();
        black_box(store.submit_virtual(&mut cluster).unwrap());
    });
    println!("{}", r.line());
    results.push(r);

    // submit schedule at tiny ranges (the fig4a stress case)
    let r = bench("submit schedule p=384 16MiB/PE 1KiB ranges", 1, reps(3), || {
        let cfg = RestoreConfig::builder(384, 64, 262_144)
            .replicas(4)
            .perm_range_bytes(Some(1024))
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(384, 48);
        let mut store = ReStore::new(cfg, &cluster).unwrap();
        black_box(store.submit_virtual(&mut cluster).unwrap());
    });
    println!("{}", r.line());
    results.push(r);

    // execution-mode submit: schedule + the zero-copy store writes
    // (formerly one Vec per unit × replica)
    let shards: Vec<Vec<u8>> = (0..48)
        .map(|pe| (0..16_384 * 64).map(|i| (pe * 31 + i) as u8).collect())
        .collect();
    let r = bench("submit execute p=48 1MiB/PE r=4 perm", 1, reps(5), || {
        let cfg = RestoreConfig::builder(48, 64, 16_384)
            .replicas(4)
            .perm_range_bytes(Some(64 * 1024))
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(48, 48);
        let mut store = ReStore::new(cfg, &cluster).unwrap();
        black_box(store.submit(&mut cluster, &shards).unwrap());
    });
    println!("{}", r.line());
    results.push(r);

    // load-1% end to end (schedule + routing + cost)
    let cfg = RestoreConfig::paper_default(1536).unwrap();
    let mut cluster = Cluster::new_execution(1536, 48);
    let mut store = ReStore::new(cfg, &cluster).unwrap();
    store.submit_virtual(&mut cluster).unwrap();
    let mut rep = 0usize;
    let r = bench("load-1% resolve+route p=1536", 2, reps(20), || {
        rep += 1;
        let reqs = load_percent_requests(&store, &cluster, 1.0, rep % 1536);
        black_box(store.load(&mut cluster, &reqs).unwrap());
    });
    println!("{}", r.line());
    results.push(r);

    // IDL Monte-Carlo step
    let mut rng = Rng::seed_from_u64(1);
    let r = bench("IDL simulation p=2^20 r=4 (per run)", 1, reps(5), || {
        black_box(restore::restore::idl::simulate_failures_until_idl(1 << 20, 4, &mut rng));
    });
    println!("{}", r.line());
    results.push(r);

    // PJRT execution latency
    match Engine::load_default() {
        Ok(mut engine) => {
            let points = restore::apps::kmeans::generate_points(1, 0, 256, 8, 4);
            let centers = restore::apps::kmeans::starting_centers(1, 4, 8);
            let r = bench("PJRT kmeans_step_tiny (256x8)", 3, 30, || {
                black_box(engine.execute_f32("kmeans_step_tiny", &[&points, &centers]).unwrap());
            });
            println!("{}", r.line());
            results.push(r);

            let points = restore::apps::kmeans::generate_points(1, 0, 4096, 32, 20);
            let centers = restore::apps::kmeans::starting_centers(1, 20, 32);
            let r = bench("PJRT kmeans_step_small (4096x32)", 2, 15, || {
                black_box(engine.execute_f32("kmeans_step_small", &[&points, &centers]).unwrap());
            });
            println!("{}", r.line());
            results.push(r);

            let points = restore::apps::kmeans::generate_points(1, 0, 65536, 32, 20);
            let centers = restore::apps::kmeans::starting_centers(1, 20, 32);
            let r = bench("PJRT kmeans_step paper (65536x32)", 1, 5, || {
                black_box(engine.execute_f32("kmeans_step", &[&points, &centers]).unwrap());
            });
            println!("{}", r.line());
            results.push(r);
            println!(
                "\nPJRT totals: {} calls, {} cumulative",
                engine.exec_calls,
                fmt_time(engine.exec_seconds)
            );
        }
        Err(e) => println!("PJRT benches skipped: {e}"),
    }

    // machine-readable perf artifact for CI's cross-PR trajectory
    write_json_artifact("BENCH_hotpath.json", &results).expect("write BENCH_hotpath.json");
    println!("\nwrote BENCH_hotpath.json ({} entries)", results.len());
}
