//! Integration: §IV-E replica repair through the full ReStore store, and
//! node-correlated failure resilience of the placement.

use restore::config::RestoreConfig;
use restore::restore::load::scatter_requests;
use restore::restore::repair::RepairScheme;
use restore::restore::ReStore;
use restore::simnet::cluster::Cluster;
use restore::simnet::failure::node_failure;

fn setup(p: usize, r: usize) -> (Cluster, ReStore, Vec<Vec<u8>>) {
    let cfg = RestoreConfig::builder(p, 8, 64).replicas(r).build().unwrap();
    let mut cluster = Cluster::new_execution(p, 4);
    let mut store = ReStore::new(cfg, &cluster).unwrap();
    let shards: Vec<Vec<u8>> =
        (0..p).map(|pe| (0..64 * 8).map(|i| (pe * 17 + i) as u8).collect()).collect();
    store.submit(&mut cluster, &shards).unwrap();
    (cluster, store, shards)
}

#[test]
fn repair_restores_replication_level_and_data() {
    // The scenario §IV-E exists for: group {1,5,9,13} (stride p/r = 4)
    // loses two members, gets repaired, then loses the other two. Without
    // repair that is a certain IDL; with repair the re-created copies
    // (placed on PEs outside the dying group for these seeds) keep the
    // data recoverable.
    for scheme in [RepairScheme::DoubleHashing, RepairScheme::FeistelWalk] {
        // counterfactual: same four failures, no repair -> IDL
        let (mut c0, mut s0, _) = setup(16, 4);
        c0.kill(&[1, 5, 9, 13]);
        let reqs0 = scatter_requests(&s0, &c0, &[1]);
        assert!(
            s0.load(&mut c0, &reqs0).is_err(),
            "without repair, losing a whole group must be an IDL"
        );

        let (mut cluster, mut store, shards) = setup(16, 4);
        cluster.kill(&[1, 5]);
        let rep = store.repair_replicas(&mut cluster, scheme).unwrap();
        assert!(rep.transfers > 0, "{scheme:?}: something must move");
        assert_eq!(rep.unrepairable, 0);
        assert!(rep.cost.sim_time_s > 0.0);

        // every slice has >= r alive holders again
        for primary in 0..16usize {
            let start = primary as u64 * 64;
            let holders = (0..16)
                .filter(|&pe| cluster.is_alive(pe) && store.stores()[pe].holds(start, 64))
                .count();
            assert!(holders >= 4, "{scheme:?}: slice {primary} has {holders} alive holders");
        }

        // finish off the group; repaired copies must keep slice 1 loadable
        cluster.kill(&[9, 13]);
        let reqs = scatter_requests(&store, &cluster, &[1]);
        let out = store
            .load(&mut cluster, &reqs)
            .unwrap_or_else(|e| panic!("{scheme:?}: repaired data not found: {e}"));
        let mut recovered = 0usize;
        for (req, shard) in reqs.iter().zip(&out.shards) {
            let bytes = shard.bytes.as_ref().unwrap();
            recovered += bytes.len();
            let mut off = 0;
            for range in req.ranges.ranges() {
                for x in range.start..range.end {
                    let pe = (x / 64) as usize;
                    let boff = ((x % 64) * 8) as usize;
                    assert_eq!(&bytes[off..off + 8], &shards[pe][boff..boff + 8]);
                    off += 8;
                }
            }
        }
        assert_eq!(recovered, 64 * 8, "{scheme:?}");
    }
}

#[test]
fn repair_is_idempotent() {
    let (mut cluster, mut store, _) = setup(16, 4);
    cluster.kill(&[2]);
    let first = store.repair_replicas(&mut cluster, RepairScheme::DoubleHashing).unwrap();
    let second = store.repair_replicas(&mut cluster, RepairScheme::DoubleHashing).unwrap();
    assert!(first.transfers > 0);
    assert_eq!(second.transfers, 0, "second repair must be a no-op");
}

#[test]
fn repair_without_failures_moves_nothing() {
    let (mut cluster, mut store, _) = setup(8, 2);
    let rep = store.repair_replicas(&mut cluster, RepairScheme::FeistelWalk).unwrap();
    assert_eq!(rep.transfers, 0);
    assert_eq!(rep.unrepairable, 0);
}

#[test]
fn whole_node_failure_is_survivable_by_construction() {
    // §IV-A: the r copies of any block land on PEs far apart in rank space
    // -> different nodes. Killing any ONE whole node must never cause IDL.
    let p = 64;
    let (mut cluster, mut store, _) = setup(p, 4);
    let topo = cluster.topology().clone();
    let dead = node_failure(&topo, 2); // PEs 8..12 (4 per node)
    cluster.kill(&dead);
    let reqs = scatter_requests(&store, &cluster, &dead);
    let out = store.load(&mut cluster, &reqs).unwrap();
    let total: usize = out.shards.iter().map(|s| s.bytes.as_ref().unwrap().len()).sum();
    assert_eq!(total, dead.len() * 64 * 8);
}

#[test]
fn repair_reports_unrepairable_units_on_total_group_loss() {
    let (mut cluster, mut store, _) = setup(8, 2);
    // group stride p/r = 4: kill the whole group of PE 1 -> slices lost
    cluster.kill(&[1, 5]);
    let rep = store.repair_replicas(&mut cluster, RepairScheme::DoubleHashing).unwrap();
    assert!(rep.unrepairable > 0, "losing a full group must be reported");
}
