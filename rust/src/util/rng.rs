//! Deterministic pseudorandom number generator (xoshiro256** seeded via
//! SplitMix64) — the in-tree replacement for `rand`/`rand_chacha`.
//!
//! Not cryptographic; statistically solid and fully reproducible across
//! platforms, which is what the failure schedules and Monte-Carlo
//! simulations need.

/// xoshiro256** by Blackman & Vigna (public domain reference construction).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (the construction the authors
    /// recommend for initializing from a single u64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn gen_range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.gen_f64() as f32) * (hi - lo)
    }

    /// Uniform usize in [0, n) (Lemire-reduction, bias negligible for our n).
    #[inline]
    pub fn gen_index(&mut self, n: usize) -> usize {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform u64 in [0, n).
    #[inline]
    pub fn gen_u64_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Bernoulli trial.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_index(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::seed_from_u64(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).inspect(|x| assert!((0.0..1.0).contains(x))).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gen_index_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.gen_index(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_bool_frequency() {
        let mut r = Rng::seed_from_u64(3);
        let hits = (0..100_000).filter(|_| r.gen_bool(0.25)).count();
        assert!((hits as f64 / 100_000.0 - 0.25).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>()); // astronomically unlikely
    }
}
