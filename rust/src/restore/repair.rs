//! Replica repair after failures (§IV-E + Appendix).
//!
//! The paper proposes (as future work — "currently unimplemented" in their
//! C++ library; we implement it) restoring the replication level after a
//! failure *without* moving surviving replicas: each block (or permutation
//! range) `x` has an unbounded probing sequence `ρ_x` of PEs; its replicas
//! live on the first `r` alive entries. When a PE dies, each replica it
//! held is re-created on the next alive PE of that replica's sequence.
//!
//! Two sequence constructions from the Appendix:
//!
//! * **Distribution A** — double hashing: `ρ_x(k) = (f(x) + k·h_s(x)) mod p`
//!   with `h_s(x)` forced coprime to `p` by seed-retry (expected ≈ 1.65
//!   tries, checked against the paper's own √ formula in tests). Coprime
//!   step ⇒ the probe sequence visits all `p` PEs before repeating.
//! * **Distribution B** — a seeded Feistel permutation of `[0, p)` walked
//!   in order (independent per block).
//!
//! Both support the refined §IV-E hybrid: the first `r` placements follow
//! the §IV-A deterministic layout (perfect balance), the probing sequence
//! only takes over for replacements — `O(r + f)` time, `O(1)` space.
//!
//! Repair heals the latest *committed* version only: `resubmit` staging is
//! never a repair source or target (an in-flight checkpoint either commits
//! — becoming the version repair protects — or aborts and vanishes), and a
//! later in-place resubmit reaches probing-sequence replica homes through
//! the reverse [`crate::restore::store::HolderIndex`] rather than assuming
//! deterministic §IV-A positions.

use std::collections::HashMap;

use crate::restore::hashing::{coprime_to_factors, prime_factors, seeded_hash};
use crate::restore::permutation::{Feistel, RangePermutation};

/// Appendix probing-sequence constructions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RepairScheme {
    /// Double hashing with coprime steps.
    DoubleHashing,
    /// Per-block seeded Feistel permutation of `[0, p)`.
    FeistelWalk,
}

/// Probing-sequence generator for a world of `p` PEs.
pub struct ProbeSequences {
    p: u64,
    seed: u64,
    scheme: RepairScheme,
    factors: Vec<u64>,
    /// Stats: seed retries performed while searching coprime step values
    /// (to validate the Appendix's expected ≈1.65 evaluations).
    pub seed_trials: std::cell::Cell<u64>,
    pub seed_calls: std::cell::Cell<u64>,
}

impl ProbeSequences {
    pub fn new(p: usize, seed: u64, scheme: RepairScheme) -> Self {
        ProbeSequences {
            p: p as u64,
            seed,
            scheme,
            factors: prime_factors(p as u64),
            seed_trials: std::cell::Cell::new(0),
            seed_calls: std::cell::Cell::new(0),
        }
    }

    /// `ρ_x(k)`: the k-th PE in block `x`'s probing sequence.
    pub fn probe(&self, x: u64, k: u64) -> usize {
        match self.scheme {
            RepairScheme::DoubleHashing => {
                let f0 = seeded_hash(self.seed, x) % self.p;
                let step = self.coprime_step(x);
                ((f0 + (k % self.p) * step) % self.p) as usize
            }
            RepairScheme::FeistelWalk => {
                let perm = Feistel::new(self.p, seeded_hash(self.seed, x));
                perm.apply(k % self.p) as usize
            }
        }
    }

    /// Step value coprime to `p`, found by retrying seeds (Appendix A.1).
    fn coprime_step(&self, x: u64) -> u64 {
        self.seed_calls.set(self.seed_calls.get() + 1);
        if self.p == 1 {
            return 0;
        }
        for trial in 0.. {
            self.seed_trials.set(self.seed_trials.get() + 1);
            let h = seeded_hash(self.seed ^ (0xC0FFEE + trial), x) % self.p;
            if h != 0 && coprime_to_factors(h, &self.factors) {
                return h;
            }
        }
        unreachable!()
    }

    /// First `r` alive PEs of `x`'s sequence under the §IV-E *hybrid*
    /// placement: positions `k < r` come from the deterministic §IV-A
    /// layout (`deterministic(k)`), later positions from the probing
    /// sequence, skipping dead PEs and duplicates.
    pub fn replica_homes(
        &self,
        x: u64,
        r: usize,
        alive: impl Fn(usize) -> bool,
        deterministic: impl Fn(usize) -> usize,
    ) -> Vec<usize> {
        let mut homes = Vec::with_capacity(r);
        self.replica_homes_into(x, r, alive, deterministic, &mut homes);
        homes
    }

    /// Allocation-free variant of [`ProbeSequences::replica_homes`]: fills
    /// `out` (cleared first), so repair planning reuses one buffer across
    /// all units instead of allocating a `Vec` per unit.
    pub fn replica_homes_into(
        &self,
        x: u64,
        r: usize,
        alive: impl Fn(usize) -> bool,
        deterministic: impl Fn(usize) -> usize,
        out: &mut Vec<usize>,
    ) {
        out.clear();
        for k in 0..r {
            let pe = deterministic(k);
            if alive(pe) && !out.contains(&pe) {
                out.push(pe);
            }
        }
        let mut k = 0u64;
        while out.len() < r && (k as usize) < 4 * self.p as usize {
            let pe = self.probe(x, k);
            if alive(pe) && !out.contains(&pe) {
                out.push(pe);
            }
            k += 1;
        }
    }
}

/// A repair transfer: copy the permuted range starting at `perm_start`
/// (length `blocks`) from surviving holder `src` to new holder `dst`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RepairTransfer {
    pub perm_start: u64,
    pub blocks: u64,
    pub src: usize,
    pub dst: usize,
}

/// Plan the repair of all replicas lost with the `newly_dead` PEs.
///
/// `units` enumerates the (permuted) storage units as
/// `(unit_id, perm_start, blocks)`; `holders_of` returns the *current*
/// (pre-repair) surviving holders of a unit; `old_homes`/`new_homes` are
/// the replica home sets before/after marking the PEs dead. The planner
/// emits one transfer per (unit, lost replica), sourcing round-robin from
/// the survivors.
pub fn plan_repairs(
    units: &[(u64, u64, u64)],
    old_homes: impl Fn(u64) -> Vec<usize>,
    new_homes: impl Fn(u64) -> Vec<usize>,
) -> Vec<RepairTransfer> {
    let mut out = Vec::new();
    let mut rr: HashMap<u64, usize> = HashMap::new();
    for &(unit, perm_start, blocks) in units {
        let old = old_homes(unit);
        let new = new_homes(unit);
        let survivors: Vec<usize> =
            old.iter().copied().filter(|pe| new.contains(pe)).collect();
        if survivors.is_empty() {
            continue; // IDL: nothing to repair from
        }
        for &home in &new {
            if !old.contains(&home) {
                let idx = rr.entry(unit).or_insert(0);
                let src = survivors[*idx % survivors.len()];
                *idx += 1;
                out.push(RepairTransfer { perm_start, blocks, src, dst: home });
            }
        }
    }
    out
}

/// Report of a [`ReStore::repair_replicas`] run.
#[derive(Debug, Clone, Default)]
pub struct RepairReport {
    /// Transfers executed (one per re-created replica unit).
    pub transfers: usize,
    /// Units whose replicas were ALL lost (unrepairable; the §IV-D IDL
    /// event — the caller should fall back to reloading from disk).
    pub unrepairable: usize,
    /// Network cost of the repair phase.
    pub cost: crate::simnet::network::PhaseCost,
}

/// Planned §IV-E repair for one dataset: the transfers re-creating every
/// lost replica (in unit order) plus the units with no surviving replica.
/// Planning is read-only; the stores move only in
/// [`Dataset::apply_repair`], after the (possibly cross-dataset) phase has
/// been charged.
pub(crate) struct RepairPlan {
    transfers: Vec<RepairTransfer>,
    unrepairable: usize,
}

/// Charge ONE repair sparse all-to-all covering every dataset's plan.
///
/// Repair bills per *transfer* (each re-created replica is its own
/// point-to-point message — the cost oracle in the golden tests pins this),
/// and the phase accumulator sums per-PE counters, so the order transfers
/// enter the phase cannot change the cost: plain concatenation of the
/// plans is charge-identical to any (src, dst) merge order while still
/// collapsing the former per-dataset repair rounds into a single phase
/// (one latency term instead of one per dataset).
pub(crate) fn charge_repair_plans(
    cluster: &mut crate::simnet::cluster::Cluster,
    plans: &[(&RepairPlan, u64)],
) -> crate::error::Result<crate::simnet::network::PhaseCost> {
    let mut phase = cluster.phase();
    for (plan, bs) in plans {
        for t in &plan.transfers {
            phase.add(t.src, t.dst, t.blocks * bs)?;
        }
    }
    Ok(phase.commit())
}

impl crate::restore::registry::Dataset {
    /// §IV-E: re-create the replicas lost with the currently-dead PEs on
    /// the next alive PE of each unit's probing sequence, leaving all
    /// surviving replicas in place. Uses the *hybrid* placement: the first
    /// `r` homes are the deterministic §IV-A layout, replacements come
    /// from `scheme`'s probing sequence. Permutation-range granularity
    /// (§IV-E last paragraph): one unit per stored slice.
    ///
    /// Idempotent: repairing twice after the same failures moves nothing
    /// the second time. Multi-dataset callers should prefer
    /// [`ReStore::repair_replicas_all`](crate::restore::ReStore::repair_replicas_all),
    /// which fuses every dataset's transfers into one phase.
    pub fn repair_replicas(
        &mut self,
        cluster: &mut crate::simnet::cluster::Cluster,
        scheme: RepairScheme,
    ) -> crate::error::Result<RepairReport> {
        let plan = self.plan_repair(cluster, scheme)?;
        let bs = self.config().block_size as u64;
        let cost = charge_repair_plans(cluster, &[(&plan, bs)])?;
        self.apply_repair(plan, cost)
    }

    /// Plan (read-only) the §IV-E repair of this dataset under the current
    /// failure set. See [`Dataset::repair_replicas`] for the semantics.
    pub(crate) fn plan_repair(
        &self,
        cluster: &crate::simnet::cluster::Cluster,
        scheme: RepairScheme,
    ) -> crate::error::Result<RepairPlan> {
        self.ensure_submitted()?;
        // Shrink handshake: after `ulfm::shrink` (or substitute/grow),
        // rebalance (or acknowledge) before repairing — §IV-B.
        self.ensure_current_epoch(cluster)?;
        let dist = self.distribution();
        let p = dist.world();
        let r = dist.replicas();
        let seqs = ProbeSequences::new(p, self.config().seed ^ 0x4E9A12_u64, scheme);

        // units = permuted slices (grouped per primary slice owner).
        // Planning is allocation-free per unit: `homes` and `srcs` are
        // reused buffers and holder discovery reads the reverse holder
        // index — O(r + f) per unit instead of the former O(p) store
        // sweep (O(p²) per repair at the paper's p = 24 576).
        //
        // The deterministic layout and the probing sequences both work in
        // *distribution* ranks (the compact post-rebalance world);
        // stores, the holder index, and the network use *cluster* ranks —
        // `pe_map` translates at the boundary (the identity before any
        // rebalance).
        let pe_map: &[u32] = &self.pe_map;
        let alive = |pe: usize| cluster.is_alive(pe); // cluster ranks
        let alive_dist = |pe: usize| cluster.is_alive(pe_map[pe] as usize); // dist ranks
        let stride = dist.copy_stride();
        let offset = dist.placement_offset();
        let mut transfers: Vec<RepairTransfer> = Vec::new();
        let mut unrepairable = 0usize;
        let mut homes: Vec<usize> = Vec::with_capacity(r);
        let mut srcs: Vec<usize> = Vec::with_capacity(r);
        for primary in 0..p {
            let det = |k: usize| (primary + k * stride + offset) % p;
            let unit = primary as u64;
            seqs.replica_homes_into(unit, r, alive_dist, det, &mut homes);
            if homes.is_empty() {
                unrepairable += 1;
                continue;
            }
            if homes.len() < r {
                // fewer than r alive PEs overall; keep what we can
            }
            // balanced unequal slices: the unit's boundaries come from the
            // closed-form slice lattice, not a fixed blocks_per_pe stride
            let slice_start = dist.slice_start(primary);
            let len = dist.slice_len(primary);
            // Source candidates: the slot's alive PRE-CALL holders, read
            // from the reverse index once before any destination for this
            // unit is planned. A destination created this call holds no
            // valid bytes until its own transfer executes, so the
            // round-robin pick must never draw from one (the stale-read
            // hazard when chained failures overlap) — capturing the
            // pre-call set here guarantees that structurally.
            let holders = self.holder_index().holders_of(primary);
            srcs.clear();
            srcs.extend(holders.iter().map(|&pe| pe as usize).filter(|&pe| alive(pe)));
            if srcs.is_empty() {
                unrepairable += 1;
                continue;
            }
            for (i, &home) in homes.iter().enumerate() {
                let home_c = pe_map[home] as usize; // dist rank -> cluster rank
                if holders.binary_search(&(home_c as u32)).is_err() {
                    debug_assert!(!srcs.contains(&home_c), "repair dst picked as src");
                    transfers.push(RepairTransfer {
                        perm_start: slice_start,
                        blocks: len,
                        src: srcs[i % srcs.len()],
                        dst: home_c,
                    });
                }
            }
        }

        Ok(RepairPlan { transfers, unrepairable })
    }

    /// Execute a [`RepairPlan`] against this dataset's stores and holder
    /// index, stamping the (shared, already-charged) phase `cost` into the
    /// report. Transfers read only pre-call holders (see the stale-read
    /// note in [`Dataset::plan_repair`]) and distinct units occupy
    /// disjoint block ranges, so apply order is byte-irrelevant.
    ///
    /// Every transfer's source range is checksum-verified before it is
    /// copied: repair must never *multiply* silent corruption by stamping
    /// a rotten replica onto a fresh home. A mismatch aborts with
    /// [`Error::CorruptBlock`](crate::error::Error::CorruptBlock) naming
    /// the corrupt source. Transfers already applied stay — each is an
    /// independently valid verified copy, and repair is idempotent, so
    /// re-running after `Dataset::scrub` quarantines and heals the source
    /// completes exactly the remaining transfers.
    pub(crate) fn apply_repair(
        &mut self,
        plan: RepairPlan,
        cost: crate::simnet::network::PhaseCost,
    ) -> crate::error::Result<RepairReport> {
        use crate::restore::store::SliceBuf;

        let bs = self.config().block_size as u64;
        let dist = self.distribution().clone();
        for t in &plan.transfers {
            if let Some(y) = self.stores()[t.src].verify(t.perm_start, t.blocks) {
                return Err(crate::error::Error::CorruptBlock {
                    dataset: self.id,
                    block: dist.unpermute_block(y),
                    holder: t.src,
                });
            }
            let buf = match self.stores()[t.src].read(t.perm_start, t.blocks) {
                Some(bytes) => SliceBuf::Real(bytes.to_vec()),
                None => SliceBuf::Virtual(t.blocks * bs),
            };
            let range = crate::restore::block::BlockRange::new(
                t.perm_start,
                t.perm_start + t.blocks,
            );
            self.stores_mut()[t.dst].insert(range, buf);
            self.holder_index_mut().insert(dist.slice_of(t.perm_start), t.dst);
        }

        Ok(RepairReport {
            transfers: plan.transfers.len(),
            unrepairable: plan.unrepairable,
            cost,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn double_hashing_visits_all_pes() {
        // coprime step => the sequence is a full cycle over [0, p)
        let p = 500usize; // Appendix example: factors {2, 5}
        let seqs = ProbeSequences::new(p, 7, RepairScheme::DoubleHashing);
        for x in [0u64, 1, 42, 9999] {
            let seen: HashSet<usize> = (0..p as u64).map(|k| seqs.probe(x, k)).collect();
            assert_eq!(seen.len(), p, "x={x} sequence is not a full cycle");
        }
    }

    #[test]
    fn feistel_walk_visits_all_pes() {
        let p = 97usize;
        let seqs = ProbeSequences::new(p, 7, RepairScheme::FeistelWalk);
        for x in [0u64, 5, 1234] {
            let seen: HashSet<usize> = (0..p as u64).map(|k| seqs.probe(x, k)).collect();
            assert_eq!(seen.len(), p);
        }
    }

    #[test]
    fn expected_seed_trials_near_paper_value() {
        // Appendix: E[trials] = 7/6·(π²−6) ≈ 1.65 for random p. For
        // p = 500 (factors 2, 5): P(coprime) = 1/2·4/5 = 0.4 ⇒ E = 2.5.
        let seqs = ProbeSequences::new(500, 99, RepairScheme::DoubleHashing);
        for x in 0..2000u64 {
            seqs.probe(x, 1);
        }
        let avg = seqs.seed_trials.get() as f64 / seqs.seed_calls.get() as f64;
        assert!((avg - 2.5).abs() < 0.2, "avg trials {avg}");
    }

    #[test]
    fn replica_homes_prefers_deterministic_when_alive() {
        let seqs = ProbeSequences::new(16, 3, RepairScheme::DoubleHashing);
        let det = |k: usize| (2 + k * 4) % 16; // §IV-A layout for PE 2, r=4
        let homes = seqs.replica_homes(77, 4, |_| true, det);
        assert_eq!(homes, vec![2, 6, 10, 14]);
    }

    #[test]
    fn replica_homes_replaces_only_dead() {
        let seqs = ProbeSequences::new(16, 3, RepairScheme::DoubleHashing);
        let det = |k: usize| (2 + k * 4) % 16;
        let dead: HashSet<usize> = [6].into();
        let homes = seqs.replica_homes(77, 4, |pe| !dead.contains(&pe), det);
        assert_eq!(homes.len(), 4);
        assert!(homes.contains(&2) && homes.contains(&10) && homes.contains(&14));
        assert!(!homes.contains(&6));
        // stability: killing an unrelated PE must not move this block's
        // surviving replicas (the whole point of §IV-E)
        let dead2: HashSet<usize> = [6, 9].into();
        let homes2 = seqs.replica_homes(77, 4, |pe| !dead2.contains(&pe), det);
        if !homes.contains(&9) {
            assert_eq!(homes, homes2);
        }
    }

    #[test]
    fn repair_plan_restores_replication() {
        let seqs = ProbeSequences::new(8, 1, RepairScheme::DoubleHashing);
        let det = |k: usize| (k * 2) % 8; // homes of the unit: 0,2,4,6
        let units = vec![(0u64, 0u64, 4u64)];
        let alive_before = |_pe: usize| true;
        let dead: HashSet<usize> = [2].into();
        let alive_after = move |pe: usize| !dead.contains(&pe);
        let old = |u: u64| seqs.replica_homes(u, 4, alive_before, det);
        let new = |u: u64| seqs.replica_homes(u, 4, &alive_after, det);
        let plan = plan_repairs(&units, old, new);
        assert_eq!(plan.len(), 1);
        let t = plan[0];
        assert!(alive_after(t.src) && alive_after(t.dst));
        assert!([0usize, 4, 6].contains(&t.src));
        assert!(new(0).contains(&t.dst));
        assert!(!old(0).contains(&t.dst));
    }

    #[test]
    fn replica_homes_into_reuses_buffer_and_matches() {
        let seqs = ProbeSequences::new(16, 3, RepairScheme::DoubleHashing);
        let det = |k: usize| (2 + k * 4) % 16;
        let mut buf = Vec::new();
        for x in [7u64, 77, 777] {
            seqs.replica_homes_into(x, 4, |pe| pe != 6, det, &mut buf);
            assert_eq!(buf, seqs.replica_homes(x, 4, |pe| pe != 6, det), "x={x}");
        }
        assert!(buf.capacity() >= 4);
    }

    #[test]
    fn repair_plan_skips_idl_units() {
        let seqs = ProbeSequences::new(4, 1, RepairScheme::FeistelWalk);
        let det = |k: usize| k; // homes 0..r
        let units = vec![(0u64, 0u64, 1u64)];
        let old = |u: u64| seqs.replica_homes(u, 2, |pe| pe < 2, det);
        // everyone dead now
        let new = |u: u64| seqs.replica_homes(u, 2, |_| false, det);
        let plan = plan_repairs(&units, old, new);
        assert!(plan.is_empty());
    }
}

/// Golden parity: the index-driven planner must produce exactly the plan
/// (and therefore the post-repair stores, costs, and holder sets) of the
/// seed implementation's O(p)-per-unit store sweep.
#[cfg(test)]
mod golden {
    use super::*;
    use crate::config::RestoreConfig;
    use crate::restore::block::BlockRange;
    use crate::restore::store::{HolderIndex, PeStore, SliceBuf};
    use crate::restore::ReStore;
    use crate::simnet::cluster::Cluster;

    fn build(p: usize, r: usize, s_pr: Option<usize>) -> (Cluster, ReStore, Vec<Vec<u8>>) {
        let cfg = RestoreConfig::builder(p, 8, 64)
            .replicas(r)
            .perm_range_blocks(s_pr)
            .build()
            .unwrap();
        let mut cluster = Cluster::new_execution(p, 4);
        let mut rs = ReStore::new(cfg, &cluster).unwrap();
        let shards: Vec<Vec<u8>> =
            (0..p).map(|pe| (0..64 * 8).map(|i| (pe * 29 + i * 3) as u8).collect()).collect();
        rs.submit(&mut cluster, &shards).unwrap();
        (cluster, rs, shards)
    }

    /// The seed planner, kept verbatim as the oracle: per-unit allocated
    /// `replica_homes` Vec and an O(p) sweep over all PE stores for the
    /// holder set.
    fn reference_plan(
        rs: &ReStore,
        cluster: &Cluster,
        scheme: RepairScheme,
    ) -> Vec<RepairTransfer> {
        let dist = rs.distribution();
        let p = dist.world();
        let r = dist.replicas();
        let seqs = ProbeSequences::new(p, rs.config().seed ^ 0x4E9A12_u64, scheme);
        let alive = |pe: usize| cluster.is_alive(pe);
        let stride = dist.copy_stride();
        let offset = dist.placement_offset();
        let mut out = Vec::new();
        for primary in 0..p {
            let det = |k: usize| (primary + k * stride + offset) % p;
            let homes = seqs.replica_homes(primary as u64, r, alive, det);
            if homes.is_empty() {
                continue;
            }
            let slice_start = dist.slice_start(primary);
            let len = dist.slice_len(primary);
            let holders: Vec<usize> = (0..p)
                .filter(|&pe| alive(pe) && rs.stores()[pe].holds(slice_start, len))
                .collect();
            if holders.is_empty() {
                continue;
            }
            for (i, &home) in homes.iter().enumerate() {
                if !rs.stores()[home].holds(slice_start, len) {
                    out.push(RepairTransfer {
                        perm_start: slice_start,
                        blocks: len,
                        src: holders[i % holders.len()],
                        dst: home,
                    });
                }
            }
        }
        out
    }

    #[test]
    fn index_driven_repair_matches_sweep_reference() {
        for scheme in [RepairScheme::DoubleHashing, RepairScheme::FeistelWalk] {
            for s_pr in [Some(16), None] {
                let tag = format!("{scheme:?}/{s_pr:?}");
                let (mut cluster, mut rs, _) = build(16, 4, s_pr);
                cluster.kill(&[1, 5]);

                // oracle plan + its effect on a cloned store set
                let plan = reference_plan(&rs, &cluster, scheme);
                let mut ref_stores: Vec<PeStore> = rs.stores().to_vec();
                let mut ref_cluster = cluster.clone();
                let mut phase = ref_cluster.phase();
                for t in &plan {
                    phase.add(t.src, t.dst, t.blocks * 8).unwrap();
                }
                let ref_cost = phase.commit();
                for t in &plan {
                    let buf = match ref_stores[t.src].read(t.perm_start, t.blocks) {
                        Some(b) => SliceBuf::Real(b.to_vec()),
                        None => SliceBuf::Virtual(t.blocks * 8),
                    };
                    let range = BlockRange::new(t.perm_start, t.perm_start + t.blocks);
                    ref_stores[t.dst].insert(range, buf);
                }

                // a destination planned this call is never read as a source
                // for the same unit (the chained-failure stale-read hazard)
                for t in &plan {
                    assert!(
                        !plan
                            .iter()
                            .any(|u| u.perm_start == t.perm_start && u.dst == t.src),
                        "{tag}: transfer sources a same-call destination"
                    );
                }

                let report = rs.repair_replicas(&mut cluster, scheme).unwrap();
                assert_eq!(report.transfers, plan.len(), "{tag}: plan size");
                assert_eq!(report.cost, ref_cost, "{tag}: repair cost");
                for pe in 0..16 {
                    let got = rs.stores()[pe].slices();
                    let want = ref_stores[pe].slices();
                    assert_eq!(got.len(), want.len(), "{tag}: PE {pe} slice count");
                    for (g, w) in got.iter().zip(want) {
                        assert_eq!(g.range, w.range, "{tag}: PE {pe}");
                        match (&g.buf, &w.buf) {
                            (SliceBuf::Real(a), SliceBuf::Real(b)) => {
                                assert_eq!(a, b, "{tag}: PE {pe} {:?}", g.range)
                            }
                            (SliceBuf::Virtual(a), SliceBuf::Virtual(b)) => {
                                assert_eq!(a, b, "{tag}: PE {pe} {:?}", g.range)
                            }
                            _ => panic!("{tag}: PE {pe} buffer kind mismatch"),
                        }
                    }
                }

                // the incrementally maintained index matches a full rescan
                assert_eq!(
                    *rs.holder_index(),
                    HolderIndex::rebuild(rs.stores(), rs.distribution()),
                    "{tag}: holder index drifted"
                );
            }
        }
    }

    #[test]
    fn repair_refuses_to_copy_a_corrupt_source() {
        let (mut cluster, mut rs, _) = build(16, 4, Some(16));
        cluster.kill(&[1, 5]);
        let ds = &mut rs.datasets[0];
        let plan = ds.plan_repair(&cluster, RepairScheme::DoubleHashing).unwrap();
        assert!(!plan.transfers.is_empty());
        // Rot one bit in the first planned transfer's source slice: the
        // apply must refuse to stamp that copy onto a fresh home.
        let t = plan.transfers[0];
        assert!(ds.stores[t.src].corrupt_block_bit(t.perm_start, 0));
        let cost = charge_repair_plans(&mut cluster, &[(&plan, 8)]).unwrap();
        match ds.apply_repair(plan, cost) {
            Err(crate::error::Error::CorruptBlock { block, holder, .. }) => {
                assert_eq!(holder, t.src);
                assert_eq!(block, ds.dist.unpermute_block(t.perm_start));
            }
            other => panic!("expected CorruptBlock, got {other:?}"),
        }
    }

    #[test]
    fn chained_repairs_stay_consistent_and_idempotent() {
        let (mut cluster, mut rs, _) = build(16, 4, Some(16));
        for kills in [[1usize, 5], [9, 2]] {
            cluster.kill(&kills);
            let first = rs.repair_replicas(&mut cluster, RepairScheme::DoubleHashing).unwrap();
            assert!(first.transfers > 0);
            let second = rs.repair_replicas(&mut cluster, RepairScheme::DoubleHashing).unwrap();
            assert_eq!(second.transfers, 0, "repairing twice must move nothing");
            assert_eq!(
                *rs.holder_index(),
                HolderIndex::rebuild(rs.stores(), rs.distribution())
            );
        }
    }
}
