//! FT-RAxML-NG scenario (Fig 6): phylogenetic likelihood evaluation whose
//! per-PE MSA site shards are protected by ReStore; after failures the
//! survivors take over the dead PEs' sites and the global log-likelihood
//! is verified unchanged. Also prints the ReStore-vs-PFS recovery
//! comparison at the paper's scale (cost-model mode).
//!
//! Run with: `cargo run --release --example raxml_recovery`

use restore::apps::raxml::{self, PhyloDataset};
use restore::apps::Ownership;
use restore::config::{PfsConfig, RestoreConfig};
use restore::metrics::fmt_time;
use restore::restore::load::scatter_requests_for_ranges;
use restore::restore::serialize::blocks_to_f32s;
use restore::restore::ReStore;
use restore::runtime::Engine;
use restore::simnet::cluster::Cluster;
use restore::simnet::ulfm;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // --- Part 1: execution mode — real likelihood kernel, real recovery ----
    let p = 8;
    let sites_per_pe = 1024;
    println!("FT-RAxML-NG proxy: p={p}, {sites_per_pe} sites/PE, 4-state DNA model");

    let mut engine = Engine::load_default()?;
    let mut cluster = Cluster::new_execution(p, 4);
    let mut site_data: Vec<Vec<f32>> =
        (0..p).map(|pe| raxml::generate_sites(7, pe, sites_per_pe)).collect();

    let ll0 = raxml::evaluate_loglik(&mut cluster, &mut engine, "phylo_step_small", &site_data)?;
    println!("log-likelihood (all PEs alive): {ll0:.3}");

    // submit one site per 64 B block
    let bs = 64;
    let spf = raxml::SITE_PAYLOAD_F32S;
    let cfg = RestoreConfig::builder(p, bs, sites_per_pe).replicas(4).build()?;
    let mut store = ReStore::new(cfg, &cluster)?;
    let shards: Vec<Vec<u8>> = site_data
        .iter()
        .map(|d| {
            let mut out = Vec::with_capacity(sites_per_pe * bs);
            for site in d.chunks(spf) {
                for v in site {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out.resize(out.len() + bs - spf * 4, 0);
            }
            out
        })
        .collect();
    let submit = store.submit(&mut cluster, &shards)?;
    println!("submitted input to ReStore in {}", fmt_time(submit.cost.sim_time_s));

    // two nodes' worth of failures
    cluster.kill(&[2, 5]);
    let (failed, map, _cost) = ulfm::recover(&mut cluster);
    // adopt the shrunk communicator (6 survivors can't carry the §IV-A
    // layout with r = 4, so this acknowledges and routes around the holes)
    store.rebalance_or_acknowledge(&mut cluster, &map)?;
    let mut ownership = Ownership::identity(p, sites_per_pe as u64);
    let gained = ownership.rebalance(&failed, &cluster.survivors(), 1);
    let reqs = scatter_requests_for_ranges(&gained);
    let out = store.load(&mut cluster, &reqs)?;
    println!(
        "PEs {failed:?} failed; reloaded their {} sites scattered over {} survivors in {}",
        failed.len() * sites_per_pe,
        cluster.n_alive(),
        fmt_time(out.cost.sim_time_s)
    );
    for (req, shard) in reqs.iter().zip(&out.shards) {
        for block in shard.bytes.as_ref().unwrap().chunks(bs) {
            site_data[req.pe].extend(blocks_to_f32s(block, spf));
        }
    }
    for &f in &failed {
        site_data[f].clear();
    }
    let ll1 = raxml::evaluate_loglik(&mut cluster, &mut engine, "phylo_step_small", &site_data)?;
    println!("log-likelihood after recovery:  {ll1:.3}");
    let rel = (ll1 - ll0).abs() / ll0.abs();
    if rel >= 1e-5 {
        return Err(format!("likelihood diverged: {ll0} vs {ll1}").into());
    }
    println!("identical within f32 ordering (rel {rel:.1e}) — recovery is exact\n");

    // --- Part 2: Fig-6-style comparison at paper scale (cost model) --------
    println!("Fig-6-style recovery comparison (cost-model mode, 1 % of PEs failed):");
    println!(
        "{:<28} {:>8} {:>12} {:>14} {:>14} {:>14}",
        "dataset", "PEs", "ReStore sub", "ReStore load", "PFS uncached", "PFS cached"
    );
    for ds in PhyloDataset::paper_datasets() {
        let kill = (ds.pes / 100).max(1);
        let t = raxml::measure_recovery(ds.pes, 48, ds.bytes_per_pe, kill, &PfsConfig::default(), 1)?;
        println!(
            "{:<28} {:>8} {:>12} {:>14} {:>14} {:>14}",
            ds.name,
            ds.pes,
            fmt_time(t.restore_submit_s),
            fmt_time(t.restore_load_s),
            fmt_time(t.pfs_uncached_s),
            fmt_time(t.pfs_cached_s)
        );
    }
    Ok(())
}
