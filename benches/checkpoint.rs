//! Checkpointing benchmark (EXPERIMENTS.md §Checkpointing).
//!
//! Two questions, two sections, both in cost-model mode at the paper's
//! production scales (p = 1536 and p = 24576):
//!
//! * **What does a checkpoint cost, full vs delta?** A full resubmit
//!   re-replicates the whole dataset; a delta resubmit of k dirty blocks
//!   re-replicates only those blocks' replica sets. Reported as simulated
//!   nanoseconds and replicated bytes per checkpoint for the full space
//!   and for k = 64 scattered dirty blocks — the message/byte parity
//!   contract (`Dirty` charges exactly what the touched blocks cost) made
//!   quantitative.
//!
//! * **What does overlap buy at each checkpoint interval?** The
//!   GASPI-style async-checkpoint framing (arXiv:1505.04628): an
//!   iterative app checkpoints every I iterations, and replication either
//!   blocks the loop (`Overlap::Blocking`) or hides behind the next
//!   iteration's compute (`Overlap::Compute`), paying only the *exposed*
//!   remainder. Swept over I ∈ {1, 4, 16} with the per-iteration compute
//!   calibrated to one full-checkpoint latency, so overlap has exactly
//!   one iteration's worth of compute to hide behind. Reported as
//!   checkpoint overhead per iteration (ns) for both modes plus the
//!   recomputation exposure of the interval (worst-case lost work on a
//!   failure: I iterations + the checkpoint latency itself).
//!
//! With `BENCH_SHORT=1` the p = 24576 configuration is skipped and the
//! sweep is shortened (the CI schema smoke — see `make bench-json-short`).
//! Emits `BENCH_checkpoint.json` in the `{name, ns_per_iter}` artifact
//! schema (the name states the unit).

use restore::config::RestoreConfig;
use restore::restore::block::{BlockRange, RangeSet};
use restore::restore::{Overlap, ReStore};
use restore::simnet::cluster::Cluster;
use restore::util::bench::{black_box, short_mode, write_json_artifact, BenchResult};

const PPN: usize = 48;
const DELTA_BLOCKS: u64 = 64;

fn whole_space(store: &ReStore) -> RangeSet {
    RangeSet::new(vec![BlockRange::new(0, store.distribution().n_blocks())])
}

/// k single blocks scattered evenly across the block space — the worst
/// coalescing case for a delta (every dirty block is its own message).
fn scattered(store: &ReStore, k: u64) -> RangeSet {
    let n = store.distribution().n_blocks();
    let stride = (n / k).max(1);
    RangeSet::new((0..k).map(|i| BlockRange::new(i * stride, i * stride + 1)).collect())
}

/// Section 1: full-vs-delta checkpoint cost at scale.
fn full_vs_delta_at(p: usize, results: &mut Vec<BenchResult>) {
    let cfg = RestoreConfig::paper_default(p).unwrap();
    let mut cluster = Cluster::with_spares(p, PPN, 0);
    let mut store = ReStore::new(cfg, &cluster).unwrap();
    store.submit_virtual(&mut cluster).unwrap();

    let full = whole_space(&store);
    let rep_full = store.resubmit_virtual(&mut cluster, &full, Overlap::Blocking).unwrap();
    let delta = scattered(&store, DELTA_BLOCKS);
    let rep_delta = store.resubmit_virtual(&mut cluster, &delta, Overlap::Blocking).unwrap();
    assert_eq!(rep_delta.dirty_blocks, DELTA_BLOCKS);
    assert!(rep_delta.replicated_bytes < rep_full.replicated_bytes / 100);

    let tag = format!("p={p}");
    println!(
        "checkpoint {tag}: full sim {:.2} ms ({:.1} MiB), delta k={DELTA_BLOCKS} sim \
         {:.3} ms ({:.1} KiB) -> {:.0}x cheaper",
        rep_full.cost.sim_time_s * 1e3,
        rep_full.replicated_bytes as f64 / (1u64 << 20) as f64,
        rep_delta.cost.sim_time_s * 1e3,
        rep_delta.replicated_bytes as f64 / (1u64 << 10) as f64,
        rep_full.cost.sim_time_s / rep_delta.cost.sim_time_s,
    );
    results.push(BenchResult::from_value(
        &format!("checkpoint full-resubmit-sim-ns {tag}"),
        rep_full.cost.sim_time_s * 1e9,
    ));
    results.push(BenchResult::from_value(
        &format!("checkpoint full-resubmit-bytes {tag}"),
        rep_full.replicated_bytes as f64,
    ));
    results.push(BenchResult::from_value(
        &format!("checkpoint delta-resubmit-sim-ns {tag} k={DELTA_BLOCKS}"),
        rep_delta.cost.sim_time_s * 1e9,
    ));
    results.push(BenchResult::from_value(
        &format!("checkpoint delta-resubmit-bytes {tag} k={DELTA_BLOCKS}"),
        rep_delta.replicated_bytes as f64,
    ));
    black_box(rep_full.version);
}

/// Section 2: overlapped-vs-blocking overhead swept over the checkpoint
/// interval I. Per-iteration compute = one full-checkpoint latency, so
/// `Overlap::Compute` has exactly one iteration to hide behind.
fn overlap_sweep_at(p: usize, results: &mut Vec<BenchResult>) {
    let iters = if short_mode() { 8 } else { 32 };
    // Calibrate: one full-checkpoint simulated latency on a throwaway store.
    let cfg = RestoreConfig::paper_default(p).unwrap();
    let mut cal_cluster = Cluster::with_spares(p, PPN, 0);
    let mut cal = ReStore::new(cfg.clone(), &cal_cluster).unwrap();
    cal.submit_virtual(&mut cal_cluster).unwrap();
    let full = whole_space(&cal);
    let compute_s =
        cal.resubmit_virtual(&mut cal_cluster, &full, Overlap::Blocking).unwrap().cost.sim_time_s;

    for &interval in &[1usize, 4, 16] {
        let mut overhead = [0.0f64; 2]; // [blocking, overlapped]
        let mut ck_latency = 0.0f64;
        for (mode, slot) in [(Overlap::Blocking, 0), (Overlap::Compute(compute_s), 1)] {
            let mut cluster = Cluster::with_spares(p, PPN, 0);
            let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
            store.submit_virtual(&mut cluster).unwrap();
            let t0 = cluster.now();
            for it in 0..iters {
                cluster.tick_compute(compute_s);
                if (it + 1) % interval == 0 {
                    let dirty = whole_space(&store);
                    let rep = store.resubmit_virtual(&mut cluster, &dirty, mode).unwrap();
                    ck_latency = rep.cost.sim_time_s;
                }
            }
            // everything beyond pure compute is checkpoint overhead
            overhead[slot] = (cluster.now() - t0) - iters as f64 * compute_s;
        }
        let tag = format!("p={p} interval={interval}");
        // worst-case lost work on a failure just before a checkpoint lands
        let exposure_s = interval as f64 * compute_s + ck_latency;
        println!(
            "checkpoint sweep {tag}: blocking overhead {:.2} ms/iter, overlapped \
             {:.2} ms/iter ({:.0}% hidden), exposure {:.1} ms",
            overhead[0] / iters as f64 * 1e3,
            overhead[1] / iters as f64 * 1e3,
            (1.0 - overhead[1] / overhead[0].max(f64::EPSILON)) * 1e2,
            exposure_s * 1e3,
        );
        results.push(BenchResult::from_value(
            &format!("checkpoint blocking-overhead-ns-per-iter {tag}"),
            overhead[0] / iters as f64 * 1e9,
        ));
        results.push(BenchResult::from_value(
            &format!("checkpoint overlapped-overhead-ns-per-iter {tag}"),
            overhead[1] / iters as f64 * 1e9,
        ));
        results.push(BenchResult::from_value(
            &format!("checkpoint exposure-ns {tag}"),
            exposure_s * 1e9,
        ));
    }
}

fn main() {
    println!("=== checkpoint benchmarks ===\n");
    let mut results: Vec<BenchResult> = Vec::new();
    let scales: &[usize] = &[1536, 24576];
    let scales = if short_mode() { &scales[..1] } else { scales };
    for &p in scales {
        full_vs_delta_at(p, &mut results);
        overlap_sweep_at(p, &mut results);
    }
    write_json_artifact("BENCH_checkpoint.json", &results).expect("write BENCH_checkpoint.json");
    println!("\nwrote BENCH_checkpoint.json ({} entries)", results.len());
}
