//! Block identifiers and ranges.
//!
//! ReStore addresses user data as `n` fixed-size serialized *blocks* with
//! dense IDs `0..n` (§IV-A). The API works on half-open ID ranges — the
//! paper's load interface takes "a list of ranges of block identifiers"
//! (§V) — so ranges, not single blocks, are the unit everything below
//! operates on. This is also what lets the implementation scale: schedules
//! are O(ranges), never O(blocks).

/// A half-open range of block IDs `[start, end)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockRange {
    pub start: u64,
    pub end: u64,
}

impl BlockRange {
    pub fn new(start: u64, end: u64) -> Self {
        assert!(start <= end, "inverted range [{start}, {end})");
        BlockRange { start, end }
    }

    pub fn len(&self) -> u64 {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    pub fn contains(&self, id: u64) -> bool {
        self.start <= id && id < self.end
    }

    pub fn intersect(&self, other: &BlockRange) -> Option<BlockRange> {
        let s = self.start.max(other.start);
        let e = self.end.min(other.end);
        (s < e).then(|| BlockRange::new(s, e))
    }

    /// Split into subranges aligned to multiples of `chunk` (the
    /// permutation-range decomposition of §IV-B).
    pub fn chunks(&self, chunk: u64) -> impl Iterator<Item = BlockRange> + '_ {
        assert!(chunk > 0);
        let mut cur = self.start;
        let end = self.end;
        std::iter::from_fn(move || {
            if cur >= end {
                return None;
            }
            let next = ((cur / chunk) + 1) * chunk;
            let stop = next.min(end);
            let out = BlockRange::new(cur, stop);
            cur = stop;
            Some(out)
        })
    }
}

/// A normalized set of block ranges: sorted, non-overlapping, non-adjacent.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RangeSet {
    ranges: Vec<BlockRange>,
}

impl RangeSet {
    pub fn new(mut ranges: Vec<BlockRange>) -> Self {
        ranges.retain(|r| !r.is_empty());
        ranges.sort();
        let mut out: Vec<BlockRange> = Vec::with_capacity(ranges.len());
        for r in ranges {
            match out.last_mut() {
                Some(last) if r.start <= last.end => last.end = last.end.max(r.end),
                _ => out.push(r),
            }
        }
        RangeSet { ranges: out }
    }

    pub fn ranges(&self) -> &[BlockRange] {
        &self.ranges
    }

    pub fn total_blocks(&self) -> u64 {
        self.ranges.iter().map(BlockRange::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }

    /// Does the set contain block `id`? O(log ranges).
    pub fn contains(&self, id: u64) -> bool {
        let i = self.ranges.partition_point(|r| r.start <= id);
        i.checked_sub(1).is_some_and(|i| id < self.ranges[i].end)
    }

    /// Set union — the multi-dataset request router's merge primitive
    /// (e.g. combining several load-balancer grants for one PE into the
    /// single request set a `load_many` part accepts per dataset).
    pub fn union(&self, other: &RangeSet) -> RangeSet {
        let mut v = Vec::with_capacity(self.ranges.len() + other.ranges.len());
        v.extend_from_slice(&self.ranges);
        v.extend_from_slice(&other.ranges);
        RangeSet::new(v)
    }

    /// Set intersection, by a two-pointer sweep over the sorted disjoint
    /// range lists.
    pub fn intersect(&self, other: &RangeSet) -> RangeSet {
        let mut out: Vec<BlockRange> = Vec::new();
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.ranges.len() && j < other.ranges.len() {
            let (a, b) = (self.ranges[i], other.ranges[j]);
            if let Some(ov) = a.intersect(&b) {
                out.push(ov);
            }
            // advance whichever range ends first (the other may still
            // overlap the next one)
            if a.end <= b.end {
                i += 1;
            } else {
                j += 1;
            }
        }
        RangeSet { ranges: out }
    }

    /// Set difference `self \ other` — what remains of a request after
    /// removing the blocks another source already covers (the router's
    /// bounds/coverage check: `request.subtract(&dataset_space)` must be
    /// empty for a well-formed request).
    pub fn subtract(&self, other: &RangeSet) -> RangeSet {
        let mut out: Vec<BlockRange> = Vec::new();
        let mut j = 0usize;
        for &a in &self.ranges {
            let mut cur = a.start;
            // skip other-ranges that end at or before cur
            while j < other.ranges.len() && other.ranges[j].end <= cur {
                j += 1;
            }
            let mut k = j;
            while cur < a.end {
                match other.ranges.get(k) {
                    Some(b) if b.start < a.end => {
                        if b.start > cur {
                            out.push(BlockRange::new(cur, b.start));
                        }
                        cur = cur.max(b.end);
                        if b.end <= a.end {
                            k += 1;
                        }
                    }
                    _ => {
                        out.push(BlockRange::new(cur, a.end));
                        cur = a.end;
                    }
                }
            }
        }
        RangeSet { ranges: out }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_basics() {
        let r = BlockRange::new(10, 20);
        assert_eq!(r.len(), 10);
        assert!(r.contains(10) && r.contains(19) && !r.contains(20));
        assert!(!r.is_empty());
        assert!(BlockRange::new(5, 5).is_empty());
    }

    #[test]
    #[should_panic(expected = "inverted")]
    fn inverted_range_panics() {
        BlockRange::new(5, 4);
    }

    #[test]
    fn intersect() {
        let a = BlockRange::new(0, 10);
        assert_eq!(a.intersect(&BlockRange::new(5, 15)), Some(BlockRange::new(5, 10)));
        assert_eq!(a.intersect(&BlockRange::new(10, 15)), None);
        assert_eq!(a.intersect(&BlockRange::new(2, 3)), Some(BlockRange::new(2, 3)));
    }

    #[test]
    fn chunks_align_to_boundaries() {
        let r = BlockRange::new(5, 23);
        let cs: Vec<_> = r.chunks(8).collect();
        assert_eq!(
            cs,
            vec![
                BlockRange::new(5, 8),
                BlockRange::new(8, 16),
                BlockRange::new(16, 23)
            ]
        );
        assert_eq!(cs.iter().map(BlockRange::len).sum::<u64>(), r.len());
    }

    #[test]
    fn chunks_exact_fit() {
        let r = BlockRange::new(16, 32);
        let cs: Vec<_> = r.chunks(8).collect();
        assert_eq!(cs, vec![BlockRange::new(16, 24), BlockRange::new(24, 32)]);
    }

    #[test]
    fn rangeset_normalizes() {
        let s = RangeSet::new(vec![
            BlockRange::new(10, 20),
            BlockRange::new(0, 5),
            BlockRange::new(15, 25),
            BlockRange::new(5, 5),
        ]);
        assert_eq!(s.ranges(), &[BlockRange::new(0, 5), BlockRange::new(10, 25)]);
        assert_eq!(s.total_blocks(), 20);
    }

    #[test]
    fn rangeset_merges_adjacent() {
        let s = RangeSet::new(vec![BlockRange::new(0, 5), BlockRange::new(5, 10)]);
        assert_eq!(s.ranges(), &[BlockRange::new(0, 10)]);
    }

    #[test]
    fn set_algebra_basics() {
        let a = RangeSet::new(vec![BlockRange::new(0, 10), BlockRange::new(20, 30)]);
        let b = RangeSet::new(vec![BlockRange::new(5, 25)]);
        assert_eq!(
            a.union(&b).ranges(),
            &[BlockRange::new(0, 30)]
        );
        assert_eq!(
            a.intersect(&b).ranges(),
            &[BlockRange::new(5, 10), BlockRange::new(20, 25)]
        );
        assert_eq!(
            a.subtract(&b).ranges(),
            &[BlockRange::new(0, 5), BlockRange::new(25, 30)]
        );
        assert_eq!(
            b.subtract(&a).ranges(),
            &[BlockRange::new(10, 20)]
        );
        let empty = RangeSet::default();
        assert_eq!(a.subtract(&empty), a);
        assert!(a.intersect(&empty).is_empty());
        assert_eq!(a.union(&empty), a);
        assert!(a.contains(0) && a.contains(9) && !a.contains(10) && a.contains(29));
        assert!(!a.contains(15) && !a.contains(30));
    }

    /// Property test: `union`/`intersect`/`subtract` against a naive
    /// per-block-ID bitmap oracle over a small universe, plus the
    /// normalization invariants (sorted, disjoint, non-adjacent) every
    /// `RangeSet` must uphold — the contract the multi-dataset request
    /// router leans on.
    #[test]
    fn set_algebra_matches_bitmap_oracle() {
        use crate::util::rng::Rng;
        const UNIVERSE: u64 = 96;

        fn random_set(rng: &mut Rng) -> RangeSet {
            let k = rng.gen_index(5);
            let ranges: Vec<BlockRange> = (0..k)
                .map(|_| {
                    let s = rng.gen_u64_below(UNIVERSE);
                    let e = (s + 1 + rng.gen_u64_below(24)).min(UNIVERSE);
                    BlockRange::new(s, e)
                })
                .collect();
            RangeSet::new(ranges)
        }

        fn bitmap(set: &RangeSet) -> Vec<bool> {
            let mut bits = vec![false; UNIVERSE as usize];
            for r in set.ranges() {
                for id in r.start..r.end {
                    bits[id as usize] = true;
                }
            }
            bits
        }

        fn assert_normalized(set: &RangeSet, tag: &str) {
            for r in set.ranges() {
                assert!(r.start < r.end, "{tag}: empty range {r:?}");
            }
            for w in set.ranges().windows(2) {
                assert!(
                    w[0].end < w[1].start,
                    "{tag}: ranges {:?} and {:?} overlap or touch",
                    w[0],
                    w[1]
                );
            }
        }

        let mut rng = Rng::seed_from_u64(0x5E7A16EB);
        for trial in 0..500 {
            let a = random_set(&mut rng);
            let b = random_set(&mut rng);
            let (ba, bb) = (bitmap(&a), bitmap(&b));
            for op in ["union", "intersect", "subtract"] {
                let got = match op {
                    "union" => a.union(&b),
                    "intersect" => a.intersect(&b),
                    _ => a.subtract(&b),
                };
                assert_normalized(&got, op);
                for id in 0..UNIVERSE {
                    let i = id as usize;
                    let want = match op {
                        "union" => ba[i] || bb[i],
                        "intersect" => ba[i] && bb[i],
                        _ => ba[i] && !bb[i],
                    };
                    assert_eq!(
                        got.contains(id),
                        want,
                        "trial {trial}: {op} of {:?} and {:?} wrong at block {id}",
                        a.ranges(),
                        b.ranges()
                    );
                }
                // total_blocks agrees with the membership count
                let count = (0..UNIVERSE).filter(|&id| got.contains(id)).count() as u64;
                assert_eq!(got.total_blocks(), count, "trial {trial}: {op} volume");
            }
        }
    }
}
