//! Replica placement: the paper's data distribution (§IV-A, §IV-B).
//!
//! Copy `k` of the block with ID `x` lives on PE
//!
//! ```text
//! L(x, k) = ⌊π(x)·p/n⌋ + k·p/r   (mod p)
//! ```
//!
//! where `π` permutes *permutation ranges* of `s_pr` consecutive blocks
//! (identity when permutation is disabled). Because `n = p · blocks_per_pe`,
//! `⌊y·p/n⌋ = ⌊y / blocks_per_pe⌋` — the permuted ID space is divided into
//! `p` contiguous *slices* of `blocks_per_pe` blocks, and every PE stores
//! `r` whole slices (one per copy). The PEs `{ i ≡ g (mod p/r) }` store
//! identical data — the §IV-D *groups* whose simultaneous failure is the
//! only irrecoverable event.
//!
//! ## The placement index (perf)
//!
//! `π` is a 4-round Feistel cipher with cycle walking — ~16 hash rounds per
//! unit mapping, paid by *every* `permute_block` call. Submit touches every
//! unit once, but the load path re-maps the requested units on **every**
//! recovery, so the cipher cost recurs per failure. When the unit domain is
//! small enough ([`UNIT_INDEX_MAX_UNITS`]) the constructor precomputes the
//! whole unit→slot table once — one `Vec<u32>` shared (via `Arc`) by
//! submit, load, and repair — turning the per-unit mapping into one L1/L2
//! array read.
//!
//! Trade-off: 4 bytes per permutation unit of *global* memory. At the
//! paper's defaults (256 KiB ranges, 16 MiB/PE ⇒ 64 units/PE) that is
//! 256 B/PE — 6 MiB for the full p = 24 576 system, negligible next to the
//! 64 MiB/PE of replica payload. At pathological unit counts (tiny ranges ×
//! huge worlds) the table is skipped and the cipher is evaluated on demand,
//! so memory stays bounded; the inverse direction (`unpermute_block`, only
//! used on cold error paths) always uses the cipher.

use std::sync::Arc;

use crate::config::RestoreConfig;
use crate::error::{Error, Result};
use crate::restore::block::BlockRange;
use crate::restore::permutation::{Feistel, Identity, RangePermutation};

/// A contiguous piece of a request after mapping to the permuted ID space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PermutedPiece {
    /// Start in permuted block ID space.
    pub perm_start: u64,
    /// Corresponding start in original block ID space.
    pub orig_start: u64,
    /// Piece length in blocks. Never crosses a permutation-range boundary
    /// or (after [`Distribution::split_at_slices`]) a slice boundary.
    pub len: u64,
}

/// Largest unit domain for which the precomputed unit→slot placement index
/// is built (4 bytes per unit ⇒ ≤ 64 MiB of index). See the module docs
/// for the memory-vs-Feistel-throughput trade-off.
pub const UNIT_INDEX_MAX_UNITS: u64 = 1 << 24;

/// The placement function shared by submit, load, and repair.
#[derive(Clone)]
pub struct Distribution {
    p: usize,
    r: usize,
    offset: usize,
    /// The raw configured placement offset (before the `mod p` reduction),
    /// kept so [`Distribution::reshaped`] can re-reduce it at the new world
    /// size exactly as a fresh construction would.
    offset_cfg: usize,
    blocks_per_pe: u64,
    /// Permutation unit in blocks (= blocks_per_pe when permutation is off,
    /// so the whole shard is one unit).
    s_pr: u64,
    /// True when the configuration disabled permutation ranges (the unit
    /// permutation is the identity and `s_pr` tracks the slice size).
    identity: bool,
    perm: Arc<dyn RangePermutation>,
    /// Precomputed `unit → permuted slot` table (forward direction of
    /// `perm`), built once at construction when the domain is small enough.
    /// `None` ⇒ evaluate the cipher on demand.
    unit_index: Option<Arc<Vec<u32>>>,
}

impl Distribution {
    pub fn new(cfg: &RestoreConfig) -> Self {
        let bpp = cfg.blocks_per_pe as u64;
        let (s_pr, perm): (u64, Arc<dyn RangePermutation>) = match cfg.perm_range_blocks {
            Some(s) => {
                let domain = cfg.n_blocks() / s as u64;
                (s as u64, Arc::new(Feistel::new(domain, cfg.seed)))
            }
            None => {
                let domain = cfg.world as u64; // one unit per PE shard
                (bpp, Arc::new(Identity { domain }))
            }
        };
        // Placement index: only worth materializing for a real permutation
        // (the identity maps units for free) and a bounded domain.
        let unit_index = (cfg.perm_range_blocks.is_some()
            && perm.domain() <= UNIT_INDEX_MAX_UNITS)
            .then(|| {
                Arc::new((0..perm.domain()).map(|u| perm.apply(u) as u32).collect::<Vec<u32>>())
            });
        Distribution {
            p: cfg.world,
            r: cfg.replicas,
            offset: cfg.placement_offset % cfg.world,
            offset_cfg: cfg.placement_offset,
            blocks_per_pe: bpp,
            s_pr,
            identity: cfg.perm_range_blocks.is_none(),
            perm,
            unit_index,
        }
    }

    /// Can this layout be rewritten for a post-shrink world of `new_world`
    /// PEs holding the same `n` blocks? The §IV-A layout needs equal slices
    /// (`new_world | n`), `r | new_world` for the copy stride, and — with
    /// permutation ranges on — unit-aligned slices (`s_pr | n/new_world`,
    /// i.e. `new_world` divides the unit count) so the shared permuted ID
    /// space carries over unchanged.
    pub fn reshape_feasible(&self, new_world: usize) -> bool {
        if new_world == 0 || self.n_blocks() % new_world as u64 != 0 {
            return false;
        }
        if new_world % self.r != 0 {
            return false;
        }
        let new_bpp = self.n_blocks() / new_world as u64;
        self.identity || new_bpp % self.s_pr == 0
    }

    /// The same data, re-laid-out §IV-A-style over `new_world` PEs — the
    /// core of the shrinking-recovery rebalance (§IV-B): the permuted block
    /// ID space (permutation, seed, unit size, and therefore the
    /// precomputed unit→slot placement index) is **shared by `Arc`** with
    /// the old layout, only the slice partition (`blocks_per_pe`), the copy
    /// stride `new_world/r`, and the offset reduction change. Identical to
    /// `Distribution::new` of a fresh config at `new_world` (golden-tested),
    /// without re-deriving Feistel keys or re-materializing the index.
    ///
    /// With permutation disabled the unit is the whole slice, so the
    /// identity permutation is simply re-instantiated at the new domain.
    pub fn reshaped(&self, new_world: usize) -> Result<Distribution> {
        if !self.reshape_feasible(new_world) {
            return Err(Error::Config(format!(
                "cannot reshape layout to world {new_world}: need {new_world} | {} blocks, \
                 r={} | {new_world}{}",
                self.n_blocks(),
                self.r,
                if self.identity {
                    String::new()
                } else {
                    format!(", and {new_world} | {} permutation units", self.perm.domain())
                }
            )));
        }
        let new_bpp = self.n_blocks() / new_world as u64;
        let (s_pr, perm, unit_index): (u64, Arc<dyn RangePermutation>, _) = if self.identity {
            (new_bpp, Arc::new(Identity { domain: new_world as u64 }), None)
        } else {
            (self.s_pr, Arc::clone(&self.perm), self.unit_index.clone())
        };
        Ok(Distribution {
            p: new_world,
            r: self.r,
            offset: self.offset_cfg % new_world,
            offset_cfg: self.offset_cfg,
            blocks_per_pe: new_bpp,
            s_pr,
            identity: self.identity,
            perm,
            unit_index,
        })
    }

    pub fn world(&self) -> usize {
        self.p
    }

    pub fn replicas(&self) -> usize {
        self.r
    }

    pub fn blocks_per_pe(&self) -> u64 {
        self.blocks_per_pe
    }

    /// Permutation-unit size in blocks.
    pub fn perm_range_blocks(&self) -> u64 {
        self.s_pr
    }

    pub fn n_blocks(&self) -> u64 {
        self.p as u64 * self.blocks_per_pe
    }

    /// Group offset `p/r` between successive copies (§IV-A).
    pub fn copy_stride(&self) -> usize {
        self.p / self.r
    }

    /// The configured constant placement offset (see `RestoreConfig`).
    pub fn placement_offset(&self) -> usize {
        self.offset
    }

    /// §IV-D group of a PE: all PEs with equal `pe mod p/r` store the same
    /// slices.
    pub fn group_of(&self, pe: usize) -> usize {
        pe % self.copy_stride()
    }

    /// Is the precomputed unit→slot placement index active?
    pub fn has_unit_index(&self) -> bool {
        self.unit_index.is_some()
    }

    /// Permuted slot of permutation unit `unit` — one array read when the
    /// placement index is built, a Feistel evaluation otherwise.
    #[inline]
    pub fn unit_slot(&self, unit: u64) -> u64 {
        match &self.unit_index {
            Some(ix) => ix[unit as usize] as u64,
            None => self.perm.apply(unit),
        }
    }

    /// Permuted position of original block `x`.
    #[inline]
    pub fn permute_block(&self, x: u64) -> u64 {
        let unit = x / self.s_pr;
        let off = x % self.s_pr;
        self.unit_slot(unit) * self.s_pr + off
    }

    /// Original position of permuted block `y`.
    pub fn unpermute_block(&self, y: u64) -> u64 {
        let unit = y / self.s_pr;
        let off = y % self.s_pr;
        self.perm.invert(unit) * self.s_pr + off
    }

    /// PE owning the *primary* (k = 0) copy of permuted block `y`.
    pub fn primary_of_permuted(&self, y: u64) -> usize {
        debug_assert!(y < self.n_blocks());
        (y / self.blocks_per_pe) as usize
    }

    /// PE holding copy `k` of permuted block `y`: `L` of the paper
    /// (plus the configurable constant placement offset).
    pub fn holder(&self, y: u64, k: usize) -> usize {
        debug_assert!(k < self.r);
        (self.primary_of_permuted(y) + k * self.copy_stride() + self.offset) % self.p
    }

    /// All `r` holders of permuted block `y`.
    pub fn holders(&self, y: u64) -> Vec<usize> {
        (0..self.r).map(|k| self.holder(y, k)).collect()
    }

    /// The permuted slice `[start, end)` stored by `pe` as copy `k`.
    pub fn stored_slice(&self, pe: usize, k: usize) -> BlockRange {
        debug_assert!(pe < self.p && k < self.r);
        let primary =
            (pe + 2 * self.p - (k * self.copy_stride() + self.offset) % self.p) % self.p;
        let start = primary as u64 * self.blocks_per_pe;
        BlockRange::new(start, start + self.blocks_per_pe)
    }

    /// Original block range submitted by `pe` (the application's shard).
    pub fn shard_of(&self, pe: usize) -> BlockRange {
        let start = pe as u64 * self.blocks_per_pe;
        BlockRange::new(start, start + self.blocks_per_pe)
    }

    /// Decompose an *original* block range into permuted pieces, each fully
    /// inside one permutation unit AND one permuted slice (so each piece
    /// has a single well-defined holder set).
    pub fn permuted_pieces(&self, range: BlockRange, out: &mut Vec<PermutedPiece>) {
        for unit_piece in range.chunks(self.s_pr) {
            let perm_start = self.permute_block(unit_piece.start);
            // A piece inside one permutation unit maps contiguously; it can
            // still straddle a slice boundary if s_pr does not divide
            // blocks_per_pe alignment of the permuted start — split there.
            let piece = PermutedPiece {
                perm_start,
                orig_start: unit_piece.start,
                len: unit_piece.len(),
            };
            self.split_at_slices(piece, out);
        }
    }

    fn split_at_slices(&self, piece: PermutedPiece, out: &mut Vec<PermutedPiece>) {
        let mut start = piece.perm_start;
        let mut orig = piece.orig_start;
        let end = piece.perm_start + piece.len;
        while start < end {
            let slice_end = (start / self.blocks_per_pe + 1) * self.blocks_per_pe;
            let stop = slice_end.min(end);
            out.push(PermutedPiece { perm_start: start, orig_start: orig, len: stop - start });
            orig += stop - start;
            start = stop;
        }
    }
}

impl std::fmt::Debug for Distribution {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Distribution")
            .field("p", &self.p)
            .field("r", &self.r)
            .field("blocks_per_pe", &self.blocks_per_pe)
            .field("s_pr", &self.s_pr)
            .field("unit_index", &self.unit_index.as_ref().map(|ix| ix.len()))
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RestoreConfig;

    fn dist(p: usize, bpp: usize, r: usize, s_pr: Option<usize>) -> Distribution {
        let cfg = RestoreConfig::builder(p, 64, bpp)
            .replicas(r)
            .perm_range_blocks(s_pr)
            .build()
            .unwrap();
        Distribution::new(&cfg)
    }

    #[test]
    fn paper_figure1_layout() {
        // Fig 1: p=4, n=16, r=2, no permutation. Copy 1 of block x on PE
        // ⌊x/4⌋, copy 2 on PE ⌊x/4⌋+2 mod 4.
        let d = dist(4, 4, 2, None);
        for x in 0..16u64 {
            assert_eq!(d.permute_block(x), x); // identity
            assert_eq!(d.holder(x, 0), (x / 4) as usize);
            assert_eq!(d.holder(x, 1), ((x / 4 + 2) % 4) as usize);
        }
        // PE 0 stores its own slice (copy 1) and PE 2's slice (copy 2).
        assert_eq!(d.stored_slice(0, 0), BlockRange::new(0, 4));
        assert_eq!(d.stored_slice(0, 1), BlockRange::new(8, 12));
        assert_eq!(d.stored_slice(2, 1), BlockRange::new(0, 4));
    }

    #[test]
    fn holders_are_distinct_and_stride_separated() {
        let d = dist(16, 64, 4, Some(8));
        for y in (0..d.n_blocks()).step_by(37) {
            let hs = d.holders(y);
            let set: std::collections::HashSet<_> = hs.iter().collect();
            assert_eq!(set.len(), 4);
            for w in hs.windows(2) {
                assert_eq!((w[1] + 16 - w[0]) % 16, 4); // stride p/r = 4
            }
        }
    }

    #[test]
    fn permute_roundtrip() {
        let d = dist(8, 64, 2, Some(8));
        for x in 0..d.n_blocks() {
            assert_eq!(d.unpermute_block(d.permute_block(x)), x);
        }
    }

    #[test]
    fn permutation_preserves_offsets_within_unit() {
        let d = dist(8, 64, 2, Some(8));
        for x in (0..d.n_blocks()).step_by(8) {
            let base = d.permute_block(x);
            for off in 1..8 {
                assert_eq!(d.permute_block(x + off), base + off);
            }
        }
    }

    #[test]
    fn stored_slice_inverts_holder() {
        let d = dist(12, 48, 3, Some(4));
        for pe in 0..12 {
            for k in 0..3 {
                let slice = d.stored_slice(pe, k);
                // every permuted block in that slice has pe as its k-holder
                for y in slice.start..slice.end {
                    assert_eq!(d.holder(y, k), pe);
                }
            }
        }
    }

    #[test]
    fn pieces_cover_request_and_respect_boundaries() {
        let d = dist(8, 64, 2, Some(8));
        let req = BlockRange::new(5, 200);
        let mut pieces = Vec::new();
        d.permuted_pieces(req, &mut pieces);
        // total length preserved
        assert_eq!(pieces.iter().map(|p| p.len).sum::<u64>(), req.len());
        let mut orig = req.start;
        for p in &pieces {
            assert_eq!(p.orig_start, orig, "pieces in request order");
            orig += p.len;
            // no piece crosses a slice boundary
            let first_slice = p.perm_start / 64;
            let last_slice = (p.perm_start + p.len - 1) / 64;
            assert_eq!(first_slice, last_slice);
            // mapping is consistent with permute_block
            assert_eq!(d.permute_block(p.orig_start), p.perm_start);
        }
    }

    #[test]
    fn groups_store_identical_data() {
        let d = dist(8, 16, 2, Some(4));
        // group stride p/r = 4: PEs 1 and 5 are in the same group
        let slices =
            |pe: usize| -> Vec<BlockRange> { (0..2).map(|k| d.stored_slice(pe, k)).collect() };
        let a = slices(1);
        let b = slices(5);
        let sa: std::collections::HashSet<_> = a.into_iter().collect();
        let sb: std::collections::HashSet<_> = b.into_iter().collect();
        assert_eq!(sa, sb);
        assert_eq!(d.group_of(1), d.group_of(5));
        assert_ne!(d.group_of(1), d.group_of(2));
    }

    #[test]
    fn unit_index_matches_cipher() {
        // The precomputed table must agree with the Feistel cipher exactly
        // (one entry per unit, forward direction).
        let cfg = RestoreConfig::builder(8, 64, 64)
            .replicas(2)
            .perm_range_blocks(Some(8))
            .build()
            .unwrap();
        let d = Distribution::new(&cfg);
        assert!(d.has_unit_index());
        let f = Feistel::new(cfg.n_blocks() / 8, cfg.seed);
        for u in 0..(cfg.n_blocks() / 8) {
            assert_eq!(d.unit_slot(u), f.apply(u), "unit {u}");
        }
    }

    #[test]
    fn identity_distribution_skips_unit_index() {
        let d = dist(4, 16, 2, None);
        assert!(!d.has_unit_index());
        assert_eq!(d.permute_block(17), 17);
    }

    #[test]
    fn reshaped_matches_fresh_construction() {
        // The rebalance layout must be indistinguishable from building a
        // new Distribution at the shrunken world from scratch — same
        // permuted space, same holders, same slices.
        for (s_pr, new_p) in [(Some(16usize), 8usize), (Some(16), 4), (None, 8), (None, 4)] {
            let cfg = RestoreConfig::builder(16, 8, 64)
                .replicas(4)
                .perm_range_blocks(s_pr)
                .seed(0xD157)
                .build()
                .unwrap();
            let old = Distribution::new(&cfg);
            let got = old.reshaped(new_p).unwrap();
            let fresh_cfg = RestoreConfig::builder(new_p, 8, (cfg.n_blocks() as usize) / new_p)
                .replicas(4)
                .perm_range_blocks(s_pr)
                .seed(0xD157)
                .build()
                .unwrap();
            let want = Distribution::new(&fresh_cfg);
            assert_eq!(got.world(), want.world());
            assert_eq!(got.blocks_per_pe(), want.blocks_per_pe());
            assert_eq!(got.perm_range_blocks(), want.perm_range_blocks());
            assert_eq!(got.n_blocks(), old.n_blocks());
            for y in 0..got.n_blocks() {
                assert_eq!(got.permute_block(y), want.permute_block(y), "s_pr {s_pr:?} y {y}");
                assert_eq!(got.unpermute_block(y), want.unpermute_block(y));
                for k in 0..4 {
                    assert_eq!(got.holder(y, k), want.holder(y, k), "s_pr {s_pr:?} y {y} k {k}");
                }
            }
            for pe in 0..new_p {
                for k in 0..4 {
                    assert_eq!(got.stored_slice(pe, k), want.stored_slice(pe, k));
                }
            }
        }
    }

    #[test]
    fn reshape_feasibility_rules() {
        // p=16, bpp=64, s_pr=16: n = 1024 blocks, 64 permutation units.
        let d = dist(16, 64, 4, Some(16));
        assert!(d.reshape_feasible(16));
        assert!(d.reshape_feasible(8));
        assert!(d.reshape_feasible(4));
        assert!(!d.reshape_feasible(0));
        assert!(!d.reshape_feasible(12), "1024 blocks are not divisible into 12 slices");
        assert!(!d.reshape_feasible(2), "r=4 must divide the new world");
        assert!(d.reshaped(2).is_err());
        // identity layouts only need n % p' == 0 and r | p'
        let id = dist(16, 64, 2, None);
        assert!(id.reshape_feasible(8));
        assert!(!id.reshape_feasible(10), "n % p' != 0");
        assert!(!id.reshape_feasible(1), "r=2 must divide the new world");
    }

    #[test]
    fn reshaped_preserves_offset_semantics() {
        let cfg = RestoreConfig::builder(8, 8, 64)
            .replicas(2)
            .placement_offset(5)
            .build()
            .unwrap();
        let old = Distribution::new(&cfg);
        let got = old.reshaped(4).unwrap();
        let fresh = RestoreConfig::builder(4, 8, 128)
            .replicas(2)
            .placement_offset(5)
            .build()
            .unwrap();
        let want = Distribution::new(&fresh);
        assert_eq!(got.placement_offset(), want.placement_offset());
        for y in (0..512).step_by(13) {
            assert_eq!(got.holder(y, 1), want.holder(y, 1));
        }
    }

    #[test]
    fn no_permutation_keeps_shard_contiguous() {
        let d = dist(4, 16, 2, None);
        let mut pieces = Vec::new();
        d.permuted_pieces(BlockRange::new(16, 32), &mut pieces);
        assert_eq!(pieces.len(), 1);
        assert_eq!(pieces[0].perm_start, 16);
    }
}
