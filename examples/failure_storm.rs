//! An MTBF-driven failure storm weathered by all three recovery policies.
//!
//! The paper always recovers by *shrinking* (§IV-B). This example drives
//! the same storm — Poisson failure arrivals against the simulated
//! cluster clock, one PE per strike — through the full policy space:
//!
//! * `policy::Shrink` — the paper's behavior: survivors adopt a smaller
//!   communicator, ReStore rebalances to the `p' < p` world;
//! * `policy::Substitute` — the world size is preserved by seating spare
//!   PEs in the dead ranks' positions; the reshape degenerates to a
//!   repair-shaped transfer (only the dead ranks' replicas move, onto
//!   their spares);
//! * `policy::ShrinkThenRegrow` — shrink now, re-grow toward the original
//!   world with whatever spares remain, ONE reshape against the final map.
//!
//! Every wave runs the complete agree → reshape → fused
//! rebalance/acknowledge (→ fused §IV-E repair when needed) handshake for
//! BOTH registered datasets, and after every wave the example reloads
//! *all* blocks of both datasets and checks them byte-for-byte against
//! the originally submitted shards — the golden oracle: no matter which
//! policy ran, recovery is exact.
//!
//! Run with: `cargo run --release --example failure_storm`

use restore::config::RestoreConfig;
use restore::metrics::fmt_time;
use restore::restore::block::{BlockRange, RangeSet};
use restore::restore::idl;
use restore::restore::policy::{
    RecoveryAction, RecoveryPolicy, Shrink, ShrinkThenRegrow, Substitute,
};
use restore::restore::{DatasetId, LoadRequest, ReStore};
use restore::simnet::cluster::Cluster;
use restore::simnet::failure::MtbfStorm;
use restore::simnet::network::PhaseCost;

const P: usize = 64;
const PPN: usize = 8;
const SPARES: usize = 16;
const R: usize = 4;
const BPP: u64 = 64;
const BS: usize = 8;
/// Second dataset: model state with its own replication level/block size.
const R2: usize = 2;
const BPP2: u64 = 16;
const BS2: usize = 16;
/// Per-PE mean time between failures. 64 alive PEs -> one strike every
/// ~50 simulated seconds; each wave kills a single PE (a survivable mix
/// at r = 4, since every recovery restores full replication before the
/// next strike).
const PE_MTBF_S: f64 = 3200.0;
const WAVES: usize = 6;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut policies: Vec<Box<dyn RecoveryPolicy>> = vec![
        Box::new(Shrink),
        Box::new(Substitute),
        Box::new(ShrinkThenRegrow { target_world: P }),
    ];
    for policy in policies.iter_mut() {
        run_storm(policy.as_mut())?;
    }
    println!("\nall policies weathered the storm; every reload was byte-exact");
    Ok(())
}

fn run_storm(policy: &mut dyn RecoveryPolicy) -> Result<(), Box<dyn std::error::Error>> {
    println!("\n=== policy `{}`: {WAVES}-wave MTBF storm over p = {P} (+{SPARES} spares) ===",
        policy.name());
    let cfg = RestoreConfig::builder(P, BS, BPP as usize).replicas(R).build()?;
    let model_cfg = RestoreConfig::builder(P, BS2, BPP2 as usize).replicas(R2).build()?;
    let mut cluster = Cluster::with_spares(P, PPN, SPARES);
    let mut store = ReStore::new(cfg, &cluster)?;
    let model = store.create_dataset(model_cfg, &cluster)?;
    let shards: Vec<Vec<u8>> = (0..P)
        .map(|pe| (0..BPP as usize * BS).map(|i| (pe * 41 + i * 3) as u8).collect())
        .collect();
    let model_shards: Vec<Vec<u8>> = (0..P)
        .map(|pe| (0..BPP2 as usize * BS2).map(|i| (pe * 13 + i * 7) as u8).collect())
        .collect();
    store.submit(&mut cluster, &shards)?;
    store.dataset_mut(model)?.submit(&mut cluster, &model_shards)?;

    // Same seed for every policy: all three face the *identical* storm.
    let mut storm = MtbfStorm::new(PE_MTBF_S, 0.0, 0xA11CE);
    let mut recovery_total_s = 0.0;
    for wave in 1..=WAVES {
        let ev = storm.next_event(&cluster).expect("enough survivors to continue");
        // run the application until the strike lands
        let gap = PhaseCost { sim_time_s: ev.at_s - cluster.now(), ..Default::default() };
        cluster.advance(&gap);
        cluster.kill(&ev.kills);

        let out = policy.recover(&mut cluster, &mut store)?;
        recovery_total_s += out.recovery_time_s;
        let action = match out.action {
            RecoveryAction::Shrunk { new_world } => format!("shrunk to {new_world}"),
            RecoveryAction::Substituted { replaced } => {
                format!("substituted {replaced} spare(s), world kept at {}", out.map.new_world())
            }
            RecoveryAction::Regrown { shrunk_to, regrown_to } => {
                format!("shrunk to {shrunk_to}, regrown to {regrown_to}")
            }
        };
        println!(
            "wave {wave} at {}: killed {:?} -> {action}{} ({}, {} spares left)",
            fmt_time(ev.at_s),
            ev.kills,
            if out.degraded { " [degraded]" } else { "" },
            fmt_time(out.recovery_time_s),
            cluster.n_spares(),
        );

        // Golden oracle: EVERY block of BOTH datasets reloads with exactly
        // the bytes submitted before any failure.
        verify_full_reload(&mut cluster, &mut store, DatasetId::FIRST, &shards, BPP, BS)?;
        verify_full_reload(&mut cluster, &mut store, DatasetId(1), &model_shards, BPP2, BS2)?;
    }

    let p_final = store.distribution().world() as u64;
    println!(
        "storm over: world {} -> {p_final}, {} spares left, {} total recovery time",
        P,
        cluster.n_spares(),
        fmt_time(recovery_total_s),
    );
    println!(
        "P(IDL | 8 more failures) at the final world (small-f approx): {:.2e}",
        idl::p_idl_approx(p_final, R as u64, 8)
    );
    Ok(())
}

/// Reload every block of `id` to one survivor and compare byte-for-byte
/// with the originally submitted shards.
fn verify_full_reload(
    cluster: &mut Cluster,
    store: &mut ReStore,
    id: DatasetId,
    shards: &[Vec<u8>],
    bpp: u64,
    bs: usize,
) -> Result<(), Box<dyn std::error::Error>> {
    let pe = cluster.survivors()[0];
    let n = shards.len() as u64 * bpp;
    let reqs = vec![LoadRequest { pe, ranges: RangeSet::new(vec![BlockRange::new(0, n)]) }];
    let out = store.dataset_mut(id)?.load(cluster, &reqs)?;
    let bytes = out.shards[0].bytes.as_ref().expect("execution mode");
    let mut off = 0usize;
    for x in 0..n {
        let src = &shards[(x / bpp) as usize];
        let boff = ((x % bpp) as usize) * bs;
        assert_eq!(
            &bytes[off..off + bs],
            &src[boff..boff + bs],
            "dataset {id:?}: block {x} corrupted"
        );
        off += bs;
    }
    Ok(())
}
