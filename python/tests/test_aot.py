"""AOT bridge tests: artifacts lower, manifest is consistent, HLO is text."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot


@pytest.fixture(scope="module")
def small_manifest(tmp_path_factory):
    outdir = tmp_path_factory.mktemp("artifacts")
    import sys

    argv = sys.argv
    sys.argv = [
        "aot",
        "--outdir",
        str(outdir),
        "--only",
        "kmeans_step_tiny,kmeans_update_tiny,phylo_step_small",
    ]
    try:
        aot.main()
    finally:
        sys.argv = argv
    with open(outdir / "manifest.json") as f:
        return outdir, json.load(f)


def test_manifest_lists_requested_variants(small_manifest):
    _, manifest = small_manifest
    assert set(manifest) == {
        "kmeans_step_tiny",
        "kmeans_update_tiny",
        "phylo_step_small",
    }


def test_artifacts_are_hlo_text(small_manifest):
    outdir, manifest = small_manifest
    for entry in manifest.values():
        text = open(os.path.join(outdir, entry["file"])).read()
        assert text.startswith("HloModule"), entry["file"]
        # the rust loader requires an entry computation
        assert "ENTRY" in text


def test_manifest_shapes_match_variants(small_manifest):
    _, manifest = small_manifest
    km = manifest["kmeans_step_tiny"]
    assert [a["shape"] for a in km["args"]] == [[256, 8], [4, 8]]
    assert [r["shape"] for r in km["results"]] == [[4, 8], [4], [1]]
    assert [r["name"] for r in km["results"]] == ["sums", "counts", "inertia"]
    ph = manifest["phylo_step_small"]
    assert [r["shape"] for r in ph["results"]] == [[1024, 4], [1]]


def test_lowered_kmeans_numerics_roundtrip(small_manifest):
    # Compile the tiny variant's HLO back through jax's CPU client and
    # compare against the oracle — proves the *artifact*, not just the
    # python function, is correct.
    outdir, manifest = small_manifest
    from jax._src.lib import xla_client as xc
    from compile.kernels.ref import kmeans_assign_ref

    text = open(os.path.join(outdir, manifest["kmeans_step_tiny"]["file"])).read()
    client = xc._xla.get_tfrt_cpu_client()  # local CPU PJRT client
    # Parse HLO text into an XlaComputation via the same API the rust side
    # uses conceptually (text -> module proto -> computation).
    comp = getattr(xc._xla, "hlo_text_to_xla_computation", None)
    if comp is None:
        pytest.skip("hlo_text parser not exposed by this jaxlib")
    rng = np.random.default_rng(0)
    points = rng.standard_normal((256, 8)).astype(np.float32)
    centers = rng.standard_normal((4, 8)).astype(np.float32)
    executable = client.compile(comp(text))
    out = executable.execute([client.buffer_from_pyval(points),
                              client.buffer_from_pyval(centers)])
    sums = np.asarray(out[0])
    rsums, _, _ = kmeans_assign_ref(jnp.asarray(points), jnp.asarray(centers))
    np.testing.assert_allclose(sums, rsums, rtol=1e-4, atol=1e-3)
