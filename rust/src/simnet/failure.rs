//! Failure schedules.
//!
//! The paper's application experiments (§VI-C) "simulate an expected failure
//! of 1 % of all nodes distributed uniformly at random during these
//! iterations ... by determining a suitable probability for each PE to fail
//! in each iteration" (a discrete exponential decay). Fig 3 kills PEs
//! uniformly at random one by one. Node-correlated failures (whole node
//! dies, taking its 48 PEs) are the failure mode the placement's
//! node-spreading argument (§IV-A) defends against — provided here for the
//! ablation benches.

use crate::simnet::cluster::Cluster;
use crate::simnet::topology::Topology;
use crate::util::rng::Rng;

/// Discrete exponential-decay schedule: each alive PE fails independently
/// with probability `q` per iteration, with `q` chosen so that the expected
/// surviving fraction after `iterations` equals `1 - total_fraction`.
#[derive(Debug, Clone, Copy)]
pub struct ExpDecaySchedule {
    pub per_iteration_prob: f64,
}

impl ExpDecaySchedule {
    pub fn new(total_fraction: f64, iterations: usize) -> Self {
        assert!((0.0..1.0).contains(&total_fraction));
        assert!(iterations > 0);
        // (1 - q)^iterations = 1 - total_fraction
        let q = 1.0 - (1.0 - total_fraction).powf(1.0 / iterations as f64);
        ExpDecaySchedule { per_iteration_prob: q }
    }

    /// Sample the ranks failing this iteration from `alive`.
    pub fn sample(&self, rng: &mut Rng, alive: &[usize]) -> Vec<usize> {
        alive
            .iter()
            .copied()
            .filter(|_| rng.gen_bool(self.per_iteration_prob))
            .collect()
    }
}

/// Kill `count` PEs chosen uniformly at random from `alive` (Fig 3 setup).
pub fn uniform_kills(rng: &mut Rng, alive: &[usize], count: usize) -> Vec<usize> {
    let mut pool: Vec<usize> = alive.to_vec();
    rng.shuffle(&mut pool);
    pool.truncate(count.min(pool.len()));
    pool
}

/// Whole-node failure: all PEs of `node` die together.
pub fn node_failure(topo: &Topology, node: usize) -> Vec<usize> {
    topo.ranks_on_node(node).collect()
}

/// One storm arrival: the wall-clock the failure strikes at and the ranks
/// it takes down.
#[derive(Debug, Clone, PartialEq)]
pub struct StormEvent {
    /// Simulated absolute time of the failure (seconds; compare against
    /// `Cluster::now()`).
    pub at_s: f64,
    /// Cluster ranks killed by this event (one PE, or a whole node for a
    /// correlated burst).
    pub kills: Vec<usize>,
}

/// MTBF-driven failure storm: failures arrive as a Poisson process against
/// the simulated cluster clock. Each *PE* has mean time between failures
/// `pe_mtbf_s`, so with `a` alive communicator members the cluster-level
/// failure rate is `a / pe_mtbf_s` and inter-arrival gaps are exponential
/// with that rate — the standard memoryless large-machine failure model
/// (and the continuous-time version of the paper's §VI-C per-iteration
/// failure probability). With probability `node_burst_prob` an arrival is
/// *node-correlated*: the victim's whole node dies together, the failure
/// mode §IV-A's node-spreading placement defends against.
#[derive(Debug, Clone)]
pub struct MtbfStorm {
    pe_mtbf_s: f64,
    node_burst_prob: f64,
    rng: Rng,
}

impl MtbfStorm {
    pub fn new(pe_mtbf_s: f64, node_burst_prob: f64, seed: u64) -> Self {
        assert!(pe_mtbf_s > 0.0, "MTBF must be positive");
        assert!((0.0..=1.0).contains(&node_burst_prob));
        MtbfStorm { pe_mtbf_s, node_burst_prob, rng: Rng::seed_from_u64(seed) }
    }

    /// Sample the next failure event after `cluster.now()`. Returns `None`
    /// once fewer than two communicator members survive (no storm left to
    /// weather). The victim is drawn uniformly from the alive members via
    /// the allocation-free survivor iterator; a node burst widens it to
    /// the victim's whole node (already-dead neighbors are no-ops at
    /// `Cluster::kill`).
    pub fn next_event(&mut self, cluster: &Cluster) -> Option<StormEvent> {
        let alive = cluster.n_alive();
        if alive < 2 {
            return None;
        }
        let rate = alive as f64 / self.pe_mtbf_s;
        let gap_s = -(1.0 - self.rng.gen_f64()).ln() / rate;
        let victim = cluster
            .survivors_iter()
            .nth(self.rng.gen_index(alive))
            .expect("n_alive survivors");
        let kills = if self.rng.gen_bool(self.node_burst_prob) {
            let topo = cluster.topology();
            topo.ranks_on_node(topo.node_of(victim)).collect()
        } else {
            vec![victim]
        };
        Some(StormEvent { at_s: cluster.now() + gap_s, kills })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_decay_hits_target_fraction_in_expectation() {
        let sched = ExpDecaySchedule::new(0.01, 500);
        // survival after 500 iterations = (1-q)^500 = 0.99
        let survive = (1.0 - sched.per_iteration_prob).powi(500);
        assert!((survive - 0.99).abs() < 1e-12);
    }

    #[test]
    fn exp_decay_samples_roughly_one_percent() {
        let mut rng = Rng::seed_from_u64(7);
        let sched = ExpDecaySchedule::new(0.01, 500);
        let mut alive: Vec<usize> = (0..24576).collect();
        for _ in 0..500 {
            let dead = sched.sample(&mut rng, &alive);
            alive.retain(|r| !dead.contains(r));
        }
        let frac = 1.0 - alive.len() as f64 / 24576.0;
        // paper observed "up to 262 PEs failing" at 24576 (≈1.07 %)
        assert!(frac > 0.005 && frac < 0.02, "fraction {frac}");
    }

    #[test]
    fn uniform_kills_are_distinct_and_alive() {
        let mut rng = Rng::seed_from_u64(1);
        let alive: Vec<usize> = (0..100).step_by(2).collect();
        let k = uniform_kills(&mut rng, &alive, 10);
        assert_eq!(k.len(), 10);
        let set: std::collections::HashSet<_> = k.iter().collect();
        assert_eq!(set.len(), 10);
        for r in &k {
            assert!(alive.contains(r));
        }
    }

    #[test]
    fn uniform_kills_caps_at_pool() {
        let mut rng = Rng::seed_from_u64(2);
        assert_eq!(uniform_kills(&mut rng, &[1, 2, 3], 10).len(), 3);
    }

    #[test]
    fn node_failure_kills_whole_node() {
        let topo = Topology::new(100, 48);
        assert_eq!(node_failure(&topo, 1), (48..96).collect::<Vec<_>>());
        assert_eq!(node_failure(&topo, 2), (96..100).collect::<Vec<_>>());
    }

    #[test]
    fn mtbf_storm_gaps_have_exponential_mean() {
        // 64 PEs at 6400 s MTBF each -> cluster rate 1/100 s^-1, so the
        // mean inter-arrival gap is ~100 s (law of large numbers check)
        let cluster = Cluster::new_execution(64, 8);
        let mut storm = MtbfStorm::new(6400.0, 0.0, 42);
        let n = 4000;
        let mut total = 0.0;
        for _ in 0..n {
            let ev = storm.next_event(&cluster).unwrap();
            assert_eq!(ev.kills.len(), 1);
            assert!(cluster.is_alive(ev.kills[0]));
            total += ev.at_s - cluster.now();
        }
        let mean = total / n as f64;
        assert!((90.0..110.0).contains(&mean), "mean gap {mean}");
    }

    #[test]
    fn mtbf_storm_is_deterministic_and_rate_scales_with_survivors() {
        let mut a = MtbfStorm::new(1000.0, 0.25, 7);
        let mut b = MtbfStorm::new(1000.0, 0.25, 7);
        let mut cluster = Cluster::new_execution(32, 8);
        for _ in 0..20 {
            let ea = a.next_event(&cluster).unwrap();
            let eb = b.next_event(&cluster).unwrap();
            assert_eq!(ea, eb);
            cluster.kill(&ea.kills);
            if cluster.n_alive() < 2 {
                break;
            }
        }
        // once fewer than two members survive the storm ends
        let mut tiny = Cluster::new_execution(2, 2);
        tiny.kill(&[0]);
        assert!(a.next_event(&tiny).is_none());
    }

    #[test]
    fn mtbf_storm_node_bursts_take_whole_nodes() {
        let cluster = Cluster::new_execution(96, 48);
        let mut storm = MtbfStorm::new(100.0, 1.0, 3);
        let ev = storm.next_event(&cluster).unwrap();
        assert_eq!(ev.kills.len(), 48);
        let node = cluster.topology().node_of(ev.kills[0]);
        assert_eq!(ev.kills, node_failure(cluster.topology(), node));
    }
}
