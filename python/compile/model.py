"""L2: jitted step functions for the two paper applications.

These are the computations the Rust coordinator executes via PJRT on its
(simulated) PEs. Each returns a tuple — aot.py lowers them with
return_tuple=True so the Rust side unwraps a single tuple literal.

All hot-spot compute goes through the L1 Pallas kernels in kernels/;
everything else here is glue that XLA fuses around the kernel.
"""

import jax.numpy as jnp

from .kernels.kmeans import kmeans_assign
from .kernels.phylo import phylo_loglik


def kmeans_step(points, centers, *, tile=None):
    """One local k-means assignment step on a PE's point shard.

    Returns (sums (K,D), counts (K,), inertia (1,)). The Rust coordinator
    all-reduces sums/counts/inertia across PEs and then runs `kmeans_update`.
    """
    kwargs = {} if tile is None else {"tile": tile}
    sums, counts, inertia = kmeans_assign(points, centers, **kwargs)
    return (sums, counts, inertia.reshape((1,)))


def kmeans_update(sums, counts, old_centers):
    """Center update from globally all-reduced partials.

    Empty clusters keep their previous center (the paper's simple k-means
    keeps running regardless of cluster degeneracy).
    """
    safe = jnp.maximum(counts, 1.0)[:, None]
    new = sums / safe
    return (jnp.where(counts[:, None] > 0.0, new, old_centers),)


def phylo_step(clv_l, clv_r, p_l, p_r, freqs, weights, *, tile=None):
    """One CLV update + log-likelihood over a PE's site shard.

    Returns (clv (S,A), loglik (1,)). The coordinator all-reduces loglik
    (sum over site shards) — exactly RAxML-NG's per-iteration reduction.
    """
    kwargs = {} if tile is None else {"tile": tile}
    clv, ll = phylo_loglik(clv_l, clv_r, p_l, p_r, freqs, weights, **kwargs)
    return (clv, ll.reshape((1,)))
