//! In-tree replacements for the crates.io staples unavailable in this
//! offline environment (see Cargo.toml): a deterministic RNG, minimal JSON
//! and TOML parsers, and a micro-bench harness.

pub mod bench;
pub mod json;
pub mod rng;
pub mod toml;
