//! Property-based tests over randomized configurations (in-tree generator;
//! the environment has no proptest — see Cargo.toml note). Each property
//! runs against many random (p, r, blocks, s_pr, failures) tuples and
//! shrinks nothing but prints the failing seed, which reproduces exactly.

use restore::config::{RestoreConfig, ServerSelection};
use restore::restore::block::{BlockRange, RangeSet};
use restore::restore::distribution::Distribution;
use restore::restore::load::{load_all_requests, scatter_requests};
use restore::restore::permutation::{Feistel, RangePermutation};
use restore::restore::repair::RepairScheme;
use restore::restore::store::{assert_memory_invariant, HolderIndex};
use restore::restore::{LoadRequest, ReStore};
use restore::simnet::cluster::Cluster;
use restore::simnet::failure::MtbfStorm;
use restore::simnet::network::PhaseCost;
use restore::simnet::ulfm::{self, RankMap};
use restore::util::rng::Rng;
use restore::Error;

/// Random valid config: p in [2, 32], r | p, block size in {4..64},
/// perm ranges on/off.
fn random_config(rng: &mut Rng) -> RestoreConfig {
    loop {
        let p = 2 + rng.gen_index(31);
        let divisors: Vec<usize> = (1..=p).filter(|r| p % r == 0 && *r <= 8).collect();
        let r = divisors[rng.gen_index(divisors.len())];
        let bs = [4usize, 8, 16, 64][rng.gen_index(4)];
        let bpp_choices = [16usize, 32, 64, 96, 256];
        let bpp = bpp_choices[rng.gen_index(bpp_choices.len())];
        let s_pr = if rng.gen_bool(0.5) {
            let divs: Vec<usize> = (1..=bpp).filter(|s| bpp % s == 0).collect();
            Some(divs[rng.gen_index(divs.len())])
        } else {
            None
        };
        let sel = [ServerSelection::Random, ServerSelection::LeastLoaded, ServerSelection::Primary]
            [rng.gen_index(3)];
        if let Ok(cfg) = RestoreConfig::builder(p, bs, bpp)
            .replicas(r)
            .perm_range_blocks(s_pr)
            .seed(rng.next_u64())
            .server_selection(sel)
            .build()
        {
            return cfg;
        }
    }
}

fn shards_for(cfg: &RestoreConfig, rng: &mut Rng) -> Vec<Vec<u8>> {
    (0..cfg.world)
        .map(|_| {
            (0..cfg.blocks_per_pe * cfg.block_size).map(|_| rng.next_u64() as u8).collect()
        })
        .collect()
}

fn expected_bytes(shards: &[Vec<u8>], ranges: &RangeSet, cfg: &RestoreConfig) -> Vec<u8> {
    let bpp = cfg.blocks_per_pe as u64;
    let bs = cfg.block_size;
    let mut out = Vec::new();
    for r in ranges.ranges() {
        for x in r.start..r.end {
            let pe = (x / bpp) as usize;
            let off = ((x % bpp) as usize) * bs;
            out.extend_from_slice(&shards[pe][off..off + bs]);
        }
    }
    out
}

#[test]
fn prop_submit_satisfies_memory_invariant() {
    let mut rng = Rng::seed_from_u64(0xA11CE);
    for trial in 0..40 {
        let cfg = random_config(&mut rng);
        let mut cluster = Cluster::new_execution(cfg.world, 4);
        let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
        store.submit_virtual(&mut cluster).unwrap_or_else(|e| panic!("trial {trial}: {e}"));
        let dist = Distribution::new(&cfg);
        assert_memory_invariant(store.stores(), &dist);
    }
}

#[test]
fn prop_arbitrary_requests_roundtrip_bitexact_under_failures() {
    let mut rng = Rng::seed_from_u64(0xB0B);
    for trial in 0..25 {
        let cfg = random_config(&mut rng);
        let mut cluster = Cluster::new_execution(cfg.world, 4);
        let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
        let shards = shards_for(&cfg, &mut rng);
        store.submit(&mut cluster, &shards).unwrap();

        // kill up to r-1 PEs of each group — never an IDL
        let stride = cfg.world / cfg.replicas;
        let mut dead = Vec::new();
        for g in 0..stride {
            let kills = rng.gen_index(cfg.replicas); // 0..r-1
            for k in 0..kills {
                dead.push(g + k * stride);
            }
        }
        let dead: Vec<usize> =
            dead.into_iter().take(cluster.n_alive().saturating_sub(1)).collect();
        cluster.kill(&dead);

        // random requests from random alive PEs
        let survivors = cluster.survivors();
        let n = cfg.n_blocks();
        let n_reqs = 1 + rng.gen_index(4);
        let mut reqs: Vec<LoadRequest> = Vec::new();
        for _ in 0..n_reqs {
            let pe = survivors[rng.gen_index(survivors.len())];
            let n_ranges = 1 + rng.gen_index(3);
            let mut ranges: Vec<BlockRange> = Vec::new();
            for _ in 0..n_ranges {
                let a = rng.gen_u64_below(n);
                let len = 1 + rng.gen_u64_below((n - a).min(cfg.blocks_per_pe as u64 * 2));
                ranges.push(BlockRange::new(a, a + len));
            }
            reqs.push(LoadRequest { pe, ranges: RangeSet::new(ranges) });
        }

        let out = store
            .load(&mut cluster, &reqs)
            .unwrap_or_else(|e| panic!("trial {trial} (p={}, r={}): {e}", cfg.world, cfg.replicas));
        for (req, shard) in reqs.iter().zip(&out.shards) {
            assert_eq!(
                shard.bytes.as_deref().unwrap(),
                expected_bytes(&shards, &req.ranges, &cfg),
                "trial {trial}: wrong bytes for PE {}",
                req.pe
            );
        }
    }
}

#[test]
fn prop_scatter_recovery_covers_lost_shards_exactly() {
    let mut rng = Rng::seed_from_u64(0xC0C0A);
    for trial in 0..25 {
        let cfg = random_config(&mut rng);
        if cfg.replicas < 2 {
            continue;
        }
        let mut cluster = Cluster::new_execution(cfg.world, 4);
        let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
        store.submit_virtual(&mut cluster).unwrap();

        // kill a random set of < r PEs from distinct groups
        let stride = cfg.world / cfg.replicas;
        let mut dead: Vec<usize> = Vec::new();
        for g in 0..stride {
            if rng.gen_bool(0.3) {
                dead.push(g + rng.gen_index(cfg.replicas) * stride);
            }
        }
        dead.dedup();
        let dead: Vec<usize> =
            dead.into_iter().take(cluster.n_alive().saturating_sub(1)).collect();
        if dead.is_empty() {
            continue;
        }
        cluster.kill(&dead);

        let reqs = scatter_requests(&store, &cluster, &dead);
        let requested: u64 = reqs.iter().map(|r| r.ranges.total_blocks()).sum();
        assert_eq!(
            requested,
            dead.len() as u64 * cfg.blocks_per_pe as u64,
            "trial {trial}: scatter must request exactly the lost blocks"
        );
        // requests must be disjoint and land only on survivors
        let mut all: Vec<BlockRange> = Vec::new();
        for r in &reqs {
            assert!(cluster.is_alive(r.pe));
            all.extend(r.ranges.ranges().iter().copied());
        }
        let merged = RangeSet::new(all.clone());
        assert_eq!(merged.total_blocks(), requested, "trial {trial}: overlapping requests");
        store.load(&mut cluster, &reqs).unwrap();
    }
}

#[test]
fn prop_load_all_partitions_whole_id_space() {
    let mut rng = Rng::seed_from_u64(0xDEAD);
    for _trial in 0..30 {
        let cfg = random_config(&mut rng);
        let mut cluster = Cluster::new_execution(cfg.world, 4);
        let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
        store.submit_virtual(&mut cluster).unwrap();
        let reqs = load_all_requests(&store, &cluster);
        let all: Vec<BlockRange> =
            reqs.iter().flat_map(|r| r.ranges.ranges().iter().copied()).collect();
        let merged = RangeSet::new(all);
        assert_eq!(merged.total_blocks(), cfg.n_blocks());
        assert_eq!(merged.ranges().len(), 1, "must be a seamless partition");
        store.load(&mut cluster, &reqs).unwrap();
    }
}

/// Bidirectional holder-index consistency: `slots_of(pe)` (the reverse
/// pe → slots map that makes `drop_pe` O(slots held)) and `holders_of(s)`
/// (the forward slot → PEs view) must describe the same relation.
fn assert_holder_index_reverse_consistent(idx: &HolderIndex, world: usize, when: &str) {
    for pe in 0..world {
        for &s in idx.slots_of(pe) {
            assert!(
                idx.holders_of(s as usize).binary_search(&(pe as u32)).is_ok(),
                "{when}: reverse map lists slot {s} for PE {pe} but the forward view disagrees"
            );
        }
    }
    for s in 0..idx.slots() {
        for &pe in idx.holders_of(s) {
            assert!(
                idx.slots_of(pe as usize).binary_search(&(s as u32)).is_ok(),
                "{when}: forward view lists PE {pe} on slot {s} but the reverse map disagrees"
            );
        }
    }
}

/// Model-based reverse-map property: against a naive `BTreeSet` oracle,
/// random insert / remove / drop_pe interleavings (spanning the inline ↔
/// overflow spill boundary both ways) must keep both views of the
/// [`HolderIndex`] exact — including `remove`'s existed-bit.
#[test]
fn prop_holder_index_reverse_map_matches_btree_oracle_under_random_ops() {
    use std::collections::BTreeSet;

    let mut rng = Rng::seed_from_u64(0x2E58);
    for trial in 0..40 {
        let slots = 1 + rng.gen_index(24);
        // world > slots so spare-rank PEs beyond the slot count exercise the
        // grow-on-demand reverse map
        let world = slots + 1 + rng.gen_index(16);
        let mut idx = HolderIndex::new(slots);
        let mut model: Vec<BTreeSet<u32>> = vec![BTreeSet::new(); slots];
        for op in 0..400 {
            let roll = rng.gen_f64();
            if roll < 0.55 {
                let (s, pe) = (rng.gen_index(slots), rng.gen_index(world));
                idx.insert(s, pe);
                model[s].insert(pe as u32);
            } else if roll < 0.8 {
                let (s, pe) = (rng.gen_index(slots), rng.gen_index(world));
                let existed = idx.remove(s, pe);
                assert_eq!(
                    existed,
                    model[s].remove(&(pe as u32)),
                    "trial {trial} op {op}: remove({s}, {pe}) existed-bit"
                );
            } else {
                let pe = rng.gen_index(world);
                idx.drop_pe(pe);
                for set in &mut model {
                    set.remove(&(pe as u32));
                }
            }
        }
        for (s, set) in model.iter().enumerate() {
            let want: Vec<u32> = set.iter().copied().collect();
            assert_eq!(idx.holders_of(s), &want[..], "trial {trial}: slot {s} forward view");
        }
        for pe in 0..world {
            let want: Vec<u32> = (0..slots)
                .filter(|&s| model[s].contains(&(pe as u32)))
                .map(|s| s as u32)
                .collect();
            assert_eq!(idx.slots_of(pe), &want[..], "trial {trial}: PE {pe} reverse view");
        }
    }
}

/// The epoch-stamped sparse accumulator pooled across phases and
/// topologies must charge every phase identically to a fresh
/// densely-zeroed accumulator over random message/fragment mixes —
/// including empty phases, self-messages (free), and reuse across
/// shrinking and regrowing topologies — while walking only the entries
/// the phase touched.
#[test]
fn prop_pooled_sparse_accumulator_charges_like_fresh_dense() {
    use restore::config::NetworkConfig;
    use restore::simnet::network::Accumulator;
    use restore::simnet::topology::Topology;

    let mut rng = Rng::seed_from_u64(0xACC0);
    let mut pooled = Accumulator::default();
    for trial in 0..25 {
        let p = 2 + rng.gen_index(300);
        let ppn = [1usize, 2, 4, 8, 48][rng.gen_index(5)];
        let topo = Topology::new(p, ppn);
        let net = NetworkConfig::default();
        for phase in 0..8 {
            pooled.reset(&net, &topo);
            let mut fresh = Accumulator::new(&net, &topo);
            let n_msgs = rng.gen_index(24);
            let mut endpoints = 0usize;
            for _ in 0..n_msgs {
                let (src, dst) = (rng.gen_index(p), rng.gen_index(p));
                let bytes = rng.gen_u64_below(1 << 16);
                pooled.msg(src, dst, bytes);
                fresh.msg(src, dst, bytes);
                endpoints += 2;
            }
            for _ in 0..rng.gen_index(6) {
                let pe = rng.gen_index(p);
                let count = 1 + rng.gen_u64_below(16);
                pooled.frag(pe, count);
                fresh.frag(pe, count);
                endpoints += 1;
            }
            assert_eq!(
                pooled.finish_reset(),
                fresh.finish(),
                "trial {trial} phase {phase} (p={p}, ppn={ppn})"
            );
            let (tp, tn) = pooled.last_touched();
            assert!(
                tp <= endpoints.min(p) && tn <= endpoints.min(topo.nodes()),
                "trial {trial} phase {phase}: touched ({tp}, {tn}) exceeds the \
                 {endpoints} endpoints the phase visited"
            );
        }
    }
}

#[test]
fn prop_holder_index_matches_store_scan_under_kill_repair_storms() {
    // After ANY sequence of kills, repairs, and dead-store reclaims, the
    // incrementally maintained reverse holder index must exactly equal a
    // from-scratch scan of every PE store — and a repeated repair after
    // the same failures must move nothing (idempotence).
    let mut rng = Rng::seed_from_u64(0x1DE7);
    for trial in 0..20 {
        let cfg = random_config(&mut rng);
        let mut cluster = Cluster::new_execution(cfg.world, 4);
        let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
        store.submit_virtual(&mut cluster).unwrap();
        let check = |store: &ReStore, when: &str| {
            let rebuilt = HolderIndex::rebuild(store.stores(), store.distribution());
            assert_eq!(
                *store.holder_index(),
                rebuilt,
                "trial {trial} (p={}, r={}): index drifted {when}",
                cfg.world,
                cfg.replicas
            );
            assert_holder_index_reverse_consistent(
                store.holder_index(),
                store.stores().len(),
                &format!("trial {trial} {when}"),
            );
        };
        check(&store, "after submit");

        let scheme = if rng.gen_bool(0.5) {
            RepairScheme::DoubleHashing
        } else {
            RepairScheme::FeistelWalk
        };
        for wave in 0..3 {
            if cluster.n_alive() <= 1 {
                break;
            }
            // kill a random non-empty subset of survivors (leave one alive)
            let survivors = cluster.survivors();
            let kills = 1 + rng.gen_index((survivors.len() - 1).max(1));
            let dead: Vec<usize> = (0..kills)
                .map(|_| survivors[rng.gen_index(survivors.len())])
                .collect();
            let dead: Vec<usize> =
                dead.into_iter().take(cluster.n_alive().saturating_sub(1)).collect();
            cluster.kill(&dead);

            // occasionally reclaim the dead PEs' stores before repairing
            // (acknowledge_shrink doubles as the pure reclaim when no
            // shrink happened — the epoch is unchanged here)
            if rng.gen_bool(0.3) {
                store.acknowledge_shrink(&cluster).unwrap();
                check(&store, &format!("after acknowledge_shrink in wave {wave}"));
            }

            let first = store.repair_replicas(&mut cluster, scheme).unwrap();
            check(&store, &format!("after repair wave {wave}"));
            let second = store.repair_replicas(&mut cluster, scheme).unwrap();
            assert_eq!(
                second.transfers, 0,
                "trial {trial} wave {wave}: second repair moved {} units (first moved {})",
                second.transfers, first.transfers
            );
            check(&store, &format!("after idempotent re-repair wave {wave}"));
        }
    }
}

#[test]
fn prop_acknowledge_shrink_reclaims_only_dead_stores() {
    let cfg = RestoreConfig::builder(4, 8, 16).replicas(2).build().unwrap();
    let mut cluster = Cluster::new_execution(4, 2);
    let mut store = ReStore::new(cfg, &cluster).unwrap();
    store.submit_virtual(&mut cluster).unwrap();
    // no failures: a pure no-op (idempotent reclaim)
    store.acknowledge_shrink(&cluster).unwrap();
    for pe in 0..4 {
        assert_eq!(store.stores()[pe].slices().len(), 2, "alive store must be untouched");
    }
    cluster.kill(&[1]);
    store.acknowledge_shrink(&cluster).unwrap();
    assert_eq!(store.stores()[1].slices().len(), 0, "dead store must be reclaimed");
    for pe in [0usize, 2, 3] {
        assert_eq!(store.stores()[pe].slices().len(), 2);
    }
    assert_eq!(
        *store.holder_index(),
        HolderIndex::rebuild(store.stores(), store.distribution())
    );
    store.acknowledge_shrink(&cluster).unwrap(); // idempotent
    // it also adopts the communicator epoch after a shrink
    let (_map, _cost) = restore::simnet::ulfm::shrink(&mut cluster);
    assert_ne!(store.epoch(), cluster.epoch());
    store.acknowledge_shrink(&cluster).unwrap();
    assert_eq!(store.epoch(), cluster.epoch());
}

#[test]
fn prop_rebalance_minimality_index_and_fast_path_over_random_kill_waves() {
    // For random configurations and random kill waves — including the
    // non-dividing survivor counts the balanced unequal-slice layout now
    // admits — the §IV-B rebalance must (a) migrate exactly the bytes
    // whose destination did not already hold them (minimality, checked
    // against a store-diff oracle), (b) leave the incrementally-built
    // holder index equal to a from-scratch rebuild, (c) restore r alive
    // holders in deterministic positions for every slot (the load fast
    // path), and (d) keep every byte loadable.
    let mut rng = Rng::seed_from_u64(0x5EBA1A);
    let mut ran = 0usize;
    let mut ran_unequal = 0usize;
    for trial in 0..60 {
        let p = [8usize, 12, 16, 24, 32][rng.gen_index(5)];
        let divisors: Vec<usize> = (2..=p).filter(|r| p % r == 0 && *r <= 4).collect();
        let r = divisors[rng.gen_index(divisors.len())];
        let bpp = [32usize, 64, 128][rng.gen_index(3)];
        let s_pr = if rng.gen_bool(0.5) {
            let divs: Vec<usize> = [4usize, 8, 16, 32].iter().copied().filter(|s| bpp % s == 0).collect();
            Some(divs[rng.gen_index(divs.len())])
        } else {
            None
        };
        let cfg = RestoreConfig::builder(p, 8, bpp)
            .replicas(r)
            .perm_range_blocks(s_pr)
            .seed(rng.next_u64())
            .build()
            .unwrap();
        let n = cfg.n_blocks();
        let stride = p / r;

        // every p' >= max(stride, r) is feasible now (balanced unequal
        // slices need only r <= p'; p' >= stride keeps a <= r-1 per-group
        // kill pattern IDL-free)
        let candidates: Vec<usize> = (stride.max(r)..p).collect();
        if candidates.is_empty() {
            continue;
        }
        let p_new = candidates[rng.gen_index(candidates.len())];

        let mut cluster = Cluster::new_execution(p, 4);
        let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
        store.submit_virtual(&mut cluster).unwrap();

        // kill p - p' PEs, at most r-1 per §IV-D group (no IDL)
        let mut per_group = vec![0usize; stride];
        let mut killed = 0usize;
        while killed < p - p_new {
            let survivors = cluster.survivors();
            let pe = survivors[rng.gen_index(survivors.len())];
            if per_group[pe % stride] < r - 1 {
                per_group[pe % stride] += 1;
                cluster.kill(&[pe]);
                killed += 1;
            }
        }

        // store-diff oracle input: what each survivor held before
        let pre_held: Vec<Vec<BlockRange>> = (0..p)
            .map(|pe| store.stores()[pe].slices().iter().map(|s| s.range).collect())
            .collect();

        let (_failed, map, _cost) = restore::simnet::ulfm::recover(&mut cluster);
        assert!(store.can_rebalance(&cluster), "trial {trial}: p'={p_new} must be feasible");
        let report = store
            .rebalance(&mut cluster, &map)
            .unwrap_or_else(|e| panic!("trial {trial} (p={p}, r={r}, p'={p_new}): {e}"));
        ran += 1;
        if n % p_new as u64 != 0 {
            ran_unequal += 1;
        }
        assert_eq!(report.new_world, p_new);

        // (a) minimality: migrated bytes == sum over survivors of new
        // bytes they did not already hold
        let mut expected = 0u64;
        for &pe in &map.new_to_old {
            for s in store.stores()[pe].slices() {
                let mut missing = s.range.len();
                for old in &pre_held[pe] {
                    if let Some(overlap) = s.range.intersect(old) {
                        missing -= overlap.len();
                    }
                }
                expected += missing * 8;
            }
        }
        assert_eq!(
            report.migrated_bytes, expected,
            "trial {trial} (p={p}, r={r}, p'={p_new}): migration is not minimal"
        );

        // (b) incremental index == from-scratch rebuild at the new world
        let dist = store.distribution().clone();
        assert_eq!(
            *store.holder_index(),
            HolderIndex::rebuild(store.stores(), &dist),
            "trial {trial}: holder index drifted through rebalance"
        );

        // (c) fast path: every slot has exactly r alive holders in the
        // deterministic §IV-A positions of the new layout; slice lengths
        // follow the balanced ⌊n/p'⌋/⌈n/p'⌉ partition
        let q = n / p_new as u64;
        let rem = n % p_new as u64;
        for slot in 0..p_new {
            let holders = store.holder_index().holders_of(slot);
            assert_eq!(holders.len(), r, "trial {trial}: slot {slot}");
            let mut det: Vec<u32> = (0..r)
                .map(|k| store.cluster_rank(dist.holder(dist.slice_start(slot), k)) as u32)
                .collect();
            det.sort_unstable();
            assert_eq!(holders, &det[..], "trial {trial}: slot {slot} off the §IV-A set");
            for &h in holders {
                assert!(cluster.is_alive(h as usize));
            }
            let want_len = q + ((slot as u64) < rem) as u64;
            assert_eq!(dist.slice_len(slot), want_len, "trial {trial}: slot {slot} length");
        }
        // ...and dead stores were reclaimed; each survivor holds exactly
        // its r balanced slices (r·n/p' blocks when p' | n)
        for (j, &pe) in map.new_to_old.iter().enumerate() {
            let blocks: u64 = store.stores()[pe].slices().iter().map(|s| s.range.len()).sum();
            let expect: u64 = (0..r).map(|k| dist.stored_slice(j, k).len()).sum();
            assert_eq!(blocks, expect, "trial {trial}: PE {pe}");
        }
        for pe in 0..p {
            if !cluster.is_alive(pe) {
                let blocks: u64 =
                    store.stores()[pe].slices().iter().map(|s| s.range.len()).sum();
                assert_eq!(blocks, 0, "trial {trial}: dead PE {pe} still holds data");
            }
        }

        // (d) the whole ID space still loads (cost-model mode)
        let survivors = cluster.survivors();
        let ns = survivors.len() as u64;
        let reqs: Vec<LoadRequest> = survivors
            .iter()
            .enumerate()
            .filter_map(|(j, &pe)| {
                let s = (j as u64 * n) / ns;
                let e = ((j as u64 + 1) * n) / ns;
                (s < e).then(|| LoadRequest {
                    pe,
                    ranges: RangeSet::new(vec![BlockRange::new(s, e)]),
                })
            })
            .collect();
        store
            .load(&mut cluster, &reqs)
            .unwrap_or_else(|e| panic!("trial {trial}: post-rebalance load failed: {e}"));
    }
    assert!(ran >= 10, "only {ran} feasible rebalance trials ran — generator too narrow");
    assert!(
        ran_unequal >= 5,
        "only {ran_unequal} unequal-slice (non-dividing p') trials ran — generator too narrow"
    );
}

/// Reshaped layouts must equal a fresh balanced construction at the new
/// world for random (p, p', r, s_pr) tuples — shrink (p' < p), identity
/// (p' = p), AND grow (p' > p, the substitution/re-grow direction) all
/// route through the same `reshaped()` — including non-dividing p' and
/// chained reshapes, and the slice geometry must satisfy its closed-form
/// invariants (⌊n/p'⌋/⌈n/p'⌉ lengths, prefix-sum boundaries, slice_of
/// inverse, distinct holders).
#[test]
fn prop_reshaped_matches_fresh_balanced_over_random_tuples() {
    let mut rng = Rng::seed_from_u64(0xBA1A2CED);
    for trial in 0..50 {
        let cfg = random_config(&mut rng);
        let p = cfg.world;
        let r = cfg.replicas;
        let old = Distribution::new(&cfg);
        let n = cfg.n_blocks();
        // any p' in [r, 2p] is feasible now (2p <= n since bpp >= 16)
        let upper = (2 * p).min(n as usize);
        let p_new = r + rng.gen_index(upper - r + 1);
        assert!(old.reshape_feasible(p_new), "trial {trial}: p'={p_new} (r={r})");
        let got = old.reshaped(p_new).unwrap();
        let want = Distribution::new_balanced(
            p_new,
            n,
            r,
            cfg.perm_range_blocks.map(|s| s as u64),
            cfg.seed,
            cfg.placement_offset,
        )
        .unwrap();

        // geometry invariants
        let q = n / p_new as u64;
        let rem = n % p_new as u64;
        let mut prefix = 0u64;
        for i in 0..p_new {
            assert_eq!(got.slice_start(i), prefix, "trial {trial}: slice_start({i})");
            let want_len = q + ((i as u64) < rem) as u64;
            assert_eq!(got.slice_len(i), want_len, "trial {trial}: slice_len({i})");
            assert_eq!(want.slice_len(i), want_len);
            prefix += want_len;
        }
        assert_eq!(prefix, n, "trial {trial}: slices must partition [0, n)");

        // golden equality with the fresh construction on sampled blocks
        for _ in 0..64 {
            let y = rng.gen_u64_below(n);
            assert_eq!(got.slice_of(y), want.slice_of(y), "trial {trial}: slice_of({y})");
            assert!(got.slice_start(got.slice_of(y)) <= y && y < got.slice_end(got.slice_of(y)));
            assert_eq!(got.permute_block(y % n), want.permute_block(y % n));
            assert_eq!(got.unpermute_block(y), want.unpermute_block(y));
            let mut seen = std::collections::HashSet::new();
            for k in 0..r {
                let h = got.holder(y, k);
                assert_eq!(h, want.holder(y, k), "trial {trial}: holder({y}, {k})");
                assert!(seen.insert(h), "trial {trial}: duplicate holder {h} for y={y}");
                assert!(got.stored_slice(h, k).contains(y), "trial {trial}: inverse view");
            }
        }

        // chained reshape: a second reshape (either direction) from the
        // already-unequal layout must still match the fresh construction
        // at the final world
        {
            let p_final = r + rng.gen_index(upper - r + 1);
            let chained = got.reshaped(p_final).unwrap();
            let fresh = Distribution::new_balanced(
                p_final,
                n,
                r,
                cfg.perm_range_blocks.map(|s| s as u64),
                cfg.seed,
                cfg.placement_offset,
            )
            .unwrap();
            for _ in 0..32 {
                let y = rng.gen_u64_below(n);
                assert_eq!(chained.slice_of(y), fresh.slice_of(y), "trial {trial} chained");
                for k in 0..r {
                    assert_eq!(chained.holder(y, k), fresh.holder(y, k), "trial {trial} chained");
                }
            }
        }
    }
}

/// The acceptance scenario: a 16 → 13 → 7 chained shrink (both steps
/// non-dividing) in execution mode — each rebalance must be golden-equal
/// to a fresh balanced layout (stores byte-identical modulo the rank
/// translation, holder index translation-equal) and minimal against the
/// store-diff oracle, and every byte must stay loadable.
#[test]
fn prop_chained_16_13_7_shrink_golden_and_minimal() {
    let cfg = RestoreConfig::builder(16, 8, 64)
        .replicas(4)
        .perm_range_blocks(Some(16))
        .seed(0x16137)
        .build()
        .unwrap();
    let mut cluster = Cluster::new_execution(16, 4);
    let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
    let mut rng = Rng::seed_from_u64(0x16137);
    let shards = shards_for(&cfg, &mut rng);
    store.submit(&mut cluster, &shards).unwrap();
    let global: Vec<u8> = shards.iter().flatten().copied().collect();
    let bs = cfg.block_size;
    let n = cfg.n_blocks();

    // one wave: kill the given cluster ranks, recover, rebalance, verify
    let wave = |cluster: &mut Cluster,
                    store: &mut ReStore,
                    kills: &[usize],
                    p_want: usize,
                    tag: &str| {
        let pre_held: Vec<Vec<BlockRange>> = (0..16)
            .map(|pe| store.stores()[pe].slices().iter().map(|s| s.range).collect())
            .collect();
        cluster.kill(kills);
        let (_f, map, _c) = restore::simnet::ulfm::recover(cluster);
        assert!(store.can_rebalance(cluster), "{tag}: p'={p_want} must rebalance");
        let report = store.rebalance(cluster, &map).unwrap();
        assert_eq!(report.new_world, p_want, "{tag}");

        // minimality vs the store-diff oracle
        let mut expected = 0u64;
        for &pe in &map.new_to_old {
            for s in store.stores()[pe].slices() {
                let mut missing = s.range.len();
                for old in &pre_held[pe] {
                    if let Some(overlap) = s.range.intersect(old) {
                        missing -= overlap.len();
                    }
                }
                expected += missing * bs as u64;
            }
        }
        assert_eq!(report.migrated_bytes, expected, "{tag}: migration not minimal");
        assert_eq!(
            report.kept_bytes + report.migrated_bytes,
            4 * n * bs as u64,
            "{tag}: kept + migrated must cover the stored volume"
        );

        // golden: every survivor's stores equal the fresh balanced layout
        let dist = store.distribution().clone();
        assert_eq!(n % p_want as u64 == 0, dist.equal_slices(), "{tag}");
        for (j, &pe) in map.new_to_old.iter().enumerate() {
            let mut want: Vec<(BlockRange, Vec<u8>)> = (0..4)
                .map(|k| {
                    let range = dist.stored_slice(j, k);
                    let mut buf = Vec::new();
                    for y in range.start..range.end {
                        let x = dist.unpermute_block(y) as usize;
                        buf.extend_from_slice(&global[x * bs..(x + 1) * bs]);
                    }
                    (range, buf)
                })
                .collect();
            want.sort_by_key(|(r, _)| r.start);
            let ours = store.stores()[pe].slices();
            assert_eq!(ours.len(), want.len(), "{tag}: new rank {j}");
            for (g, (wrange, wbytes)) in ours.iter().zip(&want) {
                assert_eq!(g.range, *wrange, "{tag}: new rank {j}");
                let restore::restore::store::SliceBuf::Real(gb) = &g.buf else {
                    panic!("{tag}: execution mode must store real bytes");
                };
                assert_eq!(gb, wbytes, "{tag}: new rank {j} slice {wrange:?}");
            }
        }
        // holder index: translation-equal to a from-scratch rebuild
        assert_eq!(
            *store.holder_index(),
            HolderIndex::rebuild(store.stores(), &dist),
            "{tag}: holder index drifted"
        );
    };

    // 16 -> 13: kill 3 ranks from distinct §IV-D groups (stride 4)
    wave(&mut cluster, &mut store, &[0, 5, 10], 13, "wave 16->13");
    // 13 -> 7: kill 6 consecutive new ranks (= the 6 lowest survivors);
    // holders sit at stride ⌊13/4⌋ = 3, so a window of 6 takes at most 2
    // of any slot's 4 holders — never an IDL
    let kills: Vec<usize> = cluster.survivors()[..6].to_vec();
    wave(&mut cluster, &mut store, &kills, 7, "wave 13->7");

    // every byte of the original data still loads bit-exactly
    let survivors = cluster.survivors();
    let ns = survivors.len() as u64;
    let reqs: Vec<LoadRequest> = survivors
        .iter()
        .enumerate()
        .filter_map(|(j, &pe)| {
            let s = (j as u64 * n) / ns;
            let e = ((j as u64 + 1) * n) / ns;
            (s < e).then(|| LoadRequest {
                pe,
                ranges: RangeSet::new(vec![BlockRange::new(s, e)]),
            })
        })
        .collect();
    let out = store.load(&mut cluster, &reqs).unwrap();
    for (req, shard) in reqs.iter().zip(&out.shards) {
        assert_eq!(
            shard.bytes.as_deref().unwrap(),
            expected_bytes(&shards, &req.ranges, &cfg),
            "post-chain load mismatch for PE {}",
            req.pe
        );
    }
}

#[test]
fn prop_feistel_bijection_random_domains() {
    let mut rng = Rng::seed_from_u64(0xFE15);
    for _ in 0..50 {
        let domain = 1 + rng.gen_u64_below(1 << 14);
        let f = Feistel::new(domain, rng.next_u64());
        // spot-check bijection by sampling (full check for small domains)
        if domain <= 512 {
            let mut seen = vec![false; domain as usize];
            for i in 0..domain {
                let y = f.apply(i);
                assert!(y < domain && !seen[y as usize]);
                seen[y as usize] = true;
            }
        } else {
            for _ in 0..200 {
                let i = rng.gen_u64_below(domain);
                let y = f.apply(i);
                assert!(y < domain);
                assert_eq!(f.invert(y), i);
            }
        }
    }
}

#[test]
fn prop_distribution_holder_consistency() {
    // stored_slice and holder must be inverse views of each other for
    // random configs.
    let mut rng = Rng::seed_from_u64(0x90D);
    for _ in 0..40 {
        let cfg = random_config(&mut rng);
        let dist = Distribution::new(&cfg);
        for _ in 0..50 {
            let y = rng.gen_u64_below(dist.n_blocks());
            for k in 0..dist.replicas() {
                let pe = dist.holder(y, k);
                assert!(dist.stored_slice(pe, k).contains(y));
            }
        }
    }
}

/// Every rank map the ulfm primitives mint — shrink, substitute, AND
/// grow — must round-trip `validate_against` at the epoch it was minted,
/// equal the communicator it installed, compose across chained MTBF storm
/// waves in whatever order the spare pool admits, and go stale the moment
/// the next event lands, surfacing as the dedicated
/// `Error::StaleRankMap` rather than a silent pass.
#[test]
fn prop_substitute_and_grow_maps_validate_and_go_stale_across_storm_waves() {
    let mut rng = Rng::seed_from_u64(0x57A1E);
    let mut substituted = 0usize;
    let mut regrown = 0usize;
    for trial in 0..40 {
        let p = 4 + rng.gen_index(29); // 4..=32
        let ppn = [2usize, 4, 8][rng.gen_index(3)];
        let spares = rng.gen_index(p + 1); // 0..=p
        let mut cluster = Cluster::with_spares(p, ppn, spares);
        let mut storm = MtbfStorm::new(1.0e4, 0.2, rng.next_u64());
        let mut prev: Option<RankMap> = None;
        for wave in 0..4 {
            let Some(ev) = storm.next_event(&cluster) else { break };
            assert!(ev.at_s >= cluster.now(), "trial {trial}: storm time ran backwards");
            assert!(!ev.kills.is_empty(), "trial {trial}: empty storm event");
            let gap = PhaseCost { sim_time_s: ev.at_s - cluster.now(), ..Default::default() };
            cluster.advance(&gap);
            cluster.kill(&ev.kills);

            // the previous wave's map is stale the moment this wave lands
            if let Some(m) = prev.take() {
                assert!(
                    matches!(m.validate_against(&cluster), Err(Error::StaleRankMap(_))),
                    "trial {trial} wave {wave}: pre-wave map survived validation"
                );
            }

            let (failed, _cost) = ulfm::agree(&mut cluster);
            assert_eq!(failed, cluster.failed(), "trial {trial}: agreement must be cumulative");

            let n_dead = cluster.comm().iter().filter(|&&r| !cluster.is_alive(r)).count();
            assert!(n_dead >= 1, "trial {trial}: storm kills must hit communicator members");
            let map = if n_dead <= cluster.n_spares() && rng.gen_bool(0.5) {
                let world_before = cluster.comm().len();
                let (m, _) = ulfm::substitute(&mut cluster).unwrap();
                assert_eq!(m.new_world(), world_before, "trial {trial}: substitute must preserve p");
                substituted += 1;
                m
            } else {
                let (m, _) = ulfm::shrink(&mut cluster);
                if cluster.n_spares() > 0 && rng.gen_bool(0.5) {
                    m.validate_against(&cluster)
                        .unwrap_or_else(|e| panic!("trial {trial}: shrink map invalid: {e}"));
                    let extra = 1 + rng.gen_index(cluster.n_spares());
                    let (g, _) = ulfm::grow(&mut cluster, extra).unwrap();
                    assert_eq!(g.new_world(), m.new_world() + extra, "trial {trial}");
                    // the pre-grow shrink map is itself stale now
                    assert!(
                        matches!(m.validate_against(&cluster), Err(Error::StaleRankMap(_))),
                        "trial {trial} wave {wave}: shrink map survived the grow"
                    );
                    regrown += 1;
                    g
                } else {
                    m
                }
            };
            map.validate_against(&cluster)
                .unwrap_or_else(|e| panic!("trial {trial} wave {wave}: fresh map invalid: {e}"));
            // the map IS the installed communicator (round-trip identity)
            assert_eq!(map.new_to_old, cluster.comm(), "trial {trial} wave {wave}");
            prev = Some(map);
        }
    }
    assert!(substituted >= 10, "only {substituted} substitute waves ran — generator too narrow");
    assert!(regrown >= 10, "only {regrown} re-grow waves ran — generator too narrow");
}

/// Scrub under random corruption waves: quarantine + §IV-E repair must
/// leave the incrementally maintained holder index equal to a from-scratch
/// [`HolderIndex::rebuild`], the §IV-C memory invariant intact, and every
/// byte of the dataset loadable and golden — whether the wave was scrubbed
/// in one full-budget wrap or in `p` single-slot budgeted steps.
#[test]
fn prop_scrub_quarantine_repair_restores_index_and_bytes_under_corruption_waves() {
    use restore::restore::DatasetId;

    let mut rng = Rng::seed_from_u64(0x5C2B);
    let mut trials = 0usize;
    while trials < 12 {
        let cfg = random_config(&mut rng);
        // r >= 3 keeps every slot repairable: a wave injects at most r - 1
        // strikes, so at least one copy of any slot survives un-rotted.
        if cfg.replicas < 3 {
            continue;
        }
        trials += 1;
        let mut cluster = Cluster::new_execution(cfg.world, 4);
        let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
        let shards = shards_for(&cfg, &mut rng);
        store.submit(&mut cluster, &shards).unwrap();

        for wave in 0..4 {
            let ctx = || format!("trial {trials} wave {wave} (p={}, r={})", cfg.world, cfg.replicas);
            let n_strikes = 1 + rng.gen_index(cfg.replicas - 1);
            for _ in 0..n_strikes {
                let pe = rng.gen_index(cfg.world);
                let resident = store.stores()[pe].real_bytes();
                if resident == 0 {
                    continue;
                }
                let byte = rng.gen_u64_below(resident);
                store.corrupt_bit(pe, byte, rng.gen_index(8) as u8);
            }

            // even waves: one full-budget wrap; odd waves: p single-slot
            // steps (budget 0 still makes progress) composing a full circle
            let (mut quarantined, mut repaired, mut irrecoverable) = (0usize, 0usize, 0usize);
            if wave % 2 == 0 {
                let rep = store.scrub(&mut cluster, u64::MAX).unwrap();
                assert!(rep.wrapped, "{}", ctx());
                quarantined += rep.quarantined;
                repaired += rep.repaired;
                irrecoverable += rep.irrecoverable;
            } else {
                for _ in 0..cfg.world {
                    let rep = store.scrub(&mut cluster, 0).unwrap();
                    quarantined += rep.quarantined;
                    repaired += rep.repaired;
                    irrecoverable += rep.irrecoverable;
                }
            }
            assert_eq!(irrecoverable, 0, "{}: <= r-1 strikes can never eat a whole slot", ctx());
            assert_eq!(
                repaired, quarantined,
                "{}: repair must re-create exactly the quarantined copies",
                ctx()
            );

            // the incrementally maintained index equals a from-scratch scan
            assert_eq!(
                *store.holder_index(),
                HolderIndex::rebuild(store.stores(), store.distribution()),
                "{}: index drifted",
                ctx()
            );
            assert_memory_invariant(store.stores(), store.distribution());

            // a second wrap over the repaired store finds nothing
            let clean = store.scrub(&mut cluster, u64::MAX).unwrap();
            assert_eq!(clean.corrupt_blocks, 0, "{}: corruption survived the scrub", ctx());

            // golden oracle: every byte reloads exactly as submitted
            let n = cfg.n_blocks();
            let ranges = RangeSet::new(vec![BlockRange::new(0, n)]);
            let reqs = vec![LoadRequest { pe: 0, ranges: ranges.clone() }];
            let out = store
                .dataset_mut(DatasetId::FIRST)
                .unwrap()
                .load(&mut cluster, &reqs)
                .unwrap_or_else(|e| panic!("{}: reload failed: {e}", ctx()));
            assert_eq!(
                out.shards[0].bytes.as_deref().unwrap(),
                expected_bytes(&shards, &ranges, &cfg),
                "{}: repaired bytes differ from golden",
                ctx()
            );
        }
    }
}

/// A dataset's layout is *complete* and *golden*: the reverse holder index
/// equals a from-scratch rebuild, the §IV-C memory invariant holds, every
/// slot of the current distribution has its full r copies resident, and
/// every stored block byte-equals the originally submitted shards. A torn
/// (partially installed) layout fails at least one of these.
fn assert_complete_and_golden(ds: &restore::restore::Dataset, shards: &[Vec<u8>], when: &str) {
    let dist = ds.distribution();
    assert_eq!(
        *ds.holder_index(),
        HolderIndex::rebuild(ds.stores(), dist),
        "{when}: index torn"
    );
    assert_memory_invariant(ds.stores(), dist);
    let bs = ds.config().block_size;
    let bpp = ds.config().blocks_per_pe as u64;
    let r = ds.config().replicas;
    for slot in 0..dist.world() {
        let range = dist.slice_range(slot);
        if range.is_empty() {
            continue;
        }
        let holders = ds.holder_index().holders_of(slot);
        assert_eq!(holders.len(), r, "{when}: slot {slot} copy set torn");
        for &pe in holders {
            let bytes = ds.stores()[pe as usize]
                .read(range.start, range.len())
                .unwrap_or_else(|| panic!("{when}: slot {slot} copy on PE {pe} missing"));
            for (i, y) in (range.start..range.end).enumerate() {
                let x = dist.unpermute_block(y);
                let exp = &shards[(x / bpp) as usize][((x % bpp) as usize) * bs..][..bs];
                assert_eq!(&bytes[i * bs..(i + 1) * bs], exp, "{when}: block {x} rotted");
            }
        }
    }
}

/// The torn-recovery invariant: a kill injected at EVERY step boundary of
/// the fused reshape aborts the wave with a stale-map/epoch error and
/// leaves every dataset with either its complete old layout or the
/// complete new one — never a torn mix — after which a retry against a
/// freshly minted map converges. Chained across waves, so each wave's
/// starting state is the previous wave's post-retry layout.
#[test]
fn prop_mid_reshape_kill_leaves_complete_old_or_new_layouts_across_waves() {
    use restore::restore::{DatasetId, ReshapeStep};

    const P: usize = 20;
    const BPP: usize = 32;
    const BS: usize = 8;
    let cfg = RestoreConfig::builder(P, BS, BPP).replicas(4).build().unwrap();
    let cfg2 = RestoreConfig::builder(P, BS, BPP).replicas(2).build().unwrap();
    let mut cluster = Cluster::new_execution(P, 4);
    let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
    let ds2 = store.create_dataset(cfg2.clone(), &cluster).unwrap();
    let mut rng = Rng::seed_from_u64(0x70B4);
    let shards = shards_for(&cfg, &mut rng);
    let shards2 = shards_for(&cfg2, &mut rng);
    store.submit(&mut cluster, &shards).unwrap();
    store.dataset_mut(ds2).unwrap().submit(&mut cluster, &shards2).unwrap();

    let boundaries = [
        ReshapeStep::Validated,
        ReshapeStep::Planned,
        ReshapeStep::Charged,
        ReshapeStep::Installed(0),
        ReshapeStep::Installed(1),
    ];
    for (wave, &target) in boundaries.iter().enumerate() {
        // the wave's ordinary failure, then the shrink handshake
        let victim = *cluster.survivors().first().unwrap();
        cluster.kill(&[victim]);
        let (map, _cost) = ulfm::shrink(&mut cluster);

        let mut fired = false;
        let res = store.rebalance_or_acknowledge_all_with_faults(
            &mut cluster,
            &map,
            &mut |step, cl| {
                if step == target && !fired {
                    fired = true;
                    let extra = *cl.survivors().last().unwrap();
                    cl.kill(&[extra]);
                }
            },
        );
        assert!(fired, "wave {wave}: boundary {target:?} never reached");
        let err = res.expect_err("a mid-reshape kill must abort the wave");
        assert!(
            matches!(err, Error::StaleRankMap(_) | Error::StaleEpoch { .. }),
            "wave {wave}: aborted with the wrong error: {err}"
        );

        // no torn state: whichever side of the install each dataset was
        // on, its layout is complete and golden
        let when = format!("wave {wave} after abort at {target:?}");
        assert_complete_and_golden(store.dataset(DatasetId::FIRST).unwrap(), &shards, &when);
        assert_complete_and_golden(store.dataset(ds2).unwrap(), &shards2, &when);

        // the retry against a freshly minted map converges un-injected
        let (map2, _cost) = ulfm::shrink(&mut cluster);
        store
            .rebalance_or_acknowledge_all(&mut cluster, &map2)
            .unwrap_or_else(|e| panic!("wave {wave}: retry failed: {e}"));
        let when = format!("wave {wave} after retry");
        assert_complete_and_golden(store.dataset(DatasetId::FIRST).unwrap(), &shards, &when);
        assert_complete_and_golden(store.dataset(ds2).unwrap(), &shards2, &when);

        // and the load path agrees: every block of both datasets reloads
        for (id, golden, c) in
            [(DatasetId::FIRST, &shards, &cfg), (ds2, &shards2, &cfg2)]
        {
            let pe = cluster.survivors()[0];
            let ranges = RangeSet::new(vec![BlockRange::new(0, c.n_blocks())]);
            let reqs = vec![LoadRequest { pe, ranges: ranges.clone() }];
            let out = store.dataset_mut(id).unwrap().load(&mut cluster, &reqs).unwrap();
            assert_eq!(
                out.shards[0].bytes.as_deref().unwrap(),
                expected_bytes(golden, &ranges, c),
                "wave {wave}: dataset {id:?} lost bytes"
            );
        }
    }
}

#[test]
fn prop_idl_simulation_never_below_r() {
    let mut rng = Rng::seed_from_u64(0x1D1);
    for _ in 0..30 {
        let r = 1 + rng.gen_u64_below(4);
        let groups = 1 + rng.gen_u64_below(64);
        let p = r * groups;
        let f = restore::restore::idl::simulate_failures_until_idl(p, r, &mut rng);
        assert!(f >= r, "IDL after {f} failures with r={r}");
        assert!(f <= p);
    }
}
