//! ULFM-style fault-tolerance operations over the simulated cluster.
//!
//! Mirrors the recovery sequence of the paper's applications (§VI-A/§VI-C):
//! after a failure is detected, the survivors run an *agreement* on the set
//! of failed ranks (`MPIX_Comm_agree`-like) and then *shrink* the
//! communicator (`MPIX_Comm_shrink`-like), producing a dense re-ranking.
//! The paper could not benchmark real ULFM (it was too unstable — they
//! filed the bug) and replaced these with functionally similar MPI calls;
//! we model their cost with a latency term that matches the observation in
//! §VI-C that "the overall running time increases ... mainly due to MPI
//! operations used to restore a functioning communicator".
//!
//! Beyond the paper's shrink-only recovery, this module also models the
//! other half of the "Shrink or Substitute" design space: [`substitute`]
//! seats spares from the cluster's pool in the dead ranks' communicator
//! positions (world size preserved), and [`grow`] widens the communicator
//! (`p → p + extra`) so a shrunk job can elastically reclaim capacity.
//! Both carry an `MPI_Comm_spawn`-style cost term on top of the
//! reconfiguration collectives. The policy layer that chooses between
//! them lives in `restore::policy`.

use crate::error::{Error, Result};
use crate::simnet::cluster::Cluster;
use crate::simnet::network::PhaseCost;

/// Fixed agreement/shrink overhead (connection teardown, group bookkeeping).
pub const SHRINK_BASE_S: f64 = 1.0e-3;
/// Per-log2(p) cost of the agreement + shrink collectives.
pub const SHRINK_PER_LOG_S: f64 = 1.5e-3;
/// Fixed cost of activating spares (`MPI_Comm_spawn`-style process
/// acquisition + connection setup — an order of magnitude above the
/// shrink base, matching the "Shrink or Substitute" observation that
/// substitution pays more up front to preserve the world size).
pub const SPAWN_BASE_S: f64 = 8.0e-3;
/// Per-log2(p) cost of merging the spawned ranks into the communicator.
pub const SPAWN_PER_LOG_S: f64 = 2.0e-3;

/// Rank translation between the pre-failure and post-shrink communicators.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RankMap {
    /// old rank -> new rank (None for failed PEs).
    pub old_to_new: Vec<Option<usize>>,
    /// new rank -> old rank.
    pub new_to_old: Vec<usize>,
    /// Cluster epoch this map describes — stamped by the `ulfm` primitive
    /// right after its epoch bump, so staleness is diagnosable from the
    /// map alone (every [`Error::StaleRankMap`] message carries the
    /// observed map epoch vs the cluster's expected one).
    pub epoch: u64,
}

impl RankMap {
    /// Identity map over `p` alive ranks (epoch 0 — a fresh cluster).
    pub fn identity(p: usize) -> Self {
        RankMap {
            old_to_new: (0..p).map(Some).collect(),
            new_to_old: (0..p).collect(),
            epoch: 0,
        }
    }

    pub fn new_world(&self) -> usize {
        self.new_to_old.len()
    }

    /// Verify this map describes `cluster`'s *current* communicator: every
    /// new rank maps to an alive cluster rank, the alive set is covered
    /// exactly once, and the two directions agree. Note new ranks need NOT
    /// preserve old-rank order — [`shrink`] maps are monotone by
    /// construction, but a [`substitute`] map seats a (high-numbered)
    /// spare in the dead rank's position and a [`grow`] map appends
    /// spares past the old world. The recovery policies
    /// (`restore::policy`, `ReStore::rebalance_or_acknowledge`) call this
    /// before ANY layout decision — a stale map (from an earlier epoch)
    /// silently addressing dead ranks is the bug class this guards
    /// against. Failures surface as the dedicated
    /// [`Error::StaleRankMap`].
    pub fn validate_against(&self, cluster: &Cluster) -> Result<()> {
        // Every failure message carries the observed-vs-expected epoch
        // pair: equal epochs with a dead member means "failures landed
        // since the reconfiguration" (kills alone do not bump the epoch);
        // unequal epochs mean the map is from an older reconfiguration.
        let err = |m: String| {
            Err(Error::StaleRankMap(format!(
                "{m} (map observed at epoch {}, cluster expects epoch {})",
                self.epoch,
                cluster.epoch()
            )))
        };
        if self.epoch != cluster.epoch() {
            return err("map is from an earlier reconfiguration".to_string());
        }
        if self.old_to_new.len() != cluster.world() {
            return err(format!(
                "rank map covers {} old ranks, cluster world is {}",
                self.old_to_new.len(),
                cluster.world()
            ));
        }
        if self.new_world() != cluster.n_alive() {
            return err(format!(
                "rank map has {} new ranks, cluster has {} survivors (stale map?)",
                self.new_world(),
                cluster.n_alive()
            ));
        }
        for (new, &old) in self.new_to_old.iter().enumerate() {
            if !cluster.is_alive(old) {
                return err(format!("rank map: new rank {new} maps to dead PE {old}"));
            }
            if self.old_to_new.get(old).copied().flatten() != Some(new) {
                return err(format!("rank map: directions disagree at old rank {old}"));
            }
        }
        for (old, &new) in self.old_to_new.iter().enumerate() {
            if new.is_some() != cluster.is_alive(old) {
                return err(format!(
                    "rank map: old rank {old} mapping disagrees with its alive state"
                ));
            }
        }
        Ok(())
    }
}

/// Build the RankMap for a prospective communicator membership list.
fn map_from_comm(world: usize, comm: &[usize]) -> RankMap {
    let mut old_to_new = vec![None; world];
    for (new, &old) in comm.iter().enumerate() {
        old_to_new[old] = Some(new);
    }
    // The caller stamps the epoch once its `establish_comm` bumped it.
    RankMap { old_to_new, new_to_old: comm.to_vec(), epoch: 0 }
}

/// Agreement on the failed set: every survivor learns which PEs died.
/// Cost: a fault-tolerant allreduce over a bitmap (3 log p rounds — the
/// two-phase commit structure of `MPIX_Comm_agree`).
pub fn agree(cluster: &mut Cluster) -> (Vec<usize>, PhaseCost) {
    let p = cluster.n_alive().max(2) as f64;
    let rounds = 3 * p.log2().ceil() as u64;
    let cost = PhaseCost::latency(cluster.network(), rounds);
    cluster.advance(&cost);
    // Exact-capacity collect off the allocation-free iterator: ONE heap
    // allocation per agreement regardless of world size (asserted by the
    // counting-allocator suite) — the storm driver calls this every wave.
    let n_failed = cluster.failed_iter().count();
    let mut failed = Vec::with_capacity(n_failed);
    failed.extend(cluster.failed_iter());
    (failed, cost)
}

/// Shrink the communicator: surviving members keep their relative order
/// and get dense new ranks (exactly what
/// `MPI_Comm_split(comm, alive, old_rank)` does in the paper's simulation
/// methodology — `MPIX_Comm_shrink` preserves rank order the same way).
pub fn shrink(cluster: &mut Cluster) -> (RankMap, PhaseCost) {
    let new_comm: Vec<usize> =
        cluster.comm().iter().copied().filter(|&r| cluster.is_alive(r)).collect();
    let mut map = map_from_comm(cluster.world(), &new_comm);
    let p = new_comm.len().max(2) as f64;
    let cost = PhaseCost {
        sim_time_s: SHRINK_BASE_S + SHRINK_PER_LOG_S * p.log2(),
        bottleneck_msgs: 2 * p.log2().ceil() as u64,
        ..Default::default()
    };
    cluster.advance(&cost);
    cluster.establish_comm(new_comm);
    map.epoch = cluster.epoch();
    (map, cost)
}

/// Substitute every failed communicator member with a spare from the pool,
/// preserving the world size: each dead rank's communicator position is
/// taken over by an activated spare (lowest-numbered spares first), so all
/// surviving members keep their ranks — the FTHP-MPI/"Shrink or
/// Substitute" standby-replacement policy. Costs a spawn term
/// ([`SPAWN_BASE_S`]/[`SPAWN_PER_LOG_S`]) on top of the shrink-style
/// reconfiguration collectives.
///
/// Errors with [`Error::Config`] — without mutating the cluster — if no
/// communicator member is dead or the pool has fewer healthy spares than
/// there are failures (callers degrade to [`shrink`]).
pub fn substitute(cluster: &mut Cluster) -> Result<(RankMap, PhaseCost)> {
    let n_dead = cluster.comm().iter().filter(|&&r| !cluster.is_alive(r)).count();
    if n_dead == 0 {
        return Err(Error::Config("substitute: no failed ranks in the communicator".into()));
    }
    if cluster.n_spares() < n_dead {
        return Err(Error::Config(format!(
            "substitute: spare pool exhausted (need {n_dead}, have {})",
            cluster.n_spares()
        )));
    }
    let replacements: Vec<usize> = cluster.spares_iter().take(n_dead).collect();
    let mut new_comm = cluster.comm().to_vec();
    let mut next = replacements.iter().copied();
    for slot in new_comm.iter_mut() {
        if !cluster.is_alive(*slot) {
            *slot = next.next().expect("one replacement per dead member");
        }
    }
    for &s in &replacements {
        cluster.activate_spare(s);
    }
    let p = new_comm.len().max(2) as f64;
    let cost = PhaseCost {
        sim_time_s: SHRINK_BASE_S + SPAWN_BASE_S + (SHRINK_PER_LOG_S + SPAWN_PER_LOG_S) * p.log2(),
        bottleneck_msgs: 3 * p.log2().ceil() as u64,
        ..Default::default()
    };
    cluster.advance(&cost);
    let mut map = map_from_comm(cluster.world(), &new_comm);
    cluster.establish_comm(new_comm);
    map.epoch = cluster.epoch();
    Ok((map, cost))
}

/// Grow the communicator by `extra` spares appended past the current
/// members (`p → p + extra`) — the elastic re-grow half of the policy
/// space: a job that shrank through a failure storm reclaims capacity once
/// spares return. Requires a fully-alive communicator (run [`shrink`] or
/// [`substitute`] first) and `extra` healthy spares; errors with
/// [`Error::Config`] otherwise, without mutating the cluster.
pub fn grow(cluster: &mut Cluster, extra: usize) -> Result<(RankMap, PhaseCost)> {
    if extra == 0 {
        return Err(Error::Config("grow: extra must be > 0".into()));
    }
    if cluster.comm().iter().any(|&r| !cluster.is_alive(r)) {
        return Err(Error::Config(
            "grow requires a fully-alive communicator; run shrink or substitute first".into(),
        ));
    }
    if cluster.n_spares() < extra {
        return Err(Error::Config(format!(
            "grow: spare pool exhausted (need {extra}, have {})",
            cluster.n_spares()
        )));
    }
    let added: Vec<usize> = cluster.spares_iter().take(extra).collect();
    for &s in &added {
        cluster.activate_spare(s);
    }
    let mut new_comm = cluster.comm().to_vec();
    new_comm.extend(added);
    let p = new_comm.len().max(2) as f64;
    let cost = PhaseCost {
        sim_time_s: SHRINK_BASE_S + SPAWN_BASE_S + (SHRINK_PER_LOG_S + SPAWN_PER_LOG_S) * p.log2(),
        bottleneck_msgs: 3 * p.log2().ceil() as u64,
        ..Default::default()
    };
    cluster.advance(&cost);
    let mut map = map_from_comm(cluster.world(), &new_comm);
    cluster.establish_comm(new_comm);
    map.epoch = cluster.epoch();
    Ok((map, cost))
}

/// Full recovery sequence after failures are noticed: agree + shrink.
pub fn recover(cluster: &mut Cluster) -> (Vec<usize>, RankMap, PhaseCost) {
    let (failed, c1) = agree(cluster);
    let (map, c2) = shrink(cluster);
    (failed, map, c1.then(c2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrink_densifies_ranks_in_order() {
        let mut c = Cluster::new_execution(8, 4);
        c.kill(&[2, 5]);
        let (map, cost) = shrink(&mut c);
        assert_eq!(map.new_world(), 6);
        assert_eq!(map.new_to_old, vec![0, 1, 3, 4, 6, 7]);
        assert_eq!(map.old_to_new[2], None);
        assert_eq!(map.old_to_new[3], Some(2));
        assert_eq!(map.old_to_new[7], Some(5));
        assert!(cost.sim_time_s > SHRINK_BASE_S);
        assert_eq!(c.epoch(), 1);
        map.validate_against(&c).unwrap();
    }

    #[test]
    fn stale_rank_map_is_rejected() {
        let mut c = Cluster::new_execution(8, 4);
        c.kill(&[2]);
        let (map, _) = shrink(&mut c);
        map.validate_against(&c).unwrap();
        // a later failure makes the map stale — surfaced as the dedicated
        // StaleRankMap variant, not a generic Config error
        c.kill(&[5]);
        assert!(matches!(
            map.validate_against(&c),
            Err(Error::StaleRankMap(_))
        ));
        let (map2, _) = shrink(&mut c);
        map2.validate_against(&c).unwrap();
        assert_eq!(c.epoch(), 2);
        // identity map over the wrong world
        assert!(RankMap::identity(4).validate_against(&c).is_err());
    }

    #[test]
    fn rank_maps_carry_their_epoch_and_errors_name_the_pair() {
        let mut c = Cluster::new_execution(8, 4);
        c.kill(&[2]);
        let (map, _) = shrink(&mut c);
        assert_eq!(map.epoch, c.epoch());
        c.kill(&[5]);
        let (map2, _) = shrink(&mut c);
        assert_eq!(map2.epoch, 2);
        // every staleness message carries observed-vs-expected epochs
        let msg = map.validate_against(&c).unwrap_err().to_string();
        assert!(msg.contains("observed at epoch 1"), "{msg}");
        assert!(msg.contains("expects epoch 2"), "{msg}");
        // equal epochs + a fresh kill: the pair is still reported
        c.kill(&[7]);
        let msg = map2.validate_against(&c).unwrap_err().to_string();
        assert!(msg.contains("observed at epoch 2"), "{msg}");
        assert!(msg.contains("expects epoch 2"), "{msg}");
    }

    #[test]
    fn agree_reports_failed_set() {
        let mut c = Cluster::new_execution(16, 4);
        c.kill(&[0, 15]);
        let (failed, cost) = agree(&mut c);
        assert_eq!(failed, vec![0, 15]);
        assert!(cost.sim_time_s > 0.0);
    }

    #[test]
    fn recover_composes_costs() {
        let mut c = Cluster::new_execution(16, 4);
        c.kill(&[3]);
        let t0 = c.now();
        let (failed, map, cost) = recover(&mut c);
        assert_eq!(failed, vec![3]);
        assert_eq!(map.new_world(), 15);
        assert!((c.now() - t0 - cost.sim_time_s).abs() < 1e-12);
    }

    #[test]
    fn identity_map() {
        let m = RankMap::identity(4);
        assert_eq!(m.old_to_new[3], Some(3));
        assert_eq!(m.new_world(), 4);
    }

    #[test]
    fn substitute_seats_spares_in_dead_positions() {
        let mut c = Cluster::with_spares(8, 4, 3);
        c.kill(&[3, 6]);
        let (map, cost) = substitute(&mut c).unwrap();
        // world size preserved; survivors keep their ranks; lowest spares
        // take over the dead positions in order
        assert_eq!(map.new_world(), 8);
        assert_eq!(map.new_to_old, vec![0, 1, 2, 8, 4, 5, 9, 7]);
        assert_eq!(map.old_to_new[3], None);
        assert_eq!(map.old_to_new[8], Some(3));
        assert_eq!(map.old_to_new[9], Some(6));
        assert_eq!(map.old_to_new[0], Some(0));
        assert_eq!(c.n_alive(), 8);
        assert_eq!(c.n_spares(), 1);
        assert_eq!(c.epoch(), 1);
        assert!(cost.sim_time_s > SPAWN_BASE_S);
        map.validate_against(&c).unwrap();
    }

    #[test]
    fn substitute_requires_failures_and_spares() {
        let mut c = Cluster::with_spares(4, 2, 1);
        assert!(substitute(&mut c).is_err()); // nothing failed
        c.kill(&[0, 2]);
        let err = substitute(&mut c); // 2 dead, 1 spare
        assert!(err.is_err());
        // failed preconditions must not mutate the cluster
        assert_eq!(c.n_spares(), 1);
        assert_eq!(c.epoch(), 0);
        c.kill(&[3]); // now 3 dead, still 1 spare -> degrade path is shrink
        let (map, _) = shrink(&mut c);
        assert_eq!(map.new_to_old, vec![1]);
    }

    #[test]
    fn grow_appends_spares_past_the_current_members() {
        let mut c = Cluster::with_spares(8, 4, 4);
        c.kill(&[2]);
        let (smap, _) = shrink(&mut c);
        assert_eq!(smap.new_world(), 7);
        let (gmap, cost) = grow(&mut c, 2).unwrap();
        assert_eq!(gmap.new_world(), 9);
        assert_eq!(gmap.new_to_old, vec![0, 1, 3, 4, 5, 6, 7, 8, 9]);
        assert_eq!(gmap.old_to_new[8], Some(7));
        assert_eq!(c.epoch(), 2);
        assert_eq!(c.n_spares(), 2);
        assert!(cost.sim_time_s > SPAWN_BASE_S);
        gmap.validate_against(&c).unwrap();
        // the pre-grow shrink map is now stale
        assert!(matches!(smap.validate_against(&c), Err(Error::StaleRankMap(_))));
    }

    #[test]
    fn grow_rejects_dead_members_and_empty_pool() {
        let mut c = Cluster::with_spares(4, 2, 1);
        c.kill(&[1]);
        assert!(grow(&mut c, 1).is_err()); // dead member still seated
        let (_, _) = shrink(&mut c);
        assert!(grow(&mut c, 0).is_err());
        assert!(grow(&mut c, 2).is_err()); // only 1 spare
        assert_eq!(c.epoch(), 1); // failed grows don't bump the epoch
        grow(&mut c, 1).unwrap();
        assert_eq!(c.n_alive(), 4);
        assert_eq!(c.n_spares(), 0);
    }

    #[test]
    fn substitution_chain_composes_across_waves() {
        // wave 1: substitute; wave 2: kill a former spare AND an original
        // rank — the next substitute must reseat both positions
        let mut c = Cluster::with_spares(6, 3, 4);
        c.kill(&[1]);
        let (m1, _) = substitute(&mut c).unwrap();
        assert_eq!(m1.new_to_old, vec![0, 6, 2, 3, 4, 5]);
        c.kill(&[6, 4]);
        let (m2, _) = substitute(&mut c).unwrap();
        assert_eq!(m2.new_to_old, vec![0, 7, 2, 3, 8, 5]);
        assert_eq!(c.epoch(), 2);
        m2.validate_against(&c).unwrap();
        assert!(matches!(m1.validate_against(&c), Err(Error::StaleRankMap(_))));
    }

    #[test]
    fn shrink_after_substitute_keeps_comm_order() {
        // substitution seats spare 8 at position 1; a later shrink of rank 4
        // must preserve the substituted communicator order, not re-sort it
        let mut c = Cluster::with_spares(6, 3, 2);
        c.kill(&[1]);
        let (_, _) = substitute(&mut c).unwrap();
        c.kill(&[4]);
        let (map, _) = shrink(&mut c);
        assert_eq!(map.new_to_old, vec![0, 6, 2, 3, 5]);
        map.validate_against(&c).unwrap();
    }

    #[test]
    fn shrink_cost_grows_slowly_with_p() {
        let mut small = Cluster::new_execution(48, 48);
        let mut big = Cluster::new_execution(24576, 48);
        let (_, cs) = shrink(&mut small);
        let (_, cb) = shrink(&mut big);
        assert!(cb.sim_time_s > cs.sim_time_s);
        assert!(cb.sim_time_s < cs.sim_time_s * 4.0);
    }
}
