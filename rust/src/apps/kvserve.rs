//! Zipf KV serving trace: the driver behind `benches/kv.rs`.
//!
//! Models the ROADMAP's "millions of users" serving shape over the
//! cost-model substrate: a pool of frontend PEs issues skewed point reads
//! (Zipf(θ) — a handful of hot keys dominate) in batches against one or
//! more datasets, interleaved with point-write rounds, while an MTBF
//! failure storm kills PEs mid-trace and a [`RecoveryPolicy`] repairs the
//! store between batches. Per-get *simulated* latency is recorded for
//! every read — a cache hit costs one local block copy, a miss costs its
//! batch's fused request + data all-to-all — so the trace reports the
//! serving numbers the bench publishes: p50/p99 latency, cache hit rate,
//! message/byte totals (for the batched-vs-unbatched ablation), and the
//! recovery *blast radius* (how many of the reads issued right after a
//! failure miss, because the epoch bump stranded every cached entry).

use crate::config::RestoreConfig;
use crate::error::{Error, Result};
use crate::restore::kv::{KvBatch, KvStore, Zipf};
use crate::restore::policy::RecoveryPolicy;
use crate::restore::registry::DatasetId;
use crate::restore::resubmit::Overlap;
use crate::restore::ReStore;
use crate::simnet::cluster::Cluster;
use crate::simnet::failure::MtbfStorm;
use crate::simnet::network::PhaseCost;
use crate::util::rng::Rng;

/// Shape of one Zipf serving trace (see [`run_zipf_trace`]).
#[derive(Debug, Clone)]
pub struct KvTraceConfig {
    /// World size.
    pub p: usize,
    /// PEs per node (failure-burst and topology granularity).
    pub ppn: usize,
    pub blocks_per_pe: usize,
    pub block_size: usize,
    pub replicas: usize,
    /// Datasets served (≥ 1); gets spread round-robin across them.
    pub datasets: usize,
    /// Total point gets to serve.
    pub ops: usize,
    /// Gets fused per [`KvBatch`] (1 = the unbatched ablation).
    pub batch: usize,
    /// Zipf skew θ (≈ 0.99 is the YCSB default; higher = hotter head).
    pub theta: f64,
    /// Per-PE cache slots (0 = the uncached ablation).
    pub cache_capacity: usize,
    /// Requester pool: gets are issued by the first `frontends` alive PEs
    /// (0 = every alive PE is a frontend).
    pub frontends: usize,
    /// Issue a write round every this many batches (0 = read-only trace).
    pub write_every_batches: usize,
    /// Point writes per write round.
    pub writes_per_round: usize,
    /// Per-PE MTBF driving the failure storm (0 = no failures).
    pub pe_mtbf_s: f64,
    /// If no failure fired by the trace midpoint, jump the clock to the
    /// next storm event until this many have fired — keeps blast-radius
    /// measurements meaningful on short traces.
    pub min_failures: usize,
    /// Gets counted into the blast-radius window after each recovery.
    pub post_failure_window: usize,
    /// Inter-batch arrival gap (simulated seconds) — what lets the storm
    /// clock make progress relative to per-op service times.
    pub think_s: f64,
    pub seed: u64,
}

impl KvTraceConfig {
    /// A read-heavy serving mix at world size `p`: Zipf(1.1) reads in
    /// batches of 256 from 8 frontend PEs over 2 datasets, a 64-key write
    /// round every 16 batches, r = 4.
    pub fn read_heavy(p: usize, ops: usize, seed: u64) -> KvTraceConfig {
        KvTraceConfig {
            p,
            ppn: 48,
            blocks_per_pe: 64,
            block_size: 256,
            replicas: 4,
            datasets: 2,
            ops,
            batch: 256,
            theta: 1.1,
            cache_capacity: 16384,
            frontends: 8,
            write_every_batches: 16,
            writes_per_round: 64,
            pe_mtbf_s: 0.0,
            min_failures: 0,
            post_failure_window: 2048,
            think_s: 2e-4,
            seed,
        }
    }
}

/// What a [`run_zipf_trace`] run served and cost.
#[derive(Debug, Clone, Default)]
pub struct KvTraceReport {
    pub gets: u64,
    pub hits: u64,
    pub misses: u64,
    pub hit_rate: f64,
    /// Median / 99th-percentile simulated per-get latency (seconds).
    pub p50_s: f64,
    pub p99_s: f64,
    /// Network totals across every read batch (hit serving adds none).
    pub total_msgs: u64,
    pub total_bytes: u64,
    pub puts: u64,
    /// Write rounds skipped because a holder was dead / a slot was lost
    /// at issue time (the app keeps its authoritative copy and retries).
    pub skipped_puts: u64,
    pub failures: u64,
    pub recoveries: u64,
    pub recovery_time_s: f64,
    /// Gets issued inside the post-recovery windows, and how many of them
    /// missed (the cache-invalidation blast radius).
    pub blast_gets: u64,
    pub blast_misses: u64,
    pub stale_serves: u64,
    pub sim_time_s: f64,
}

impl KvTraceReport {
    /// Miss fraction inside the post-recovery windows.
    pub fn blast_radius(&self) -> f64 {
        if self.blast_gets == 0 {
            0.0
        } else {
            self.blast_misses as f64 / self.blast_gets as f64
        }
    }

    /// Fraction of issued writes that had to be skipped.
    pub fn skipped_put_rate(&self) -> f64 {
        let total = self.puts + self.skipped_puts;
        if total == 0 {
            0.0
        } else {
            self.skipped_puts as f64 / total as f64
        }
    }
}

/// Drive one Zipf serving trace over cost-model datasets and report the
/// serving numbers. Deterministic for a given config (storm included).
pub fn run_zipf_trace(
    cfg: &KvTraceConfig,
    policy: &mut dyn RecoveryPolicy,
) -> Result<KvTraceReport> {
    assert!(cfg.datasets >= 1 && cfg.batch >= 1 && cfg.ops >= 1);
    let rcfg = RestoreConfig::builder(cfg.p, cfg.block_size, cfg.blocks_per_pe)
        .replicas(cfg.replicas)
        .build()?;
    let mut cluster = Cluster::new_execution(cfg.p, cfg.ppn);
    let mut store = ReStore::new(rcfg.clone(), &cluster)?;
    store.submit_virtual(&mut cluster)?;
    let mut ids = vec![DatasetId::FIRST];
    for _ in 1..cfg.datasets {
        let id = store.create_dataset(rcfg.clone(), &cluster)?;
        store.dataset_mut(id)?.submit_virtual(&mut cluster)?;
        ids.push(id);
    }
    let mut kv = KvStore::new();
    for &id in &ids {
        kv.register(&store, id, cfg.cache_capacity)?;
    }

    let n_keys = (cfg.p * cfg.blocks_per_pe) as usize;
    let zipf = Zipf::new(n_keys, cfg.theta);
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut storm = (cfg.pe_mtbf_s > 0.0)
        .then(|| MtbfStorm::new(cfg.pe_mtbf_s, 0.1, cfg.seed ^ 0x5707_11));
    let mut pending = storm.as_mut().and_then(|s| s.next_event(&cluster));

    let mut rep = KvTraceReport::default();
    let mut lat: Vec<f64> = Vec::with_capacity(cfg.ops);
    let mut blast_left = 0usize;
    let mut served = 0usize;
    let mut batches = 0usize;
    while served < cfg.ops {
        // Fire every storm event the clock has reached; if the trace is
        // half done without a failure, jump to the next event so short
        // traces still measure a blast radius.
        while let Some(ev) = pending.as_ref() {
            let due = ev.at_s <= cluster.now();
            let force = rep.failures < cfg.min_failures as u64 && served >= cfg.ops / 2;
            if !(due || force) {
                break;
            }
            if !due {
                cluster.tick_compute(ev.at_s - cluster.now());
            }
            let ev = pending.take().expect("checked above");
            cluster.kill(&ev.kills);
            rep.failures += ev.kills.len() as u64;
            let outcome = policy.recover(&mut cluster, &mut store)?;
            rep.recoveries += 1;
            rep.recovery_time_s += outcome.recovery_time_s;
            blast_left = cfg.post_failure_window;
            pending = storm.as_mut().and_then(|s| s.next_event(&cluster));
        }

        cluster.tick_compute(cfg.think_s);
        let alive = cluster.alive_ranks();
        let pool = if cfg.frontends == 0 {
            alive.len()
        } else {
            cfg.frontends.min(alive.len())
        };
        let mut batch = KvBatch::new();
        let k = cfg.batch.min(cfg.ops - served);
        for i in 0..k {
            let pe = alive[rng.gen_index(pool)] as usize;
            let id = ids[(served + i) % ids.len()];
            batch.get(id, pe, zipf.sample(&mut rng));
        }
        let out = kv.execute(&mut store, &mut cluster, &batch)?;
        served += k;
        batches += 1;
        rep.gets += k as u64;
        rep.hits += out.hits;
        rep.misses += out.misses;
        rep.total_msgs += out.cost.total_msgs;
        rep.total_bytes += out.cost.total_bytes;
        let hit_lat =
            PhaseCost::local_copy(cluster.network(), cfg.block_size as u64).sim_time_s;
        let miss_lat = out.request_cost.sim_time_s + out.data_cost.sim_time_s;
        for g in &out.gets {
            lat.push(if g.hit { hit_lat } else { miss_lat });
            if blast_left > 0 {
                blast_left -= 1;
                rep.blast_gets += 1;
                if !g.hit {
                    rep.blast_misses += 1;
                }
            }
        }

        // Write round: commit a Zipf key set as one dirty resubmit.
        if cfg.write_every_batches > 0
            && batches % cfg.write_every_batches == 0
            && cfg.writes_per_round > 0
        {
            let keys: Vec<u64> =
                (0..cfg.writes_per_round).map(|_| zipf.sample(&mut rng)).collect();
            let id = ids[batches / cfg.write_every_batches % ids.len()];
            match kv.put_virtual(&mut store, &mut cluster, id, &keys, Overlap::Blocking) {
                Ok(_) => rep.puts += keys.len() as u64,
                Err(Error::DeadPe(_))
                | Err(Error::IrrecoverableDataLoss { .. })
                | Err(Error::ResubmitAborted { .. }) => {
                    rep.skipped_puts += keys.len() as u64;
                }
                Err(e) => return Err(e),
            }
        }
    }

    lat.sort_unstable_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    rep.p50_s = lat[lat.len() / 2];
    rep.p99_s = lat[(lat.len() * 99 / 100).min(lat.len() - 1)];
    rep.hit_rate = if rep.gets == 0 { 0.0 } else { rep.hits as f64 / rep.gets as f64 };
    for &id in &ids {
        rep.stale_serves += kv.stats(id)?.stale_serves;
    }
    rep.sim_time_s = cluster.now();
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::restore::policy::Shrink;

    #[test]
    fn read_heavy_trace_caches_and_batches() {
        let cfg = KvTraceConfig { ops: 4096, ..KvTraceConfig::read_heavy(96, 4096, 11) };
        let rep = run_zipf_trace(&cfg, &mut Shrink).unwrap();
        assert_eq!(rep.gets, 4096);
        assert_eq!(rep.hits + rep.misses, rep.gets);
        assert!(rep.hit_rate > 0.3, "Zipf(1.1) from 8 frontends should hit: {}", rep.hit_rate);
        assert!(rep.p50_s > 0.0 && rep.p99_s >= rep.p50_s);
        assert_eq!(rep.stale_serves, 0);
        assert!(rep.puts > 0);
    }

    #[test]
    fn trace_is_deterministic() {
        let cfg = KvTraceConfig { ops: 2048, ..KvTraceConfig::read_heavy(96, 2048, 5) };
        let a = run_zipf_trace(&cfg, &mut Shrink).unwrap();
        let b = run_zipf_trace(&cfg, &mut Shrink).unwrap();
        assert_eq!(a.total_msgs, b.total_msgs);
        assert_eq!(a.total_bytes, b.total_bytes);
        assert_eq!((a.hits, a.misses), (b.hits, b.misses));
        assert_eq!(a.p99_s, b.p99_s);
    }

    #[test]
    fn failures_mid_trace_recover_and_blast_the_cache() {
        let mut cfg = KvTraceConfig::read_heavy(96, 8192, 23);
        cfg.pe_mtbf_s = 96.0 * 0.05;
        cfg.min_failures = 1;
        let rep = run_zipf_trace(&cfg, &mut Shrink).unwrap();
        assert!(rep.failures >= 1, "min_failures must force at least one event");
        assert!(rep.recoveries >= 1);
        assert!(rep.recovery_time_s > 0.0);
        assert!(rep.blast_gets > 0);
        // the epoch bump stranded the cache: post-recovery reads miss more
        assert!(rep.blast_misses > 0);
        assert_eq!(rep.stale_serves, 0);
    }

    #[test]
    fn unbatched_ablation_sends_more_messages() {
        let mut a = KvTraceConfig::read_heavy(96, 2048, 7);
        a.cache_capacity = 0;
        let mut b = a.clone();
        b.batch = 1;
        let batched = run_zipf_trace(&a, &mut Shrink).unwrap();
        let unbatched = run_zipf_trace(&b, &mut Shrink).unwrap();
        assert!(
            batched.total_msgs < unbatched.total_msgs,
            "fused batches must send strictly fewer messages: {} vs {}",
            batched.total_msgs,
            unbatched.total_msgs
        );
        assert!(batched.total_bytes <= unbatched.total_bytes);
    }
}
