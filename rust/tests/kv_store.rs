//! KV serving layer integration tests.
//!
//! * The **golden message/byte contract** of `KvBatch`: a fused batch of
//!   k point gets charges exactly ONE request message and ONE data
//!   message when the keys land on one (requester, server) pair — total
//!   message count strictly below the 2k a sequential serving of the same
//!   gets sends — while request bytes (k · 24 B piece descriptors) and
//!   data bytes (k · block_size) are EXACTLY equal to sequential. Keys
//!   are chosen distinct and pairwise non-adjacent: adjacent keys would
//!   coalesce into one descriptor and legitimately *undercut* sequential
//!   bytes, which is a real extra saving but not the identity under test.
//!
//! * A **property test** driving random get / batched-get / put / scan /
//!   kill+recover / repair+invalidate interleavings against two identical
//!   stores: one served through a cached `KvStore`, one through an
//!   uncached fresh-load oracle. Every served byte must be identical
//!   between the two AND match a locally tracked expected image; after
//!   every step the cache is audited (zero mismatched entries, zero stale
//!   serves) — the invariant that a hit can only happen at matching
//!   epoch + version + generation.

use restore::config::RestoreConfig;
use restore::error::Error;
use restore::restore::repair::RepairScheme;
use restore::restore::{DatasetId, KvBatch, KvStore, Overlap, ReStore};
use restore::simnet::cluster::Cluster;
use restore::simnet::ulfm;
use restore::util::rng::Rng;

fn flat_image(n_blocks: u64, bs: usize, salt: u8) -> Vec<u8> {
    (0..n_blocks as usize * bs)
        .map(|i| (i as u8).wrapping_mul(29).wrapping_add(salt))
        .collect()
}

fn shards_of(store: &ReStore, bs: usize, flat: &[u8]) -> Vec<Vec<u8>> {
    let dist = store.distribution();
    (0..dist.world())
        .map(|j| {
            let r = dist.shard_of(j);
            flat[r.start as usize * bs..r.end as usize * bs].to_vec()
        })
        .collect()
}

// ---------------------------------------------------------------------------
// golden message/byte contract
// ---------------------------------------------------------------------------

#[test]
fn fused_batch_charges_one_request_and_one_data_message() {
    const P: usize = 16;
    const BS: usize = 32;
    const BPP: usize = 64;
    const K: usize = 6;
    let n = (P * BPP) as u64;
    let image = flat_image(n, BS, 3);

    let build = || {
        let cfg = RestoreConfig::builder(P, BS, BPP).replicas(4).build().unwrap();
        let mut cluster = Cluster::new_execution(P, 4);
        let mut store = ReStore::new(cfg, &cluster).unwrap();
        store.submit(&mut cluster, &shards_of(&store, BS, &image)).unwrap();
        let mut kv = KvStore::new();
        kv.register(&store, DatasetId::FIRST, 0).unwrap(); // pure routing, no cache
        (cluster, store, kv)
    };
    let (mut cluster, mut store, mut kv) = build();

    // Pick K distinct, pairwise NON-adjacent keys that all live in the
    // same permuted slice (= same holder set, and the router's
    // deterministic per-(requester, slice) pick means one server), plus a
    // requester that is not itself a holder — so the fused batch is
    // exactly one remote (requester, server) conversation.
    let (slot, keys) = {
        let ds = store.dataset(DatasetId::FIRST).unwrap();
        let dist = ds.distribution();
        let mut per_slot: Vec<Vec<u64>> = vec![Vec::new(); dist.world()];
        for x in 0..n {
            per_slot[dist.slice_of(dist.permute_block(x))].push(x);
        }
        let (slot, xs) = per_slot
            .iter()
            .enumerate()
            .find(|(_, xs)| {
                // greedily count pairwise non-adjacent keys (sorted order)
                let mut picked = 0u64;
                let mut last = u64::MAX - 1;
                for &x in xs.iter() {
                    if last == u64::MAX - 1 || x > last + 1 {
                        picked += 1;
                        last = x;
                    }
                }
                picked >= K as u64
            })
            .expect("some slice holds >= K non-adjacent keys");
        let mut picked: Vec<u64> = Vec::new();
        for &x in xs {
            if picked.last().map_or(true, |&l| x > l + 1) {
                picked.push(x);
                if picked.len() == K {
                    break;
                }
            }
        }
        (slot, picked)
    };
    let holders: Vec<u32> =
        store.dataset(DatasetId::FIRST).unwrap().holder_index().holders_of(slot).to_vec();
    let requester = (0..P).find(|pe| !holders.contains(&(*pe as u32))).expect("p > r");

    // -- fused: ONE request sparse all-to-all + ONE data sparse all-to-all
    let mut batch = KvBatch::new();
    for &k in &keys {
        batch.get(DatasetId::FIRST, requester, k);
    }
    let fused = kv.execute(&mut store, &mut cluster, &batch).unwrap();
    assert_eq!(fused.hits, 0);
    assert_eq!(
        fused.request_cost.total_msgs, 1,
        "all K gets share one (requester, server) pair -> one request message"
    );
    assert_eq!(fused.data_cost.total_msgs, 1, "one data message back");
    assert_eq!(fused.cost.total_msgs, 2);

    // -- sequential twin: the same K gets one at a time
    let (mut s_cluster, mut s_store, mut s_kv) = build();
    let mut seq_msgs = 0u64;
    let mut seq_request_bytes = 0u64;
    let mut seq_data_bytes = 0u64;
    for (i, &k) in keys.iter().enumerate() {
        let g = s_kv.get(&mut s_store, &mut s_cluster, DatasetId::FIRST, requester, k).unwrap();
        assert!(!g.hit);
        seq_msgs += g.cost.total_msgs;
        seq_request_bytes += g.cost.total_bytes - BS as u64; // data = one block
        seq_data_bytes += BS as u64;
        assert_eq!(
            g.bytes.unwrap().as_slice(),
            fused.value(i).unwrap(),
            "fused and sequential serve identical bytes"
        );
        assert_eq!(fused.value(i).unwrap(), &image[k as usize * BS..(k as usize + 1) * BS]);
    }
    assert_eq!(seq_msgs, 2 * K as u64, "sequential: one request + one data message per get");
    assert!(
        fused.cost.total_msgs < seq_msgs,
        "fused message count must be strictly below sequential ({} vs {seq_msgs})",
        fused.cost.total_msgs
    );
    // byte identity: k non-adjacent keys are k piece descriptors in the
    // request phase and k blocks in the data phase, fused or not
    assert_eq!(fused.request_cost.total_bytes, seq_request_bytes);
    assert_eq!(fused.data_cost.total_bytes, seq_data_bytes);
    assert_eq!(
        fused.cost.total_bytes,
        seq_request_bytes + seq_data_bytes,
        "fusing changes message count, never bytes"
    );
}

// ---------------------------------------------------------------------------
// randomized cached-vs-oracle property test
// ---------------------------------------------------------------------------

const P: usize = 12;
const BS: usize = 16;
const BPP: usize = 32;
const N: u64 = (P * BPP) as u64;
const CACHE: usize = 64;
const OPS: usize = 160;

struct Stack {
    cluster: Cluster,
    store: ReStore,
    kv: KvStore,
    ids: Vec<DatasetId>,
}

fn stack(cache_slots: usize) -> Stack {
    let cfg = RestoreConfig::builder(P, BS, BPP).replicas(4).build().unwrap();
    let mut cluster = Cluster::new_execution(P, 4);
    let mut store = ReStore::new(cfg.clone(), &cluster).unwrap();
    store.submit(&mut cluster, &shards_of(&store, BS, &flat_image(N, BS, 1))).unwrap();
    let id2 = store.create_dataset(cfg, &cluster).unwrap();
    let shards2 = shards_of(&store, BS, &flat_image(N, BS, 2));
    store.dataset_mut(id2).unwrap().submit(&mut cluster, &shards2).unwrap();
    let ids = vec![DatasetId::FIRST, id2];
    let mut kv = KvStore::new();
    for (i, &id) in ids.iter().enumerate() {
        kv.register_with_image(&store, id, cache_slots, flat_image(N, BS, 1 + i as u8)).unwrap();
    }
    Stack { cluster, store, kv, ids }
}

/// Audit both stacks after every step: the cached side must be coherent
/// (no live entry differing from a replica) and must never have served a
/// stale value.
fn audit(cached: &Stack, oracle: &Stack) {
    for &id in &cached.ids {
        let a = cached.kv.validate_cache(&cached.store, id).unwrap();
        assert_eq!(a.mismatched_entries, 0, "live cache entry diverged from the replicas");
        let s = cached.kv.stats(id).unwrap();
        assert_eq!(s.stale_serves, 0, "a stale value was served");
        assert_eq!(oracle.kv.stats(id).unwrap().hits, 0, "the oracle must never cache");
    }
    assert_eq!(
        cached.cluster.alive_ranks(),
        oracle.cluster.alive_ranks(),
        "mirrored kills must keep the stacks in lockstep"
    );
}

#[test]
fn random_interleavings_match_uncached_oracle_byte_for_byte() {
    for seed in [11u64, 29, 47] {
        let mut rng = Rng::seed_from_u64(seed);
        let mut c = stack(CACHE);
        let mut o = stack(0);
        // the locally tracked truth: what every key must currently serve
        let mut expected: Vec<Vec<u8>> =
            (0..c.ids.len()).map(|i| flat_image(N, BS, 1 + i as u8)).collect();
        let mut kills = 0usize;

        for step in 0..OPS {
            let alive: Vec<usize> =
                c.cluster.alive_ranks().iter().map(|&r| r as usize).collect();
            let d = rng.gen_index(c.ids.len());
            let id = c.ids[d];
            match rng.gen_index(14) {
                // -- single gets (the common case) --
                0..=5 => {
                    let pe = alive[rng.gen_index(alive.len())];
                    let key = rng.gen_u64_below(N);
                    let got = c.kv.get(&mut c.store, &mut c.cluster, id, pe, key).unwrap();
                    let want = o.kv.get(&mut o.store, &mut o.cluster, id, pe, key).unwrap();
                    assert!(!want.hit);
                    let got = got.bytes.unwrap();
                    assert_eq!(got.as_slice(), want.bytes.unwrap().as_slice(), "step {step}");
                    assert_eq!(
                        got.as_slice(),
                        &expected[d][key as usize * BS..(key as usize + 1) * BS]
                    );
                }
                // -- fused batches across BOTH datasets --
                6..=8 => {
                    let mut batch = KvBatch::new();
                    let mut trace = Vec::new();
                    for _ in 0..8 {
                        let pe = alive[rng.gen_index(alive.len())];
                        let di = rng.gen_index(c.ids.len());
                        let key = rng.gen_u64_below(N);
                        batch.get(c.ids[di], pe, key);
                        trace.push((di, pe, key));
                    }
                    let out = c.kv.execute(&mut c.store, &mut c.cluster, &batch).unwrap();
                    for (i, &(di, pe, key)) in trace.iter().enumerate() {
                        let want =
                            o.kv.get(&mut o.store, &mut o.cluster, c.ids[di], pe, key).unwrap();
                        assert_eq!(out.value(i).unwrap(), want.bytes.unwrap().as_slice());
                        assert_eq!(
                            out.value(i).unwrap(),
                            &expected[di][key as usize * BS..(key as usize + 1) * BS]
                        );
                    }
                }
                // -- point writes through the dirty-resubmit path --
                9 | 10 => {
                    let keys: Vec<u64> = (0..4).map(|_| rng.gen_u64_below(N)).collect();
                    let values: Vec<Vec<u8>> = keys
                        .iter()
                        .map(|&k| {
                            (0..BS).map(|j| (k as u8) ^ (j as u8) ^ (step as u8)).collect()
                        })
                        .collect();
                    let writes: Vec<(u64, &[u8])> =
                        keys.iter().zip(&values).map(|(&k, v)| (k, v.as_slice())).collect();
                    let rc = c.kv.put_many(
                        &mut c.store,
                        &mut c.cluster,
                        id,
                        &writes,
                        Overlap::Blocking,
                    );
                    let ro = o.kv.put_many(
                        &mut o.store,
                        &mut o.cluster,
                        id,
                        &writes,
                        Overlap::Blocking,
                    );
                    assert_eq!(rc.is_ok(), ro.is_ok(), "mirrored puts must agree (step {step})");
                    match rc {
                        Ok(_) => {
                            for (&k, v) in keys.iter().zip(&values) {
                                expected[d][k as usize * BS..(k as usize + 1) * BS]
                                    .copy_from_slice(v);
                            }
                        }
                        // a degraded layout can refuse writes; both sides
                        // rolled their images back, truth is unchanged
                        Err(Error::DeadPe(_))
                        | Err(Error::IrrecoverableDataLoss { .. })
                        | Err(Error::ResubmitAborted { .. }) => {}
                        Err(e) => panic!("unexpected put failure at step {step}: {e}"),
                    }
                }
                // -- range scans --
                11 => {
                    let start = rng.gen_u64_below(N - 8);
                    let end = start + 1 + rng.gen_u64_below(8);
                    let pe = alive[rng.gen_index(alive.len())];
                    let got =
                        c.kv.scan(&mut c.store, &mut c.cluster, id, pe, start, end).unwrap();
                    let want =
                        o.kv.scan(&mut o.store, &mut o.cluster, id, pe, start, end).unwrap();
                    let got = got.bytes.unwrap();
                    assert_eq!(got, want.bytes.unwrap());
                    assert_eq!(
                        got,
                        &expected[d][start as usize * BS..end as usize * BS]
                    );
                }
                // -- a failure + the full recovery handshake, mirrored --
                12 => {
                    if kills < 2 && alive.len() > P - 2 {
                        kills += 1;
                        let victim = alive[alive.len() - rng.gen_index(3) - 1];
                        for s in [&mut c, &mut o] {
                            s.cluster.kill(&[victim]);
                            let (_failed, map, _cost) = ulfm::recover(&mut s.cluster);
                            s.store
                                .rebalance_or_acknowledge_all(&mut s.cluster, &map)
                                .unwrap();
                        }
                        // the epoch bump must have stranded everything
                        for &id in &c.ids {
                            let a = c.kv.validate_cache(&c.store, id).unwrap();
                            assert_eq!(a.live_entries, 0, "entry survived an epoch bump");
                        }
                    }
                }
                // -- repair (idempotent here) + the manual invalidation
                //    contract for placement changes without a stamp bump --
                _ => {
                    for s in [&mut c, &mut o] {
                        s.store
                            .repair_replicas_all(&mut s.cluster, RepairScheme::DoubleHashing)
                            .unwrap();
                        s.kv.invalidate_all();
                    }
                    for &id in &c.ids {
                        let a = c.kv.validate_cache(&c.store, id).unwrap();
                        assert_eq!(a.live_entries, 0, "invalidate_all must strand every entry");
                    }
                }
            }
            audit(&c, &o);
        }

        // the cache did real work on this trace
        let total_hits: u64 =
            c.ids.iter().map(|&id| c.kv.stats(id).unwrap().hits).sum();
        assert!(total_hits > 0, "seed {seed}: trace never hit the cache");
    }
}
