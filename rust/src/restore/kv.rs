//! KV serving layer: a `get`/`put`/`scan` front-end over the registry.
//!
//! The registry already IS a replicated key-value store in everything but
//! API: keys are block ids, the Feistel permutation (§IV-A) hashes them
//! across PEs, every block has `r` live holders, and the load router
//! balances reads over them. This module adds the serving shape the
//! ROADMAP's "millions of users" north star asks for (the Fohry & Fink
//! ULFM KV store, PAPERS.md), with two perf levers on the read path:
//!
//! - **Request batching** ([`KvBatch`]). Many small point gets — across
//!   requesters, keys, and *datasets* — fuse into ONE request sparse
//!   all-to-all plus ONE data sparse all-to-all through
//!   [`ReStore::load_many_pooled`]: per-(dataset, requester) key sets fold
//!   into maximal [`RangeSet`] runs and ride the existing plan/merge
//!   machinery. This is §IV-C's fewer-messages argument applied to point
//!   reads: bytes equal the k sequential single-key gets (the request
//!   phase charges per piece descriptor, not per message), while message
//!   count drops to one per distinct (requester, server) pair — strictly
//!   below `2k` whenever any two gets share a pair (golden-tested in
//!   `rust/tests/kv_store.rs`).
//!
//! - **A per-PE read cache** with O(1) invalidation. Each requester PE
//!   owns a bounded direct-mapped cache whose entries are stamped with
//!   the dataset's `(epoch, version)` pair ([`Dataset::stamp`]) plus a
//!   table-local generation. A rebalance/substitution bumps the epoch, a
//!   resubmit bumps the version, and [`KvStore::invalidate`] bumps the
//!   generation (the repair/scrub-heal hook) — each stamps *every* cached
//!   entry stale in O(1), never by sweeping, exactly the generation trick
//!   PR 8's stamped load table uses. A hit performs zero allocations and
//!   never touches the network accumulator: it charges one local memcpy
//!   ([`PhaseCost::local_copy`]) and serves bytes straight out of the
//!   cache arena. Serving a stale value is structurally impossible —
//!   every read validates the dataset epoch first (a stale epoch is an
//!   error, not a silent serve) and a hit requires all three stamps to
//!   match the *current* dataset state; the [`KvStats::stale_serves`]
//!   tripwire recounts the comparison at serve time and stays zero.
//!
//! Writes ride PR 9's mutable-dataset path: [`KvStore::put_many`] applies
//! point writes to a flat authoritative image and commits them as a
//! [`ResubmitMode::Dirty`] resubmit (atomic version bump, abort falls
//! back to the committed version with the image rolled back);
//! [`KvStore::scan`] maps a key range onto a single `RangeSet` load
//! through the router. Cache-coherence across all of it is prop-tested
//! against an uncached fresh-load oracle under random
//! get/put/kill/recover/scan interleavings.

use crate::error::{Error, Result};
use crate::restore::block::{BlockRange, RangeSet};
use crate::restore::load::{point_get_ranges, point_get_requests};
use crate::restore::registry::DatasetId;
use crate::restore::resubmit::{Overlap, ResubmitMode, ResubmitReport};
use crate::restore::{LoadRequest, ReStore};
use crate::simnet::cluster::Cluster;
use crate::simnet::network::PhaseCost;
use crate::util::rng::Rng;

/// Slot-empty marker in a [`PeCache`]; no valid key reaches it (a key is
/// a block id, bounded by the dataset's block count).
const EMPTY_KEY: u64 = u64::MAX;

/// Read-path counters of one registered dataset (see [`KvStore::stats`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvStats {
    /// Gets served from the per-PE cache (no network phase).
    pub hits: u64,
    /// Gets that went to the holders through the load router.
    pub misses: u64,
    /// Point writes committed through the resubmit path.
    pub puts: u64,
    /// Range scans served.
    pub scans: u64,
    /// Cached entries whose stamps no longer matched the dataset at the
    /// moment of serving. The hit predicate already requires matching
    /// stamps, so this is a tripwire that must stay 0 — it recounts the
    /// comparison after the hit decision (the `stale-serves=0` marker in
    /// `examples/kv_trace.rs` and the Zipf bench asserts on it).
    pub stale_serves: u64,
    /// O(1) whole-table invalidations (epoch/version bumps are implicit;
    /// this counts explicit [`KvStore::invalidate`] generation bumps).
    pub invalidations: u64,
}

impl KvStats {
    /// Fraction of gets served from the cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One requester PE's bounded direct-mapped read cache. Parallel arrays:
/// slot `key % capacity` holds the key plus the `(epoch, version, gen)`
/// stamps it was filled under; `values` is a single `capacity ·
/// block_size` arena (empty for cost-model datasets, which cache the
/// *locality* of a key, not bytes). Invalidation never walks these
/// arrays — a stamp bump anywhere strands every entry at once.
struct PeCache {
    keys: Vec<u64>,
    epochs: Vec<u64>,
    versions: Vec<u64>,
    gens: Vec<u64>,
    values: Vec<u8>,
}

impl PeCache {
    fn new(capacity: usize, block_size: usize, execution: bool) -> PeCache {
        PeCache {
            keys: vec![EMPTY_KEY; capacity],
            epochs: vec![0; capacity],
            versions: vec![0; capacity],
            gens: vec![0; capacity],
            values: if execution { vec![0; capacity * block_size] } else { Vec::new() },
        }
    }
}

/// One registered dataset's serving state inside a [`KvStore`].
struct Table {
    dataset: DatasetId,
    /// Cache slots per requester PE (0 disables caching entirely).
    capacity: usize,
    /// Table-local generation stamp: bumped by [`KvStore::invalidate`],
    /// invalidating every cached entry in O(1) without an epoch or
    /// version change (the repair/scrub-heal contract).
    gen: u64,
    /// Lazily allocated per requester rank — only PEs that actually read
    /// through the cache pay for slots.
    caches: Vec<Option<Box<PeCache>>>,
    /// Flat authoritative content (`n_blocks · block_size` bytes, original
    /// block order) mirroring the committed version — the write path's
    /// source of truth ([`KvStore::put_many`]). `None` for cost-model
    /// tables and read-only registrations.
    image: Option<Vec<u8>>,
    stats: KvStats,
}

impl Table {
    fn slot(&self, key: u64) -> usize {
        (key % self.capacity as u64) as usize
    }

    /// Is `(pe, key)` cached at exactly the current stamps? Allocation-free.
    fn probe(&self, pe: usize, key: u64, epoch: u64, version: u64) -> bool {
        if self.capacity == 0 {
            return false;
        }
        let Some(Some(c)) = self.caches.get(pe) else {
            return false;
        };
        let s = self.slot(key);
        c.keys[s] == key
            && c.epochs[s] == epoch
            && c.versions[s] == version
            && c.gens[s] == self.gen
    }

    /// Fill `(pe, key)` at the current stamps; `bytes` is `None` for
    /// cost-model datasets (the stamp alone is the cache entry).
    fn fill(
        &mut self,
        pe: usize,
        key: u64,
        epoch: u64,
        version: u64,
        bytes: Option<&[u8]>,
        bs: usize,
    ) {
        if self.capacity == 0 {
            return;
        }
        if self.caches.len() <= pe {
            self.caches.resize_with(pe + 1, || None);
        }
        let (capacity, gen) = (self.capacity, self.gen);
        let c = self.caches[pe]
            .get_or_insert_with(|| Box::new(PeCache::new(capacity, bs, bytes.is_some())));
        let s = (key % capacity as u64) as usize;
        c.keys[s] = key;
        c.epochs[s] = epoch;
        c.versions[s] = version;
        c.gens[s] = gen;
        if let Some(b) = bytes {
            c.values[s * bs..(s + 1) * bs].copy_from_slice(b);
        }
    }
}

/// A served value: borrowed straight from the cache arena on a hit
/// (allocation-free), owned on a cache-disabled miss.
pub enum KvBytes<'a> {
    /// Served from the per-PE cache arena.
    Cached(&'a [u8]),
    /// Served from the holders (cache disabled for this table).
    Owned(Vec<u8>),
}

impl KvBytes<'_> {
    pub fn as_slice(&self) -> &[u8] {
        match self {
            KvBytes::Cached(b) => b,
            KvBytes::Owned(b) => b,
        }
    }
}

impl std::ops::Deref for KvBytes<'_> {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

/// Result of a single [`KvStore::get`].
pub struct KvGet<'a> {
    /// The value's `block_size` bytes (`None` for cost-model datasets).
    pub bytes: Option<KvBytes<'a>>,
    /// Served from the per-PE cache?
    pub hit: bool,
    /// What this get charged the clock: a local memcpy on a hit, the
    /// two-phase load cost on a miss.
    pub cost: PhaseCost,
}

/// Result of a [`KvStore::scan`].
pub struct KvScan {
    /// The range's bytes in key order (`None` for cost-model datasets).
    pub bytes: Option<Vec<u8>>,
    pub cost: PhaseCost,
}

/// A batch of point gets — possibly spanning several datasets — fused
/// into one two-phase sparse all-to-all by [`KvStore::execute`].
#[derive(Debug, Clone, Default)]
pub struct KvBatch {
    gets: Vec<(DatasetId, usize, u64)>,
}

impl KvBatch {
    pub fn new() -> KvBatch {
        KvBatch::default()
    }

    /// Queue a point get: requester `pe` wants `key` of `dataset`.
    /// Duplicate `(dataset, pe, key)` entries are served from one fetch.
    pub fn get(&mut self, dataset: DatasetId, pe: usize, key: u64) -> &mut KvBatch {
        self.gets.push((dataset, pe, key));
        self
    }

    pub fn len(&self) -> usize {
        self.gets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gets.is_empty()
    }
}

/// One get's outcome inside a [`KvBatchOutput`], in input order.
#[derive(Debug, Clone)]
pub struct KvBatchGet {
    pub dataset: DatasetId,
    pub pe: usize,
    pub key: u64,
    pub hit: bool,
    /// This get's bytes as `&output.values[span]` (`None` for cost-model
    /// datasets).
    pub span: Option<std::ops::Range<usize>>,
}

/// Result of a fused [`KvStore::execute`]: every value in one arena, the
/// batch's hits charged as one fused local copy and its misses as exactly
/// one request + one data sparse all-to-all across all datasets.
#[derive(Debug, Clone)]
pub struct KvBatchOutput {
    /// Single output allocation; each get's bytes are `&values[span]`.
    pub values: Vec<u8>,
    /// Per-get outcomes, in the order the gets were queued.
    pub gets: Vec<KvBatchGet>,
    pub hits: u64,
    pub misses: u64,
    /// The fused request phase (zero if every get hit).
    pub request_cost: PhaseCost,
    /// The fused data phase (zero if every get hit).
    pub data_cost: PhaseCost,
    /// Total charged: hit memcpys + request + data.
    pub cost: PhaseCost,
}

impl KvBatchOutput {
    /// Bytes of get `i` (input order); `None` for cost-model datasets.
    pub fn value(&self, i: usize) -> Option<&[u8]> {
        self.gets[i].span.clone().map(|s| &self.values[s])
    }
}

/// What [`KvStore::validate_cache`] found — the prop-test teeth.
#[derive(Debug, Clone, Copy, Default)]
pub struct KvCacheAudit {
    /// Entries whose stamps match the dataset's current
    /// `(epoch, version)` and the table generation — the servable set.
    pub live_entries: u64,
    /// Entries stranded by an epoch/version/generation bump. Stale
    /// entries are *inert* (the hit predicate skips them); they are
    /// counted, never served.
    pub stale_entries: u64,
    /// Live entries whose cached bytes differ from a live holder's
    /// committed bytes. Any nonzero value is a cache-coherence bug.
    pub mismatched_entries: u64,
}

/// The KV serving front-end: a set of registered datasets, each with its
/// per-PE read cache and (optionally) a flat write-through image. See the
/// module docs for the serving model.
#[derive(Default)]
pub struct KvStore {
    tables: Vec<Table>,
}

impl KvStore {
    pub fn new() -> KvStore {
        KvStore::default()
    }

    fn table_index(&self, id: DatasetId) -> Result<usize> {
        self.tables
            .iter()
            .position(|t| t.dataset == id)
            .ok_or_else(|| Error::Config(format!("kv: dataset {id} is not registered")))
    }

    /// Register `id` for serving with `cache_capacity` slots per
    /// requester PE (0 disables the cache — the uncached ablation). The
    /// table starts without an image, so [`KvStore::put_many`] is
    /// unavailable (cost-model tables write via [`KvStore::put_virtual`]).
    pub fn register(
        &mut self,
        store: &ReStore,
        id: DatasetId,
        cache_capacity: usize,
    ) -> Result<()> {
        store.dataset(id)?;
        if self.tables.iter().any(|t| t.dataset == id) {
            return Err(Error::Config(format!("kv: dataset {id} is already registered")));
        }
        self.tables.push(Table {
            dataset: id,
            capacity: cache_capacity,
            gen: 0,
            caches: Vec::new(),
            image: None,
            stats: KvStats::default(),
        });
        Ok(())
    }

    /// [`KvStore::register`] plus a flat authoritative `image`
    /// (`n_blocks · block_size` bytes in original block order) that must
    /// equal the dataset's committed content — the bytes submitted (or
    /// last resubmitted). The image is the write path's source of truth:
    /// [`KvStore::put_many`] applies point writes to it and commits them
    /// as a dirty resubmit, rolling the image back if the resubmit
    /// aborts, so it always mirrors the committed version.
    pub fn register_with_image(
        &mut self,
        store: &ReStore,
        id: DatasetId,
        cache_capacity: usize,
        image: Vec<u8>,
    ) -> Result<()> {
        let ds = store.dataset(id)?;
        ds.ensure_submitted()?;
        if !ds.is_execution_mode() {
            return Err(Error::Config(format!(
                "kv: register_with_image on cost-model dataset {id} (no bytes); use register"
            )));
        }
        let want = ds.distribution().n_blocks() as usize * ds.config().block_size;
        if image.len() != want {
            return Err(Error::Config(format!(
                "kv: dataset {id} image has {} bytes, expected {want}",
                image.len()
            )));
        }
        self.register(store, id, cache_capacity)?;
        self.tables.last_mut().expect("just registered").image = Some(image);
        Ok(())
    }

    /// Read-path counters of `id` (copied out).
    pub fn stats(&self, id: DatasetId) -> Result<KvStats> {
        Ok(self.tables[self.table_index(id)?].stats)
    }

    /// The authoritative flat image of `id`, if registered with one.
    pub fn image(&self, id: DatasetId) -> Result<Option<&[u8]>> {
        Ok(self.tables[self.table_index(id)?].image.as_deref())
    }

    /// Strand every cached entry of `id` in O(1) by bumping the table
    /// generation — the hook for events that change holder placement
    /// without an epoch or version bump (e.g. [`Dataset::scrub`] healing
    /// a quarantined copy, [`ReStore::repair_replicas_all`]). Epoch and
    /// version bumps invalidate implicitly; this covers everything else.
    ///
    /// [`Dataset::scrub`]: crate::restore::registry::Dataset::scrub
    pub fn invalidate(&mut self, id: DatasetId) -> Result<()> {
        let t = self.table_index(id)?;
        self.tables[t].gen += 1;
        self.tables[t].stats.invalidations += 1;
        Ok(())
    }

    /// [`KvStore::invalidate`] for every registered dataset.
    pub fn invalidate_all(&mut self) {
        for t in &mut self.tables {
            t.gen += 1;
            t.stats.invalidations += 1;
        }
    }

    /// Point read: requester `pe` gets `key` of `id`. A cache hit charges
    /// one local `block_size` memcpy and allocates nothing; a miss is a
    /// single-key load through the router (any of the `r` holders
    /// serves), which then fills the cache. The dataset must be at the
    /// cluster's current epoch — after a failure, recovery must run
    /// before any read ([`Error::StaleEpoch`] otherwise), which is what
    /// makes a stale serve structurally impossible rather than merely
    /// unlikely.
    pub fn get(
        &mut self,
        store: &mut ReStore,
        cluster: &mut Cluster,
        id: DatasetId,
        pe: usize,
        key: u64,
    ) -> Result<KvGet<'_>> {
        let t = self.table_index(id)?;
        let (epoch, version, bs, n_blocks, execution) = {
            let ds = store.dataset(id)?;
            ds.ensure_submitted()?;
            ds.ensure_current_epoch(cluster)?;
            let (e, v) = ds.stamp();
            (e, v, ds.config().block_size, ds.distribution().n_blocks(), ds.is_execution_mode())
        };
        if key >= n_blocks {
            return Err(Error::KeyOutOfRange { dataset: id, key, keys: n_blocks });
        }
        if !cluster.is_alive(pe) {
            return Err(Error::DeadPe(pe));
        }

        if self.tables[t].probe(pe, key, epoch, version) {
            // Tripwire: recount the stamp comparison at serve time. The
            // probe above already required it, so this can only fire if a
            // future refactor lets the dataset move between probe and
            // serve — it must stay 0 (asserted by bench and example).
            let (e2, v2) = store.dataset(id)?.stamp();
            if (e2, v2) != (epoch, version) {
                self.tables[t].stats.stale_serves += 1;
            } else {
                let cost = PhaseCost::local_copy(cluster.network(), bs as u64);
                cluster.advance(&cost);
                let tbl = &mut self.tables[t];
                tbl.stats.hits += 1;
                let s = tbl.slot(key);
                let c = tbl.caches[pe].as_ref().expect("probe hit implies cache");
                let bytes = execution.then(|| KvBytes::Cached(&c.values[s * bs..(s + 1) * bs]));
                return Ok(KvGet { bytes, hit: true, cost });
            }
        }

        // Miss: one single-key load through the router, then fill.
        let reqs = [LoadRequest { pe, ranges: RangeSet::new(vec![BlockRange::new(key, key + 1)]) }];
        let out = store.dataset_mut(id)?.load(cluster, &reqs)?;
        let value = out.shards.into_iter().next().expect("one request, one shard").bytes;
        let tbl = &mut self.tables[t];
        tbl.stats.misses += 1;
        tbl.fill(pe, key, epoch, version, value.as_deref(), bs);
        Ok(KvGet { bytes: value.map(KvBytes::Owned), hit: false, cost: out.cost })
    }

    /// Serve a whole [`KvBatch`] fused: hits are charged as ONE local
    /// copy of their summed bytes (the network accumulator is never
    /// touched), and all misses — across every dataset in the batch —
    /// fold into per-(dataset, requester) range sets and ride ONE
    /// [`ReStore::load_many_pooled`] call: exactly one request sparse
    /// all-to-all plus one data sparse all-to-all, total message count
    /// one per distinct (requester, server) pair. Planning allocations
    /// are O(batch size), independent of the world size (pinned by
    /// `rust/tests/alloc_counts.rs`).
    pub fn execute(
        &mut self,
        store: &mut ReStore,
        cluster: &mut Cluster,
        batch: &KvBatch,
    ) -> Result<KvBatchOutput> {
        struct Meta {
            id: DatasetId,
            table: usize,
            epoch: u64,
            version: u64,
            bs: usize,
            n_blocks: u64,
            execution: bool,
        }
        // One registry validation per distinct dataset.
        let mut metas: Vec<Meta> = Vec::new();
        for &(id, _, _) in &batch.gets {
            if metas.iter().any(|m| m.id == id) {
                continue;
            }
            let table = self.table_index(id)?;
            let ds = store.dataset(id)?;
            ds.ensure_submitted()?;
            ds.ensure_current_epoch(cluster)?;
            let (epoch, version) = ds.stamp();
            metas.push(Meta {
                id,
                table,
                epoch,
                version,
                bs: ds.config().block_size,
                n_blocks: ds.distribution().n_blocks(),
                execution: ds.is_execution_mode(),
            });
        }
        let meta_of = |metas: &[Meta], id: DatasetId| -> usize {
            metas.iter().position(|m| m.id == id).expect("meta collected above")
        };

        // Resolve every get against its cache; validate as we go.
        let mut hit_flags: Vec<bool> = Vec::with_capacity(batch.gets.len());
        let mut hits = 0u64;
        let mut hit_bytes = 0u64;
        for &(id, pe, key) in &batch.gets {
            let m = &metas[meta_of(&metas, id)];
            if key >= m.n_blocks {
                return Err(Error::KeyOutOfRange { dataset: id, key, keys: m.n_blocks });
            }
            if !cluster.is_alive(pe) {
                return Err(Error::DeadPe(pe));
            }
            let hit = self.tables[m.table].probe(pe, key, m.epoch, m.version);
            if hit {
                hits += 1;
                hit_bytes += m.bs as u64;
            }
            hit_flags.push(hit);
        }

        // All hits together are one fused local copy; nothing of a hit
        // ever reaches the network accumulator.
        let hit_cost = if hits > 0 {
            let cost = PhaseCost::local_copy(cluster.network(), hit_bytes);
            cluster.advance(&cost);
            cost
        } else {
            PhaseCost::default()
        };

        // Group misses per (dataset, requester); fold each group's sorted
        // deduplicated keys into maximal ranges -> the fused load parts.
        let mut miss: Vec<(usize, usize, u64)> = batch
            .gets
            .iter()
            .zip(&hit_flags)
            .filter(|&(_, &hit)| !hit)
            .map(|(&(id, pe, key), _)| (meta_of(&metas, id), pe, key))
            .collect();
        miss.sort_unstable();
        let mut parts: Vec<(DatasetId, Vec<LoadRequest>)> = Vec::new();
        // (meta, pe) -> (part, shard), in the order requests were built.
        let mut lookup: Vec<(usize, usize, usize, usize)> = Vec::new();
        let mut keys_scratch: Vec<u64> = Vec::new();
        let mut i = 0;
        while i < miss.len() {
            let (mi, pe) = (miss[i].0, miss[i].1);
            keys_scratch.clear();
            while i < miss.len() && miss[i].0 == mi && miss[i].1 == pe {
                keys_scratch.push(miss[i].2);
                i += 1;
            }
            let req = point_get_requests(pe, &mut keys_scratch);
            let part = match parts.iter().position(|(id, _)| *id == metas[mi].id) {
                Some(p) => p,
                None => {
                    parts.push((metas[mi].id, Vec::new()));
                    parts.len() - 1
                }
            };
            lookup.push((mi, pe, part, parts[part].1.len()));
            parts[part].1.push(req);
        }
        let pooled =
            if parts.is_empty() { None } else { Some(store.load_many_pooled(cluster, &parts)?) };

        // Lay out the output arena in input order and fill it: hits from
        // the cache slots, misses from the pooled arena.
        let mut gets_out: Vec<KvBatchGet> = Vec::with_capacity(batch.gets.len());
        let mut total = 0usize;
        for (&(id, pe, key), &hit) in batch.gets.iter().zip(&hit_flags) {
            let m = &metas[meta_of(&metas, id)];
            let span = m.execution.then(|| {
                let s = total..total + m.bs;
                total += m.bs;
                s
            });
            gets_out.push(KvBatchGet { dataset: id, pe, key, hit, span });
        }
        let mut values = vec![0u8; total];
        for g in &gets_out {
            let Some(span) = g.span.clone() else { continue };
            let m = &metas[meta_of(&metas, g.dataset)];
            if g.hit {
                let tbl = &self.tables[m.table];
                let s = tbl.slot(g.key);
                let c = tbl.caches[g.pe].as_ref().expect("probe hit implies cache");
                values[span].copy_from_slice(&c.values[s * m.bs..(s + 1) * m.bs]);
            } else {
                let (_, _, part, shard) = *lookup
                    .iter()
                    .find(|&&(mi, pe, _, _)| metas[mi].id == g.dataset && pe == g.pe)
                    .expect("every miss has a request");
                let bytes = pooled
                    .as_ref()
                    .expect("misses imply a pooled load")
                    .shard_bytes(part, shard)
                    .expect("execution dataset has a span");
                let off = offset_in(&parts[part].1[shard].ranges, g.key) * m.bs;
                values[span].copy_from_slice(&bytes[off..off + m.bs]);
            }
        }

        // Fill caches with the missed values at the current stamps, and
        // settle per-table stats.
        for g in &gets_out {
            let m = &metas[meta_of(&metas, g.dataset)];
            let tbl = &mut self.tables[m.table];
            if g.hit {
                tbl.stats.hits += 1;
            } else {
                tbl.stats.misses += 1;
                let bytes = g.span.clone().map(|s| &values[s]);
                tbl.fill(g.pe, g.key, m.epoch, m.version, bytes, m.bs);
            }
        }

        let (request_cost, data_cost) = match &pooled {
            Some(p) => (p.request_cost, p.data_cost),
            None => (PhaseCost::default(), PhaseCost::default()),
        };
        let misses = batch.gets.len() as u64 - hits;
        Ok(KvBatchOutput {
            values,
            gets: gets_out,
            hits,
            misses,
            request_cost,
            data_cost,
            cost: hit_cost.then(request_cost.then(data_cost)),
        })
    }

    /// Point writes: apply `writes` (`(key, value)` pairs, each value
    /// exactly `block_size` bytes) to the authoritative image and commit
    /// them as ONE [`ResubmitMode::Dirty`] resubmit — adjacent keys
    /// coalesce into ranges, replication double-buffers against the
    /// staging store, and the version bump atomically strands every
    /// cached entry of the previous version. If the resubmit aborts
    /// (failure mid-replication), the image is rolled back so it keeps
    /// mirroring the committed version; re-run recovery and retry.
    /// Requires [`KvStore::register_with_image`].
    pub fn put_many(
        &mut self,
        store: &mut ReStore,
        cluster: &mut Cluster,
        id: DatasetId,
        writes: &[(u64, &[u8])],
        overlap: Overlap,
    ) -> Result<ResubmitReport> {
        let t = self.table_index(id)?;
        let (bs, n_blocks) = {
            let ds = store.dataset(id)?;
            (ds.config().block_size, ds.distribution().n_blocks())
        };
        for &(key, bytes) in writes {
            if key >= n_blocks {
                return Err(Error::KeyOutOfRange { dataset: id, key, keys: n_blocks });
            }
            if bytes.len() != bs {
                return Err(Error::Config(format!(
                    "kv: put value for key {key} has {} bytes, block size is {bs}",
                    bytes.len()
                )));
            }
        }
        let tbl = &mut self.tables[t];
        let Some(image) = tbl.image.as_mut() else {
            return Err(Error::Config(format!(
                "kv: dataset {id} has no image; put_many needs register_with_image \
                 (cost-model tables write via put_virtual)"
            )));
        };
        // Apply to the image, remembering the previous bytes: an aborted
        // resubmit rolls back so the image never runs ahead of the
        // committed version.
        let mut undo: Vec<(u64, Vec<u8>)> = Vec::with_capacity(writes.len());
        let mut dirty_keys: Vec<u64> = Vec::with_capacity(writes.len());
        for &(key, bytes) in writes {
            let off = key as usize * bs;
            undo.push((key, image[off..off + bs].to_vec()));
            image[off..off + bs].copy_from_slice(bytes);
            dirty_keys.push(key);
        }
        let dirty = point_get_ranges(&mut dirty_keys);
        match store.dataset_mut(id)?.resubmit_flat(
            cluster,
            image,
            ResubmitMode::Dirty(&dirty),
            overlap,
        ) {
            Ok(rep) => {
                tbl.stats.puts += writes.len() as u64;
                Ok(rep)
            }
            Err(e) => {
                for (key, old) in undo.iter().rev() {
                    let off = *key as usize * bs;
                    image[off..off + bs].copy_from_slice(old);
                }
                Err(e)
            }
        }
    }

    /// Cost-model point writes: commit `keys` as one dirty resubmit (no
    /// bytes move; schedules and costs are identical to the
    /// execution-mode write of the same key set). Tables registered with
    /// an image must use [`KvStore::put_many`] — a virtual write would
    /// silently desynchronize it.
    pub fn put_virtual(
        &mut self,
        store: &mut ReStore,
        cluster: &mut Cluster,
        id: DatasetId,
        keys: &[u64],
        overlap: Overlap,
    ) -> Result<ResubmitReport> {
        let t = self.table_index(id)?;
        if self.tables[t].image.is_some() {
            return Err(Error::Config(format!(
                "kv: dataset {id} has an authoritative image; use put_many"
            )));
        }
        let n_blocks = store.dataset(id)?.distribution().n_blocks();
        for &key in keys {
            if key >= n_blocks {
                return Err(Error::KeyOutOfRange { dataset: id, key, keys: n_blocks });
            }
        }
        let mut sorted = keys.to_vec();
        let dirty = point_get_ranges(&mut sorted);
        let rep = store.dataset_mut(id)?.resubmit_virtual(cluster, &dirty, overlap)?;
        self.tables[t].stats.puts += keys.len() as u64;
        Ok(rep)
    }

    /// Range read: requester `pe` gets keys `[start, end)` of `id` as one
    /// `RangeSet` load through the router (one request per holder pair,
    /// not one per key). Scans bypass the point cache — a range read
    /// would evict `end - start` hot point entries for keys that are
    /// rarely re-read individually.
    pub fn scan(
        &mut self,
        store: &mut ReStore,
        cluster: &mut Cluster,
        id: DatasetId,
        pe: usize,
        start: u64,
        end: u64,
    ) -> Result<KvScan> {
        let t = self.table_index(id)?;
        let n_blocks = store.dataset(id)?.distribution().n_blocks();
        if end > n_blocks {
            return Err(Error::KeyOutOfRange { dataset: id, key: end - 1, keys: n_blocks });
        }
        if start >= end {
            return Err(Error::Config(format!("kv: empty scan [{start}, {end})")));
        }
        if !cluster.is_alive(pe) {
            return Err(Error::DeadPe(pe));
        }
        let reqs = [LoadRequest { pe, ranges: RangeSet::new(vec![BlockRange::new(start, end)]) }];
        let out = store.dataset_mut(id)?.load(cluster, &reqs)?;
        self.tables[t].stats.scans += 1;
        let shard = out.shards.into_iter().next().expect("one request, one shard");
        Ok(KvScan { bytes: shard.bytes, cost: out.cost })
    }

    /// Audit `id`'s cache against the store: classify every entry as live
    /// (stamps current) or stale (stranded by a bump), and byte-compare
    /// every live entry against a live holder's committed bytes
    /// (execution datasets). Walks the cache — test/debug surface, not a
    /// serving path. `mismatched_entries != 0` is a coherence bug; stale
    /// entries are normal (they are counted, never served).
    pub fn validate_cache(&self, store: &ReStore, id: DatasetId) -> Result<KvCacheAudit> {
        let t = self.table_index(id)?;
        let ds = store.dataset(id)?;
        let (epoch, version) = ds.stamp();
        let dist = ds.distribution();
        let bs = ds.config().block_size;
        let tbl = &self.tables[t];
        let mut audit = KvCacheAudit::default();
        for cache in tbl.caches.iter().flatten() {
            for s in 0..tbl.capacity {
                let key = cache.keys[s];
                if key == EMPTY_KEY {
                    continue;
                }
                let live = cache.epochs[s] == epoch
                    && cache.versions[s] == version
                    && cache.gens[s] == tbl.gen;
                if !live {
                    audit.stale_entries += 1;
                    continue;
                }
                audit.live_entries += 1;
                if cache.values.is_empty() {
                    continue; // cost-model: the stamp is the whole entry
                }
                let cached = &cache.values[s * bs..(s + 1) * bs];
                let y = dist.permute_block(key);
                let stored = ds
                    .holder_index()
                    .holders_of(dist.slice_of(y))
                    .iter()
                    .find_map(|&h| ds.stores()[h as usize].read(y, 1));
                if stored != Some(cached) {
                    audit.mismatched_entries += 1;
                }
            }
        }
        Ok(audit)
    }
}

/// Offset (in blocks) of `key` within a request's range set — where the
/// fused load placed its bytes inside the request's pooled span.
fn offset_in(ranges: &RangeSet, key: u64) -> usize {
    let mut off = 0u64;
    for r in ranges.ranges() {
        if key < r.end {
            debug_assert!(key >= r.start, "key below its own request's ranges");
            return (off + (key - r.start)) as usize;
        }
        off += r.len();
    }
    unreachable!("key {key} not in its own request's ranges");
}

/// Zipf(θ) sampler over `[0, n)` — the classic skewed KV workload (key 0
/// hottest). Built once (O(n) table), sampled by binary search on the
/// cumulative weights; the Feistel permutation then scatters hot keys
/// across holders, so popularity skew does not become placement skew.
pub struct Zipf {
    cum: Vec<f64>,
    total: f64,
}

impl Zipf {
    pub fn new(n: usize, theta: f64) -> Zipf {
        assert!(n > 0, "Zipf over an empty key space");
        let mut cum = Vec::with_capacity(n);
        let mut total = 0.0f64;
        for i in 0..n {
            total += 1.0 / ((i + 1) as f64).powf(theta);
            cum.push(total);
        }
        Zipf { cum, total }
    }

    pub fn n(&self) -> usize {
        self.cum.len()
    }

    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.gen_f64() * self.total;
        self.cum.partition_point(|&c| c <= u).min(self.cum.len() - 1) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RestoreConfig;
    use crate::simnet::ulfm;

    const P: usize = 8;
    const BS: usize = 16;
    const BPP: usize = 8;
    const N: u64 = (P * BPP) as u64;

    fn flat_image(salt: u8) -> Vec<u8> {
        (0..N as usize * BS).map(|i| (i as u8).wrapping_mul(31).wrapping_add(salt)).collect()
    }

    fn shards_of(store: &ReStore, flat: &[u8]) -> Vec<Vec<u8>> {
        let dist = store.distribution();
        (0..dist.world())
            .map(|j| {
                let r = dist.shard_of(j);
                flat[r.start as usize * BS..r.end as usize * BS].to_vec()
            })
            .collect()
    }

    fn execution_store() -> (Cluster, ReStore, Vec<u8>) {
        let cfg = RestoreConfig::builder(P, BS, BPP).replicas(4).build().unwrap();
        let mut cluster = Cluster::new_execution(P, 4);
        let mut store = ReStore::new(cfg, &cluster).unwrap();
        let image = flat_image(7);
        store.submit(&mut cluster, &shards_of(&store, &image)).unwrap();
        (cluster, store, image)
    }

    fn cost_model_store(p: usize) -> (Cluster, ReStore) {
        let cfg = RestoreConfig::builder(p, BS, BPP).replicas(4).build().unwrap();
        let mut cluster = Cluster::new_execution(p, 4);
        let mut store = ReStore::new(cfg, &cluster).unwrap();
        store.submit_virtual(&mut cluster).unwrap();
        (cluster, store)
    }

    #[test]
    fn get_miss_then_hit_serves_identical_bytes_locally() {
        let (mut cluster, mut store, image) = execution_store();
        let mut kv = KvStore::new();
        kv.register(&store, DatasetId::FIRST, 32).unwrap();

        let g = kv.get(&mut store, &mut cluster, DatasetId::FIRST, 2, 11).unwrap();
        assert!(!g.hit);
        assert_eq!(g.bytes.unwrap().as_slice(), &image[11 * BS..12 * BS]);

        let clock = cluster.now();
        let g = kv.get(&mut store, &mut cluster, DatasetId::FIRST, 2, 11).unwrap();
        assert!(g.hit);
        assert_eq!(g.bytes.unwrap().as_slice(), &image[11 * BS..12 * BS]);
        // hit charged a local memcpy only: no messages, tiny time
        assert_eq!(g.cost.total_msgs, 0);
        assert!(cluster.now() > clock);

        // a different requester has its own cache: miss again
        let g = kv.get(&mut store, &mut cluster, DatasetId::FIRST, 3, 11).unwrap();
        assert!(!g.hit);

        let s = kv.stats(DatasetId::FIRST).unwrap();
        assert_eq!((s.hits, s.misses, s.stale_serves), (1, 2, 0));
    }

    #[test]
    fn epoch_bump_invalidates_and_stale_epoch_never_serves() {
        let (mut cluster, mut store, image) = execution_store();
        let mut kv = KvStore::new();
        kv.register(&store, DatasetId::FIRST, 32).unwrap();
        kv.get(&mut store, &mut cluster, DatasetId::FIRST, 2, 5).unwrap();
        assert!(kv.get(&mut store, &mut cluster, DatasetId::FIRST, 2, 5).unwrap().hit);

        cluster.kill(&[7]);
        let (_, map, _) = ulfm::recover(&mut cluster);
        // Before recovery adopts the epoch, a get errors out rather than
        // serving the (potentially stale) cached value.
        assert!(matches!(
            kv.get(&mut store, &mut cluster, DatasetId::FIRST, 2, 5),
            Err(Error::StaleEpoch { .. })
        ));
        store.rebalance_or_acknowledge_all(&mut cluster, &map).unwrap();

        let audit = kv.validate_cache(&store, DatasetId::FIRST).unwrap();
        assert_eq!(audit.live_entries, 0);
        assert!(audit.stale_entries > 0);

        let g = kv.get(&mut store, &mut cluster, DatasetId::FIRST, 2, 5).unwrap();
        assert!(!g.hit, "epoch bump must strand the cached entry");
        assert_eq!(g.bytes.unwrap().as_slice(), &image[5 * BS..6 * BS]);
        assert_eq!(kv.stats(DatasetId::FIRST).unwrap().stale_serves, 0);
    }

    #[test]
    fn put_many_bumps_version_invalidates_and_serves_new_bytes() {
        let (mut cluster, mut store, image) = execution_store();
        let mut kv = KvStore::new();
        kv.register_with_image(&store, DatasetId::FIRST, 32, image.clone()).unwrap();
        kv.get(&mut store, &mut cluster, DatasetId::FIRST, 1, 20).unwrap();
        assert!(kv.get(&mut store, &mut cluster, DatasetId::FIRST, 1, 20).unwrap().hit);

        let v = vec![0xAB; BS];
        let before = store.version();
        kv.put_many(
            &mut store,
            &mut cluster,
            DatasetId::FIRST,
            &[(20, v.as_slice()), (21, v.as_slice())],
            Overlap::Blocking,
        )
        .unwrap();
        assert_eq!(store.version(), before + 1);

        let g = kv.get(&mut store, &mut cluster, DatasetId::FIRST, 1, 20).unwrap();
        assert!(!g.hit, "version bump must strand the cached entry");
        assert_eq!(g.bytes.unwrap().as_slice(), &v[..]);
        // untouched keys still serve the old content
        let g = kv.get(&mut store, &mut cluster, DatasetId::FIRST, 1, 19).unwrap();
        assert_eq!(g.bytes.unwrap().as_slice(), &image[19 * BS..20 * BS]);
        // the image tracked the committed write
        assert_eq!(&kv.image(DatasetId::FIRST).unwrap().unwrap()[20 * BS..21 * BS], &v[..]);
    }

    #[test]
    fn direct_resubmit_strands_cache_without_a_stale_serve() {
        let (mut cluster, mut store, mut image) = execution_store();
        let mut kv = KvStore::new();
        kv.register(&store, DatasetId::FIRST, 32).unwrap();
        kv.get(&mut store, &mut cluster, DatasetId::FIRST, 4, 30).unwrap();

        // Mutate the dataset BEHIND the kv layer (a direct resubmit).
        for b in &mut image[30 * BS..31 * BS] {
            *b = b.wrapping_add(1);
        }
        let shards = shards_of(&store, &image);
        store
            .resubmit(
                &mut cluster,
                &shards,
                ResubmitMode::Dirty(&RangeSet::new(vec![BlockRange::new(30, 31)])),
                Overlap::Blocking,
            )
            .unwrap();

        let audit = kv.validate_cache(&store, DatasetId::FIRST).unwrap();
        assert_eq!((audit.live_entries, audit.stale_entries), (0, 1));
        let g = kv.get(&mut store, &mut cluster, DatasetId::FIRST, 4, 30).unwrap();
        assert!(!g.hit);
        assert_eq!(g.bytes.unwrap().as_slice(), &image[30 * BS..31 * BS]);
        assert_eq!(kv.stats(DatasetId::FIRST).unwrap().stale_serves, 0);
    }

    #[test]
    fn invalidate_strands_entries_without_epoch_or_version_change() {
        let (mut cluster, mut store, _) = execution_store();
        let mut kv = KvStore::new();
        kv.register(&store, DatasetId::FIRST, 32).unwrap();
        kv.get(&mut store, &mut cluster, DatasetId::FIRST, 0, 1).unwrap();
        assert!(kv.get(&mut store, &mut cluster, DatasetId::FIRST, 0, 1).unwrap().hit);
        kv.invalidate(DatasetId::FIRST).unwrap();
        assert!(!kv.get(&mut store, &mut cluster, DatasetId::FIRST, 0, 1).unwrap().hit);
        assert_eq!(kv.stats(DatasetId::FIRST).unwrap().invalidations, 1);
    }

    #[test]
    fn batch_mixes_hits_and_misses_across_datasets_byte_exactly() {
        let (mut cluster, mut store, image) = execution_store();
        let cfg2 = RestoreConfig::builder(P, BS, BPP).replicas(4).build().unwrap();
        let id2 = store.create_dataset(cfg2, &cluster).unwrap();
        let image2 = flat_image(99);
        let shards2 = shards_of(&store, &image2);
        store.dataset_mut(id2).unwrap().submit(&mut cluster, &shards2).unwrap();

        let mut kv = KvStore::new();
        kv.register(&store, DatasetId::FIRST, 32).unwrap();
        kv.register(&store, id2, 32).unwrap();
        // warm two keys
        kv.get(&mut store, &mut cluster, DatasetId::FIRST, 1, 3).unwrap();
        kv.get(&mut store, &mut cluster, id2, 2, 40).unwrap();

        let mut batch = KvBatch::new();
        batch
            .get(DatasetId::FIRST, 1, 3) // hit
            .get(DatasetId::FIRST, 1, 9) // miss
            .get(id2, 2, 40) // hit
            .get(id2, 3, 9) // miss (other dataset, same key id)
            .get(id2, 3, 9); // duplicate: one fetch, two outputs
        let out = kv.execute(&mut store, &mut cluster, &batch).unwrap();
        assert_eq!((out.hits, out.misses), (2, 3));
        assert_eq!(out.value(0).unwrap(), &image[3 * BS..4 * BS]);
        assert_eq!(out.value(1).unwrap(), &image[9 * BS..10 * BS]);
        assert_eq!(out.value(2).unwrap(), &image2[40 * BS..41 * BS]);
        assert_eq!(out.value(3).unwrap(), &image2[9 * BS..10 * BS]);
        assert_eq!(out.value(4).unwrap(), &image2[9 * BS..10 * BS]);
        // exactly one request + one data phase for all misses together
        assert!(out.request_cost.sim_time_s > 0.0);
        assert!(out.data_cost.sim_time_s > 0.0);

        // everything the batch missed is now cached at current stamps
        let audit = kv.validate_cache(&store, DatasetId::FIRST).unwrap();
        assert_eq!(audit.mismatched_entries, 0);
        let mut batch2 = KvBatch::new();
        batch2.get(DatasetId::FIRST, 1, 9).get(id2, 3, 9);
        let out2 = kv.execute(&mut store, &mut cluster, &batch2).unwrap();
        assert_eq!((out2.hits, out2.misses), (2, 0));
        assert_eq!(out2.cost.total_msgs, 0);
    }

    #[test]
    fn cost_model_gets_cache_locality_and_put_virtual_invalidates() {
        let (mut cluster, mut store) = cost_model_store(P);
        let mut kv = KvStore::new();
        kv.register(&store, DatasetId::FIRST, 32).unwrap();

        let g = kv.get(&mut store, &mut cluster, DatasetId::FIRST, 2, 11).unwrap();
        assert!(!g.hit);
        assert!(g.bytes.is_none());
        assert!(kv.get(&mut store, &mut cluster, DatasetId::FIRST, 2, 11).unwrap().hit);

        kv.put_virtual(&mut store, &mut cluster, DatasetId::FIRST, &[11, 3], Overlap::Blocking)
            .unwrap();
        assert!(!kv.get(&mut store, &mut cluster, DatasetId::FIRST, 2, 11).unwrap().hit);
        assert_eq!(kv.stats(DatasetId::FIRST).unwrap().puts, 2);
    }

    #[test]
    fn scan_matches_the_image_and_bypasses_the_cache() {
        let (mut cluster, mut store, image) = execution_store();
        let mut kv = KvStore::new();
        kv.register(&store, DatasetId::FIRST, 32).unwrap();
        let s = kv.scan(&mut store, &mut cluster, DatasetId::FIRST, 5, 10, 20).unwrap();
        assert_eq!(s.bytes.unwrap(), &image[10 * BS..20 * BS]);
        // scanned keys were not cached: a point get still misses
        assert!(!kv.get(&mut store, &mut cluster, DatasetId::FIRST, 5, 12).unwrap().hit);
        assert_eq!(kv.stats(DatasetId::FIRST).unwrap().scans, 1);
    }

    #[test]
    fn key_bounds_and_registration_errors() {
        let (mut cluster, mut store, _) = execution_store();
        let mut kv = KvStore::new();
        assert!(kv.get(&mut store, &mut cluster, DatasetId::FIRST, 0, 0).is_err());
        kv.register(&store, DatasetId::FIRST, 8).unwrap();
        assert!(kv.register(&store, DatasetId::FIRST, 8).is_err());
        assert!(matches!(
            kv.get(&mut store, &mut cluster, DatasetId::FIRST, 0, N),
            Err(Error::KeyOutOfRange { key, keys, .. }) if key == N && keys == N
        ));
        let one_write: [(u64, &[u8]); 1] = [(0, &[0u8; BS])];
        assert!(kv
            .put_many(&mut store, &mut cluster, DatasetId::FIRST, &one_write, Overlap::Blocking)
            .is_err());
        assert!(kv.scan(&mut store, &mut cluster, DatasetId::FIRST, 0, 5, 5).is_err());
    }

    #[test]
    fn capacity_zero_disables_caching_but_serves_correctly() {
        let (mut cluster, mut store, image) = execution_store();
        let mut kv = KvStore::new();
        kv.register(&store, DatasetId::FIRST, 0).unwrap();
        for _ in 0..2 {
            let g = kv.get(&mut store, &mut cluster, DatasetId::FIRST, 2, 11).unwrap();
            assert!(!g.hit);
            assert_eq!(g.bytes.unwrap().as_slice(), &image[11 * BS..12 * BS]);
        }
        assert_eq!(kv.stats(DatasetId::FIRST).unwrap().hits, 0);
    }

    #[test]
    fn offset_in_walks_range_sets() {
        let rs = RangeSet::new(vec![
            BlockRange::new(2, 4),
            BlockRange::new(7, 8),
            BlockRange::new(10, 13),
        ]);
        assert_eq!(offset_in(&rs, 2), 0);
        assert_eq!(offset_in(&rs, 3), 1);
        assert_eq!(offset_in(&rs, 7), 2);
        assert_eq!(offset_in(&rs, 10), 3);
        assert_eq!(offset_in(&rs, 12), 5);
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let zipf = Zipf::new(100, 0.99);
        let mut rng = Rng::seed_from_u64(42);
        let mut counts = [0u32; 100];
        for _ in 0..10_000 {
            counts[zipf.sample(&mut rng) as usize] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > 10 * counts[90].max(1) / 2, "head must dominate the tail");
    }
}
