//! Parallel-file-system baseline (Fig 6/7).
//!
//! "Loading from the PFS is a lower bound for all checkpointing libraries
//! that have to read their data from disk" (§VI-D1). The paper measures two
//! access methods on SuperMUC-NG's Lustre:
//!
//! * **ifstream** — one file per reading PE, a private POSIX stream.
//! * **MPI I/O** — one shared file, `MPI_File_read_at_all` collective.
//!
//! The model charges (a) per-open metadata latency with contention
//! (metadata servers serialize opens; collective open amortizes it),
//! (b) per-client stream bandwidth, and (c) the aggregate PFS bandwidth
//! shared by all clients — whichever bound binds. The "cached" variant
//! (Fig 6's dashed series) reads from the node page cache instead.
//! Constants live in [`PfsConfig`](crate::config::PfsConfig) and are
//! calibrated in EXPERIMENTS.md §Calibration.

use crate::config::PfsConfig;

/// PFS access method, matching the paper's two measured series.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PfsMethod {
    /// One file per PE, C++ `ifstream`-style.
    IfStream,
    /// One shared file, `MPI_File_read_at_all`.
    MpiIo,
}

/// Cache state of the input file(s) (Fig 6 distinguishes first/repeat read).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheState {
    Uncached,
    Cached,
}

/// The modeled PFS.
#[derive(Debug, Clone)]
pub struct Pfs {
    cfg: PfsConfig,
}

impl Pfs {
    pub fn new(cfg: PfsConfig) -> Self {
        Pfs { cfg }
    }

    /// Seconds for `clients` PEs to each read `bytes_per_client` bytes.
    pub fn read_time_s(
        &self,
        method: PfsMethod,
        cache: CacheState,
        clients: usize,
        bytes_per_client: u64,
    ) -> f64 {
        if clients == 0 || bytes_per_client == 0 {
            return 0.0;
        }
        let c = &self.cfg;
        let total = clients as f64 * bytes_per_client as f64;

        // Metadata/open phase. Independent opens contend on the metadata
        // servers (we charge sqrt-contention: MDS scale out, but not
        // linearly); a collective open costs one open + a barrier-ish term.
        // Cached re-reads hit warm dentries: one uncontended open.
        let open = match (method, cache) {
            (_, CacheState::Cached) => c.open_latency_s,
            (PfsMethod::IfStream, _) => c.open_latency_s * (clients as f64).sqrt(),
            (PfsMethod::MpiIo, _) => {
                c.open_latency_s * (clients as f64).log2().max(1.0) * 0.1 + c.open_latency_s
            }
        };

        let transfer = match cache {
            CacheState::Cached => {
                // Page-cache read: per-node memory bandwidth, no PFS limits.
                bytes_per_client as f64 / c.page_cache_bw_bytes_per_s
            }
            CacheState::Uncached => {
                // Per-client stream bound and aggregate bound; MPI I/O's
                // collective buffering reaches a higher fraction of the
                // aggregate (fewer, larger, aligned stripes; ifstream
                // clients fight for OSTs once clients >> OSTs).
                let per_client = bytes_per_client as f64 / c.per_client_bw_bytes_per_s;
                let eff_aggregate = match method {
                    PfsMethod::MpiIo => c.aggregate_bw_bytes_per_s,
                    PfsMethod::IfStream => {
                        let contention =
                            1.0 + (clients as f64 / c.osts as f64).max(0.0).sqrt();
                        c.aggregate_bw_bytes_per_s / contention
                    }
                };
                per_client.max(total / eff_aggregate)
            }
        };
        open + transfer
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pfs() -> Pfs {
        Pfs::new(PfsConfig::default())
    }

    #[test]
    fn zero_work_is_free() {
        assert_eq!(pfs().read_time_s(PfsMethod::IfStream, CacheState::Uncached, 0, 1), 0.0);
        assert_eq!(pfs().read_time_s(PfsMethod::MpiIo, CacheState::Cached, 10, 0), 0.0);
    }

    #[test]
    fn cached_faster_than_uncached() {
        let p = pfs();
        let mib16 = 16 * 1024 * 1024;
        for &clients in &[48usize, 1536, 24576] {
            let cold = p.read_time_s(PfsMethod::IfStream, CacheState::Uncached, clients, mib16);
            let warm = p.read_time_s(PfsMethod::IfStream, CacheState::Cached, clients, mib16);
            assert!(warm < cold, "clients={clients}: warm {warm} !< cold {cold}");
        }
    }

    #[test]
    fn aggregate_bandwidth_binds_at_scale() {
        // Fig 7's shape: PFS time grows roughly linearly once aggregate
        // bandwidth saturates, while at small scale the per-client stream
        // dominates.
        let p = pfs();
        let mib16 = 16 * 1024 * 1024u64;
        let t_small = p.read_time_s(PfsMethod::MpiIo, CacheState::Uncached, 48, mib16);
        let t_big = p.read_time_s(PfsMethod::MpiIo, CacheState::Uncached, 24576, mib16);
        assert!(t_big > t_small * 50.0, "t_big {t_big} vs t_small {t_small}");
    }

    #[test]
    fn mpiio_beats_ifstream_at_scale() {
        // Fig 7: MPI I/O is faster than per-PE ifstream at high PE counts.
        let p = pfs();
        let mib16 = 16 * 1024 * 1024u64;
        let ifs = p.read_time_s(PfsMethod::IfStream, CacheState::Uncached, 24576, mib16);
        let mio = p.read_time_s(PfsMethod::MpiIo, CacheState::Uncached, 24576, mib16);
        assert!(mio < ifs);
    }

    #[test]
    fn open_latency_visible_for_tiny_reads() {
        let p = pfs();
        let t = p.read_time_s(PfsMethod::IfStream, CacheState::Cached, 4096, 64);
        assert!(t > PfsConfig::default().open_latency_s);
    }
}
