//! END-TO-END DRIVER (DESIGN.md §4, recorded in EXPERIMENTS.md): the
//! paper's §VI-C k-means workload on a real small cluster with all three
//! layers composed:
//!
//!   L1 Pallas kernel  → AOT HLO artifact (`kmeans_step_small`)
//!   L2 JAX model      → executed from Rust via PJRT, every PE, every iter
//!   L3 Rust           → simulated 16-PE cluster, ULFM recovery, ReStore
//!
//! 16 PEs × 4096 points × 32 dims (0.5 MiB/PE), 60 Lloyd iterations, ~20 %
//! of PEs failing mid-run (scaled up from the paper's 1 % so a 16-PE demo
//! actually exercises recovery). Prints the per-phase Fig 5 breakdown and
//! the loss (inertia) curve, and cross-checks the run against a
//! failure-free control.
//!
//! Run with: `cargo run --release --example kmeans_failures`

use restore::apps::kmeans::{self, KmeansParams};
use restore::config::RestoreConfig;
use restore::metrics::fmt_time;
use restore::runtime::Engine;
use restore::simnet::cluster::Cluster;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let p = 16;
    let params = KmeansParams {
        points_per_pe: 4096,
        dims: 32,
        k: 20,
        iterations: 60,
        failure_fraction: 0.2,
        seed: 42,
        step_variant: "kmeans_step_small".into(),
        update_variant: "kmeans_update".into(),
    };
    let bytes_per_pe = params.points_per_pe * params.dims * 4;
    let cfg = RestoreConfig::builder(p, 64, bytes_per_pe / 64)
        .replicas(4)
        .perm_range_bytes(Some(64 * 1024))
        .build()?;

    println!(
        "k-means end-to-end: p={p}, {} points x {} dims per PE ({} KiB), k={}, {} iterations",
        params.points_per_pe,
        params.dims,
        bytes_per_pe / 1024,
        params.k,
        params.iterations
    );

    // --- failure-free control run ------------------------------------------
    let mut engine = Engine::load_default()?;
    let mut cluster = Cluster::new_execution(p, 4);
    let mut control = params.clone();
    control.failure_fraction = 0.0;
    let clean = kmeans::run_execution(&mut cluster, &mut engine, &cfg, &control)?;
    println!("\ncontrol (no failures): inertia {:.1}", clean.final_inertia);

    // --- the fault-tolerant run ---------------------------------------------
    let mut engine = Engine::load_default()?;
    let mut cluster = Cluster::new_execution(p, 4);
    let rep = kmeans::run_execution(&mut cluster, &mut engine, &cfg, &params)?;

    println!(
        "with failures: {} PEs failed in {} events, {} survivors finished",
        rep.failures,
        rep.failure_events,
        cluster.n_alive()
    );
    println!("  final inertia        {:.1}", rep.final_inertia);
    println!("\nFig-5-style breakdown (simulated time):");
    println!("  overall              {}", fmt_time(rep.sim_total_s));
    println!("  k-means loop         {}", fmt_time(rep.sim_kmeans_loop_s));
    println!(
        "  ReStore overhead     {}  ({:.2} % of overall)",
        fmt_time(rep.sim_restore_s),
        100.0 * rep.sim_restore_s / rep.sim_total_s
    );
    println!("  MPI recovery         {}", fmt_time(rep.sim_mpi_recovery_s));
    println!(
        "\nPJRT: {} kernel executions, {} wall time",
        engine.exec_calls,
        fmt_time(rep.wall_compute_s)
    );

    // Exactness check: the global multiset of points after all recoveries
    // must be bit-identical to the control's (the paper's recovery claim).
    // Inertia itself is chaotic under f32 reordering — k-means can settle
    // in a different local optimum when partial sums regroup — so it is
    // reported, not asserted.
    println!(
        "\ncross-check vs control: points checksum {:#018x} vs {:#018x} {}",
        rep.points_checksum,
        clean.points_checksum,
        if rep.points_checksum == clean.points_checksum {
            "(OK — every recovered point bit-exact)"
        } else {
            "(MISMATCH!)"
        }
    );
    let rel = (rep.final_inertia - clean.final_inertia).abs() / clean.final_inertia;
    println!("inertia difference vs control: {rel:.2e} (informational: f32-order chaos)");
    if rep.points_checksum != clean.points_checksum {
        return Err("recovered data diverged from control".into());
    }
    Ok(())
}
