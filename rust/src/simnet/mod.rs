//! Simulated fault-tolerant cluster substrate.
//!
//! The paper runs on MPI + ULFM on SuperMUC-NG and — because ULFM itself was
//! too unstable for benchmarks — *simulates failures* by removing processes
//! from the computation (`MPI_Comm_split`) and replacing recovery calls with
//! functionally similar ones (§VI-A). We reproduce exactly that methodology
//! in-process:
//!
//! * [`topology`] — nodes / PEs / failure domains (48 PEs share a node+NIC).
//! * [`network`] — the α-β(-NIC) cost model that converts *exact* per-PE
//!   message/byte schedules into simulated time (DESIGN.md §1).
//! * [`cluster`] — the world: alive set, message exchange (really moving
//!   bytes in execution mode), collectives, the simulated clock.
//! * [`ulfm`] — failure detection + agreement + communicator shrinking,
//!   mirroring `MPIX_Comm_agree` / `MPIX_Comm_shrink`.
//! * [`failure`] — failure schedules (uniform, the paper's §VI-C discrete
//!   exponential decay, node-correlated).

pub mod cluster;
pub mod failure;
pub mod network;
pub mod topology;
pub mod ulfm;

pub use cluster::{Cluster, Payload};
pub use network::PhaseCost;
pub use topology::Topology;
