"""Pallas phylogenetic-likelihood kernel vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.phylo import phylo_loglik
from compile.kernels.ref import phylo_clv_ref, phylo_loglik_ref


def random_case(rng, s, a=4):
    # CLVs are probabilities in (0, 1]; transition matrices are row-stochastic
    # (as produced by expm(Q t) for a CTMC rate matrix Q).
    clv_l = jnp.asarray(rng.uniform(0.05, 1.0, (s, a)), dtype=jnp.float32)
    clv_r = jnp.asarray(rng.uniform(0.05, 1.0, (s, a)), dtype=jnp.float32)

    def stoch():
        m = rng.uniform(0.05, 1.0, (a, a))
        return jnp.asarray(m / m.sum(axis=1, keepdims=True), dtype=jnp.float32)

    p_l, p_r = stoch(), stoch()
    freqs = rng.uniform(0.1, 1.0, a)
    freqs = jnp.asarray(freqs / freqs.sum(), dtype=jnp.float32)
    weights = jnp.asarray(rng.integers(1, 5, s), dtype=jnp.float32)
    return clv_l, clv_r, p_l, p_r, freqs, weights


def check(args, tile):
    clv, ll = phylo_loglik(*args, tile=tile)
    rclv, rll = phylo_loglik_ref(*args)
    np.testing.assert_allclose(clv, rclv, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ll, rll, rtol=1e-4, atol=1e-2)


def test_paper_shape():
    check(random_case(np.random.default_rng(0), 16384), tile=4096)


def test_small_shape_multi_tile():
    check(random_case(np.random.default_rng(1), 1024), tile=256)


def test_clv_matches_ref_exactly_on_identity():
    # With identity transition matrices the parent CLV is the elementwise
    # product of the children.
    s, a = 512, 4
    rng = np.random.default_rng(2)
    clv_l = jnp.asarray(rng.uniform(0.1, 1.0, (s, a)), dtype=jnp.float32)
    clv_r = jnp.asarray(rng.uniform(0.1, 1.0, (s, a)), dtype=jnp.float32)
    eye = jnp.eye(a, dtype=jnp.float32)
    freqs = jnp.full((a,), 0.25, dtype=jnp.float32)
    weights = jnp.ones((s,), dtype=jnp.float32)
    clv, _ = phylo_loglik(clv_l, clv_r, eye, eye, freqs, weights, tile=128)
    np.testing.assert_allclose(clv, clv_l * clv_r, rtol=1e-6)
    np.testing.assert_allclose(
        clv, phylo_clv_ref(clv_l, clv_r, eye, eye), rtol=1e-6
    )


def test_weights_scale_loglik():
    args = random_case(np.random.default_rng(3), 512)
    clv_l, clv_r, p_l, p_r, freqs, weights = args
    _, ll1 = phylo_loglik(clv_l, clv_r, p_l, p_r, freqs, weights, tile=128)
    _, ll2 = phylo_loglik(clv_l, clv_r, p_l, p_r, freqs, 2.0 * weights, tile=128)
    np.testing.assert_allclose(ll2, 2.0 * ll1, rtol=1e-4)


def test_underflow_is_clamped():
    # Tiny CLVs would produce log(0) without the clamp.
    s, a = 128, 4
    tiny = jnp.full((s, a), 1e-30, dtype=jnp.float32)
    p = jnp.full((a, a), 0.25, dtype=jnp.float32)
    freqs = jnp.full((a,), 0.25, dtype=jnp.float32)
    weights = jnp.ones((s,), dtype=jnp.float32)
    _, ll = phylo_loglik(tiny, tiny, p, p, freqs, weights, tile=128)
    assert np.isfinite(float(ll))


@settings(max_examples=20, deadline=None)
@given(
    tiles=st.integers(1, 4),
    tile=st.sampled_from([128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shapes(tiles, tile, seed):
    check(random_case(np.random.default_rng(seed), tiles * tile), tile=tile)
